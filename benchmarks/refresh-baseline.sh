#!/usr/bin/env sh
# Refresh the committed bench baseline in benchmarks/baseline: run every
# registered bench under the CI sampling budget and snapshot the results
# there. Run on a quiet machine, then commit the BENCH_*.json files.
set -eu
cd "$(dirname "$0")/.."
: "${BENCH_BUDGET_MS:=60}"
export BENCH_BUDGET_MS
BENCH_DIR="$(pwd)/benchmarks/baseline" cargo bench 2>&1 | tail -40
ls -l benchmarks/baseline/BENCH_*.json
