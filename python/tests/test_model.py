"""L2 model correctness: prefill/decode/probe consistency and shapes.

The central invariant: the incremental decode path (with Pallas kernels and
an explicit KV cache) must be numerically consistent with the full
teacher-forced forward pass — otherwise the serving stack would diverge
from the trained model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import datagen as D
from compile import vocab as V
from compile.kernels import entropy_ref
from compile.model import (decode_batch, decode_step, forward_all,
                           init_params, main_config, param_specs, prefill,
                           probe, proxy_config, unflatten_params,
                           flatten_params)

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

SEQ = 64  # shorter sequence for test speed


@pytest.fixture(scope="module", params=["main", "proxy"])
def model(request):
    mk = main_config if request.param == "main" else proxy_config
    cfg = mk(V.VOCAB, SEQ)
    params = init_params(cfg, jax.random.PRNGKey(7))
    return cfg, params


def _rand_tokens(rng, s):
    return jnp.asarray(rng.integers(1, V.VOCAB, size=s), jnp.int32)


def test_forward_shapes(model):
    cfg, params = model
    toks = _rand_tokens(np.random.default_rng(0), SEQ)
    logits, kc, vc = forward_all(cfg, params, toks)
    assert logits.shape == (SEQ, cfg.vocab)
    assert kc.shape == (cfg.n_layer, cfg.n_head, SEQ, cfg.d_head)
    assert vc.shape == kc.shape
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_matches_forward(model):
    cfg, params = model
    toks = _rand_tokens(np.random.default_rng(1), SEQ)
    logits_all, _, _ = forward_all(cfg, params, toks)
    for n in [1, 5, SEQ]:
        last, _, _ = prefill(cfg, params, toks, jnp.int32(n))
        np.testing.assert_allclose(last, logits_all[n - 1],
                                   rtol=1e-4, atol=1e-4)


@given(n=st.integers(2, 20), steps=st.integers(1, 12),
       seed=st.integers(0, 1000))
def test_decode_matches_forward(model, n, steps, seed):
    """prefill(n) + k decode steps == teacher-forced logits at n-1+k."""
    cfg, params = model
    toks = _rand_tokens(np.random.default_rng(seed), SEQ)
    logits_all, _, _ = forward_all(cfg, params, toks)
    _, kc, vc = prefill(cfg, params, toks, jnp.int32(n))
    for p in range(n, min(n + steps, SEQ)):
        lg, kc, vc = decode_step(cfg, params, kc, vc, jnp.int32(p), toks[p])
        np.testing.assert_allclose(lg, logits_all[p], rtol=1e-3, atol=1e-3)


def test_probe_does_not_commit_suffix(model):
    """Probing must leave the caller's cache usable: decoding after a probe
    gives identical logits to decoding without the probe."""
    cfg, params = model
    toks = _rand_tokens(np.random.default_rng(2), SEQ)
    _, kc, vc = prefill(cfg, params, toks, jnp.int32(10))
    suffix = jnp.asarray([V.ETHINK, V.FINAL, V.ANS, 0], jnp.int32)
    probe(cfg, params, kc, vc, jnp.int32(10), suffix, jnp.int32(3))
    # caller's kc/vc were never mutated (functional), so this is trivially
    # true in jax — the real check is the rust runtime's buffer discipline;
    # here we check the probe's *logits* equal manual uncommitted decode.
    lg_direct, _, _ = decode_step(cfg, params, kc, vc, jnp.int32(10),
                                  suffix[0])
    eat, lg_probe = probe(cfg, params, kc, vc, jnp.int32(10), suffix,
                          jnp.int32(1))
    np.testing.assert_allclose(lg_probe, lg_direct, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(eat, entropy_ref(lg_direct), rtol=1e-4,
                               atol=1e-4)


@given(slen=st.integers(1, 4))
def test_probe_suffix_length(model, slen):
    """EAT must equal the entropy after exactly `slen` suffix steps."""
    cfg, params = model
    toks = _rand_tokens(np.random.default_rng(3), SEQ)
    _, kc, vc = prefill(cfg, params, toks, jnp.int32(8))
    suffix = jnp.asarray([V.ETHINK, V.FINAL, V.ANS, V.NL], jnp.int32)
    eat, lg_probe = probe(cfg, params, kc, vc, jnp.int32(8), suffix,
                          jnp.int32(slen))
    kc2, vc2, lg = kc, vc, None
    for t in range(slen):
        lg, kc2, vc2 = decode_step(cfg, params, kc2, vc2, jnp.int32(8 + t),
                                   suffix[t])
    np.testing.assert_allclose(lg_probe, lg, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(eat, entropy_ref(lg), rtol=1e-4, atol=1e-4)


def test_decode_batch_matches_sequential(model):
    cfg, params = model
    rng = np.random.default_rng(4)
    B = 3
    kcs, vcs, poss, toks_b, want = [], [], [], [], []
    for b in range(B):
        toks = _rand_tokens(rng, SEQ)
        n = 5 + b
        _, kc, vc = prefill(cfg, params, toks, jnp.int32(n))
        lg, kc1, vc1 = decode_step(cfg, params, kc, vc, jnp.int32(n), toks[n])
        kcs.append(kc); vcs.append(vc); poss.append(n); toks_b.append(toks[n])
        want.append(lg)
    lgb, kcb, vcb = decode_batch(cfg, params, jnp.stack(kcs), jnp.stack(vcs),
                                 jnp.asarray(poss, jnp.int32),
                                 jnp.stack(toks_b))
    for b in range(B):
        np.testing.assert_allclose(lgb[b], want[b], rtol=1e-3, atol=1e-3)


def test_param_flatten_roundtrip(model):
    cfg, params = model
    flat = flatten_params(cfg, params)
    back = unflatten_params(cfg, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(params[k], back[k])


def test_param_specs_cover_all_layers(model):
    cfg, _ = model
    names = [n for n, _ in param_specs(cfg)]
    assert len(names) == len(set(names)), "duplicate param names"
    for l in range(cfg.n_layer):
        assert f"layer{l}.wq" in names
    assert names[0] == "tok_emb" and names[-1] == "head"
