"""Trainer smoke tests: the hand-rolled Adam must actually descend, and
checkpoints must round-trip exactly (they are the serving weights)."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import datagen as D
from compile import vocab as V
from compile import train as T
from compile.model import init_params, proxy_config


def _tiny_cfg():
    # smallest possible config for speed
    cfg = proxy_config(V.VOCAB, 64)
    return cfg


def test_adam_descends():
    cfg = _tiny_cfg()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = T.adam_init(params)
    toks, mask = D.make_batch(rng, 8)
    toks, mask = toks[:, :64], mask[:, :64]
    first = None
    for _ in range(20):
        params, opt, loss = T.adam_step(cfg, params, opt,
                                        jnp.asarray(toks), jnp.asarray(mask))
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.8, (first, float(loss))


def test_loss_ignores_padding():
    """Poisoning padded positions must not change the loss."""
    cfg = _tiny_cfg()
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks, mask = D.make_batch(rng, 4)
    toks, mask = toks[:, :64].copy(), mask[:, :64]
    l1 = T.sequence_loss(cfg, params, jnp.asarray(toks), jnp.asarray(mask))
    # overwrite pad-region *targets* (mask==0 positions are never targets);
    # rows whose trace was cut by the 64-token slice have no EOS and no
    # padding, so skip them
    for b in range(4):
        eos = np.where(toks[b] == V.EOS)[0]
        if eos.size and eos[0] + 2 < 64:
            toks[b, eos[0] + 2:] = 9
    l2 = T.sequence_loss(cfg, params, jnp.asarray(toks), jnp.asarray(mask))
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(2))
    path = os.path.join(tmp_path, "ckpt.npz")
    T.save_checkpoint(cfg, params, path)
    back = T.load_checkpoint(cfg, path)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]),
                                      np.asarray(back[k]))


def test_eval_answer_accuracy_range():
    # eval_answer_accuracy builds full-length (SEQ_LEN) batches, so the
    # model must be configured with the corpus sequence length
    cfg = proxy_config(V.VOCAB, D.SEQ_LEN)
    params = init_params(cfg, jax.random.PRNGKey(3))
    acc = T.eval_answer_accuracy(cfg, params, np.random.default_rng(0),
                                 n_eval=8)
    assert 0.0 <= acc <= 1.0
