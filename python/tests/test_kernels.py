"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes, scales, masks and block sizes; every property is
an assert_allclose against ref.py — the core correctness signal for the
compute layer that the Rust coordinator ultimately executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (decode_attention, decode_attention_ref, entropy,
                             entropy_ref)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# entropy kernel
# ---------------------------------------------------------------------------


@given(v=st.integers(2, 400), scale=st.floats(0.01, 20.0),
       seed=st.integers(0, 2**31 - 1))
def test_entropy_matches_ref(v, scale, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(v,)) * scale, jnp.float32)
    np.testing.assert_allclose(entropy(z), entropy_ref(z),
                               rtol=1e-4, atol=1e-4)


@given(v=st.integers(2, 200), blk=st.sampled_from([8, 16, 64, 128, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_entropy_block_invariance(v, blk, seed):
    """The result must not depend on the VMEM tile size."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(v,)) * 5, jnp.float32)
    np.testing.assert_allclose(entropy(z, block=blk), entropy_ref(z),
                               rtol=1e-4, atol=1e-4)


@given(b=st.integers(1, 6), v=st.integers(2, 100),
       seed=st.integers(0, 2**31 - 1))
def test_entropy_batched(b, v, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(b, v)) * 3, jnp.float32)
    np.testing.assert_allclose(entropy(z), entropy_ref(z),
                               rtol=1e-4, atol=1e-4)


def test_entropy_uniform_is_log_v():
    """H(uniform over V) = log V — the analytic anchor."""
    for v in [2, 48, 333]:
        z = jnp.zeros((v,), jnp.float32)
        np.testing.assert_allclose(entropy(z), np.log(v), rtol=1e-5)


def test_entropy_onehot_is_zero():
    """A (near-)deterministic distribution has (near-)zero entropy."""
    z = jnp.asarray([50.0] + [0.0] * 47, jnp.float32)
    assert float(entropy(z)) < 1e-4


def test_entropy_extreme_logits_stable():
    """Numerical stability: huge logits must not overflow to NaN/Inf."""
    z = jnp.asarray([1e4, 1e4 - 5, -1e4, 0.0], jnp.float32)
    h = float(entropy(z))
    assert np.isfinite(h)
    np.testing.assert_allclose(h, float(entropy_ref(z)), atol=1e-4)


def test_entropy_shift_invariance():
    """H(z + c) == H(z) for any constant shift."""
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(48,)) * 4, jnp.float32)
    np.testing.assert_allclose(entropy(z), entropy(z + 1234.5), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# decode attention kernel
# ---------------------------------------------------------------------------


@given(h=st.integers(1, 4), dh=st.sampled_from([8, 16, 32]),
       s=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**31 - 1), data=st.data())
def test_decode_attention_matches_ref(h, dh, s, seed, data):
    vl = data.draw(st.integers(1, s))
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(h, s, dh)), jnp.float32)
    out = decode_attention(q, k, v, vl)
    ref = decode_attention_ref(q, k, v, vl)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@given(blk=st.sampled_from([16, 32, 64, 128]), seed=st.integers(0, 2**31 - 1))
def test_decode_attention_block_invariance(blk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 16)), jnp.float32)
    out = decode_attention(q, k, v, 77, block=blk)
    ref = decode_attention_ref(q, k, v, 77)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_decode_attention_single_valid_position():
    """With valid_len=1 attention must return exactly v[:, 0, :]."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 32, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 32, 8)), jnp.float32)
    out = decode_attention(q, k, v, 1)
    np.testing.assert_allclose(out, v[:, 0, :], rtol=1e-5, atol=1e-5)


def test_decode_attention_mask_excludes_future():
    """Values beyond valid_len must not influence the output."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 8)), jnp.float32)
    v = np.asarray(rng.normal(size=(1, 64, 8)), np.float32)
    out1 = decode_attention(q, k, jnp.asarray(v), 10)
    v2 = v.copy()
    v2[:, 10:, :] = 1e6  # poison the masked region
    out2 = decode_attention(q, k, jnp.asarray(v2), 10)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_decode_attention_rejects_indivisible_block():
    q = jnp.zeros((1, 8), jnp.float32)
    k = jnp.zeros((1, 48, 8), jnp.float32)
    with pytest.raises(AssertionError):
        decode_attention(q, k, k, 5, block=32)
