"""Corpus generator invariants: the teacher must emit exactly the reasoning
format the paper assumes (Eq. 4) and the answers must be arithmetically
correct — otherwise the trained model learns the wrong task."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import datagen as D
from compile import vocab as V

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


def _parse(trace):
    """Split a trace into (ops, corrupt?, lines, answer)."""
    t = list(trace)
    assert t[0] == V.BOS
    sep = t.index(V.SEP)
    ops = t[2:sep]
    think = t.index(V.THINK)
    ethink = t.index(V.ETHINK)
    body = t[think + 1:ethink]
    tail = t[ethink:]
    assert tail[0] == V.ETHINK and tail[1] == V.FINAL
    ans_i = tail.index(V.ANS)
    answer = tail[ans_i + 1]
    assert tail[ans_i + 2] == V.EOS
    return ops, body, answer


@given(seed=st.integers(0, 10_000))
def test_trace_structure(seed):
    rng = np.random.default_rng(seed)
    t = D.make_trace(rng)
    assert len(t) <= D.SEQ_LEN
    ops, body, answer = _parse(t)
    assert 2 <= len(ops) <= 10
    # body is a sequence of NL-terminated lines
    if body:
        assert body[-1] == V.NL


@given(seed=st.integers(0, 10_000))
def test_uncorrupted_answer_is_true_sum(seed):
    rng = np.random.default_rng(seed)
    t = D.make_trace(rng, p_corrupt=0.0)
    ops, _, answer = _parse(t)
    vals = [V.num_value(o) for o in ops]
    assert V.num_value(answer) == sum(vals) % V.MOD


@given(seed=st.integers(0, 10_000))
def test_full_trace_partial_sums_correct(seed):
    """Compute lines carry correct running partial sums."""
    rng = np.random.default_rng(seed)
    t = D.make_trace(rng, p_corrupt=0.0, p_early=0.0)
    ops, body, _ = _parse(t)
    vals = [V.num_value(o) for o in ops]
    lines, cur = [], []
    for tok in body:
        if tok == V.NL:
            lines.append(cur); cur = []
        else:
            cur.append(tok)
    compute = [l for l in lines if l[0] != V.VER]
    assert len(compute) == len(vals)
    s = 0
    for i, line in enumerate(compute):
        s = (s + vals[i]) % V.MOD
        assert V.num_value(line[0]) == (i + 1) % V.MOD
        assert V.num_value(line[1]) == s
    # verify lines re-confirm the final total (R1-style double-checking)
    total = sum(vals) % V.MOD
    for l in lines:
        if l[0] == V.VER:
            assert 1 <= V.num_value(l[1]) <= len(vals)
            assert V.num_value(l[2]) == total


@given(seed=st.integers(0, 10_000))
def test_early_stop_trace_answer_is_true_sum(seed):
    """Even when truncated, the supervised answer is the true total —
    the calibration-critical property (DESIGN.md §3)."""
    rng = np.random.default_rng(seed)
    t = D.make_trace(rng, p_corrupt=0.0, p_early=1.0)
    ops, body, answer = _parse(t)
    vals = [V.num_value(o) for o in ops]
    assert V.num_value(answer) == sum(vals) % V.MOD


def test_early_stop_remaining_ops_skewed_small():
    """Early-stop truncations concentrate on small remaining-op counts r,
    which is what teaches partial lookahead and produces the paper's
    gradual EAT decline (DESIGN.md §3)."""
    rng = np.random.default_rng(0)
    remaining = []
    for _ in range(2000):
        t = D.make_trace(rng, p_corrupt=0.0, p_early=1.0)
        ops, body, _ = _parse(t)
        lines = sum(1 for tok in body if tok == V.NL)
        remaining.append(len(ops) - lines)
    remaining = np.asarray(remaining)
    assert (remaining >= 1).all()  # j < n: never a full chain
    frac_small = np.mean(remaining <= 3)
    assert frac_small > 0.7, f"r<=3 fraction {frac_small}"
    assert np.mean(remaining == 1) > 0.25


@given(seed=st.integers(0, 10_000))
def test_corrupted_trace_contains_unk(seed):
    rng = np.random.default_rng(seed)
    t = D.make_trace(rng, p_corrupt=1.0, p_early=0.0)
    assert V.UNK in t


def test_batch_shapes_and_mask():
    rng = np.random.default_rng(0)
    xs, mask = D.make_batch(rng, 8)
    assert xs.shape == (8, D.SEQ_LEN) and mask.shape == xs.shape
    for b in range(8):
        row, m = xs[b], mask[b]
        ln = int(np.argmax(row == V.EOS)) + 1
        assert m[: ln - 1].all() and not m[ln - 1:].any()
        assert (row[ln:] == V.PAD).all()


@given(seed=st.integers(0, 5000))
def test_tool_trace_answer_is_last_operand(seed):
    rng = np.random.default_rng(seed)
    t = D.make_tool_trace(rng)
    assert t[1] == V.TOOL
    sep = t.index(V.SEP)
    last_op = V.num_value(t[sep - 1])
    ans_i = t.index(V.ANS)
    assert V.num_value(t[ans_i + 1]) == last_op
    assert t[t.index(V.ETHINK) + 2] == V.LBRACK  # tool-call opener (Eq. 15)


def test_question_tokens_corruption():
    q = D.question_tokens([1, 2, 3], corrupt_at=1)
    assert q == [V.BOS, V.Q, V.num(1), V.UNK, V.num(3), V.SEP]


def test_vocab_layout_stable():
    """Token ids are baked into trained checkpoints — they must not drift."""
    js = V.vocab_json()
    assert js["pad"] == 0 and js["bos"] == 1 and js["eos"] == 2
    assert js["think"] == 3 and js["ethink"] == 4 and js["nl"] == 5
    assert js["final"] == 6 and js["ans"] == 7
    assert js["num0"] == 16 and js["mod"] == 32 and js["vocab"] == 48
