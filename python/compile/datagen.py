"""Synthetic chain-sum reasoning corpus generator (training side).

The teacher writes traces in the reasoning-model format of the paper
(Eq. 4). Compute lines accumulate the running sum; verification lines
re-state earlier partial sums and form the *overthinking* tail that the
trained model then imitates at inference time — giving the Rust coordinator
real overthinking to cut with EAT.

The Rust eval harness generates only *questions* (datasets/chainsum.rs);
reasoning at eval time is produced by the trained model itself.
"""

from __future__ import annotations

import numpy as np

from . import vocab as V

SEQ_LEN = 128  # fixed training/serving sequence length (positions 0..127)


def question_tokens(ops: list[int], corrupt_at: int | None = None) -> list[int]:
    """``BOS Q a_1 .. a_n SEP`` — the prompt shared by train and serve."""
    toks = [V.BOS, V.Q]
    for i, a in enumerate(ops):
        toks.append(V.UNK if corrupt_at == i else V.num(a))
    toks.append(V.SEP)
    return toks


def compute_line(i: int, partial: int, corrupted: bool) -> list[int]:
    """Reasoning line ``i p_i NL`` (``i UNK NL`` once corruption is hit)."""
    return [V.num(i), V.UNK if corrupted else V.num(partial), V.NL]


def verify_line(j: int, total: int, corrupted: bool) -> list[int]:
    """Overthinking line ``V j total NL``: the model re-confirms its final
    answer (like an R1-style "wait, let me double-check... yes, total")
    while citing some step index j. The restated value is always the
    *current total* — matching how reasoning models re-verify a conclusion
    rather than a random intermediate."""
    return [V.VER, V.num(j), V.UNK if corrupted else V.num(total), V.NL]


def answer_tail(ans: int | None, rng: np.random.Generator) -> list[int]:
    """``</think> FINAL ANS v EOS``; corrupted questions get a random guess."""
    v = int(rng.integers(0, V.MOD)) if ans is None else ans % V.MOD
    return [V.ETHINK, V.FINAL, V.ANS, V.num(v), V.EOS]


def make_trace(
    rng: np.random.Generator,
    n_min: int = 2,
    n_max: int = 10,
    p_corrupt: float = 0.08,
    p_early: float = 0.4,
    max_verify_factor: float = 2.0,
) -> list[int]:
    """One full teacher trace: question + reasoning + answer, <= SEQ_LEN.

    With probability ``p_early`` the trace is an *early-stop* trace: the
    reasoning is truncated at a random compute line j < n and the answer is
    still the TRUE total. This is what makes the trained model *calibrated*
    under forced truncation — the supervision target after a truncated chain
    is the genuine final sum (which requires summing the n-j remaining
    operands in a single step, a task whose single-shot difficulty grows
    with n-j). Without these traces the model would learn the degenerate
    "copy the last partial sum" rule and be confidently wrong at every
    truncation point, destroying the paper's calibration premise (App. C).
    """
    n = int(rng.integers(n_min, n_max + 1))
    ops = rng.integers(0, V.MOD, size=n).tolist()
    corrupt_at = int(rng.integers(0, n)) if rng.random() < p_corrupt else None
    total = sum(ops) % V.MOD

    toks = question_tokens(ops, corrupt_at)
    toks.append(V.THINK)

    if corrupt_at is None and rng.random() < p_early:
        # Early-stop trace: j compute lines, then the true answer. The
        # remaining-op count r = n - j is drawn skewed toward SMALL values
        # so the model learns partial lookahead (answering with r ops left
        # is an r-term one-shot sum, learnable for small r). This is what
        # produces the paper's *gradual* EAT decline along the chain —
        # uncertainty shrinks as fewer operands remain — rather than a
        # flat-uniform plateau followed by a cliff.
        roll = rng.random()
        if roll < 0.35:
            r = 1
        elif roll < 0.6:
            r = 2
        elif roll < 0.8:
            r = 3
        else:
            r = int(rng.integers(1, n + 1))
        j = max(n - r, 0)
        s = 0
        for i in range(j):
            s = (s + ops[i]) % V.MOD
            toks.extend(compute_line(i + 1, s, False))
        toks.extend(answer_tail(total, rng))
        assert len(toks) <= SEQ_LEN, f"trace too long: {len(toks)}"
        return toks

    partials, s, corrupted = [], 0, False
    for i, a in enumerate(ops):
        if corrupt_at is not None and i >= corrupt_at:
            corrupted = True
        s = (s + a) % V.MOD
        partials.append(None if corrupted else s)
        toks.extend(compute_line(i + 1, 0 if corrupted else s, corrupted))

    # Overthinking tail: re-verify random prefix sums. Length varies so that
    # the corpus covers all positions up to SEQ_LEN (late positional
    # embeddings must be trained) and so EAT has a flat region to detect.
    budget = SEQ_LEN - len(toks) - 5
    n_verify = int(rng.integers(0, int(max_verify_factor * n) + 1))
    if rng.random() < 0.25:  # a quarter of traces fill (train late positions)
        n_verify = budget // 4
    for _ in range(min(n_verify, budget // 4)):
        j = int(rng.integers(1, n + 1))
        toks.extend(verify_line(j, 0 if corrupted else s, corrupted))

    ans = None if corrupted else s
    toks.extend(answer_tail(ans, rng))
    assert len(toks) <= SEQ_LEN, f"trace too long: {len(toks)}"
    return toks


def make_tool_trace(rng: np.random.Generator) -> list[int]:
    """Tool-calling analogue (App. I.2): answer is copyable from the question
    (last operand), so reasoning is unnecessary and Pass@1 is high from the
    start — reproducing the paper's 'reasoning not needed here' finding."""
    n = int(rng.integers(2, 7))
    ops = rng.integers(0, V.MOD, size=n).tolist()
    toks = [V.BOS, V.TOOL]
    for a in ops:
        toks.append(V.num(a))
    toks.append(V.SEP)
    toks.append(V.THINK)
    n_lines = int(rng.integers(0, 4))
    for i in range(n_lines):
        toks.extend([V.num(i + 1), V.num(ops[-1]), V.NL])
    toks.extend([V.ETHINK, V.FINAL, V.LBRACK, V.ANS, V.num(ops[-1]), V.EOS])
    assert len(toks) <= SEQ_LEN
    return toks


def make_batch(
    rng: np.random.Generator,
    batch: int,
    p_tool: float = 0.05,
    **kw,
) -> tuple[np.ndarray, np.ndarray]:
    """Padded (tokens, loss_mask) arrays of shape [batch, SEQ_LEN].

    loss_mask is 1.0 on positions whose *target* (next token) is a real
    token of the trace, 0.0 on padding.
    """
    xs = np.full((batch, SEQ_LEN), V.PAD, dtype=np.int32)
    mask = np.zeros((batch, SEQ_LEN), dtype=np.float32)
    for b in range(batch):
        t = (make_tool_trace(rng) if rng.random() < p_tool
             else make_trace(rng, **kw))
        xs[b, : len(t)] = t
        # position i predicts token i+1; valid while i+1 < len(t)
        mask[b, : len(t) - 1] = 1.0
        # up-weight the answer-value prediction (the single token the whole
        # task is about) so answer accuracy converges faster
        ans_pos = t.index(V.ANS)
        mask[b, ans_pos] = 4.0
    return xs, mask


def exact_answer(ops: list[int]) -> int:
    return sum(ops) % V.MOD
