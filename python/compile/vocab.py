"""Vocabulary for the synthetic chain-sum reasoning task.

Single source of truth for token ids, shared with the Rust coordinator via
``artifacts/vocab.json`` (written by aot.py). The task mirrors the structure
the paper assumes of a reasoning LLM (Eq. 4):

    BOS Q a_1 ... a_n SEP <think> r_1 ... r_m </think> FINAL ANS v EOS

where each reasoning line r_i is either a compute line ``i p_i NL`` (p_i the
i-th running partial sum mod MOD) or a verification line ``V j p_j NL``.
"""

from __future__ import annotations

from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Token id layout. Keep ids stable: rust reads vocab.json but tests assert the
# layout to catch accidental drift between trained weights and the tokenizer.
# ---------------------------------------------------------------------------

PAD = 0          # padding (never predicted; masked in the loss)
BOS = 1          # beginning of sequence
EOS = 2          # end of sequence
THINK = 3        # <think>
ETHINK = 4       # </think>
NL = 5           # paragraph separator "\n\n" — ends every reasoning line
FINAL = 6        # the prefix string "The final answer:" (App. D, Eq. 13)
ANS = 7          # answer marker; the token after ANS is the answer value
Q = 8            # question marker
SEP = 9          # end-of-question separator
VER = 10         # verification-line marker ("V")
UNK = 11         # corrupted operand (makes the question unsolvable)
LBRACK = 12      # "[" — tool-call opener (App. I.2 analogue)
TOOL = 13        # tool-call question marker (copy task)
NUM0 = 16        # numbers 0..MOD-1 are tokens NUM0 .. NUM0+MOD-1

MOD = 32         # modulus of the chain-sum task == answer space size
VOCAB = NUM0 + MOD  # = 48

SPECIAL_NAMES = {
    PAD: "<pad>", BOS: "<bos>", EOS: "<eos>", THINK: "<think>",
    ETHINK: "</think>", NL: "\\n\\n", FINAL: "Final answer:", ANS: "A",
    Q: "Q", SEP: ";", VER: "V", UNK: "?", LBRACK: "[", TOOL: "T",
}


def num(v: int) -> int:
    """Token id of the number ``v`` (mod MOD)."""
    return NUM0 + (v % MOD)


def is_num(tok: int) -> bool:
    return NUM0 <= tok < NUM0 + MOD


def num_value(tok: int) -> int:
    assert is_num(tok), f"token {tok} is not a number"
    return tok - NUM0


def detok(tokens) -> str:
    """Human-readable rendering of a token sequence (for debugging/tests)."""
    out = []
    for t in tokens:
        t = int(t)
        if is_num(t):
            out.append(str(num_value(t)))
        else:
            out.append(SPECIAL_NAMES.get(t, f"<{t}>"))
    return " ".join(out)


@dataclass(frozen=True)
class VocabSpec:
    pad: int = PAD
    bos: int = BOS
    eos: int = EOS
    think: int = THINK
    ethink: int = ETHINK
    nl: int = NL
    final: int = FINAL
    ans: int = ANS
    q: int = Q
    sep: int = SEP
    ver: int = VER
    unk: int = UNK
    lbrack: int = LBRACK
    tool: int = TOOL
    num0: int = NUM0
    mod: int = MOD
    vocab: int = VOCAB


def vocab_json() -> dict:
    """The dict dumped to artifacts/vocab.json for the Rust tokenizer."""
    s = VocabSpec()
    return {
        "pad": s.pad, "bos": s.bos, "eos": s.eos, "think": s.think,
        "ethink": s.ethink, "nl": s.nl, "final": s.final, "ans": s.ans,
        "q": s.q, "sep": s.sep, "ver": s.ver, "unk": s.unk,
        "lbrack": s.lbrack, "tool": s.tool,
        "num0": s.num0, "mod": s.mod, "vocab": s.vocab,
    }
