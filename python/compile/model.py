"""L2: the reasoning-model compute graph in JAX.

A decoder-only transformer (pre-RMSNorm, MHA, GELU MLP, learned positional
embeddings, untied LM head) with four jitted entry points that aot.py lowers
to HLO text for the Rust coordinator:

  * prefill       — full-prompt pass, builds the KV cache, returns logits
                    at the last prompt position
  * decode        — one token step against the cache (Pallas decode
                    attention kernel on the hot path)
  * decode_batch  — the same, vmapped over B sequences (continuous batching)
  * probe         — the paper's EAT probe: virtually append a short suffix
                    (``</think>`` [+ prefix string], Eq. 12/13) *without*
                    committing it to the cache and return the entropy of the
                    single next token (Pallas entropy kernel, Eq. 5)

The training forward (``forward_all``) teacher-forces a full sequence with
plain einsum attention (what XLA fuses best on CPU); consistency between it
and the prefill/decode path is asserted in python/tests/test_model.py.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import decode_attention, entropy

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_head: int
    n_layer: int
    d_ff: int
    seq_len: int
    probe_len: int = 4   # PK: max suffix slots of the EAT probe
    batch: int = 4       # B: decode_batch width

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


def main_config(vocab: int, seq_len: int) -> ModelConfig:
    """The 'reasoning model' theta (stands in for DeepSeek-R1-Qwen3-8B)."""
    return ModelConfig("main", vocab, d_model=64, n_head=2, n_layer=2,
                       d_ff=256, seq_len=seq_len)


def proxy_config(vocab: int, seq_len: int) -> ModelConfig:
    """The small proxy phi for black-box EAT (stands in for R1-Qwen-1.5B)."""
    return ModelConfig("proxy", vocab, d_model=32, n_head=2, n_layer=1,
                       d_ff=128, seq_len=seq_len)


# ---------------------------------------------------------------------------
# Parameters: canonical flat ordering shared with the Rust weights loader.
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list; the manifest and HLO argument order."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    specs = [("tok_emb", (v, d)), ("pos_emb", (s, d))]
    for l in range(cfg.n_layer):
        p = f"layer{l}."
        specs += [
            (p + "ln1", (d,)),
            (p + "wq", (d, d)), (p + "wk", (d, d)),
            (p + "wv", (d, d)), (p + "wo", (d, d)),
            (p + "ln2", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    specs += [("ln_f", (d,)), ("head", (d, v))]
    return specs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jnp.ndarray]:
    params = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("b1", "b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            std = fan_in ** -0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: dict) -> list[jnp.ndarray]:
    return [params[name] for name, _ in param_specs(cfg)]


def unflatten_params(cfg: ModelConfig, flat: list) -> dict:
    return {name: x for (name, _), x in zip(param_specs(cfg), flat)}


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _mlp(p: dict, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(x @ p[prefix + "w1"] + p[prefix + "b1"])
    return h @ p[prefix + "w2"] + p[prefix + "b2"]


def _heads(cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """[..., D] -> [..., H, Dh]"""
    return x.reshape(*x.shape[:-1], cfg.n_head, cfg.d_head)


# ---------------------------------------------------------------------------
# Training / prefill forward (full sequence, einsum attention)
# ---------------------------------------------------------------------------


def forward_all(cfg: ModelConfig, params: dict, tokens: jnp.ndarray):
    """Teacher-forced forward. tokens [S] -> (logits [S, V], kc, vc).

    Returns the per-layer K/V so prefill can reuse this single pass to
    populate the cache: kc/vc have shape [L, H, S, Dh].
    """
    s = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    scale = cfg.d_head ** -0.5
    kcs, vcs = [], []
    for l in range(cfg.n_layer):
        p = f"layer{l}."
        h = rmsnorm(x, params[p + "ln1"])
        q = _heads(cfg, h @ params[p + "wq"])  # [S, H, Dh]
        k = _heads(cfg, h @ params[p + "wk"])
        v = _heads(cfg, h @ params[p + "wv"])
        kcs.append(k.transpose(1, 0, 2))       # [H, S, Dh]
        vcs.append(v.transpose(1, 0, 2))
        scores = jnp.einsum("ihd,jhd->hij", q, k) * scale
        scores = jnp.where(causal[None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("hij,jhd->ihd", w, v).reshape(s, cfg.d_model)
        x = x + att @ params[p + "wo"]
        x = x + _mlp(params, p, rmsnorm(x, params[p + "ln2"]))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["head"]
    return logits, jnp.stack(kcs), jnp.stack(vcs)


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            n: jnp.ndarray):
    """tokens [S] padded prompt, n = true length.

    Returns (logits at position n-1 [V], kcache, vcache [L, H, S, Dh]).
    Cache entries past n-1 are garbage; the decode loop overwrites position
    p before any later position attends to it, so this is safe.
    """
    logits, kc, vc = forward_all(cfg, params, tokens)
    last = jnp.take(logits, n - 1, axis=0)
    return last, kc, vc


# ---------------------------------------------------------------------------
# Decode step (Pallas attention on the hot path)
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params: dict, kc: jnp.ndarray,
                vc: jnp.ndarray, pos: jnp.ndarray, token: jnp.ndarray):
    """One incremental step: write K/V at `pos`, attend to cache[: pos+1].

    kc, vc: [L, H, S, Dh]; pos, token: i32 scalars.
    Returns (logits [V], kc', vc').
    """
    x = params["tok_emb"][token] + jnp.take(params["pos_emb"], pos, axis=0)
    for l in range(cfg.n_layer):
        p = f"layer{l}."
        h = rmsnorm(x, params[p + "ln1"])
        q = _heads(cfg, h @ params[p + "wq"])        # [H, Dh]
        k = _heads(cfg, h @ params[p + "wk"])
        v = _heads(cfg, h @ params[p + "wv"])
        # k, v are [H, Dh]; the cache slot at (l, :, pos, :) is [1, H, 1, Dh]
        kc = jax.lax.dynamic_update_slice(
            kc, k.reshape(1, cfg.n_head, 1, cfg.d_head), (l, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v.reshape(1, cfg.n_head, 1, cfg.d_head), (l, 0, pos, 0))
        att = decode_attention(q, kc[l], vc[l], pos + 1)     # [H, Dh]
        x = x + att.reshape(cfg.d_model) @ params[p + "wo"]
        x = x + _mlp(params, p, rmsnorm(x, params[p + "ln2"]))
    x = rmsnorm(x, params["ln_f"])
    return x @ params["head"], kc, vc


def decode_batch(cfg: ModelConfig, params: dict, kc: jnp.ndarray,
                 vc: jnp.ndarray, pos: jnp.ndarray, tokens: jnp.ndarray):
    """Continuous-batching step. kc/vc [B, L, H, S, Dh]; pos/tokens [B]."""
    step = lambda kcb, vcb, p, t: decode_step(cfg, params, kcb, vcb, p, t)
    return jax.vmap(step)(kc, vc, pos, tokens)


# ---------------------------------------------------------------------------
# EAT probe (Eq. 5 / Alg. 1 line 6)
# ---------------------------------------------------------------------------


def probe(cfg: ModelConfig, params: dict, kc: jnp.ndarray, vc: jnp.ndarray,
          pos: jnp.ndarray, suffix: jnp.ndarray, slen: jnp.ndarray):
    """Entropy of the next-token distribution after virtually appending
    ``suffix[:slen]`` at position ``pos`` — without mutating the caller's
    cache (the updated cache is simply not returned).

    suffix: [PK] i32 (padded); slen: i32 in [1, PK].
    Returns (eat f32 scalar, logits [V] after the last active suffix token).
    """
    pk = cfg.probe_len

    def body(carry, t):
        kc, vc, logits = carry
        tok = suffix[t]
        lg, kc2, vc2 = decode_step(cfg, params, kc, vc, pos + t, tok)
        active = t < slen
        kc = jnp.where(active, kc2, kc)
        vc = jnp.where(active, vc2, vc)
        logits = jnp.where(t == slen - 1, lg, logits)
        return (kc, vc, logits), None

    init = (kc, vc, jnp.zeros((cfg.vocab,), jnp.float32))
    (kc, vc, logits), _ = jax.lax.scan(body, init, jnp.arange(pk))
    return entropy(logits), logits
