"""AOT compile path: lower the L2 graphs to HLO *text* + export weights.

Python runs exactly once (``make artifacts``); afterwards the Rust binary is
self-contained. Interchange format is HLO text — NOT ``.serialize()`` — the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids; the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs (under artifacts/):
  prefill_{m}.hlo.txt  decode_{m}.hlo.txt  probe_{m}.hlo.txt     m in {main,proxy}
  decode_batch_main.hlo.txt
  weights_{m}.bin      — concatenated little-endian f32 in manifest order
  manifest_{m}.json    — [{name, shape, offset, size}] (element offsets)
  config.json          — model dims + artifact names + entry-point arg specs
  vocab.json           — token-id layout (single source of truth for Rust)

Usage: python -m compile.aot [--out-dir ../artifacts] [--models main,proxy]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen as D
from . import vocab as V
from .model import (ModelConfig, decode_batch, decode_step, main_config,
                    param_specs, prefill, probe, proxy_config)
from .train import load_checkpoint


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_model(cfg: ModelConfig, out_dir: str, with_batch: bool) -> dict:
    """Lower all entry points of one model; returns its config.json stanza."""
    nparams = len(param_specs(cfg))
    pspecs = [_spec(shape) for _, shape in param_specs(cfg)]
    cache = _spec((cfg.n_layer, cfg.n_head, cfg.seq_len, cfg.d_head))
    i32 = jnp.int32

    def prefill_fn(*args):
        flat, toks, n = args[:nparams], args[nparams], args[nparams + 1]
        p = {name: x for (name, _), x in zip(param_specs(cfg), flat)}
        return prefill(cfg, p, toks, n)

    def decode_fn(*args):
        flat = args[:nparams]
        kc, vc, pos, tok = args[nparams:]
        p = {name: x for (name, _), x in zip(param_specs(cfg), flat)}
        return decode_step(cfg, p, kc, vc, pos, tok)

    def probe_fn(*args):
        flat = args[:nparams]
        kc, vc, pos, suffix, slen = args[nparams:]
        p = {name: x for (name, _), x in zip(param_specs(cfg), flat)}
        return probe(cfg, p, kc, vc, pos, suffix, slen)

    def decode_batch_fn(*args):
        flat = args[:nparams]
        kc, vc, pos, toks = args[nparams:]
        p = {name: x for (name, _), x in zip(param_specs(cfg), flat)}
        return decode_batch(cfg, p, kc, vc, pos, toks)

    entries = {
        "prefill": (prefill_fn,
                    pspecs + [_spec((cfg.seq_len,), i32), _spec((), i32)]),
        "decode": (decode_fn,
                   pspecs + [cache, cache, _spec((), i32), _spec((), i32)]),
        "probe": (probe_fn,
                  pspecs + [cache, cache, _spec((), i32),
                            _spec((cfg.probe_len,), i32), _spec((), i32)]),
    }
    if with_batch:
        bcache = _spec((cfg.batch, cfg.n_layer, cfg.n_head, cfg.seq_len,
                        cfg.d_head))
        entries["decode_batch"] = (
            decode_batch_fn,
            pspecs + [bcache, bcache, _spec((cfg.batch,), i32),
                      _spec((cfg.batch,), i32)])

    files = {}
    for name, (fn, specs) in entries.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_{cfg.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"  {fname}: {len(text)} chars")
        files[name] = fname

    return {
        "name": cfg.name,
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_head": cfg.n_head,
        "n_layer": cfg.n_layer, "d_ff": cfg.d_ff, "d_head": cfg.d_head,
        "seq_len": cfg.seq_len, "probe_len": cfg.probe_len,
        "batch": cfg.batch,
        "n_params": nparams,
        "weights": f"weights_{cfg.name}.bin",
        "manifest": f"manifest_{cfg.name}.json",
        "hlo": files,
    }


def export_weights(cfg: ModelConfig, params: dict, out_dir: str) -> None:
    manifest, offset = [], 0
    chunks = []
    for name, shape in param_specs(cfg):
        arr = np.asarray(params[name], np.float32).reshape(-1)
        manifest.append({"name": name, "shape": list(shape),
                         "offset": offset, "size": int(arr.size)})
        chunks.append(arr)
        offset += arr.size
    blob = np.concatenate(chunks)
    blob.tofile(os.path.join(out_dir, f"weights_{cfg.name}.bin"))
    with open(os.path.join(out_dir, f"manifest_{cfg.name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  weights_{cfg.name}.bin: {blob.size} f32 ({blob.nbytes} bytes)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="main,proxy")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfgs = {
        "main": main_config(V.VOCAB, D.SEQ_LEN),
        "proxy": proxy_config(V.VOCAB, D.SEQ_LEN),
    }
    model_stanzas = {}
    for m in args.models.split(","):
        cfg = cfgs[m]
        print(f"[{m}] lowering...")
        model_stanzas[m] = lower_model(cfg, args.out_dir,
                                       with_batch=(m == "main"))
        ckpt = os.path.join(args.out_dir, f"ckpt_{m}.npz")
        if not os.path.exists(ckpt):
            raise SystemExit(
                f"missing {ckpt}: run `python -m compile.train` first "
                f"(make artifacts does this automatically)")
        params = load_checkpoint(cfg, ckpt)
        export_weights(cfg, params, args.out_dir)

    with open(os.path.join(args.out_dir, "config.json"), "w") as f:
        json.dump({"models": model_stanzas, "seq_len": D.SEQ_LEN}, f,
                  indent=1)
    with open(os.path.join(args.out_dir, "vocab.json"), "w") as f:
        json.dump(V.vocab_json(), f, indent=1)
    print("wrote config.json, vocab.json")


if __name__ == "__main__":
    main()
