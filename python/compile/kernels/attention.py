"""Pallas kernel: single-query (decode-step) flash attention over a KV cache.

The decode hot-spot of the serving stack: one query vector per head attends
to all cached positions < valid_len.

TPU mapping (DESIGN.md §Hardware-Adaptation): the CUDA flash-decode design
splits the KV sequence across threadblocks with shared-memory staging; here
the HBM->VMEM schedule is expressed with a grid over (head, seq-block).
Each grid step loads one [blk_s, Dh] KV tile into VMEM, computes q.K^T on
MXU-friendly tiles, and merges into online-softmax accumulators (m, l,
acc[Dh]) carried in the per-head output row plus a (1, 2) stats output —
the same functional accumulation pattern as the entropy kernel, so the
kernel needs no scratch memory and stays interpret-mode portable.

Length masking (positions >= valid_len) is computed from the grid index and
an iota inside the tile; valid_len arrives as a (1,) i32 operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_BIG = -1e30


def _decode_attn_kernel(plen_ref, q_ref, k_ref, v_ref, o_ref, stats_ref,
                        *, blk_s: int, dh: int):
    """Grid = (H, nblk_s); seq-blocks iterate fastest (row-major)."""
    j = pl.program_id(1)

    q = q_ref[...].reshape(dh).astype(jnp.float32)          # [Dh]
    k = k_ref[...].reshape(blk_s, dh).astype(jnp.float32)   # [blk, Dh]
    v = v_ref[...].reshape(blk_s, dh).astype(jnp.float32)   # [blk, Dh]
    plen = plen_ref[0]

    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    scores = (k @ q) * scale                                  # [blk]
    pos = j * blk_s + jax.lax.iota(jnp.int32, blk_s)
    scores = jnp.where(pos < plen, scores, NEG_BIG)

    m_b = jnp.max(scores)
    w = jnp.exp(scores - m_b)                                 # [blk]
    l_b = jnp.sum(w)
    acc_b = w @ v                                             # [Dh]

    @pl.when(j == 0)
    def _init():
        stats_ref[0, 0] = m_b
        stats_ref[0, 1] = l_b
        o_ref[...] = acc_b.reshape(o_ref.shape)

    @pl.when(j > 0)
    def _merge():
        m, l = stats_ref[0, 0], stats_ref[0, 1]
        m_new = jnp.maximum(m, m_b)
        c_old = jnp.exp(m - m_new)
        c_b = jnp.exp(m_b - m_new)
        stats_ref[0, 0] = m_new
        stats_ref[0, 1] = l * c_old + l_b * c_b
        o_ref[...] = o_ref[...] * c_old + (acc_b * c_b).reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("block",))
def decode_attention(
    q: jnp.ndarray,          # [H, Dh]
    k: jnp.ndarray,          # [H, S, Dh]
    v: jnp.ndarray,          # [H, S, Dh]
    valid_len: jnp.ndarray,  # scalar i32
    block: int = 64,
) -> jnp.ndarray:            # [H, Dh]
    """Single-query attention; positions >= valid_len are masked out."""
    h, s, dh = k.shape
    blk_s = min(block, s)
    assert s % blk_s == 0, f"seq {s} not divisible by block {blk_s}"
    nblk = s // blk_s
    plen = jnp.asarray(valid_len, jnp.int32).reshape(1)

    out, stats = pl.pallas_call(
        functools.partial(_decode_attn_kernel, blk_s=blk_s, dh=dh),
        grid=(h, nblk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),            # plen
            pl.BlockSpec((1, dh), lambda i, j: (i, 0)),       # q
            pl.BlockSpec((1, blk_s, dh), lambda i, j: (i, j, 0)),  # k
            pl.BlockSpec((1, blk_s, dh), lambda i, j: (i, j, 0)),  # v
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda i, j: (i, 0)),       # acc rows
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),        # (m, l)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, 2), jnp.float32),
        ],
        interpret=True,
    )(plen, q.astype(jnp.float32), k, v)

    return out / stats[:, 1:2]
