"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest + hypothesis sweep shapes and
dtypes and assert_allclose the Pallas kernels (interpret=True) against these.
They are also used directly by the *training* forward pass, where full-batch
jnp einsum code is what XLA fuses best on CPU.
"""

from __future__ import annotations

import jax.numpy as jnp


def entropy_ref(logits: jnp.ndarray) -> jnp.ndarray:
    """Shannon entropy (nats) of softmax(logits) along the last axis.

    Numerically stable: H = logsumexp(z) - sum(softmax(z) * z).
    Works for any leading batch shape.
    """
    z = logits.astype(jnp.float32)
    m = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - m)
    Z = jnp.sum(ez, axis=-1)
    S = jnp.sum((z - m) * ez, axis=-1)
    return jnp.log(Z) - S / Z


def decode_attention_ref(
    q: jnp.ndarray,          # [H, Dh]
    k: jnp.ndarray,          # [H, S, Dh]
    v: jnp.ndarray,          # [H, S, Dh]
    valid_len: jnp.ndarray,  # scalar i32: attend to positions < valid_len
) -> jnp.ndarray:            # [H, Dh]
    """Single-query attention over a KV cache with a length mask."""
    H, S, Dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.array(Dh, jnp.float32))
    scores = jnp.einsum("hd,hsd->hs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < valid_len
    scores = jnp.where(mask, scores, -1e30)
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("hs,hsd->hd", w, v.astype(jnp.float32))
