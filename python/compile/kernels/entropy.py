"""Pallas kernel: fused online softmax-entropy over the vocabulary axis.

This is the paper's signature computation — EAT itself (Eqs. 2 and 5):
``H(softmax(z))`` for a logits vector ``z`` of vocabulary size V.

TPU mapping (DESIGN.md §Hardware-Adaptation): instead of materializing
``softmax(z)`` in HBM and reducing (two passes over V), the kernel streams
VMEM-sized vocab tiles through a single pass, carrying the flash-style
online accumulator (m, Z, S):

    m = running max(z)
    Z = sum exp(z - m)
    S = sum (z - m) * exp(z - m)

merged across tiles with the standard rescaling identities, so that at the
end  H = log(Z) - S / Z.  The accumulator lives in the (1, 3) output block
that every grid step maps to — the canonical Pallas accumulation pattern.

Compiled with interpret=True: the CPU PJRT plugin cannot run Mosaic
custom-calls; the BlockSpec structure *is* the TPU schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_BIG = -1e30  # padding fill; finite so (z-m)*exp(z-m) stays NaN-free


def _entropy_kernel(z_ref, acc_ref):
    """One vocab tile: merge this tile's (m, Z, S) into the accumulator."""
    i = pl.program_id(0)

    z = z_ref[...].astype(jnp.float32)  # [1, blk]
    m_b = jnp.max(z)
    ez = jnp.exp(z - m_b)
    z_b = jnp.sum(ez)
    s_b = jnp.sum((z - m_b) * ez)

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = m_b
        acc_ref[0, 1] = z_b
        acc_ref[0, 2] = s_b

    @pl.when(i > 0)
    def _merge():
        m, zz, ss = acc_ref[0, 0], acc_ref[0, 1], acc_ref[0, 2]
        m_new = jnp.maximum(m, m_b)
        c_old = jnp.exp(m - m_new)
        c_b = jnp.exp(m_b - m_new)
        acc_ref[0, 0] = m_new
        acc_ref[0, 1] = zz * c_old + z_b * c_b
        acc_ref[0, 2] = (ss + (m - m_new) * zz) * c_old + (
            s_b + (m_b - m_new) * z_b
        ) * c_b


@functools.partial(jax.jit, static_argnames=("block",))
def entropy(logits: jnp.ndarray, block: int = 128) -> jnp.ndarray:
    """Entropy (nats) of softmax(logits) along the last axis.

    Accepts [V] or any leading batch shape [..., V]; batch dims are handled
    by vmap over the single-vector kernel.
    """
    if logits.ndim > 1:
        flat = logits.reshape((-1, logits.shape[-1]))
        out = jax.vmap(lambda z: entropy(z, block=block))(flat)
        return out.reshape(logits.shape[:-1])

    (v,) = logits.shape
    blk = min(block, max(v, 8))
    pad = (-v) % blk
    z = jnp.pad(logits.astype(jnp.float32), (0, pad),
                constant_values=NEG_BIG)
    vp = v + pad
    nblk = vp // blk

    acc = pl.pallas_call(
        _entropy_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 3), jnp.float32),
        interpret=True,
    )(z.reshape(1, vp))

    zz, ss = acc[0, 1], acc[0, 2]
    return jnp.log(zz) - ss / zz
