# L1: Pallas kernels for the paper's compute hot-spots.
#  - entropy.entropy          : fused online softmax-entropy (EAT, Eq. 5)
#  - attention.decode_attention: single-query flash decode attention
# Pure-jnp oracles live in ref.py; see python/tests/ for the sweeps.
from .attention import decode_attention  # noqa: F401
from .entropy import entropy  # noqa: F401
from .ref import decode_attention_ref, entropy_ref  # noqa: F401
