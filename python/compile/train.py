"""Trainer for the synthetic reasoning models (build-time only).

Hand-rolled Adam (optax is not available offline) over the causal-LM
cross-entropy of teacher traces from datagen.py. Trains both the main
reasoning model and the small proxy, then writes float32 checkpoints to
``artifacts/ckpt_{main,proxy}.npz`` which aot.py bakes into the serving
artifacts.

Usage:  python -m compile.train [--steps N] [--model main|proxy|both]
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen as D
from . import vocab as V
from .model import (ModelConfig, forward_all, init_params, main_config,
                    param_specs, proxy_config)

# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def sequence_loss(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy over masked positions.

    tokens [B, S] i32; mask [B, S] f32 (1.0 where position i predicts a
    real target at i+1).
    """
    def one(toks):
        logits, _, _ = forward_all(cfg, params, toks)
        return logits

    logits = jax.vmap(one)(tokens)                      # [B, S, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)  # predict t+1
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, :-1]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


def adam_init(params: dict) -> dict:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


@partial(jax.jit, static_argnums=(0,))
def adam_step(cfg: ModelConfig, params: dict, opt: dict, tokens, mask,
              lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8):
    loss, grads = jax.value_and_grad(
        lambda p: sequence_loss(cfg, p, tokens, mask))(params)
    t = opt["t"] + 1.0
    m = jax.tree_util.tree_map(
        lambda mm, g: b1 * mm + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(
        lambda vv, g: b2 * vv + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1.0 - b1 ** t)
    vhat_scale = 1.0 / (1.0 - b2 ** t)
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm * mhat_scale) /
        (jnp.sqrt(vv * vhat_scale) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}, loss


# ---------------------------------------------------------------------------
# Evaluation: held-out answer accuracy under teacher forcing
# ---------------------------------------------------------------------------


def eval_answer_accuracy(cfg: ModelConfig, params: dict,
                         rng: np.random.Generator, n_eval: int = 64) -> float:
    """Fraction of held-out full traces whose answer token is argmax-correct
    at the position right after ANS (the single-token answer)."""
    toks, _ = D.make_batch(rng, n_eval, p_tool=0.0, p_corrupt=0.0,
                           p_early=0.0)
    logits = jax.vmap(lambda t: forward_all(cfg, params, t)[0])(
        jnp.asarray(toks))
    correct = 0
    for b in range(n_eval):
        row = toks[b]
        ans_pos = int(np.where(row == V.ANS)[0][0])  # predicts row[ans_pos+1]
        pred = int(jnp.argmax(logits[b, ans_pos]))
        correct += int(pred == int(row[ans_pos + 1]))
    return correct / n_eval


# ---------------------------------------------------------------------------
# Training driver
# ---------------------------------------------------------------------------


def train_model(cfg: ModelConfig, steps: int, batch: int, seed: int,
                lr: float, log_every: int = 50) -> dict:
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    t0 = time.time()
    for step in range(1, steps + 1):
        toks, mask = D.make_batch(rng, batch)
        params, opt, loss = adam_step(cfg, params, opt,
                                      jnp.asarray(toks), jnp.asarray(mask),
                                      lr=lr)
        if step % log_every == 0 or step == 1:
            acc = eval_answer_accuracy(cfg, params,
                                       np.random.default_rng(9999))
            print(f"[{cfg.name}] step {step:5d}  loss {float(loss):.4f}  "
                  f"ans-acc {acc:.3f}  ({time.time()-t0:.0f}s)", flush=True)
    return params


def save_checkpoint(cfg: ModelConfig, params: dict, path: str) -> None:
    arrays = {name: np.asarray(params[name], np.float32)
              for name, _ in param_specs(cfg)}
    np.savez(path, **arrays)
    print(f"saved {path} ({sum(a.size for a in arrays.values())} params)")


def load_checkpoint(cfg: ModelConfig, path: str) -> dict:
    data = np.load(path)
    return {name: jnp.asarray(data[name]) for name, _ in param_specs(cfg)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--proxy-steps", type=int, default=800)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model", choices=["main", "proxy", "both"],
                    default="both")
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    if args.model in ("main", "both"):
        cfg = main_config(V.VOCAB, D.SEQ_LEN)
        params = train_model(cfg, args.steps, args.batch, args.seed, args.lr)
        save_checkpoint(cfg, params, f"{args.out_dir}/ckpt_main.npz")
    if args.model in ("proxy", "both"):
        cfg = proxy_config(V.VOCAB, D.SEQ_LEN)
        params = train_model(cfg, args.proxy_steps, args.batch,
                             args.seed + 1, args.lr)
        save_checkpoint(cfg, params, f"{args.out_dir}/ckpt_proxy.npz")


if __name__ == "__main__":
    main()
