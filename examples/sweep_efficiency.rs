//! Threshold-sweep demo (paper Fig. 3 in miniature): replay recorded
//! traces offline across delta / T grids and print the efficiency curves +
//! headline token saving at iso-accuracy.
//!
//!     cargo run --release --example sweep_efficiency -- \
//!         [--traces results/traces/synth-math500.json]
//!
//! Generate traces first: `repro trace --dataset synth-math500`.

use anyhow::Result;

use eat_serve::eval::sweep::{default_deltas, default_token_budgets, sweep_eat, sweep_token};
use eat_serve::eval::{Signal, TraceSet};
use eat_serve::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let path = args.str_or("traces", "results/traces/synth-math500.json");
    let ts = TraceSet::load(std::path::Path::new(path))?;
    println!("loaded {} traces from {path}\n", ts.traces.len());

    let t_max = args.usize_or("budget", 96);
    let alpha = args.f64_or("alpha", 0.2);
    let eat = sweep_eat(&ts, Signal::MainPrefixed, alpha, &default_deltas(), t_max, false, "eat");
    let proxy = sweep_eat(&ts, Signal::Proxy, alpha, &default_deltas(), t_max, false, "eat-proxy");
    let tok = sweep_token(&ts, &default_token_budgets(t_max), "token");

    println!("{:<12} {:>12} {:>12} {:>10}", "policy", "threshold", "tokens", "pass@1");
    for c in [&tok, &eat, &proxy] {
        for p in &c.points {
            println!(
                "{:<12} {:>12.3e} {:>12.0} {:>10.4}",
                c.label, p.threshold, p.total_tokens, p.agg_pass1
            );
        }
    }
    println!("\nAUC: eat={:.4} eat-proxy={:.4} token={:.4}", eat.auc(), proxy.auc(), tok.auc());

    let best_tok = tok.points.iter().map(|p| p.agg_pass1).fold(0.0, f64::max);
    let target = 0.98 * best_tok;
    if let (Some(te), Some(tt)) = (eat.tokens_at_accuracy(target), tok.tokens_at_accuracy(target)) {
        println!(
            "iso-accuracy {:.3}: EAT uses {:.0} tokens vs {:.0} for the fixed budget ({:.1}% saving; paper: 12-22%)",
            target, te, tt, 100.0 * (1.0 - te / tt)
        );
    }
    Ok(())
}
