//! Black-box demo (paper Fig. 5 / App. I.7): a simulated "Claude 3.7"
//! streaming API delivers reasoning chunks with realistic latency; a local
//! proxy model computes EAT per chunk and stops the stream when the EMA
//! variance stabilizes — saving simulated remote generation time without
//! ever seeing the remote model's logits.
//!
//! Since DESIGN.md §3.6 the pipeline is a coordinator workload: all
//! `--slots` streams run concurrently through fused batched decode on
//! both the remote-main and local-proxy lanes, chunk arrivals are
//! scheduled on a virtual clock, and the whole run (including the
//! Fig. 5b overlap accounting) is a pure function of `--seed`.
//!
//!     cargo run --release --example blackbox_claude -- [--questions 8]

use anyhow::Result;

use eat_serve::blackbox::{
    BlackboxBatcher, BlackboxConfig, LatencyModel, ProxyCostModel, CHUNK_MONITOR_ALPHA,
    CHUNK_MONITOR_DELTA,
};
use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{poisson_arrivals, run_open_loop, MetricsReport, DEFAULT_TICK_DT};
use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::cli::Args;
use eat_serve::util::clock::Clock;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::load_or_reference(args.str_or("artifacts", "artifacts"));
    let cfg = {
        let mut c = ServeConfig::default();
        // chunk-granularity monitoring defaults (short EMA window, fast
        // de-bias, loosened threshold — see blackbox::CHUNK_MONITOR_*)
        c.delta = args.f64_or("delta", CHUNK_MONITOR_DELTA);
        c.alpha = args.f64_or("alpha", CHUNK_MONITOR_ALPHA);
        c.seed = args.u64_or("seed", 11);
        c
    };
    let n = args.usize_or("questions", 8);
    let slots = args.usize_or("slots", 4);
    let bb = BlackboxConfig {
        chunk_tokens: args.usize_or("chunk", 6),
        latency: LatencyModel::default(),
        proxy_cost: ProxyCostModel::default(),
    };
    let ds = Dataset::synth_aime(&rt.vocab, n, cfg.seed);

    println!(
        "remote: simulated streaming reasoning API over the {}-param model",
        rt.main.param_elems()
    );
    println!(
        "local : {}-param proxy monitoring {slots} concurrent streams (fused decode)\n",
        rt.proxy.param_elems()
    );

    let seed = cfg.seed;
    let mut batcher = BlackboxBatcher::with_clock(&rt, cfg, bb, slots, Clock::virt());
    // open-loop Poisson arrivals: streams overlap, chunk deliveries
    // interleave on the virtual timeline
    let arrivals = poisson_arrivals(n, 2.0, seed);
    run_open_loop(&mut batcher, &ds.questions, &arrivals, DEFAULT_TICK_DT)?;

    let mut results = batcher.results;
    results.sort_by_key(|r| r.question_id);
    for res in &results {
        let q = ds
            .questions
            .iter()
            .find(|q| q.id == res.question_id)
            .expect("result for a submitted question");
        println!(
            "q{:<2} stop@chunk {:<4} tokens {:>3}  saved {:>6.1}s  correct={}  ({})",
            res.question_id,
            res.stop_chunk.map(|c| c.to_string()).unwrap_or("-".into()),
            res.tokens_at_stop,
            res.saved_ms / 1e3,
            res.correct,
            if q.solvable() { "solvable" } else { "unsolvable" },
        );
    }
    println!();
    println!("{}", batcher.metrics.report());
    println!(
        "(Fig. 5b: per-chunk EAT compute hides inside the chunk inter-arrival gap \
         even with {slots} streams sharing the proxy — zero added wall-clock)"
    );
    Ok(())
}
