//! Black-box demo (paper Fig. 5 / App. I.7): a simulated "Claude 3.7"
//! streaming API delivers reasoning chunks with realistic latency; a local
//! proxy model computes EAT per chunk and stops the stream when the EMA
//! variance stabilizes — saving simulated remote generation time without
//! ever seeing the remote model's logits.
//!
//!     cargo run --release --example blackbox_claude -- [--questions 8]

use anyhow::Result;

use eat_serve::blackbox::{run_blackbox, LatencyModel};
use eat_serve::config::ServeConfig;
use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::cli::Args;
use eat_serve::util::stats::mean;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::load_or_reference(args.str_or("artifacts", "artifacts"));
    let cfg = {
        let mut c = ServeConfig::default();
        // chunk-granularity monitoring sees ~4-8x fewer observations than
        // per-line monitoring, so the EMA window is scaled accordingly
        // (alpha 0.5) and the variance threshold loosened
        c.delta = args.f64_or("delta", 5e-2);
        c.alpha = args.f64_or("alpha", 0.5);
        c
    };
    let n = args.usize_or("questions", 8);
    let chunk = args.usize_or("chunk", 6);
    let ds = Dataset::synth_aime(&rt.vocab, n, 11);

    println!("remote: simulated streaming reasoning API over the {}-param model", rt.main.param_elems());
    println!("local : {}-param proxy computing EAT per received chunk\n", rt.proxy.param_elems());

    let mut saved = 0.0;
    let mut gaps = Vec::new();
    let mut computes = Vec::new();
    for q in &ds.questions {
        let res = run_blackbox(&rt, &cfg, q, LatencyModel::default(), chunk, 3 + q.id as u64)?;
        for p in &res.points {
            gaps.push(p.arrival_gap_ms);
            computes.push(p.proxy_compute_ms);
        }
        println!(
            "q{:<2} stop@chunk {:<4} tokens {:>3}  saved {:>6.1}s  correct={}  ({})",
            q.id,
            res.stop_chunk.map(|c| c.to_string()).unwrap_or("-".into()),
            res.tokens_at_stop,
            res.saved_ms / 1e3,
            res.correct,
            if q.solvable() { "solvable" } else { "unsolvable" },
        );
        saved += res.saved_ms;
    }
    println!("\ntotal simulated remote time saved: {:.1}s over {n} questions", saved / 1e3);
    println!(
        "overlap check (Fig. 5b): mean chunk inter-arrival {:.1} ms vs mean local EAT compute {:.2} ms -> {:.0}x headroom, zero added wall-clock",
        mean(&gaps),
        mean(&computes),
        mean(&gaps) / mean(&computes).max(1e-9)
    );
    Ok(())
}
