//! Quickstart: serve one reasoning question with EAT-based early exiting
//! (Alg. 1) and print the monitored trajectory.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the AOT artifacts when built with `--features pjrt` and
//! `make artifacts` has run; otherwise the deterministic in-process
//! reference backend serves the same protocol with zero setup.

use anyhow::Result;

use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{serve_one, MonitorModel};
use eat_serve::datasets::Dataset;
use eat_serve::exit::{EatPolicy, TokenBudgetPolicy};
use eat_serve::runtime::{Backend, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::load_or_reference("artifacts");
    println!(
        "loaded models on the {} backend: main ({} params), proxy ({} params)",
        rt.backend_kind(),
        rt.main.param_elems(),
        rt.proxy.param_elems(),
    );

    let cfg = ServeConfig::default();
    let ds = Dataset::synth_math500(&rt.vocab, 5, 7);

    println!("\n--- EAT early exit (alpha={}, delta={}) ---", cfg.alpha, cfg.delta);
    for q in &ds.questions {
        let policy = Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens));
        let res = serve_one(&rt, &cfg, MonitorModel::SelfModel, q, policy, 1)?;
        println!(
            "q{} (n={}): {} reasoning tokens, exit={:?}, correct={}, answer tail: {}",
            q.id,
            q.n_ops(),
            res.reasoning_tokens,
            res.exit_reason,
            res.correct,
            rt.vocab.detok(&res.answer_tail)
        );
    }

    println!("\n--- fixed token budget baseline (T=96) for comparison ---");
    for q in &ds.questions {
        let policy = Box::new(TokenBudgetPolicy::new(96));
        let res = serve_one(&rt, &cfg, MonitorModel::SelfModel, q, policy, 1)?;
        println!(
            "q{}: {} reasoning tokens, exit={:?}, correct={}",
            q.id, res.reasoning_tokens, res.exit_reason, res.correct
        );
    }

    println!("\n--- black-box: proxy model monitors the main model ---");
    for q in ds.questions.iter().take(2) {
        let policy = Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens));
        let res = serve_one(&rt, &cfg, MonitorModel::Proxy, q, policy, 1)?;
        println!(
            "q{}: {} reasoning tokens via proxy EAT, correct={}",
            q.id, res.reasoning_tokens, res.correct
        );
    }
    Ok(())
}
