//! End-to-end serving driver (the repo's headline validation run):
//! a Poisson workload of reasoning requests served through the
//! continuous batcher, comparing three serving configurations on the
//! SAME seeded arrival process —
//!
//!   1. EAT early exiting + the EAT-aware preemptive scheduler,
//!   2. EAT early exiting + plain FIFO admission,
//!   3. fixed token budget + FIFO (the baseline serving stack);
//!
//! reporting latency / throughput / accuracy / token usage and the
//! scheduler counters (preemptions, resumes, deadline misses).
//!
//!     cargo run --release --example serve_batch -- \
//!         [--requests 48] [--slots 4] [--rate 4.0] [--deadline 30] \
//!         [--dataset synth-gpqa-small] [--wall]
//!
//! By default the run is simulated on a VIRTUAL clock (DESIGN.md §3.4):
//! fully deterministic in --seed, with one scheduling tick charged as
//! 10 ms of simulated time. Pass --wall to pace arrivals in real time
//! instead. Results are recorded in EXPERIMENTS.md §End-to-end serving.

use anyhow::Result;

use eat_serve::config::{SchedMode, ServeConfig};
use eat_serve::coordinator::{
    eat_policy_factory, poisson_arrivals, run_open_loop, Batcher, MetricsReport, MonitorModel,
    DEFAULT_TICK_DT,
};
use eat_serve::datasets::Dataset;
use eat_serve::exit::TokenBudgetPolicy;
use eat_serve::runtime::Runtime;
use eat_serve::util::cli::Args;
use eat_serve::util::clock::Clock;

#[allow(clippy::too_many_arguments)]
fn run_workload(
    rt: &Runtime,
    cfg: &ServeConfig,
    dataset: &str,
    n: usize,
    slots: usize,
    rate_per_s: f64,
    policy: &str,
    mode: SchedMode,
    wall: bool,
) -> Result<()> {
    let ds = Dataset::by_name(dataset, &rt.vocab, cfg.seed)?;
    let budget = cfg.max_think_tokens;
    let factory: eat_serve::coordinator::batcher::PolicyFactory = match policy {
        "eat" => eat_policy_factory(cfg),
        "token" => Box::new(move || Box::new(TokenBudgetPolicy::new(budget))),
        other => anyhow::bail!("unknown policy {other}"),
    };
    let mut cfg = cfg.clone();
    cfg.sched.mode = mode;
    let clock = if wall { Clock::wall() } else { Clock::virt() };
    let mut batcher =
        Batcher::with_clock(rt, cfg.clone(), MonitorModel::SelfModel, slots, factory, clock);

    // Open-loop Poisson arrivals: identical across the compared
    // configurations (same seed ⇒ same arrival times ⇒ same workload).
    let arrivals = poisson_arrivals(n, rate_per_s, cfg.seed);
    run_open_loop(&mut batcher, &ds.questions, &arrivals, DEFAULT_TICK_DT)?;

    let sched = match mode {
        SchedMode::Fifo => "fifo",
        SchedMode::EatAware => "eat-aware",
    };
    println!(
        "=== policy={policy} sched={sched} dataset={dataset} slots={slots} rate={rate_per_s}/s ==="
    );
    println!("{}", batcher.metrics.report());
    println!("kv slot peak       {} / {}", batcher.kv_peak(), slots);
    println!("mean slot occupancy {:.2}", batcher.metrics.mean_slot_occupancy());
    let mean_tokens =
        batcher.metrics.reasoning_tokens as f64 / batcher.metrics.completed.max(1) as f64;
    println!("mean reasoning tok {mean_tokens:.1}\n");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::load_or_reference(args.str_or("artifacts", "artifacts"));
    let mut cfg = ServeConfig::default();
    cfg.alpha = args.f64_or("alpha", cfg.alpha);
    cfg.delta = args.f64_or("delta", cfg.delta);
    cfg.seed = args.u64_or("seed", 0);
    cfg.sched.deadline_s = args.f64_or("deadline", 30.0);

    let dataset = args.str_or("dataset", "synth-gpqa-small");
    let n = args.usize_or("requests", 48);
    let slots = args.usize_or("slots", 4);
    let rate = args.f64_or("rate", 4.0);
    let wall = args.has("wall");

    run_workload(&rt, &cfg, dataset, n, slots, rate, "eat", SchedMode::EatAware, wall)?;
    run_workload(&rt, &cfg, dataset, n, slots, rate, "eat", SchedMode::Fifo, wall)?;
    run_workload(&rt, &cfg, dataset, n, slots, rate, "token", SchedMode::Fifo, wall)?;
    Ok(())
}
