//! End-to-end serving driver (the repo's headline validation run):
//! a Poisson workload of reasoning requests served through the continuous
//! batcher with EAT early exiting, reporting latency / throughput /
//! accuracy / token usage — and the same workload under the fixed-budget
//! baseline for comparison.
//!
//!     cargo run --release --example serve_batch -- \
//!         [--requests 48] [--slots 4] [--rate 4.0] [--dataset synth-math500-small]
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end serving.

use anyhow::Result;

use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{Batcher, MonitorModel};
use eat_serve::datasets::Dataset;
use eat_serve::exit::{EatPolicy, TokenBudgetPolicy};
use eat_serve::runtime::Runtime;
use eat_serve::util::cli::Args;
use eat_serve::util::rng::Rng;

fn run_workload(
    rt: &Runtime,
    cfg: &ServeConfig,
    dataset: &str,
    n: usize,
    slots: usize,
    rate_per_s: f64,
    policy: &str,
) -> Result<()> {
    let ds = Dataset::by_name(dataset, &rt.vocab, cfg.seed)?;
    let (alpha, delta, budget) = (cfg.alpha, cfg.delta, cfg.max_think_tokens);
    let factory: eat_serve::coordinator::batcher::PolicyFactory = match policy {
        "eat" => Box::new(move || Box::new(EatPolicy::new(alpha, delta, budget))),
        "token" => Box::new(move || Box::new(TokenBudgetPolicy::new(budget))),
        other => anyhow::bail!("unknown policy {other}"),
    };
    let mut batcher = Batcher::new(rt, cfg.clone(), MonitorModel::SelfModel, slots, factory);

    // Poisson arrivals: submit requests as their (simulated) arrival time
    // passes, interleaved with scheduler ticks — open-loop load.
    let mut rng = Rng::new(cfg.seed ^ 0xA221);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut t = 0.0;
    for _ in 0..n {
        t += rng.exponential(rate_per_s);
        arrivals.push(t);
    }
    let started = std::time::Instant::now();
    let mut next = 0usize;
    loop {
        let now = started.elapsed().as_secs_f64();
        while next < n && arrivals[next] <= now {
            batcher.submit(ds.questions[next % ds.questions.len()].clone());
            next += 1;
        }
        let advanced = batcher.tick()?;
        if next >= n && batcher.pending() == 0 && batcher.active_count() == 0 {
            break;
        }
        if advanced == 0 && next < n {
            // idle until the next arrival
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    println!("=== policy={policy} dataset={dataset} slots={slots} rate={rate_per_s}/s ===");
    println!("{}", batcher.metrics.report());
    println!("kv slot peak       {} / {}", batcher.kv_peak(), slots);
    let mean_tokens = batcher.metrics.reasoning_tokens as f64
        / batcher.metrics.completed.max(1) as f64;
    println!("mean reasoning tok {mean_tokens:.1}\n");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::load_or_reference(args.str_or("artifacts", "artifacts"));
    let mut cfg = ServeConfig::default();
    cfg.alpha = args.f64_or("alpha", cfg.alpha);
    cfg.delta = args.f64_or("delta", cfg.delta);
    cfg.seed = args.u64_or("seed", 0);

    let dataset = args.str_or("dataset", "synth-math500-small");
    let n = args.usize_or("requests", 48);
    let slots = args.usize_or("slots", 4);
    let rate = args.f64_or("rate", 4.0);

    run_workload(&rt, &cfg, dataset, n, slots, rate, "eat")?;
    run_workload(&rt, &cfg, dataset, n, slots, rate, "token")?;
    Ok(())
}
