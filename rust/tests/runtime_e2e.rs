//! End-to-end tests over the real AOT artifacts. These require the
//! `pjrt` feature (with a real xla-rs, not the stub) *and* a built
//! `artifacts/` directory; otherwise each test skips with a message —
//! they never fail on a clean checkout. The artifact-free equivalents of
//! the serving-protocol tests live in batcher_protocol.rs against the
//! reference backend.

use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{serve_one, Batcher, MonitorModel};
use eat_serve::datasets::{check_answer, Dataset};
use eat_serve::eval::TraceGen;
use eat_serve::exit::{EatPolicy, TokenBudgetPolicy};
use eat_serve::runtime::{Backend, BatchLane, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping e2e test (needs --features pjrt + `make artifacts`): {e}");
            None
        }
    }
}

/// Entropy returned by the probe (Pallas kernel inside the HLO) must match
/// host-side entropy computed from the probe's own logits.
#[test]
fn probe_entropy_matches_host_entropy() {
    let Some(rt) = runtime() else { return };
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, 3, 21);
    for q in &ds.questions {
        let mut prompt = q.prompt.clone();
        prompt.push(vocab.think);
        let (_l, cache) = rt.main.prefill(&prompt).unwrap();
        let (eat, logits) = rt.main.probe(&cache, &vocab.suffix_prefixed()).unwrap();
        // host entropy (f64, temperature 1)
        let mx = logits.iter().cloned().fold(f32::MIN, f32::max) as f64;
        let exps: Vec<f64> = logits.iter().map(|&z| ((z as f64) - mx).exp()).collect();
        let zsum: f64 = exps.iter().sum();
        let h: f64 = exps
            .iter()
            .map(|&e| {
                let p = e / zsum;
                if p > 0.0 {
                    -p * p.ln()
                } else {
                    0.0
                }
            })
            .sum();
        assert!(
            (eat as f64 - h).abs() < 1e-3,
            "kernel {} vs host {}",
            eat,
            h
        );
    }
}

/// Probing must not corrupt the cache: decode after a probe gives the same
/// logits as decode without the probe.
#[test]
fn probe_does_not_mutate_cache() {
    let Some(rt) = runtime() else { return };
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, 1, 22);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);
    let (_l, cache_a) = rt.main.prefill(&prompt).unwrap();
    let (_l2, cache_b) = rt.main.prefill(&prompt).unwrap();

    // probe cache_a several times
    for _ in 0..3 {
        rt.main.probe(&cache_a, &vocab.suffix_prefixed()).unwrap();
    }
    let mut ca = cache_a;
    let mut cb = cache_b;
    let la = rt.main.decode(&mut ca, vocab.nl).unwrap();
    let lb = rt.main.decode(&mut cb, vocab.nl).unwrap();
    for (a, b) in la.iter().zip(&lb) {
        assert!((a - b).abs() < 1e-5);
    }
}

/// Forked caches evolve independently.
#[test]
fn fork_cache_isolated() {
    let Some(rt) = runtime() else { return };
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, 1, 23);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);
    let (_l, mut cache) = rt.main.prefill(&prompt).unwrap();
    let mut fork = rt.main.fork(&cache).unwrap();
    // advance the fork differently
    rt.main.decode(&mut fork, vocab.ver).unwrap();
    rt.main.decode(&mut fork, vocab.unk).unwrap();
    assert_eq!(fork.pos(), cache.pos() + 2);
    // original still produces the same logits as a fresh prefill
    let (_l3, mut fresh) = rt.main.prefill(&prompt).unwrap();
    let a = rt.main.decode(&mut cache, vocab.nl).unwrap();
    let b = rt.main.decode(&mut fresh, vocab.nl).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5);
    }
}

/// Fused batched decode agrees with sequential single decodes — including
/// on the second call, when the resident batch image path kicks in.
#[test]
fn decode_batch_matches_sequential() {
    let Some(rt) = runtime() else { return };
    let Some(b) = rt.main.batch_width() else {
        return;
    };
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, b, 24);
    let mut fused = Vec::new();
    let mut seq = Vec::new();
    for q in ds.questions.iter().take(b) {
        let mut p = q.prompt.clone();
        p.push(vocab.think);
        let (_l, cache) = rt.main.prefill(&p).unwrap();
        seq.push(rt.main.fork(&cache).unwrap());
        fused.push(cache);
    }
    for round in 0..2 {
        let mut seq_logits = Vec::new();
        for c in seq.iter_mut() {
            seq_logits.push(rt.main.decode(c, vocab.nl).unwrap());
        }
        let mut lanes: Vec<Option<BatchLane>> = fused
            .iter_mut()
            .map(|c| {
                Some(BatchLane {
                    cache: c,
                    token: vocab.nl,
                })
            })
            .collect();
        let batch_logits = rt.main.decode_batch(&mut lanes).unwrap();
        drop(lanes);
        for (bl, sl) in batch_logits.iter().zip(&seq_logits) {
            let bl = bl.as_ref().unwrap();
            for (x, y) in bl.iter().zip(sl) {
                assert!((x - y).abs() < 1e-3, "round {round}: batch {x} vs seq {y}");
            }
        }
    }
    // second round must have reused the resident image for every lane
    assert!(rt.main.counters().batch_resident_lanes.get() >= b as u64);
}

/// The trained model actually solves easy questions through the full
/// serving path, and EAT exits use fewer tokens than the fixed budget at
/// matched accuracy on a small mixed workload.
#[test]
fn serving_accuracy_and_token_saving() {
    let Some(rt) = runtime() else { return };
    let cfg = ServeConfig::default();
    // easy/medium subset: on the hard tail the sampled reasoning itself is
    // error-prone (model accuracy ~0.75 overall), which is orthogonal to
    // what this test checks (EAT exits don't lose accuracy vs the budget
    // baseline and save tokens)
    let pool = Dataset::synth_math500(&rt.vocab, 60, 25);
    let questions: Vec<_> = pool
        .questions
        .into_iter()
        .filter(|q| q.n_ops() <= 5)
        .take(12)
        .collect();
    assert_eq!(questions.len(), 12);

    let mut eat_tokens = 0usize;
    let mut eat_correct = 0usize;
    let mut budget_tokens = 0usize;
    let mut budget_correct = 0usize;
    for q in &questions {
        let r = serve_one(
            &rt,
            &cfg,
            MonitorModel::SelfModel,
            q,
            Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens)),
            500 + q.id as u64,
        )
        .unwrap();
        eat_tokens += r.reasoning_tokens;
        eat_correct += r.correct as usize;
        let r2 = serve_one(
            &rt,
            &cfg,
            MonitorModel::SelfModel,
            q,
            Box::new(TokenBudgetPolicy::new(cfg.max_think_tokens)),
            500 + q.id as u64,
        )
        .unwrap();
        budget_tokens += r2.reasoning_tokens;
        budget_correct += r2.correct as usize;
    }
    // The claims under test are *relative* (the paper's): EAT exits do
    // not lose accuracy vs the full-budget baseline and never cost more
    // tokens. Absolute accuracy is a property of the tiny trained model
    // (~0.75 pass@1), shared by both policies.
    assert!(
        eat_correct as i64 >= budget_correct as i64 - 1,
        "EAT lost accuracy: {eat_correct} vs {budget_correct}"
    );
    assert!(
        eat_correct >= 6,
        "both policies collapsed: {eat_correct}/12"
    );
    assert!(
        eat_tokens <= budget_tokens,
        "EAT used more tokens: {eat_tokens} vs {budget_tokens}"
    );
}

/// The continuous batcher completes a queued workload, respects the slot
/// cap, and reports sane metrics.
#[test]
fn batcher_completes_workload() {
    let Some(rt) = runtime() else { return };
    let cfg = ServeConfig::default();
    let slots = 3usize;
    let mut batcher = Batcher::new(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        slots,
        Box::new(move || Box::new(EatPolicy::new(0.2, 1e-3, 96))),
    );
    let ds = Dataset::synth_math500(&rt.vocab, 8, 26);
    for q in &ds.questions {
        batcher.submit(q.clone());
    }
    batcher.run_to_completion().unwrap();
    assert_eq!(batcher.metrics.completed, 8);
    assert!(batcher.kv_peak() <= slots);
    assert!(batcher.metrics.accuracy() > 0.6);
    assert_eq!(batcher.pending(), 0);
    assert_eq!(batcher.active_count(), 0);
}

/// Black-box path: proxy monitoring stops solvable questions early and
/// the answer extraction agrees with check_answer.
#[test]
fn blackbox_stops_early_on_solvable() {
    let Some(rt) = runtime() else { return };
    // chunk-granularity monitoring defaults — same settings as the CLI
    // and examples/blackbox_claude.rs
    let mut cfg = ServeConfig::default();
    cfg.delta = eat_serve::blackbox::CHUNK_MONITOR_DELTA;
    cfg.alpha = eat_serve::blackbox::CHUNK_MONITOR_ALPHA;
    // medium-hard questions have the long overthinking tails the monitor
    // can cut (easy ones self-terminate within a chunk or two — nothing to
    // save there)
    let pool = Dataset::synth_aime(&rt.vocab, 30, 27);
    let questions: Vec<_> = pool
        .questions
        .into_iter()
        .filter(|q| (6..=8).contains(&q.n_ops()))
        .take(4)
        .collect();
    let mut stopped = 0;
    for q in &questions {
        let res = eat_serve::blackbox::run_blackbox(
            &rt,
            &cfg,
            q,
            eat_serve::blackbox::LatencyModel::default(),
            8,
            13,
        )
        .unwrap();
        stopped += res.stop_chunk.is_some() as usize;
        assert_eq!(res.correct, check_answer(&rt.vocab, q, &res.answer_tail));
    }
    assert!(stopped >= 2, "expected early stops on easy questions");
}

/// Trace generation emits the fields every figure depends on.
#[test]
fn tracegen_records_all_signals() {
    let Some(rt) = runtime() else { return };
    let cfg = ServeConfig::default();
    let tracegen = TraceGen::new(&rt, cfg);
    let ds = Dataset::synth_math500(&rt.vocab, 2, 28);
    let t = tracegen.run(&ds.questions[0], 0).unwrap();
    assert!(!t.points.is_empty());
    for p in &t.points {
        assert!(p.eat.is_finite());
        assert!(p.eat_proxy.unwrap().is_finite());
        assert!(p.eat_plain.unwrap().is_finite());
        assert!(p.eat_newline.unwrap().is_finite());
        assert!(p.confidence.unwrap() > 0.0);
        assert!((0.0..=1.0).contains(&p.pass1_avgk));
        assert!(p.unique_answers >= 1);
    }
    // Pass@1 saturation implies low EAT at the end for solvable questions
    let last = t.points.last().unwrap();
    if last.pass1_avgk > 0.9 {
        assert!(last.eat < 0.5, "EAT should be low once Pass@1 saturates");
    }
}
