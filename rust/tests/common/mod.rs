//! Helpers shared by the coordinator integration suites
//! (batcher_protocol.rs, scheduler_sim.rs). Not a test target itself —
//! pulled in via `mod common;`.

use eat_serve::coordinator::RequestResult;

pub use eat_serve::coordinator::eat_policy_factory as eat_factory;

/// The comparable portion of a result (wall-clock excluded) — the
/// definition of "bit-identical" the determinism suites assert on.
#[allow(clippy::type_complexity)]
pub fn key(r: &RequestResult) -> (usize, String, usize, usize, usize, usize, Vec<u32>, bool) {
    (
        r.question_id,
        format!("{:?}", r.exit_reason),
        r.reasoning_tokens,
        r.lines,
        r.probes,
        r.rollout_tokens,
        r.answer_tail.clone(),
        r.correct,
    )
}
