//! Deterministic serving simulator suite (DESIGN.md §3.4): end-to-end
//! runs on the reference backend under a VIRTUAL clock, pinning down
//!
//!  * same seed ⇒ byte-identical metrics JSON (completed/correct/token
//!    counts, latency percentiles, slot timeline) across runs;
//!  * fused vs `force_sequential` decode paths ⇒ identical metrics;
//!  * preempt → suspend → resume-by-re-prefill ⇒ bit-identical
//!    trajectories vs an uninterrupted FIFO run of the same workload;
//!  * under slot contention, the EAT-aware scheduler (preemption + stall
//!    retirement) completes the workload with fewer total reasoning
//!    tokens than FIFO at equal accuracy.
//!
//! Per-request RNGs are seeded from the submission sequence number, so a
//! request's trajectory is invariant to admission order and scheduling
//! mode — that invariance is what makes the cross-mode comparisons exact.

mod common;

use common::{eat_factory, key};
use eat_serve::config::{OverloadPolicy, SchedMode, ServeConfig};
use eat_serve::coordinator::{
    pick_shed_victims, poisson_arrivals, run_open_loop, Batcher, MetricsReport, MonitorModel,
    RequestResult, ServeMetrics, DEFAULT_TICK_DT,
};
use eat_serve::datasets::{chainsum::Kind, Dataset, Question};
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::clock::Clock;

/// One full open-loop serve run under a fresh virtual clock; returns the
/// metrics JSON string and the results sorted by question id. `mono`
/// runs the monolithic full-sequence KV store instead of the default
/// paged copy-on-write store.
#[allow(clippy::too_many_arguments)]
fn run_sim_on(
    mono: bool,
    mode: SchedMode,
    slots: usize,
    n: usize,
    rate: f64,
    seed: u64,
    sequential: bool,
) -> (String, Vec<RequestResult>) {
    let rt = if mono {
        Runtime::reference_monolithic()
    } else {
        Runtime::reference()
    };
    let mut cfg = ServeConfig::default();
    cfg.seed = seed;
    cfg.sched.mode = mode;
    let ds = Dataset::synth_gpqa(&rt.vocab, n.max(4), seed);
    let mut b = Batcher::with_clock(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        slots,
        eat_factory(&cfg),
        Clock::virt(),
    );
    b.force_sequential = sequential;
    let arrivals = poisson_arrivals(n, rate, seed);
    run_open_loop(&mut b, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    assert_eq!(b.metrics.completed, n);
    assert_eq!(b.pending(), 0);
    assert_eq!(b.active_count(), 0);
    assert_eq!(b.suspended_count(), 0);
    let json = b.metrics.to_json().to_string();
    let mut results = b.results;
    results.sort_by_key(|r| r.question_id);
    (json, results)
}

fn run_sim(
    mode: SchedMode,
    slots: usize,
    n: usize,
    rate: f64,
    seed: u64,
    sequential: bool,
) -> (String, Vec<RequestResult>) {
    run_sim_on(false, mode, slots, n, rate, seed, sequential)
}

#[test]
fn same_seed_virtual_runs_are_byte_identical() {
    // the golden determinism guarantee: an entire serve run — arrivals,
    // admission order, preemptions, latency percentiles — is a pure
    // function of the seed under the virtual clock
    let (json_a, res_a) = run_sim(SchedMode::EatAware, 2, 16, 30.0, 7, false);
    let (json_b, res_b) = run_sim(SchedMode::EatAware, 2, 16, 30.0, 7, false);
    assert_eq!(json_a, json_b, "same-seed metrics JSON diverged");
    assert_eq!(res_a.len(), res_b.len());
    for (a, b) in res_a.iter().zip(&res_b) {
        assert_eq!(key(a), key(b));
        assert_eq!(a.wall_ms, b.wall_ms, "virtual latencies must be exact");
    }
    // the snapshot carries the full percentile set and the timeline
    assert!(json_a.contains("\"p99\""));
    assert!(json_a.contains("\"slot_timeline\""));
    // a different seed produces a different run
    let (json_c, _) = run_sim(SchedMode::EatAware, 2, 16, 30.0, 8, false);
    assert_ne!(json_a, json_c, "seed is not reaching the simulation");
}

#[test]
fn fused_and_sequential_paths_emit_identical_metrics() {
    // the session protocol cannot observe which decode path serviced it,
    // and the tick structure is identical — so even latency percentiles
    // and the slot timeline must match byte-for-byte
    let (json_fused, res_fused) = run_sim(SchedMode::EatAware, 2, 12, 25.0, 11, false);
    let (json_seq, res_seq) = run_sim(SchedMode::EatAware, 2, 12, 25.0, 11, true);
    assert_eq!(json_fused, json_seq, "fused vs sequential metrics diverged");
    for (a, b) in res_fused.iter().zip(&res_seq) {
        assert_eq!(key(a), key(b));
    }
}

/// A contended workload with known composition: `n_corrupted` unsolvable
/// questions first (they stall — EAT never stabilizes), then easy
/// solvable ones (n_ops <= 4: they leave the reasoning phase within ~30
/// ticks, well inside the aging bound, so stall handling can never touch
/// their trajectories).
fn mixed_workload(n_corrupted: usize, n_solvable: usize, seed: u64) -> Vec<Question> {
    let rt = Runtime::reference();
    let pool = Dataset::synth_gpqa(&rt.vocab, 120, seed);
    let corrupted: Vec<Question> = pool
        .questions
        .iter()
        .filter(|q| q.kind == Kind::Corrupted)
        .take(n_corrupted)
        .cloned()
        .collect();
    let solvable: Vec<Question> = pool
        .questions
        .iter()
        .filter(|q| q.kind == Kind::ChainSum && q.n_ops() <= 4)
        .take(n_solvable)
        .cloned()
        .collect();
    assert_eq!(corrupted.len(), n_corrupted, "pool too small");
    assert_eq!(solvable.len(), n_solvable, "pool too small");
    let mut qs = corrupted;
    qs.extend(solvable);
    qs
}

/// Submit a fixed workload upfront (slot contention: slots < requests)
/// and drain it on the given runtime.
fn run_contended_on(
    rt: &Runtime,
    cfg: &ServeConfig,
    questions: &[Question],
    slots: usize,
) -> ContendedRun {
    let mut b = Batcher::with_clock(
        rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        slots,
        eat_factory(cfg),
        Clock::virt(),
    );
    for q in questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.completed, questions.len());
    assert_eq!(b.suspended_count(), 0);
    let mut results = b.results;
    results.sort_by_key(|r| r.question_id);
    ContendedRun {
        reasoning_tokens: b.metrics.reasoning_tokens,
        correct: b.metrics.correct,
        preemptions: b.metrics.preemptions,
        resumes: b.metrics.resumes,
        resume_prefill_tokens: b.metrics.resume_prefill_tokens,
        spills: b.metrics.kv_spills,
        stalled: b.metrics.exit_reasons.get("Stalled").copied().unwrap_or(0),
        results,
    }
}

fn run_contended(cfg: &ServeConfig, questions: &[Question], slots: usize) -> ContendedRun {
    run_contended_on(&Runtime::reference(), cfg, questions, slots)
}

struct ContendedRun {
    reasoning_tokens: u64,
    correct: usize,
    preemptions: u64,
    resumes: u64,
    resume_prefill_tokens: u64,
    spills: u64,
    stalled: usize,
    results: Vec<RequestResult>,
}

#[test]
fn preempted_then_resumed_sessions_are_bit_identical_to_uninterrupted() {
    // pure preempt/resume round-trip: a huge starvation guard disables
    // stall retirement, so the EAT-aware run differs from FIFO only in
    // WHEN sessions run, never in WHAT they compute
    let questions = mixed_workload(2, 8, 5);
    let mut cfg = ServeConfig::default();
    cfg.seed = 5;
    cfg.delta = 1e-7; // corrupted V-hat sits decades above the threshold
    cfg.sched.stall_stability = 0.2;
    cfg.sched.preempt_after_ticks = 8; // aggressive preemption
    cfg.sched.max_preemptions = 100; // retirement never triggers

    let mut eat_cfg = cfg.clone();
    eat_cfg.sched.mode = SchedMode::EatAware;
    let preemptive = run_contended(&eat_cfg, &questions, 2);

    let mut fifo_cfg = cfg.clone();
    fifo_cfg.sched.mode = SchedMode::Fifo;
    let fifo = run_contended(&fifo_cfg, &questions, 2);

    assert!(preemptive.preemptions > 0, "contended stalled sessions must get preempted");
    assert_eq!(preemptive.resumes, preemptive.preemptions, "every suspended session must resume");
    assert!(preemptive.resume_prefill_tokens > 0, "resume must restore the committed history");
    assert_eq!(fifo.preemptions, 0, "FIFO must never preempt");
    // the acceptance bit-identity: token history, probe count, exit step
    // and answer tail all survive the suspend/re-prefill round trip
    assert_eq!(preemptive.results.len(), fifo.results.len());
    for (p, f) in preemptive.results.iter().zip(&fifo.results) {
        assert_eq!(key(p), key(f), "preempt/resume changed a trajectory");
    }
}

#[test]
fn eat_aware_scheduler_saves_tokens_at_equal_accuracy_under_contention() {
    // acceptance criterion: slots < concurrent requests; the EAT-aware
    // scheduler preempts the stalled (corrupted) sessions and — once
    // they burn through the starvation guard still showing no EAT
    // progress — retires them by forced elicitation, while FIFO lets
    // them burn the full token budget. Solvable trajectories are
    // untouched (they exit reasoning inside the aging bound), so
    // accuracy is exactly equal and total reasoning tokens strictly
    // smaller.
    let questions = mixed_workload(3, 9, 9);
    let mut cfg = ServeConfig::default();
    cfg.seed = 9;
    cfg.delta = 1e-7;
    cfg.sched.stall_stability = 0.2;
    cfg.sched.preempt_after_ticks = 32;
    cfg.sched.max_preemptions = 1;

    let mut eat_cfg = cfg.clone();
    eat_cfg.sched.mode = SchedMode::EatAware;
    let eat_aware = run_contended(&eat_cfg, &questions, 2);

    let mut fifo_cfg = cfg.clone();
    fifo_cfg.sched.mode = SchedMode::Fifo;
    let fifo = run_contended(&fifo_cfg, &questions, 2);

    assert!(
        eat_aware.reasoning_tokens < fifo.reasoning_tokens,
        "EAT-aware must spend fewer reasoning tokens: {} vs {}",
        eat_aware.reasoning_tokens,
        fifo.reasoning_tokens
    );
    assert_eq!(
        eat_aware.correct,
        fifo.correct,
        "adaptive compute allocation must not cost accuracy"
    );
    assert_eq!(
        eat_aware.stalled,
        3,
        "each corrupted question should be stall-retired exactly once"
    );
    // per-question: solvable trajectories are bit-identical; corrupted
    // ones are wrong either way (unsolvable questions are never correct)
    for (e, f) in eat_aware.results.iter().zip(&fifo.results) {
        assert_eq!(e.question_id, f.question_id);
        if format!("{:?}", e.exit_reason) == "Stalled" {
            assert!(!e.correct && !f.correct);
            assert!(e.reasoning_tokens < f.reasoning_tokens);
        } else {
            assert_eq!(key(e), key(f));
        }
    }
}

#[test]
fn paged_and_monolithic_stores_emit_byte_identical_metrics() {
    // the paged-store acceptance bar: same seed, same scheduler, same
    // workload — the ENTIRE metrics JSON (counters, latency percentiles,
    // slot timeline, resume accounting) must not depend on whether KV
    // state lives in a paged CoW pool or monolithic full-sequence caches
    let (json_paged, res_paged) = run_sim_on(false, SchedMode::EatAware, 2, 16, 30.0, 7, false);
    let (json_mono, res_mono) = run_sim_on(true, SchedMode::EatAware, 2, 16, 30.0, 7, false);
    assert_eq!(json_paged, json_mono, "paged vs monolithic metrics diverged");
    for (p, m) in res_paged.iter().zip(&res_mono) {
        assert_eq!(key(p), key(m), "paged vs monolithic trajectory diverged");
    }
    // and under FIFO too (no preemption in the mix)
    let (json_paged, _) = run_sim_on(false, SchedMode::Fifo, 2, 12, 25.0, 11, false);
    let (json_mono, _) = run_sim_on(true, SchedMode::Fifo, 2, 12, 25.0, 11, false);
    assert_eq!(json_paged, json_mono);
}

#[test]
fn page_repin_resume_skips_the_reprefill_entirely() {
    // preempt/resume on the paged store must unpin/repin pages: zero
    // extra prefill calls on the backend, trajectories token-for-token
    // identical to the monolithic re-prefill path AND to an
    // uninterrupted FIFO run
    let questions = mixed_workload(2, 8, 5);
    let mut cfg = ServeConfig::default();
    cfg.seed = 5;
    cfg.delta = 1e-7;
    cfg.sched.mode = SchedMode::EatAware;
    cfg.sched.stall_stability = 0.2;
    cfg.sched.preempt_after_ticks = 8;
    cfg.sched.max_preemptions = 100;

    let paged_rt = Runtime::reference();
    let paged = run_contended_on(&paged_rt, &cfg, &questions, 2);
    let mono_rt = Runtime::reference_monolithic();
    let mono = run_contended_on(&mono_rt, &cfg, &questions, 2);

    assert!(paged.preemptions > 0, "workload never hit the preemptor");
    assert_eq!(paged.preemptions, mono.preemptions);
    assert_eq!(paged.resumes, mono.resumes);
    assert_eq!(paged.spills, 0, "default budget must never spill");
    // the monolithic store re-prefills once per resume; the paged store
    // repins — exactly one prefill per request, ever
    assert_eq!(
        paged_rt.main.counters().prefills.get(),
        questions.len() as u64,
        "paged resume must not re-prefill"
    );
    assert_eq!(
        mono_rt.main.counters().prefills.get(),
        questions.len() as u64 + mono.resumes,
        "monolithic resume must re-prefill"
    );
    // the restored-token accounting is identical either way (that is
    // what keeps the metrics JSON byte-comparable across stores)
    assert_eq!(paged.resume_prefill_tokens, mono.resume_prefill_tokens);
    for (p, m) in paged.results.iter().zip(&mono.results) {
        assert_eq!(key(p), key(m), "repin changed a trajectory");
    }
}

#[test]
fn host_budget_pressure_spills_to_reprefill_bit_identically() {
    // a tight --kv-pages budget: only one worst-case session resident
    // (8 pages at page size 16 over seq 128), and suspended sessions
    // compete for the same 8 host pages — retention overflows, pages
    // are spilled, and the re-prefill fallback must reproduce the exact
    // trajectories of an uncontended monolithic FIFO run
    let questions = mixed_workload(2, 6, 5);
    let mut cfg = ServeConfig::default();
    cfg.seed = 5;
    cfg.delta = 1e-7;
    cfg.kv_pages = Some(8);
    cfg.sched.mode = SchedMode::EatAware;
    cfg.sched.stall_stability = 0.2;
    cfg.sched.preempt_after_ticks = 64; // suspendees carry ~5 pages each
    cfg.sched.max_preemptions = 100;

    let paged_rt = Runtime::reference();
    let pressured = run_contended_on(&paged_rt, &cfg, &questions, 2);
    assert!(pressured.preemptions > 0, "page pressure never preempted");
    assert!(pressured.spills > 0, "host budget never overflowed");
    assert!(
        paged_rt.main.counters().prefills.get() > questions.len() as u64,
        "spilled sessions must fall back to re-prefill"
    );

    let mut fifo_cfg = ServeConfig::default();
    fifo_cfg.seed = 5;
    fifo_cfg.delta = 1e-7;
    let fifo = run_contended_on(&Runtime::reference_monolithic(), &fifo_cfg, &questions, 2);
    assert_eq!(pressured.results.len(), fifo.results.len());
    for (p, f) in pressured.results.iter().zip(&fifo.results) {
        assert_eq!(key(p), key(f), "spill fallback changed a trajectory");
    }
}

#[test]
fn proxy_monitored_sessions_survive_preemption() {
    // black-box monitoring keeps a second (proxy) KV cache per session;
    // preemption must rebuild BOTH caches on resume — trajectories still
    // match the uninterrupted FIFO run
    let questions = mixed_workload(2, 4, 13);
    let mut cfg = ServeConfig::default();
    cfg.seed = 13;
    cfg.delta = 1e-7;
    cfg.sched.stall_stability = 0.2;
    cfg.sched.preempt_after_ticks = 8;
    cfg.sched.max_preemptions = 100;

    let run = |mode: SchedMode| {
        let rt = Runtime::reference();
        let mut c = cfg.clone();
        c.sched.mode = mode;
        let mut b = Batcher::with_clock(
            &rt,
            c.clone(),
            MonitorModel::Proxy,
            2,
            eat_factory(&c),
            Clock::virt(),
        );
        for q in &questions {
            b.submit(q.clone());
        }
        b.run_to_completion().unwrap();
        assert_eq!(b.metrics.completed, questions.len());
        let preemptions = b.metrics.preemptions;
        let mut results = b.results;
        results.sort_by_key(|r| r.question_id);
        (preemptions, results)
    };
    let (preemptions, eat_results) = run(SchedMode::EatAware);
    let (_, fifo_results) = run(SchedMode::Fifo);
    assert!(preemptions > 0, "proxy workload never hit the preemptor");
    for (p, f) in eat_results.iter().zip(&fifo_results) {
        assert_eq!(key(p), key(f), "proxy cache not rebuilt faithfully");
    }
}

#[test]
fn steady_state_ticks_do_not_allocate() {
    // the per-tick work lists are preallocated to the slot count and the
    // active set can never exceed it, so the whole run — warmup included —
    // performs zero scratch reallocations (allocs_per_tick == 0)
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.seed = 7;
    cfg.sched.mode = SchedMode::EatAware;
    let ds = Dataset::synth_gpqa(&rt.vocab, 16, 7);
    let mut b = Batcher::with_clock(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        3,
        eat_factory(&cfg),
        Clock::virt(),
    );
    let arrivals = poisson_arrivals(16, 30.0, 7);
    run_open_loop(&mut b, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    assert_eq!(b.metrics.completed, 16);
    let c = rt.main.counters();
    assert!(c.sched_ticks.get() > 0, "no ticks recorded");
    assert_eq!(c.sched_allocs.get(), 0, "tick scratch reallocated");
}

/// Shed-victim ordering on a hand-built candidate set (the proptest in
/// `proptests.rs` quantifies the same contract over random inputs):
/// descending stability, ties broken oldest-submission-first, skipping
/// no-signal and already-draining sessions and anything below the
/// stability floor.
#[test]
fn shed_victim_selection_fixed_example() {
    // (ExitPolicy::stability, submission seq, eliciting)
    let candidates = [
        (Some(0.9), 5, false), // 0: stable, newer of the 0.9 pair
        (Some(0.3), 1, false), // 1: below the floor — not near an exit
        (None, 2, false),      // 2: no signal yet — never shed
        (Some(0.9), 3, false), // 3: stable, older → outranks index 0
        (Some(0.7), 4, true),  // 4: mid-elicitation — already draining
        (Some(0.7), 0, false), // 5: qualifies, lowest stability last
    ];
    assert_eq!(pick_shed_victims(&candidates, 0.5), vec![3, 0, 5]);
    assert_eq!(pick_shed_victims(&candidates, 0.95), Vec::<usize>::new());
}

/// One saturated open-loop run — a burst of arrivals far over what two
/// slots can drain — under the given overload policy. Returns the final
/// metrics JSON and the counters the overload assertions inspect.
fn run_overload(policy: OverloadPolicy, deadline_s: f64, seed: u64) -> (String, ServeMetrics) {
    let n = 24;
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.seed = seed;
    cfg.sched.mode = SchedMode::EatAware;
    cfg.sched.overload = policy;
    cfg.sched.deadline_s = deadline_s;
    let ds = Dataset::synth_gpqa(&rt.vocab, n, seed);
    let mut b = Batcher::with_clock(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        2,
        eat_factory(&cfg),
        Clock::virt(),
    );
    let arrivals = poisson_arrivals(n, 400.0, seed);
    run_open_loop(&mut b, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    assert_eq!(b.pending(), 0);
    assert_eq!(b.active_count(), 0);
    assert_eq!(b.suspended_count(), 0);
    (b.metrics.to_json().to_string(), b.metrics)
}

#[test]
fn eat_shed_fires_under_page_pressure_without_spills() {
    // infinite SLO isolates the shedding path: nothing is rejected, so
    // every arrival must complete — some of them early via forced exit
    let (json, m) = run_overload(OverloadPolicy::EatShed, f64::INFINITY, 7);
    assert!(m.shed_exits > 0, "saturated run never shed a session");
    assert_eq!(m.rejected, 0, "infinite deadline cannot reject");
    assert_eq!(m.completed, 24, "shed sessions still complete (early)");
    assert_eq!(m.kv_spills, 0, "shedding must free lanes without spilling");
    assert_eq!(
        m.exit_reasons.get("Shed").copied().unwrap_or(0) as u64,
        m.shed_exits,
        "every shed must surface as ExitReason::Shed"
    );
    assert!(json.contains("\"shed_exits\""), "metrics JSON lost the shed counter");
    // overload runs stay a pure function of the seed
    let (json_b, _) = run_overload(OverloadPolicy::EatShed, f64::INFINITY, 7);
    assert_eq!(json, json_b, "EAT-shed run is not deterministic");
}

#[test]
fn reject_only_drops_expired_arrivals_and_accounts_every_request() {
    // a deadline far tighter than the backlog can meet: late arrivals
    // are rejected at the queue head, never admitted, and the
    // completed/rejected split still accounts for every submission
    let (json, m) = run_overload(OverloadPolicy::RejectOnly, 0.5, 7);
    assert!(m.rejected > 0, "tight deadline under saturation never rejected");
    assert_eq!(m.shed_exits, 0, "reject-only must not shed residents");
    assert_eq!(
        m.completed + m.rejected as usize,
        24,
        "a request was neither completed nor rejected"
    );
    assert!(m.slo_attainment() < 1.0, "rejections must dent SLO attainment");
    assert!(json.contains("\"rejected\""), "metrics JSON lost the reject counter");
    let (json_b, _) = run_overload(OverloadPolicy::RejectOnly, 0.5, 7);
    assert_eq!(json, json_b, "reject-only run is not deterministic");
}
