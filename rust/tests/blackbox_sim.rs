//! Deterministic black-box serving simulator suite (DESIGN.md §3.6):
//! end-to-end proxy-monitored stream runs on the reference backend under
//! a VIRTUAL clock, pinning down
//!
//!  * same seed ⇒ byte-identical metrics JSON (stream/stop counts,
//!    overlap accounting, latency percentiles) across runs;
//!  * fused vs `force_sequential` decode paths ⇒ identical metrics —
//!    on the remote-main lanes AND on the local-proxy lanes (asserted
//!    against a proxy that carries a fused batch entry point);
//!  * per-stream trajectories are invariant to the batch width: B
//!    concurrent streams produce exactly the single-lane trajectories;
//!  * trajectories are bit-identical under different [`LatencyModel`]
//!    settings — the RNG-split regression: latency jitter draws from a
//!    dedicated stream and can only move timestamps.

use eat_serve::blackbox::{
    BlackboxBatcher, BlackboxConfig, BlackboxResult, LatencyModel, ProxyCostModel,
    CHUNK_MONITOR_ALPHA, CHUNK_MONITOR_DELTA,
};
use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{poisson_arrivals, run_open_loop, MetricsReport, DEFAULT_TICK_DT};
use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, RefBackend, Runtime};
use eat_serve::util::clock::Clock;
use eat_serve::vocab::Vocab;

fn bb_cfg(chunk_tokens: usize, latency: LatencyModel) -> BlackboxConfig {
    BlackboxConfig {
        chunk_tokens,
        latency,
        proxy_cost: ProxyCostModel::default(),
    }
}

fn serve_cfg(seed: u64) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.alpha = CHUNK_MONITOR_ALPHA;
    cfg.delta = CHUNK_MONITOR_DELTA;
    cfg.seed = seed;
    cfg
}

/// The comparable portion of a stream result: everything except the
/// shared-clock latency — including the bit patterns of every monitor
/// point (eat, vhat, arrival gap, proxy compute).
#[allow(clippy::type_complexity)]
fn key(r: &BlackboxResult) -> (usize, Option<usize>, usize, usize, Vec<u32>, bool, Vec<[u64; 4]>) {
    (
        r.question_id,
        r.stop_chunk,
        r.tokens_at_stop,
        r.chunks,
        r.answer_tail.clone(),
        r.correct,
        r.points
            .iter()
            .map(|p| {
                [
                    p.eat.to_bits(),
                    p.vhat.to_bits(),
                    p.arrival_gap_ms.to_bits(),
                    p.proxy_compute_ms.to_bits(),
                ]
            })
            .collect(),
    )
}

/// One full open-loop black-box serve run under a fresh virtual clock.
fn run_sim_on(
    rt: &Runtime,
    slots: usize,
    n: usize,
    rate: f64,
    seed: u64,
    sequential: bool,
    latency: LatencyModel,
) -> (String, Vec<BlackboxResult>) {
    let cfg = serve_cfg(seed);
    let ds = Dataset::synth_aime(&rt.vocab, n.max(4), seed);
    let mut b =
        BlackboxBatcher::with_clock(rt, cfg, bb_cfg(8, latency), slots, Clock::virt());
    b.force_sequential = sequential;
    let arrivals = poisson_arrivals(n, rate, seed);
    run_open_loop(&mut b, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    assert_eq!(b.metrics.completed, n);
    assert_eq!(b.pending(), 0);
    assert_eq!(b.active_count(), 0);
    let json = b.metrics.to_json().to_string();
    let mut results = b.results;
    results.sort_by_key(|r| r.question_id);
    (json, results)
}

fn run_sim(
    slots: usize,
    n: usize,
    rate: f64,
    seed: u64,
    sequential: bool,
) -> (String, Vec<BlackboxResult>) {
    run_sim_on(
        &Runtime::reference(),
        slots,
        n,
        rate,
        seed,
        sequential,
        LatencyModel::default(),
    )
}

#[test]
fn same_seed_blackbox_runs_are_byte_identical() {
    // the golden determinism guarantee: a many-stream proxy-monitored
    // serve run — arrivals, chunk deliveries, stops, overlap accounting
    // — is a pure function of the seed under the virtual clock
    let (json_a, res_a) = run_sim(4, 10, 3.0, 7, false);
    let (json_b, res_b) = run_sim(4, 10, 3.0, 7, false);
    assert_eq!(json_a, json_b, "same-seed blackbox metrics JSON diverged");
    assert_eq!(res_a.len(), res_b.len());
    for (a, b) in res_a.iter().zip(&res_b) {
        assert_eq!(key(a), key(b));
    }
    // the snapshot carries the overlap accounting
    assert!(json_a.contains("\"overlap_headroom\""));
    assert!(json_a.contains("\"proxy_compute_ms\""));
    // a different seed produces a different run
    let (json_c, _) = run_sim(4, 10, 3.0, 8, false);
    assert_ne!(json_a, json_c, "seed is not reaching the simulation");
}

#[test]
fn fused_and_sequential_paths_emit_identical_metrics() {
    // the stream protocol cannot observe which decode path serviced it
    let (json_fused, res_fused) = run_sim(4, 8, 3.0, 11, false);
    let (json_seq, res_seq) = run_sim(4, 8, 3.0, 11, true);
    assert_eq!(json_fused, json_seq, "fused vs sequential metrics diverged");
    for (a, b) in res_fused.iter().zip(&res_seq) {
        assert_eq!(key(a), key(b));
    }
}

/// A reference runtime whose PROXY also carries a fused batch entry
/// point, so the local-proxy lanes exercise `decode_batch` too.
fn batched_proxy_runtime() -> Runtime {
    let vocab = Vocab::default_layout();
    Runtime {
        vocab,
        main: Box::new(RefBackend::new("ref-main", vocab, 128, Some(8))),
        proxy: Box::new(RefBackend::new("ref-proxy", vocab, 128, Some(8))),
        artifacts: None,
    }
}

#[test]
fn batched_proxy_decode_is_bit_identical_to_sequential() {
    // acceptance bar: batched vs sequential PROXY decode cannot change a
    // thing — neither within one runtime (force_sequential A/B) nor
    // against the default runtime whose proxy has no batch entry point
    let rt_batched = batched_proxy_runtime();
    let (json_fused, res_fused) = run_sim_on(
        &rt_batched, 4, 8, 3.0, 13, false, LatencyModel::default(),
    );
    let (json_seq, res_seq) = run_sim_on(
        &rt_batched, 4, 8, 3.0, 13, true, LatencyModel::default(),
    );
    assert_eq!(json_fused, json_seq, "batched proxy lanes changed the run");
    // the fused path actually engaged the proxy's batch entry point
    assert!(
        rt_batched.main.counters().batch_decodes.get() > 0,
        "main fused path never engaged"
    );
    assert!(
        rt_batched.proxy.counters().batch_decodes.get() > 0,
        "proxy fused path never engaged"
    );
    let (json_unbatched, res_unbatched) = run_sim(4, 8, 3.0, 13, false);
    assert_eq!(json_fused, json_unbatched, "proxy batch width leaked into metrics");
    for ((a, b), c) in res_fused.iter().zip(&res_seq).zip(&res_unbatched) {
        assert_eq!(key(a), key(b));
        assert_eq!(key(a), key(c));
    }
}

#[test]
fn trajectories_are_invariant_to_batch_width() {
    // B concurrent streams must produce exactly the trajectories of a
    // single-lane run: per-stream RNGs are seeded by submission seq and
    // monitor decisions depend only on delivered content
    let (_json_wide, res_wide) = run_sim(4, 8, 3.0, 5, false);
    let (_json_narrow, res_narrow) = run_sim(1, 8, 3.0, 5, false);
    assert_eq!(res_wide.len(), res_narrow.len());
    for (w, n) in res_wide.iter().zip(&res_narrow) {
        assert_eq!(key(w), key(n), "batch width changed a trajectory");
    }
}

#[test]
fn trajectories_are_invariant_to_the_latency_model() {
    // the RNG-split regression at serve scale: a slower, noisier remote
    // moves every timestamp but not a single sampled token or stop
    let rt = Runtime::reference();
    let slow = LatencyModel {
        base_ms: 300.0,
        per_token_ms: 80.0,
        jitter: 0.5,
    };
    let fast = LatencyModel {
        base_ms: 2.0,
        per_token_ms: 0.5,
        jitter: 0.0,
    };
    let (json_slow, res_slow) = run_sim_on(&rt, 4, 8, 3.0, 9, false, slow);
    let rt2 = Runtime::reference();
    let (json_fast, res_fast) = run_sim_on(&rt2, 4, 8, 3.0, 9, false, fast);
    assert_ne!(json_slow, json_fast, "latency must move the timestamps");
    for (s, f) in res_slow.iter().zip(&res_fast) {
        assert_eq!(s.question_id, f.question_id);
        assert_eq!(s.stop_chunk, f.stop_chunk, "latency changed a stop decision");
        assert_eq!(s.tokens_at_stop, f.tokens_at_stop);
        assert_eq!(s.chunks, f.chunks);
        assert_eq!(s.answer_tail, f.answer_tail, "latency changed a trajectory");
        assert_eq!(s.points.len(), f.points.len());
        for (ps, pf) in s.points.iter().zip(&f.points) {
            assert_eq!(ps.eat.to_bits(), pf.eat.to_bits());
            assert_eq!(ps.vhat.to_bits(), pf.vhat.to_bits());
        }
    }
}

#[test]
fn monitor_stops_streams_and_overlap_holds() {
    // qualitative Fig. 5 behavior at serve scale: a good share of the
    // solvable streams stop early, the saving is positive, and the
    // modeled proxy compute hides inside every chunk gap
    let (json, res) = run_sim(4, 12, 3.0, 21, false);
    let stopped = res.iter().filter(|r| r.stop_chunk.is_some()).count();
    assert!(stopped >= 2, "expected early stops, got {stopped}/12");
    let saved: f64 = res.iter().map(|r| r.saved_ms).sum();
    assert!(saved > 0.0);
    assert!(json.contains("\"overrun_chunks\":0"), "proxy compute overran a gap: {json}");
    for r in &res {
        for p in &r.points {
            assert!(
                p.proxy_compute_ms < p.arrival_gap_ms,
                "q{} chunk {}: compute {} ms vs gap {} ms",
                r.question_id,
                p.chunk,
                p.proxy_compute_ms,
                p.arrival_gap_ms
            );
        }
    }
}
