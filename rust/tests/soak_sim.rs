//! Soak-core contracts (DESIGN.md §3.10), at scales big enough to force
//! wheel rotation, slab reuse and reservoir sampling but small enough
//! for tier-1:
//!
//!  * determinism — double runs serialize byte-identical report JSON
//!    (the same diff the CI `soak-smoke` job performs at 100k sessions);
//!  * core equivalence — the event core and the pre-wheel driver core
//!    agree on every completion invariant across random configs;
//!  * memory — the accounted footprint is bounded by residency (pushing
//!    10x the sessions through leaves it flat) and the `--mem-mb`
//!    ceiling actually fails a breaching run.

use eat_serve::coordinator::{run_soak, session_demand, SoakConfig, SoakMode};
use eat_serve::util::json::Json;
use eat_serve::util::rng::Rng;

fn base() -> SoakConfig {
    SoakConfig {
        sessions: 20_000,
        rate_per_s: 500.0,
        slots: 256,
        seed: 0,
        ..SoakConfig::default()
    }
}

#[test]
fn double_runs_are_byte_identical() {
    for mode in [SoakMode::Events, SoakMode::Driver] {
        let cfg = SoakConfig {
            sessions: if mode == SoakMode::Events { 20_000 } else { 5_000 },
            ..base()
        };
        let a = run_soak(&cfg, mode).unwrap().to_json().to_string();
        let b = run_soak(&cfg, mode).unwrap().to_json().to_string();
        assert_eq!(a, b, "{mode:?} soak is not a pure function of its config");
        assert!(a.contains("\"bytes_per_session\""));
    }
}

#[test]
fn seed_actually_moves_the_outcome() {
    let a = run_soak(&base(), SoakMode::Events).unwrap();
    let b = run_soak(&SoakConfig { seed: 1, ..base() }, SoakMode::Events).unwrap();
    assert_eq!(a.completed, b.completed);
    assert_ne!(
        a.total_tokens, b.total_tokens,
        "reseeding must reshuffle the demand profile"
    );
}

#[test]
fn cores_agree_on_invariants_across_random_configs() {
    for case in 0..8u64 {
        let mut rng = Rng::new(case ^ 0x50A7);
        let cfg = SoakConfig {
            sessions: rng.range(500, 4000),
            rate_per_s: 20.0 + rng.f64() * 100.0,
            slots: rng.range(4, 64) as usize,
            seed: case,
            ..SoakConfig::default()
        };
        let ev = run_soak(&cfg, SoakMode::Events).unwrap();
        let dr = run_soak(&cfg, SoakMode::Driver).unwrap();
        assert_eq!(ev.completed, cfg.sessions, "case {case}: events lost a session");
        assert_eq!(dr.completed, cfg.sessions, "case {case}: driver lost a session");
        assert_eq!(ev.total_tokens, dr.total_tokens, "case {case}");
        assert_eq!(ev.stalled, dr.stalled, "case {case}");
        assert!(ev.peak_resident <= cfg.slots, "case {case}");
        assert!(dr.peak_resident <= cfg.slots, "case {case}");
        // expected token total straight from the demand function
        let want: u64 = (0..cfg.sessions)
            .map(|s| session_demand(cfg.seed, s).ticks as u64)
            .sum();
        assert_eq!(ev.total_tokens, want, "case {case}: tokens drifted from demand");
    }
}

#[test]
fn event_core_footprint_is_flat_in_session_count() {
    // saturate the reservoirs in both runs so the only degree of freedom
    // left is residency-bounded state
    let cap = 2048usize;
    let small = run_soak(
        &SoakConfig { sessions: 10_000, summary_cap: cap, ..base() },
        SoakMode::Events,
    )
    .unwrap();
    let big = run_soak(
        &SoakConfig { sessions: 100_000, summary_cap: cap, ..base() },
        SoakMode::Events,
    )
    .unwrap();
    assert!(
        big.peak_bytes < small.peak_bytes * 2,
        "10x sessions moved the accounted footprint {} -> {} bytes",
        small.peak_bytes,
        big.peak_bytes
    );
    assert!(big.bytes_per_session() > 0);
}

#[test]
fn memory_ceiling_fails_a_breaching_run() {
    let err = run_soak(
        &SoakConfig { mem_budget_bytes: Some(1024), ..base() },
        SoakMode::Events,
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("memory budget exceeded"),
        "unexpected error: {err}"
    );
    // a sane ceiling passes: the 100k CI smoke runs under 64 MiB
    run_soak(
        &SoakConfig { mem_budget_bytes: Some(64 << 20), ..base() },
        SoakMode::Events,
    )
    .unwrap();
}

#[test]
fn report_json_shape_is_stable() {
    let j = run_soak(&base(), SoakMode::Events).unwrap().to_json();
    for key in [
        "accuracy",
        "arrivals",
        "bytes_per_session",
        "completed",
        "correct",
        "elapsed_virtual_s",
        "goodput_per_s",
        "latency_ms",
        "mode",
        "occupancy_mean",
        "occupancy_peak",
        "peak_bytes",
        "peak_waiting",
        "rejected",
        "shed",
        "slo_attainment",
        "stalled",
        "total_tokens",
        "wait_ms",
    ] {
        assert!(!matches!(j.get(key), Json::Null), "report lost key {key}");
    }
}
