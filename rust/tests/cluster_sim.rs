//! Deterministic cluster serving suite (DESIGN.md §3.7): N engine
//! replicas behind the EAT-aware router on the reference backend under a
//! VIRTUAL clock, pinning down
//!
//!  * same seed ⇒ byte-identical cluster metrics JSON (router counters,
//!    per-replica snapshots, latency percentiles) across runs;
//!  * `cluster(N=1)` ⇒ byte-identical replica metrics and bit-identical
//!    trajectories vs a plain single-batcher run (the router degenerates
//!    to a pass-through);
//!  * live session migration is a KV-page handoff, not a re-prefill:
//!    after a run with migrations the runtime prefill counter equals the
//!    request count exactly, and every migrated trajectory matches the
//!    unmigrated same-seed run token for token — on the paged *and* the
//!    monolithic store.
//!
//! Per-request RNGs are seeded from the globally unique submission seq
//! the router assigns, so a trajectory is invariant to placement and
//! migration — that invariance is what makes every comparison here exact.

mod common;

use common::{eat_factory, key};
use eat_serve::config::{SchedMode, ServeConfig};
use eat_serve::coordinator::{
    poisson_arrivals, run_open_loop, Batcher, Cluster, ClusterConfig, MetricsReport, MonitorModel,
    RequestResult, RoutePolicy, DEFAULT_TICK_DT,
};
use eat_serve::datasets::{chainsum::Kind, Dataset, Question};
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::clock::Clock;

fn mk_cluster<'a>(rt: &'a Runtime, cfg: &ServeConfig, ccfg: ClusterConfig) -> Cluster<'a> {
    let factories = (0..ccfg.replicas).map(|_| eat_factory(cfg)).collect();
    Cluster::with_clock(rt, cfg.clone(), MonitorModel::SelfModel, ccfg, factories, Clock::virt())
}

/// One full open-loop cluster run under a fresh virtual clock; returns
/// the cluster metrics JSON, each replica's ServeMetrics JSON by id, and
/// the results sorted by question id.
fn run_cluster(
    replicas: usize,
    slots: usize,
    n: usize,
    rate: f64,
    seed: u64,
    migrate: bool,
) -> (String, Vec<String>, Vec<RequestResult>) {
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.seed = seed;
    cfg.sched.mode = SchedMode::EatAware;
    let ds = Dataset::synth_gpqa(&rt.vocab, n.max(4), seed);
    let ccfg = ClusterConfig {
        replicas,
        slots_per_replica: slots,
        route: RoutePolicy::EatAware,
        migrate,
    };
    let mut c = mk_cluster(&rt, &cfg, ccfg);
    let arrivals = poisson_arrivals(n, rate, seed);
    run_open_loop(&mut c, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    let m = c.metrics();
    assert_eq!(m.completed, n);
    assert!(!c.has_work());
    assert_eq!(c.pending(), 0);
    assert_eq!(c.active_count(), 0);
    assert_eq!(c.suspended_count(), 0);
    let per: Vec<String> = (0..replicas)
        .map(|i| c.replica(i).metrics.to_json().to_string())
        .collect();
    let json = m.to_json().to_string();
    (json, per, c.all_results())
}

#[test]
fn same_seed_cluster_runs_are_byte_identical() {
    // the cluster determinism guarantee: shared virtual clock, replicas
    // ticked in id order, routing ties broken to the lowest id — the
    // whole N-replica run is a pure function of the seed
    let (json_a, per_a, res_a) = run_cluster(3, 2, 18, 30.0, 7, true);
    let (json_b, per_b, res_b) = run_cluster(3, 2, 18, 30.0, 7, true);
    assert_eq!(json_a, json_b, "same-seed cluster JSON diverged");
    assert_eq!(per_a, per_b, "same-seed replica snapshots diverged");
    assert_eq!(res_a.len(), res_b.len());
    for (a, b) in res_a.iter().zip(&res_b) {
        assert_eq!(key(a), key(b));
        assert_eq!(a.wall_ms, b.wall_ms, "virtual latencies must be exact");
    }
    assert!(json_a.contains("\"per_replica\""));
    assert!(json_a.contains("\"goodput_rps\""));
    // a different seed produces a different run
    let (json_c, _, _) = run_cluster(3, 2, 18, 30.0, 8, true);
    assert_ne!(json_a, json_c, "seed is not reaching the cluster");
}

#[test]
fn cluster_of_one_is_byte_identical_to_a_single_batcher() {
    // the API-redesign acceptance bar: with one replica the router is a
    // pass-through — same submission seqs, same tick cadence, and the
    // migrate flag is inert — so the replica's ServeMetrics JSON matches
    // a plain Batcher run byte for byte
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.seed = 7;
    cfg.sched.mode = SchedMode::EatAware;
    let ds = Dataset::synth_gpqa(&rt.vocab, 16, 7);
    let mut b = Batcher::with_clock(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        2,
        eat_factory(&cfg),
        Clock::virt(),
    );
    let arrivals = poisson_arrivals(16, 30.0, 7);
    run_open_loop(&mut b, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    let single_json = b.metrics.to_json().to_string();
    let mut single_res = b.results;
    single_res.sort_by_key(|r| r.question_id);

    let (cluster_json, per, cluster_res) = run_cluster(1, 2, 16, 30.0, 7, true);
    assert_eq!(per[0], single_json, "cluster(N=1) replica metrics must match single");
    assert_eq!(cluster_res.len(), single_res.len());
    for (c, s) in cluster_res.iter().zip(&single_res) {
        assert_eq!(key(c), key(s), "cluster(N=1) trajectory diverged from single");
        assert_eq!(c.wall_ms, s.wall_ms);
    }
    assert!(cluster_json.contains("\"replicas\""));
}

/// Corrupted questions at even indices, easy ones filling the rest:
/// round-robin placement lands every corrupted (stalling) question on
/// replica 0, so replica 1 drains its easy share and goes idle while
/// replica 0 is still saturated — the rebalance precondition.
fn skewed_workload(n_corrupted: usize, n_easy: usize, seed: u64) -> Vec<Question> {
    let rt = Runtime::reference();
    let pool = Dataset::synth_gpqa(&rt.vocab, 120, seed);
    let corrupted: Vec<Question> = pool
        .questions
        .iter()
        .filter(|q| q.kind == Kind::Corrupted)
        .take(n_corrupted)
        .cloned()
        .collect();
    let easy: Vec<Question> = pool
        .questions
        .iter()
        .filter(|q| q.kind == Kind::ChainSum && q.n_ops() <= 4)
        .take(n_easy)
        .cloned()
        .collect();
    assert_eq!(corrupted.len(), n_corrupted, "pool too small");
    assert_eq!(easy.len(), n_easy, "pool too small");
    let mut qs = Vec::new();
    let (mut ci, mut ei) = (corrupted.into_iter(), easy.into_iter());
    loop {
        match (ci.next(), ei.next()) {
            (None, None) => break,
            (c, e) => {
                qs.extend(c);
                qs.extend(e);
            }
        }
    }
    qs
}

/// The contended scheduler configuration of scheduler_sim.rs: stalled
/// (corrupted) sessions get preempted aggressively, but the starvation
/// guard never lets stall retirement fire — so WHAT every session
/// computes is identical to an uninterrupted FIFO run, only WHEN it runs
/// differs.
fn preemptive_cfg(seed: u64) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.seed = seed;
    cfg.delta = 1e-7;
    cfg.sched.mode = SchedMode::EatAware;
    cfg.sched.stall_stability = 0.2;
    cfg.sched.preempt_after_ticks = 8;
    cfg.sched.max_preemptions = 100;
    cfg
}

/// Uninterrupted reference: the same workload through one FIFO batcher
/// with plenty of lanes, results sorted by question id.
fn unmigrated_reference(questions: &[Question], seed: u64) -> Vec<RequestResult> {
    let rt = Runtime::reference();
    let mut cfg = preemptive_cfg(seed);
    cfg.sched.mode = SchedMode::Fifo;
    let mut b = Batcher::with_clock(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        4,
        eat_factory(&cfg),
        Clock::virt(),
    );
    for q in questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.preemptions, 0, "FIFO must never preempt");
    let mut results = b.results;
    results.sort_by_key(|r| r.question_id);
    results
}

#[test]
fn cluster_rebalance_migrates_without_reprefill_or_trajectory_change() {
    // end-to-end through Cluster::tick: skewed load triggers the
    // rebalancer, sessions/waiters hop replicas, and on the paged store
    // the shared-pool page handoff means the backend prefills exactly
    // once per request — migration never re-prefills
    let questions = skewed_workload(3, 5, 5);
    let rt = Runtime::reference();
    let cfg = preemptive_cfg(5);
    let ccfg = ClusterConfig {
        replicas: 2,
        slots_per_replica: 2,
        route: RoutePolicy::RoundRobin,
        migrate: true,
    };
    let mut c = mk_cluster(&rt, &cfg, ccfg);
    for q in &questions {
        c.submit(q.clone());
    }
    c.run_to_completion().unwrap();
    let m = c.metrics();
    assert_eq!(m.completed, questions.len());
    assert!(m.migrations + m.reroutes > 0, "skewed load never rebalanced");
    assert_eq!(m.kv_spills, 0, "default budget must never spill");
    assert_eq!(
        rt.main.counters().prefills.get(),
        questions.len() as u64,
        "migration or resume re-prefilled on the paged store"
    );
    let reference = unmigrated_reference(&questions, 5);
    let migrated = c.all_results();
    assert_eq!(migrated.len(), reference.len());
    for (mres, f) in migrated.iter().zip(&reference) {
        assert_eq!(key(mres), key(f), "migration changed a trajectory");
    }
}

/// Manual two-batcher handoff on one shared runtime: tick the loaded
/// batcher until `extract_migration` yields a mid-flight *session*
/// (committed tokens > 0), injecting every extracted waiter into the
/// idle batcher, then drain both on the shared clock. Returns the merged
/// sorted results, the migrated session's committed tokens, and the
/// (spills, resumes) totals.
fn manual_migration_run(
    rt: &Runtime,
    questions: &[Question],
    seed: u64,
) -> (Vec<RequestResult>, usize, u64, u64) {
    let cfg = preemptive_cfg(seed);
    let clock = Clock::virt();
    let mut b0 = Batcher::with_clock(
        rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        2,
        eat_factory(&cfg),
        clock.clone(),
    );
    let mut b1 = Batcher::with_clock(
        rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        2,
        eat_factory(&cfg),
        clock.clone(),
    );
    for (i, q) in questions.iter().enumerate() {
        b0.submit_seq(q.clone(), i as u64);
    }
    let mut session_tokens = 0usize;
    let mut guard = 0;
    while session_tokens == 0 {
        b0.tick().unwrap();
        clock.advance(DEFAULT_TICK_DT);
        if b0.suspended_count() > 0 {
            if let Some(m) = b0.extract_migration().unwrap() {
                if m.is_session() {
                    session_tokens = m.tokens();
                }
                b1.inject_migration(&mut b0, m);
            }
        }
        guard += 1;
        assert!(guard < 5_000, "no suspended session ever became migratable");
    }
    while b0.has_work() || b1.has_work() {
        b0.tick().unwrap();
        b1.tick().unwrap();
        clock.advance(DEFAULT_TICK_DT);
    }
    assert!(b0.metrics.migrations_out >= 1);
    assert!(b1.metrics.migrations_in >= 1);
    assert!(b1.metrics.migrated_tokens > 0, "session handoff carried no tokens");
    let spills = b0.metrics.kv_spills + b1.metrics.kv_spills;
    let resumes = b0.metrics.resumes + b1.metrics.resumes;
    let mut results = b0.results;
    results.append(&mut b1.results);
    results.sort_by_key(|r| r.question_id);
    assert_eq!(results.len(), questions.len());
    (results, session_tokens, spills, resumes)
}

#[test]
fn migrated_session_repins_pages_on_paged_and_reprefills_on_mono() {
    // the page-handoff acceptance bar, on both stores: the same manual
    // migration scenario repins from the shared pool on the paged store
    // (prefills == requests, zero spills) and falls back to re-prefill on
    // the monolithic store (one extra prefill per resume) — with
    // bit-identical trajectories everywhere
    let questions = skewed_workload(3, 5, 5);
    let reference = unmigrated_reference(&questions, 5);

    let paged_rt = Runtime::reference();
    let (paged_res, tokens, spills, _) = manual_migration_run(&paged_rt, &questions, 5);
    assert!(tokens > 0, "migrated session carried no committed history");
    assert_eq!(spills, 0, "default budget must never spill");
    assert_eq!(
        paged_rt.main.counters().prefills.get(),
        questions.len() as u64,
        "paged migration must repin, not re-prefill"
    );
    for (p, f) in paged_res.iter().zip(&reference) {
        assert_eq!(key(p), key(f), "paged migration changed a trajectory");
    }

    let mono_rt = Runtime::reference_monolithic();
    let (mono_res, _, _, mono_resumes) = manual_migration_run(&mono_rt, &questions, 5);
    assert!(
        mono_rt.main.counters().prefills.get() > questions.len() as u64,
        "monolithic resume must re-prefill"
    );
    assert_eq!(
        mono_rt.main.counters().prefills.get(),
        questions.len() as u64 + mono_resumes,
        "monolithic store must re-prefill exactly once per resume"
    );
    for (m, f) in mono_res.iter().zip(&reference) {
        assert_eq!(key(m), key(f), "monolithic migration changed a trajectory");
    }
    // and the two stores agree with each other, token for token
    for (p, m) in paged_res.iter().zip(&mono_res) {
        assert_eq!(key(p), key(m), "paged vs monolithic migration diverged");
    }
}
