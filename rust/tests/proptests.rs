//! Property-based tests over coordinator invariants (no artifacts needed).
//!
//! The offline registry has no `proptest`, so properties are checked over
//! many seeded random inputs from the repo's own RNG — same idea, no
//! shrinking. Each property runs a few hundred cases.

mod common;

use common::{eat_factory, key};
use eat_serve::config::{SchedMode, ServeConfig};
use eat_serve::coordinator::kv::SlotId;
use eat_serve::coordinator::{
    collect_arrivals, eat_policy_factory, pick_shed_victims, poisson_arrivals, run_open_loop,
    Batcher, KvPageManager, MonitorModel, PageAllocator, PageId, PoissonStream,
};
use eat_serve::datasets::Dataset;
use eat_serve::exit::{
    AllOf, AnswerConsistencyPolicy, AnyOf, ConfidencePolicy, CumulativeEntropyPolicy, EatPolicy,
    ExitDecision, ExitPolicy, ExitReason, LineObs, PathDeviationPolicy, SequenceEntropyPolicy,
    StallAwareEatPolicy, TokenBudgetPolicy, UniqueAnswersPolicy, WeightedEnsemble,
};
use eat_serve::eval::{replay, replay_scanned, Signal};
use eat_serve::monitor::{EmaVar, LinePoint, Trace};
use eat_serve::runtime::Runtime;
use eat_serve::util::cli::ArrivalSpec;
use eat_serve::util::clock::Clock;
use eat_serve::util::json;
use eat_serve::util::rng::Rng;
use eat_serve::util::stats;
use eat_serve::vocab::Vocab;

const CASES: u64 = 300;

fn random_trace(rng: &mut Rng) -> Trace {
    let n_lines = rng.range(1, 40) as usize;
    let stab = rng.range(1, 40) as usize;
    let points = (1..=n_lines)
        .map(|i| {
            let stable = i >= stab;
            LinePoint {
                line: i,
                tokens: i * 3,
                eat: if stable {
                    0.02 + 0.01 * rng.f64()
                } else {
                    1.0 + 2.0 * rng.f64()
                },
                eat_proxy: if rng.chance(0.8) {
                    Some(rng.f64() * 3.0)
                } else {
                    None
                },
                eat_plain: Some(rng.f64() * 0.1),
                eat_newline: Some(rng.f64()),
                vhat: f64::INFINITY,
                p_correct: if stable { 0.9 } else { 0.1 * rng.f64() },
                pass1_avgk: if stable { 1.0 } else { rng.f64() * 0.2 },
                unique_answers: rng.range(1, 32) as usize,
                confidence: Some(rng.f64()),
            }
        })
        .collect();
    Trace {
        question_id: rng.below(1000) as usize,
        n_ops: rng.range(2, 12) as usize,
        answer: if rng.chance(0.9) {
            Some(rng.below(32) as u32)
        } else {
            None
        },
        prompt_tokens: rng.range(5, 16) as usize,
        self_terminated: rng.chance(0.5),
        reasoning_tokens: (0..n_lines * 3).map(|_| rng.below(48) as u32).collect(),
        points,
    }
}

/// One instance of every exit-policy family in the zoo, including the
/// combinators — the pool the reset/backstop properties quantify over.
fn zoo_members(max_tokens: usize) -> Vec<Box<dyn ExitPolicy>> {
    vec![
        Box::new(EatPolicy::new(0.2, 1e-3, max_tokens)),
        Box::new(StallAwareEatPolicy::new(0.2, 1e-3, max_tokens)),
        Box::new(TokenBudgetPolicy::new(max_tokens)),
        Box::new(UniqueAnswersPolicy::new(16, 1, max_tokens)),
        Box::new(ConfidencePolicy::new(0.2, 1e-3, max_tokens)),
        Box::new(PathDeviationPolicy::new(0.2, 1e-3, max_tokens)),
        Box::new(SequenceEntropyPolicy::new(0.05, max_tokens)),
        // effectively-infinite nat budget: only the level rule and the
        // token backstop can fire, keeping the property about those
        Box::new(CumulativeEntropyPolicy::new(0.2, 0.05, 1e9, max_tokens)),
        Box::new(AnswerConsistencyPolicy::with_stride(8, 2, max_tokens, 3)),
        Box::new(AllOf::new(vec![
            Box::new(EatPolicy::new(0.2, 1e-3, max_tokens)),
            Box::new(ConfidencePolicy::new(0.2, 1e-3, max_tokens)),
        ])),
        Box::new(AnyOf::new(vec![
            Box::new(EatPolicy::new(0.2, 1e-3, max_tokens)),
            Box::new(UniqueAnswersPolicy::new(16, 1, max_tokens)),
        ])),
        Box::new(WeightedEnsemble::new(
            vec![
                (2.0, Box::new(EatPolicy::new(0.2, 1e-3, max_tokens)) as Box<dyn ExitPolicy>),
                (1.0, Box::new(StallAwareEatPolicy::new(0.2, 1e-3, max_tokens))),
                (1.0, Box::new(ConfidencePolicy::new(0.2, 1e-3, max_tokens))),
            ],
            0.5,
        )),
    ]
}

/// Every zoo member is reusable: a policy that already replayed one
/// (unrelated) trace must replay a second trace bit-identically to a
/// freshly constructed twin — the reset() contract the sweep harness
/// leans on when it reuses one policy across a whole grid.
#[test]
fn prop_zoo_reused_policy_replays_bit_identical_to_fresh() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x200E);
        let dirty = random_trace(&mut rng);
        let target = random_trace(&mut rng);
        let signal = if rng.chance(0.5) {
            Signal::MainPrefixed
        } else {
            Signal::Proxy
        };
        let charge = rng.chance(0.5);
        let fresh = zoo_members(10_000);
        let reused = zoo_members(10_000);
        for (mut f, mut r) in fresh.into_iter().zip(reused) {
            let name = f.name();
            // dirty the reused policy with a full unrelated replay;
            // replay() itself calls reset() up front, which is exactly
            // the contract under test
            let _ = replay(&dirty, r.as_mut(), signal, charge);
            let a = replay(&target, f.as_mut(), signal, charge);
            let b = replay(&target, r.as_mut(), signal, charge);
            assert_eq!(a.exit_line, b.exit_line, "seed {seed} policy {name}");
            assert_eq!(a.exit_reason, b.exit_reason, "seed {seed} policy {name}");
            assert_eq!(a.reasoning_tokens, b.reasoning_tokens, "seed {seed} policy {name}");
            assert_eq!(a.overhead_tokens, b.overhead_tokens, "seed {seed} policy {name}");
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "seed {seed} policy {name}");
        }
    }
}

/// Two universal zoo invariants: (a) no member exits Stable on the very
/// first evaluated observation (one sample is never evidence of
/// stability), and (b) every member honours the token-budget backstop —
/// replay never runs past the first line boundary at or beyond budget.
#[test]
fn prop_zoo_budget_backstop_and_no_zero_evidence_exit() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xBAC5);
        let budget = rng.range(1, 120) as usize;
        for mut p in zoo_members(budget) {
            let name = p.name();
            p.reset();
            let d = p.observe(&LineObs {
                tokens: 0,
                eat: Some(1.7),
                unique_answers: Some(5),
                confidence: Some(0.42),
                self_terminated: false,
            });
            assert_eq!(
                d,
                ExitDecision::Continue,
                "seed {seed} policy {name}: exited on first observation"
            );
        }
        let trace = random_trace(&mut rng);
        let backstop_line = trace
            .points
            .iter()
            .find(|pt| pt.tokens >= budget)
            .map(|pt| pt.line);
        for mut p in zoo_members(budget) {
            let name = p.name();
            let out = replay(&trace, p.as_mut(), Signal::MainPrefixed, false);
            if let (Some(exit), Some(stop)) = (out.exit_line, backstop_line) {
                assert!(
                    exit <= stop,
                    "seed {seed} policy {name}: exit line {exit} past budget line {stop}"
                );
            }
        }
    }
}

/// EMA variance is always finite and non-negative after the first update.
#[test]
fn prop_ema_nonnegative_finite() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let mut ema = EmaVar::new(0.01 + 0.98 * rng.f64());
        for _ in 0..rng.range(1, 200) {
            let v = ema.update(rng.normal() * 10.0);
            assert!(v.is_finite() && v >= 0.0, "seed {seed}: v={v}");
        }
    }
}

/// The de-biased variance never undershoots the raw variance.
#[test]
fn prop_ema_debias_monotone() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xD1A5);
        let mut ema = EmaVar::new(0.05 + 0.9 * rng.f64());
        for _ in 0..rng.range(1, 100) {
            ema.update(rng.f64() * 5.0);
            assert!(ema.debiased_var() >= ema.var() - 1e-15);
        }
    }
}

/// Replay never reports more reasoning tokens than the trace contains and
/// the exit line (when any) indexes a real point.
#[test]
fn prop_replay_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x11E9);
        let trace = random_trace(&mut rng);
        let mut policy: Box<dyn ExitPolicy> = match rng.below(4) {
            0 => Box::new(EatPolicy::new(0.2, 2f64.powi(-(rng.below(20) as i32)), 10_000)),
            1 => Box::new(TokenBudgetPolicy::new(rng.range(1, 150) as usize)),
            2 => Box::new(UniqueAnswersPolicy::new(
                rng.range(1, 64) as usize,
                rng.range(1, 3) as usize,
                10_000,
            )),
            _ => Box::new(ConfidencePolicy::new(0.2, 2f64.powi(-(rng.below(20) as i32)), 10_000)),
        };
        let out = replay(&trace, policy.as_mut(), Signal::MainPrefixed, rng.chance(0.5));
        assert!(out.reasoning_tokens <= trace.reasoning_tokens.len().max(trace.points.last().map(|p| p.tokens).unwrap_or(0)));
        if let Some(line) = out.exit_line {
            assert!(trace.points.iter().any(|p| p.line == line));
        }
        assert!((0.0..=1.0).contains(&out.accuracy));
        assert!((0.0..=1.0).contains(&out.accuracy_exact));
    }
}

/// Monotonicity of the threshold dial: a *larger* delta (looser stability
/// requirement) never exits later than a smaller one on the same trace.
#[test]
fn prop_eat_threshold_monotone() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7031);
        let trace = random_trace(&mut rng);
        let loose = 2f64.powi(-(rng.below(8) as i32));
        let strict = loose / 2f64.powi(rng.range(1, 10) as i32);
        let exit_at = |delta: f64| {
            let mut p = EatPolicy::new(0.2, delta, usize::MAX);
            replay(&trace, &mut p, Signal::MainPrefixed, false)
                .exit_line
                .unwrap_or(usize::MAX)
        };
        assert!(
            exit_at(loose) <= exit_at(strict),
            "seed {seed}: delta {loose} exited after {strict}"
        );
    }
}

/// Token budget policy exits within one line of its budget.
#[test]
fn prop_token_budget_respected() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x70CB);
        let trace = random_trace(&mut rng);
        let t = rng.range(1, 130) as usize;
        let mut p = TokenBudgetPolicy::new(t);
        let out = replay(&trace, &mut p, Signal::MainPrefixed, false);
        if out.exit_line.is_some() && out.exit_reason == ExitReason::TokenBudget {
            // exit happens at the first line boundary with tokens >= t
            assert!(out.reasoning_tokens >= t);
            assert!(out.reasoning_tokens < t + 3 + 1, "one line past budget max");
        }
    }
}

/// Trace JSON round-trip is lossless for all random traces.
#[test]
fn prop_trace_json_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x750D);
        let t = random_trace(&mut rng);
        let back = Trace::from_json(&json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(t.question_id, back.question_id);
        assert_eq!(t.answer, back.answer);
        assert_eq!(t.reasoning_tokens, back.reasoning_tokens);
        assert_eq!(t.points.len(), back.points.len());
        for (a, b) in t.points.iter().zip(&back.points) {
            assert!((a.eat - b.eat).abs() < 1e-9);
            assert_eq!(a.eat_proxy.is_some(), b.eat_proxy.is_some());
            assert_eq!(a.unique_answers, b.unique_answers);
        }
    }
}

/// Policies are reusable after reset(): same trace, same outcome.
#[test]
fn prop_policy_reset_deterministic() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x4E5E);
        let trace = random_trace(&mut rng);
        let mut p = EatPolicy::new(0.2, 1e-3, 10_000);
        let a = replay(&trace, &mut p, Signal::MainPrefixed, false);
        let b = replay(&trace, &mut p, Signal::MainPrefixed, false);
        assert_eq!(a.exit_line, b.exit_line);
        assert_eq!(a.reasoning_tokens, b.reasoning_tokens);
    }
}

/// Observing with a fresh policy after many noisy lines never yields an
/// immediate Stable exit on the very first observation.
#[test]
fn prop_no_first_line_stable_exit() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xF125);
        let mut p = EatPolicy::new(0.01 + rng.f64() * 0.9, 1e-6, usize::MAX);
        let d = p.observe(&LineObs {
            tokens: 3,
            eat: Some(rng.f64() * 4.0 + 0.5),
            ..Default::default()
        });
        // V'_1 = (x - a x)^2 * a / a = nonzero for x > 0
        assert_eq!(d, ExitDecision::Continue, "seed {seed}");
    }
}

/// AUC is invariant to point ordering and bounded by max accuracy.
#[test]
fn prop_auc_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xA0C);
        let n = rng.range(2, 30) as usize;
        let mut pts: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.f64() * 1000.0, rng.f64()))
            .collect();
        let auc = stats::auc_normalized(&pts);
        let max_acc = pts.iter().map(|p| p.1).fold(0.0, f64::max);
        assert!(auc <= max_acc + 1e-9, "seed {seed}");
        assert!(auc >= 0.0);
        rng.shuffle(&mut pts);
        let auc2 = stats::auc_normalized(&pts);
        assert!((auc - auc2).abs() < 1e-9, "ordering changed AUC");
    }
}

/// Under random acquire/release sequences the KV page manager never
/// leaks a lane, never double-frees, and never over-admits — the
/// invariant the scheduler's preempt/resume churn leans on. With the
/// default page budget, page admission must degenerate to exact lane
/// admission.
#[test]
fn prop_kv_lanes_never_leak_or_double_free() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5107);
        let cap = rng.range(1, 8) as usize;
        let reserve = rng.range(1, 20) as usize;
        let mut m = KvPageManager::new(cap, 16, reserve, None);
        let mut held: Vec<SlotId> = Vec::new();
        for _ in 0..200 {
            assert_eq!(held.len() + m.available(), cap, "lane leak (seed {seed})");
            assert_eq!(m.in_use(), held.len());
            assert_eq!(m.pinned_pages(), held.len() * reserve, "page pin drift");
            if rng.chance(0.5) {
                match m.acquire() {
                    Some(s) => {
                        assert!(!held.contains(&s), "lane handed out twice");
                        held.push(s);
                    }
                    None => assert_eq!(held.len(), cap, "refused admission below capacity"),
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len() as u64) as usize;
                let s = held.swap_remove(i);
                m.release(s).unwrap();
                assert!(m.release(s).is_err(), "double free undetected");
            }
        }
        assert!(m.peak() <= cap);
    }
}

/// Page allocator refcount discipline under random alloc/retain/release
/// churn: every reference is dropped exactly once, pages free exactly
/// when their last reference goes, double frees and retains-after-free
/// error out, and the end state leaks nothing.
#[test]
fn prop_page_allocator_refcounts_zero_exactly_once() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xFA6E5);
        let fixed = rng.chance(0.5);
        let cap = rng.range(1, 12) as usize;
        let mut a = if fixed {
            PageAllocator::new_fixed(cap)
        } else {
            PageAllocator::new_growable()
        };
        // one entry per outstanding reference
        let mut refs: Vec<PageId> = Vec::new();
        for _ in 0..200 {
            let distinct = {
                let mut ids: Vec<u32> = refs.iter().map(|p| p.0).collect();
                ids.sort_unstable();
                ids.dedup();
                ids.len()
            };
            assert_eq!(a.in_use(), distinct, "live-page accounting drift (seed {seed})");
            match rng.below(3) {
                0 => match a.alloc() {
                    Ok(p) => {
                        assert_eq!(a.refcount(p), 1);
                        refs.push(p);
                    }
                    Err(_) => {
                        assert!(fixed && a.in_use() == cap, "alloc failed below capacity");
                    }
                },
                1 if !refs.is_empty() => {
                    let p = refs[rng.below(refs.len() as u64) as usize];
                    a.retain(p).unwrap();
                    refs.push(p);
                }
                _ if !refs.is_empty() => {
                    let i = rng.below(refs.len() as u64) as usize;
                    let p = refs.swap_remove(i);
                    let remaining = refs.iter().filter(|&&q| q == p).count();
                    let freed = a.release(p).unwrap();
                    assert_eq!(freed, remaining == 0, "freed at the wrong refcount");
                    if freed {
                        assert!(a.release(p).is_err(), "double free undetected");
                        assert!(a.retain(p).is_err(), "retain after free undetected");
                    }
                }
                _ => {}
            }
        }
        for p in refs.drain(..) {
            let _ = a.release(p).unwrap();
        }
        assert_eq!(a.in_use(), 0, "references leaked (seed {seed})");
        assert_eq!(
            a.counters.frees,
            a.counters.allocs,
            "every allocated page must free exactly once (seed {seed})"
        );
    }
}

/// Generic [`PageTable`] ownership discipline — ONE property routine
/// instantiated for both element types the backends use: `u32` (the
/// reference backend's token tables) and `f32` (the pjrt backend's K/V
/// tables). Random push/clone/drop/overwrite sequences against a shared
/// pool must (a) keep every table's gathered contents equal to an
/// independent dense mirror — CoW isolation: writing through one table
/// never leaks into another — and (b) return the pool to zero live
/// pages with allocs == frees once every table is dropped.
#[test]
fn prop_page_table_cow_discipline_covers_both_element_types() {
    use std::cell::RefCell;
    use std::rc::Rc;

    use eat_serve::coordinator::{PagePool, PageTable};

    fn gather<T: Clone + Default>(table: &PageTable<T>, len: usize, page: usize) -> Vec<T> {
        let pool = table.pool().borrow();
        let mut out = Vec::with_capacity(len);
        for (i, pg) in table.pages().iter().enumerate() {
            let take = page.min(len - i * page);
            out.extend_from_slice(&pool.page(*pg)[..take]);
        }
        out
    }

    fn check<T, F>(mk: F)
    where
        T: Clone + Default + PartialEq + std::fmt::Debug,
        F: Fn(u64) -> T,
    {
        const PAGE: usize = 4;
        for seed in 0..60u64 {
            let mut rng = Rng::new(seed ^ 0x7AB1E);
            let pool = Rc::new(RefCell::new(PagePool::<T>::new_growable(PAGE)));
            // each entry: (table, dense mirror of its logical contents)
            let mut tables: Vec<(PageTable<T>, Vec<T>)> =
                vec![(PageTable::new(pool.clone()), Vec::new())];
            for _ in 0..rng.range(20, 80) {
                match rng.below(4) {
                    // append one element (opens a page at boundaries,
                    // CoWs a shared tail otherwise)
                    0 => {
                        let i = rng.below(tables.len() as u64) as usize;
                        let (t, mirror) = &mut tables[i];
                        let off = mirror.len() % PAGE;
                        if off == 0 {
                            t.push_zeroed().unwrap();
                        }
                        let idx = t.page_count() - 1;
                        let v = mk(rng.next_u64());
                        t.write(idx, |p| p[off] = v.clone()).unwrap();
                        mirror.push(v);
                    }
                    // fork: retain-on-Clone
                    1 if tables.len() < 6 => {
                        let i = rng.below(tables.len() as u64) as usize;
                        let c = (tables[i].0.clone(), tables[i].1.clone());
                        tables.push(c);
                    }
                    // drop: release-on-Drop (keep at least one table)
                    2 if tables.len() > 1 => {
                        let i = rng.below(tables.len() as u64) as usize;
                        tables.swap_remove(i);
                    }
                    // overwrite a random committed element in place
                    _ => {
                        let i = rng.below(tables.len() as u64) as usize;
                        let (t, mirror) = &mut tables[i];
                        if mirror.is_empty() {
                            continue;
                        }
                        let at = rng.below(mirror.len() as u64) as usize;
                        let v = mk(rng.next_u64());
                        t.write(at / PAGE, |p| p[at % PAGE] = v.clone()).unwrap();
                        mirror[at] = v;
                    }
                }
                // CoW isolation: every table still reads exactly its own
                // mirror, no matter what the others did
                for (t, mirror) in &tables {
                    assert_eq!(
                        &gather(t, mirror.len(), PAGE),
                        mirror,
                        "table contents diverged from mirror (seed {seed})"
                    );
                }
            }
            drop(tables);
            assert_eq!(pool.borrow().pages_in_use(), 0, "pages leaked (seed {seed})");
            let c = pool.borrow().counters();
            assert_eq!(c.allocs, c.frees, "alloc/free imbalance (seed {seed})");
        }
    }

    check::<u32, _>(|x| x as u32);
    check::<f32, _>(|x| (x % 1000) as f32);
}

/// Paged-cache churn oracle: random prefill/fork/decode/probe/drop
/// sequences on a paged reference backend must (a) produce logits
/// bit-identical to the monolithic pure function of each cache's token
/// history, and (b) leave zero live pages once every cache is dropped.
#[test]
fn prop_paged_cache_churn_matches_mono_and_never_leaks() {
    use eat_serve::runtime::{Backend, BackendCache, RefBackend};
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xC0117);
        let vocab = Vocab::default_layout();
        let page_size = rng.range(1, 9) as usize;
        let paged = RefBackend::with_pages("ref-main", vocab, 128, None, Some(page_size));
        let mono = RefBackend::monolithic("ref-main", vocab, 128, None);
        // (cache, shadow token history)
        let mut live: Vec<(BackendCache, Vec<u32>)> = Vec::new();
        for _ in 0..60 {
            match rng.below(5) {
                0 => {
                    let mut p = vec![vocab.bos, vocab.q];
                    for _ in 0..rng.range(1, 5) {
                        p.push(vocab.num(rng.below(vocab.modulus as u64) as u32));
                    }
                    p.push(vocab.sep);
                    p.push(vocab.think);
                    let (logits, cache) = paged.prefill(&p).unwrap();
                    assert_eq!(logits, mono.prefill(&p).unwrap().0, "seed {seed}");
                    live.push((cache, p));
                }
                1 if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    let fork = paged.fork(&live[i].0).unwrap();
                    let hist = live[i].1.clone();
                    live.push((fork, hist));
                }
                2 if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    let (cache, hist) = &mut live[i];
                    if hist.len() + 1 < 100 {
                        let tok = vocab.num(rng.below(vocab.modulus as u64) as u32);
                        let logits = paged.decode(cache, tok).unwrap();
                        hist.push(tok);
                        assert_eq!(
                            logits,
                            mono.prefill(hist).unwrap().0,
                            "paged decode diverged from the pure function (seed {seed})"
                        );
                    }
                }
                3 if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    let (cache, hist) = &live[i];
                    let suffix = vocab.suffix_prefixed();
                    let (_eat, logits) = paged.probe(cache, &suffix).unwrap();
                    let mut h = hist.clone();
                    h.extend_from_slice(&suffix);
                    assert_eq!(logits, mono.prefill(&h).unwrap().0, "seed {seed}");
                    assert_eq!(cache.pos(), hist.len(), "probe mutated the cache");
                }
                _ if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    live.swap_remove(i);
                }
                _ => {}
            }
        }
        drop(live);
        assert_eq!(paged.pool_pages_in_use(), Some(0), "page leak after drop (seed {seed})");
    }
}

/// Random admit/preempt/resume/retire sequences arise from running the
/// EAT-aware scheduler itself over random configurations under a virtual
/// clock: every submitted request must complete (the aging bound +
/// starvation guard rule out starvation), no KV slot may leak, and cache
/// installs must balance retires.
#[test]
fn prop_scheduler_never_starves_or_leaks() {
    for seed in 0..40 {
        let mut rng = Rng::new(seed ^ 0x5CED);
        let rt = Runtime::reference();
        let mut cfg = ServeConfig::default();
        cfg.seed = seed;
        cfg.sched.mode = SchedMode::EatAware;
        cfg.sched.preempt_after_ticks = rng.range(2, 40);
        cfg.sched.max_preemptions = rng.range(0, 4) as u32;
        cfg.sched.stall_stability = 0.1 + 0.3 * rng.f64();
        cfg.sched.deadline_s = 0.5 + rng.f64();
        cfg.sched.resume_priority_after_s = 0.05 + rng.f64();
        let slots = rng.range(1, 4) as usize;
        let n = rng.range(3, 14) as usize;
        let ds = Dataset::synth_gpqa(&rt.vocab, 8, seed);
        let mut b = Batcher::with_clock(
            &rt,
            cfg.clone(),
            MonitorModel::SelfModel,
            slots,
            eat_policy_factory(&cfg),
            Clock::virt(),
        );
        let arrivals = poisson_arrivals(n, 20.0 + 50.0 * rng.f64(), seed);
        run_open_loop(&mut b, &ds.questions, &arrivals, 0.01).unwrap();
        assert_eq!(b.metrics.completed, n, "request starved (seed {seed})");
        assert_eq!(b.pending(), 0);
        assert_eq!(b.active_count(), 0);
        assert_eq!(b.suspended_count(), 0);
        assert_eq!(b.kv_utilization(), 0.0, "KV slot leaked (seed {seed})");
        let sc = b.store_counters();
        assert_eq!(sc.installs, sc.retires, "cache slot leaked (seed {seed})");
        assert_eq!(b.metrics.resumes, b.metrics.preemptions);
        assert_eq!(
            sc.installs,
            n as u64 + b.metrics.resumes,
            "install accounting broken (seed {seed})"
        );
    }
}

/// Random submit/tick/migrate interleavings across a pool of batchers
/// sharing one runtime (the cluster substrate): every request completes
/// exactly once, migration bookkeeping balances, and once everything
/// drains the shared page pool holds zero pages with allocs == frees —
/// no leak, no double-free, regardless of where sessions wandered.
#[test]
fn prop_cluster_migration_interleavings_never_leak_pages() {
    use eat_serve::runtime::Backend;
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0xC7057E);
        let rt = Runtime::reference();
        let mut cfg = ServeConfig::default();
        cfg.seed = seed;
        cfg.sched.mode = SchedMode::EatAware;
        cfg.sched.preempt_after_ticks = rng.range(2, 24);
        cfg.sched.max_preemptions = rng.range(0, 4) as u32;
        cfg.sched.stall_stability = 0.1 + 0.3 * rng.f64();
        let n_replicas = rng.range(2, 4) as usize;
        let slots = rng.range(1, 3) as usize;
        let n = rng.range(4, 10) as usize;
        let ds = Dataset::synth_gpqa(&rt.vocab, n, seed);
        let clock = Clock::virt();
        let mut bs: Vec<Batcher> = (0..n_replicas)
            .map(|_| {
                Batcher::with_clock(
                    &rt,
                    cfg.clone(),
                    MonitorModel::SelfModel,
                    slots,
                    eat_policy_factory(&cfg),
                    clock.clone(),
                )
            })
            .collect();
        let mut seq = 0u64;
        for _ in 0..300 {
            match rng.below(6) {
                0 if (seq as usize) < n => {
                    let i = rng.below(n_replicas as u64) as usize;
                    bs[i].submit_seq(ds.questions[seq as usize].clone(), seq);
                    seq += 1;
                }
                1 => {
                    let si = rng.below(n_replicas as u64) as usize;
                    let di = rng.below(n_replicas as u64) as usize;
                    if si != di {
                        let (lo, hi) = (si.min(di), si.max(di));
                        let (left, right) = bs.split_at_mut(hi);
                        let (s, d) = if si < di {
                            (&mut left[lo], &mut right[0])
                        } else {
                            (&mut right[0], &mut left[lo])
                        };
                        if let Some(m) = s.extract_migration().unwrap() {
                            d.inject_migration(s, m);
                        }
                    }
                }
                _ => {
                    for b in bs.iter_mut() {
                        b.tick().unwrap();
                    }
                    clock.advance(0.01);
                }
            }
        }
        // whatever the interleaving left unsubmitted goes in round-robin,
        // then the pool drains with no further migrations
        while (seq as usize) < n {
            let i = (seq as usize) % n_replicas;
            bs[i].submit_seq(ds.questions[seq as usize].clone(), seq);
            seq += 1;
        }
        let mut guard = 0;
        while bs.iter().any(|b| b.has_work()) {
            for b in bs.iter_mut() {
                b.tick().unwrap();
            }
            clock.advance(0.01);
            guard += 1;
            assert!(guard < 200_000, "cluster failed to drain (seed {seed})");
        }
        let completed: usize = bs.iter().map(|b| b.metrics.completed).sum();
        assert_eq!(completed, n, "request lost in migration (seed {seed})");
        let out: u64 = bs.iter().map(|b| b.metrics.migrations_out).sum();
        let inn: u64 = bs.iter().map(|b| b.metrics.migrations_in).sum();
        assert_eq!(out, inn, "migration bookkeeping imbalance (seed {seed})");
        for b in &bs {
            assert_eq!(b.pending(), 0);
            assert_eq!(b.active_count(), 0);
            assert_eq!(b.suspended_count(), 0);
            assert_eq!(b.kv_utilization(), 0.0, "KV slot leaked (seed {seed})");
        }
        drop(bs);
        assert_eq!(
            rt.main.pool_pages_in_use(),
            Some(0),
            "page leak across migrations (seed {seed})"
        );
        let (allocs, frees) = rt.main.pool_alloc_free().unwrap();
        assert_eq!(allocs, frees, "page alloc/free imbalance (seed {seed})");
    }
}

/// A session handed between batchers at random moments (KV pages and
/// all) must replay bit-identically to the same-seed run that never
/// migrates: per-request RNGs are seeded by the submission seq, so
/// WHERE a session runs can never change WHAT it computes.
#[test]
fn prop_migrated_trajectories_bit_identical_to_unmigrated() {
    use eat_serve::datasets::chainsum::Kind;
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0x316A7E);
        let rt = Runtime::reference();
        // corrupted questions stall (so preemption and migration have
        // victims to move); easy ones finish fast
        let pool = Dataset::synth_gpqa(&rt.vocab, 120, seed);
        let mut questions: Vec<_> = pool
            .questions
            .iter()
            .filter(|q| q.kind == Kind::Corrupted)
            .take(2)
            .cloned()
            .collect();
        questions.extend(
            pool.questions
                .iter()
                .filter(|q| q.kind == Kind::ChainSum && q.n_ops() <= 4)
                .take(5)
                .cloned(),
        );
        assert_eq!(questions.len(), 7, "pool too small (seed {seed})");
        let mut cfg = ServeConfig::default();
        cfg.seed = seed;
        cfg.delta = 1e-7;
        cfg.sched.mode = SchedMode::EatAware;
        cfg.sched.stall_stability = 0.2;
        cfg.sched.preempt_after_ticks = rng.range(4, 16);
        cfg.sched.max_preemptions = 100; // retirement never fires

        // migrated run: two batchers over one shared runtime, random
        // handoffs in both directions while the workload drains
        let clock = Clock::virt();
        let mut b0 = Batcher::with_clock(
            &rt,
            cfg.clone(),
            MonitorModel::SelfModel,
            2,
            eat_factory(&cfg),
            clock.clone(),
        );
        let mut b1 = Batcher::with_clock(
            &rt,
            cfg.clone(),
            MonitorModel::SelfModel,
            2,
            eat_factory(&cfg),
            clock.clone(),
        );
        for (i, q) in questions.iter().enumerate() {
            b0.submit_seq(q.clone(), i as u64);
        }
        let mut guard = 0;
        while b0.has_work() || b1.has_work() {
            if rng.chance(0.25) {
                let (s, d) = if rng.chance(0.5) {
                    (&mut b0, &mut b1)
                } else {
                    (&mut b1, &mut b0)
                };
                if let Some(m) = s.extract_migration().unwrap() {
                    d.inject_migration(s, m);
                }
            }
            b0.tick().unwrap();
            b1.tick().unwrap();
            clock.advance(0.01);
            guard += 1;
            assert!(guard < 100_000, "migrated run failed to drain (seed {seed})");
        }
        let mut migrated = b0.results;
        migrated.append(&mut b1.results);
        migrated.sort_by_key(|r| r.question_id);

        // reference: the same workload through one FIFO batcher, never
        // interrupted
        let ref_rt = Runtime::reference();
        let mut fifo_cfg = cfg.clone();
        fifo_cfg.sched.mode = SchedMode::Fifo;
        let mut f = Batcher::with_clock(
            &ref_rt,
            fifo_cfg.clone(),
            MonitorModel::SelfModel,
            2,
            eat_factory(&fifo_cfg),
            Clock::virt(),
        );
        for q in &questions {
            f.submit(q.clone());
        }
        f.run_to_completion().unwrap();
        let mut reference = f.results;
        reference.sort_by_key(|r| r.question_id);

        assert_eq!(migrated.len(), reference.len(), "seed {seed}");
        for (m, r) in migrated.iter().zip(&reference) {
            assert_eq!(key(m), key(r), "migration changed a trajectory (seed {seed})");
        }
    }
}

/// Differential oracle for the lazy read path (DESIGN.md §3.8): on
/// random documents — nested containers, strings exercising every
/// escape form (incl. `\u` and lone surrogates), numbers printed
/// through the writer, random whitespace between tokens — every value
/// reachable by path must come back from `JsonScanner` byte-identical
/// to the full-tree parse of the same text.
#[test]
fn prop_scanner_extractions_match_tree_parse() {
    use eat_serve::util::json::{Json, JsonScanner};

    // (escaped body as it appears between quotes, expected decoded text)
    fn gen_string(rng: &mut Rng) -> (String, String) {
        let mut body = String::new();
        let mut expect = String::new();
        for _ in 0..rng.below(6) {
            match rng.below(12) {
                0 => {
                    body.push_str("\\\"");
                    expect.push('"');
                }
                1 => {
                    body.push_str("\\\\");
                    expect.push('\\');
                }
                2 => {
                    body.push_str("\\/");
                    expect.push('/');
                }
                3 => {
                    body.push_str("\\n");
                    expect.push('\n');
                }
                4 => {
                    body.push_str("\\t");
                    expect.push('\t');
                }
                5 => {
                    body.push_str("\\u0041");
                    expect.push('A');
                }
                6 => {
                    body.push_str("\\u00e9");
                    expect.push('é');
                }
                // every \uXXXX decodes independently; surrogate halves
                // (paired or lone) map to U+FFFD — unescape_body is THE
                // definition, both read paths must agree on it
                7 => {
                    body.push_str("\\ud800");
                    expect.push('\u{FFFD}');
                }
                8 => {
                    body.push_str("\\ud83d\\ude00");
                    expect.push_str("\u{FFFD}\u{FFFD}");
                }
                9 => {
                    body.push_str("é漢");
                    expect.push_str("é漢");
                }
                _ => {
                    body.push_str("ab c");
                    expect.push_str("ab c");
                }
            }
        }
        (body, expect)
    }

    fn ws(rng: &mut Rng, out: &mut String) {
        for _ in 0..rng.below(3) {
            out.push(match rng.below(4) {
                0 => ' ',
                1 => '\n',
                2 => '\t',
                _ => '\r',
            });
        }
    }

    // Emit a random value as text (with whitespace) and return the tree
    // the full parser must produce for it.
    fn gen_value(rng: &mut Rng, depth: usize, out: &mut String) -> Json {
        let leaf_only = depth >= 3;
        match rng.below(if leaf_only { 4 } else { 6 }) {
            0 => {
                out.push_str("null");
                Json::Null
            }
            1 => {
                let b = rng.chance(0.5);
                out.push_str(if b { "true" } else { "false" });
                Json::Bool(b)
            }
            2 => {
                // no -0.0: the writer prints it as "0" (sign lost), and
                // this test compares round-tripped bits exactly
                let n = match rng.below(4) {
                    0 => rng.below(1_000_000) as f64,
                    1 => -(1.0 + rng.below(1000) as f64),
                    2 => rng.normal() * 1e-6,
                    _ => rng.f64() * 1e12,
                };
                // numbers travel through the writer's own formatting,
                // so text -> f64 is the shortest round trip both paths
                // must parse to identical bits
                out.push_str(&Json::num(n).to_string());
                Json::Num(n)
            }
            3 => {
                let (body, expect) = gen_string(rng);
                out.push('"');
                out.push_str(&body);
                out.push('"');
                Json::Str(expect)
            }
            4 => {
                out.push('[');
                let n = rng.below(4) as usize;
                let mut items = Vec::new();
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    ws(rng, out);
                    items.push(gen_value(rng, depth + 1, out));
                    ws(rng, out);
                }
                out.push(']');
                Json::Arr(items)
            }
            _ => {
                out.push('{');
                let n = rng.below(4) as usize;
                let mut map = std::collections::BTreeMap::new();
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    ws(rng, out);
                    // unique keys (duplicate-key tie-breaking is out of
                    // contract; the writer never emits duplicates), one
                    // escaped spelling so key decoding is exercised too
                    let (key_body, key) = if rng.chance(0.3) {
                        (format!("k\\u0065y{i}"), format!("key{i}"))
                    } else {
                        (format!("k{i}"), format!("k{i}"))
                    };
                    out.push('"');
                    out.push_str(&key_body);
                    out.push('"');
                    ws(rng, out);
                    out.push(':');
                    ws(rng, out);
                    let v = gen_value(rng, depth + 1, out);
                    ws(rng, out);
                    map.insert(key, v);
                }
                out.push('}');
                Json::Obj(map)
            }
        }
    }

    fn check(sc: &JsonScanner, tree: &Json, seed: u64) {
        match tree {
            Json::Null => assert!(sc.path_is_null(&[]), "seed {seed}"),
            Json::Bool(b) => assert_eq!(sc.path_bool(&[]), Some(*b), "seed {seed}"),
            Json::Num(n) => assert_eq!(
                sc.path_num(&[]).map(f64::to_bits),
                Some(n.to_bits()),
                "seed {seed}"
            ),
            Json::Str(s) => {
                assert_eq!(sc.path_str(&[]).as_deref(), Some(s.as_str()), "seed {seed}")
            }
            Json::Arr(items) => {
                let subs: Vec<JsonScanner> = sc.array_items().collect();
                assert_eq!(subs.len(), items.len(), "seed {seed}");
                for (s, t) in subs.iter().zip(items) {
                    check(s, t, seed);
                }
            }
            Json::Obj(map) => {
                // both directions: every tree key reachable by path(),
                // and every scanned entry present in the tree
                let entries: Vec<_> = sc.entries().collect();
                assert_eq!(entries.len(), map.len(), "seed {seed}");
                for (k, v) in map {
                    let sub = sc
                        .path(&[k.as_str()])
                        .unwrap_or_else(|| panic!("seed {seed}: scanner lost key `{k}`"));
                    check(&sub, v, seed);
                }
                for (k, sub) in &entries {
                    check(sub, &map[k.as_ref()], seed);
                }
            }
        }
    }

    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5CA11);
        let mut text = String::new();
        ws(&mut rng, &mut text);
        let tree = gen_value(&mut rng, 0, &mut text);
        ws(&mut rng, &mut text);
        // the generator's expected tree IS what the full parser builds
        assert_eq!(json::parse(&text).unwrap(), tree, "seed {seed}: {text}");
        check(&JsonScanner::new(&text), &tree, seed);
    }
}

/// The lazy replay path decides identically to the materialized one on
/// random traces, across all signals, policies and overhead charging.
#[test]
fn prop_replay_scanned_matches_tree_replay() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5CA2);
        let trace = random_trace(&mut rng);
        let text = trace.to_json().to_string();
        let sc = json::JsonScanner::new(&text);
        let signal = match rng.below(4) {
            0 => Signal::MainPrefixed,
            1 => Signal::MainPlain,
            2 => Signal::Proxy,
            _ => Signal::Newline,
        };
        let charge = rng.chance(0.5);
        let mk = |r: &mut Rng| -> Box<dyn ExitPolicy> {
            match r.below(8) {
                0 => Box::new(EatPolicy::new(0.2, 2f64.powi(-(r.below(16) as i32)), 10_000)),
                1 => Box::new(TokenBudgetPolicy::new(r.range(1, 120) as usize)),
                2 => Box::new(UniqueAnswersPolicy::new(
                    r.range(1, 32) as usize,
                    r.range(1, 3) as usize,
                    10_000,
                )),
                3 => Box::new(PathDeviationPolicy::new(
                    0.2,
                    2f64.powi(-(r.below(16) as i32)),
                    10_000,
                )),
                4 => Box::new(SequenceEntropyPolicy::new(0.03 + r.f64(), 10_000)),
                5 => Box::new(CumulativeEntropyPolicy::new(
                    0.2,
                    0.03 + r.f64(),
                    20.0 + 100.0 * r.f64(),
                    10_000,
                )),
                6 => Box::new(AnswerConsistencyPolicy::with_stride(
                    r.range(1, 32) as usize,
                    r.range(1, 4) as usize,
                    10_000,
                    r.range(1, 4) as usize,
                )),
                _ => {
                    let delta = 2f64.powi(-(r.below(16) as i32));
                    let k = r.range(1, 32) as usize;
                    let t = r.range(1, 3) as usize;
                    let children: Vec<(f64, Box<dyn ExitPolicy>)> = vec![
                        (2.0, Box::new(EatPolicy::new(0.2, delta, 10_000))),
                        (1.0, Box::new(UniqueAnswersPolicy::new(k, t, 10_000))),
                    ];
                    Box::new(WeightedEnsemble::new(children, 0.5))
                }
            }
        };
        // identical policy from an identical rng stream for both paths
        let mut policy_rng = Rng::new(seed ^ 0xB0);
        let mut p_tree = mk(&mut policy_rng);
        let mut policy_rng = Rng::new(seed ^ 0xB0);
        let mut p_scan = mk(&mut policy_rng);
        let a = replay(&trace, p_tree.as_mut(), signal, charge);
        let b = replay_scanned(&sc, p_scan.as_mut(), signal, charge).unwrap();
        assert_eq!(a.exit_line, b.exit_line, "seed {seed}");
        assert_eq!(a.exit_reason, b.exit_reason, "seed {seed}");
        assert_eq!(a.reasoning_tokens, b.reasoning_tokens, "seed {seed}");
        assert_eq!(a.overhead_tokens, b.overhead_tokens, "seed {seed}");
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "seed {seed}");
        assert_eq!(
            a.accuracy_exact.to_bits(),
            b.accuracy_exact.to_bits(),
            "seed {seed}"
        );
    }
}

/// Differential oracle for the event wheel (DESIGN.md §3.10): under
/// random schedule/pop/pop_due interleavings — in-ring times, far
/// (overflow-path) times, and late (behind-the-cursor) times — the
/// wheel must dequeue in exactly the order of a plain binary heap over
/// the full `(virtual_time, lane, seq)` key, bit for bit. This is the
/// contract that let the wheel take over the batcher/cluster/workload
/// event scheduling without moving a single metrics byte.
#[test]
fn prop_event_wheel_dequeues_in_exact_heap_order() {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    use eat_serve::util::wheel::{EventKey, EventWheel};

    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x3EE1D);
        let width = [0.01, 0.1, 1.0][rng.below(3) as usize];
        let nbuckets = [2usize, 16, 1024][rng.below(3) as usize];
        let horizon = width * nbuckets as f64;
        let mut wheel: EventWheel<u64> = EventWheel::with_geometry(width, nbuckets);
        let mut model: BinaryHeap<Reverse<(EventKey, u64)>> = BinaryHeap::new();
        let mut check = |got: Option<(EventKey, u64)>,
                         want: Option<(EventKey, u64)>,
                         frontier: &mut f64| {
            match (got, want) {
                (None, None) => {}
                (Some((g, gv)), Some((w, wv))) => {
                    assert_eq!(g.time.to_bits(), w.time.to_bits(), "seed {seed}");
                    assert_eq!((g.lane, g.seq, gv), (w.lane, w.seq, wv), "seed {seed}");
                    *frontier = frontier.max(g.time);
                }
                (g, w) => panic!("seed {seed}: wheel {g:?} vs heap {w:?}"),
            }
        };
        // rough consumption frontier the generated times straddle
        let mut t = 0.0f64;
        let mut id = 0u64;
        for _ in 0..rng.range(40, 200) {
            match rng.below(5) {
                0 | 1 | 2 => {
                    for _ in 0..rng.range(1, 6) {
                        let time = match rng.below(4) {
                            0 => t - rng.f64() * horizon, // late: clamps to cursor
                            1 => t + rng.f64() * width,   // cursor bucket
                            2 => t + rng.f64() * horizon, // in ring
                            _ => t + horizon * (1.0 + rng.f64() * 3.0), // overflow
                        };
                        let key = EventKey::new(time, rng.below(4) as u32, rng.below(64));
                        wheel.schedule(key, id);
                        model.push(Reverse((key, id)));
                        id += 1;
                    }
                }
                3 => {
                    for _ in 0..rng.range(1, 8) {
                        check(wheel.pop(), model.pop().map(|Reverse(x)| x), &mut t);
                    }
                }
                _ => {
                    let now = t + rng.f64() * horizon;
                    let mut due = Vec::new();
                    wheel.pop_due(now, &mut due);
                    for got in due {
                        check(Some(got), model.pop().map(|Reverse(x)| x), &mut t);
                    }
                    if let Some(Reverse((k, _))) = model.peek() {
                        assert!(k.time > now, "seed {seed}: pop_due left a due event");
                    }
                    t = t.max(now);
                }
            }
        }
        // drain: the tails agree too
        while let Some(got) = wheel.pop() {
            check(Some(got), model.pop().map(|Reverse(x)| x), &mut t);
        }
        assert!(model.pop().is_none(), "seed {seed}: heap outlived the wheel");
    }
}

/// Dataset generation invariants across seeds and sizes.
#[test]
fn prop_dataset_answers_consistent() {
    let vocab = Vocab::default_layout();
    for seed in 0..100 {
        let ds = eat_serve::datasets::Dataset::synth_gpqa(&vocab, 30, seed);
        for q in &ds.questions {
            match q.kind {
                eat_serve::datasets::chainsum::Kind::Corrupted => {
                    assert!(q.answer.is_none());
                    assert!(q.prompt.contains(&vocab.unk));
                }
                eat_serve::datasets::chainsum::Kind::ToolCall => {
                    assert_eq!(q.answer, Some(*q.ops.last().unwrap()));
                }
                _ => {
                    assert_eq!(
                        q.answer,
                        Some(q.ops.iter().sum::<u32>() % vocab.modulus)
                    );
                }
            }
            // prompts contain no out-of-vocabulary ids
            for &t in &q.prompt {
                assert!(t < vocab.size);
            }
        }
    }
}

/// Differential check for the arrival-process zoo (DESIGN.md §3.11):
/// routing Poisson through the `ArrivalSpec` → `ArrivalProcess` trait
/// must reproduce the legacy `PoissonStream` arrival-for-arrival, bit
/// for bit, across random (rate, seed) — the guarantee that let the
/// serve/soak entry points switch to `build_arrivals` without moving a
/// single default-path byte.
#[test]
fn prop_arrival_zoo_poisson_matches_legacy_stream() {
    for case in 0..CASES {
        let seed = case ^ 0xA2217;
        let mut rng = Rng::new(seed);
        let rate = 0.5 + rng.f64() * 500.0;
        let n = rng.range(1, 120) as usize;
        let via_spec = collect_arrivals(&ArrivalSpec::Poisson, n, rate, seed).unwrap();
        let mut legacy = PoissonStream::new(rate, seed);
        for (i, t) in via_spec.iter().enumerate() {
            assert_eq!(
                t.to_bits(),
                legacy.next_arrival().to_bits(),
                "case {case}: arrival {i} drifted from PoissonStream"
            );
        }
        // and the batch helper the pre-zoo callers used
        let batch = poisson_arrivals(n, rate, seed);
        for (i, (a, b)) in via_spec.iter().zip(&batch).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "case {case}: arrival {i} drifted from poisson_arrivals"
            );
        }
    }
}

/// The burst (MMPP) and diurnal streams are pure functions of
/// (rate, seed): a double run is byte-identical, times never go
/// backwards, and consuming the stream through event wheels of wildly
/// different geometry pops in exactly the same order — arrival shape
/// is independent of the scheduler's bucket layout.
#[test]
fn prop_burst_and_diurnal_replay_exactly_across_wheel_geometry() {
    use eat_serve::util::wheel::{EventKey, EventWheel};

    for case in 0..CASES {
        let seed = case ^ 0xB0057;
        let mut rng = Rng::new(seed);
        let rate = 1.0 + rng.f64() * 200.0;
        let n = rng.range(8, 96) as usize;
        for spec in [ArrivalSpec::Burst, ArrivalSpec::Diurnal] {
            let a = collect_arrivals(&spec, n, rate, seed).unwrap();
            let b = collect_arrivals(&spec, n, rate, seed).unwrap();
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "case {case} {spec:?}: replay drift at arrival {i}"
                );
            }
            for w in a.windows(2) {
                assert!(
                    w[1] >= w[0] && w[0] >= 0.0,
                    "case {case} {spec:?}: arrival time went backwards"
                );
            }
            let mut orders: Vec<Vec<u64>> = Vec::new();
            for (width, nbuckets) in [(0.01, 8usize), (0.25, 64), (2.0, 512)] {
                let mut wheel: EventWheel<u64> = EventWheel::with_geometry(width, nbuckets);
                for (i, &t) in a.iter().enumerate() {
                    wheel.schedule(EventKey::new(t, 0, i as u64), i as u64);
                }
                let mut order = Vec::with_capacity(n);
                while let Some((_, v)) = wheel.pop() {
                    order.push(v);
                }
                assert_eq!(order.len(), n, "case {case} {spec:?}: wheel lost arrivals");
                orders.push(order);
            }
            assert_eq!(orders[0], orders[1], "case {case} {spec:?}: geometry changed order");
            assert_eq!(orders[0], orders[2], "case {case} {spec:?}: geometry changed order");
        }
    }
}

/// Shed-victim selection (DESIGN.md §3.11): `pick_shed_victims` must
/// return exactly the qualifying candidates — measured stability at or
/// above the floor, not mid-elicitation — each at most once, ordered
/// by descending stability with ties broken by ascending submission
/// seq (oldest first). Seqs are unique by construction, matching the
/// batcher's monotone submission counter.
#[test]
fn prop_shed_victim_order_is_stability_desc_then_seq() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x51ED5);
        let n = rng.range(0, 40) as usize;
        let min_stability = rng.f64() * 0.8;
        let candidates: Vec<(Option<f64>, u64, bool)> = (0..n)
            .map(|i| {
                // coarse stability grid so descending-order ties are common
                let stability = if rng.chance(0.8) {
                    Some(rng.below(6) as f64 * 0.2)
                } else {
                    None
                };
                (stability, rng.below(4) * 64 + i as u64, rng.chance(0.2))
            })
            .collect();
        let picks = pick_shed_victims(&candidates, min_stability);
        let mut picked = vec![false; n];
        for &i in &picks {
            assert!(!picked[i], "case {case}: index {i} shed twice");
            picked[i] = true;
        }
        for (i, &(stability, _, eliciting)) in candidates.iter().enumerate() {
            let qualifies = !eliciting && stability.is_some_and(|s| s >= min_stability);
            assert_eq!(
                picked[i], qualifies,
                "case {case}: index {i} qualification mismatch"
            );
        }
        for w in picks.windows(2) {
            let (sa, qa, _) = candidates[w[0]];
            let (sb, qb, _) = candidates[w[1]];
            let (sa, sb) = (sa.unwrap(), sb.unwrap());
            assert!(
                sa > sb || (sa == sb && qa < qb),
                "case {case}: order violated between indices {} and {}",
                w[0],
                w[1]
            );
        }
    }
}

/// Per-tenant page budgets never leak and never overrun: across random
/// cap assignments and random `acquire_for`/`release` interleavings,
/// the per-tenant ledger tracks held lanes exactly, capped tenants stay
/// at or under their cap, uncapped tenants are never charged, every
/// refusal is explained by a cap or an exhausted pool, and releasing
/// everything returns every ledger — global and per-tenant — to zero.
#[test]
fn prop_tenant_caps_never_leak_or_overrun() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x7E4A7);
        let lanes = rng.range(1, 24) as usize;
        let reserve = rng.range(1, 5) as usize;
        let mut kv = KvPageManager::new(lanes, 16, reserve, None);
        let tenants = rng.range(1, 6) as u32;
        // cap a random subset; the rest stay uncapped (global gates only)
        let mut caps: Vec<Option<usize>> = vec![None; tenants as usize];
        for t in 0..tenants {
            if rng.chance(0.7) {
                let pages = rng.range(0, (lanes * reserve) as u64) as usize;
                kv.set_tenant_cap(t, pages);
                // set_tenant_cap clamps up to one worst-case reservation
                caps[t as usize] = Some(pages.max(reserve));
            }
        }
        let mut held: Vec<(SlotId, u32)> = Vec::new();
        for _ in 0..rng.range(50, 250) {
            if held.is_empty() || rng.chance(0.55) {
                let t = rng.below(tenants as u64) as u32;
                let can = kv.tenant_can_admit(t);
                match kv.acquire_for(t) {
                    Some(slot) => {
                        assert!(can, "case {case}: tenant {t} admitted past its cap");
                        held.push((slot, t));
                    }
                    None => assert!(
                        !can || kv.available() == 0,
                        "case {case}: tenant {t} refused with headroom"
                    ),
                }
            } else {
                let i = rng.below(held.len() as u64) as usize;
                let (slot, _) = held.swap_remove(i);
                kv.release(slot).unwrap();
            }
            assert_eq!(
                kv.pinned_pages(),
                held.len() * reserve,
                "case {case}: global ledger drift"
            );
            for t in 0..tenants {
                let mine = held.iter().filter(|&&(_, ht)| ht == t).count() * reserve;
                let tracked = kv.tenant_pinned_pages(t);
                match caps[t as usize] {
                    Some(cap) => {
                        assert_eq!(tracked, mine, "case {case}: tenant {t} ledger drift");
                        assert!(tracked <= cap, "case {case}: tenant {t} over its cap");
                    }
                    None => {
                        assert_eq!(tracked, 0, "case {case}: uncapped tenant {t} charged")
                    }
                }
            }
        }
        for (slot, _) in held.drain(..) {
            kv.release(slot).unwrap();
        }
        assert_eq!(kv.pinned_pages(), 0, "case {case}: pages leaked");
        for t in 0..tenants {
            assert_eq!(
                kv.tenant_pinned_pages(t),
                0,
                "case {case}: tenant {t} ledger leaked"
            );
        }
    }
}
