//! Split-phase serving protocol tests — engine + batcher + batch cache
//! store against the deterministic reference backend. **No artifacts, no
//! PJRT**: this is the suite that pins down the coordinator's behavior
//! in a clean checkout.
//!
//! Covered:
//!  * one fused `decode_batch` per scheduling tick (via RuntimeCounters)
//!  * fused vs sequential-fallback determinism (identical RequestResults)
//!  * BatchCacheStore dirty-slot upload accounting through the batcher
//!  * backpressure + mid-tick retire interaction
//!  * out-of-band probe/rollout servicing (EAT, #UA@K)

mod common;

use common::{eat_factory, key};
use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{Batcher, MonitorModel, RequestResult};
use eat_serve::datasets::Dataset;
use eat_serve::exit::{TokenBudgetPolicy, UniqueAnswersPolicy};
use eat_serve::runtime::{Backend, RefBackend, Runtime};
use eat_serve::vocab::Vocab;

fn run_batcher(
    rt: &Runtime,
    cfg: &ServeConfig,
    slots: usize,
    n: usize,
    sequential: bool,
) -> Vec<RequestResult> {
    let ds = Dataset::synth_math500(&rt.vocab, n, cfg.seed);
    let mut b = Batcher::new(rt, cfg.clone(), MonitorModel::SelfModel, slots, eat_factory(cfg));
    b.force_sequential = sequential;
    for q in &ds.questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.completed, n);
    let mut results = b.results;
    results.sort_by_key(|r| r.question_id);
    results
}

#[test]
fn fused_tick_issues_exactly_one_decode_batch() {
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let ds = Dataset::synth_math500(&rt.vocab, 4, 1);
    let mut b = Batcher::new(&rt, cfg.clone(), MonitorModel::SelfModel, 4, eat_factory(&cfg));
    for q in &ds.questions {
        b.submit(q.clone());
    }
    let c = rt.main.counters();
    assert_eq!(c.batch_decodes.get(), 0);

    // every tick with live sessions must issue exactly ONE fused call
    // (4 active sessions fit the 8-wide reference batch)
    let mut ticks_with_decodes = 0u64;
    while b.pending() > 0 || b.active_count() > 0 {
        let before = c.batch_decodes.get();
        b.tick().unwrap();
        let after = c.batch_decodes.get();
        assert!(
            after - before <= 1,
            "tick issued {} fused calls",
            after - before
        );
        ticks_with_decodes += after - before;
    }
    assert!(ticks_with_decodes > 0, "fused path never engaged");
    // every main-model decode went through the fused entry point
    assert_eq!(
        c.decodes.get(),
        0,
        "single decodes leaked onto the fused path"
    );
    assert_eq!(c.batch_decodes.get(), ticks_with_decodes);
    assert!(c.batch_lanes.get() >= c.batch_decodes.get());
}

#[test]
fn fused_and_sequential_fallback_are_bit_identical() {
    let cfg = ServeConfig::default();
    // fresh runtimes so counters/caches are independent
    let fused = run_batcher(&Runtime::reference(), &cfg, 4, 10, false);
    let seq = run_batcher(&Runtime::reference(), &cfg, 4, 10, true);
    assert_eq!(fused.len(), seq.len());
    for (f, s) in fused.iter().zip(&seq) {
        assert_eq!(key(f), key(s), "fused vs sequential diverged");
    }
}

#[test]
fn sequential_fallback_engages_when_backend_has_no_batch() {
    let vocab = Vocab::default_layout();
    // same name (and therefore scripted behavior) as the default
    // reference main model, but without a fused batch entry point
    let rt = Runtime {
        vocab,
        main: Box::new(RefBackend::new("ref-main", vocab, 128, None)),
        proxy: Box::new(RefBackend::proxy(vocab)),
        artifacts: None,
    };
    let cfg = ServeConfig::default();
    let results = run_batcher(&rt, &cfg, 3, 6, false);
    assert_eq!(results.len(), 6);
    let c = rt.main.counters();
    assert_eq!(c.batch_decodes.get(), 0);
    assert!(c.decodes.get() > 0);
    // and it still matches the fused reference run result-for-result
    let fused = run_batcher(&Runtime::reference(), &cfg, 3, 6, false);
    for (f, s) in fused.iter().zip(&results) {
        assert_eq!(key(f), key(s));
    }
}

#[test]
fn store_dirty_accounting_through_the_batcher() {
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let ds = Dataset::synth_math500(&rt.vocab, 3, 2);
    let mut b = Batcher::new(&rt, cfg.clone(), MonitorModel::SelfModel, 4, eat_factory(&cfg));
    for q in &ds.questions {
        b.submit(q.clone());
    }
    // tick 1: three fresh admissions -> three dirty lane uploads
    b.tick().unwrap();
    let sc = b.store_counters();
    assert_eq!(sc.installs, 3);
    assert_eq!(sc.fused_calls, 1);
    assert_eq!(sc.dirty_lane_uploads, 3);
    assert_eq!(sc.resident_lane_hits, 0);
    // tick 2: same lanes, now resident
    b.tick().unwrap();
    let sc = b.store_counters();
    assert_eq!(sc.dirty_lane_uploads, 3);
    assert_eq!(sc.resident_lane_hits, 3);
    b.run_to_completion().unwrap();
    let sc = b.store_counters();
    assert_eq!(sc.retires, 3, "all slots must be retired");
    // steady-state dominance: resident hits far outnumber dirty uploads
    assert!(sc.resident_lane_hits > sc.dirty_lane_uploads);
}

#[test]
fn backpressure_retire_and_midtick_exits() {
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.seed = 5;
    let n = 12;
    let slots = 3;
    let ds = Dataset::synth_math500(&rt.vocab, n, cfg.seed);
    let mut b = Batcher::new(&rt, cfg.clone(), MonitorModel::SelfModel, slots, eat_factory(&cfg));
    for q in &ds.questions {
        b.submit(q.clone());
    }
    let mut max_active = 0;
    while b.pending() > 0 || b.active_count() > 0 {
        b.tick().unwrap();
        max_active = max_active.max(b.active_count());
        assert!(b.active_count() <= slots, "slot cap violated");
    }
    assert_eq!(b.metrics.completed, n);
    assert_eq!(b.kv_peak(), slots, "backpressure never saturated the slots");
    assert!(max_active <= slots);
    // retired slots were recycled: more requests than slots completed
    assert_eq!(b.store_counters().installs as usize, n);
    assert_eq!(b.store_counters().retires as usize, n);
    assert!(b.metrics.accuracy() > 0.5, "reference reasoner collapsed");
}

#[test]
fn proxy_monitoring_services_probes_out_of_band() {
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let ds = Dataset::synth_math500(&rt.vocab, 4, 3);
    let mut b = Batcher::new(&rt, cfg.clone(), MonitorModel::Proxy, 4, eat_factory(&cfg));
    for q in &ds.questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.completed, 4);
    // EAT probes hit the proxy; the main model saw none
    assert_eq!(rt.main.counters().probes.get(), 0);
    assert!(rt.proxy.counters().probes.get() > 0);
    // reasoning tokens were mirrored into proxy caches sequentially
    assert!(rt.proxy.counters().decodes.get() > 0);
    // main decodes still all fused
    assert_eq!(rt.main.counters().decodes.get(), 0);
}

#[test]
fn rollout_policies_ride_the_batched_loop() {
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let ds = Dataset::synth_math500(&rt.vocab, 4, 4);
    let factory: eat_serve::coordinator::batcher::PolicyFactory =
        Box::new(|| Box::new(UniqueAnswersPolicy::new(16, 1, 96)));
    let mut b = Batcher::new(&rt, cfg, MonitorModel::SelfModel, 4, factory);
    for q in &ds.questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.completed, 4);
    // #UA@K probes the main model's answer distribution out-of-band
    assert!(rt.main.counters().probes.get() > 0);
    assert!(b.metrics.rollout_tokens > 0, "UA rollout cost not charged");
}

#[test]
fn batcher_matches_serve_one_for_a_single_request() {
    // one slot, one request: the batched loop must reproduce the
    // sequential serve_one path exactly (same seed derivation aside) —
    // pinned by running the batcher twice rather than comparing across
    // different seeding schemes
    let cfg = ServeConfig::default();
    let a = run_batcher(&Runtime::reference(), &cfg, 1, 5, false);
    let b = run_batcher(&Runtime::reference(), &cfg, 1, 5, false);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(key(x), key(y), "batcher is not deterministic");
    }
}

#[test]
fn probe_only_steps_copy_zero_pages() {
    // the acceptance bar for the paged store: an EAT probe performs no
    // full-cache copy — not a single pool page is copied, shared, or
    // allocated by servicing it
    use eat_serve::coordinator::engine::{service_work, start_session, StepWork};
    use eat_serve::util::rng::Rng;
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let q = Dataset::synth_math500(&rt.vocab, 4, 1).questions.remove(0);
    let (mut session, mut caches) = start_session(
        &rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        q,
        eat_factory(&cfg)(),
        Rng::new(3),
    )
    .unwrap();
    let c = rt.main.counters();
    let mut probes_serviced = 0;
    loop {
        let work = session.poll();
        let probe_step = matches!(work, StepWork::Probe { .. });
        let (copied, shared) = (c.pages_copied.get(), c.pages_shared.get());
        match work {
            StepWork::Done => break,
            w => service_work(&rt, &mut session, &mut caches, w).unwrap(),
        }
        if probe_step {
            probes_serviced += 1;
            assert_eq!(c.pages_copied.get(), copied, "a probe copied a page");
            assert_eq!(c.pages_shared.get(), shared, "a probe forked the cache");
        }
    }
    assert!(probes_serviced > 0, "EAT never probed");
    // the whole EAT serve (decodes + probes, no rollouts) is fork-free
    assert_eq!(c.cow_forks.get(), 0);
    assert_eq!(c.pages_copied.get(), 0);
}

#[test]
fn rollout_forks_are_cow_not_full_copies() {
    use eat_serve::exit::ConfidencePolicy;
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let ds = Dataset::synth_math500(&rt.vocab, 4, 7);
    let factory: eat_serve::coordinator::batcher::PolicyFactory =
        Box::new(|| Box::new(ConfidencePolicy::new(0.2, 1e-3, 96)));
    let mut b = Batcher::new(&rt, cfg, MonitorModel::SelfModel, 4, factory);
    for q in &ds.questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.completed, 4);
    let c = rt.main.counters();
    assert!(c.cow_forks.get() > 0, "confidence rollouts must fork");
    // every fork shares its parent's pages by refcount...
    assert!(c.pages_shared.get() >= c.cow_forks.get());
    // ...and diverges by copying AT MOST its partial tail page — never
    // the whole cache (a full-sequence copy would be ~8 pages per fork)
    assert!(
        c.pages_copied.get() <= c.cow_forks.get(),
        "forks copied {} pages over {} forks",
        c.pages_copied.get(),
        c.cow_forks.get()
    );
}

#[test]
fn token_budget_policy_needs_no_probes() {
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.max_think_tokens = 24;
    let ds = Dataset::synth_math500(&rt.vocab, 3, 6);
    let factory: eat_serve::coordinator::batcher::PolicyFactory =
        Box::new(|| Box::new(TokenBudgetPolicy::new(24)));
    let mut b = Batcher::new(&rt, cfg, MonitorModel::SelfModel, 3, factory);
    for q in &ds.questions {
        b.submit(q.clone());
    }
    b.run_to_completion().unwrap();
    assert_eq!(b.metrics.completed, 3);
    assert_eq!(rt.main.counters().probes.get(), 0, "free policy probed");
    for r in &b.results {
        assert!(r.reasoning_tokens <= 24 + 2, "budget overshot: {r:?}");
    }
}
