//! Cross-module integration tests that run without AOT artifacts:
//! policies x replay x sweeps x stores wired together the way the figure
//! drivers use them. (Artifact-dependent paths live in runtime_e2e.rs.)

use eat_serve::config::ServeConfig;
use eat_serve::eval::sweep::{
    default_deltas, default_token_budgets, sweep_confidence, sweep_eat,
    sweep_token, sweep_ua,
};
use eat_serve::eval::{replay, Signal, TraceSet};
use eat_serve::exit::{EatPolicy, ExitPolicy, ExitReason, UniqueAnswersPolicy};
use eat_serve::monitor::{LinePoint, Trace};
use eat_serve::util::rng::Rng;

/// Build a realistic-shaped trace set: per-question difficulty n drawn
/// from `ns`, EAT collapses at line n (the chain-sum dynamic), plus an
/// overthinking tail.
fn traceset(ns: &[usize], tail: usize, seed: u64) -> TraceSet {
    let mut rng = Rng::new(seed);
    let traces = ns
        .iter()
        .enumerate()
        .map(|(id, &n)| {
            let lines = n + tail;
            Trace {
                question_id: id,
                n_ops: n,
                answer: Some(1),
                prompt_tokens: n + 3,
                self_terminated: true,
                reasoning_tokens: vec![0; lines * 3],
                points: (1..=lines)
                    .map(|i| {
                        let stable = i >= n;
                        LinePoint {
                            line: i,
                            tokens: i * 3,
                            eat: if stable {
                                0.01 + 0.01 * rng.f64()
                            } else {
                                3.3 + 0.1 * rng.normal()
                            },
                            eat_proxy: Some(if stable {
                                0.03 + 0.01 * rng.f64()
                            } else {
                                3.4 + 0.1 * rng.normal()
                            }),
                            eat_plain: Some(0.001),
                            eat_newline: Some(0.5 + 0.4 * rng.f64()),
                            vhat: f64::INFINITY,
                            p_correct: if stable { 0.99 } else { 1.0 / 32.0 },
                            pass1_avgk: if stable { 1.0 } else { 0.03 },
                            unique_answers: if stable { 1 } else { 25 },
                            confidence: Some(if stable {
                                0.95 + 0.02 * rng.f64()
                            } else {
                                0.3 + 0.1 * rng.f64()
                            }),
                        }
                    })
                    .collect(),
            }
        })
        .collect();
    TraceSet {
        dataset: "integration".into(),
        traces,
    }
}

#[test]
fn adaptive_eat_beats_fixed_budget_end_to_end() {
    // heavy-tailed difficulty, long overthinking tails — the paper's
    // setting: most questions easy, a rare hard tail the fixed budget
    // must still cover. alpha = 0.5 is the scale-adapted default
    // (config.rs doc); with it the EMA transient decays fast enough for
    // adaptivity to pay off on short traces.
    let ns: Vec<usize> = (0..40)
        .map(|i| if i % 10 == 0 { 25 } else { 2 + (i % 4) })
        .collect();
    let ts = traceset(&ns, 20, 1);
    let eat = sweep_eat(&ts, Signal::MainPrefixed, 0.5, &default_deltas(), 10_000, false, "eat");
    let tok = sweep_token(&ts, &default_token_budgets(90), "token");
    assert!(
        eat.auc() > tok.auc(),
        "EAT AUC {} should beat token AUC {}",
        eat.auc(),
        tok.auc()
    );
    // iso-accuracy saving exists
    let best = tok.points.iter().map(|p| p.agg_pass1).fold(0.0, f64::max);
    let (te, tt) = (
        eat.tokens_at_accuracy(0.98 * best),
        tok.tokens_at_accuracy(0.98 * best),
    );
    let (te, tt) = (te.expect("eat reaches target"), tt.expect("token reaches target"));
    assert!(te < tt, "no saving: eat {te} vs token {tt}");
}

#[test]
fn proxy_signal_nearly_matches_self_signal() {
    let ns: Vec<usize> = (0..30).map(|i| 2 + (i % 8)).collect();
    let ts = traceset(&ns, 15, 2);
    let self_c = sweep_eat(&ts, Signal::MainPrefixed, 0.2, &default_deltas(), 10_000, false, "self");
    let proxy_c = sweep_eat(&ts, Signal::Proxy, 0.2, &default_deltas(), 10_000, false, "proxy");
    assert!((self_c.auc() - proxy_c.auc()).abs() < 0.1 * self_c.auc());
}

#[test]
fn ua_needs_large_k_and_costs_more() {
    let ns: Vec<usize> = (0..30).map(|i| 2 + (i % 8)).collect();
    let ts = traceset(&ns, 15, 3);
    // small K saturates #UA below the threshold too easily only when
    // unique_answers are capped by K — reproduced by the replay cost model
    let ua8 = sweep_ua(&ts, 8, &[1], 10_000, true, 1, "ua8");
    let ua32 = sweep_ua(&ts, 32, &[1], 10_000, true, 1, "ua32");
    let eat = sweep_eat(&ts, Signal::MainPrefixed, 0.2, &[1e-3], 10_000, true, "eat");
    // cost ordering: ua32 > ua8 > eat (charged overhead)
    assert!(ua32.points[0].total_tokens > ua8.points[0].total_tokens);
    assert!(ua8.points[0].total_tokens > eat.points[0].total_tokens);
}

#[test]
fn confidence_comparable_to_eat_but_pricier() {
    let ns: Vec<usize> = (0..30).map(|i| 2 + (i % 8)).collect();
    let ts = traceset(&ns, 15, 4);
    let eat = sweep_eat(&ts, Signal::MainPrefixed, 0.2, &default_deltas(), 10_000, true, "eat");
    let conf = sweep_confidence(&ts, 0.2, &default_deltas(), 10_000, true, "conf");
    // similar peak accuracy (the paper's Fig. 4 finding)...
    let peak = |c: &eat_serve::eval::Curve| {
        c.points.iter().map(|p| p.agg_pass1).fold(0.0, f64::max)
    };
    assert!((peak(&eat) - peak(&conf)).abs() < 0.05);
    // ...but per evaluated line confidence charges its 5-token greedy
    // rollout vs EAT's 3-token probe (Eq. 16 cost model): compare the
    // charged overhead at equal exit behavior (a threshold so strict
    // neither exits -> both consume all lines)
    let strict = &[1e-18f64];
    let eat_full = sweep_eat(&ts, Signal::MainPrefixed, 0.2, strict, 10_000, true, "eatf");
    let conf_full = sweep_confidence(&ts, 0.2, strict, 10_000, true, "conff");
    assert!(
        conf_full.points[0].total_tokens > eat_full.points[0].total_tokens,
        "conf {} <= eat {}",
        conf_full.points[0].total_tokens,
        eat_full.points[0].total_tokens
    );
}

#[test]
fn unsolvable_traces_burn_budget() {
    // EAT never stabilizes on unsolvable questions (App. I.4): keep eat
    // noisy-high through the whole trace
    let mut ts = traceset(&[5], 20, 5);
    let mut rng = Rng::new(9);
    for p in ts.traces[0].points.iter_mut() {
        p.eat = 2.5 + rng.normal().abs();
        p.pass1_avgk = 0.03;
        p.p_correct = 1.0 / 32.0;
    }
    ts.traces[0].answer = None;
    ts.traces[0].self_terminated = false;
    let mut policy = EatPolicy::new(0.2, 1e-4, 10_000);
    let out = replay(&ts.traces[0], &mut policy, Signal::MainPrefixed, false);
    assert_eq!(out.exit_line, None, "must not exit on unsolvable");
    assert_eq!(out.exit_reason, ExitReason::TokenBudget);
}

#[test]
fn sparse_ua_evaluation_reduces_overhead() {
    let ns: Vec<usize> = (0..20).map(|i| 3 + (i % 6)).collect();
    let ts = traceset(&ns, 12, 6);
    let dense = sweep_ua(&ts, 32, &[1], 10_000, true, 1, "dense");
    let sparse = sweep_ua(&ts, 32, &[1], 10_000, true, 8, "sparse");
    assert!(sparse.points[0].total_tokens < dense.points[0].total_tokens);
    // sparse evaluation still reaches decent accuracy
    assert!(sparse.points[0].agg_pass1 > 0.8);
}

#[test]
fn traceset_save_load_filter_pipeline() {
    let ts = traceset(&[2, 4, 6], 10, 7);
    let path = std::env::temp_dir().join("eat_integration_store.json");
    ts.save(&path).unwrap();
    let back = TraceSet::load(&path).unwrap();
    assert_eq!(back.traces.len(), 3);
    let solvable = back.filter_solvable(0.8);
    assert_eq!(solvable.traces.len(), 3); // all saturate in this set
}

#[test]
fn ua_policy_stride_interacts_with_budget() {
    let mut p = UniqueAnswersPolicy::with_stride(16, 1, 30, 4);
    // lines 1..3: no UA evaluation, under budget -> continue
    for i in 1..4 {
        let d = p.observe(&eat_serve::exit::LineObs {
            tokens: i * 3,
            ..Default::default()
        });
        assert!(!d.is_exit());
    }
    // line 4 evaluates and converges
    let d = p.observe(&eat_serve::exit::LineObs {
        tokens: 12,
        unique_answers: Some(1),
        ..Default::default()
    });
    assert!(d.is_exit());
}

#[test]
fn serve_config_paper_defaults_stable() {
    let c = ServeConfig::default();
    assert_eq!((c.temperature, c.top_p), (0.6, 0.95));
    assert!(c.prefixed_probe);
}

#[test]
fn zoo_races_every_family_deterministically() {
    use eat_serve::eval::{run_zoo, zoo_report_json, ZooConfig};

    // realistic chain-sum-shaped traces, heavy-tailed difficulty
    let ns: Vec<usize> = (0..24)
        .map(|i| if i % 8 == 0 { 20 } else { 2 + (i % 5) })
        .collect();
    let ts = traceset(&ns, 18, 11);
    let report = run_zoo(&ts, &ZooConfig::default());

    // every required family raced, plus at least one combinator
    let names: Vec<&str> = report.families.iter().map(|f| f.family.as_str()).collect();
    let req = ["eat", "token", "ua", "confidence", "path-dev", "seq-entropy", "cum-entropy"];
    for required in req {
        assert!(names.contains(&required), "family {required} missing: {names:?}");
    }
    assert!(names.iter().any(|n| n.contains('(')), "no combinator raced: {names:?}");
    assert!(names.len() >= 7);

    // the frontier is non-empty and only finite points sit on it
    assert!(report.families.iter().any(|f| f.on_frontier));
    for f in &report.families {
        assert!(f.auc_raw.is_finite(), "{}: non-finite raw AUC", f.family);
        assert!(f.auc_charged.is_finite(), "{}: non-finite charged AUC", f.family);
    }

    // the report is byte-deterministic: same traces, same JSON
    let again = run_zoo(&ts, &ZooConfig::default());
    assert_eq!(
        zoo_report_json(&report).to_string(),
        zoo_report_json(&again).to_string(),
        "zoo report must serialize byte-identically across runs"
    );
}
