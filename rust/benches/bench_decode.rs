//! Serving hot-path microbenchmarks: prefill, decode step, probe suffix
//! lengths. The fused-vs-sequential continuous-batching ablation lives
//! in bench_batch_decode.rs.
//!
//!     cargo bench --bench bench_decode
//!
//! Runs against the AOT artifacts when available, otherwise against the
//! deterministic reference backend — the snapshot records which.

use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_or_reference("artifacts");
    println!("backend: {}", rt.backend_kind());
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, 8, 9);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);

    let mut results = Vec::new();
    results.push(bench("prefill/main", || {
        rt.main.prefill(&prompt).unwrap();
    }));
    results.push(bench("prefill/proxy", || {
        rt.proxy.prefill(&prompt).unwrap();
    }));

    let (_lg, cache) = rt.main.prefill(&prompt)?;
    results.push(bench("decode/main_single", || {
        let mut fork = rt.main.fork(&cache).unwrap();
        rt.main.decode(&mut fork, vocab.nl).unwrap();
    }));
    let (_lgp, pcache) = rt.proxy.prefill(&prompt)?;
    results.push(bench("decode/proxy_single", || {
        let mut fork = rt.proxy.fork(&pcache).unwrap();
        rt.proxy.decode(&mut fork, vocab.nl).unwrap();
    }));

    // fused batched decode vs sequential: see bench_batch_decode.rs

    // probe suffix length scaling (Eq. 12's 1-token vs Eq. 13's 3-token)
    results.push(bench("probe/suffix1", || {
        rt.main.probe(&cache, &vocab.suffix_plain()).unwrap();
    }));
    results.push(bench("probe/suffix3", || {
        rt.main.probe(&cache, &vocab.suffix_prefixed()).unwrap();
    }));

    let extra = vec![
        ("backend", Json::str(rt.backend_kind())),
        ("prompt_tokens", Json::num(prompt.len() as f64)),
    ];
    let path = write_snapshot("decode", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
