//! Serving hot-path microbenchmarks: prefill, decode step, fused batched
//! decode vs sequential, probe suffix lengths. The fused-vs-sequential
//! comparison is the continuous-batching ablation recorded in
//! EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench bench_decode

use eat_serve::datasets::Dataset;
use eat_serve::runtime::Runtime;
use eat_serve::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench (artifacts not built): {e}");
            return Ok(());
        }
    };
    let vocab = rt.cfg.vocab;
    let ds = Dataset::synth_math500(&vocab, 8, 9);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);

    bench("prefill/main", || {
        rt.main.prefill(&rt.client, &prompt).unwrap();
    });
    bench("prefill/proxy", || {
        rt.proxy.prefill(&rt.client, &prompt).unwrap();
    });

    let (_lg, cache) = rt.main.prefill(&rt.client, &prompt)?;
    bench("decode/main_single", || {
        let mut fork = rt.main.fork_cache(&rt.client, &cache).unwrap();
        rt.main.decode(&rt.client, &mut fork, vocab.nl).unwrap();
    });
    let (_lgp, pcache) = rt.proxy.prefill(&rt.client, &prompt)?;
    bench("decode/proxy_single", || {
        let mut fork = rt.proxy.fork_cache(&rt.client, &pcache).unwrap();
        rt.proxy.decode(&rt.client, &mut fork, vocab.nl).unwrap();
    });

    // fused batched decode (B=4) vs 4 sequential decodes
    if rt.main.has_batch() {
        let b = rt.main.cfg.batch;
        let mk_caches = || -> anyhow::Result<Vec<_>> {
            (0..b)
                .map(|i| {
                    let mut p = ds.questions[i].prompt.clone();
                    p.push(vocab.think);
                    Ok(rt.main.prefill(&rt.client, &p)?.1)
                })
                .collect()
        };
        // fork fresh caches per iteration (a committed decode advances the
        // cache; repeated in-place stepping would overflow seq_len) — the
        // fork cost is identical for both variants, keeping the
        // comparison fair
        let templates = mk_caches()?;
        let toks = vec![vocab.nl; b];
        let fused = bench("decode/batch4_fused", || {
            let mut caches: Vec<_> = templates
                .iter()
                .map(|c| rt.main.fork_cache(&rt.client, c).unwrap())
                .collect();
            rt.main.decode_batch(&rt.client, &mut caches, &toks).unwrap();
        });
        let seq = bench("decode/batch4_sequential", || {
            let mut caches: Vec<_> = templates
                .iter()
                .map(|c| rt.main.fork_cache(&rt.client, c).unwrap())
                .collect();
            for c in caches.iter_mut() {
                rt.main.decode(&rt.client, c, vocab.nl).unwrap();
            }
        });
        println!(
            "\nfused B=4 decode is {:.2}x the latency of 4 sequential steps \
             (per-token speedup {:.2}x)",
            fused.mean_ns / seq.mean_ns * 4.0 / 4.0,
            seq.mean_ns / fused.mean_ns
        );
    }

    // probe suffix length scaling (Eq. 12's 1-token vs Eq. 13's 3-token)
    bench("probe/suffix1", || {
        rt.main.probe(&rt.client, &cache, &vocab.suffix_plain()).unwrap();
    });
    bench("probe/suffix3", || {
        rt.main.probe(&rt.client, &cache, &vocab.suffix_prefixed()).unwrap();
    });
    Ok(())
}
