//! Serving hot-path microbenchmarks: prefill, decode step, probe suffix
//! lengths. The fused-vs-sequential continuous-batching ablation lives
//! in bench_batch_decode.rs.
//!
//!     cargo bench --bench bench_decode

use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench (artifacts not built): {e}");
            return Ok(());
        }
    };
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, 8, 9);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);

    bench("prefill/main", || {
        rt.main.prefill(&prompt).unwrap();
    });
    bench("prefill/proxy", || {
        rt.proxy.prefill(&prompt).unwrap();
    });

    let (_lg, cache) = rt.main.prefill(&prompt)?;
    bench("decode/main_single", || {
        let mut fork = rt.main.fork(&cache).unwrap();
        rt.main.decode(&mut fork, vocab.nl).unwrap();
    });
    let (_lgp, pcache) = rt.proxy.prefill(&prompt)?;
    bench("decode/proxy_single", || {
        let mut fork = rt.proxy.fork(&pcache).unwrap();
        rt.proxy.decode(&mut fork, vocab.nl).unwrap();
    });

    // fused batched decode vs sequential: see bench_batch_decode.rs

    // probe suffix length scaling (Eq. 12's 1-token vs Eq. 13's 3-token)
    bench("probe/suffix1", || {
        rt.main.probe(&cache, &vocab.suffix_plain()).unwrap();
    });
    bench("probe/suffix3", || {
        rt.main.probe(&cache, &vocab.suffix_prefixed()).unwrap();
    });
    Ok(())
}
