//! Lazy JSON scanning vs full-tree parsing on trace files (DESIGN.md
//! §3.8) — the acceptance check for the hot-path speed pass.
//!
//!     cargo bench --bench bench_json
//!
//! Three tiers on the same serialized `TraceSet` text:
//!  - full tree parse (allocates a `Json` tree for every value);
//!  - partial extraction (per trace: `question_id` + final line's
//!    `pass1_avgk`) through the tree vs through `JsonScanner`, which
//!    never materializes anything — the snapshot records the speedup
//!    as `partial_speedup_x` (expected well past 5x: the scanner only
//!    lexes past what it skips, allocating nothing);
//!  - full decode to `Trace` structs, tree (`from_json`) vs scanner
//!    (`from_scanner`).

use eat_serve::monitor::{LinePoint, Trace};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::{self, Json, JsonScanner};
use eat_serve::util::rng::Rng;

fn synthetic_trace(id: usize, lines: usize, rng: &mut Rng) -> Trace {
    Trace {
        question_id: id,
        n_ops: 6,
        answer: Some(3),
        prompt_tokens: 9,
        self_terminated: false,
        reasoning_tokens: vec![5; lines * 3],
        points: (1..=lines)
            .map(|i| LinePoint {
                line: i,
                tokens: i * 3,
                eat: 2.0 * rng.f64(),
                eat_proxy: Some(rng.f64()),
                eat_plain: Some(0.0),
                eat_newline: Some(rng.f64()),
                vhat: f64::INFINITY,
                p_correct: rng.f64(),
                pass1_avgk: rng.f64(),
                unique_answers: 1 + (i % 5),
                confidence: Some(0.5),
            })
            .collect(),
    }
}

fn main() -> anyhow::Result<()> {
    const TRACES: usize = 120;
    const LINES: usize = 30;
    let mut rng = Rng::new(17);
    let traces: Vec<Trace> = (0..TRACES)
        .map(|i| synthetic_trace(i, LINES, &mut rng))
        .collect();
    let text = Json::obj(vec![
        ("dataset", Json::str("bench")),
        ("traces", Json::arr(traces.iter().map(|t| t.to_json()))),
    ])
    .to_string();
    println!(
        "traceset: {TRACES} traces x {LINES} lines = {} KiB of JSON\n",
        text.len() / 1024
    );

    let mut results = Vec::new();

    // full tree parse, no field access — the allocation floor
    results.push(bench("json/tree_parse", || {
        std::hint::black_box(json::parse(&text).unwrap());
    }));

    // partial extraction: question_id + final pass1_avgk per trace
    let tree_partial = bench("json/tree_partial_extract", || {
        let v = json::parse(&text).unwrap();
        let mut acc = 0.0f64;
        for t in v.get("traces").as_arr().unwrap() {
            acc += t.req_usize("question_id").unwrap() as f64;
            let pts = t.get("points").as_arr().unwrap();
            acc += pts.last().unwrap().get("pass1_avgk").as_f64().unwrap();
        }
        std::hint::black_box(acc);
    });
    let scan_partial = bench("json/scan_partial_extract", || {
        let sc = JsonScanner::new(&text);
        let mut acc = 0.0f64;
        for t in sc.path(&["traces"]).unwrap().array_items() {
            acc += t.req_usize("question_id").unwrap() as f64;
            let last = t
                .path(&["points"])
                .unwrap()
                .array_items()
                .last()
                .unwrap();
            acc += last.req_num("pass1_avgk").unwrap();
        }
        std::hint::black_box(acc);
    });
    let partial_speedup = tree_partial.mean_ns / scan_partial.mean_ns;
    println!(
        "partial extraction: tree {:.3} ms vs scan {:.3} ms -> {partial_speedup:.1}x",
        tree_partial.mean_ns / 1e6,
        scan_partial.mean_ns / 1e6
    );

    // full decode to Trace structs
    let tree_load = bench("json/tree_load_traces", || {
        let v = json::parse(&text).unwrap();
        let ts: Vec<Trace> = v
            .get("traces")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| Trace::from_json(t).unwrap())
            .collect();
        std::hint::black_box(ts);
    });
    let scan_load = bench("json/scan_load_traces", || {
        let sc = JsonScanner::new(&text);
        let ts: Vec<Trace> = sc
            .path(&["traces"])
            .unwrap()
            .array_items()
            .map(|t| Trace::from_scanner(&t).unwrap())
            .collect();
        std::hint::black_box(ts);
    });
    let load_speedup = tree_load.mean_ns / scan_load.mean_ns;
    println!(
        "full decode: tree {:.3} ms vs scan {:.3} ms -> {load_speedup:.2}x",
        tree_load.mean_ns / 1e6,
        scan_load.mean_ns / 1e6
    );

    results.extend([tree_partial, scan_partial, tree_load, scan_load]);
    let extra = vec![
        ("text_bytes", Json::num(text.len() as f64)),
        ("traces", Json::num(TRACES as f64)),
        ("partial_speedup_x", Json::num(partial_speedup)),
        ("load_speedup_x", Json::num(load_speedup)),
    ];
    let path = write_snapshot("json", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
