//! Virtual-clock serving simulator throughput: how fast the scheduler
//! itself runs (simulated requests per wall second), FIFO vs EAT-aware,
//! plus the scheduler event mix of one contended run — preemptions,
//! resumes, re-prefill tokens — so the preemption overhead is auditable.
//!
//!     cargo bench --bench bench_scheduler
//!
//! Runs on the deterministic reference backend (no artifacts needed):
//! the virtual clock means the bench measures pure scheduling + protocol
//! overhead, not model execution time.

use eat_serve::config::{SchedMode, ServeConfig};
use eat_serve::coordinator::{
    eat_policy_factory, poisson_arrivals, run_open_loop, Batcher, MonitorModel, DEFAULT_TICK_DT,
};
use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::clock::Clock;
use eat_serve::util::json::Json;

fn simulate(rt: &Runtime, cfg: &ServeConfig, n: usize, slots: usize) -> (u64, u64, u64) {
    let ds = Dataset::synth_gpqa(&rt.vocab, 24, cfg.seed);
    let mut b = Batcher::with_clock(
        rt,
        cfg.clone(),
        MonitorModel::SelfModel,
        slots,
        eat_policy_factory(cfg),
        Clock::virt(),
    );
    let arrivals = poisson_arrivals(n, 40.0, cfg.seed);
    run_open_loop(&mut b, &ds.questions, &arrivals, DEFAULT_TICK_DT).unwrap();
    assert_eq!(b.metrics.completed, n);
    (b.metrics.preemptions, b.metrics.resumes, b.metrics.resume_prefill_tokens)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::reference();
    println!("backend: {} (virtual clock)\n", rt.backend_kind());

    const N: usize = 24;
    const SLOTS: usize = 3;
    let mut results = Vec::new();
    for mode in [SchedMode::Fifo, SchedMode::EatAware] {
        let mut cfg = ServeConfig::default();
        cfg.seed = 11;
        cfg.sched.mode = mode;
        let name = match mode {
            SchedMode::Fifo => "serve_sim/fifo",
            SchedMode::EatAware => "serve_sim/eat_aware",
        };
        let r = bench(name, || {
            simulate(&rt, &cfg, N, SLOTS);
        });
        let req_per_s = N as f64 / (r.mean_ns / 1e9);
        println!("  {name}: {req_per_s:.0} simulated req/s\n");
        results.push(r);
    }

    // event mix of one contended EAT-aware run
    let mut cfg = ServeConfig::default();
    cfg.seed = 11;
    cfg.sched.mode = SchedMode::EatAware;
    let c = rt.main.counters();
    let (ticks0, allocs0) = (c.sched_ticks.get(), c.sched_allocs.get());
    let (preemptions, resumes, re_prefill) = simulate(&rt, &cfg, N, SLOTS);
    let (ticks, allocs) = (
        c.sched_ticks.get() - ticks0,
        c.sched_allocs.get() - allocs0,
    );
    let allocs_per_tick = allocs as f64 / (ticks.max(1)) as f64;
    println!("scheduler event mix ({N} requests, {SLOTS} slots):");
    println!("  preemptions         {preemptions:>8}");
    println!("  resumes             {resumes:>8}");
    println!(
        "  restored tokens     {re_prefill:>8}  (repinned pages on paged; re-prefilled on mono)"
    );
    println!("  ticks               {ticks:>8}");
    println!("  scratch allocs      {allocs:>8}  ({allocs_per_tick:.4} per tick; steady state is 0)");
    let event_mix = Json::obj(vec![
        ("requests", Json::num(N as f64)),
        ("slots", Json::num(SLOTS as f64)),
        ("preemptions", Json::num(preemptions as f64)),
        ("resumes", Json::num(resumes as f64)),
        ("restored_tokens", Json::num(re_prefill as f64)),
        ("sched_ticks", Json::num(ticks as f64)),
        ("sched_allocs", Json::num(allocs as f64)),
        ("allocs_per_tick", Json::num(allocs_per_tick)),
    ]);
    let path = write_snapshot("scheduler", &results, vec![("event_mix", event_mix)])?;
    println!("snapshot: {path}");
    Ok(())
}
