//! Soak core face-off (DESIGN.md §3.10): the event-wheel scheduling
//! core against the pre-wheel tick-scan driver it replaced, on the
//! identical workload. Both cores complete the same sessions with the
//! same total tokens (cross-checked here), so the wall-clock ratio is a
//! pure measure of scheduling overhead: O(2 events) per session versus
//! O(ticks × residents) scans. Snapshots to `BENCH_soak.json` with a
//! `speedup` table alongside the timing rows.
//!
//!     cargo bench --bench bench_soak
//!
//! Everything runs on virtual time; the numbers are a pure function of
//! the seed.

use eat_serve::coordinator::{run_soak, SoakConfig, SoakMode};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;

fn cfg(sessions: u64) -> SoakConfig {
    SoakConfig { sessions, seed: 11, ..SoakConfig::default() }
}

fn main() -> anyhow::Result<()> {
    let mut results = Vec::new();
    let mut speedups = Vec::new();
    for sessions in [10_000u64, 100_000] {
        let mut mean_ns = [0.0f64; 2];
        for (i, (mode, tag)) in [(SoakMode::Events, "events"), (SoakMode::Driver, "driver")]
            .into_iter()
            .enumerate()
        {
            let name = format!("soak/{tag}_{sessions}");
            let r = bench(&name, || {
                run_soak(&cfg(sessions), mode).unwrap();
            });
            r.report();
            mean_ns[i] = r.mean_ns;
            results.push(r);
        }
        // the two cores must agree before their times are comparable
        let ev = run_soak(&cfg(sessions), SoakMode::Events)?;
        let dr = run_soak(&cfg(sessions), SoakMode::Driver)?;
        assert_eq!(ev.completed, dr.completed, "cores disagree on completions");
        assert_eq!(ev.total_tokens, dr.total_tokens, "cores disagree on tokens");
        let speedup = mean_ns[1] / mean_ns[0].max(1.0);
        let sps = |ns: f64| sessions as f64 / (ns.max(1.0) / 1e9);
        println!(
            "  {sessions} sessions: events {:.0}/s vs driver {:.0}/s -> {speedup:.1}x\n",
            sps(mean_ns[0]),
            sps(mean_ns[1]),
        );
        speedups.push(Json::obj(vec![
            ("sessions", Json::num(sessions as f64)),
            ("events_sessions_per_s", Json::num(sps(mean_ns[0]))),
            ("driver_sessions_per_s", Json::num(sps(mean_ns[1]))),
            ("speedup", Json::num(speedup)),
            ("bytes_per_session", Json::num(ev.bytes_per_session() as f64)),
        ]));
    }
    let extra = vec![("speedup", Json::arr(speedups))];
    let path = write_snapshot("soak", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
