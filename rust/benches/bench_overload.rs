//! Saturation sweep (DESIGN.md §3.11): goodput and SLO attainment of
//! the overload-control policies as offered load crosses capacity —
//! 1.0x, 1.5x and 2.0x the slot pool's sustainable completion rate.
//! EAT-guided shedding (force-exit nearest-to-exit residents) is raced
//! against reject-only admission on the identical arrival sequence; the
//! equal-accuracy invariant is asserted before either timing is
//! reported. Snapshots to `BENCH_overload.json` with a `goodput` table
//! alongside the timing rows.
//!
//!     cargo bench --bench bench_overload
//!
//! Everything runs on virtual time; the numbers are a pure function of
//! the seed.

use eat_serve::config::OverloadPolicy;
use eat_serve::coordinator::{run_soak, SoakConfig, SoakMode};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;

fn cfg(overload: f64, shed: OverloadPolicy) -> SoakConfig {
    SoakConfig {
        sessions: 50_000,
        overload: Some(overload),
        slo_s: 10.0,
        shed,
        seed: 11,
        ..SoakConfig::default()
    }
}

fn main() -> anyhow::Result<()> {
    let mut results = Vec::new();
    let mut goodput = Vec::new();
    for overload in [1.0f64, 1.5, 2.0] {
        for (shed, tag) in [
            (OverloadPolicy::RejectOnly, "reject"),
            (OverloadPolicy::EatShed, "eat"),
        ] {
            let name = format!("overload/{tag}_{overload:.1}x");
            let r = bench(&name, || {
                run_soak(&cfg(overload, shed), SoakMode::Events).unwrap();
            });
            r.report();
            results.push(r);
        }
        let rej = run_soak(&cfg(overload, OverloadPolicy::RejectOnly), SoakMode::Events)?;
        let eat = run_soak(&cfg(overload, OverloadPolicy::EatShed), SoakMode::Events)?;
        assert!(
            (eat.accuracy() - rej.accuracy()).abs() < 0.02,
            "shedding moved accuracy: eat {} vs reject {}",
            eat.accuracy(),
            rej.accuracy()
        );
        println!(
            "  {overload:.1}x: goodput eat {:.0}/s vs reject {:.0}/s | SLO {:.3} vs {:.3} \
             (shed {}, rejected {})\n",
            eat.goodput_per_s(),
            rej.goodput_per_s(),
            eat.slo_attainment(),
            rej.slo_attainment(),
            eat.shed,
            rej.rejected,
        );
        goodput.push(Json::obj(vec![
            ("overload", Json::num(overload)),
            ("eat_goodput_per_s", Json::num(eat.goodput_per_s())),
            ("reject_goodput_per_s", Json::num(rej.goodput_per_s())),
            ("eat_slo_attainment", Json::num(eat.slo_attainment())),
            ("reject_slo_attainment", Json::num(rej.slo_attainment())),
            ("eat_shed", Json::num(eat.shed as f64)),
            ("reject_rejected", Json::num(rej.rejected as f64)),
            ("eat_accuracy", Json::num(eat.accuracy())),
            ("reject_accuracy", Json::num(rej.accuracy())),
        ]));
    }
    let extra = vec![("goodput", Json::arr(goodput))];
    let path = write_snapshot("overload", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
