//! Fig. 6c (top) — rollout cost vs EAT probe cost.
//!
//! #UA@K and confidence-style signals must *generate* answer rollouts;
//! the paper measures a single rollout at >50x the EAT evaluation cost.
//! Here a rollout honestly decodes suffix + answer tokens on a forked
//! cache through the AOT decode executable.
//!
//!     cargo bench --bench bench_rollout
//!
//! Runs against the AOT artifacts when available, otherwise against the
//! deterministic reference backend — the snapshot records which.

use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::sampler::Sampler;
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;
use eat_serve::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_or_reference("artifacts");
    println!("backend: {}", rt.backend_kind());
    let vocab = rt.vocab;
    let ds = Dataset::synth_aime(&vocab, 1, 5);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);
    let (_lg, mut cache) = rt.main.prefill(&prompt)?;
    while cache.pos() < 64 {
        rt.main.decode(&mut cache, vocab.nl)?;
    }
    let suffix = vocab.suffix_prefixed();
    let sampler = Sampler::new(0.6, 0.95);
    let mut rng = Rng::new(0);

    let probe = bench("eat_probe", || {
        rt.main.probe(&cache, &suffix).unwrap();
    });

    // one full answer rollout: fork cache, decode suffix, sample to EOS
    let mut one_rollout = || {
        let mut fork = rt.main.fork(&cache).unwrap();
        let mut logits = Vec::new();
        for &t in &suffix {
            logits = rt.main.decode(&mut fork, t).unwrap();
        }
        for _ in 0..3 {
            let t = sampler.sample(&logits, &mut rng);
            if t == vocab.eos {
                break;
            }
            logits = rt.main.decode(&mut fork, t).unwrap();
        }
    };
    let r1 = bench("rollout/k1", &mut one_rollout);
    let r8 = bench("rollout/k8", || {
        for _ in 0..8 {
            one_rollout();
        }
    });
    let r32 = bench("rollout/k32", || {
        for _ in 0..32 {
            one_rollout();
        }
    });

    println!("\ncost ratios vs one EAT probe (paper Fig. 6c: rollout is >50x at K=32):");
    println!("  1 rollout : {:.1}x", r1.mean_ns / probe.mean_ns);
    println!("  8 rollouts: {:.1}x", r8.mean_ns / probe.mean_ns);
    println!("  32 rollouts: {:.1}x", r32.mean_ns / probe.mean_ns);

    let extra = vec![
        ("backend", Json::str(rt.backend_kind())),
        ("rollout1_vs_probe_x", Json::num(r1.mean_ns / probe.mean_ns)),
        ("rollout32_vs_probe_x", Json::num(r32.mean_ns / probe.mean_ns)),
    ];
    let results = vec![probe, r1, r8, r32];
    let path = write_snapshot("rollout", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
