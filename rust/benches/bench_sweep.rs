//! Sweep-harness throughput: one EAT delta sweep and one full zoo race
//! over a synthetic trace set — the offline-eval hot loop.
//!
//!     cargo bench --bench bench_sweep
//!
//! Two tiers:
//!  - `sweep/eat_deltas`: the single-family kernel (`sweep_eat`) over
//!    the default 24-delta grid — replay dominates; this is the cost a
//!    figure driver pays per family;
//!  - `sweep/zoo_race`: the whole frontier harness (`run_zoo`) — every
//!    family x its grid, raw + charged, plus the pooled Pareto mask.
//!
//! The snapshot records the per-replay cost so regressions in the
//! replay kernel (not just the harness glue) move a tracked number.

use eat_serve::eval::sweep::{default_deltas, sweep_eat};
use eat_serve::eval::{run_zoo, Signal, TraceSet, ZooConfig};
use eat_serve::monitor::{LinePoint, Trace};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;
use eat_serve::util::rng::Rng;

/// Chain-sum-shaped step trace: noisy EAT before stabilization at line
/// `st`, a flat low plateau after, 24-token lines (the paper's regime:
/// probe overhead is a small fraction of line cost).
fn step_trace(id: usize, st: usize, lines: usize, rng: &mut Rng) -> Trace {
    Trace {
        question_id: id,
        n_ops: st,
        answer: Some(1),
        prompt_tokens: st + 3,
        self_terminated: true,
        reasoning_tokens: vec![5; lines * 24],
        points: (1..=lines)
            .map(|i| {
                let stable = i >= st;
                LinePoint {
                    line: i,
                    tokens: i * 24,
                    eat: if stable {
                        0.02 + 0.01 * rng.f64()
                    } else {
                        2.0 + rng.f64()
                    },
                    eat_proxy: Some(if stable { 0.05 } else { 2.2 }),
                    eat_plain: Some(0.001),
                    eat_newline: Some(0.5),
                    vhat: f64::INFINITY,
                    p_correct: if stable { 0.98 } else { 0.1 },
                    pass1_avgk: if stable { 1.0 } else { 0.1 },
                    unique_answers: if stable { 1 } else { 8 },
                    confidence: Some(if stable { 0.9 } else { 0.3 }),
                }
            })
            .collect(),
    }
}

fn main() -> anyhow::Result<()> {
    const TRACES: usize = 24;
    const LINES: usize = 60;
    let mut rng = Rng::new(23);
    let ts = TraceSet {
        dataset: "bench".into(),
        traces: (0..TRACES)
            .map(|i| step_trace(i, 3 + (i % 12) * 4, LINES, &mut rng))
            .collect(),
    };
    println!("traceset: {TRACES} traces x {LINES} lines\n");

    let mut results = Vec::new();

    let deltas = default_deltas();
    let eat_sweep = bench("sweep/eat_deltas", || {
        let c = sweep_eat(&ts, Signal::MainPrefixed, 0.2, &deltas, 10_000, true, "eat");
        std::hint::black_box(c);
    });
    let per_replay_ns = eat_sweep.mean_ns / (deltas.len() * TRACES) as f64;
    println!(
        "eat sweep: {:.3} ms for {} deltas -> {per_replay_ns:.0} ns/replay",
        eat_sweep.mean_ns / 1e6,
        deltas.len()
    );

    let zc = ZooConfig::default();
    let zoo = bench("sweep/zoo_race", || {
        let report = run_zoo(&ts, &zc);
        std::hint::black_box(report);
    });
    let report = run_zoo(&ts, &zc);
    println!(
        "zoo race: {:.1} ms for {} families",
        zoo.mean_ns / 1e6,
        report.families.len()
    );

    results.extend([eat_sweep, zoo]);
    let eat = report
        .families
        .iter()
        .find(|f| f.family == "eat")
        .expect("eat family present");
    let extra = vec![
        ("traces", Json::num(TRACES as f64)),
        ("families", Json::num(report.families.len() as f64)),
        ("per_replay_ns", Json::num(per_replay_ns)),
        ("eat_auc_charged", Json::num(eat.auc_charged)),
    ];
    let path = write_snapshot("sweep", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
