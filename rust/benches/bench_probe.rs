//! Fig. 6c (bottom) — EAT probe overhead vs context length.
//!
//! The paper's claim (§4.3): computing EAT needs one forward pass over the
//! suffix against the existing KV cache — deterministic overhead, linear
//! in |R|, "roughly equivalent to generating one extra token".
//!
//!     cargo bench --bench bench_probe
//!
//! Runs against the AOT artifacts when available, otherwise against the
//! deterministic reference backend — the snapshot records which.

use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_or_reference("artifacts");
    println!("backend: {}", rt.backend_kind());
    let vocab = rt.vocab;
    let ds = Dataset::synth_aime(&vocab, 1, 3);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);
    let (_lg, mut cache) = rt.main.prefill(&prompt)?;

    let suffix = vocab.suffix_prefixed();
    let mut results = Vec::new();
    let mut scaling = Vec::new();
    // grow the committed context and measure the probe at checkpoints
    for target in [16usize, 32, 64, 96, 120] {
        while cache.pos() < target {
            rt.main.decode(&mut cache, vocab.nl)?;
        }
        let r = bench(&format!("eat_probe/ctx{target}"), || {
            rt.main.probe(&cache, &suffix).unwrap();
        });
        scaling.push((target, r.mean_ns));
        results.push(r);
    }

    // one committed decode step for the "one extra token" comparison
    let (_lg2, mut c2) = rt.main.prefill(&prompt)?;
    while c2.pos() < 64 {
        rt.main.decode(&mut c2, vocab.nl)?;
    }
    let probe_at_64 = scaling.iter().find(|r| r.0 == 64).unwrap().1;
    let d = bench("decode_step/ctx64", || {
        let mut fork = rt.main.fork(&c2).unwrap();
        rt.main.decode(&mut fork, vocab.nl).unwrap();
    });
    let probe_vs_decode = probe_at_64 / d.mean_ns;
    println!(
        "\nEAT probe at ctx=64 is {probe_vs_decode:.2}x one decode step (paper: ~1 extra \
         token; our probe runs a 3-token suffix)"
    );
    println!("probe scaling (should be ~flat-to-linear in context):");
    for (ctx, ns) in &scaling {
        println!("  ctx {ctx:>4}: {:.3} ms", ns / 1e6);
    }
    results.push(d);
    // proxy-model probe for the black-box path
    let (_l, pc) = rt.proxy.prefill(&prompt)?;
    results.push(bench("eat_probe/proxy_ctx_prompt", || {
        rt.proxy.probe(&pc, &suffix).unwrap();
    }));

    let scaling_rows = scaling.iter().map(|(ctx, ns)| {
        Json::obj(vec![
            ("ctx", Json::num(*ctx as f64)),
            ("probe_mean_ns", Json::num(*ns)),
        ])
    });
    let extra = vec![
        ("backend", Json::str(rt.backend_kind())),
        ("probe_vs_decode_x", Json::num(probe_vs_decode)),
        ("probe_scaling", Json::arr(scaling_rows)),
    ];
    let path = write_snapshot("probe", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
