//! Fig. 5b — the black-box overlap claim: per-chunk local EAT compute
//! (proxy decode of the chunk + one probe) must be far cheaper than the
//! simulated chunk inter-arrival latency of the remote streaming API, so
//! monitoring adds zero wall-clock overhead.
//!
//!     cargo bench --bench bench_blackbox

use eat_serve::blackbox::LatencyModel;
use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::bench::bench;
use eat_serve::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench (artifacts not built): {e}");
            return Ok(());
        }
    };
    let vocab = rt.vocab;
    let ds = Dataset::synth_aime(&vocab, 1, 13);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);
    let (_l, cache) = rt.proxy.prefill(&prompt)?;
    let suffix = vocab.suffix_prefixed();

    // chunk sizes in tokens (the paper receives ~100-token chunks)
    for chunk in [4usize, 12, 24] {
        let r = bench(&format!("blackbox/proxy_chunk{chunk}"), || {
            let mut fork = rt.proxy.fork(&cache).unwrap();
            for _ in 0..chunk {
                rt.proxy.decode(&mut fork, vocab.nl).unwrap();
            }
            rt.proxy.probe(&fork, &suffix).unwrap();
        });
        let mut rng = Rng::new(1);
        let lat = LatencyModel::default();
        let arrivals: Vec<f64> = (0..200).map(|_| lat.chunk_ms(chunk, &mut rng)).collect();
        let mean_arrival = arrivals.iter().sum::<f64>() / arrivals.len() as f64;
        println!(
            "  chunk {chunk:>2} tokens: local compute {:.2} ms vs simulated arrival {:.1} ms -> {:.0}x headroom",
            r.mean_ns / 1e6,
            mean_arrival,
            mean_arrival / (r.mean_ns / 1e6)
        );
    }
    println!("\n(Fig. 5b: EAT computation fully overlaps the streaming API latency)");
    Ok(())
}
