//! Fig. 5b — the black-box overlap claim under *batching*: per-chunk
//! local EAT compute (proxy decode of the chunk + one probe) must hide
//! inside the simulated chunk inter-arrival latency of the remote
//! streaming API even when B concurrent streams share the proxy, so
//! monitoring adds zero wall-clock overhead.
//!
//! Two sections:
//!  1. micro — wall-clock cost of one chunk's proxy work vs the mean
//!     simulated arrival gap (the original Fig. 5b check);
//!  2. serve — full black-box coordinator runs at B = 1/4/8 concurrent
//!     streams on a virtual clock (DESIGN.md §3.6), reporting the
//!     deterministic overlap accounting plus the fused-lane counters
//!     and the real wall time the simulation took.
//!
//!     cargo bench --bench bench_blackbox

use eat_serve::blackbox::{
    BlackboxBatcher, BlackboxConfig, LatencyModel, ProxyCostModel, CHUNK_MONITOR_ALPHA,
    CHUNK_MONITOR_DELTA,
};
use eat_serve::config::ServeConfig;
use eat_serve::coordinator::{poisson_arrivals, run_open_loop, DEFAULT_TICK_DT};
use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::bench::{bench, write_snapshot, BenchResult};
use eat_serve::util::clock::Clock;
use eat_serve::util::json::Json;
use eat_serve::util::rng::Rng;

fn micro(rt: &Runtime) -> anyhow::Result<Vec<BenchResult>> {
    let vocab = rt.vocab;
    let ds = Dataset::synth_aime(&vocab, 1, 13);
    let mut prompt = ds.questions[0].prompt.clone();
    prompt.push(vocab.think);
    let (_l, cache) = rt.proxy.prefill(&prompt)?;
    let suffix = vocab.suffix_prefixed();

    // chunk sizes in tokens (the paper receives ~100-token chunks)
    let mut results = Vec::new();
    for chunk in [4usize, 12, 24] {
        let r = bench(&format!("blackbox/proxy_chunk{chunk}"), || {
            let mut fork = rt.proxy.fork(&cache).unwrap();
            for _ in 0..chunk {
                rt.proxy.decode(&mut fork, vocab.nl).unwrap();
            }
            rt.proxy.probe(&fork, &suffix).unwrap();
        });
        let mut rng = Rng::new(1);
        let lat = LatencyModel::default();
        let arrivals: Vec<f64> = (0..200).map(|_| lat.chunk_ms(chunk, &mut rng)).collect();
        let mean_arrival = arrivals.iter().sum::<f64>() / arrivals.len() as f64;
        println!(
            "  chunk {chunk:>2} tokens: local compute {:.2} ms vs simulated arrival {:.1} ms -> {:.0}x headroom",
            r.mean_ns / 1e6,
            mean_arrival,
            mean_arrival / (r.mean_ns / 1e6)
        );
        results.push(r);
    }
    Ok(results)
}

fn serve_batched(b: usize) -> anyhow::Result<Json> {
    // fresh runtime per width so the fused/decode counters are per-run
    let rt = Runtime::reference();
    let mut cfg = ServeConfig::default();
    cfg.alpha = CHUNK_MONITOR_ALPHA;
    cfg.delta = CHUNK_MONITOR_DELTA;
    cfg.seed = 7;
    let bb = BlackboxConfig {
        chunk_tokens: 8,
        latency: LatencyModel::default(),
        proxy_cost: ProxyCostModel::default(),
    };
    let n = 2 * b.max(2);
    let ds = Dataset::synth_aime(&rt.vocab, n, cfg.seed);
    let seed = cfg.seed;
    let mut batcher = BlackboxBatcher::with_clock(&rt, cfg, bb, b, Clock::virt());
    let arrivals = poisson_arrivals(n, 4.0, seed);
    let t0 = std::time::Instant::now();
    run_open_loop(&mut batcher, &ds.questions, &arrivals, DEFAULT_TICK_DT)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let m = &batcher.metrics;
    let ms = batcher.main_store_counters();
    println!(
        "  B={b}: {} streams, {} chunks, {} probes | gap p50 {:.1} ms vs proxy compute p50 {:.2} ms -> {:.0}x headroom, {} overruns",
        m.completed,
        m.chunks,
        m.probes,
        m.arrival_gap_ms.p50(),
        m.proxy_compute_ms.p50(),
        m.overlap_headroom(),
        m.overrun_chunks,
    );
    println!(
        "        fused main calls {} ({} lanes), sim elapsed {:.1}s vs wall {:.2}s, saved {:.1}s remote",
        ms.fused_calls,
        rt.main.counters().batch_lanes.get(),
        m.elapsed_s(),
        wall_s,
        m.saved_ms / 1e3,
    );
    Ok(Json::obj(vec![
        ("b", Json::num(b as f64)),
        ("completed", Json::num(m.completed as f64)),
        ("chunks", Json::num(m.chunks as f64)),
        ("probes", Json::num(m.probes as f64)),
        ("gap_p50_ms", Json::num(m.arrival_gap_ms.p50())),
        ("proxy_p50_ms", Json::num(m.proxy_compute_ms.p50())),
        ("headroom_x", Json::num(m.overlap_headroom())),
        ("overrun_chunks", Json::num(m.overrun_chunks as f64)),
        ("fused_main_calls", Json::num(ms.fused_calls as f64)),
        ("sim_elapsed_s", Json::num(m.elapsed_s())),
        ("wall_s", Json::num(wall_s)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_or_reference("artifacts");
    println!("== micro: one chunk of proxy work vs simulated arrival gap ==");
    let results = micro(&rt)?;
    println!("\n== serve: batched proxy monitoring of B concurrent streams ==");
    let mut serve_rows = Vec::new();
    for b in [1usize, 4, 8] {
        serve_rows.push(serve_batched(b)?);
    }
    println!("\n(Fig. 5b: EAT computation fully overlaps the streaming API latency, B-wide)");

    let extra = vec![
        ("backend", Json::str(rt.backend_kind())),
        ("serve", Json::arr(serve_rows)),
    ];
    let path = write_snapshot("blackbox", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
