//! Paged vs monolithic KV store: EAT probe and rollout-fork cost at
//! B = 1/4/8 concurrent sequences — the cost-model honesty check behind
//! DESIGN.md §3.5 (the paper's premise is that the probe is *cheap*;
//! forks and preemptions must not smuggle full-sequence copies back in).
//!
//!     cargo bench --bench bench_paged_cache
//!
//! Reference backend only (the comparison is between cache layouts, not
//! kernels): both stores compute identical logits, so the delta is pure
//! cache bookkeeping. The CoW counter report quantifies the sharing —
//! a fork is O(pages) refcount bumps plus at most ONE copied page on
//! first divergence, versus the monolithic full-history clone.

use std::time::Duration;

use eat_serve::coordinator::DEFAULT_PAGE_SIZE;
use eat_serve::runtime::{Backend, BackendCache, RefBackend};
use eat_serve::util::bench::{bench_with, default_budget, write_snapshot};
use eat_serve::util::json::Json;
use eat_serve::vocab::Vocab;

const ROLLOUT_LEN: usize = 5;

/// Prefill B caches and decode them to mid-reasoning depth (~56
/// committed tokens: several pages deep at the default page size).
fn mid_reasoning_caches(b: &dyn Backend, vocab: Vocab, n: usize) -> Vec<BackendCache> {
    (0..n)
        .map(|i| {
            let mut p = vec![vocab.bos, vocab.q];
            for k in 0..4u32 {
                p.push(vocab.num((i as u32 + k) % 7 + 1));
            }
            p.push(vocab.sep);
            p.push(vocab.think);
            let (mut logits, mut cache) = b.prefill(&p).unwrap();
            for _ in 0..48 {
                let tok = eat_serve::sampler::argmax(&logits);
                if tok == vocab.ethink {
                    break;
                }
                logits = b.decode(&mut cache, tok).unwrap();
            }
            cache
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let vocab = Vocab::default_layout();
    let paged = RefBackend::with_pages("ref-main", vocab, 128, Some(8), Some(DEFAULT_PAGE_SIZE));
    let mono = RefBackend::monolithic("ref-main", vocab, 128, Some(8));
    let suffix = vocab.suffix_prefixed();
    let budget = default_budget().min(Duration::from_millis(400));
    let mut results = Vec::new();

    println!("paged page size: {DEFAULT_PAGE_SIZE} tok  (mono = one full-sequence block)\n");
    for b in [1usize, 4, 8] {
        let paged_caches = mid_reasoning_caches(&paged, vocab, b);
        let mono_caches = mid_reasoning_caches(&mono, vocab, b);

        // EAT probe: one per cache (the per-line monitoring step)
        let pr_paged = bench_with(&format!("probe/paged_b{b}"), budget, 3, 10, &mut || {
            for c in &paged_caches {
                paged.probe(c, &suffix).unwrap();
            }
        });
        let pr_mono = bench_with(&format!("probe/mono_b{b}"), budget, 3, 10, &mut || {
            for c in &mono_caches {
                mono.probe(c, &suffix).unwrap();
            }
        });

        // rollout fork: fork + suffix + greedy rollout, then drop the
        // fork (the #UA@K / confidence baseline step)
        let rollout = |backend: &RefBackend, caches: &[BackendCache]| {
            for c in caches {
                let mut fork = backend.fork(c).unwrap();
                let mut logits = Vec::new();
                for &t in &suffix {
                    logits = backend.decode(&mut fork, t).unwrap();
                }
                for _ in 0..ROLLOUT_LEN {
                    let tok = eat_serve::sampler::argmax(&logits);
                    logits = backend.decode(&mut fork, tok).unwrap();
                }
            }
        };
        let fk_paged = bench_with(&format!("rollout_fork/paged_b{b}"), budget, 3, 10, &mut || {
            rollout(&paged, &paged_caches)
        });
        let fk_mono = bench_with(&format!("rollout_fork/mono_b{b}"), budget, 3, 10, &mut || {
            rollout(&mono, &mono_caches)
        });

        println!(
            "  B={b}: probe paged/mono {:.2}x   rollout-fork paged/mono {:.2}x\n",
            pr_mono.mean_ns / pr_paged.mean_ns.max(1.0),
            fk_mono.mean_ns / fk_paged.mean_ns.max(1.0),
        );
        results.extend([pr_paged, pr_mono, fk_paged, fk_mono]);
    }

    let c = paged.counters();
    let forks = c.cow_forks.get().max(1);
    println!("paged CoW audit over the bench:");
    println!("  cow_forks           {:>10}", c.cow_forks.get());
    println!(
        "  pages_shared        {:>10}  ({:.1} refcount bumps/fork)",
        c.pages_shared.get(),
        c.pages_shared.get() as f64 / forks as f64
    );
    println!(
        "  pages_copied        {:>10}  ({:.2} CoW copies/fork — a full-sequence \
         clone would be {:.0})",
        c.pages_copied.get(),
        c.pages_copied.get() as f64 / forks as f64,
        (128f64 / DEFAULT_PAGE_SIZE as f64),
    );
    println!("  live pages at exit  {:>10}", paged.pool_pages_in_use().unwrap());
    println!(
        "\n(the probe itself allocates, shares and copies ZERO pages — asserted \
         in batcher_protocol.rs; this table is the rollout-fork story)"
    );

    let cow_audit = Json::obj(vec![
        ("page_size_tok", Json::num(DEFAULT_PAGE_SIZE as f64)),
        ("cow_forks", Json::num(c.cow_forks.get() as f64)),
        ("pages_shared", Json::num(c.pages_shared.get() as f64)),
        ("pages_copied", Json::num(c.pages_copied.get() as f64)),
    ]);
    let path = write_snapshot("paged_cache", &results, vec![("cow_audit", cow_audit)])?;
    println!("snapshot: {path}");
    Ok(())
}
