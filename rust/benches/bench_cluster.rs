//! Cluster goodput scaling (DESIGN.md §3.7): the same contended
//! workload drained by N = 1/2/4 engine replicas behind the EAT-aware
//! router with live session migration on. Reports both the wall-clock
//! cost of simulating the cluster and the *virtual* goodput — completed
//! requests per simulated second — which is the paper-facing scaling
//! number, and snapshots everything to `BENCH_cluster.json`.
//!
//!     cargo bench --bench bench_cluster
//!
//! Runs on the deterministic reference backend under a virtual clock,
//! so every number here is a pure function of the seed.

use eat_serve::config::{SchedMode, ServeConfig};
use eat_serve::coordinator::{
    eat_policy_factory, Cluster, ClusterConfig, ClusterMetrics, MonitorModel, RoutePolicy,
};
use eat_serve::datasets::Dataset;
use eat_serve::runtime::Runtime;
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::clock::Clock;
use eat_serve::util::json::Json;

const N_REQ: usize = 24;
const SLOTS: usize = 3;

/// Drain `N_REQ` upfront arrivals through an N-replica cluster on a
/// virtual clock; the drain duration is the goodput window.
fn simulate(rt: &Runtime, replicas: usize) -> ClusterMetrics {
    let mut cfg = ServeConfig::default();
    cfg.seed = 11;
    cfg.sched.mode = SchedMode::EatAware;
    let ccfg = ClusterConfig {
        replicas,
        slots_per_replica: SLOTS,
        route: RoutePolicy::EatAware,
        migrate: replicas > 1,
    };
    let factories = (0..replicas).map(|_| eat_policy_factory(&cfg)).collect();
    let ds = Dataset::synth_gpqa(&rt.vocab, N_REQ, cfg.seed);
    let mut c = Cluster::with_clock(
        rt,
        cfg,
        MonitorModel::SelfModel,
        ccfg,
        factories,
        Clock::virt(),
    );
    for q in ds.questions.iter().take(N_REQ) {
        c.submit(q.clone());
    }
    c.run_to_completion().unwrap();
    let m = c.metrics();
    assert_eq!(m.completed, N_REQ);
    m
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::reference();
    println!("backend: {} (virtual clock)\n", rt.backend_kind());

    let mut results = Vec::new();
    let mut scaling = Vec::new();
    for replicas in [1usize, 2, 4] {
        let name = format!("cluster_sim/replicas_{replicas}");
        let r = bench(&name, || {
            simulate(&rt, replicas);
        });
        let m = simulate(&rt, replicas);
        println!(
            "  {name}: {:.1} sim req/s goodput over {:.2} sim s  \
             (migrations {}, reroutes {})\n",
            m.goodput_rps(),
            m.elapsed_s,
            m.migrations,
            m.reroutes
        );
        scaling.push(Json::obj(vec![
            ("replicas", Json::num(replicas as f64)),
            ("completed", Json::num(m.completed as f64)),
            ("elapsed_virtual_s", Json::num(m.elapsed_s)),
            ("goodput_rps", Json::num(m.goodput_rps())),
            ("migrations", Json::num(m.migrations as f64)),
            ("reroutes", Json::num(m.reroutes as f64)),
        ]));
        results.push(r);
    }
    let extra = vec![("goodput_scaling", Json::arr(scaling))];
    let path = write_snapshot("cluster", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
