//! Fused batched decode vs per-session sequential decode — the
//! continuous-batching win the batcher refactor banks on (tokens/sec at
//! B = 1/4/8), with the RuntimeCounters delta so the execution mix is
//! auditable in the bench trajectory.
//!
//!     cargo bench --bench bench_batch_decode
//!
//! Runs against the AOT artifacts when available (`--features pjrt` +
//! `make artifacts`), otherwise against the deterministic reference
//! backend — the *relative* fused-vs-sequential shape is meaningful on
//! both; absolute numbers only on pjrt.

use eat_serve::datasets::Dataset;
use eat_serve::runtime::{Backend, BackendCache, BatchLane, Runtime};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::Json;

fn counters_snapshot(rt: &Runtime) -> (u64, u64, u64, u64) {
    let c = rt.main.counters();
    (
        c.decodes.get(),
        c.batch_decodes.get(),
        c.batch_lanes.get(),
        c.batch_resident_lanes.get(),
    )
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_or_reference("artifacts");
    println!("backend: {}", rt.backend_kind());
    let Some(width) = rt.main.batch_width() else {
        eprintln!("skipping: backend has no fused decode_batch entry point");
        return Ok(());
    };
    let vocab = rt.vocab;
    let ds = Dataset::synth_math500(&vocab, 8, 9);

    // template caches: distinct prompts, shared across both variants
    let templates: Vec<BackendCache> = (0..8usize)
        .map(|i| {
            let mut p = ds.questions[i].prompt.clone();
            p.push(vocab.think);
            Ok(rt.main.prefill(&p)?.1)
        })
        .collect::<anyhow::Result<_>>()?;

    println!("fused batch width: {width}\n");
    let before = counters_snapshot(&rt);
    let mut results = Vec::new();

    // several decode steps per forked batch, so the backend's resident
    // batch image engages from step 2 onward (steady-state serving shape)
    const STEPS: usize = 4;
    for b in [1usize, 4, 8] {
        // fork fresh caches per iteration (a committed decode advances
        // the cache; unbounded in-place stepping would overflow seq_len)
        // — the fork cost is identical for both variants, keeping the
        // comparison fair
        let fused = bench(&format!("decode/fused_b{b}"), || {
            let mut caches: Vec<BackendCache> = templates[..b]
                .iter()
                .map(|c| rt.main.fork(c).unwrap())
                .collect();
            for _ in 0..STEPS {
                // chunk when B exceeds the artifact's batch width
                for chunk in caches.chunks_mut(width) {
                    let mut lanes: Vec<Option<BatchLane>> = chunk
                        .iter_mut()
                        .map(|c| {
                            Some(BatchLane {
                                cache: c,
                                token: vocab.nl,
                            })
                        })
                        .collect();
                    lanes.resize_with(width, || None);
                    rt.main.decode_batch(&mut lanes).unwrap();
                }
            }
        });
        let seq = bench(&format!("decode/sequential_b{b}"), || {
            let mut caches: Vec<BackendCache> = templates[..b]
                .iter()
                .map(|c| rt.main.fork(c).unwrap())
                .collect();
            for _ in 0..STEPS {
                for c in caches.iter_mut() {
                    rt.main.decode(c, vocab.nl).unwrap();
                }
            }
        });
        let fused_tps = (b * STEPS) as f64 / (fused.mean_ns / 1e9);
        let seq_tps = (b * STEPS) as f64 / (seq.mean_ns / 1e9);
        println!(
            "  B={b}: fused {:.0} tok/s vs sequential {:.0} tok/s -> {:.2}x\n",
            fused_tps,
            seq_tps,
            fused_tps / seq_tps
        );
        results.extend([fused, seq]);
    }

    let after = counters_snapshot(&rt);
    println!("RuntimeCounters delta over the bench:");
    println!("  single decodes      {:>10}", after.0 - before.0);
    println!("  fused decode calls  {:>10}", after.1 - before.1);
    println!("  fused lanes         {:>10}", after.2 - before.2);
    println!("  resident lane hits  {:>10}", after.3 - before.3);
    println!(
        "\n(one fused call commits up to {width} tokens; the batcher issues \
         exactly one per scheduling tick — see batcher_protocol.rs)"
    );

    let counters = Json::obj(vec![
        ("single_decodes", Json::num((after.0 - before.0) as f64)),
        ("fused_calls", Json::num((after.1 - before.1) as f64)),
        ("fused_lanes", Json::num((after.2 - before.2) as f64)),
        ("resident_lane_hits", Json::num((after.3 - before.3) as f64)),
    ]);
    let extra = vec![
        ("backend", Json::str(rt.backend_kind())),
        ("batch_width", Json::num(width as f64)),
        ("counters_delta", counters),
    ];
    let path = write_snapshot("batch_decode", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
