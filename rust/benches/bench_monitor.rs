//! Coordinator-side hot paths that must never bottleneck serving: the EMA
//! monitor update (runs every reasoning line), policy dispatch, offline
//! replay throughput, and trace (de)serialization — tree parse vs the
//! lazy `JsonScanner` path (DESIGN.md §3.8).
//!
//!     cargo bench --bench bench_monitor

use eat_serve::exit::{EatPolicy, ExitPolicy, LineObs};
use eat_serve::eval::{replay, replay_scanned, Signal, TraceSet};
use eat_serve::monitor::{EmaVar, LinePoint, Trace};
use eat_serve::util::bench::{bench, write_snapshot};
use eat_serve::util::json::{self, Json, JsonScanner};
use eat_serve::util::rng::Rng;

fn synthetic_trace(lines: usize) -> Trace {
    let mut rng = Rng::new(7);
    Trace {
        question_id: 0,
        n_ops: 6,
        answer: Some(3),
        prompt_tokens: 9,
        self_terminated: false,
        reasoning_tokens: vec![5; lines * 3],
        points: (1..=lines)
            .map(|i| LinePoint {
                line: i,
                tokens: i * 3,
                eat: if i > 6 { 0.02 } else { 2.0 + rng.f64() },
                eat_proxy: Some(0.1),
                eat_plain: Some(0.0),
                eat_newline: Some(rng.f64()),
                vhat: f64::INFINITY,
                p_correct: if i > 6 { 0.99 } else { 0.05 },
                pass1_avgk: if i > 6 { 1.0 } else { 0.06 },
                unique_answers: if i > 6 { 1 } else { 14 },
                confidence: Some(0.5),
            })
            .collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let mut results = Vec::new();

    // EMA update: the per-line O(1) core of Alg. 1
    let mut ema = EmaVar::new(0.2);
    let mut x = 0.0f64;
    results.push(bench("monitor/ema_update", || {
        x += 1.0;
        std::hint::black_box(ema.update((x % 7.0) * 0.3));
    }));

    // policy observe (incl. exit decision)
    let mut policy = EatPolicy::new(0.2, 1e-9, usize::MAX);
    let obs = LineObs {
        tokens: 33,
        eat: Some(1.5),
        ..Default::default()
    };
    results.push(bench("monitor/policy_observe", || {
        std::hint::black_box(policy.observe(&obs));
    }));

    // full-trace replay (the unit of every sweep point)
    let trace = synthetic_trace(30);
    results.push(bench("replay/trace30_eat", || {
        let mut p = EatPolicy::new(0.2, 1e-3, usize::MAX);
        std::hint::black_box(replay(&trace, &mut p, Signal::MainPrefixed, false));
    }));

    // the same replay straight off JSON text, no Trace materialized
    let trace_text = trace.to_json().to_string();
    results.push(bench("replay/trace30_eat_scanned", || {
        let sc = JsonScanner::new(&trace_text);
        let mut p = EatPolicy::new(0.2, 1e-3, usize::MAX);
        std::hint::black_box(
            replay_scanned(&sc, &mut p, Signal::MainPrefixed, false).unwrap(),
        );
    }));

    // sweep scale: 500 traces x 24 thresholds happens per figure panel
    let set = TraceSet {
        dataset: "bench".into(),
        traces: (0..100).map(|_| synthetic_trace(25)).collect(),
    };
    results.push(bench("replay/sweep_100x24", || {
        for i in 0..24 {
            let delta = 2f64.powi(-i);
            for t in &set.traces {
                let mut p = EatPolicy::new(0.2, delta, usize::MAX);
                std::hint::black_box(replay(t, &mut p, Signal::MainPrefixed, false));
            }
        }
    }));

    // trace JSON round-trip (store/load of the App. H protocol)
    let js = trace.to_json().to_string();
    results.push(bench("store/trace_to_json", || {
        std::hint::black_box(trace.to_json().to_string());
    }));
    results.push(bench("store/trace_parse", || {
        let v = json::parse(&js).unwrap();
        std::hint::black_box(Trace::from_json(&v).unwrap());
    }));
    results.push(bench("store/trace_scan", || {
        std::hint::black_box(Trace::from_scanner(&JsonScanner::new(&js)).unwrap());
    }));

    let extra = vec![("trace_lines", Json::num(30.0))];
    let path = write_snapshot("monitor", &results, extra)?;
    println!("snapshot: {path}");
    Ok(())
}
