//! Configuration system: artifact metadata + serving/eval settings.
//!
//! `ArtifactsConfig` mirrors `artifacts/config.json` (written by aot.py):
//! model dimensions, entry-point files and weight manifests. `ServeConfig`
//! and `EvalConfig` hold the runtime knobs (decoding, exit thresholds,
//! batching) with the paper's defaults.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};
use crate::vocab::Vocab;

/// One model's dimensions + artifact file names, as emitted by aot.py.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub d_ff: usize,
    pub d_head: usize,
    pub seq_len: usize,
    pub probe_len: usize,
    pub batch: usize,
    pub n_params: usize,
    pub weights: String,
    pub manifest: String,
    pub hlo_prefill: String,
    pub hlo_decode: String,
    pub hlo_probe: String,
    pub hlo_decode_batch: Option<String>,
}

impl ModelConfig {
    fn from_json(v: &Json) -> anyhow::Result<ModelConfig> {
        let hlo = v.req("hlo")?;
        Ok(ModelConfig {
            name: v.req_str("name")?.to_string(),
            vocab: v.req_usize("vocab")?,
            d_model: v.req_usize("d_model")?,
            n_head: v.req_usize("n_head")?,
            n_layer: v.req_usize("n_layer")?,
            d_ff: v.req_usize("d_ff")?,
            d_head: v.req_usize("d_head")?,
            seq_len: v.req_usize("seq_len")?,
            probe_len: v.req_usize("probe_len")?,
            batch: v.req_usize("batch")?,
            n_params: v.req_usize("n_params")?,
            weights: v.req_str("weights")?.to_string(),
            manifest: v.req_str("manifest")?.to_string(),
            hlo_prefill: hlo.req_str("prefill")?.to_string(),
            hlo_decode: hlo.req_str("decode")?.to_string(),
            hlo_probe: hlo.req_str("probe")?.to_string(),
            hlo_decode_batch: hlo
                .get("decode_batch")
                .as_str()
                .map(|s| s.to_string()),
        })
    }

    /// Total cache elements per sequence: [L, H, S, Dh] f32, K and V.
    pub fn cache_elems(&self) -> usize {
        self.n_layer * self.n_head * self.seq_len * self.d_head
    }
}

/// The whole artifacts directory: both models + vocab.
#[derive(Debug, Clone)]
pub struct ArtifactsConfig {
    pub dir: PathBuf,
    pub seq_len: usize,
    pub main: ModelConfig,
    pub proxy: ModelConfig,
    pub vocab: Vocab,
}

impl ArtifactsConfig {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<ArtifactsConfig> {
        let dir = dir.as_ref().to_path_buf();
        let cfg_text = std::fs::read_to_string(dir.join("config.json"))
            .map_err(|e| {
                anyhow::anyhow!(
                    "cannot read {}/config.json ({e}); run `make artifacts`",
                    dir.display()
                )
            })?;
        let cfg = json::parse(&cfg_text)?;
        let models = cfg.req("models")?;
        let vocab_text = std::fs::read_to_string(dir.join("vocab.json"))?;
        let vocab = Vocab::from_json(&json::parse(&vocab_text)?)?;
        Ok(ArtifactsConfig {
            seq_len: cfg.req_usize("seq_len")?,
            main: ModelConfig::from_json(models.req("main")?)?,
            proxy: ModelConfig::from_json(models.req("proxy")?)?,
            vocab,
            dir,
        })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelConfig> {
        match name {
            "main" => Ok(&self.main),
            "proxy" => Ok(&self.proxy),
            other => anyhow::bail!("unknown model `{other}`"),
        }
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// Decoding + serving knobs; defaults follow the paper (§App. H:
/// temperature 0.6, top-p 0.95; §5.3: T = 10K tokens scaled to our trace
/// lengths; Alg. 1: alpha = 0.2). The Fig. 13 ablation on our substrate
/// confirms the paper's default: alpha in [0.01, 0.2] gives the best
/// accuracy-per-token AUC (the slowly-decaying V-hat transient protects
/// hard questions from premature exits), degrading monotonically above
/// 0.4.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub temperature: f32,
    pub top_p: f32,
    /// Max thinking tokens T (Alg. 1 input). Our traces are ~25x shorter
    /// than the paper's (128-token sequences vs 10K budgets).
    pub max_think_tokens: usize,
    /// EMA timescale alpha (Eq. 7/8).
    pub alpha: f64,
    /// EAT variance threshold delta (Alg. 1 line 9).
    pub delta: f64,
    /// Use the "Final answer:" prefix string when probing (Eq. 13).
    pub prefixed_probe: bool,
    /// Seed for all sampling.
    pub seed: u64,
    /// KV page budget (DESIGN.md §3.5): caps the device-resident pages
    /// (admission gate — a session needs worst-case headroom) and
    /// bounds the host-side pages suspended sessions may retain for
    /// re-prefill-free resume (overflow spills to the re-prefill
    /// fallback). `None` = device budget of `slots × worst-case
    /// pages/session` with unbounded host retention, which makes page
    /// admission degenerate to lane admission, never spills, and keeps
    /// paged and monolithic serve runs byte-identical. The `--kv-pages`
    /// serve flag sets it.
    pub kv_pages: Option<usize>,
    /// Scheduler knobs (DESIGN.md §3.4).
    pub sched: SchedConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            temperature: 0.6,
            top_p: 0.95,
            max_think_tokens: 96,
            alpha: 0.2,
            delta: 1e-3,
            prefixed_probe: true,
            seed: 0,
            kv_pages: None,
            sched: SchedConfig::default(),
        }
    }
}

/// What the batcher does when demand exceeds the KV/page budget
/// (DESIGN.md §3.11). `None` (the default, the historical behavior)
/// queues everything forever. The active policies bound the backlog
/// against the per-request SLO deadline; `EatShed` additionally spends
/// the EAT distance-to-exit signal to free lanes: force-exit the
/// sessions *nearest* a safe exit first, instead of spilling resident
/// sessions to re-prefill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Queue without bound; never reject, never shed.
    None,
    /// Reject queued arrivals once their SLO deadline has passed.
    RejectOnly,
    /// `RejectOnly` + force-exit nearest-to-exit resident sessions
    /// (descending `ExitPolicy::stability`) while arrivals are starved
    /// of pages.
    EatShed,
}

impl OverloadPolicy {
    /// Parse the shared `--shed none|reject|eat` CLI spelling.
    pub fn from_flag(s: &str) -> anyhow::Result<OverloadPolicy> {
        match s {
            "none" => Ok(OverloadPolicy::None),
            "reject" => Ok(OverloadPolicy::RejectOnly),
            "eat" => Ok(OverloadPolicy::EatShed),
            other => anyhow::bail!("unknown --shed `{other}` (none|reject|eat)"),
        }
    }
}

/// How the batcher allocates contended KV slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Arrival order, no preemption — the pre-scheduler behavior.
    Fifo,
    /// EAT-aware: earliest-deadline admission, preemption of long-stalled
    /// sessions, stall retirement past the starvation guard.
    EatAware,
}

/// Scheduler configuration (DESIGN.md §3.4). The defaults keep the
/// historical FIFO behavior; `EatAware` turns the batcher into a
/// preemptive priority scheduler driven by the monitor's EMA-variance
/// distance to the exit threshold (`ExitPolicy::stability`).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub mode: SchedMode,
    /// Per-request latency SLO in seconds: admission prefers earlier
    /// deadlines in EAT-aware mode, and completions past their deadline
    /// count as misses in the metrics.
    pub deadline_s: f64,
    /// Aging bound: scheduling ticks a session must stay resident before
    /// it counts as long-stalled and becomes preemptible.
    pub preempt_after_ticks: u64,
    /// Stability (see `ExitPolicy::stability`) at or below which a
    /// resident session counts as stalled. Stabilized sessions are never
    /// preempted — they are driven to completion.
    pub stall_stability: f64,
    /// Starvation guard: a session preempted this many times becomes
    /// unpreemptible and its resumption outranks fresh admissions. A
    /// session still stalled after burning through the guard is retired
    /// by forced elicitation (`ExitReason::Stalled`) instead of burning
    /// the rest of its token budget.
    pub max_preemptions: u32,
    /// Suspended sessions waiting longer than this also outrank fresh
    /// admissions, even before hitting `max_preemptions`.
    pub resume_priority_after_s: f64,
    /// Saturation behavior (DESIGN.md §3.11). Default `None` keeps the
    /// historical queue-forever behavior bit-for-bit.
    pub overload: OverloadPolicy,
    /// Only sessions at or above this `ExitPolicy::stability` are
    /// EAT-shed candidates — shedding is reserved for near-converged
    /// sessions whose answer the paper's signal already trusts.
    pub shed_min_stability: f64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            mode: SchedMode::Fifo,
            deadline_s: 60.0,
            preempt_after_ticks: 32,
            stall_stability: 0.25,
            max_preemptions: 2,
            resume_priority_after_s: 1.0,
            overload: OverloadPolicy::None,
            shed_min_stability: 0.5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_defaults_match_paper() {
        let c = ServeConfig::default();
        assert_eq!(c.temperature, 0.6);
        assert_eq!(c.top_p, 0.95);
        assert_eq!(c.alpha, 0.2); // the paper Alg. 1 default
        assert!(c.prefixed_probe);
        // default scheduling stays FIFO (the pre-scheduler behavior)
        assert_eq!(c.sched.mode, SchedMode::Fifo);
        // default page budget = lane-equivalent (paged ≡ monolithic)
        assert!(c.kv_pages.is_none());
        assert!(c.sched.max_preemptions > 0);
        assert!(c.sched.stall_stability > 0.0 && c.sched.stall_stability < 1.0);
        // default overload control is off: queueing behavior (and so all
        // sub-capacity sim JSON) is unchanged by the saturation PR
        assert_eq!(c.sched.overload, OverloadPolicy::None);
        assert!(c.sched.shed_min_stability > c.sched.stall_stability);
    }

    #[test]
    fn overload_flag_parses() {
        assert_eq!(OverloadPolicy::from_flag("none").unwrap(), OverloadPolicy::None);
        assert_eq!(OverloadPolicy::from_flag("reject").unwrap(), OverloadPolicy::RejectOnly);
        assert_eq!(OverloadPolicy::from_flag("eat").unwrap(), OverloadPolicy::EatShed);
        assert!(OverloadPolicy::from_flag("drop").is_err());
    }

    #[test]
    fn model_config_parses() {
        let js = r#"{
          "name":"main","vocab":48,"d_model":64,"n_head":2,"n_layer":2,
          "d_ff":256,"d_head":32,"seq_len":128,"probe_len":4,"batch":4,
          "n_params":26,"weights":"w.bin","manifest":"m.json",
          "hlo":{"prefill":"p.hlo.txt","decode":"d.hlo.txt",
                 "probe":"pr.hlo.txt","decode_batch":"db.hlo.txt"}}"#;
        let m = ModelConfig::from_json(&json::parse(js).unwrap()).unwrap();
        assert_eq!(m.d_head, 32);
        assert_eq!(m.cache_elems(), 2 * 2 * 128 * 32);
        assert_eq!(m.hlo_decode_batch.as_deref(), Some("db.hlo.txt"));
    }

    #[test]
    fn model_config_missing_field_errors() {
        let js = r#"{"name":"x"}"#;
        assert!(ModelConfig::from_json(&json::parse(js).unwrap()).is_err());
    }
}
