//! Confidence baseline (Yang et al. 2025b, Eq. 16): length-normalized
//! likelihood of a greedy 5-token answer rollout,
//!
//!   Conf(R) = exp( (1/T) sum_t log p(a_t | R, a_<t) ),
//!
//! stabilized with the same EMA-variance rule as EAT (the paper's Fig. 4
//! comparison applies identical alpha windows to both signals). Roughly 5x
//! the evaluation cost of EAT because of the rollout.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::monitor::EmaVar;

#[derive(Debug, Clone)]
pub struct ConfidencePolicy {
    pub alpha: f64,
    pub delta: f64,
    pub max_tokens: usize,
    /// Rollout length T of Eq. 16 (5 in the paper).
    pub rollout_len: usize,
    ema: EmaVar,
}

impl ConfidencePolicy {
    pub fn new(alpha: f64, delta: f64, max_tokens: usize) -> Self {
        ConfidencePolicy {
            alpha,
            delta,
            max_tokens,
            rollout_len: 5,
            ema: EmaVar::new(alpha),
        }
    }

    pub fn vhat(&self) -> f64 {
        self.ema.debiased_var()
    }
}

impl ExitPolicy for ConfidencePolicy {
    fn name(&self) -> String {
        format!(
            "confidence(alpha={},delta={:.3e},T={})",
            self.alpha, self.delta, self.max_tokens
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        let conf = obs
            .confidence
            .expect("ConfidencePolicy requires the confidence signal");
        let vhat = self.ema.update(conf);
        if vhat < self.delta {
            return ExitDecision::Exit(ExitReason::Stable);
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.ema = EmaVar::new(self.alpha);
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            confidence: true,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.ema.count() == 0 {
            // no rollout yet: neutral, never preempted
            return None;
        }
        Some(super::stability_from_vhat(
            self.ema.debiased_var(),
            self.delta,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, conf: f64) -> LineObs {
        LineObs {
            tokens,
            confidence: Some(conf),
            ..Default::default()
        }
    }

    #[test]
    fn exits_on_stable_confidence() {
        let mut p = ConfidencePolicy::new(0.2, 1e-5, 10_000);
        for i in 0..8 {
            assert!(!p
                .observe(&obs(i * 3, 0.3 + 0.2 * (i % 2) as f64))
                .is_exit());
        }
        let mut exited = false;
        for i in 8..60 {
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, 0.97)) {
                assert_eq!(r, ExitReason::Stable);
                exited = true;
                break;
            }
        }
        assert!(exited);
    }

    #[test]
    fn needs_confidence_only() {
        let n = ConfidencePolicy::new(0.2, 1e-4, 10).needs();
        assert!(n.confidence && !n.eat && n.rollouts_k == 0);
    }

    #[test]
    fn stability_neutral_then_rises_as_confidence_settles() {
        let mut p = ConfidencePolicy::new(0.2, 1e-4, 10_000);
        assert_eq!(p.stability(), None, "no rollout yet must read as neutral");
        for i in 0..4 {
            p.observe(&obs(i * 3, 0.3 + 0.4 * (i % 2) as f64));
        }
        let noisy = p.stability().unwrap();
        for i in 4..60 {
            if p.observe(&obs(i * 3, 0.97)).is_exit() {
                break;
            }
        }
        let settled = p.stability().unwrap();
        assert!(settled > noisy, "{noisy} -> {settled}");
        assert!(noisy > 0.0 && settled <= 1.0);
    }
}
