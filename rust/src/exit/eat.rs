//! Alg. 1: EAT-based early exiting via EMA variance thresholding.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::monitor::EmaVar;

#[derive(Debug, Clone)]
pub struct EatPolicy {
    /// EMA timescale alpha (Eq. 7/8); paper default 0.2.
    pub alpha: f64,
    /// Variance threshold delta (line 9); swept over 2^-{0..39} in §5.3.
    pub delta: f64,
    /// Max thinking tokens T.
    pub max_tokens: usize,
    ema: EmaVar,
}

impl EatPolicy {
    pub fn new(alpha: f64, delta: f64, max_tokens: usize) -> EatPolicy {
        EatPolicy {
            alpha,
            delta,
            max_tokens,
            ema: EmaVar::new(alpha),
        }
    }

    /// Current de-biased variance (for traces/figures).
    pub fn vhat(&self) -> f64 {
        self.ema.debiased_var()
    }
}

impl ExitPolicy for EatPolicy {
    fn name(&self) -> String {
        format!(
            "eat(alpha={},delta={:.3e},T={})",
            self.alpha, self.delta, self.max_tokens
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        let eat = obs
            .eat
            .expect("EatPolicy requires the EAT signal (needs().eat)");
        let vhat = self.ema.update(eat);
        if vhat < self.delta {
            return ExitDecision::Exit(ExitReason::Stable);
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.ema = EmaVar::new(self.alpha);
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            eat: true,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.ema.count() == 0 {
            // no observation yet: "no data" is not "no progress" — the
            // scheduler must treat this as neutral, never as stalled
            return None;
        }
        Some(super::stability_from_vhat(self.ema.debiased_var(), self.delta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, eat: f64) -> LineObs {
        LineObs {
            tokens,
            eat: Some(eat),
            ..Default::default()
        }
    }

    #[test]
    fn exits_when_signal_stabilizes() {
        let mut p = EatPolicy::new(0.2, 1e-4, 1000);
        // noisy phase: no exit
        for i in 0..10 {
            let d = p.observe(&obs(i * 3, 3.0 + (i % 3) as f64));
            assert_eq!(d, ExitDecision::Continue, "line {i}");
        }
        // stable phase: must exit with Stable
        let mut exited = false;
        for i in 10..80 {
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, 0.05)) {
                assert_eq!(r, ExitReason::Stable);
                exited = true;
                break;
            }
        }
        assert!(exited);
    }

    #[test]
    fn budget_backstop() {
        let mut p = EatPolicy::new(0.2, 1e-12, 30);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut last = ExitDecision::Continue;
        for i in 1..=11 {
            last = p.observe(&obs(i * 3, rng.f64() * 4.0));
            if last.is_exit() {
                break;
            }
        }
        assert_eq!(last, ExitDecision::Exit(ExitReason::TokenBudget));
    }

    #[test]
    fn self_termination_wins() {
        let mut p = EatPolicy::new(0.2, 1e-4, 1000);
        let d = p.observe(&LineObs {
            tokens: 3,
            eat: Some(2.0),
            self_terminated: true,
            ..Default::default()
        });
        assert_eq!(d, ExitDecision::Exit(ExitReason::SelfTerminated));
    }

    #[test]
    fn reset_clears_state() {
        let mut p = EatPolicy::new(0.2, 1e-4, 1000);
        for i in 0..50 {
            p.observe(&obs(i, 0.5));
        }
        assert!(p.vhat() < 1e-4);
        p.reset();
        assert!(p.vhat().is_infinite());
    }

    #[test]
    fn stability_rises_as_the_signal_settles() {
        let mut p = EatPolicy::new(0.2, 1e-4, 10_000);
        assert_eq!(p.stability(), None, "no observation yet must read as neutral, not stalled");
        for i in 0..4 {
            p.observe(&obs(i * 3, 3.0 + (i % 2) as f64));
        }
        let noisy = p.stability().unwrap();
        for i in 4..60 {
            if p.observe(&obs(i * 3, 0.05)).is_exit() {
                break;
            }
        }
        let settled = p.stability().unwrap();
        assert!(settled > noisy, "stability must rise toward the exit: {noisy} -> {settled}");
        assert!(noisy > 0.0 && settled <= 1.0);
    }

    #[test]
    fn smaller_delta_exits_later() {
        // identical decaying-noise signal; the stricter threshold must
        // exit at a later line (the paper's compute/performance dial)
        let signal: Vec<f64> = (0..200)
            .map(|i| 3.0 * (-(i as f64) / 20.0).exp() * (1.0 + 0.1 * ((i * 7) % 3) as f64))
            .collect();
        let exit_line = |delta: f64| -> usize {
            let mut p = EatPolicy::new(0.2, delta, usize::MAX);
            for (i, &e) in signal.iter().enumerate() {
                if p.observe(&obs(i * 3, e)).is_exit() {
                    return i;
                }
            }
            signal.len()
        };
        assert!(exit_line(1e-2) < exit_line(1e-6));
    }
}
