//! Sequence-level entropy as confidence (Think Just Enough, arxiv
//! 2510.08146): treat the running *mean* EAT over all lines so far as a
//! sequence-level confidence proxy and exit once it drops below a fixed
//! level — the model is, on average over the whole trajectory, confident
//! about what follows its reasoning. Unlike EAT's variance rule this is
//! a level rule on the unwindowed mean: cheap (same one-probe signal),
//! but it forgets nothing, so an expensive early exploration phase
//! delays the exit long after the signal has settled — precisely the
//! contrast the zoo's Pareto table is built to expose.
//!
//! NaN contract: one NaN sample poisons the running mean; the level
//! comparison is false forever after and only the token-budget backstop
//! fires. Degenerate traces finish, they never panic.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};

#[derive(Debug, Clone, Copy)]
pub struct SequenceEntropyPolicy {
    /// Entropy level (nats): exit when the mean EAT so far < level.
    pub level: f64,
    /// Max thinking tokens T.
    pub max_tokens: usize,
    /// Lines required before the adaptive exit can fire (a one-line mean
    /// is not a sequence-level statistic).
    pub min_lines: usize,
    sum: f64,
    n: usize,
}

impl SequenceEntropyPolicy {
    pub fn new(level: f64, max_tokens: usize) -> SequenceEntropyPolicy {
        SequenceEntropyPolicy {
            level,
            max_tokens,
            min_lines: 3,
            sum: 0.0,
            n: 0,
        }
    }

    /// Mean EAT over every line observed so far; +inf before the first
    /// observation (a fresh policy can never read as confident).
    pub fn mean_entropy(&self) -> f64 {
        if self.n == 0 {
            return f64::INFINITY;
        }
        self.sum / self.n as f64
    }
}

impl ExitPolicy for SequenceEntropyPolicy {
    fn name(&self) -> String {
        format!(
            "seq-entropy(level={:.3e},T={})",
            self.level, self.max_tokens
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        let eat = obs
            .eat
            .expect("SequenceEntropyPolicy requires the EAT signal (needs().eat)");
        self.sum += eat;
        self.n += 1;
        if self.n >= self.min_lines && self.mean_entropy() < self.level {
            return ExitDecision::Exit(ExitReason::Stable);
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            eat: true,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        Some(super::stability_from_vhat(self.mean_entropy(), self.level))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, eat: f64) -> LineObs {
        LineObs {
            tokens,
            eat: Some(eat),
            ..Default::default()
        }
    }

    #[test]
    fn exits_when_mean_entropy_drops_below_level() {
        let mut p = SequenceEntropyPolicy::new(0.5, 10_000);
        for i in 0..5 {
            assert_eq!(p.observe(&obs(i * 3, 2.0)), ExitDecision::Continue);
        }
        // mean decays as low lines accumulate: (5*2.0 + k*0.01) / (5+k)
        let mut exited = false;
        for i in 5..40 {
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, 0.01)) {
                assert_eq!(r, ExitReason::Stable);
                exited = true;
                break;
            }
        }
        assert!(exited);
        assert!(p.mean_entropy() < 0.5);
    }

    #[test]
    fn min_lines_gate_blocks_early_exit() {
        let mut p = SequenceEntropyPolicy::new(1.0, 10_000);
        // lines 1 and 2 sit below the level but cannot exit yet
        assert_eq!(p.observe(&obs(3, 0.01)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(6, 0.01)), ExitDecision::Continue);
        assert!(p.observe(&obs(9, 0.01)).is_exit());
    }

    #[test]
    fn budget_backstop() {
        let mut p = SequenceEntropyPolicy::new(1e-12, 9);
        assert_eq!(p.observe(&obs(3, 2.0)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(6, 2.0)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(9, 2.0)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn self_termination_wins() {
        let mut p = SequenceEntropyPolicy::new(0.5, 1000);
        let d = p.observe(&LineObs {
            tokens: 3,
            eat: Some(2.0),
            self_terminated: true,
            ..Default::default()
        });
        assert_eq!(d, ExitDecision::Exit(ExitReason::SelfTerminated));
    }

    #[test]
    fn nan_sample_disables_the_adaptive_exit_not_the_backstop() {
        let mut p = SequenceEntropyPolicy::new(10.0, 12);
        p.observe(&obs(3, f64::NAN));
        assert_eq!(p.observe(&obs(6, 0.01)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(9, 0.01)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(12, 0.01)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn reset_clears_state() {
        let mut p = SequenceEntropyPolicy::new(0.5, 1000);
        for i in 0..10 {
            p.observe(&obs(i, 0.01));
        }
        p.reset();
        assert!(p.mean_entropy().is_infinite());
        assert_eq!(p.stability(), None);
        // a fresh start must again need min_lines before exiting
        assert_eq!(p.observe(&obs(3, 0.01)), ExitDecision::Continue);
    }

    #[test]
    fn needs_eat_only() {
        let n = SequenceEntropyPolicy::new(0.5, 10).needs();
        assert!(n.eat && !n.confidence && n.rollouts_k == 0);
    }
}
