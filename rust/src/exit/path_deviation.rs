//! Reasoning-path-deviation monitor (arxiv 2603.14251): track the EAT
//! trajectory as a running "reasoning path" (EMA mean) and watch the
//! squared innovation of each new line against that path,
//!
//!   d_n = x_n - M_{n-1},      D_n = (1-a) D_{n-1} + a d_n^2,
//!
//! exiting when the de-biased innovation energy D'_n falls below delta:
//! the model has stopped deviating from its established path, so further
//! reasoning is re-treading it. Structurally this is Alg. 1 evaluated on
//! the *pre-update* deviation — it shares EAT's one-probe cost and its
//! (0,1] stability mapping, which is exactly what makes it a fair zoo
//! competitor.
//!
//! NaN contract: a NaN EAT sample poisons both EMAs, every comparison
//! against delta is false from then on, and only the token-budget
//! backstop fires — degenerate traces finish, they never panic.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::monitor::EmaVar;

#[derive(Debug, Clone)]
pub struct PathDeviationPolicy {
    /// EMA timescale for both the path and the deviation monitor.
    pub alpha: f64,
    /// Innovation-energy threshold (exit when D' < delta).
    pub delta: f64,
    /// Max thinking tokens T.
    pub max_tokens: usize,
    /// Deviation evaluations required before the adaptive exit can fire
    /// (the first line only seeds the path and produces no deviation).
    pub min_evals: u64,
    path: EmaVar,
    dev: EmaVar,
}

impl PathDeviationPolicy {
    pub fn new(alpha: f64, delta: f64, max_tokens: usize) -> PathDeviationPolicy {
        PathDeviationPolicy {
            alpha,
            delta,
            max_tokens,
            min_evals: 2,
            path: EmaVar::new(alpha),
            dev: EmaVar::new(alpha),
        }
    }

    /// Current de-biased innovation energy D' (for traces/figures);
    /// +inf until the second observation.
    pub fn deviation(&self) -> f64 {
        self.dev.debiased_mean()
    }
}

impl ExitPolicy for PathDeviationPolicy {
    fn name(&self) -> String {
        format!(
            "path-dev(alpha={},delta={:.3e},T={})",
            self.alpha, self.delta, self.max_tokens
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        let eat = obs
            .eat
            .expect("PathDeviationPolicy requires the EAT signal (needs().eat)");
        if self.path.count() == 0 {
            // first line seeds the path; there is no deviation yet
            self.path.update(eat);
        } else {
            let d = eat - self.path.mean();
            self.dev.update(d * d);
            self.path.update(eat);
            if self.dev.count() >= self.min_evals && self.dev.debiased_mean() < self.delta {
                return ExitDecision::Exit(ExitReason::Stable);
            }
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.path = EmaVar::new(self.alpha);
        self.dev = EmaVar::new(self.alpha);
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            eat: true,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.dev.count() == 0 {
            // path not established yet: neutral, never preempted
            return None;
        }
        Some(super::stability_from_vhat(
            self.dev.debiased_mean(),
            self.delta,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, eat: f64) -> LineObs {
        LineObs {
            tokens,
            eat: Some(eat),
            ..Default::default()
        }
    }

    #[test]
    fn exits_when_path_stops_deviating() {
        let mut p = PathDeviationPolicy::new(0.2, 1e-4, 10_000);
        for i in 0..10 {
            let d = p.observe(&obs(i * 3, 3.0 + (i % 3) as f64));
            assert_eq!(d, ExitDecision::Continue, "line {i}");
        }
        let mut exited = false;
        for i in 10..80 {
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, 0.05)) {
                assert_eq!(r, ExitReason::Stable);
                exited = true;
                break;
            }
        }
        assert!(exited);
    }

    #[test]
    fn first_observation_only_seeds_the_path() {
        // even a loose threshold cannot fire on line 1: there is no
        // deviation to measure yet
        let mut p = PathDeviationPolicy::new(0.2, 10.0, 10_000);
        assert_eq!(p.observe(&obs(3, 0.0)), ExitDecision::Continue);
        assert!(p.deviation().is_infinite());
    }

    #[test]
    fn budget_backstop() {
        let mut p = PathDeviationPolicy::new(0.2, 1e-12, 30);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut last = ExitDecision::Continue;
        for i in 1..=11 {
            last = p.observe(&obs(i * 3, rng.f64() * 4.0));
            if last.is_exit() {
                break;
            }
        }
        assert_eq!(last, ExitDecision::Exit(ExitReason::TokenBudget));
    }

    #[test]
    fn self_termination_wins() {
        let mut p = PathDeviationPolicy::new(0.2, 1e-4, 1000);
        let d = p.observe(&LineObs {
            tokens: 3,
            eat: Some(2.0),
            self_terminated: true,
            ..Default::default()
        });
        assert_eq!(d, ExitDecision::Exit(ExitReason::SelfTerminated));
    }

    #[test]
    fn nan_sample_disables_the_adaptive_exit_not_the_backstop() {
        let mut p = PathDeviationPolicy::new(0.2, 1.0, 12);
        p.observe(&obs(3, 0.02));
        p.observe(&obs(6, f64::NAN));
        // poisoned monitor: comparisons are false, no Stable exit ever...
        assert_eq!(p.observe(&obs(9, 0.02)), ExitDecision::Continue);
        // ...but the token budget still terminates the request
        assert_eq!(
            p.observe(&obs(12, 0.02)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn reset_clears_state() {
        let mut p = PathDeviationPolicy::new(0.2, 1e-4, 1000);
        for i in 0..50 {
            p.observe(&obs(i, 0.5));
        }
        assert!(p.deviation() < 1e-4);
        p.reset();
        assert!(p.deviation().is_infinite());
        assert_eq!(p.stability(), None);
    }

    #[test]
    fn needs_eat_only() {
        let n = PathDeviationPolicy::new(0.2, 1e-4, 10).needs();
        assert!(n.eat && !n.confidence && n.rollouts_k == 0);
    }

    #[test]
    fn stability_rises_as_the_path_settles() {
        let mut p = PathDeviationPolicy::new(0.2, 1e-4, 10_000);
        assert_eq!(p.stability(), None);
        for i in 0..4 {
            p.observe(&obs(i * 3, 3.0 + (i % 2) as f64));
        }
        let noisy = p.stability().unwrap();
        for i in 4..60 {
            if p.observe(&obs(i * 3, 0.05)).is_exit() {
                break;
            }
        }
        let settled = p.stability().unwrap();
        assert!(settled > noisy, "{noisy} -> {settled}");
    }
}
