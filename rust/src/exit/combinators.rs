//! Policy combinators: conjunction, disjunction and weighted voting over
//! child [`ExitPolicy`]s, so the zoo's monitors compose into ensembles
//! without touching the engine.
//!
//! Semantics shared by all three:
//!
//! * **observe** — every still-undecided child sees every line. A child
//!   that votes exit is *latched* (it is never observed again; its vote
//!   stands) rather than re-polled, because a stateful child's decision
//!   is a stopping time, not a level that can be re-read. The
//!   combinator's own exit reason is the *binding vote*: the reason of
//!   the child whose latch completed the quorum on that line.
//! * **needs** — the [`SignalNeeds::union`] fold of the children, so the
//!   engine computes every signal any child consumes (rollout strides
//!   combine by gcd; see `union`).
//! * **reset** — resets every child and clears all latches.
//! * **stability** — latched children count as 1.0 (their exit is not
//!   merely imminent, it has happened); children with no signal yet
//!   (`None`) are skipped. `AllOf` reports the minimum (the conjunction
//!   is only as close to exiting as its furthest member), `AnyOf` the
//!   maximum, [`WeightedEnsemble`] the weight-weighted mean. All report
//!   `None` until at least one child has a signal — "no data" stays
//!   neutral for the scheduler.
//!
//! The token-budget backstop composes: children share the request's
//! budget, so each latches `TokenBudget` at the backstop line and every
//! combinator exits there (a conjunction's effective backstop is the
//! max of its children's budgets).

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};

fn union_needs(children: impl Iterator<Item = SignalNeeds>) -> SignalNeeds {
    children.fold(SignalNeeds::default(), SignalNeeds::union)
}

/// Exit only when *every* child has voted exit (conservative: spurious
/// single-monitor exits are vetoed by the rest of the ensemble).
pub struct AllOf {
    children: Vec<Box<dyn ExitPolicy>>,
    latched: Vec<Option<ExitReason>>,
}

impl AllOf {
    pub fn new(children: Vec<Box<dyn ExitPolicy>>) -> AllOf {
        assert!(!children.is_empty(), "AllOf needs at least one child");
        let latched = vec![None; children.len()];
        AllOf { children, latched }
    }
}

impl ExitPolicy for AllOf {
    fn name(&self) -> String {
        let names: Vec<String> = self.children.iter().map(|c| c.name()).collect();
        format!("all({})", names.join(" & "))
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        let mut binding = None;
        for (child, latch) in self.children.iter_mut().zip(self.latched.iter_mut()) {
            if latch.is_some() {
                continue;
            }
            if let ExitDecision::Exit(r) = child.observe(obs) {
                *latch = Some(r);
                binding = Some(r);
            }
        }
        match binding {
            Some(r) if self.latched.iter().all(|l| l.is_some()) => ExitDecision::Exit(r),
            _ => ExitDecision::Continue,
        }
    }

    fn reset(&mut self) {
        for child in &mut self.children {
            child.reset();
        }
        self.latched.fill(None);
    }

    fn needs(&self) -> SignalNeeds {
        union_needs(self.children.iter().map(|c| c.needs()))
    }

    fn stability(&self) -> Option<f64> {
        self.children
            .iter()
            .zip(&self.latched)
            .filter_map(|(c, l)| if l.is_some() { Some(1.0) } else { c.stability() })
            .fold(None, |m: Option<f64>, s| Some(m.map_or(s, |m| m.min(s))))
    }
}

/// Exit as soon as *any* child votes exit (aggressive: the cheapest
/// monitor to trigger ends the request). Children are polled in order
/// and the first exit short-circuits the rest for that line.
pub struct AnyOf {
    children: Vec<Box<dyn ExitPolicy>>,
}

impl AnyOf {
    pub fn new(children: Vec<Box<dyn ExitPolicy>>) -> AnyOf {
        assert!(!children.is_empty(), "AnyOf needs at least one child");
        AnyOf { children }
    }
}

impl ExitPolicy for AnyOf {
    fn name(&self) -> String {
        let names: Vec<String> = self.children.iter().map(|c| c.name()).collect();
        format!("any({})", names.join(" | "))
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        for child in &mut self.children {
            let d = child.observe(obs);
            if d.is_exit() {
                return d;
            }
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        for child in &mut self.children {
            child.reset();
        }
    }

    fn needs(&self) -> SignalNeeds {
        union_needs(self.children.iter().map(|c| c.needs()))
    }

    fn stability(&self) -> Option<f64> {
        self.children
            .iter()
            .filter_map(|c| c.stability())
            .fold(None, |m: Option<f64>, s| Some(m.map_or(s, |m| m.max(s))))
    }
}

/// Weighted vote: exit once the latched children carry at least
/// `quorum` of the total weight. `quorum` in (0, 1]; 1.0 degenerates to
/// [`AllOf`], and a quorum at or below the smallest normalized weight
/// degenerates to [`AnyOf`].
pub struct WeightedEnsemble {
    children: Vec<(f64, Box<dyn ExitPolicy>)>,
    latched: Vec<Option<ExitReason>>,
    quorum: f64,
    total_weight: f64,
}

impl WeightedEnsemble {
    pub fn new(children: Vec<(f64, Box<dyn ExitPolicy>)>, quorum: f64) -> WeightedEnsemble {
        assert!(!children.is_empty(), "WeightedEnsemble needs at least one child");
        assert!(
            quorum > 0.0 && quorum <= 1.0,
            "quorum must be in (0, 1], got {quorum}"
        );
        let mut total_weight = 0.0;
        for (w, _) in &children {
            assert!(w.is_finite() && *w > 0.0, "weights must be finite and positive, got {w}");
            total_weight += w;
        }
        let latched = vec![None; children.len()];
        WeightedEnsemble {
            children,
            latched,
            quorum,
            total_weight,
        }
    }

    fn latched_weight(&self) -> f64 {
        self.children
            .iter()
            .zip(&self.latched)
            .filter(|(_, l)| l.is_some())
            .map(|((w, _), _)| w)
            .sum()
    }
}

impl ExitPolicy for WeightedEnsemble {
    fn name(&self) -> String {
        let names: Vec<String> = self
            .children
            .iter()
            .map(|(w, c)| format!("{w}*{}", c.name()))
            .collect();
        format!("vote(q={}; {})", self.quorum, names.join(" + "))
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        let mut binding = None;
        for ((_, child), latch) in self.children.iter_mut().zip(self.latched.iter_mut()) {
            if latch.is_some() {
                continue;
            }
            if let ExitDecision::Exit(r) = child.observe(obs) {
                *latch = Some(r);
                binding = Some(r);
            }
        }
        match binding {
            Some(r) if self.latched_weight() / self.total_weight >= self.quorum => {
                ExitDecision::Exit(r)
            }
            _ => ExitDecision::Continue,
        }
    }

    fn reset(&mut self) {
        for (_, child) in &mut self.children {
            child.reset();
        }
        self.latched.fill(None);
    }

    fn needs(&self) -> SignalNeeds {
        union_needs(self.children.iter().map(|(_, c)| c.needs()))
    }

    fn stability(&self) -> Option<f64> {
        let mut wsum = 0.0;
        let mut acc = 0.0;
        for ((w, child), latch) in self.children.iter().zip(&self.latched) {
            let s = if latch.is_some() {
                Some(1.0)
            } else {
                child.stability()
            };
            if let Some(s) = s {
                wsum += w;
                acc += w * s;
            }
        }
        if wsum > 0.0 {
            Some(acc / wsum)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exit::{ConfidencePolicy, EatPolicy, UniqueAnswersPolicy};

    /// Deterministic stub: exits with `reason` at the `at`-th observed
    /// line, and reports a fixed stability.
    struct ExitAtLine {
        at: usize,
        reason: ExitReason,
        stab: Option<f64>,
        seen: usize,
    }

    impl ExitAtLine {
        fn new(at: usize, reason: ExitReason) -> Box<ExitAtLine> {
            Box::new(ExitAtLine {
                at,
                reason,
                stab: None,
                seen: 0,
            })
        }

        fn with_stability(at: usize, reason: ExitReason, stab: f64) -> Box<ExitAtLine> {
            Box::new(ExitAtLine {
                at,
                reason,
                stab: Some(stab),
                seen: 0,
            })
        }
    }

    impl ExitPolicy for ExitAtLine {
        fn name(&self) -> String {
            format!("stub(at={})", self.at)
        }

        fn observe(&mut self, _obs: &LineObs) -> ExitDecision {
            self.seen += 1;
            if self.seen >= self.at {
                ExitDecision::Exit(self.reason)
            } else {
                ExitDecision::Continue
            }
        }

        fn reset(&mut self) {
            self.seen = 0;
        }

        fn stability(&self) -> Option<f64> {
            self.stab
        }
    }

    fn line(tokens: usize) -> LineObs {
        LineObs {
            tokens,
            eat: Some(1.0),
            unique_answers: Some(5),
            confidence: Some(0.4),
            ..Default::default()
        }
    }

    #[test]
    fn all_of_waits_for_every_child() {
        let mut p = AllOf::new(vec![
            ExitAtLine::new(2, ExitReason::Stable) as Box<dyn ExitPolicy>,
            ExitAtLine::new(5, ExitReason::AnswersConverged),
        ]);
        for i in 1..5 {
            assert_eq!(p.observe(&line(i * 3)), ExitDecision::Continue, "line {i}");
        }
        // the binding vote is the child that completed the conjunction
        assert_eq!(
            p.observe(&line(15)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn any_of_exits_on_first_child() {
        let mut p = AnyOf::new(vec![
            ExitAtLine::new(9, ExitReason::Stable) as Box<dyn ExitPolicy>,
            ExitAtLine::new(3, ExitReason::AnswersConverged),
        ]);
        assert_eq!(p.observe(&line(3)), ExitDecision::Continue);
        assert_eq!(p.observe(&line(6)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&line(9)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn weighted_quorum_counts_latched_weight() {
        // weights 2+1+1; quorum 0.5 needs latched weight >= 2
        let mut p = WeightedEnsemble::new(
            vec![
                (2.0, ExitAtLine::new(5, ExitReason::Stable) as Box<dyn ExitPolicy>),
                (1.0, ExitAtLine::new(2, ExitReason::AnswersConverged)),
                (1.0, ExitAtLine::new(9, ExitReason::Stalled)),
            ],
            0.5,
        );
        for i in 1..5 {
            assert_eq!(p.observe(&line(i * 3)), ExitDecision::Continue, "line {i}");
        }
        // line 5: the weight-2 child latches, total 3/4 >= 0.5 — its vote binds
        assert_eq!(p.observe(&line(15)), ExitDecision::Exit(ExitReason::Stable));
    }

    #[test]
    fn quorum_one_is_conjunction() {
        let mut p = WeightedEnsemble::new(
            vec![
                (1.0, ExitAtLine::new(1, ExitReason::Stable) as Box<dyn ExitPolicy>),
                (3.0, ExitAtLine::new(4, ExitReason::Stalled)),
            ],
            1.0,
        );
        for i in 1..4 {
            assert_eq!(p.observe(&line(i * 3)), ExitDecision::Continue, "line {i}");
        }
        assert_eq!(p.observe(&line(12)), ExitDecision::Exit(ExitReason::Stalled));
    }

    #[test]
    #[should_panic]
    fn rejects_empty_ensemble() {
        AllOf::new(vec![]);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_quorum() {
        WeightedEnsemble::new(
            vec![(1.0, ExitAtLine::new(1, ExitReason::Stable) as Box<dyn ExitPolicy>)],
            0.0,
        );
    }

    #[test]
    fn needs_is_the_union_of_children() {
        let p = AllOf::new(vec![
            Box::new(EatPolicy::new(0.2, 1e-3, 96)) as Box<dyn ExitPolicy>,
            Box::new(UniqueAnswersPolicy::with_stride(16, 1, 96, 2)),
            Box::new(ConfidencePolicy::new(0.2, 1e-3, 96)),
        ]);
        let n = p.needs();
        assert!(n.eat && n.confidence);
        assert_eq!(n.rollouts_k, 16);
        assert_eq!(n.rollout_every, 2);
    }

    #[test]
    fn mixed_strides_union_by_gcd() {
        // strides 2 and 3: rollouts must be available on lines 2,3,4,6...
        // — every multiple of gcd(2,3)=1
        let p = AnyOf::new(vec![
            Box::new(UniqueAnswersPolicy::with_stride(8, 1, 96, 2)) as Box<dyn ExitPolicy>,
            Box::new(UniqueAnswersPolicy::with_stride(4, 1, 96, 3)),
        ]);
        let n = p.needs();
        assert_eq!(n.rollouts_k, 8);
        assert_eq!(n.rollout_every, 1);
    }

    #[test]
    fn reset_clears_latches_and_children() {
        let mut p = AllOf::new(vec![
            ExitAtLine::new(1, ExitReason::Stable) as Box<dyn ExitPolicy>,
            ExitAtLine::new(3, ExitReason::Stalled),
        ]);
        assert_eq!(p.observe(&line(3)), ExitDecision::Continue); // child 0 latches
        p.reset();
        // after reset the conjunction must again wait for BOTH children
        assert_eq!(p.observe(&line(3)), ExitDecision::Continue);
        assert_eq!(p.observe(&line(6)), ExitDecision::Continue);
        assert!(p.observe(&line(9)).is_exit());
    }

    #[test]
    fn stability_min_max_and_latched_as_one() {
        let all = AllOf::new(vec![
            ExitAtLine::with_stability(99, ExitReason::Stable, 0.3) as Box<dyn ExitPolicy>,
            ExitAtLine::with_stability(99, ExitReason::Stable, 0.8),
        ]);
        assert_eq!(all.stability(), Some(0.3), "conjunction reports its furthest member");
        let any = AnyOf::new(vec![
            ExitAtLine::with_stability(99, ExitReason::Stable, 0.3) as Box<dyn ExitPolicy>,
            ExitAtLine::with_stability(99, ExitReason::Stable, 0.8),
        ]);
        assert_eq!(any.stability(), Some(0.8), "disjunction reports its closest member");
        // a latched child counts as 1.0, not its live stability
        let mut latched = AllOf::new(vec![
            ExitAtLine::with_stability(1, ExitReason::Stable, 0.1) as Box<dyn ExitPolicy>,
            ExitAtLine::with_stability(99, ExitReason::Stable, 0.6),
        ]);
        latched.observe(&line(3));
        assert_eq!(latched.stability(), Some(0.6));
        // children without a signal are skipped; none reporting -> None
        let dark = AllOf::new(vec![ExitAtLine::new(99, ExitReason::Stable) as Box<dyn ExitPolicy>]);
        assert_eq!(dark.stability(), None);
        // weighted mean over reporting children
        let vote = WeightedEnsemble::new(
            vec![
                (3.0, ExitAtLine::with_stability(99, ExitReason::Stable, 1.0) as Box<dyn ExitPolicy>),
                (1.0, ExitAtLine::with_stability(99, ExitReason::Stable, 0.0)),
                (1.0, ExitAtLine::new(99, ExitReason::Stable)),
            ],
            0.5,
        );
        assert_eq!(vote.stability(), Some(0.75));
    }

    #[test]
    fn names_render_the_composition() {
        let p = WeightedEnsemble::new(
            vec![(2.0, Box::new(EatPolicy::new(0.2, 1e-3, 96)) as Box<dyn ExitPolicy>)],
            0.5,
        );
        assert!(p.name().starts_with("vote(q=0.5; 2*eat("));
        let a = AllOf::new(vec![Box::new(EatPolicy::new(0.2, 1e-3, 96)) as Box<dyn ExitPolicy>]);
        assert!(a.name().starts_with("all(eat("));
    }
}
