//! Cumulative-entropy regulator (arxiv 2510.02249): budget the *total*
//! uncertainty a request may spend, not its token count. Two dials:
//!
//!   * a smoothed level rule — exit `Stable` once the de-biased EMA mean
//!     of the EAT signal drops below `level` (the model has become
//!     confident about its answer), and
//!   * an entropy budget — retire the request `Stalled` once the running
//!     sum of EAT over all lines exceeds `budget_nats`: it has already
//!     spent more total uncertainty than a productive trajectory ever
//!     does, so further reasoning is thrash.
//!
//! The entropy budget is the interesting half: unlike a token budget it
//! charges *hard* lines more than easy ones, so a request burning budget
//! on a high-entropy plateau is cut long before an equally-long but
//! confident trajectory would be.
//!
//! NaN contract: a NaN sample poisons both the EMA and the running sum;
//! every comparison is false afterwards and only the token backstop
//! fires — degenerate traces finish, they never panic.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::monitor::EmaVar;

/// Default total-entropy budget (nats). Sized for the synthetic
/// chainsum traces: a productive trajectory spends a few nats per
/// exploration line for a handful of lines; thrash spends hundreds.
pub const DEFAULT_CUM_BUDGET_NATS: f64 = 64.0;

#[derive(Debug, Clone)]
pub struct CumulativeEntropyPolicy {
    /// EMA timescale for the smoothed level rule.
    pub alpha: f64,
    /// Confidence level (nats): exit when the de-biased EMA mean < level.
    pub level: f64,
    /// Total-entropy budget (nats): retire once sum(EAT) >= budget.
    pub budget_nats: f64,
    /// Max thinking tokens T (the universal backstop).
    pub max_tokens: usize,
    ema: EmaVar,
    cum: f64,
}

impl CumulativeEntropyPolicy {
    pub fn new(
        alpha: f64,
        level: f64,
        budget_nats: f64,
        max_tokens: usize,
    ) -> CumulativeEntropyPolicy {
        CumulativeEntropyPolicy {
            alpha,
            level,
            budget_nats,
            max_tokens,
            ema: EmaVar::new(alpha),
            cum: 0.0,
        }
    }

    /// Total entropy spent so far (nats).
    pub fn spent(&self) -> f64 {
        self.cum
    }
}

impl ExitPolicy for CumulativeEntropyPolicy {
    fn name(&self) -> String {
        format!(
            "cum-entropy(alpha={},level={:.3e},B={},T={})",
            self.alpha, self.level, self.budget_nats, self.max_tokens
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        let eat = obs
            .eat
            .expect("CumulativeEntropyPolicy requires the EAT signal (needs().eat)");
        self.cum += eat;
        self.ema.update(eat);
        if self.ema.debiased_mean() < self.level {
            return ExitDecision::Exit(ExitReason::Stable);
        }
        if self.cum >= self.budget_nats {
            return ExitDecision::Exit(ExitReason::Stalled);
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.ema = EmaVar::new(self.alpha);
        self.cum = 0.0;
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            eat: true,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.ema.count() == 0 {
            return None;
        }
        Some(super::stability_from_vhat(
            self.ema.debiased_mean(),
            self.level,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, eat: f64) -> LineObs {
        LineObs {
            tokens,
            eat: Some(eat),
            ..Default::default()
        }
    }

    #[test]
    fn exits_stable_when_smoothed_entropy_drops() {
        let mut p = CumulativeEntropyPolicy::new(0.3, 0.1, 1e9, 10_000);
        for i in 0..5 {
            assert_eq!(p.observe(&obs(i * 3, 2.0)), ExitDecision::Continue);
        }
        let mut exited = false;
        for i in 5..60 {
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, 0.01)) {
                assert_eq!(r, ExitReason::Stable);
                exited = true;
                break;
            }
        }
        assert!(exited);
    }

    #[test]
    fn entropy_budget_retires_thrashing_requests() {
        // a high-entropy plateau never satisfies the level rule but burns
        // through the nat budget: 3 nats/line against a 10-nat budget
        let mut p = CumulativeEntropyPolicy::new(0.3, 1e-6, 10.0, 10_000);
        assert_eq!(p.observe(&obs(3, 3.0)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(6, 3.0)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(9, 3.0)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(12, 3.0)),
            ExitDecision::Exit(ExitReason::Stalled)
        );
        assert!(p.spent() >= 10.0);
    }

    #[test]
    fn confident_lines_charge_less_than_hard_ones() {
        // same line count, lower entropy: the confident trajectory has
        // spent far less of its budget
        let mut hard = CumulativeEntropyPolicy::new(0.3, 1e-9, 1e9, 10_000);
        let mut easy = CumulativeEntropyPolicy::new(0.3, 1e-9, 1e9, 10_000);
        for i in 0..10 {
            hard.observe(&obs(i * 3, 3.0));
            easy.observe(&obs(i * 3, 0.3));
        }
        assert!(easy.spent() < hard.spent() / 5.0);
    }

    #[test]
    fn budget_backstop() {
        let mut p = CumulativeEntropyPolicy::new(0.3, 1e-12, 1e9, 6);
        assert_eq!(p.observe(&obs(3, 2.0)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(6, 2.0)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn self_termination_wins() {
        let mut p = CumulativeEntropyPolicy::new(0.3, 0.1, 10.0, 1000);
        let d = p.observe(&LineObs {
            tokens: 3,
            eat: Some(2.0),
            self_terminated: true,
            ..Default::default()
        });
        assert_eq!(d, ExitDecision::Exit(ExitReason::SelfTerminated));
    }

    #[test]
    fn nan_sample_disables_adaptive_exits_not_the_backstop() {
        let mut p = CumulativeEntropyPolicy::new(0.3, 10.0, 1.0, 9);
        p.observe(&obs(3, f64::NAN));
        // both the level rule and the nat budget are poisoned...
        assert_eq!(p.observe(&obs(6, 0.01)), ExitDecision::Continue);
        // ...but the token backstop still fires
        assert_eq!(
            p.observe(&obs(9, 0.01)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn reset_clears_state() {
        let mut p = CumulativeEntropyPolicy::new(0.3, 0.1, 10.0, 1000);
        for i in 0..3 {
            p.observe(&obs(i * 3, 2.0));
        }
        assert!(p.spent() > 0.0);
        p.reset();
        assert_eq!(p.spent(), 0.0);
        assert_eq!(p.stability(), None);
    }

    #[test]
    fn needs_eat_only() {
        let n = CumulativeEntropyPolicy::new(0.3, 0.1, 10.0, 10).needs();
        assert!(n.eat && !n.confidence && n.rollouts_k == 0);
    }
}
