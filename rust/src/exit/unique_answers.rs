//! Alg. 3: #UA@K — early exit when the number of distinct answers among K
//! sampled rollouts drops to Delta. Adaptive like EAT, but each evaluation
//! costs K full answer rollouts (the paper's Fig. 6 cost critique).

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};

#[derive(Debug, Clone, Copy)]
pub struct UniqueAnswersPolicy {
    /// Number of rollouts K per evaluation.
    pub k: usize,
    /// Unique-answer threshold Delta (exit when #UA <= Delta).
    pub threshold: usize,
    /// Max thinking tokens T.
    pub max_tokens: usize,
    /// Evaluate only every `every` lines (Fig. 19's budget-matched mode;
    /// 1 = every line as in Alg. 3).
    pub every: usize,
    seen_lines: usize,
}

impl UniqueAnswersPolicy {
    pub fn new(k: usize, threshold: usize, max_tokens: usize) -> Self {
        Self::with_stride(k, threshold, max_tokens, 1)
    }

    pub fn with_stride(
        k: usize,
        threshold: usize,
        max_tokens: usize,
        every: usize,
    ) -> Self {
        assert!(k > 0 && threshold >= 1 && every >= 1);
        UniqueAnswersPolicy {
            k,
            threshold,
            max_tokens,
            every,
            seen_lines: 0,
        }
    }

    /// Does this policy evaluate rollouts at the current line?
    pub fn evaluates_now(&self) -> bool {
        (self.seen_lines + 1) % self.every == 0
    }
}

impl ExitPolicy for UniqueAnswersPolicy {
    fn name(&self) -> String {
        format!(
            "ua(K={},Delta={},T={},every={})",
            self.k, self.threshold, self.max_tokens, self.every
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        self.seen_lines += 1;
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        if self.seen_lines % self.every == 0 {
            let ua = obs
                .unique_answers
                .expect("UniqueAnswersPolicy requires rollouts");
            if ua <= self.threshold {
                return ExitDecision::Exit(ExitReason::AnswersConverged);
            }
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.seen_lines = 0;
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            rollouts_k: self.k,
            rollout_every: self.every,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, ua: usize) -> LineObs {
        LineObs {
            tokens,
            unique_answers: Some(ua),
            ..Default::default()
        }
    }

    #[test]
    fn exits_when_answers_converge() {
        let mut p = UniqueAnswersPolicy::new(16, 1, 1000);
        assert_eq!(p.observe(&obs(3, 9)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(6, 3)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(9, 1)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn threshold_two() {
        let mut p = UniqueAnswersPolicy::new(16, 2, 1000);
        assert_eq!(
            p.observe(&obs(3, 2)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn stride_skips_evaluations() {
        let mut p = UniqueAnswersPolicy::with_stride(32, 1, 1000, 3);
        // lines 1 and 2: no evaluation (unique_answers may be absent)
        assert!(!p.evaluates_now());
        assert_eq!(
            p.observe(&LineObs {
                tokens: 3,
                ..Default::default()
            }),
            ExitDecision::Continue
        );
        assert_eq!(
            p.observe(&LineObs {
                tokens: 6,
                ..Default::default()
            }),
            ExitDecision::Continue
        );
        // line 3: evaluates
        assert!(p.evaluates_now());
        assert_eq!(
            p.observe(&obs(9, 1)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn budget_backstop() {
        let mut p = UniqueAnswersPolicy::new(8, 1, 6);
        assert_eq!(p.observe(&obs(3, 5)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(6, 5)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn needs_k_rollouts() {
        assert_eq!(UniqueAnswersPolicy::new(32, 1, 10).needs().rollouts_k, 32);
    }
}
