//! Stall-detection policy — the paper's §6 future-work extension ("a
//! complementary lower-threshold mechanism that halts when progress
//! stalls, e.g., when EAT's variance decays too slowly"; feasibility shown
//! by the follow-up Wang et al. 2026).
//!
//! The failure mode it fixes: on *unsolvable* questions EAT stays high and
//! noisy, V-hat never crosses delta, and Alg. 1 burns the entire budget
//! (Fig. 14 / App. I.4). `StallAwareEatPolicy` layers two extra rules on
//! top of Alg. 1:
//!
//!  1. **level stall**: the EMA *mean* of EAT has stayed above
//!     `high_level` for `patience` consecutive lines — the model is still
//!     maximally uncertain after substantial reasoning; give up early.
//!  2. **decay stall**: V-hat's relative decay over the last `patience`
//!     lines is below `min_decay` — the variance plateaued far above
//!     delta and will not reach it within the budget; extrapolate and
//!     give up.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::monitor::EmaVar;

#[derive(Debug, Clone)]
pub struct StallAwareEatPolicy {
    pub alpha: f64,
    pub delta: f64,
    pub max_tokens: usize,
    /// EAT level (nats) considered "still fully uncertain". With a
    /// 32-answer space, uniform is log(32) = 3.47.
    pub high_level: f64,
    /// Consecutive stalled lines before giving up.
    pub patience: usize,
    /// Minimum relative V-hat decay per line (e.g. 0.02 = 2%/line).
    pub min_decay: f64,
    ema: EmaVar,
    vhat_history: Vec<f64>,
    high_streak: usize,
    min_lines: usize,
}

impl StallAwareEatPolicy {
    pub fn new(alpha: f64, delta: f64, max_tokens: usize) -> Self {
        StallAwareEatPolicy {
            alpha,
            delta,
            max_tokens,
            high_level: 3.0,
            patience: 8,
            min_decay: 0.01,
            ema: EmaVar::new(alpha),
            vhat_history: Vec::new(),
            high_streak: 0,
            min_lines: 4,
        }
    }
}

impl ExitPolicy for StallAwareEatPolicy {
    fn name(&self) -> String {
        format!(
            "eat-stall(alpha={},delta={:.3e},high={},patience={})",
            self.alpha, self.delta, self.high_level, self.patience
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        let eat = obs.eat.expect("StallAwareEatPolicy requires EAT");
        let vhat = self.ema.update(eat);
        self.vhat_history.push(vhat);
        let lines = self.vhat_history.len();

        // Alg. 1 core rule
        if vhat < self.delta {
            return ExitDecision::Exit(ExitReason::Stable);
        }

        // extension 1: level stall — still maximally uncertain
        if self.ema.mean() >= self.high_level {
            self.high_streak += 1;
        } else {
            self.high_streak = 0;
        }
        if lines >= self.min_lines && self.high_streak >= self.patience {
            return ExitDecision::Exit(ExitReason::Stalled);
        }

        // extension 2: decay stall — V-hat plateaued far above delta
        if lines >= self.patience + self.min_lines {
            let past = self.vhat_history[lines - 1 - self.patience];
            let decay_per_line =
                1.0 - (vhat / past.max(1e-300)).powf(1.0 / self.patience as f64);
            if vhat > 100.0 * self.delta && decay_per_line < self.min_decay {
                return ExitDecision::Exit(ExitReason::Stalled);
            }
        }

        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.ema = EmaVar::new(self.alpha);
        self.vhat_history.clear();
        self.high_streak = 0;
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            eat: true,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.ema.count() == 0 {
            return None; // no observation yet — neutral, not stalled
        }
        Some(super::stability_from_vhat(self.ema.debiased_var(), self.delta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn obs(tokens: usize, eat: f64) -> LineObs {
        LineObs {
            tokens,
            eat: Some(eat),
            ..Default::default()
        }
    }

    #[test]
    fn still_exits_stable_on_solvable_signal() {
        let mut p = StallAwareEatPolicy::new(0.5, 1e-2, 10_000);
        let mut decided = None;
        for i in 1..=40 {
            let e = if i < 5 { 3.4 - 0.4 * i as f64 } else { 0.02 };
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, e)) {
                decided = Some((i, r));
                break;
            }
        }
        let (line, reason) = decided.expect("should exit");
        assert_eq!(reason, ExitReason::Stable);
        assert!(line < 25, "line={line}");
    }

    #[test]
    fn gives_up_on_unsolvable_high_plateau() {
        // EAT stuck near log(32): baseline Alg.1 would burn all 10k
        // tokens; the stall rule exits after ~patience lines
        let mut p = StallAwareEatPolicy::new(0.5, 1e-6, 10_000);
        let mut rng = Rng::new(3);
        let mut exit = None;
        for i in 1..=60 {
            let e = 3.3 + 0.15 * rng.normal();
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, e)) {
                exit = Some((i, r));
                break;
            }
        }
        let (line, reason) = exit.expect("must give up");
        assert_eq!(reason, ExitReason::Stalled);
        assert!(line <= 20, "gave up too late: line {line}");
    }

    #[test]
    fn gives_up_on_vhat_plateau() {
        // mid-level noisy EAT (not high enough for the level rule) whose
        // variance never decays: the decay rule fires
        let mut p = StallAwareEatPolicy::new(0.5, 1e-9, 10_000);
        p.high_level = 10.0; // disable the level rule
        let mut rng = Rng::new(4);
        let mut exit = None;
        for i in 1..=200 {
            let e = 1.5 + 0.8 * rng.normal();
            if let ExitDecision::Exit(r) = p.observe(&obs(i * 3, e)) {
                exit = Some((i, r));
                break;
            }
        }
        let (line, reason) = exit.expect("must give up");
        assert_eq!(reason, ExitReason::Stalled);
        assert!(line <= 60, "line={line}");
    }

    #[test]
    fn does_not_stall_while_decaying() {
        // a cleanly decaying variance must NOT trigger the stall rules
        // before the Stable exit
        let mut p = StallAwareEatPolicy::new(0.5, 1e-4, 10_000);
        p.high_level = 10.0;
        for i in 1..=80 {
            let e = 3.0 * (0.8f64).powi(i as i32);
            match p.observe(&obs(i * 3, e)) {
                ExitDecision::Exit(ExitReason::Stable) => return,
                ExitDecision::Exit(r) => panic!("wrong exit {r:?} at {i}"),
                ExitDecision::Continue => {}
            }
        }
        panic!("never exited");
    }

    #[test]
    fn reset_clears_stall_state() {
        let mut p = StallAwareEatPolicy::new(0.5, 1e-6, 10_000);
        for i in 1..=10 {
            p.observe(&obs(i * 3, 3.4));
        }
        p.reset();
        assert_eq!(p.high_streak, 0);
        assert!(p.vhat_history.is_empty());
    }
}
