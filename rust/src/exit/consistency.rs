//! Answer-consistency probe (Dynamic Early Exit, arxiv 2504.15895): at a
//! fixed line stride, roll out K candidate answers and exit once they
//! *stay* unanimous for `patience` consecutive evaluations — consistency
//! sustained over time, not the single-shot #UA@K threshold of Alg. 3.
//! The streak requirement is what distinguishes this probe from
//! [`super::UniqueAnswersPolicy`]: one lucky unanimous evaluation during
//! exploration does not end the request. Cost per evaluation is the same
//! K rollouts, so the zoo's overhead-charged sweep prices both
//! identically.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};

#[derive(Debug, Clone, Copy)]
pub struct AnswerConsistencyPolicy {
    /// Number of rollouts K per evaluation.
    pub k: usize,
    /// Consecutive unanimous evaluations required before exiting.
    pub patience: usize,
    /// Max thinking tokens T.
    pub max_tokens: usize,
    /// Evaluate only every `every` lines (budget-matched sparse mode).
    pub every: usize,
    streak: usize,
    seen_lines: usize,
}

impl AnswerConsistencyPolicy {
    pub fn new(k: usize, patience: usize, max_tokens: usize) -> Self {
        Self::with_stride(k, patience, max_tokens, 1)
    }

    pub fn with_stride(k: usize, patience: usize, max_tokens: usize, every: usize) -> Self {
        assert!(k > 0 && patience >= 1 && every >= 1);
        AnswerConsistencyPolicy {
            k,
            patience,
            max_tokens,
            every,
            streak: 0,
            seen_lines: 0,
        }
    }

    /// Current run of consecutive unanimous evaluations.
    pub fn streak(&self) -> usize {
        self.streak
    }
}

impl ExitPolicy for AnswerConsistencyPolicy {
    fn name(&self) -> String {
        format!(
            "consistency(K={},patience={},T={},every={})",
            self.k, self.patience, self.max_tokens, self.every
        )
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        self.seen_lines += 1;
        if obs.self_terminated {
            return ExitDecision::Exit(ExitReason::SelfTerminated);
        }
        if self.seen_lines % self.every == 0 {
            let ua = obs
                .unique_answers
                .expect("AnswerConsistencyPolicy requires rollouts");
            if ua <= 1 {
                self.streak += 1;
                if self.streak >= self.patience {
                    return ExitDecision::Exit(ExitReason::AnswersConverged);
                }
            } else {
                self.streak = 0;
            }
        }
        if obs.tokens >= self.max_tokens {
            return ExitDecision::Exit(ExitReason::TokenBudget);
        }
        ExitDecision::Continue
    }

    fn reset(&mut self) {
        self.streak = 0;
        self.seen_lines = 0;
    }

    fn needs(&self) -> SignalNeeds {
        SignalNeeds {
            rollouts_k: self.k,
            rollout_every: self.every,
            ..Default::default()
        }
    }

    fn stability(&self) -> Option<f64> {
        if self.seen_lines / self.every == 0 {
            // no evaluation yet: neutral, never preempted
            return None;
        }
        // streak progress toward the patience bar, in (0, 1]
        Some(((self.streak + 1) as f64 / (self.patience + 1) as f64).min(1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(tokens: usize, ua: usize) -> LineObs {
        LineObs {
            tokens,
            unique_answers: Some(ua),
            ..Default::default()
        }
    }

    #[test]
    fn exits_after_sustained_consistency() {
        let mut p = AnswerConsistencyPolicy::new(8, 2, 1000);
        assert_eq!(p.observe(&obs(3, 5)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(6, 1)), ExitDecision::Continue, "streak 1 of 2");
        assert_eq!(
            p.observe(&obs(9, 1)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn disagreement_resets_the_streak() {
        let mut p = AnswerConsistencyPolicy::new(8, 2, 1000);
        p.observe(&obs(3, 1));
        assert_eq!(p.streak(), 1);
        p.observe(&obs(6, 4)); // disagreement: start over
        assert_eq!(p.streak(), 0);
        assert_eq!(p.observe(&obs(9, 1)), ExitDecision::Continue);
        assert!(p.observe(&obs(12, 1)).is_exit());
    }

    #[test]
    fn stride_cannot_exit_before_the_first_evaluation_line() {
        let mut p = AnswerConsistencyPolicy::with_stride(8, 1, 1000, 3);
        // lines 1-2: no evaluation, unanimity invisible
        assert_eq!(p.observe(&obs(3, 1)), ExitDecision::Continue);
        assert_eq!(p.observe(&obs(6, 1)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(9, 1)),
            ExitDecision::Exit(ExitReason::AnswersConverged)
        );
    }

    #[test]
    fn budget_backstop() {
        let mut p = AnswerConsistencyPolicy::new(8, 99, 6);
        assert_eq!(p.observe(&obs(3, 1)), ExitDecision::Continue);
        assert_eq!(
            p.observe(&obs(6, 1)),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn self_termination_wins() {
        let mut p = AnswerConsistencyPolicy::new(8, 1, 1000);
        let d = p.observe(&LineObs {
            tokens: 3,
            unique_answers: Some(1),
            self_terminated: true,
            ..Default::default()
        });
        assert_eq!(d, ExitDecision::Exit(ExitReason::SelfTerminated));
    }

    #[test]
    fn reset_clears_streak_and_stride_phase() {
        let mut p = AnswerConsistencyPolicy::with_stride(8, 2, 1000, 2);
        p.observe(&obs(3, 5));
        p.observe(&obs(6, 1)); // eval line: streak 1
        assert_eq!(p.streak(), 1);
        p.reset();
        assert_eq!(p.streak(), 0);
        assert_eq!(p.stability(), None);
        // stride phase restarted: line 1 is again a non-eval line
        assert_eq!(
            p.observe(&LineObs {
                tokens: 3,
                ..Default::default()
            }),
            ExitDecision::Continue
        );
    }

    #[test]
    fn needs_k_rollouts_at_stride() {
        let n = AnswerConsistencyPolicy::with_stride(16, 2, 10, 4).needs();
        assert_eq!(n.rollouts_k, 16);
        assert_eq!(n.rollout_every, 4);
        assert!(!n.eat && !n.confidence);
    }

    #[test]
    fn stability_tracks_streak_progress() {
        let mut p = AnswerConsistencyPolicy::new(8, 3, 10_000);
        assert_eq!(p.stability(), None);
        p.observe(&obs(3, 9));
        let cold = p.stability().unwrap();
        p.observe(&obs(6, 1));
        p.observe(&obs(9, 1));
        let warm = p.stability().unwrap();
        assert!(warm > cold, "{cold} -> {warm}");
        assert!(warm <= 1.0);
    }
}
