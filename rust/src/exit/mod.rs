//! Early-exit policies: the paper's contribution (EAT, Alg. 1), every
//! baseline it compares against (token budget Alg. 2, #UA@K Alg. 3,
//! confidence Eq. 16), and the wider stopping-rule zoo from the related
//! literature (DESIGN.md §3.9): reasoning-path deviation (arxiv
//! 2603.14251), sequence-level entropy (arxiv 2510.08146),
//! answer-consistency probing (arxiv 2504.15895), cumulative-entropy
//! regulation (arxiv 2510.02249), plus [`AllOf`]/[`AnyOf`]/
//! [`WeightedEnsemble`] combinators that compose any of them.
//!
//! A policy is a pure state machine over per-line observations, so the
//! same implementation runs both *online* in the serving engine and
//! *offline* in the replay harness (paper App. H simulated early exiting).

pub mod combinators;
pub mod confidence;
pub mod consistency;
pub mod cumulative;
pub mod eat;
pub mod path_deviation;
pub mod seq_entropy;
pub mod stall;
pub mod token_budget;
pub mod unique_answers;

pub use combinators::{AllOf, AnyOf, WeightedEnsemble};
pub use confidence::ConfidencePolicy;
pub use consistency::AnswerConsistencyPolicy;
pub use cumulative::{CumulativeEntropyPolicy, DEFAULT_CUM_BUDGET_NATS};
pub use eat::EatPolicy;
pub use path_deviation::PathDeviationPolicy;
pub use seq_entropy::SequenceEntropyPolicy;
pub use stall::StallAwareEatPolicy;
pub use token_budget::TokenBudgetPolicy;
pub use unique_answers::UniqueAnswersPolicy;

/// What a policy sees at each reasoning-line boundary. Fields are optional
/// because different policies consume different (and differently-priced)
/// signals; the engine only computes what the active policy needs.
#[derive(Debug, Clone, Copy, Default)]
pub struct LineObs {
    /// Total reasoning tokens committed so far (|R| in Alg. 1).
    pub tokens: usize,
    /// EAT value (Eq. 5) for this line, if probed.
    pub eat: Option<f64>,
    /// Number of unique answers among K rollouts, if rolled out.
    pub unique_answers: Option<usize>,
    /// Confidence score (Eq. 16), if rolled out.
    pub confidence: Option<f64>,
    /// The model generated `</think>` by itself.
    pub self_terminated: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitReason {
    /// Signal stabilized (V' < delta) — the adaptive exit.
    Stable,
    /// Fixed token budget T exhausted.
    TokenBudget,
    /// The model ended its own reasoning with `</think>`.
    SelfTerminated,
    /// #UA@K dropped to the Delta threshold (Alg. 3 line 7).
    AnswersConverged,
    /// Progress stalled (§6 extension): EAT stuck high or V-hat decaying
    /// too slowly to ever reach delta — give up instead of burning budget.
    Stalled,
    /// Load-shed under saturation (DESIGN.md §3.11): the coordinator
    /// force-exited this session — nearest-to-exit first, by
    /// `ExitPolicy::stability` — to free KV pages for waiting arrivals.
    Shed,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitDecision {
    Continue,
    Exit(ExitReason),
}

impl ExitDecision {
    pub fn is_exit(&self) -> bool {
        matches!(self, ExitDecision::Exit(_))
    }
}

/// The scheduler's urgency mapping (DESIGN.md §3.4), shared by every
/// EMA-variance policy: the log-distance of V-hat to the exit threshold
/// `delta`, mapped into (0, 1] — 1.0 at/below the threshold (exit
/// imminent), → 0 as V-hat grows away from it. A non-finite V-hat (no
/// observation yet) maps to 0.0: no evidence of progress.
pub fn stability_from_vhat(vhat: f64, delta: f64) -> f64 {
    if !vhat.is_finite() {
        return 0.0;
    }
    1.0 / (1.0 + (vhat / delta).max(1.0).ln())
}

/// An early-exit policy.
pub trait ExitPolicy {
    /// Human-readable name for reports.
    fn name(&self) -> String;
    /// Observe one reasoning-line boundary and decide.
    fn observe(&mut self, obs: &LineObs) -> ExitDecision;
    /// Reset per-request state (policies are reused across requests).
    fn reset(&mut self);
    /// Which signals this policy needs the engine to compute.
    fn needs(&self) -> SignalNeeds {
        SignalNeeds::default()
    }

    /// Scheduler hint (DESIGN.md §3.4): how close the policy's adaptive
    /// signal is to its exit threshold, mapped into (0, 1]. 1.0 means the
    /// exit is imminent (the scheduler drives such sessions to
    /// completion); values near 0 mean the monitored variance sits far
    /// above the threshold — a stalled request, the preemption candidate.
    /// `None` for policies without an adaptive signal (fixed budgets)
    /// and *before the first observation* — "no data" is not "no
    /// progress" — which the scheduler treats as neutral (never
    /// preempted).
    fn stability(&self) -> Option<f64> {
        None
    }
}

/// Signal requirements, so the engine can skip expensive probes/rollouts
/// the active policy does not use (the crux of the paper's cost analysis:
/// EAT needs one probe, #UA@K needs K rollouts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SignalNeeds {
    pub eat: bool,
    pub rollouts_k: usize,
    /// Rollouts are evaluated only every `rollout_every` lines (Fig. 19's
    /// budget-matched sparse evaluation; 1 = every line as in Alg. 3).
    pub rollout_every: usize,
    pub confidence: bool,
}

impl Default for SignalNeeds {
    fn default() -> Self {
        SignalNeeds {
            eat: false,
            rollouts_k: 0,
            rollout_every: 1,
            confidence: false,
        }
    }
}

impl SignalNeeds {
    /// Combine two requirement sets — what a combinator's `needs()` must
    /// report so the engine computes every signal any child consumes.
    /// Booleans and K union upward; rollout strides combine by **gcd**,
    /// because a child with stride `s` evaluates on lines that are
    /// multiples of `s`, and every such line is a multiple of the gcd —
    /// the engine's single stride must serve all children's evaluation
    /// lines. A side with no rollouts contributes no stride constraint.
    pub fn union(self, other: SignalNeeds) -> SignalNeeds {
        fn gcd(a: usize, b: usize) -> usize {
            if b == 0 {
                a
            } else {
                gcd(b, a % b)
            }
        }
        let rollout_every = match (self.rollouts_k > 0, other.rollouts_k > 0) {
            (true, true) => gcd(self.rollout_every, other.rollout_every).max(1),
            (true, false) => self.rollout_every,
            (false, _) => other.rollout_every,
        };
        SignalNeeds {
            eat: self.eat || other.eat,
            rollouts_k: self.rollouts_k.max(other.rollouts_k),
            rollout_every,
            confidence: self.confidence || other.confidence,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_helpers() {
        assert!(!ExitDecision::Continue.is_exit());
        assert!(ExitDecision::Exit(ExitReason::Stable).is_exit());
    }

    #[test]
    fn needs_union_folds_signals_and_strides() {
        let eat = SignalNeeds {
            eat: true,
            ..Default::default()
        };
        let conf = SignalNeeds {
            confidence: true,
            ..Default::default()
        };
        let u = eat.union(conf);
        assert!(u.eat && u.confidence && u.rollouts_k == 0);

        // strides: gcd when both sides roll out, pass-through otherwise
        let ua6 = SignalNeeds {
            rollouts_k: 8,
            rollout_every: 6,
            ..Default::default()
        };
        let ua4 = SignalNeeds {
            rollouts_k: 16,
            rollout_every: 4,
            ..Default::default()
        };
        let both = ua6.union(ua4);
        assert_eq!(both.rollouts_k, 16);
        assert_eq!(both.rollout_every, 2, "gcd(6,4)");
        let one_sided = ua6.union(eat);
        assert_eq!(one_sided.rollout_every, 6, "a rollout-free side adds no constraint");
        assert_eq!(eat.union(ua4).rollout_every, 4);
        // union with the default is the identity
        assert_eq!(ua6.union(SignalNeeds::default()), ua6);
    }

    #[test]
    fn stability_mapping_bounds_and_monotonicity() {
        let d = 1e-3;
        assert_eq!(stability_from_vhat(f64::INFINITY, d), 0.0);
        assert_eq!(stability_from_vhat(d / 10.0, d), 1.0, "below threshold clamps to 1");
        let near = stability_from_vhat(2.0 * d, d);
        let far = stability_from_vhat(1e4 * d, d);
        assert!(near > far, "closer to the threshold must rank more stable");
        assert!(far > 0.0 && near < 1.0);
    }
}
