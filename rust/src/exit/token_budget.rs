//! Alg. 2: token-based early exiting — the fixed-budget baseline with "a
//! clear physical meaning" (§5.2) but no adaptivity.

use super::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};

#[derive(Debug, Clone, Copy)]
pub struct TokenBudgetPolicy {
    /// Per-question reasoning budget T.
    pub t: usize,
}

impl TokenBudgetPolicy {
    pub fn new(t: usize) -> TokenBudgetPolicy {
        TokenBudgetPolicy { t }
    }
}

impl ExitPolicy for TokenBudgetPolicy {
    fn name(&self) -> String {
        format!("token(T={})", self.t)
    }

    fn observe(&mut self, obs: &LineObs) -> ExitDecision {
        if obs.self_terminated {
            ExitDecision::Exit(ExitReason::SelfTerminated)
        } else if obs.tokens >= self.t {
            ExitDecision::Exit(ExitReason::TokenBudget)
        } else {
            ExitDecision::Continue
        }
    }

    fn reset(&mut self) {}

    fn needs(&self) -> SignalNeeds {
        SignalNeeds::default() // free: consumes no model signals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exits_exactly_at_budget() {
        let mut p = TokenBudgetPolicy::new(10);
        assert_eq!(
            p.observe(&LineObs {
                tokens: 9,
                ..Default::default()
            }),
            ExitDecision::Continue
        );
        assert_eq!(
            p.observe(&LineObs {
                tokens: 10,
                ..Default::default()
            }),
            ExitDecision::Exit(ExitReason::TokenBudget)
        );
    }

    #[test]
    fn self_termination() {
        let mut p = TokenBudgetPolicy::new(1000);
        let d = p.observe(&LineObs {
            tokens: 5,
            self_terminated: true,
            ..Default::default()
        });
        assert_eq!(d, ExitDecision::Exit(ExitReason::SelfTerminated));
    }

    #[test]
    fn needs_nothing() {
        assert_eq!(TokenBudgetPolicy::new(5).needs(), SignalNeeds::default());
    }
}
