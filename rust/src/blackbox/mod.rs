//! Black-box setting (paper §5.3, Fig. 5, App. I.7): early-stopping an API
//! reasoning model whose logits are NOT accessible, using a small local
//! proxy that computes EAT from the verbal reasoning stream alone.
//!
//! `StreamingApi` simulates the remote service (stands in for Claude 3.7
//! via OpenRouter): it serves the *main* model behind an interface that
//! only exposes reasoning text in chunks, with a configurable latency
//! model (the paper observed ~5 tokens/block, chunks of 20 blocks). The
//! `ProxyMonitor` consumes chunks, maintains its own KV cache, probes EAT
//! per chunk, and issues the stop decision. Proxy compute per chunk is
//! measured against the simulated chunk inter-arrival time to reproduce
//! Fig. 5b's "overlapped, no wall-clock overhead" claim.

use std::time::Instant;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::datasets::{check_answer, Question};
use crate::monitor::EmaVar;
use crate::runtime::{Backend, BackendCache, Runtime};
use crate::sampler::Sampler;
use crate::util::rng::Rng;

/// Latency model of the remote streaming API.
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Fixed per-chunk overhead (network + service), ms.
    pub base_ms: f64,
    /// Per-token generation latency of the remote model, ms.
    pub per_token_ms: f64,
    /// Multiplicative jitter amplitude (0.1 = +-10%).
    pub jitter: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        // Claude-3.7-over-OpenRouter ballpark scaled to our trace lengths:
        // ~40ms/token streaming + 60ms chunk overhead.
        LatencyModel {
            base_ms: 60.0,
            per_token_ms: 40.0,
            jitter: 0.15,
        }
    }
}

impl LatencyModel {
    pub fn chunk_ms(&self, tokens: usize, rng: &mut Rng) -> f64 {
        let jit = 1.0 + self.jitter * (2.0 * rng.f64() - 1.0);
        (self.base_ms + self.per_token_ms * tokens as f64) * jit
    }
}

/// One delivered chunk of reasoning text.
#[derive(Debug, Clone)]
pub struct Chunk {
    pub tokens: Vec<u32>,
    /// Simulated arrival timestamp (ms since request start).
    pub sim_arrival_ms: f64,
    /// The remote model ended its reasoning inside this chunk.
    pub finished: bool,
}

/// The simulated remote reasoning service. Internally drives the main
/// model; externally exposes only token text — no logits.
pub struct StreamingApi<'a> {
    rt: &'a Runtime,
    cache: BackendCache,
    cur_logits: Vec<f32>,
    sampler: Sampler,
    rng: Rng,
    latency: LatencyModel,
    pub chunk_tokens: usize,
    sim_clock_ms: f64,
    produced: usize,
    max_tokens: usize,
    finished: bool,
}

impl<'a> StreamingApi<'a> {
    pub fn start(
        rt: &'a Runtime,
        cfg: &ServeConfig,
        question: &Question,
        latency: LatencyModel,
        chunk_tokens: usize,
        seed: u64,
    ) -> Result<StreamingApi<'a>> {
        let mut prompt = question.prompt.clone();
        prompt.push(rt.vocab.think);
        let (logits, cache) = rt.main.prefill(&prompt)?;
        Ok(StreamingApi {
            rt,
            cache,
            cur_logits: logits,
            sampler: Sampler::new(cfg.temperature, cfg.top_p),
            rng: Rng::new(seed ^ 0xB1ACB0),
            latency,
            chunk_tokens,
            sim_clock_ms: 0.0,
            produced: 0,
            max_tokens: cfg.max_think_tokens,
            finished: false,
        })
    }

    /// Generate and "deliver" the next chunk of reasoning tokens.
    pub fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        if self.finished {
            return Ok(None);
        }
        let vocab = self.rt.vocab;
        let mut tokens = Vec::new();
        while tokens.len() < self.chunk_tokens {
            // keep headroom for finalize()'s forced tail + sampled answer
            if self.produced >= self.max_tokens
                || self.cache.pos() + vocab.answer_reserve() + 1 >= self.rt.main.seq_len()
            {
                self.finished = true;
                break;
            }
            let t = self.sampler.sample(&self.cur_logits, &mut self.rng);
            if t == vocab.ethink {
                self.finished = true;
                break;
            }
            self.cur_logits = self.rt.main.decode(&mut self.cache, t)?;
            tokens.push(t);
            self.produced += 1;
        }
        self.sim_clock_ms += self.latency.chunk_ms(tokens.len().max(1), &mut self.rng);
        Ok(Some(Chunk {
            tokens,
            sim_arrival_ms: self.sim_clock_ms,
            finished: self.finished,
        }))
    }

    /// Cancel reasoning and ask the service for its final answer (the
    /// paper force-appends `</think>` + answer-inducing text server-side).
    pub fn finalize(mut self) -> Result<Vec<u32>> {
        let vocab = self.rt.vocab;
        let mut tail = Vec::new();
        let mut logits = self.cur_logits.clone();
        for &t in &vocab.forced_answer_tail() {
            if self.cache.pos() >= self.rt.main.seq_len() {
                break;
            }
            logits = self.rt.main.decode(&mut self.cache, t)?;
            tail.push(t);
        }
        for _ in 0..crate::vocab::ANSWER_SAMPLE_CAP {
            if self.cache.pos() >= self.rt.main.seq_len() {
                break;
            }
            let t = self.sampler.sample(&logits, &mut self.rng);
            tail.push(t);
            if t == vocab.eos {
                break;
            }
            logits = self.rt.main.decode(&mut self.cache, t)?;
        }
        Ok(tail)
    }

    pub fn tokens_produced(&self) -> usize {
        self.produced
    }

    pub fn sim_clock_ms(&self) -> f64 {
        self.sim_clock_ms
    }
}

/// Per-chunk monitor record (Fig. 5 / Fig. 18 data).
#[derive(Debug, Clone)]
pub struct ChunkPoint {
    pub chunk: usize,
    pub tokens_seen: usize,
    pub eat: f64,
    pub vhat: f64,
    /// Simulated arrival gap since the previous chunk, ms.
    pub arrival_gap_ms: f64,
    /// Measured local proxy compute (decode chunk + probe), ms.
    pub proxy_compute_ms: f64,
}

#[derive(Debug, Clone)]
pub struct BlackboxResult {
    pub question_id: usize,
    pub points: Vec<ChunkPoint>,
    /// Chunk index where the monitor stopped the stream (None = ran out).
    pub stop_chunk: Option<usize>,
    pub tokens_at_stop: usize,
    pub total_tokens_available: usize,
    /// Simulated remote time saved by stopping early, ms.
    pub saved_ms: f64,
    pub answer_tail: Vec<u32>,
    pub correct: bool,
}

/// Run the full black-box pipeline on one question: stream chunks from the
/// "remote" service, monitor EAT with the local proxy, stop when the EMA
/// variance drops below delta, then ask the service to finalize.
pub fn run_blackbox(
    rt: &Runtime,
    cfg: &ServeConfig,
    question: &Question,
    latency: LatencyModel,
    chunk_tokens: usize,
    seed: u64,
) -> Result<BlackboxResult> {
    let mut api = StreamingApi::start(rt, cfg, question, latency, chunk_tokens, seed)?;

    // local proxy: own cache over the same visible prompt
    let mut prompt = question.prompt.clone();
    prompt.push(rt.vocab.think);
    let (_lg, mut proxy_cache) = rt.proxy.prefill(&prompt)?;
    let suffix = rt.vocab.suffix_prefixed();
    let mut ema = EmaVar::new(cfg.alpha);

    let mut points = Vec::new();
    let mut stop_chunk = None;
    let mut tokens_seen = 0usize;
    let mut prev_arrival = 0.0f64;
    let mut chunk_idx = 0usize;

    while let Some(chunk) = api.next_chunk()? {
        chunk_idx += 1;
        let t0 = Instant::now();
        // Probe at the last *complete* reasoning line inside the chunk:
        // chunks are fixed-size token windows and generally end mid-line;
        // probing there makes EAT needlessly noisy (the distribution after
        // a half-written line is ill-posed). Feed up to the last newline,
        // probe, then feed the remainder. Chunks without a newline carry
        // the previous EMA state forward (no probe).
        let nl_pos = chunk
            .tokens
            .iter()
            .rposition(|&t| t == rt.vocab.nl);
        let (head, tail) = match nl_pos {
            Some(i) => chunk.tokens.split_at(i + 1),
            None => (&[][..], &chunk.tokens[..]),
        };
        for &t in head {
            rt.proxy.decode(&mut proxy_cache, t)?;
        }
        let probed = if !head.is_empty() || chunk.finished {
            let (eat, _) = rt.proxy.probe(&proxy_cache, &suffix)?;
            Some(eat as f64)
        } else {
            None
        };
        for &t in tail {
            rt.proxy.decode(&mut proxy_cache, t)?;
        }
        tokens_seen += chunk.tokens.len();
        let Some(eat) = probed else {
            prev_arrival = chunk.sim_arrival_ms;
            if chunk.finished {
                break;
            }
            continue;
        };
        let vhat = ema.update(eat);
        let proxy_compute_ms = t0.elapsed().as_secs_f64() * 1e3;
        points.push(ChunkPoint {
            chunk: chunk_idx,
            tokens_seen,
            eat,
            vhat,
            arrival_gap_ms: chunk.sim_arrival_ms - prev_arrival,
            proxy_compute_ms,
        });
        prev_arrival = chunk.sim_arrival_ms;
        if vhat < cfg.delta {
            stop_chunk = Some(chunk_idx);
            break;
        }
        if chunk.finished {
            break;
        }
    }

    // Estimate remote tokens remaining had we not stopped: generate the
    // counterfactual by noting the remote budget. (The simulated service
    // would have continued to max_think_tokens or self-termination; we
    // charge the conservative budget bound, as the paper's "saved at least
    // one minute" phrasing does.)
    let total_available = cfg.max_think_tokens;
    let tokens_at_stop = tokens_seen;
    let saved_tokens = total_available.saturating_sub(tokens_at_stop);
    let saved_ms = if stop_chunk.is_some() {
        saved_tokens as f64 * latency.per_token_ms
    } else {
        0.0
    };

    let answer_tail = api.finalize()?;
    let correct = check_answer(&rt.vocab, question, &answer_tail);
    Ok(BlackboxResult {
        question_id: question.id,
        points,
        stop_chunk,
        tokens_at_stop,
        total_tokens_available: total_available,
        saved_ms,
        answer_tail,
        correct,
    })
}
