//! Black-box setting (paper §5.3, Fig. 5, App. I.7) as a *coordinator
//! workload*: early-stopping an API reasoning model whose logits are NOT
//! accessible, using a small local proxy that computes EAT from the
//! verbal reasoning stream alone — served batched and deterministic
//! (DESIGN.md §3.6).
//!
//! The old pipeline ran one question at a time against the backends
//! directly, kept its own ad-hoc `sim_clock_ms`, and measured proxy
//! compute with `Instant::now()`. This rebuild folds it into the
//! coordinator's machinery:
//!
//!  * [`BlackboxSession`] is a split-phase state machine in the
//!    `ReasoningSession` mold (DESIGN.md §3.2): `poll()` returns the
//!    next [`BlackboxWork`] — a remote-main decode, a local-proxy
//!    decode, an EAT probe, or a wait-for-chunk-arrival — and the
//!    driver feeds results back through `complete_*`;
//!  * [`BlackboxBatcher`] drives many streams at once: remote-main and
//!    local-proxy lanes live in two slot-major [`BatchCacheStore`]s
//!    (sharing the paged CoW pools and the free probe scratch), and each
//!    tick commits all pending decodes through ONE fused `decode_batch`
//!    per model — with the bit-identical sequential fallback;
//!  * chunk arrivals are scheduled on the injected Wall/Virtual
//!    [`Clock`]: a generated chunk is *delivered* to the proxy monitor
//!    only once the clock passes its simulated arrival time, so under a
//!    virtual clock a many-question serve run is a pure function of the
//!    seed (byte-identical [`crate::coordinator::BlackboxMetrics`] JSON);
//!  * per-chunk proxy compute is routed through the clock: wall runs
//!    measure it, virtual runs charge the deterministic
//!    [`ProxyCostModel`] — either way the Fig. 5b overlap accounting
//!    (compute vs chunk inter-arrival gap) lands in the metrics;
//!  * latency jitter and token sampling draw from *independent* seeded
//!    RNG streams, so the reasoning trajectory is bit-identical under
//!    any [`LatencyModel`] — only the timestamps move.

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use crate::config::ServeConfig;
use crate::coordinator::batch_cache::BatchCacheStore;
use crate::coordinator::kv::{pages_for, KvPageManager, SlotId};
use crate::coordinator::metrics::BlackboxMetrics;
use crate::coordinator::DEFAULT_TICK_DT;
use crate::datasets::{check_answer, Question};
use crate::monitor::EmaVar;
use crate::runtime::{Backend, Runtime};
use crate::sampler::Sampler;
use crate::util::clock::Clock;
use crate::util::rng::Rng;
use crate::util::wheel::EventWheel;
use crate::vocab::{Vocab, ANSWER_SAMPLE_CAP};

/// Latency model of the remote streaming API.
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Fixed per-chunk overhead (network + service), ms.
    pub base_ms: f64,
    /// Per-token generation latency of the remote model, ms.
    pub per_token_ms: f64,
    /// Multiplicative jitter amplitude (0.1 = +-10%).
    pub jitter: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        // Claude-3.7-over-OpenRouter ballpark scaled to our trace lengths:
        // ~40ms/token streaming + 60ms chunk overhead.
        LatencyModel {
            base_ms: 60.0,
            per_token_ms: 40.0,
            jitter: 0.15,
        }
    }
}

impl LatencyModel {
    /// Simulated delivery latency of one chunk. `rng` must be the
    /// session's dedicated *latency* stream: drawing jitter from the
    /// token-sampling stream would couple the reasoning trajectory to
    /// the latency settings. The jitter factor is clamped at zero so an
    /// out-of-range `--jitter` (> 1) can never run the arrival timeline
    /// backwards (a negative gap would corrupt the overlap accounting).
    pub fn chunk_ms(&self, tokens: usize, rng: &mut Rng) -> f64 {
        let jit = (1.0 + self.jitter * (2.0 * rng.f64() - 1.0)).max(0.0);
        (self.base_ms + self.per_token_ms * tokens as f64) * jit
    }
}

/// Deterministic per-operation cost model of the local proxy monitor
/// (ms). Under a virtual clock nothing real can be measured, so chunk
/// proxy compute is *charged* from this model instead — which is what
/// keeps the overlap accounting in the metrics JSON a pure function of
/// the seed. Wall-clock runs measure through the injected [`Clock`] and
/// ignore it.
#[derive(Debug, Clone, Copy)]
pub struct ProxyCostModel {
    /// Cost of committing one streamed token into the proxy KV cache.
    pub decode_ms: f64,
    /// Cost of one EAT probe (suffix append + entropy readout).
    pub probe_ms: f64,
}

impl Default for ProxyCostModel {
    fn default() -> Self {
        // small-proxy ballpark: a chunk of ~12 tokens plus one probe
        // costs ~3 ms against inter-arrival gaps of hundreds of ms
        ProxyCostModel {
            decode_ms: 0.2,
            probe_ms: 0.5,
        }
    }
}

/// Chunk-granularity monitoring defaults: the monitor sees ~3-4x fewer
/// — and much more strongly collapsed — observations than per-line
/// monitoring, so the EMA window is short with fast de-bias and the
/// variance threshold loosened. Shared by the CLI, the example, the
/// bench and the test suites so a recalibration is a one-line change.
pub const CHUNK_MONITOR_ALPHA: f64 = 0.8;
pub const CHUNK_MONITOR_DELTA: f64 = 5e-2;

/// Black-box serving knobs, bundled so the CLI / benches / tests
/// configure one thing.
#[derive(Debug, Clone, Copy)]
pub struct BlackboxConfig {
    /// Tokens per delivered chunk (the paper observed ~5 tokens/block,
    /// chunks of 20 blocks; scaled to our trace lengths).
    pub chunk_tokens: usize,
    pub latency: LatencyModel,
    pub proxy_cost: ProxyCostModel,
}

impl Default for BlackboxConfig {
    fn default() -> Self {
        BlackboxConfig {
            chunk_tokens: 12,
            latency: LatencyModel::default(),
            proxy_cost: ProxyCostModel::default(),
        }
    }
}

/// Per-chunk monitor record (Fig. 5 / Fig. 18 data).
#[derive(Debug, Clone)]
pub struct ChunkPoint {
    pub chunk: usize,
    pub tokens_seen: usize,
    pub eat: f64,
    pub vhat: f64,
    /// Simulated arrival gap since the previous chunk, ms.
    pub arrival_gap_ms: f64,
    /// Local proxy compute for the chunk (decode + probe), ms — measured
    /// through the clock on a wall run, charged from the
    /// [`ProxyCostModel`] on a virtual run.
    pub proxy_compute_ms: f64,
}

#[derive(Debug, Clone)]
pub struct BlackboxResult {
    pub question_id: usize,
    pub points: Vec<ChunkPoint>,
    /// Chunks delivered (probed or not).
    pub chunks: usize,
    /// Chunk index where the monitor stopped the stream (None = ran out).
    pub stop_chunk: Option<usize>,
    pub tokens_at_stop: usize,
    pub total_tokens_available: usize,
    /// Simulated remote time saved by stopping early, ms.
    pub saved_ms: f64,
    pub answer_tail: Vec<u32>,
    pub correct: bool,
}

/// Tolerance for "the clock reached the chunk's arrival time": virtual
/// jumps land within a few ulps of the target, and an exact `>=` could
/// spin on the last ulp forever.
const DELIVERY_EPS: f64 = 1e-9;

/// Work a black-box session asks its driver to perform.
#[derive(Debug, Clone, PartialEq)]
pub enum BlackboxWork {
    /// Commit `token` on the remote (main) model; reply with the logits.
    MainDecode { token: u32 },
    /// Commit `token` of a delivered chunk into the local proxy cache.
    ProxyDecode { token: u32 },
    /// EAT-probe the proxy cache with `suffix` (cache untouched).
    Probe { suffix: Vec<u32> },
    /// A generated chunk is in flight; nothing to do until the clock
    /// reaches `until_s`.
    Wait { until_s: f64 },
    /// The stream is finished; call [`BlackboxSession::finish`].
    Done,
}

/// Protocol state. `Await*` states have work in flight.
#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    /// Sampling the next remote token of the current chunk.
    Stream,
    /// Remote decode in flight.
    AwaitMain { tok: u32 },
    /// Chunk generated, delivery scheduled at absolute clock second
    /// `at_s`.
    AwaitChunk { at_s: f64 },
    /// Delivered chunk being folded into the proxy monitor.
    Monitor,
    /// Proxy decode in flight.
    AwaitProxy,
    /// EAT probe in flight.
    AwaitProbe,
    /// Answer elicitation: about to emit the next forced/sampled token.
    Elicit { forced: usize, sampled: usize },
    /// Elicitation decode in flight.
    AwaitElicit { tok: u32, forced: usize, sampled: usize },
    Done,
}

/// One black-box stream: the simulated remote service (main model behind
/// a text-only interface) plus the local proxy monitor, as a split-phase
/// state machine holding **no model or clock references**.
pub struct BlackboxSession {
    cfg: ServeConfig,
    bb: BlackboxConfig,
    vocab: Vocab,
    seq_len: usize,
    pub question: Question,
    sampler: Sampler,
    /// Token sampling stream (remote reasoning + answer tail).
    rng_tokens: Rng,
    /// Latency jitter stream — independent, so the trajectory is
    /// invariant to the latency model.
    rng_latency: Rng,

    /// Main-model logits after the last committed decode.
    cur_logits: Vec<f32>,
    /// Mirror of the main cache's write position.
    pos: usize,
    /// Reasoning tokens streamed by the remote model.
    produced: usize,
    /// The remote model ended its reasoning (self-termination, budget,
    /// or headroom).
    stream_done: bool,

    /// Session start on the shared clock (chunk arrivals are offsets
    /// from here).
    started_s: f64,
    /// Cumulative remote-timeline arrival of the latest chunk, ms.
    arrival_ms: f64,
    prev_arrival_ms: f64,

    chunk_idx: usize,
    chunk_buf: Vec<u32>,
    /// Proxy tokens of the delivered chunk already committed.
    monitor_idx: usize,
    /// Feed this many tokens before probing (None = no probe this
    /// chunk: it ends mid-line and carries the EMA state forward).
    probe_after: Option<usize>,
    did_probe: bool,
    probed_eat: Option<f64>,
    chunk_proxy_ms: f64,
    tokens_seen: usize,

    ema: EmaVar,
    points: Vec<ChunkPoint>,
    stop_chunk: Option<usize>,
    answer_tail: Vec<u32>,
    probe_suffix: Vec<u32>,
    state: State,
}

impl BlackboxSession {
    /// Build a session from a completed prefill of `prompt + <think>` on
    /// BOTH models (the driver owns the caches).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: ServeConfig,
        bb: BlackboxConfig,
        vocab: Vocab,
        seq_len: usize,
        question: Question,
        rng_tokens: Rng,
        rng_latency: Rng,
        prefill_logits: Vec<f32>,
        prompt_len: usize,
        started_s: f64,
    ) -> BlackboxSession {
        let sampler = Sampler::new(cfg.temperature, cfg.top_p);
        let ema = EmaVar::new(cfg.alpha);
        let probe_suffix = vocab.suffix_prefixed();
        BlackboxSession {
            cfg,
            bb,
            vocab,
            seq_len,
            question,
            sampler,
            rng_tokens,
            rng_latency,
            cur_logits: prefill_logits,
            pos: prompt_len,
            produced: 0,
            stream_done: false,
            started_s,
            arrival_ms: 0.0,
            prev_arrival_ms: 0.0,
            chunk_idx: 0,
            chunk_buf: Vec::new(),
            monitor_idx: 0,
            probe_after: None,
            did_probe: false,
            probed_eat: None,
            chunk_proxy_ms: 0.0,
            tokens_seen: 0,
            ema,
            points: Vec::new(),
            stop_chunk: None,
            answer_tail: Vec::new(),
            probe_suffix,
            state: State::Stream,
        }
    }

    /// `Some(at_s)` while a chunk is in flight and undeliverable before
    /// `at_s` — the idle-jump hook for the workload driver.
    pub fn waiting_until(&self) -> Option<f64> {
        match self.state {
            State::AwaitChunk { at_s } => Some(at_s),
            _ => None,
        }
    }

    pub fn done(&self) -> bool {
        self.state == State::Done
    }

    /// The main-cache write position this session mirrors.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// A delivered chunk has fully entered the proxy: split at the last
    /// *complete* reasoning line. Chunks are fixed-size token windows and
    /// generally end mid-line; probing there makes EAT needlessly noisy
    /// (the distribution after a half-written line is ill-posed). Feed up
    /// to the last newline, probe, then feed the remainder. Chunks
    /// without a newline carry the previous EMA state forward (no probe)
    /// — unless the stream finished, which always probes.
    fn begin_monitor(&mut self) {
        let nl = self.vocab.nl;
        self.probe_after = match self.chunk_buf.iter().rposition(|&t| t == nl) {
            Some(i) => Some(i + 1),
            None if self.stream_done => Some(0),
            None => None,
        };
        self.did_probe = false;
        self.probed_eat = None;
        self.monitor_idx = 0;
        self.chunk_proxy_ms = 0.0;
        self.state = State::Monitor;
    }

    /// Close out the delivered chunk: record the monitor point, decide
    /// stop/continue (Alg. 1 lines 7-9 at chunk granularity).
    fn finish_chunk(&mut self) {
        self.tokens_seen += self.chunk_buf.len();
        let gap = self.arrival_ms - self.prev_arrival_ms;
        self.prev_arrival_ms = self.arrival_ms;
        let mut stop = false;
        if let Some(eat) = self.probed_eat {
            let vhat = self.ema.update(eat);
            self.points.push(ChunkPoint {
                chunk: self.chunk_idx,
                tokens_seen: self.tokens_seen,
                eat,
                vhat,
                arrival_gap_ms: gap,
                proxy_compute_ms: self.chunk_proxy_ms,
            });
            if vhat < self.cfg.delta {
                self.stop_chunk = Some(self.chunk_idx);
                stop = true;
            }
        }
        self.chunk_buf.clear();
        if stop || self.stream_done {
            // cancel the stream and ask the service for its final answer
            // (the paper force-appends `</think>` + answer-inducing text
            // server-side)
            self.state = State::Elicit {
                forced: 0,
                sampled: 0,
            };
        } else {
            self.state = State::Stream;
        }
    }

    /// What should the driver do next? Idempotent for in-flight states.
    /// `now_s` is the shared clock: a chunk in flight is delivered the
    /// moment the clock passes its scheduled arrival.
    pub fn poll(&mut self, now_s: f64) -> BlackboxWork {
        loop {
            match self.state {
                State::Stream => {
                    if self.chunk_buf.len() >= self.bb.chunk_tokens || self.stream_done {
                        // chunk complete: schedule its delivery on the
                        // remote timeline (generation + network latency)
                        let gen = self.chunk_buf.len().max(1);
                        self.arrival_ms +=
                            self.bb.latency.chunk_ms(gen, &mut self.rng_latency);
                        self.chunk_idx += 1;
                        let at_s = self.started_s + self.arrival_ms / 1e3;
                        self.state = State::AwaitChunk { at_s };
                        continue;
                    }
                    // keep headroom for the forced tail + sampled answer
                    if self.produced >= self.cfg.max_think_tokens
                        || self.pos + self.vocab.answer_reserve() + 1 >= self.seq_len
                    {
                        self.stream_done = true;
                        continue;
                    }
                    let tok = self.sampler.sample(&self.cur_logits, &mut self.rng_tokens);
                    if tok == self.vocab.ethink {
                        // the remote model stopped thinking on its own
                        self.stream_done = true;
                        continue;
                    }
                    self.state = State::AwaitMain { tok };
                    return BlackboxWork::MainDecode { token: tok };
                }
                State::AwaitMain { tok } => {
                    return BlackboxWork::MainDecode { token: tok };
                }
                State::AwaitChunk { at_s } => {
                    if now_s + DELIVERY_EPS < at_s {
                        return BlackboxWork::Wait { until_s: at_s };
                    }
                    self.begin_monitor();
                    continue;
                }
                State::Monitor => {
                    if let Some(pa) = self.probe_after {
                        if self.monitor_idx >= pa && !self.did_probe {
                            self.state = State::AwaitProbe;
                            return BlackboxWork::Probe {
                                suffix: self.probe_suffix.clone(),
                            };
                        }
                    }
                    if self.monitor_idx < self.chunk_buf.len() {
                        let tok = self.chunk_buf[self.monitor_idx];
                        self.state = State::AwaitProxy;
                        return BlackboxWork::ProxyDecode { token: tok };
                    }
                    self.finish_chunk();
                    continue;
                }
                State::AwaitProxy => {
                    let tok = self.chunk_buf[self.monitor_idx];
                    return BlackboxWork::ProxyDecode { token: tok };
                }
                State::AwaitProbe => {
                    return BlackboxWork::Probe {
                        suffix: self.probe_suffix.clone(),
                    };
                }
                State::Elicit { forced, sampled } => {
                    if self.pos >= self.seq_len {
                        self.state = State::Done;
                        continue;
                    }
                    let force = self.vocab.forced_answer_tail();
                    if forced < force.len() {
                        let tok = force[forced];
                        self.state = State::AwaitElicit {
                            tok,
                            forced,
                            sampled,
                        };
                        return BlackboxWork::MainDecode { token: tok };
                    }
                    if sampled >= ANSWER_SAMPLE_CAP {
                        self.state = State::Done;
                        continue;
                    }
                    let tok = self.sampler.sample(&self.cur_logits, &mut self.rng_tokens);
                    self.answer_tail.push(tok);
                    if tok == self.vocab.eos {
                        self.state = State::Done;
                        continue;
                    }
                    self.state = State::AwaitElicit {
                        tok,
                        forced,
                        sampled: sampled + 1,
                    };
                    return BlackboxWork::MainDecode { token: tok };
                }
                State::AwaitElicit { tok, .. } => {
                    return BlackboxWork::MainDecode { token: tok };
                }
                State::Done => return BlackboxWork::Done,
            }
        }
    }

    /// Feed back the logits of a completed [`BlackboxWork::MainDecode`].
    pub fn complete_main_decode(&mut self, logits: Vec<f32>) -> Result<()> {
        match self.state {
            State::AwaitMain { tok } => {
                self.cur_logits = logits;
                self.pos += 1;
                self.produced += 1;
                self.chunk_buf.push(tok);
                self.state = State::Stream;
                Ok(())
            }
            State::AwaitElicit {
                tok,
                forced,
                sampled,
            } => {
                self.cur_logits = logits;
                self.pos += 1;
                let force_len = self.vocab.forced_answer_tail().len();
                if forced < force_len {
                    // forced tokens enter the tail once actually decoded
                    self.answer_tail.push(tok);
                    self.state = State::Elicit {
                        forced: forced + 1,
                        sampled,
                    };
                } else {
                    self.state = State::Elicit { forced, sampled };
                }
                Ok(())
            }
            _ => anyhow::bail!("complete_main_decode in state {:?}", self.state),
        }
    }

    /// Feed back a completed [`BlackboxWork::ProxyDecode`], with the
    /// compute charged to the chunk (measured on a wall clock, modeled
    /// on a virtual one).
    pub fn complete_proxy_decode(&mut self, compute_ms: f64) -> Result<()> {
        match self.state {
            State::AwaitProxy => {
                self.monitor_idx += 1;
                self.chunk_proxy_ms += compute_ms;
                self.state = State::Monitor;
                Ok(())
            }
            _ => anyhow::bail!("complete_proxy_decode in state {:?}", self.state),
        }
    }

    /// Feed back a completed [`BlackboxWork::Probe`].
    pub fn complete_probe(&mut self, eat: f32, compute_ms: f64) -> Result<()> {
        match self.state {
            State::AwaitProbe => {
                self.did_probe = true;
                self.probed_eat = Some(eat as f64);
                self.chunk_proxy_ms += compute_ms;
                self.state = State::Monitor;
                Ok(())
            }
            _ => anyhow::bail!("complete_probe in state {:?}", self.state),
        }
    }

    /// Summarize a finished stream. The saving estimate charges the
    /// conservative budget bound, as the paper's "saved at least one
    /// minute" phrasing does: had we not stopped, the remote would have
    /// continued toward `max_think_tokens`.
    pub fn finish(self) -> BlackboxResult {
        debug_assert_eq!(self.state, State::Done);
        let total_available = self.cfg.max_think_tokens;
        let saved_tokens = total_available.saturating_sub(self.tokens_seen);
        let saved_ms = if self.stop_chunk.is_some() {
            saved_tokens as f64 * self.bb.latency.per_token_ms
        } else {
            0.0
        };
        let correct = check_answer(&self.vocab, &self.question, &self.answer_tail);
        BlackboxResult {
            question_id: self.question.id,
            points: self.points,
            chunks: self.chunk_idx,
            stop_chunk: self.stop_chunk,
            tokens_at_stop: self.tokens_seen,
            total_tokens_available: total_available,
            saved_ms,
            answer_tail: self.answer_tail,
            correct,
        }
    }
}

/// A queued black-box request.
struct QueuedStream {
    question: Question,
    arrived: f64,
    seq: u64,
}

struct ActiveStream {
    session: BlackboxSession,
    slot: SlotId,
    arrived: f64,
    /// Submission seq: the wait-event key into the delivery wheel.
    seq: u64,
}

/// Continuous batcher for black-box streams: admits questions into KV
/// lanes (main + proxy reservations), generates every active remote
/// stream through ONE fused main `decode_batch` per tick, folds
/// delivered chunks into the proxy lanes (fused when the proxy model
/// has a batch entry point), and schedules chunk arrivals on the
/// injected clock. Under [`Clock::virt`] the whole run — trajectories,
/// arrival pattern, overlap accounting, metrics JSON — is a pure
/// function of the seed.
pub struct BlackboxBatcher<'a> {
    rt: &'a Runtime,
    cfg: ServeConfig,
    bb: BlackboxConfig,
    clock: Clock,
    kv: KvPageManager,
    main_store: BatchCacheStore,
    proxy_store: BatchCacheStore,
    queue: VecDeque<QueuedStream>,
    active: Vec<ActiveStream>,
    /// Scheduled chunk deliveries (DESIGN.md §3.10), keyed
    /// `(arrival_time, slot, seq)`. `RefCell` because [`Self::blocked_until`]
    /// is a `&self` probe yet must lazily drop superseded events.
    deliveries: RefCell<EventWheel<u64>>,
    /// seq → `to_bits` of the delivery time each waiting stream is
    /// parked on; the liveness filter for wheel events. A stream absent
    /// here has serviceable work.
    wait_index: BTreeMap<u64, u64>,
    next_seq: u64,
    /// Disable the fused paths even when a backend has one (A/B
    /// determinism checks, ablations).
    pub force_sequential: bool,
    pub metrics: BlackboxMetrics,
    pub results: Vec<BlackboxResult>,
}

impl<'a> BlackboxBatcher<'a> {
    /// Wall-clock batcher (live pacing: chunks arrive in real time).
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        bb: BlackboxConfig,
        slots: usize,
    ) -> BlackboxBatcher<'a> {
        BlackboxBatcher::with_clock(rt, cfg, bb, slots, Clock::wall())
    }

    /// Full constructor: inject the time source (a [`Clock::virt`] makes
    /// the entire serve run deterministic in the seed).
    pub fn with_clock(
        rt: &'a Runtime,
        cfg: ServeConfig,
        bb: BlackboxConfig,
        slots: usize,
        clock: Clock,
    ) -> BlackboxBatcher<'a> {
        // a zero-token chunk would stream nothing yet schedule empty
        // deliveries forever — clamp rather than loop
        let mut bb = bb;
        bb.chunk_tokens = bb.chunk_tokens.max(1);
        let main_ps = rt.main.page_size().unwrap_or(rt.main.seq_len());
        let proxy_ps = rt.proxy.page_size().unwrap_or(rt.proxy.seq_len());
        // worst case per resident stream: full sequence on the remote
        // main model plus the proxy mirror
        let reserve = pages_for(rt.main.seq_len(), main_ps)
            + pages_for(rt.proxy.seq_len(), proxy_ps);
        BlackboxBatcher {
            kv: KvPageManager::new(slots, main_ps, reserve, cfg.kv_pages),
            main_store: BatchCacheStore::new(slots),
            proxy_store: BatchCacheStore::new(slots),
            metrics: BlackboxMetrics::new(clock.clone()),
            rt,
            cfg,
            bb,
            clock,
            queue: VecDeque::new(),
            active: Vec::new(),
            deliveries: RefCell::new(EventWheel::new(DEFAULT_TICK_DT)),
            wait_index: BTreeMap::new(),
            next_seq: 0,
            force_sequential: false,
            results: Vec::new(),
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn submit(&mut self, question: Question) {
        self.metrics.mark_start();
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(QueuedStream {
            question,
            arrived: self.clock.now(),
            seq,
        });
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn kv_peak(&self) -> usize {
        self.kv.peak()
    }

    /// Upload/residency accounting of the remote-main lanes.
    pub fn main_store_counters(&self) -> crate::coordinator::batch_cache::StoreCounters {
        self.main_store.counters
    }

    /// Upload/residency accounting of the local-proxy lanes.
    pub fn proxy_store_counters(&self) -> crate::coordinator::batch_cache::StoreCounters {
        self.proxy_store.counters
    }

    /// The per-stream RNGs: pure functions of the serve seed and the
    /// submission sequence number — and independent of each other, so
    /// the latency model can never perturb the sampled trajectory.
    fn stream_rngs(&self, seq: u64) -> (Rng, Rng) {
        let salt = seq.wrapping_mul(0x9E3779B97F4A7C15);
        (
            Rng::new(self.cfg.seed ^ 0xB1ACB0 ^ salt),
            Rng::new(self.cfg.seed ^ 0x1A7E2C1 ^ salt),
        )
    }

    /// Admit queued questions while KV lanes + page budget allow: both
    /// models prefill `prompt + <think>` (the proxy sees the same
    /// visible prompt the remote does).
    fn admit(&mut self) -> Result<()> {
        while self.kv.available() > 0 {
            let Some(req) = self.queue.pop_front() else {
                break;
            };
            let slot = self.kv.acquire().expect("available() > 0 guarantees a lane");
            let mut prompt = req.question.prompt.clone();
            prompt.push(self.rt.vocab.think);
            let (logits, main) = self.rt.main.prefill(&prompt)?;
            let (_pl, proxy) = self.rt.proxy.prefill(&prompt)?;
            self.main_store.install(slot, main, None)?;
            self.proxy_store.install(slot, proxy, None)?;
            let (rng_tokens, rng_latency) = self.stream_rngs(req.seq);
            let session = BlackboxSession::new(
                self.cfg.clone(),
                self.bb,
                self.rt.vocab,
                self.rt.main.seq_len(),
                req.question,
                rng_tokens,
                rng_latency,
                logits,
                prompt.len(),
                self.clock.now(),
            );
            self.active.push(ActiveStream {
                session,
                slot,
                arrived: req.arrived,
                seq: req.seq,
            });
        }
        Ok(())
    }

    /// The compute charge for proxy work that took `t0 → now` on the
    /// clock: measured on a wall clock, `modeled_ms` on a virtual one
    /// (where the clock cannot move under us) — the "measured-compute
    /// hook" that keeps ChunkPoint/metrics deterministic.
    fn charge_ms(&self, t0: f64, modeled_ms: f64) -> f64 {
        if self.clock.is_virtual() {
            modeled_ms
        } else {
            (self.clock.now() - t0) * 1e3
        }
    }

    /// Earliest future chunk arrival when NOTHING is serviceable right
    /// now — every active stream is awaiting a scheduled delivery and no
    /// admission is possible. `None` = a tick would advance something.
    pub fn blocked_until(&self) -> Option<f64> {
        if self.active.is_empty() {
            return None;
        }
        if !self.queue.is_empty() && self.kv.available() > 0 {
            return None;
        }
        // a stream outside the wait index has serviceable work
        if self.wait_index.len() < self.active.len() {
            return None;
        }
        let now = self.clock.now();
        let mut deliveries = self.deliveries.borrow_mut();
        while let Some(k) = deliveries.peek() {
            match self.wait_index.get(&k.seq) {
                // the earliest *live* delivery bounds every stream's wait
                Some(&bits) if bits == k.time.to_bits() => {
                    return (k.time > now + DELIVERY_EPS).then_some(k.time);
                }
                // superseded: the stream moved on (chunk delivered, new
                // wait, retired) after this event was filed — drop it
                _ => {
                    deliveries.pop();
                }
            }
        }
        None
    }

    /// One scheduling tick: admit; poll every stream to its pending
    /// decode (probes serviced out-of-band against the proxy's free
    /// probe scratch); commit all pending main decodes in one fused
    /// `decode_batch` (idle lanes padded), then all pending proxy
    /// decodes likewise; retire finished streams. Returns the number of
    /// streams that advanced.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        let now = self.clock.now();

        let mut main_decodes: Vec<(usize, u32)> = Vec::new();
        let mut proxy_decodes: Vec<(usize, u32)> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();
        let mut advanced = 0usize;

        // phase A: drive each stream to its next decode, wait or end
        for i in 0..self.active.len() {
            loop {
                let work = self.active[i].session.poll(now);
                match work {
                    BlackboxWork::Probe { suffix } => {
                        let t0 = self.clock.now();
                        let slot = self.active[i].slot;
                        let (eat, _logits) =
                            self.rt.proxy.probe(self.proxy_store.main(slot)?, &suffix)?;
                        let ms = self.charge_ms(t0, self.bb.proxy_cost.probe_ms);
                        self.active[i].session.complete_probe(eat, ms)?;
                    }
                    BlackboxWork::MainDecode { token } => {
                        main_decodes.push((i, token));
                        self.wait_index.remove(&self.active[i].seq);
                        advanced += 1;
                        break;
                    }
                    BlackboxWork::ProxyDecode { token } => {
                        proxy_decodes.push((i, token));
                        self.wait_index.remove(&self.active[i].seq);
                        advanced += 1;
                        break;
                    }
                    BlackboxWork::Wait { .. } => {
                        // park the stream on the delivery wheel; re-filing
                        // only on a *changed* wait time keeps one live
                        // event per stream (stale ones are dropped by
                        // `blocked_until`'s index check)
                        let a = &self.active[i];
                        if let Some(at) = a.session.waiting_until() {
                            if self.wait_index.insert(a.seq, at.to_bits()) != Some(at.to_bits()) {
                                self.deliveries.borrow_mut().schedule_at(
                                    at,
                                    a.slot.0 as u32,
                                    a.seq,
                                    a.seq,
                                );
                            }
                        }
                        break;
                    }
                    BlackboxWork::Done => {
                        finished.push(i);
                        self.wait_index.remove(&self.active[i].seq);
                        break;
                    }
                }
            }
        }

        // phase B1: commit the remote-main decodes — fused when possible
        let main_width = if self.force_sequential {
            None
        } else {
            self.rt.main.batch_width()
        };
        match main_width {
            Some(w) if !main_decodes.is_empty() => {
                for chunk in main_decodes.chunks(w) {
                    let picks: Vec<(SlotId, u32)> = chunk
                        .iter()
                        .map(|&(i, tok)| (self.active[i].slot, tok))
                        .collect();
                    let logits = self.main_store.fused_decode(self.rt.main.as_ref(), &picks)?;
                    for (&(i, _), lg) in chunk.iter().zip(logits) {
                        self.active[i].session.complete_main_decode(lg)?;
                    }
                }
            }
            _ => {
                for &(i, token) in &main_decodes {
                    let slot = self.active[i].slot;
                    let lg = self.rt.main.decode(self.main_store.main_mut(slot)?, token)?;
                    self.main_store.mark_dirty(slot)?;
                    self.active[i].session.complete_main_decode(lg)?;
                }
            }
        }

        // phase B2: commit the local-proxy decodes of delivered chunks
        let proxy_width = if self.force_sequential {
            None
        } else {
            self.rt.proxy.batch_width()
        };
        match proxy_width {
            Some(w) if !proxy_decodes.is_empty() => {
                for chunk in proxy_decodes.chunks(w) {
                    let picks: Vec<(SlotId, u32)> = chunk
                        .iter()
                        .map(|&(i, tok)| (self.active[i].slot, tok))
                        .collect();
                    let t0 = self.clock.now();
                    let _ = self
                        .proxy_store
                        .fused_decode(self.rt.proxy.as_ref(), &picks)?;
                    let per = self.charge_ms(
                        t0,
                        self.bb.proxy_cost.decode_ms * chunk.len() as f64,
                    ) / chunk.len() as f64;
                    for &(i, _) in chunk {
                        self.active[i].session.complete_proxy_decode(per)?;
                    }
                }
            }
            _ => {
                for &(i, token) in &proxy_decodes {
                    let slot = self.active[i].slot;
                    let t0 = self.clock.now();
                    self.rt.proxy.decode(self.proxy_store.main_mut(slot)?, token)?;
                    self.proxy_store.mark_dirty(slot)?;
                    let ms = self.charge_ms(t0, self.bb.proxy_cost.decode_ms);
                    self.active[i].session.complete_proxy_decode(ms)?;
                }
            }
        }

        // phase C: retire in reverse index order to keep indices valid
        for &i in finished.iter().rev() {
            let a = self.active.swap_remove(i);
            self.wait_index.remove(&a.seq);
            self.main_store.retire(a.slot)?;
            self.proxy_store.retire(a.slot)?;
            self.kv.release(a.slot)?;
            let latency_ms = (now - a.arrived) * 1e3;
            let res = a.session.finish();
            for p in &res.points {
                self.metrics.record_chunk(p.arrival_gap_ms, p.proxy_compute_ms);
            }
            self.metrics.record_result(
                res.correct,
                res.stop_chunk.is_some(),
                res.tokens_at_stop,
                res.chunks,
                res.saved_ms,
                latency_ms,
            );
            self.results.push(res);
        }
        Ok(advanced)
    }

    /// Drain: run ticks until queue and active set are empty. Each tick
    /// is charged [`DEFAULT_TICK_DT`] simulated seconds on a virtual
    /// clock; when every stream is parked on a future chunk arrival the
    /// clock jumps straight to the earliest one (a wall clock naps and
    /// lets real time deliver it).
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            if let Some(until) = self.blocked_until() {
                if self.clock.is_virtual() {
                    self.clock.advance(until - self.clock.now());
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                continue;
            }
            self.tick()?;
            self.clock.advance(DEFAULT_TICK_DT);
        }
        Ok(())
    }
}

/// Run the full black-box pipeline on one question — the single-stream
/// convenience wrapper over [`BlackboxBatcher`] (one lane, virtual
/// clock) used by the figures, the example and the e2e tests. Stream
/// chunks from the "remote" service, monitor EAT with the local proxy,
/// stop when the EMA variance drops below delta, then ask the service
/// to finalize. Deterministic in `seed`.
pub fn run_blackbox(
    rt: &Runtime,
    cfg: &ServeConfig,
    question: &Question,
    latency: LatencyModel,
    chunk_tokens: usize,
    seed: u64,
) -> Result<BlackboxResult> {
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let bb = BlackboxConfig {
        chunk_tokens,
        latency,
        proxy_cost: ProxyCostModel::default(),
    };
    let mut batcher = BlackboxBatcher::with_clock(rt, cfg, bb, 1, Clock::virt());
    batcher.submit(question.clone());
    batcher.run_to_completion()?;
    batcher
        .results
        .pop()
        .ok_or_else(|| anyhow::anyhow!("blackbox run produced no result"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Dataset;

    fn easy_question(rt: &Runtime) -> Question {
        Dataset::synth_math500(&rt.vocab, 30, 3)
            .questions
            .into_iter()
            .find(|q| q.n_ops() <= 3)
            .expect("an easy question exists")
    }

    #[test]
    fn single_stream_wrapper_answers_and_monitors() {
        let rt = Runtime::reference();
        let mut cfg = ServeConfig::default();
        cfg.delta = CHUNK_MONITOR_DELTA;
        cfg.alpha = CHUNK_MONITOR_ALPHA;
        let q = easy_question(&rt);
        let res = run_blackbox(&rt, &cfg, &q, LatencyModel::default(), 6, 7).unwrap();
        assert!(res.correct, "{res:?}");
        assert!(res.chunks > 0);
        assert!(!res.points.is_empty(), "monitor must probe at least once");
        assert!(res.tokens_at_stop > 0);
        assert!(!res.answer_tail.is_empty());
        // arrival gaps are simulated latency, strictly positive
        assert!(res.points.iter().all(|p| p.arrival_gap_ms > 0.0));
        // virtual clock: proxy compute is the deterministic cost model
        assert!(res.points.iter().all(|p| p.proxy_compute_ms > 0.0));
    }

    #[test]
    fn trajectory_is_invariant_to_the_latency_model() {
        // the PR's RNG-split regression: jitter draws come from a
        // dedicated stream, so ONLY timestamps may move with the model
        let rt = Runtime::reference();
        let mut cfg = ServeConfig::default();
        cfg.delta = CHUNK_MONITOR_DELTA;
        cfg.alpha = CHUNK_MONITOR_ALPHA;
        let q = easy_question(&rt);
        let slow = LatencyModel {
            base_ms: 200.0,
            per_token_ms: 90.0,
            jitter: 0.4,
        };
        let fast = LatencyModel {
            base_ms: 5.0,
            per_token_ms: 1.0,
            jitter: 0.0,
        };
        let a = run_blackbox(&rt, &cfg, &q, slow, 6, 11).unwrap();
        let b = run_blackbox(&rt, &cfg, &q, fast, 6, 11).unwrap();
        assert_eq!(a.answer_tail, b.answer_tail, "trajectory moved with latency");
        assert_eq!(a.stop_chunk, b.stop_chunk);
        assert_eq!(a.tokens_at_stop, b.tokens_at_stop);
        assert_eq!(a.chunks, b.chunks);
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.eat.to_bits(), pb.eat.to_bits(), "EAT diverged");
            assert_eq!(pa.vhat.to_bits(), pb.vhat.to_bits(), "V-hat diverged");
            assert_ne!(
                pa.arrival_gap_ms.to_bits(),
                pb.arrival_gap_ms.to_bits(),
                "different latency models must move the timestamps"
            );
        }
    }

    #[test]
    fn same_seed_single_stream_runs_are_identical() {
        let rt = Runtime::reference();
        let mut cfg = ServeConfig::default();
        cfg.delta = CHUNK_MONITOR_DELTA;
        cfg.alpha = CHUNK_MONITOR_ALPHA;
        let q = easy_question(&rt);
        let a = run_blackbox(&rt, &cfg, &q, LatencyModel::default(), 6, 5).unwrap();
        let b = run_blackbox(&rt, &cfg, &q, LatencyModel::default(), 6, 5).unwrap();
        assert_eq!(a.answer_tail, b.answer_tail);
        assert_eq!(a.stop_chunk, b.stop_chunk);
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.arrival_gap_ms.to_bits(), pb.arrival_gap_ms.to_bits());
            assert_eq!(pa.proxy_compute_ms.to_bits(), pb.proxy_compute_ms.to_bits());
        }
    }
}
