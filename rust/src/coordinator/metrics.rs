//! Serving metrics: throughput, latency, token accounting, exit reasons,
//! scheduler events (preemption/resume/deadline misses) and the slot
//! utilization timeline.
//!
//! Time is read through an injected [`Clock`] rather than
//! `std::time::Instant`, and the throughput window opens at the *first
//! arrival* (`mark_start`) instead of at construction — metrics built
//! before traffic no longer skew elapsed/throughput.
//!
//! Every metrics type here emits through the one [`MetricsReport`]
//! interface: a deterministic JSON snapshot (under a virtual clock two
//! same-seed runs serialize byte-identically — the CI determinism steps
//! diff it) plus a one-block human report, with the percentile-summary
//! shape shared via [`summary_json`] instead of re-rolled per type.

use std::collections::BTreeMap;

use crate::exit::ExitReason;
use crate::util::clock::Clock;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Unified metrics emission (DESIGN.md §3.7): one serializer contract
/// for [`ServeMetrics`], [`BlackboxMetrics`] and [`ClusterMetrics`], so
/// the CI determinism diffs and the CLI `--metrics-json` path run
/// against a single interface instead of three hand-rolled bodies.
pub trait MetricsReport {
    /// Deterministic JSON snapshot: under a virtual clock two same-seed
    /// runs must serialize byte-identically.
    fn to_json(&self) -> Json;

    /// One-block human report for the CLI and examples.
    fn report(&self) -> String;
}

/// The shared percentile-summary serializer
/// (count/mean/min/p50/p95/p99/max) every [`MetricsReport`] embeds for
/// its latency-shaped [`Summary`] fields.
pub fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count() as f64)),
        ("mean", Json::num(s.mean())),
        ("min", Json::num(s.min())),
        ("p50", Json::num(s.p50())),
        ("p95", Json::num(s.p95())),
        ("p99", Json::num(s.p99())),
        ("max", Json::num(s.max())),
    ])
}

#[derive(Debug)]
pub struct ServeMetrics {
    clock: Clock,
    /// Opened by the first arrival (`mark_start`); `None` until then.
    started: Option<f64>,
    pub completed: usize,
    pub correct: usize,
    pub reasoning_tokens: u64,
    pub probe_count: u64,
    pub rollout_tokens: u64,
    /// KV-slot evictions of long-stalled sessions (EAT-aware mode).
    pub preemptions: u64,
    /// Suspended sessions readmitted (page repin or re-prefill).
    pub resumes: u64,
    /// Tokens restored on resume — re-prefilled under the monolithic
    /// store, repinned for free under the paged store; counted
    /// identically in both so same-seed runs stay byte-comparable
    /// across stores.
    pub resume_prefill_tokens: u64,
    /// Suspended sessions whose retained pages were spilled (host page
    /// budget full): their resume falls back to re-prefill. Always 0
    /// on the monolithic store and under the default page budget.
    pub kv_spills: u64,
    /// Waiters (queued requests or suspended sessions) handed to another
    /// replica by the cluster router. 0 outside cluster serving.
    pub migrations_out: u64,
    /// Waiters received from another replica.
    pub migrations_in: u64,
    /// Committed tokens carried by received migrated sessions — their KV
    /// repins from the shared pool on the paged store and re-prefills on
    /// mono, but is counted identically in both so same-seed runs stay
    /// byte-comparable across stores.
    pub migrated_tokens: u64,
    /// Completions that finished past their SLO deadline.
    pub deadline_misses: u64,
    /// Sessions force-exited under saturation (DESIGN.md §3.11) —
    /// nearest-to-exit first, by `ExitPolicy::stability`. They still
    /// complete (with `ExitReason::Shed`), so they are also counted in
    /// `completed`.
    pub shed_exits: u64,
    /// Queued requests dropped because their SLO deadline passed before
    /// admission (overload control). Never counted in `completed`.
    pub rejected: u64,
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exit_reasons: BTreeMap<String, usize>,
    /// (seconds, slots in use) — appended whenever occupancy changes.
    pub slot_timeline: Vec<(f64, usize)>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new(Clock::wall())
    }
}

impl ServeMetrics {
    pub fn new(clock: Clock) -> Self {
        ServeMetrics {
            clock,
            started: None,
            completed: 0,
            correct: 0,
            reasoning_tokens: 0,
            probe_count: 0,
            rollout_tokens: 0,
            preemptions: 0,
            resumes: 0,
            resume_prefill_tokens: 0,
            kv_spills: 0,
            migrations_out: 0,
            migrations_in: 0,
            migrated_tokens: 0,
            deadline_misses: 0,
            shed_exits: 0,
            rejected: 0,
            latency_ms: Summary::new(),
            queue_ms: Summary::new(),
            exit_reasons: BTreeMap::new(),
            slot_timeline: Vec::new(),
        }
    }

    /// Open the throughput window (idempotent; the batcher calls this on
    /// the first submission so pre-traffic construction cannot skew
    /// elapsed/throughput).
    pub fn mark_start(&mut self) {
        if self.started.is_none() {
            self.started = Some(self.clock.now());
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_completion(
        &mut self,
        correct: bool,
        reasoning_tokens: usize,
        probes: usize,
        rollout_tokens: usize,
        latency_ms: f64,
        queue_ms: f64,
        deadline_missed: bool,
        reason: ExitReason,
    ) {
        self.mark_start();
        self.completed += 1;
        self.correct += correct as usize;
        self.reasoning_tokens += reasoning_tokens as u64;
        self.probe_count += probes as u64;
        self.rollout_tokens += rollout_tokens as u64;
        self.deadline_misses += deadline_missed as u64;
        self.latency_ms.record(latency_ms);
        self.queue_ms.record(queue_ms);
        *self
            .exit_reasons
            .entry(format!("{reason:?}"))
            .or_insert(0) += 1;
    }

    pub fn record_preemption(&mut self) {
        self.preemptions += 1;
    }

    pub fn record_resume(&mut self, prefill_tokens: usize) {
        self.resumes += 1;
        self.resume_prefill_tokens += prefill_tokens as u64;
    }

    pub fn record_spill(&mut self) {
        self.kv_spills += 1;
    }

    /// A waiter left this replica for another (cluster migration).
    pub fn record_migration_out(&mut self) {
        self.migrations_out += 1;
    }

    /// A waiter arrived from another replica; `tokens` is the incoming
    /// session's committed history length (0 for a queued request that
    /// never prefilled).
    pub fn record_migration_in(&mut self, tokens: usize) {
        self.migrations_in += 1;
        self.migrated_tokens += tokens as u64;
    }

    /// A session was force-exited to free KV pages under saturation.
    pub fn record_shed(&mut self) {
        self.shed_exits += 1;
    }

    /// A queued request was dropped: its SLO deadline passed before it
    /// could be admitted.
    pub fn record_rejection(&mut self) {
        self.mark_start();
        self.rejected += 1;
    }

    /// Append a slot-occupancy sample if occupancy changed.
    pub fn sample_slots(&mut self, in_use: usize) {
        if self.slot_timeline.last().map(|&(_, u)| u) == Some(in_use) {
            return;
        }
        self.slot_timeline.push((self.clock.now(), in_use));
    }

    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.completed.max(1) as f64
    }

    /// Seconds since the first arrival (0 before any traffic).
    pub fn elapsed_s(&self) -> f64 {
        match self.started {
            Some(t0) => (self.clock.now() - t0).max(0.0),
            None => 0.0,
        }
    }

    pub fn requests_per_s(&self) -> f64 {
        self.completed as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.reasoning_tokens as f64 / self.elapsed_s().max(1e-9)
    }

    /// Completions that landed inside their SLO deadline.
    pub fn within_slo(&self) -> usize {
        self.completed - (self.deadline_misses as usize).min(self.completed)
    }

    /// Useful throughput under saturation: within-SLO completions per
    /// second. Equals `requests_per_s` when no SLO is configured
    /// (deadline_misses stays 0).
    pub fn goodput_rps(&self) -> f64 {
        self.within_slo() as f64 / self.elapsed_s().max(1e-9)
    }

    /// Fraction of demand served inside its SLO: within-SLO completions
    /// over everything that asked (completions + rejected arrivals).
    /// 1.0 when nothing was rejected and nothing missed its deadline.
    pub fn slo_attainment(&self) -> f64 {
        let asked = self.completed + self.rejected as usize;
        self.within_slo() as f64 / asked.max(1) as f64
    }

    /// Mean slot occupancy over the timeline (time-weighted), for
    /// reports; 0 without samples.
    pub fn mean_slot_occupancy(&self) -> f64 {
        if self.slot_timeline.len() < 2 {
            return self.slot_timeline.last().map(|&(_, u)| u as f64).unwrap_or(0.0);
        }
        let mut area = 0.0;
        for w in self.slot_timeline.windows(2) {
            area += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        let span = self.slot_timeline.last().unwrap().0 - self.slot_timeline[0].0;
        if span <= 0.0 {
            self.slot_timeline.last().map(|&(_, u)| u as f64).unwrap_or(0.0)
        } else {
            area / span
        }
    }

}

impl MetricsReport for ServeMetrics {
    /// Deterministic JSON snapshot: every counter plus latency/queue
    /// percentiles and the slot timeline.
    fn to_json(&self) -> Json {
        let reasons: Vec<(&str, Json)> = self
            .exit_reasons
            .iter()
            .map(|(k, &v)| (k.as_str(), Json::num(v as f64)))
            .collect();
        let timeline: Vec<Json> = self
            .slot_timeline
            .iter()
            .map(|&(t, u)| Json::arr(vec![Json::num(t), Json::num(u as f64)]))
            .collect();
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("correct", Json::num(self.correct as f64)),
            ("accuracy", Json::num(self.accuracy())),
            ("reasoning_tokens", Json::num(self.reasoning_tokens as f64)),
            ("probe_count", Json::num(self.probe_count as f64)),
            ("rollout_tokens", Json::num(self.rollout_tokens as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            ("resume_prefill_tokens", Json::num(self.resume_prefill_tokens as f64)),
            ("kv_spills", Json::num(self.kv_spills as f64)),
            ("migrations_out", Json::num(self.migrations_out as f64)),
            ("migrations_in", Json::num(self.migrations_in as f64)),
            ("migrated_tokens", Json::num(self.migrated_tokens as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("shed_exits", Json::num(self.shed_exits as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("elapsed_s", Json::num(self.elapsed_s())),
            ("latency_ms", summary_json(&self.latency_ms)),
            ("queue_ms", summary_json(&self.queue_ms)),
            ("exit_reasons", Json::obj(reasons)),
            ("slot_timeline", Json::arr(timeline)),
        ])
    }

    /// One-block human report for examples / `repro serve`.
    fn report(&self) -> String {
        let mut s = String::new();
        s += &format!(
            "requests           {:>8}   accuracy {:.3}\n",
            self.completed,
            self.accuracy()
        );
        s += &format!(
            "throughput         {:>8.2} req/s   {:.1} reasoning tok/s\n",
            self.requests_per_s(),
            self.tokens_per_s()
        );
        s += &format!(
            "latency ms         p50 {:>8.1}  p95 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
            self.latency_ms.p50(),
            self.latency_ms.p95(),
            self.latency_ms.p99(),
            self.latency_ms.max()
        );
        s += &format!(
            "queueing ms        p50 {:>8.1}  p95 {:>8.1}\n",
            self.queue_ms.p50(),
            self.queue_ms.p95()
        );
        s += &format!(
            "tokens             reasoning {}  probes {}  rollout {}\n",
            self.reasoning_tokens, self.probe_count, self.rollout_tokens
        );
        s += &format!(
            "scheduler          preemptions {}  resumes {} (restored {} tok)  spills {}  deadline misses {}\n",
            self.preemptions,
            self.resumes,
            self.resume_prefill_tokens,
            self.kv_spills,
            self.deadline_misses
        );
        if self.migrations_in + self.migrations_out > 0 {
            s += &format!(
                "migration          out {}  in {} ({} tok handed off)\n",
                self.migrations_out, self.migrations_in, self.migrated_tokens
            );
        }
        if self.shed_exits + self.rejected > 0 {
            s += &format!(
                "overload           shed {}  rejected {}   goodput {:.2} req/s   SLO attainment {:.3}\n",
                self.shed_exits,
                self.rejected,
                self.goodput_rps(),
                self.slo_attainment()
            );
        }
        s += "exit reasons       ";
        for (k, v) in &self.exit_reasons {
            s += &format!("{k}:{v} ");
        }
        s += "\n";
        s
    }
}

/// Serving metrics of the black-box coordinator workload (DESIGN.md
/// §3.6): stream/stop/accuracy accounting plus the Fig. 5b *overlap*
/// bookkeeping — per-chunk local proxy compute vs the simulated chunk
/// inter-arrival gap it must hide inside. Clock-injected like
/// [`ServeMetrics`]; under a virtual clock `to_json()` is byte-identical
/// across same-seed runs (the CI blackbox determinism step diffs it).
#[derive(Debug)]
pub struct BlackboxMetrics {
    clock: Clock,
    started: Option<f64>,
    pub completed: usize,
    pub correct: usize,
    /// Streams the monitor stopped before the remote ran out.
    pub stopped_early: usize,
    /// Chunks delivered (probed or not).
    pub chunks: u64,
    /// Chunk-boundary EAT probes issued by the proxy monitor.
    pub probes: u64,
    /// Remote reasoning tokens streamed before stop/termination.
    pub streamed_tokens: u64,
    /// Simulated remote generation time saved by early stops, ms.
    pub saved_ms: f64,
    /// Probed chunks whose proxy compute exceeded the arrival gap —
    /// monitoring that would NOT hide inside the stream latency.
    pub overrun_chunks: u64,
    pub arrival_gap_ms: Summary,
    pub proxy_compute_ms: Summary,
    /// Request latency (submit → finalize) on the shared clock.
    pub latency_ms: Summary,
}

impl BlackboxMetrics {
    pub fn new(clock: Clock) -> Self {
        BlackboxMetrics {
            clock,
            started: None,
            completed: 0,
            correct: 0,
            stopped_early: 0,
            chunks: 0,
            probes: 0,
            streamed_tokens: 0,
            saved_ms: 0.0,
            overrun_chunks: 0,
            arrival_gap_ms: Summary::new(),
            proxy_compute_ms: Summary::new(),
            latency_ms: Summary::new(),
        }
    }

    /// Open the throughput window (idempotent, first submission).
    pub fn mark_start(&mut self) {
        if self.started.is_none() {
            self.started = Some(self.clock.now());
        }
    }

    /// One probed chunk's overlap sample.
    pub fn record_chunk(&mut self, arrival_gap_ms: f64, proxy_compute_ms: f64) {
        self.probes += 1;
        self.arrival_gap_ms.record(arrival_gap_ms);
        self.proxy_compute_ms.record(proxy_compute_ms);
        self.overrun_chunks += (proxy_compute_ms > arrival_gap_ms) as u64;
    }

    /// One finished stream.
    pub fn record_result(
        &mut self,
        correct: bool,
        stopped_early: bool,
        streamed_tokens: usize,
        chunks: usize,
        saved_ms: f64,
        latency_ms: f64,
    ) {
        self.mark_start();
        self.completed += 1;
        self.correct += correct as usize;
        self.stopped_early += stopped_early as usize;
        self.streamed_tokens += streamed_tokens as u64;
        self.chunks += chunks as u64;
        self.saved_ms += saved_ms;
        self.latency_ms.record(latency_ms);
    }

    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.completed.max(1) as f64
    }

    /// Seconds since the first arrival (0 before any traffic).
    pub fn elapsed_s(&self) -> f64 {
        match self.started {
            Some(t0) => (self.clock.now() - t0).max(0.0),
            None => 0.0,
        }
    }

    /// Mean arrival gap over mean proxy compute — how many times over
    /// the monitor could run and still hide inside the stream latency
    /// (Fig. 5b's headroom).
    pub fn overlap_headroom(&self) -> f64 {
        if self.proxy_compute_ms.count() == 0 {
            return 0.0;
        }
        self.arrival_gap_ms.mean() / self.proxy_compute_ms.mean().max(1e-12)
    }

}

impl MetricsReport for BlackboxMetrics {
    /// Deterministic JSON snapshot (byte-identical across same-seed
    /// virtual runs).
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("correct", Json::num(self.correct as f64)),
            ("accuracy", Json::num(self.accuracy())),
            ("stopped_early", Json::num(self.stopped_early as f64)),
            ("chunks", Json::num(self.chunks as f64)),
            ("probes", Json::num(self.probes as f64)),
            ("streamed_tokens", Json::num(self.streamed_tokens as f64)),
            ("saved_ms", Json::num(self.saved_ms)),
            ("overrun_chunks", Json::num(self.overrun_chunks as f64)),
            ("overlap_headroom", Json::num(self.overlap_headroom())),
            ("elapsed_s", Json::num(self.elapsed_s())),
            ("arrival_gap_ms", summary_json(&self.arrival_gap_ms)),
            ("proxy_compute_ms", summary_json(&self.proxy_compute_ms)),
            ("latency_ms", summary_json(&self.latency_ms)),
        ])
    }

    /// One-block human report for `repro serve --blackbox` / examples.
    fn report(&self) -> String {
        let mut s = String::new();
        s += &format!(
            "streams            {:>8}   accuracy {:.3}   stopped early {}/{}\n",
            self.completed,
            self.accuracy(),
            self.stopped_early,
            self.completed
        );
        s += &format!(
            "remote stream      {} tokens over {} chunks   saved {:.1}s simulated\n",
            self.streamed_tokens,
            self.chunks,
            self.saved_ms / 1e3
        );
        s += &format!(
            "proxy monitor      {} probes   compute p50 {:.2} ms  max {:.2} ms\n",
            self.probes,
            self.proxy_compute_ms.p50(),
            self.proxy_compute_ms.max()
        );
        s += &format!(
            "overlap (Fig. 5b)  chunk gap p50 {:.1} ms   headroom {:.0}x   overruns {}\n",
            self.arrival_gap_ms.p50(),
            self.overlap_headroom(),
            self.overrun_chunks
        );
        s += &format!(
            "latency ms         p50 {:>8.1}  p95 {:>8.1}  max {:>8.1}\n",
            self.latency_ms.p50(),
            self.latency_ms.p95(),
            self.latency_ms.max()
        );
        s
    }
}

/// Cluster-level serving metrics (DESIGN.md §3.7): a deterministic
/// snapshot assembled by
/// [`crate::coordinator::cluster::Cluster::metrics`] — router counters
/// plus replica-aggregated totals, with each replica's full
/// [`ServeMetrics`] JSON embedded by replica id. Embedding the replica
/// snapshots verbatim is what makes the CI `cluster(N=1) ≡ single`
/// equivalence check a plain byte diff.
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    pub replicas: usize,
    /// Requests routed to each replica at submission, by replica id.
    pub routed: Vec<u64>,
    /// Mid-flight sessions handed between replicas (state + KV pages).
    pub migrations: u64,
    /// Queued requests rerouted between replicas before first admission.
    pub reroutes: u64,
    /// Committed tokens carried by migrated sessions (repinned from the
    /// shared page pool, never re-prefilled, on the paged store).
    pub migrated_tokens: u64,
    pub completed: usize,
    pub correct: usize,
    pub reasoning_tokens: u64,
    pub preemptions: u64,
    pub resumes: u64,
    pub kv_spills: u64,
    pub deadline_misses: u64,
    /// Saturation load-sheds summed across replicas (DESIGN.md §3.11).
    pub shed_exits: u64,
    /// SLO-expired queue rejections summed across replicas.
    pub rejected: u64,
    /// Seconds from the first cluster arrival to the snapshot.
    pub elapsed_s: f64,
    /// Per-replica [`ServeMetrics`] snapshots, by replica id.
    pub per_replica: Vec<Json>,
}

impl ClusterMetrics {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.completed.max(1) as f64
    }

    /// Within-SLO completions per second over the cluster window — the
    /// goodput the N=1/2/4 scaling bench reports. Without an SLO
    /// (`deadline_misses == 0`) this is plain completed-per-second, so
    /// the pre-saturation bench numbers are unchanged.
    pub fn goodput_rps(&self) -> f64 {
        let within = self.completed - (self.deadline_misses as usize).min(self.completed);
        within as f64 / self.elapsed_s.max(1e-9)
    }

    /// Cluster-wide SLO attainment (within-SLO completions over
    /// completions + rejections).
    pub fn slo_attainment(&self) -> f64 {
        let within = self.completed - (self.deadline_misses as usize).min(self.completed);
        let asked = self.completed + self.rejected as usize;
        within as f64 / asked.max(1) as f64
    }
}

impl MetricsReport for ClusterMetrics {
    fn to_json(&self) -> Json {
        let routed: Vec<Json> = self.routed.iter().map(|&r| Json::num(r as f64)).collect();
        Json::obj(vec![
            ("replicas", Json::num(self.replicas as f64)),
            ("routed", Json::arr(routed)),
            ("migrations", Json::num(self.migrations as f64)),
            ("reroutes", Json::num(self.reroutes as f64)),
            ("migrated_tokens", Json::num(self.migrated_tokens as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("correct", Json::num(self.correct as f64)),
            ("accuracy", Json::num(self.accuracy())),
            ("reasoning_tokens", Json::num(self.reasoning_tokens as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            ("kv_spills", Json::num(self.kv_spills as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("shed_exits", Json::num(self.shed_exits as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("per_replica", Json::arr(self.per_replica.clone())),
        ])
    }

    fn report(&self) -> String {
        let mut s = String::new();
        s += &format!(
            "cluster            {} replicas   routed {:?}\n",
            self.replicas, self.routed
        );
        s += &format!(
            "requests           {:>8}   accuracy {:.3}   goodput {:.2} req/s\n",
            self.completed,
            self.accuracy(),
            self.goodput_rps()
        );
        s += &format!(
            "migration          sessions {} ({} tok handed off)   reroutes {}\n",
            self.migrations, self.migrated_tokens, self.reroutes
        );
        s += &format!(
            "scheduler          preemptions {}  resumes {}  spills {}  deadline misses {}\n",
            self.preemptions, self.resumes, self.kv_spills, self.deadline_misses
        );
        if self.shed_exits + self.rejected > 0 {
            s += &format!(
                "overload           shed {}  rejected {}   SLO attainment {:.3}\n",
                self.shed_exits,
                self.rejected,
                self.slo_attainment()
            );
        }
        s += &format!(
            "tokens             reasoning {}   elapsed {:.2}s\n",
            self.reasoning_tokens, self.elapsed_s
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = ServeMetrics::default();
        m.record_completion(true, 30, 10, 0, 12.0, 1.0, false, ExitReason::Stable);
        m.record_completion(false, 90, 30, 0, 40.0, 2.0, true, ExitReason::TokenBudget);
        assert_eq!(m.completed, 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(m.reasoning_tokens, 120);
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.exit_reasons["Stable"], 1);
        assert_eq!(m.exit_reasons["TokenBudget"], 1);
        assert!(m.report().contains("requests"));
        assert!(m.report().contains("preemptions"));
    }

    #[test]
    fn throughput_window_opens_at_first_arrival_not_construction() {
        // the old ServeMetrics captured Instant::now() in default(),
        // so metrics built before the first arrival inflated elapsed
        let clock = Clock::virt();
        let mut m = ServeMetrics::new(clock.clone());
        clock.advance(100.0); // idle pre-traffic gap
        assert_eq!(m.elapsed_s(), 0.0, "no traffic yet");
        m.mark_start();
        clock.advance(2.0);
        m.record_completion(true, 10, 1, 0, 5.0, 0.5, false, ExitReason::Stable);
        assert!((m.elapsed_s() - 2.0).abs() < 1e-12);
        assert!((m.requests_per_s() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn scheduler_counters_and_timeline() {
        let clock = Clock::virt();
        let mut m = ServeMetrics::new(clock.clone());
        m.sample_slots(1);
        m.sample_slots(1); // deduped
        clock.advance(1.0);
        m.sample_slots(2);
        clock.advance(1.0);
        m.sample_slots(0);
        assert_eq!(m.slot_timeline.len(), 3);
        assert!((m.mean_slot_occupancy() - 1.5).abs() < 1e-9);
        m.record_preemption();
        m.record_resume(40);
        assert_eq!(m.preemptions, 1);
        assert_eq!(m.resumes, 1);
        assert_eq!(m.resume_prefill_tokens, 40);
    }

    #[test]
    fn blackbox_overlap_accounting() {
        let clock = Clock::virt();
        let mut m = BlackboxMetrics::new(clock.clone());
        assert_eq!(m.elapsed_s(), 0.0);
        m.mark_start();
        clock.advance(1.0);
        m.record_chunk(500.0, 2.5); // hides inside the gap
        m.record_chunk(100.0, 150.0); // overrun
        m.record_result(true, true, 40, 5, 2000.0, 900.0);
        m.record_result(false, false, 96, 9, 0.0, 4000.0);
        assert_eq!(m.completed, 2);
        assert_eq!(m.stopped_early, 1);
        assert_eq!(m.chunks, 14);
        assert_eq!(m.probes, 2);
        assert_eq!(m.overrun_chunks, 1);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert!((m.overlap_headroom() - 300.0 / 76.25).abs() < 1e-9);
        let json = m.to_json().to_string();
        assert!(json.contains("\"overlap_headroom\""));
        assert!(json.contains("\"overrun_chunks\""));
        assert!(m.report().contains("overlap (Fig. 5b)"));
    }

    #[test]
    fn blackbox_json_is_stable_under_a_virtual_clock() {
        let build = || {
            let clock = Clock::virt();
            let mut m = BlackboxMetrics::new(clock.clone());
            m.mark_start();
            clock.advance(0.5);
            m.record_chunk(420.0, 3.0);
            m.record_result(true, true, 30, 4, 1500.0, 480.0);
            m.to_json().to_string()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn json_snapshot_is_stable_under_a_virtual_clock() {
        let build = || {
            let clock = Clock::virt();
            let mut m = ServeMetrics::new(clock.clone());
            m.mark_start();
            clock.advance(0.25);
            m.sample_slots(2);
            m.record_completion(true, 12, 4, 0, 250.0, 3.0, false, ExitReason::Stable);
            m.to_json().to_string()
        };
        let (a, b) = (build(), build());
        assert_eq!(a, b, "same-virtual-run snapshots must be byte-identical");
        assert!(a.contains("\"preemptions\""));
        assert!(a.contains("\"p99\""));
    }

    #[test]
    fn overload_counters_goodput_and_slo_attainment() {
        let clock = Clock::virt();
        let mut m = ServeMetrics::new(clock.clone());
        m.mark_start();
        clock.advance(2.0);
        // 3 within SLO, 1 missed, 1 shed (also completes), 2 rejected
        for _ in 0..3 {
            m.record_completion(true, 10, 2, 0, 50.0, 1.0, false, ExitReason::Stable);
        }
        m.record_completion(true, 10, 2, 0, 900.0, 700.0, true, ExitReason::Stable);
        m.record_shed();
        m.record_completion(false, 4, 1, 0, 20.0, 0.5, false, ExitReason::Shed);
        m.record_rejection();
        m.record_rejection();
        assert_eq!(m.completed, 5);
        assert_eq!(m.shed_exits, 1);
        assert_eq!(m.rejected, 2);
        assert_eq!(m.within_slo(), 4);
        assert!((m.goodput_rps() - 2.0).abs() < 1e-9);
        // 4 within SLO of 5 completed + 2 rejected = 7 asked
        assert!((m.slo_attainment() - 4.0 / 7.0).abs() < 1e-12);
        assert_eq!(m.exit_reasons["Shed"], 1);
        let json = m.to_json().to_string();
        assert!(json.contains("\"shed_exits\""));
        assert!(json.contains("\"rejected\""));
        assert!(json.contains("\"goodput_rps\""));
        assert!(json.contains("\"slo_attainment\""));
        assert!(m.report().contains("overload"));
        // the overload line only appears once saturation counters move
        let quiet = ServeMetrics::default();
        assert!(!quiet.report().contains("overload"));
        assert!((quiet.slo_attainment() - 0.0).abs() < 1e-12, "no demand yet");
    }

    #[test]
    fn migration_counters_round_trip() {
        let mut m = ServeMetrics::default();
        m.record_migration_out();
        m.record_migration_in(42);
        m.record_migration_in(0); // a rerouted queued request carries no KV
        assert_eq!(m.migrations_out, 1);
        assert_eq!(m.migrations_in, 2);
        assert_eq!(m.migrated_tokens, 42);
        let json = m.to_json().to_string();
        assert!(json.contains("\"migrations_in\""));
        assert!(json.contains("\"migrated_tokens\""));
        assert!(m.report().contains("migration"));
    }

    #[test]
    fn cluster_metrics_snapshot_is_deterministic() {
        let build = || {
            let mut r0 = ServeMetrics::new(Clock::virt());
            r0.record_completion(true, 20, 5, 0, 100.0, 1.0, false, ExitReason::Stable);
            ClusterMetrics {
                replicas: 2,
                routed: vec![1, 0],
                migrations: 1,
                reroutes: 2,
                migrated_tokens: 17,
                completed: r0.completed,
                correct: r0.correct,
                reasoning_tokens: r0.reasoning_tokens,
                preemptions: 0,
                resumes: 1,
                kv_spills: 0,
                deadline_misses: 0,
                shed_exits: 0,
                rejected: 0,
                elapsed_s: 2.0,
                per_replica: vec![
                    r0.to_json(),
                    ServeMetrics::new(Clock::virt()).to_json(),
                ],
            }
        };
        let (a, b) = (build(), build());
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!((a.goodput_rps() - 0.5).abs() < 1e-12);
        let json = a.to_json().to_string();
        assert!(json.contains("\"per_replica\""));
        assert!(json.contains("\"goodput_rps\""));
        assert!(a.report().contains("cluster"));
    }
}
