//! Serving metrics: throughput, latency, token accounting, exit reasons,
//! scheduler events (preemption/resume/deadline misses) and the slot
//! utilization timeline.
//!
//! Time is read through an injected [`Clock`] rather than
//! `std::time::Instant`, and the throughput window opens at the *first
//! arrival* (`mark_start`) instead of at construction — metrics built
//! before traffic no longer skew elapsed/throughput. Under a virtual
//! clock `to_json()` is byte-identical across same-seed runs; the CI
//! determinism step diffs it.

use std::collections::BTreeMap;

use crate::exit::ExitReason;
use crate::util::clock::Clock;
use crate::util::json::Json;
use crate::util::stats::Summary;

#[derive(Debug)]
pub struct ServeMetrics {
    clock: Clock,
    /// Opened by the first arrival (`mark_start`); `None` until then.
    started: Option<f64>,
    pub completed: usize,
    pub correct: usize,
    pub reasoning_tokens: u64,
    pub probe_count: u64,
    pub rollout_tokens: u64,
    /// KV-slot evictions of long-stalled sessions (EAT-aware mode).
    pub preemptions: u64,
    /// Suspended sessions readmitted (page repin or re-prefill).
    pub resumes: u64,
    /// Tokens restored on resume — re-prefilled under the monolithic
    /// store, repinned for free under the paged store; counted
    /// identically in both so same-seed runs stay byte-comparable
    /// across stores.
    pub resume_prefill_tokens: u64,
    /// Suspended sessions whose retained pages were spilled (host page
    /// budget full): their resume falls back to re-prefill. Always 0
    /// on the monolithic store and under the default page budget.
    pub kv_spills: u64,
    /// Completions that finished past their SLO deadline.
    pub deadline_misses: u64,
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exit_reasons: BTreeMap<String, usize>,
    /// (seconds, slots in use) — appended whenever occupancy changes.
    pub slot_timeline: Vec<(f64, usize)>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new(Clock::wall())
    }
}

impl ServeMetrics {
    pub fn new(clock: Clock) -> Self {
        ServeMetrics {
            clock,
            started: None,
            completed: 0,
            correct: 0,
            reasoning_tokens: 0,
            probe_count: 0,
            rollout_tokens: 0,
            preemptions: 0,
            resumes: 0,
            resume_prefill_tokens: 0,
            kv_spills: 0,
            deadline_misses: 0,
            latency_ms: Summary::new(),
            queue_ms: Summary::new(),
            exit_reasons: BTreeMap::new(),
            slot_timeline: Vec::new(),
        }
    }

    /// Open the throughput window (idempotent; the batcher calls this on
    /// the first submission so pre-traffic construction cannot skew
    /// elapsed/throughput).
    pub fn mark_start(&mut self) {
        if self.started.is_none() {
            self.started = Some(self.clock.now());
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_completion(
        &mut self,
        correct: bool,
        reasoning_tokens: usize,
        probes: usize,
        rollout_tokens: usize,
        latency_ms: f64,
        queue_ms: f64,
        deadline_missed: bool,
        reason: ExitReason,
    ) {
        self.mark_start();
        self.completed += 1;
        self.correct += correct as usize;
        self.reasoning_tokens += reasoning_tokens as u64;
        self.probe_count += probes as u64;
        self.rollout_tokens += rollout_tokens as u64;
        self.deadline_misses += deadline_missed as u64;
        self.latency_ms.record(latency_ms);
        self.queue_ms.record(queue_ms);
        *self
            .exit_reasons
            .entry(format!("{reason:?}"))
            .or_insert(0) += 1;
    }

    pub fn record_preemption(&mut self) {
        self.preemptions += 1;
    }

    pub fn record_resume(&mut self, prefill_tokens: usize) {
        self.resumes += 1;
        self.resume_prefill_tokens += prefill_tokens as u64;
    }

    pub fn record_spill(&mut self) {
        self.kv_spills += 1;
    }

    /// Append a slot-occupancy sample if occupancy changed.
    pub fn sample_slots(&mut self, in_use: usize) {
        if self.slot_timeline.last().map(|&(_, u)| u) == Some(in_use) {
            return;
        }
        self.slot_timeline.push((self.clock.now(), in_use));
    }

    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.completed.max(1) as f64
    }

    /// Seconds since the first arrival (0 before any traffic).
    pub fn elapsed_s(&self) -> f64 {
        match self.started {
            Some(t0) => (self.clock.now() - t0).max(0.0),
            None => 0.0,
        }
    }

    pub fn requests_per_s(&self) -> f64 {
        self.completed as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.reasoning_tokens as f64 / self.elapsed_s().max(1e-9)
    }

    /// Mean slot occupancy over the timeline (time-weighted), for
    /// reports; 0 without samples.
    pub fn mean_slot_occupancy(&self) -> f64 {
        if self.slot_timeline.len() < 2 {
            return self.slot_timeline.last().map(|&(_, u)| u as f64).unwrap_or(0.0);
        }
        let mut area = 0.0;
        for w in self.slot_timeline.windows(2) {
            area += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        let span = self.slot_timeline.last().unwrap().0 - self.slot_timeline[0].0;
        if span <= 0.0 {
            self.slot_timeline.last().map(|&(_, u)| u as f64).unwrap_or(0.0)
        } else {
            area / span
        }
    }

    /// Deterministic JSON snapshot: every counter plus latency/queue
    /// percentiles and the slot timeline. Under a virtual clock two
    /// same-seed runs serialize byte-identically.
    pub fn to_json(&self) -> Json {
        let summary = |s: &Summary| {
            Json::obj(vec![
                ("count", Json::num(s.count() as f64)),
                ("mean", Json::num(s.mean())),
                ("min", Json::num(s.min())),
                ("p50", Json::num(s.p50())),
                ("p95", Json::num(s.p95())),
                ("p99", Json::num(s.p99())),
                ("max", Json::num(s.max())),
            ])
        };
        let reasons: Vec<(&str, Json)> = self
            .exit_reasons
            .iter()
            .map(|(k, &v)| (k.as_str(), Json::num(v as f64)))
            .collect();
        let timeline: Vec<Json> = self
            .slot_timeline
            .iter()
            .map(|&(t, u)| Json::arr(vec![Json::num(t), Json::num(u as f64)]))
            .collect();
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("correct", Json::num(self.correct as f64)),
            ("accuracy", Json::num(self.accuracy())),
            ("reasoning_tokens", Json::num(self.reasoning_tokens as f64)),
            ("probe_count", Json::num(self.probe_count as f64)),
            ("rollout_tokens", Json::num(self.rollout_tokens as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            ("resume_prefill_tokens", Json::num(self.resume_prefill_tokens as f64)),
            ("kv_spills", Json::num(self.kv_spills as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("elapsed_s", Json::num(self.elapsed_s())),
            ("latency_ms", summary(&self.latency_ms)),
            ("queue_ms", summary(&self.queue_ms)),
            ("exit_reasons", Json::obj(reasons)),
            ("slot_timeline", Json::arr(timeline)),
        ])
    }

    /// One-block human report for examples / `repro serve`.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s += &format!(
            "requests           {:>8}   accuracy {:.3}\n",
            self.completed,
            self.accuracy()
        );
        s += &format!(
            "throughput         {:>8.2} req/s   {:.1} reasoning tok/s\n",
            self.requests_per_s(),
            self.tokens_per_s()
        );
        s += &format!(
            "latency ms         p50 {:>8.1}  p95 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
            self.latency_ms.p50(),
            self.latency_ms.p95(),
            self.latency_ms.p99(),
            self.latency_ms.max()
        );
        s += &format!(
            "queueing ms        p50 {:>8.1}  p95 {:>8.1}\n",
            self.queue_ms.p50(),
            self.queue_ms.p95()
        );
        s += &format!(
            "tokens             reasoning {}  probes {}  rollout {}\n",
            self.reasoning_tokens, self.probe_count, self.rollout_tokens
        );
        s += &format!(
            "scheduler          preemptions {}  resumes {} (restored {} tok)  spills {}  deadline misses {}\n",
            self.preemptions,
            self.resumes,
            self.resume_prefill_tokens,
            self.kv_spills,
            self.deadline_misses
        );
        s += "exit reasons       ";
        for (k, v) in &self.exit_reasons {
            s += &format!("{k}:{v} ");
        }
        s += "\n";
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = ServeMetrics::default();
        m.record_completion(true, 30, 10, 0, 12.0, 1.0, false, ExitReason::Stable);
        m.record_completion(false, 90, 30, 0, 40.0, 2.0, true, ExitReason::TokenBudget);
        assert_eq!(m.completed, 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(m.reasoning_tokens, 120);
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.exit_reasons["Stable"], 1);
        assert_eq!(m.exit_reasons["TokenBudget"], 1);
        assert!(m.report().contains("requests"));
        assert!(m.report().contains("preemptions"));
    }

    #[test]
    fn throughput_window_opens_at_first_arrival_not_construction() {
        // the old ServeMetrics captured Instant::now() in default(),
        // so metrics built before the first arrival inflated elapsed
        let clock = Clock::virt();
        let mut m = ServeMetrics::new(clock.clone());
        clock.advance(100.0); // idle pre-traffic gap
        assert_eq!(m.elapsed_s(), 0.0, "no traffic yet");
        m.mark_start();
        clock.advance(2.0);
        m.record_completion(true, 10, 1, 0, 5.0, 0.5, false, ExitReason::Stable);
        assert!((m.elapsed_s() - 2.0).abs() < 1e-12);
        assert!((m.requests_per_s() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn scheduler_counters_and_timeline() {
        let clock = Clock::virt();
        let mut m = ServeMetrics::new(clock.clone());
        m.sample_slots(1);
        m.sample_slots(1); // deduped
        clock.advance(1.0);
        m.sample_slots(2);
        clock.advance(1.0);
        m.sample_slots(0);
        assert_eq!(m.slot_timeline.len(), 3);
        assert!((m.mean_slot_occupancy() - 1.5).abs() < 1e-9);
        m.record_preemption();
        m.record_resume(40);
        assert_eq!(m.preemptions, 1);
        assert_eq!(m.resumes, 1);
        assert_eq!(m.resume_prefill_tokens, 40);
    }

    #[test]
    fn json_snapshot_is_stable_under_a_virtual_clock() {
        let build = || {
            let clock = Clock::virt();
            let mut m = ServeMetrics::new(clock.clone());
            m.mark_start();
            clock.advance(0.25);
            m.sample_slots(2);
            m.record_completion(true, 12, 4, 0, 250.0, 3.0, false, ExitReason::Stable);
            m.to_json().to_string()
        };
        let (a, b) = (build(), build());
        assert_eq!(a, b, "same-virtual-run snapshots must be byte-identical");
        assert!(a.contains("\"preemptions\""));
        assert!(a.contains("\"p99\""));
    }
}
