//! Serving metrics: throughput, latency, token accounting, exit reasons.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::exit::ExitReason;
use crate::util::stats::Summary;

#[derive(Debug)]
pub struct ServeMetrics {
    started: Instant,
    pub completed: usize,
    pub correct: usize,
    pub reasoning_tokens: u64,
    pub probe_count: u64,
    pub rollout_tokens: u64,
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exit_reasons: BTreeMap<String, usize>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            started: Instant::now(),
            completed: 0,
            correct: 0,
            reasoning_tokens: 0,
            probe_count: 0,
            rollout_tokens: 0,
            latency_ms: Summary::new(),
            queue_ms: Summary::new(),
            exit_reasons: BTreeMap::new(),
        }
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(
        &mut self,
        correct: bool,
        reasoning_tokens: usize,
        probes: usize,
        rollout_tokens: usize,
        latency_ms: f64,
        queue_ms: f64,
        reason: ExitReason,
    ) {
        self.completed += 1;
        self.correct += correct as usize;
        self.reasoning_tokens += reasoning_tokens as u64;
        self.probe_count += probes as u64;
        self.rollout_tokens += rollout_tokens as u64;
        self.latency_ms.record(latency_ms);
        self.queue_ms.record(queue_ms);
        *self
            .exit_reasons
            .entry(format!("{reason:?}"))
            .or_insert(0) += 1;
    }

    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.completed.max(1) as f64
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn requests_per_s(&self) -> f64 {
        self.completed as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.reasoning_tokens as f64 / self.elapsed_s().max(1e-9)
    }

    /// One-block human report for examples / `repro serve`.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s += &format!(
            "requests           {:>8}   accuracy {:.3}\n",
            self.completed,
            self.accuracy()
        );
        s += &format!(
            "throughput         {:>8.2} req/s   {:.1} reasoning tok/s\n",
            self.requests_per_s(),
            self.tokens_per_s()
        );
        s += &format!(
            "latency ms         p50 {:>8.1}  p95 {:>8.1}  max {:>8.1}\n",
            self.latency_ms.p50(),
            self.latency_ms.p95(),
            self.latency_ms.max()
        );
        s += &format!(
            "queueing ms        p50 {:>8.1}  p95 {:>8.1}\n",
            self.queue_ms.p50(),
            self.queue_ms.p95()
        );
        s += &format!(
            "tokens             reasoning {}  probes {}  rollout {}\n",
            self.reasoning_tokens, self.probe_count, self.rollout_tokens
        );
        s += "exit reasons       ";
        for (k, v) in &self.exit_reasons {
            s += &format!("{k}:{v} ");
        }
        s += "\n";
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = ServeMetrics::new();
        m.record_completion(true, 30, 10, 0, 12.0, 1.0, ExitReason::Stable);
        m.record_completion(false, 90, 30, 0, 40.0, 2.0, ExitReason::TokenBudget);
        assert_eq!(m.completed, 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(m.reasoning_tokens, 120);
        assert_eq!(m.exit_reasons["Stable"], 1);
        assert_eq!(m.exit_reasons["TokenBudget"], 1);
        assert!(m.report().contains("requests"));
    }
}
