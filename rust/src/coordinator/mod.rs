//! L3 coordinator — the paper's system contribution: EAT-monitored
//! early-exit reasoning serving.
//!
//!  * `engine`      — split-phase per-request state machine: `poll()` →
//!    [`engine::StepWork`] / `complete_*(..)`; no model reference inside
//!    the session
//!  * `batcher`     — continuous batching + EAT-aware preemptive
//!    scheduler: one fused `decode_batch` per scheduling tick,
//!    probes/rollouts out-of-band, sequential fallback,
//!    preempt/resume-by-re-prefill under contention (DESIGN.md §3.4)
//!  * `workload`    — open-loop workload driver over the
//!    [`workload::ArrivalProcess`] zoo (Poisson / bursty MMPP / diurnal
//!    / trace replay; deterministic under a virtual clock), generic
//!    over [`OpenLoopTarget`] so it paces the white-box batcher and the
//!    black-box stream batcher alike
//!  * `batch_cache` — slot-major cache store with page-granular dirty
//!    upload accounting
//!  * `kv`          — paged KV subsystem: refcounted page allocator,
//!    copy-on-write page pool, page-budget admission manager
//!  * `cluster`     — multi-replica router: EAT-aware placement over N
//!    batchers sharing one runtime, with live session migration as a
//!    page handoff (DESIGN.md §3.7)
//!  * `metrics`     — serving metrics behind the one [`MetricsReport`]
//!    interface (clock-injected, deterministic JSON snapshot)
//!  * `soak`        — memory-bounded million-session soak core on the
//!    event wheel + slab arena (DESIGN.md §3.10), with the pre-wheel
//!    tick-scan driver kept as the benchmarked baseline

pub mod batch_cache;
pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod soak;
pub mod workload;

pub use batch_cache::BatchCacheStore;
pub use batcher::{
    eat_policy_factory, pick_shed_victims, zoo_policy_factory, Batcher, Migration, PolicyFactory,
    SuspendedSession, DEFAULT_TICK_DT,
};
pub use cluster::{Cluster, ClusterConfig, RoutePolicy};
pub use engine::{
    resume_session, serve_one, MonitorModel, ProbeTarget, ReasoningSession, RequestResult,
    StepWork,
};
pub use kv::{KvPageManager, PageAllocator, PageId, PagePool, PageTable, DEFAULT_PAGE_SIZE};
pub use metrics::{summary_json, BlackboxMetrics, ClusterMetrics, MetricsReport, ServeMetrics};
pub use soak::{
    capacity_per_s, run_soak, session_correct, session_demand, SoakConfig, SoakMode, SoakReport,
};
pub use workload::{
    build_arrivals, collect_arrivals, poisson_arrivals, run_open_loop, run_open_loop_stream,
    ArrivalProcess, BurstStream, DiurnalStream, OpenLoopTarget, PoissonStream, TraceStream,
};
