//! L3 coordinator — the paper's system contribution: EAT-monitored
//! early-exit reasoning serving.
//!
//!  * `engine`  — per-request reasoning state machine (prefill -> line
//!    loop with EAT probes -> answer elicitation)
//!  * `batcher` — continuous batching over sessions with KV admission
//!  * `kv`      — KV slot manager (capacity + backpressure)
//!  * `metrics` — serving metrics

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod metrics;

pub use batcher::Batcher;
pub use engine::{serve_one, MonitorModel, ReasoningSession, RequestResult};
pub use kv::KvSlotManager;
pub use metrics::ServeMetrics;
