//! L3 coordinator — the paper's system contribution: EAT-monitored
//! early-exit reasoning serving.
//!
//!  * `engine`      — split-phase per-request state machine: `poll()` →
//!    [`engine::StepWork`] / `complete_*(..)`; no model reference inside
//!    the session
//!  * `batcher`     — continuous batching: one fused `decode_batch` per
//!    scheduling tick, probes/rollouts out-of-band, sequential fallback
//!  * `batch_cache` — slot-major cache store with dirty-slot upload
//!    accounting
//!  * `kv`          — KV slot manager (capacity + backpressure)
//!  * `metrics`     — serving metrics

pub mod batch_cache;
pub mod batcher;
pub mod engine;
pub mod kv;
pub mod metrics;

pub use batch_cache::BatchCacheStore;
pub use batcher::Batcher;
pub use engine::{
    serve_one, MonitorModel, ProbeTarget, ReasoningSession, RequestResult, StepWork,
};
pub use kv::KvSlotManager;
pub use metrics::ServeMetrics;
