//! Paged KV subsystem: a refcounted block allocator, a typed page pool
//! with copy-on-write, and the serving-side page-budget manager
//! (DESIGN.md §3.5).
//!
//! The paper's whole premise is that the EAT probe is *inexpensive*:
//! append `</think>`, read one token's entropy. A monolithic
//! full-sequence cache betrays that premise operationally — every
//! rollout fork pays an O(seq) copy and every preemption pays a full
//! re-prefill. The paged store fixes the cost model the way vLLM-style
//! paged attention does:
//!
//!  * caches become page tables over a shared [`PagePool`];
//!  * `fork()` is O(pages) refcount bumps; the first divergent write
//!    copies exactly one page (copy-on-write);
//!  * probes read the page table without touching the pool at all;
//!  * suspend/resume unpins and repins pages instead of re-prefilling
//!    (the re-prefill path survives as the spill fallback and the
//!    equivalence oracle).
//!
//! [`KvPageManager`] is the coordinator-side accounting: admission
//! requires a free batch lane *and* worst-case page headroom in the
//! device budget, and suspended sessions retain their pages against a
//! host-side budget (exceeding it spills: the pages are dropped and the
//! session falls back to resume-by-re-prefill).

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::Result;

/// Batch lane of an admitted request (index into the
/// [`crate::coordinator::BatchCacheStore`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId(pub usize);

/// Handle to one fixed-size page in a [`PageAllocator`] / [`PagePool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageId(pub u32);

/// Default tokens per KV page (the paged reference runtime's geometry).
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Allocator-level accounting. `allocs`/`frees` are asserted by the
/// allocator proptests; the serving-level CoW audit (what the bench and
/// the batching tests quote) lives in
/// [`crate::runtime::backend::RuntimeCounters`] instead.
#[derive(Debug, Default, Clone, Copy)]
pub struct AllocCounters {
    /// Pages handed out (fresh allocations).
    pub allocs: u64,
    /// Pages whose refcount hit zero (returned to the freelist).
    pub frees: u64,
    /// Refcount bumps (cache forks sharing pages).
    pub retains: u64,
}

/// Refcounted fixed-size block allocator. Pages are identified by
/// [`PageId`]; `alloc` hands out a page at refcount 1, `retain` bumps,
/// `release` drops — the page returns to the freelist exactly when its
/// refcount hits zero. Double release and retain-after-free are errors,
/// not corruption.
#[derive(Debug)]
pub struct PageAllocator {
    /// Refcount per page id; 0 = free.
    refcounts: Vec<u32>,
    free: Vec<u32>,
    /// `None` = growable (backend pools); `Some(n)` = hard capacity
    /// (budget-style use and the proptests).
    capacity: Option<usize>,
    in_use: usize,
    peak: usize,
    pub counters: AllocCounters,
}

impl PageAllocator {
    /// Fixed-capacity allocator: `alloc` fails once `capacity` pages are
    /// live.
    pub fn new_fixed(capacity: usize) -> PageAllocator {
        PageAllocator {
            refcounts: vec![0; capacity],
            free: (0..capacity as u32).rev().collect(),
            capacity: Some(capacity),
            in_use: 0,
            peak: 0,
            counters: AllocCounters::default(),
        }
    }

    /// Growable allocator (backend page pools): the serving budget is
    /// enforced by [`KvPageManager`], not here.
    pub fn new_growable() -> PageAllocator {
        PageAllocator {
            refcounts: Vec::new(),
            free: Vec::new(),
            capacity: None,
            in_use: 0,
            peak: 0,
            counters: AllocCounters::default(),
        }
    }

    /// Pages currently live (refcount > 0).
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Peak concurrent live pages.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Page ids ever materialized (live + freelist).
    pub fn allocated(&self) -> usize {
        self.refcounts.len()
    }

    /// Free pages immediately available without growth.
    pub fn available(&self) -> usize {
        match self.capacity {
            Some(c) => c - self.in_use,
            None => self.free.len(),
        }
    }

    pub fn refcount(&self, page: PageId) -> u32 {
        self.refcounts.get(page.0 as usize).copied().unwrap_or(0)
    }

    /// Allocate a page at refcount 1. Errors only at a fixed capacity
    /// limit.
    pub fn alloc(&mut self) -> Result<PageId> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                anyhow::ensure!(
                    self.capacity.is_none(),
                    "page pool exhausted ({} pages)",
                    self.refcounts.len()
                );
                let id = self.refcounts.len() as u32;
                self.refcounts.push(0);
                id
            }
        };
        debug_assert_eq!(self.refcounts[id as usize], 0);
        self.refcounts[id as usize] = 1;
        self.in_use += 1;
        self.peak = self.peak.max(self.in_use);
        self.counters.allocs += 1;
        Ok(PageId(id))
    }

    /// Bump the refcount of a live page (cache fork).
    pub fn retain(&mut self, page: PageId) -> Result<()> {
        let rc = self
            .refcounts
            .get_mut(page.0 as usize)
            .ok_or_else(|| anyhow::anyhow!("retain of unknown page {}", page.0))?;
        anyhow::ensure!(*rc > 0, "retain of freed page {}", page.0);
        *rc += 1;
        self.counters.retains += 1;
        Ok(())
    }

    /// Drop one reference; returns true when the page was freed (its
    /// refcount hit zero exactly now).
    pub fn release(&mut self, page: PageId) -> Result<bool> {
        let rc = self
            .refcounts
            .get_mut(page.0 as usize)
            .ok_or_else(|| anyhow::anyhow!("release of unknown page {}", page.0))?;
        anyhow::ensure!(*rc > 0, "double free of page {}", page.0);
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page.0);
            self.in_use -= 1;
            self.counters.frees += 1;
            Ok(true)
        } else {
            Ok(false)
        }
    }
}

/// A typed page pool: the [`PageAllocator`] plus the page payloads.
/// Both backends keep one pool per model; every cache of that model is
/// a page table into it. `make_unique` is the copy-on-write primitive:
/// a shared page is copied on first divergent write, an exclusive page
/// is written in place.
#[derive(Debug)]
pub struct PagePool<T> {
    alloc: PageAllocator,
    page_elems: usize,
    data: Vec<Vec<T>>,
}

impl<T: Clone + Default> PagePool<T> {
    pub fn new_growable(page_elems: usize) -> PagePool<T> {
        PagePool {
            alloc: PageAllocator::new_growable(),
            page_elems,
            data: Vec::new(),
        }
    }

    /// Elements per page.
    pub fn page_elems(&self) -> usize {
        self.page_elems
    }

    pub fn pages_in_use(&self) -> usize {
        self.alloc.in_use()
    }

    pub fn counters(&self) -> AllocCounters {
        self.alloc.counters
    }

    /// Allocate a zero-filled page at refcount 1.
    pub fn alloc_zeroed(&mut self) -> Result<PageId> {
        let id = self.alloc.alloc()?;
        let idx = id.0 as usize;
        if idx == self.data.len() {
            self.data.push(vec![T::default(); self.page_elems]);
        } else {
            self.data[idx].fill(T::default());
        }
        Ok(id)
    }

    pub fn retain(&mut self, page: PageId) -> Result<()> {
        self.alloc.retain(page)
    }

    pub fn release(&mut self, page: PageId) -> Result<bool> {
        self.alloc.release(page)
    }

    pub fn refcount(&self, page: PageId) -> u32 {
        self.alloc.refcount(page)
    }

    /// Read a page (shared access is fine at any refcount).
    pub fn page(&self, page: PageId) -> &[T] {
        &self.data[page.0 as usize]
    }

    /// Write a page. Legal only on an exclusively held page — callers
    /// go through [`PagePool::make_unique`] first.
    pub fn page_mut(&mut self, page: PageId) -> Result<&mut [T]> {
        anyhow::ensure!(
            self.alloc.refcount(page) == 1,
            "write to shared page {} (refcount {})",
            page.0,
            self.alloc.refcount(page)
        );
        Ok(&mut self.data[page.0 as usize])
    }

    /// Copy-on-write: return a page id the caller may write through.
    /// Exclusive pages come back unchanged (`copied == false`); shared
    /// pages are copied into a fresh page, the caller's reference moves
    /// to the copy, and the original keeps its other holders.
    pub fn make_unique(&mut self, page: PageId) -> Result<(PageId, bool)> {
        anyhow::ensure!(
            self.alloc.refcount(page) > 0,
            "make_unique of freed page {}",
            page.0
        );
        if self.alloc.refcount(page) == 1 {
            return Ok((page, false));
        }
        let copy = self.data[page.0 as usize].clone();
        let fresh = self.alloc.alloc()?;
        // the allocator may have grown past the payload vec (freelist
        // empty): materialize the new page's payload slot
        let idx = fresh.0 as usize;
        if idx == self.data.len() {
            self.data.push(copy);
        } else {
            self.data[idx] = copy;
        }
        let freed = self.alloc.release(page)?;
        debug_assert!(!freed, "shared page cannot free on CoW release");
        Ok((fresh, true))
    }
}

/// An *owning* page table over a shared [`PagePool`]: the
/// retain-on-Clone / release-on-Drop refcount discipline both backends
/// used to hand-roll (`PagedTokens` in `runtime/reference.rs`, `PagedKv`
/// in `runtime/model.rs`), implemented once. Cloning retains every page
/// — the O(pages) copy-on-write fork — and dropping releases them, so a
/// table can never leak or double-free a page. Writes funnel through
/// [`PageTable::write`], which CoWs a shared page before handing out the
/// mutable payload.
#[derive(Debug)]
pub struct PageTable<T: Clone + Default> {
    pool: Rc<RefCell<PagePool<T>>>,
    pages: Vec<PageId>,
}

impl<T: Clone + Default> PageTable<T> {
    /// An empty table over `pool`.
    pub fn new(pool: Rc<RefCell<PagePool<T>>>) -> PageTable<T> {
        PageTable {
            pool,
            pages: Vec::new(),
        }
    }

    /// The shared pool this table indexes into.
    pub fn pool(&self) -> &Rc<RefCell<PagePool<T>>> {
        &self.pool
    }

    pub fn pages(&self) -> &[PageId] {
        &self.pages
    }

    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    /// Append a fresh zero-filled page (refcount 1, exclusively owned).
    pub fn push_zeroed(&mut self) -> Result<PageId> {
        let id = self.pool.borrow_mut().alloc_zeroed()?;
        self.pages.push(id);
        Ok(id)
    }

    /// Append zero pages until the table covers `n_pages` entries.
    pub fn grow_to(&mut self, n_pages: usize) -> Result<()> {
        while self.pages.len() < n_pages {
            self.push_zeroed()?;
        }
        Ok(())
    }

    /// Copy-on-write entry `idx`: after this call the table holds a page
    /// it may write through. Returns (page id, whether a physical copy
    /// happened).
    pub fn make_unique(&mut self, idx: usize) -> Result<(PageId, bool)> {
        let (id, copied) = self.pool.borrow_mut().make_unique(self.pages[idx])?;
        self.pages[idx] = id;
        Ok((id, copied))
    }

    /// Read page `idx`'s payload.
    pub fn read<R>(&self, idx: usize, f: impl FnOnce(&[T]) -> R) -> R {
        f(self.pool.borrow().page(self.pages[idx]))
    }

    /// Write through page `idx` (CoW first when shared). Returns the
    /// closure's result and whether a page was physically copied.
    pub fn write<R>(&mut self, idx: usize, f: impl FnOnce(&mut [T]) -> R) -> Result<(R, bool)> {
        let (id, copied) = self.make_unique(idx)?;
        let mut pool = self.pool.borrow_mut();
        Ok((f(pool.page_mut(id)?), copied))
    }
}

impl<T: Clone + Default> Clone for PageTable<T> {
    fn clone(&self) -> PageTable<T> {
        let mut pool = self.pool.borrow_mut();
        for pg in &self.pages {
            pool.retain(*pg).expect("cloning a table with live pages");
        }
        drop(pool);
        PageTable {
            pool: self.pool.clone(),
            pages: self.pages.clone(),
        }
    }
}

impl<T: Clone + Default> Drop for PageTable<T> {
    fn drop(&mut self) {
        let mut pool = self.pool.borrow_mut();
        for pg in self.pages.drain(..) {
            // a poisoned pool during unwind must not double-panic
            let _ = pool.release(pg);
        }
    }
}

/// Pages needed to hold `tokens` tokens at `page_size` tokens per page.
pub fn pages_for(tokens: usize, page_size: usize) -> usize {
    tokens.div_ceil(page_size.max(1))
}

/// Serving-side KV admission accounting (paged replacement for the old
/// full-sequence slot manager). Three budgets interact:
///
///  * **lanes** — fixed batch lanes in the cache store (one resident
///    session each), exactly the old slot semantics;
///  * **device pages** — a session is admitted only when
///    `pinned + reserve_pages <= device_capacity`, where `reserve_pages`
///    is the worst case (full sequence, both models). With the default
///    capacity of `lanes * reserve_pages` this degenerates to pure lane
///    admission — which is what keeps paged and monolithic serve runs
///    byte-identical — while `--kv-pages` can tighten it so page budget,
///    not lane count, becomes the admission gate;
///  * **host pages** — suspended sessions retain their (unpinned) pages
///    here; when retention would overflow, the caller spills (drops the
///    pages, falls back to resume-by-re-prefill).
#[derive(Debug)]
pub struct KvPageManager {
    lanes: usize,
    free_lanes: Vec<usize>,
    page_size: usize,
    /// Worst-case pages pinned per resident session (main [+ proxy],
    /// full sequence).
    reserve_pages: usize,
    device_capacity: usize,
    host_capacity: usize,
    /// Reserved pages of resident sessions.
    pinned: usize,
    /// Retained pages of suspended sessions.
    host_held: usize,
    peak_sessions: usize,
    peak_pinned: usize,
    /// Hierarchical per-tenant pinned-page budgets (DESIGN.md §3.11),
    /// sorted by tenant id — only tenants with an explicit cap appear,
    /// so the default (empty) configuration adds zero work and zero
    /// behavior change. No hash maps: binary search + linear vecs keep
    /// iteration order deterministic.
    tenant_budgets: Vec<TenantBudget>,
    /// Which capped tenant each lane is charged to (None for lanes of
    /// uncapped tenants), so release() can uncharge without the caller
    /// replaying the tenant id.
    lane_tenant: Vec<Option<u32>>,
}

/// Pinned-page accounting for one capped tenant.
#[derive(Debug)]
struct TenantBudget {
    tenant: u32,
    cap: usize,
    pinned: usize,
}

impl KvPageManager {
    /// `kv_pages` overrides the device capacity *and* bounds the
    /// host-side retention of suspended pages. `None` keeps the
    /// lane-equivalent device default (`lanes * reserve_pages`) with
    /// unbounded host retention — so the default paged configuration
    /// never spills, which is what keeps its serve runs byte-identical
    /// to the monolithic store's.
    pub fn new(
        lanes: usize,
        page_size: usize,
        reserve_pages: usize,
        kv_pages: Option<usize>,
    ) -> KvPageManager {
        let reserve_pages = reserve_pages.max(1);
        let default_cap = lanes * reserve_pages;
        // at least one worst-case session must fit, or admission could
        // never make progress
        let cap = kv_pages.unwrap_or(default_cap).max(reserve_pages);
        KvPageManager {
            lanes,
            free_lanes: (0..lanes).rev().collect(),
            page_size,
            reserve_pages,
            device_capacity: cap,
            host_capacity: kv_pages.map(|p| p.max(reserve_pages)).unwrap_or(usize::MAX),
            pinned: 0,
            host_held: 0,
            peak_sessions: 0,
            peak_pinned: 0,
            tenant_budgets: Vec::new(),
            lane_tenant: vec![None; lanes],
        }
    }

    /// Cap a tenant's pinned pages. Clamped up to one worst-case
    /// reservation so a capped tenant can always make progress
    /// eventually (a zero cap would wedge its queue forever while the
    /// round-robin keeps skipping it).
    pub fn set_tenant_cap(&mut self, tenant: u32, pages: usize) {
        let cap = pages.max(self.reserve_pages);
        match self.tenant_budgets.binary_search_by_key(&tenant, |b| b.tenant) {
            Ok(i) => self.tenant_budgets[i].cap = cap,
            Err(i) => self.tenant_budgets.insert(
                i,
                TenantBudget {
                    tenant,
                    cap,
                    pinned: 0,
                },
            ),
        }
    }

    fn budget_idx(&self, tenant: u32) -> Option<usize> {
        if self.tenant_budgets.is_empty() {
            return None; // default config: nothing to look up
        }
        self.tenant_budgets
            .binary_search_by_key(&tenant, |b| b.tenant)
            .ok()
    }

    /// Would a worst-case reservation for this tenant stay inside its
    /// cap? Uncapped tenants always pass (global gates still apply in
    /// `acquire_for`).
    pub fn tenant_can_admit(&self, tenant: u32) -> bool {
        match self.budget_idx(tenant) {
            Some(i) => {
                let b = &self.tenant_budgets[i];
                b.pinned + self.reserve_pages <= b.cap
            }
            None => true,
        }
    }

    /// Pages currently pinned under a tenant's cap (0 for uncapped
    /// tenants — their usage is only tracked globally).
    pub fn tenant_pinned_pages(&self, tenant: u32) -> usize {
        self.budget_idx(tenant)
            .map_or(0, |i| self.tenant_budgets[i].pinned)
    }

    pub fn capacity(&self) -> usize {
        self.lanes
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn reserve_pages(&self) -> usize {
        self.reserve_pages
    }

    pub fn device_capacity_pages(&self) -> usize {
        self.device_capacity
    }

    /// Resident sessions.
    pub fn in_use(&self) -> usize {
        self.lanes - self.free_lanes.len()
    }

    /// Peak resident sessions.
    pub fn peak(&self) -> usize {
        self.peak_sessions
    }

    pub fn pinned_pages(&self) -> usize {
        self.pinned
    }

    pub fn peak_pinned_pages(&self) -> usize {
        self.peak_pinned
    }

    pub fn host_held_pages(&self) -> usize {
        self.host_held
    }

    /// Sessions admissible right now: free lanes AND device page
    /// headroom for a worst-case reservation each.
    pub fn available(&self) -> usize {
        let by_pages = (self.device_capacity - self.pinned.min(self.device_capacity))
            / self.reserve_pages;
        self.free_lanes.len().min(by_pages)
    }

    /// Resident-session utilization (lane-based, matching the old slot
    /// manager's meaning for the metrics endpoint).
    pub fn utilization(&self) -> f64 {
        self.in_use() as f64 / self.lanes.max(1) as f64
    }

    /// Admit a session: claims a lane and pins a worst-case page
    /// reservation. None when at lane or page capacity (backpressure).
    pub fn acquire(&mut self) -> Option<SlotId> {
        if self.pinned + self.reserve_pages > self.device_capacity {
            return None;
        }
        let lane = self.free_lanes.pop()?;
        self.pinned += self.reserve_pages;
        self.peak_pinned = self.peak_pinned.max(self.pinned);
        self.peak_sessions = self.peak_sessions.max(self.in_use());
        Some(SlotId(lane))
    }

    /// `acquire`, charged against `tenant`'s budget when one is
    /// configured. With no caps set this is exactly `acquire` — the
    /// single-tenant default path stays bit-identical.
    pub fn acquire_for(&mut self, tenant: u32) -> Option<SlotId> {
        let budget = self.budget_idx(tenant);
        if let Some(i) = budget {
            let b = &self.tenant_budgets[i];
            if b.pinned + self.reserve_pages > b.cap {
                return None;
            }
        }
        let slot = self.acquire()?;
        if let Some(i) = budget {
            self.tenant_budgets[i].pinned += self.reserve_pages;
            self.lane_tenant[slot.0] = Some(tenant);
        }
        Some(slot)
    }

    /// Release a session's lane + pinned reservation (retire or
    /// preemption).
    pub fn release(&mut self, slot: SlotId) -> Result<()> {
        anyhow::ensure!(
            slot.0 < self.lanes && !self.free_lanes.contains(&slot.0),
            "double free of KV lane {}",
            slot.0
        );
        self.free_lanes.push(slot.0);
        self.pinned -= self.reserve_pages;
        if let Some(tenant) = self.lane_tenant[slot.0].take() {
            if let Some(i) = self.budget_idx(tenant) {
                let b = &mut self.tenant_budgets[i];
                b.pinned = b.pinned.saturating_sub(self.reserve_pages);
            }
        }
        Ok(())
    }

    /// Try to retain `pages` unpinned pages of a suspending session on
    /// the host-side budget. False = no room: the caller must spill
    /// (drop the pages; the session resumes by re-prefill).
    pub fn try_hold_suspended(&mut self, pages: usize) -> bool {
        if self.host_held + pages > self.host_capacity {
            return false;
        }
        self.host_held += pages;
        true
    }

    /// Return a resuming (or spilled-at-resume) session's retained pages
    /// to the host budget.
    pub fn release_suspended(&mut self, pages: usize) {
        debug_assert!(pages <= self.host_held, "suspended page accounting underflow");
        self.host_held = self.host_held.saturating_sub(pages);
    }

    /// Move `pages` of suspended-retention accounting from this manager
    /// to `dst` (cluster session migration, DESIGN.md §3.7). The pages
    /// themselves never move — every replica's manager draws on one
    /// shared [`PagePool`] — only the host-budget charge does. The
    /// charge leaves this manager either way; false means `dst` could
    /// not absorb it and the caller must spill (drop the retained
    /// caches, resume by re-prefill).
    pub fn transfer_suspended(&mut self, dst: &mut KvPageManager, pages: usize) -> bool {
        self.release_suspended(pages);
        dst.try_hold_suspended(pages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_retain_release_cycle() {
        let mut a = PageAllocator::new_fixed(2);
        let p = a.alloc().unwrap();
        let q = a.alloc().unwrap();
        assert_ne!(p, q);
        assert!(a.alloc().is_err(), "over-allocation");
        a.retain(p).unwrap();
        assert_eq!(a.refcount(p), 2);
        assert!(!a.release(p).unwrap(), "still referenced");
        assert!(a.release(p).unwrap(), "freed exactly at zero");
        assert!(a.release(p).is_err(), "double free undetected");
        assert_eq!(a.in_use(), 1);
        let r = a.alloc().unwrap();
        assert_eq!(r, p, "freed page id reused");
        let _ = q;
    }

    #[test]
    fn retain_after_free_is_an_error() {
        let mut a = PageAllocator::new_growable();
        let p = a.alloc().unwrap();
        a.release(p).unwrap();
        assert!(a.retain(p).is_err());
        assert_eq!(a.counters.allocs, 1);
        assert_eq!(a.counters.frees, 1);
    }

    #[test]
    fn pool_cow_copies_shared_pages_only() {
        let mut pool: PagePool<u32> = PagePool::new_growable(4);
        let p = pool.alloc_zeroed().unwrap();
        pool.page_mut(p).unwrap()[0] = 7;
        // exclusive: write in place
        let (same, copied) = pool.make_unique(p).unwrap();
        assert_eq!(same, p);
        assert!(!copied);
        // shared: copy, original preserved
        pool.retain(p).unwrap();
        let (fresh, copied) = pool.make_unique(p).unwrap();
        assert!(copied);
        assert_ne!(fresh, p);
        assert_eq!(pool.page(fresh)[0], 7);
        pool.page_mut(fresh).unwrap()[0] = 9;
        assert_eq!(pool.page(p)[0], 7, "CoW leaked into the shared page");
        assert_eq!(pool.refcount(p), 1);
        assert_eq!(pool.refcount(fresh), 1);
    }

    #[test]
    fn shared_page_write_refused() {
        let mut pool: PagePool<u32> = PagePool::new_growable(2);
        let p = pool.alloc_zeroed().unwrap();
        pool.retain(p).unwrap();
        assert!(pool.page_mut(p).is_err(), "write through a shared page");
    }

    #[test]
    fn manager_defaults_degenerate_to_lane_admission() {
        let mut m = KvPageManager::new(2, 16, 8, None);
        assert_eq!(m.available(), 2);
        let a = m.acquire().unwrap();
        let b = m.acquire().unwrap();
        assert_ne!(a, b);
        assert!(m.acquire().is_none(), "over-admission");
        assert_eq!(m.in_use(), 2);
        assert_eq!(m.pinned_pages(), 16);
        m.release(a).unwrap();
        assert!(m.release(a).is_err(), "double lane free");
        assert_eq!(m.available(), 1);
        let c = m.acquire().unwrap();
        assert_eq!(c, a, "lane reused");
    }

    #[test]
    fn tight_page_budget_gates_admission_below_lane_count() {
        // 4 lanes but pages for only one worst-case session
        let mut m = KvPageManager::new(4, 16, 8, Some(8));
        assert_eq!(m.available(), 1);
        let a = m.acquire().unwrap();
        assert!(m.acquire().is_none(), "page budget must gate admission");
        m.release(a).unwrap();
        assert!(m.acquire().is_some());
    }

    #[test]
    fn host_budget_spill_accounting() {
        let mut m = KvPageManager::new(1, 16, 8, Some(8));
        assert!(m.try_hold_suspended(5));
        assert!(m.try_hold_suspended(3));
        assert!(!m.try_hold_suspended(1), "host budget exceeded");
        m.release_suspended(5);
        assert!(m.try_hold_suspended(4));
        assert_eq!(m.host_held_pages(), 7);
    }

    #[test]
    fn transfer_suspended_moves_the_charge_between_managers() {
        let mut src = KvPageManager::new(1, 16, 8, Some(8));
        let mut dst = KvPageManager::new(1, 16, 8, Some(8));
        assert!(src.try_hold_suspended(6));
        assert!(src.transfer_suspended(&mut dst, 6));
        assert_eq!(src.host_held_pages(), 0, "charge left the source");
        assert_eq!(dst.host_held_pages(), 6, "charge landed at the destination");
        // destination budget full: the charge still leaves the source
        // and the caller must spill
        assert!(src.try_hold_suspended(4));
        assert!(!src.transfer_suspended(&mut dst, 4));
        assert_eq!(src.host_held_pages(), 0);
        assert_eq!(dst.host_held_pages(), 6);
    }

    #[test]
    fn page_table_clone_retains_and_drop_releases() {
        let pool = Rc::new(RefCell::new(PagePool::<u32>::new_growable(4)));
        let mut t = PageTable::new(pool.clone());
        t.push_zeroed().unwrap();
        t.push_zeroed().unwrap();
        assert_eq!(pool.borrow().pages_in_use(), 2);
        let c = t.clone();
        assert_eq!(pool.borrow().pages_in_use(), 2, "clone shares, not copies");
        assert_eq!(pool.borrow().refcount(t.pages()[0]), 2);
        drop(c);
        assert_eq!(pool.borrow().refcount(t.pages()[0]), 1);
        drop(t);
        assert_eq!(pool.borrow().pages_in_use(), 0, "drop must release every page");
    }

    #[test]
    fn page_table_write_cows_shared_pages() {
        let pool = Rc::new(RefCell::new(PagePool::<u32>::new_growable(2)));
        let mut t = PageTable::new(pool.clone());
        t.push_zeroed().unwrap();
        let ((), copied) = t.write(0, |p| p[0] = 7).unwrap();
        assert!(!copied, "exclusive pages write in place");
        let mut fork = t.clone();
        let ((), copied) = fork.write(0, |p| p[1] = 9).unwrap();
        assert!(copied, "shared pages must CoW");
        assert_ne!(t.pages()[0], fork.pages()[0]);
        assert_eq!(t.read(0, |p| p.to_vec()), vec![7, 0]);
        assert_eq!(fork.read(0, |p| p.to_vec()), vec![7, 9]);
    }

    #[test]
    fn pages_for_rounds_up() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }

    #[test]
    fn tenant_caps_gate_admission_and_release_refunds() {
        let mut m = KvPageManager::new(4, 16, 8, None);
        // one reservation's worth of budget for tenant 1
        m.set_tenant_cap(1, 8);
        assert!(m.tenant_can_admit(0), "uncapped tenant always passes");
        assert!(m.tenant_can_admit(1));
        let a = m.acquire_for(1).expect("first admit fits the cap");
        assert_eq!(m.tenant_pinned_pages(1), 8);
        assert!(!m.tenant_can_admit(1), "cap exhausted");
        assert!(m.acquire_for(1).is_none(), "second admit rejected");
        // the cap is per-tenant, not global: tenant 0 still admits
        let b = m.acquire_for(0).expect("uncapped tenant unaffected");
        assert_eq!(m.tenant_pinned_pages(0), 0, "uncapped usage untracked");
        m.release(a).unwrap();
        assert_eq!(m.tenant_pinned_pages(1), 0, "release refunds the cap");
        assert!(m.tenant_can_admit(1));
        m.release(b).unwrap();
        assert_eq!(m.pinned_pages(), 0);
    }

    #[test]
    fn acquire_for_without_caps_matches_acquire() {
        let mut plain = KvPageManager::new(3, 16, 8, Some(16));
        let mut multi = KvPageManager::new(3, 16, 8, Some(16));
        for tenant in 0..4u32 {
            assert_eq!(plain.acquire(), multi.acquire_for(tenant));
        }
        assert_eq!(plain.pinned_pages(), multi.pinned_pages());
        assert_eq!(plain.available(), multi.available());
    }
}
