//! KV-cache slot manager: capacity accounting for concurrent requests.
//!
//! The CPU PJRT backend has no real HBM budget, but the coordinator still
//! enforces an explicit cache budget the way a vLLM-style server must:
//! a request is only admitted when a slot (one full-sequence K/V pair per
//! model) is free, and the manager reports utilization for the metrics
//! endpoint. Proxy-monitored requests consume a proxy slot too.

use anyhow::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId(pub usize);

/// Fixed-capacity slot allocator.
#[derive(Debug)]
pub struct KvSlotManager {
    capacity: usize,
    /// bytes per slot (main K+V [+ proxy K+V])
    slot_bytes: usize,
    free: Vec<usize>,
    in_use: usize,
    /// peak concurrent usage (for reports)
    peak: usize,
}

impl KvSlotManager {
    pub fn new(capacity: usize, slot_bytes: usize) -> KvSlotManager {
        KvSlotManager {
            capacity,
            slot_bytes,
            free: (0..capacity).rev().collect(),
            in_use: 0,
            peak: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn utilization(&self) -> f64 {
        self.in_use as f64 / self.capacity.max(1) as f64
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use * self.slot_bytes
    }

    /// Try to admit a request; None when at capacity (the batcher then
    /// leaves it queued — backpressure).
    pub fn acquire(&mut self) -> Option<SlotId> {
        let id = self.free.pop()?;
        self.in_use += 1;
        self.peak = self.peak.max(self.in_use);
        Some(SlotId(id))
    }

    pub fn release(&mut self, slot: SlotId) -> Result<()> {
        anyhow::ensure!(
            slot.0 < self.capacity && !self.free.contains(&slot.0),
            "double free of KV slot {}",
            slot.0
        );
        self.free.push(slot.0);
        self.in_use -= 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut m = KvSlotManager::new(2, 1024);
        let a = m.acquire().unwrap();
        let b = m.acquire().unwrap();
        assert_ne!(a, b);
        assert!(m.acquire().is_none(), "over-admission");
        assert_eq!(m.in_use(), 2);
        assert_eq!(m.bytes_in_use(), 2048);
        m.release(a).unwrap();
        assert_eq!(m.available(), 1);
        let c = m.acquire().unwrap();
        assert_eq!(c, a); // slot reused
    }

    #[test]
    fn double_free_detected() {
        let mut m = KvSlotManager::new(1, 1);
        let a = m.acquire().unwrap();
        m.release(a).unwrap();
        assert!(m.release(a).is_err());
    }

    #[test]
    fn peak_tracking() {
        let mut m = KvSlotManager::new(3, 1);
        let a = m.acquire().unwrap();
        let b = m.acquire().unwrap();
        m.release(a).unwrap();
        let _c = m.acquire().unwrap();
        assert_eq!(m.peak(), 2);
        let _ = b;
    }
}
