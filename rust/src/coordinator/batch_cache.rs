//! Slot-major batch cache store: the per-slot KV state of every active
//! request, owned by the batcher instead of the sessions (DESIGN.md
//! §3.3).
//!
//! Keeping all B caches in one place is what makes the fused decode path
//! possible: each scheduling tick hands the backend a lane slice built
//! straight from the store, and the backend keeps the batched K/V image
//! resident between calls. The store tracks a *dirty* bit per slot — set
//! on admission (fresh prefill) and on any out-of-band mutation
//! (sequential-fallback decode) — so only dirty lanes need their host
//! image re-gathered into the batch; clean lanes ride the resident image.
//! On a paged backend (DESIGN.md §3.5) the dirty tracking additionally
//! drops to page granularity: a per-slot synced-position watermark
//! records how far the resident image is current, so an out-of-band
//! decode dirties one page, not the whole lane. The page counters are
//! an *accounting model* of what a paged device transfer would move —
//! the PJRT tuple API still re-gathers a stale lane wholesale (it has
//! no per-page upload; see DESIGN.md §6), exactly as the lane-level
//! counters already model uploads the reference backend never performs.
//! The accounting is backend-agnostic and therefore testable without
//! artifacts.

use anyhow::{Context, Result};

use super::kv::{pages_for, SlotId};
use crate::runtime::{Backend, BackendCache, BatchLane};

/// Upload/residency accounting (asserted by the batching tests, quoted
/// by the bench report).
#[derive(Debug, Default, Clone, Copy)]
pub struct StoreCounters {
    pub installs: u64,
    pub retires: u64,
    /// Fused decode calls issued through the store.
    pub fused_calls: u64,
    /// Engaged lanes that were dirty and needed their K/V image uploaded.
    pub dirty_lane_uploads: u64,
    /// Engaged lanes that were clean (resident image reused).
    pub resident_lane_hits: u64,
    /// KV pages of engaged lanes stale since the synced watermark —
    /// what a page-granular device transfer would upload (accounting
    /// model: the PJRT tuple API still re-gathers a stale lane
    /// wholesale; a monolithic backend counts one "page" per dirty
    /// lane).
    pub dirty_page_uploads: u64,
    /// KV pages of engaged lanes still current in the resident image.
    pub resident_page_hits: u64,
}

#[derive(Default)]
struct Slot {
    main: Option<BackendCache>,
    proxy: Option<BackendCache>,
    dirty: bool,
    /// Cache position up to which the resident batch image is current
    /// (`None` = nothing resident). Appends past this watermark dirty
    /// only the pages they touch.
    synced: Option<usize>,
}

/// Fixed-capacity slot-major cache store.
pub struct BatchCacheStore {
    slots: Vec<Slot>,
    pub counters: StoreCounters,
}

impl BatchCacheStore {
    pub fn new(capacity: usize) -> BatchCacheStore {
        BatchCacheStore {
            slots: (0..capacity).map(|_| Slot::default()).collect(),
            counters: StoreCounters::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    fn slot(&self, slot: SlotId) -> Result<&Slot> {
        self.slots.get(slot.0).context("slot id out of range")
    }

    fn slot_mut(&mut self, slot: SlotId) -> Result<&mut Slot> {
        self.slots.get_mut(slot.0).context("slot id out of range")
    }

    /// Install a freshly admitted request's caches (marks the slot
    /// dirty: its K/V image is not in the batched buffer yet).
    pub fn install(
        &mut self,
        slot: SlotId,
        main: BackendCache,
        proxy: Option<BackendCache>,
    ) -> Result<()> {
        let s = self.slot_mut(slot)?;
        anyhow::ensure!(s.main.is_none(), "slot {} already occupied", slot.0);
        s.main = Some(main);
        s.proxy = proxy;
        s.dirty = true;
        s.synced = None;
        self.counters.installs += 1;
        Ok(())
    }

    /// Drop a retired request's caches.
    pub fn retire(&mut self, slot: SlotId) -> Result<()> {
        self.take(slot).map(|_| ())
    }

    /// Vacate a slot *without dropping* its caches — the paged
    /// suspend path (DESIGN.md §3.5): the session keeps its page tables
    /// (unpinned) and repins them into a lane on resume, skipping the
    /// re-prefill. Counts as a retire so installs and retires stay
    /// balanced across preempt/resume churn.
    pub fn take(&mut self, slot: SlotId) -> Result<(BackendCache, Option<BackendCache>)> {
        let s = self.slot_mut(slot)?;
        let main = s.main.take();
        anyhow::ensure!(main.is_some(), "retiring an empty slot {}", slot.0);
        let proxy = s.proxy.take();
        s.dirty = false;
        s.synced = None;
        self.counters.retires += 1;
        Ok((main.expect("checked above"), proxy))
    }

    pub fn is_dirty(&self, slot: SlotId) -> bool {
        self.slot(slot).map(|s| s.dirty).unwrap_or(false)
    }

    /// Record an out-of-band mutation of the slot's main cache (e.g. a
    /// sequential-fallback decode): its resident batch image is stale.
    /// The synced watermark survives — appends past it dirty only the
    /// pages they touch.
    pub fn mark_dirty(&mut self, slot: SlotId) -> Result<()> {
        self.slot_mut(slot)?.dirty = true;
        Ok(())
    }

    pub fn main(&self, slot: SlotId) -> Result<&BackendCache> {
        self.slot(slot)?
            .main
            .as_ref()
            .context("slot has no main cache")
    }

    pub fn main_mut(&mut self, slot: SlotId) -> Result<&mut BackendCache> {
        self.slot_mut(slot)?
            .main
            .as_mut()
            .context("slot has no main cache")
    }

    pub fn proxy(&self, slot: SlotId) -> Option<&BackendCache> {
        self.slots.get(slot.0).and_then(|s| s.proxy.as_ref())
    }

    pub fn proxy_mut(&mut self, slot: SlotId) -> Option<&mut BackendCache> {
        self.slots.get_mut(slot.0).and_then(|s| s.proxy.as_mut())
    }

    /// Issue ONE fused `decode_batch` for the picked (slot, token) pairs,
    /// padding idle lanes, and return the per-pick logits in pick order.
    /// Engaged slots come back clean (their image is resident on the
    /// backend's batched buffer).
    pub fn fused_decode(
        &mut self,
        backend: &dyn Backend,
        picks: &[(SlotId, u32)],
    ) -> Result<Vec<Vec<f32>>> {
        let width = backend
            .batch_width()
            .context("backend has no fused batch entry point")?;
        anyhow::ensure!(
            !picks.is_empty() && picks.len() <= width,
            "{} picks for a {width}-wide batch",
            picks.len()
        );
        // Lane placement: slot-major whenever the store fits the batch
        // width, so a slot keeps the SAME lane across ticks even as
        // other requests retire — that stability is what lets the
        // backend's per-lane residency tags (PJRT scratch image) keep
        // hitting. Falls back to first-fit when slots > width; lanes
        // then reshuffle between calls, so every engaged lane is
        // honestly counted as an upload (the backend's tags will miss).
        let slot_major = self.slots.len() <= width;

        self.counters.fused_calls += 1;
        let page_size = backend.page_size();
        for (slot, _) in picks {
            let (dirty, pos, synced) = {
                let s = self.slot(*slot)?;
                let main = s.main.as_ref();
                anyhow::ensure!(main.is_some(), "picked empty slot {}", slot.0);
                (s.dirty, main.map(|c| c.pos()).unwrap_or(0), s.synced)
            };
            let lane_resident = !dirty && slot_major;
            if lane_resident {
                self.counters.resident_lane_hits += 1;
            } else {
                self.counters.dirty_lane_uploads += 1;
            }
            match page_size {
                Some(p) => {
                    // pages touched since the watermark need re-gather;
                    // everything below it rides the resident image
                    let total = pages_for(pos, p);
                    let synced = if slot_major { synced.unwrap_or(0) } else { 0 };
                    let uploads = if synced >= pos { 0 } else { total - synced / p };
                    self.counters.dirty_page_uploads += uploads as u64;
                    self.counters.resident_page_hits += (total - uploads) as u64;
                }
                None => {
                    // monolithic cache: the lane is the page
                    if lane_resident {
                        self.counters.resident_page_hits += 1;
                    } else {
                        self.counters.dirty_page_uploads += 1;
                    }
                }
            }
        }
        let mut by_slot: Vec<Option<&mut BackendCache>> = self
            .slots
            .iter_mut()
            .map(|s| s.main.as_mut())
            .collect();
        let mut lanes: Vec<Option<BatchLane<'_>>> = Vec::new();
        lanes.resize_with(width, || None);
        let mut lane_of_pick = Vec::with_capacity(picks.len());
        for (i, (slot, token)) in picks.iter().enumerate() {
            let cache = by_slot[slot.0]
                .take()
                .context("duplicate slot in fused picks")?;
            let lane = if slot_major { slot.0 } else { i };
            anyhow::ensure!(lanes[lane].is_none(), "fused lane collision");
            lanes[lane] = Some(BatchLane {
                cache,
                token: *token,
            });
            lane_of_pick.push(lane);
        }

        let out = backend.decode_batch(&mut lanes)?;
        drop(lanes);
        drop(by_slot);

        let mut logits = Vec::with_capacity(picks.len());
        for ((slot, _), lane) in picks.iter().zip(&lane_of_pick) {
            let s = &mut self.slots[slot.0];
            s.dirty = false;
            // the downloaded post-write image is the new resident state;
            // lane reshuffling (!slot_major) voids residency entirely
            s.synced = if slot_major {
                s.main.as_ref().map(|c| c.pos())
            } else {
                None
            };
            logits.push(
                out.get(*lane)
                    .and_then(|l| l.clone())
                    .context("backend returned no logits for an engaged lane")?,
            );
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{RefBackend, Runtime};
    use crate::vocab::Vocab;

    fn prefill(rt: &Runtime, seed: u32) -> BackendCache {
        let v = rt.vocab;
        let prompt = vec![v.bos, v.q, v.num(seed % 7 + 1), v.num(3), v.sep, v.think];
        rt.main.prefill(&prompt).unwrap().1
    }

    #[test]
    fn install_retire_lifecycle() {
        let rt = Runtime::reference();
        let mut store = BatchCacheStore::new(2);
        let c = prefill(&rt, 1);
        store.install(SlotId(0), c, None).unwrap();
        assert!(store.is_dirty(SlotId(0)));
        assert!(store.main(SlotId(0)).is_ok());
        assert!(store.main(SlotId(1)).is_err());
        // double install refused
        let c2 = prefill(&rt, 2);
        assert!(store.install(SlotId(0), c2, None).is_err());
        store.retire(SlotId(0)).unwrap();
        assert!(store.main(SlotId(0)).is_err());
        assert!(store.retire(SlotId(0)).is_err());
        assert_eq!(store.counters.installs, 1);
        assert_eq!(store.counters.retires, 1);
    }

    #[test]
    fn dirty_accounting_over_fused_ticks() {
        let rt = Runtime::reference();
        let v = rt.vocab;
        let mut store = BatchCacheStore::new(3);
        for i in 0..3 {
            let c = prefill(&rt, i);
            store.install(SlotId(i as usize), c, None).unwrap();
        }
        let picks: Vec<(SlotId, u32)> =
            (0..3).map(|i| (SlotId(i), v.ver)).collect();

        // tick 1: all three lanes are fresh admissions -> dirty uploads
        store.fused_decode(rt.main.as_ref(), &picks).unwrap();
        assert_eq!(store.counters.dirty_lane_uploads, 3);
        assert_eq!(store.counters.resident_lane_hits, 0);

        // tick 2: all lanes resident
        store.fused_decode(rt.main.as_ref(), &picks).unwrap();
        assert_eq!(store.counters.dirty_lane_uploads, 3);
        assert_eq!(store.counters.resident_lane_hits, 3);

        // out-of-band mutation dirties exactly that lane
        let cache = store.main_mut(SlotId(1)).unwrap();
        rt.main.decode(cache, v.ver).unwrap();
        store.mark_dirty(SlotId(1)).unwrap();
        store.fused_decode(rt.main.as_ref(), &picks).unwrap();
        assert_eq!(store.counters.dirty_lane_uploads, 4);
        assert_eq!(store.counters.resident_lane_hits, 5);
        assert_eq!(store.counters.fused_calls, 3);
    }

    #[test]
    fn page_granular_dirty_accounting() {
        // page size 4, 6-token prompts: two pages per fresh cache
        let vocab = Vocab::default_layout();
        let rt = Runtime {
            vocab,
            main: Box::new(RefBackend::with_pages("ref-main", vocab, 128, Some(8), Some(4))),
            proxy: Box::new(RefBackend::with_pages("ref-proxy", vocab, 128, None, Some(4))),
            artifacts: None,
        };
        let mut store = BatchCacheStore::new(3);
        for i in 0..3 {
            let c = prefill(&rt, i);
            assert_eq!(c.pos(), 6);
            store.install(SlotId(i as usize), c, None).unwrap();
        }
        let picks: Vec<(SlotId, u32)> = (0..3).map(|i| (SlotId(i), vocab.ver)).collect();

        // tick 1: fresh admissions — both pages of every lane upload
        store.fused_decode(rt.main.as_ref(), &picks).unwrap();
        assert_eq!(store.counters.dirty_page_uploads, 6);
        assert_eq!(store.counters.resident_page_hits, 0);

        // tick 2: fully resident (watermark == pos)
        store.fused_decode(rt.main.as_ref(), &picks).unwrap();
        assert_eq!(store.counters.dirty_page_uploads, 6);
        assert_eq!(store.counters.resident_page_hits, 6);

        // out-of-band decode on slot 1 (pos 8 -> 9): exactly ONE page of
        // that lane goes stale; the lane-level bit would re-upload all 3
        let cache = store.main_mut(SlotId(1)).unwrap();
        rt.main.decode(cache, vocab.ver).unwrap();
        store.mark_dirty(SlotId(1)).unwrap();
        store.fused_decode(rt.main.as_ref(), &picks).unwrap();
        assert_eq!(store.counters.dirty_page_uploads, 7, "one page, not the whole lane");
        assert_eq!(store.counters.resident_page_hits, 12);
        // lane-level counters keep their coarse meaning
        assert_eq!(store.counters.dirty_lane_uploads, 4);
        assert_eq!(store.counters.resident_lane_hits, 5);
    }

    #[test]
    fn take_preserves_caches_and_balances_retires() {
        let rt = Runtime::reference();
        let mut store = BatchCacheStore::new(2);
        let c = prefill(&rt, 1);
        let pos = c.pos();
        store.install(SlotId(0), c, None).unwrap();
        let (main, proxy) = store.take(SlotId(0)).unwrap();
        assert_eq!(main.pos(), pos, "take must not disturb the cache");
        assert!(proxy.is_none());
        assert!(store.main(SlotId(0)).is_err(), "slot vacated");
        assert_eq!(store.counters.installs, 1);
        assert_eq!(store.counters.retires, 1);
        // repin into another lane
        store.install(SlotId(1), main, None).unwrap();
        assert_eq!(store.main(SlotId(1)).unwrap().pos(), pos);
    }

    #[test]
    fn fused_decode_advances_only_picked_slots() {
        let rt = Runtime::reference();
        let v = rt.vocab;
        let mut store = BatchCacheStore::new(3);
        for i in 0..3 {
            let c = prefill(&rt, i);
            store.install(SlotId(i as usize), c, None).unwrap();
        }
        let before: Vec<usize> = (0..3)
            .map(|i| store.main(SlotId(i)).unwrap().pos())
            .collect();
        let logits = store
            .fused_decode(rt.main.as_ref(), &[(SlotId(0), v.ver), (SlotId(2), v.ver)])
            .unwrap();
        assert_eq!(logits.len(), 2);
        assert_eq!(store.main(SlotId(0)).unwrap().pos(), before[0] + 1);
        assert_eq!(store.main(SlotId(1)).unwrap().pos(), before[1]);
        assert_eq!(store.main(SlotId(2)).unwrap().pos(), before[2] + 1);
    }

    #[test]
    fn fused_decode_respects_batch_width() {
        let vocab = Vocab::default_layout();
        let rt = Runtime {
            vocab,
            main: Box::new(RefBackend::new("tiny", vocab, 128, Some(2))),
            proxy: Box::new(RefBackend::proxy(vocab)),
            artifacts: None,
        };
        let mut store = BatchCacheStore::new(3);
        for i in 0..3 {
            let c = prefill(&rt, i);
            store.install(SlotId(i as usize), c, None).unwrap();
        }
        let picks: Vec<(SlotId, u32)> =
            (0..3).map(|i| (SlotId(i), vocab.ver)).collect();
        assert!(
            store.fused_decode(rt.main.as_ref(), &picks).is_err(),
            "3 picks must not fit a 2-wide batch"
        );
        assert!(store
            .fused_decode(rt.main.as_ref(), &picks[..2])
            .is_ok());
    }
}
