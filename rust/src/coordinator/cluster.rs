//! Multi-replica cluster serving (DESIGN.md §3.7): N engine replicas —
//! each its own [`Batcher`] with private lanes and page budgets — behind
//! one router, all drawing on the *same* shared runtime page pools.
//!
//! The router does EAT-aware placement: a new arrival goes to the
//! replica with the least pressure, where pressure is the backlog
//! (queued + suspended waiters) plus the [`Batcher::drain_distance`] —
//! the Σ of `1 − stability` over resident sessions. A replica whose
//! sessions all sit near their exit threshold is about to free its
//! lanes, so the distance-to-exit signal the paper uses to *stop*
//! reasoning doubles as the load signal for *placing* it. Monitors with
//! the same distance-to-exit shape (Dynamic Early Exit, Think Just
//! Enough) plug in through [`crate::exit::ExitPolicy::stability`]
//! unchanged.
//!
//! Under skewed load the router also performs **live session
//! migration**: when one replica is saturated with a backlog while
//! another has idle lanes, a waiter is lifted off the hot replica
//! ([`Batcher::extract_migration`]) and injected into the cold one
//! ([`Batcher::inject_migration`]). Because KV caches are refcounted
//! [`crate::coordinator::PageTable`]s into pools owned by the shared
//! runtime, migrating a mid-flight session is a page handoff — budget
//! accounting moves via
//! [`crate::coordinator::KvPageManager::transfer_suspended`], the pages
//! themselves never copy and resumption repins them with **zero
//! re-prefill** (asserted against the runtime prefill counters by the
//! cluster suite).
//!
//! Determinism is pinned cluster-wide: every replica shares one
//! [`Clock`], [`Cluster::tick_once`] ticks replicas in ascending id
//! order — so all scheduling events are totally ordered by
//! `(virtual_time, replica_id)` — and the router hands out globally
//! unique submission seqs, which seed the per-request RNGs. A request's
//! trajectory is therefore invariant to placement and migration, and a
//! same-seed N-replica run serializes byte-identical
//! [`ClusterMetrics`] JSON. With one replica the router degenerates to
//! a pass-through: `cluster(N=1)` emits byte-identical [`ServeMetrics`]
//! to a plain single-batcher run (the CI equivalence check).

use anyhow::Result;

use super::batcher::{Batcher, PolicyFactory, DEFAULT_TICK_DT};
use super::engine::{MonitorModel, RequestResult};
use super::metrics::{ClusterMetrics, MetricsReport};
use super::workload::OpenLoopTarget;
use crate::config::ServeConfig;
use crate::datasets::Question;
use crate::runtime::Runtime;
use crate::util::clock::Clock;
use crate::util::wheel::EventWheel;

/// Arrival placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle replicas in submission order (load-oblivious baseline).
    RoundRobin,
    /// Least pressure first: backlog + EAT distance-to-exit of the
    /// resident sessions (ties break to the lowest replica id).
    EatAware,
}

/// Cluster shape. Bundled so call sites stay readable as knobs grow.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub replicas: usize,
    /// KV lanes per replica (each replica gets its own page budget).
    pub slots_per_replica: usize,
    pub route: RoutePolicy,
    /// Rebalance skewed load by migrating waiters between replicas.
    pub migrate: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            slots_per_replica: 4,
            route: RoutePolicy::EatAware,
            migrate: false,
        }
    }
}

/// N replicas behind an EAT-aware router; see the module docs.
pub struct Cluster<'a> {
    replicas: Vec<Batcher<'a>>,
    clock: Clock,
    route: RoutePolicy,
    migrate: bool,
    /// Globally unique submission seq — the per-request RNG seed, so a
    /// trajectory is invariant to which replica serves it.
    next_seq: u64,
    rr_next: usize,
    /// First cluster arrival (the goodput window).
    started: Option<f64>,
    /// Arrivals placed per replica, by replica id.
    routed: Vec<u64>,
    /// Mid-flight sessions handed between replicas.
    migrations: u64,
    /// Queued requests rerouted before first admission.
    reroutes: u64,
    /// Committed tokens carried by migrated sessions.
    migrated_tokens: u64,
    /// Per-tick replica schedule, drained within the tick: replicas with
    /// work fire as `(now, replica_id)` events, so tick order *is* the
    /// cluster-wide event order and workless replicas cost nothing.
    tick_events: EventWheel<usize>,
}

impl<'a> Cluster<'a> {
    /// Wall-clock cluster (live serving).
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        cluster_cfg: ClusterConfig,
        factories: Vec<PolicyFactory>,
    ) -> Cluster<'a> {
        Cluster::with_clock(rt, cfg, monitor, cluster_cfg, factories, Clock::wall())
    }

    /// Full constructor: one policy factory per replica (so every
    /// replica mints fresh policy instances), one shared clock.
    pub fn with_clock(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        cluster_cfg: ClusterConfig,
        factories: Vec<PolicyFactory>,
        clock: Clock,
    ) -> Cluster<'a> {
        assert!(cluster_cfg.replicas >= 1, "cluster needs at least one replica");
        assert_eq!(
            factories.len(),
            cluster_cfg.replicas,
            "one policy factory per replica"
        );
        let replicas: Vec<Batcher<'a>> = factories
            .into_iter()
            .map(|f| {
                Batcher::with_clock(
                    rt,
                    cfg.clone(),
                    monitor,
                    cluster_cfg.slots_per_replica,
                    f,
                    clock.clone(),
                )
            })
            .collect();
        let n = replicas.len();
        Cluster {
            replicas,
            clock,
            route: cluster_cfg.route,
            migrate: cluster_cfg.migrate,
            next_seq: 0,
            rr_next: 0,
            started: None,
            routed: vec![0; n],
            migrations: 0,
            reroutes: 0,
            migrated_tokens: 0,
            tick_events: EventWheel::new(DEFAULT_TICK_DT),
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, id: usize) -> &Batcher<'a> {
        &self.replicas[id]
    }

    /// Per-token sequential decode on every replica (the A/B check
    /// against fused batch decode; see [`Batcher::force_sequential`]).
    pub fn set_force_sequential(&mut self, on: bool) {
        for b in self.replicas.iter_mut() {
            b.force_sequential = on;
        }
    }

    /// Router pressure of one replica: waiters plus the distance-to-exit
    /// mass of its resident sessions.
    fn pressure(b: &Batcher<'_>) -> f64 {
        b.waiters() as f64 + b.drain_distance()
    }

    /// Pick the replica for the next arrival.
    fn route_pick(&mut self) -> usize {
        match self.route {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.replicas.len();
                i
            }
            RoutePolicy::EatAware => {
                let mut best = 0usize;
                for (i, b) in self.replicas.iter().enumerate().skip(1) {
                    // strict < keeps ties on the lowest id (determinism)
                    if Self::pressure(b) < Self::pressure(&self.replicas[best]) {
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Route one arrival; the replica records it under a globally
    /// unique seq so its trajectory is placement-invariant.
    pub fn submit(&mut self, question: Question) {
        self.submit_tenant(question, 0);
    }

    /// [`submit`](Cluster::submit) with a tenant id: the replica's
    /// per-tenant DRR admission (DESIGN.md §3.11) sees the same tenant
    /// wherever the router places the request. Tenant 0 is the default
    /// path and changes nothing.
    pub fn submit_tenant(&mut self, question: Question, tenant: u32) {
        if self.started.is_none() {
            self.started = Some(self.clock.now());
        }
        let id = self.route_pick();
        let seq = self.next_seq;
        self.next_seq += 1;
        self.routed[id] += 1;
        self.replicas[id].submit_seq_tenant(question, seq, tenant);
    }

    /// Move waiters off saturated replicas onto idle lanes: repeatedly
    /// pair the most-backlogged replica with zero free lanes against the
    /// most-free replica with zero waiters, and migrate one unit of work
    /// between them. Each handoff gives the destination a waiter
    /// (disqualifying it as a destination), so the loop terminates
    /// within `replicas` iterations per tick.
    fn rebalance(&mut self) -> Result<()> {
        loop {
            let mut src: Option<usize> = None;
            let mut dst: Option<usize> = None;
            for (i, b) in self.replicas.iter().enumerate() {
                // strict > keeps ties on the lowest id
                if b.free_lanes() == 0
                    && b.waiters() > 0
                    && src.is_none_or(|j| b.waiters() > self.replicas[j].waiters())
                {
                    src = Some(i);
                }
                if b.free_lanes() > 0
                    && b.waiters() == 0
                    && dst.is_none_or(|j| b.free_lanes() > self.replicas[j].free_lanes())
                {
                    dst = Some(i);
                }
            }
            let (Some(si), Some(di)) = (src, dst) else {
                return Ok(());
            };
            // saturated and idle are disjoint, so si != di; split-borrow
            // the pair out of the replica vec
            let (lo, hi) = (si.min(di), si.max(di));
            let (left, right) = self.replicas.split_at_mut(hi);
            let (a, b) = (&mut left[lo], &mut right[0]);
            let (s, d) = if si < di { (a, b) } else { (b, a) };
            let Some(m) = s.extract_migration()? else {
                return Ok(());
            };
            if m.is_session() {
                self.migrations += 1;
                self.migrated_tokens += m.tokens() as u64;
            } else {
                self.reroutes += 1;
            }
            d.inject_migration(s, m);
        }
    }

    /// One cluster tick at the current virtual time: rebalance (when
    /// migration is on and there are ≥ 2 replicas), then tick replicas
    /// in `(virtual_time, replica_id)` event order off the wheel — the
    /// total order all cluster determinism rests on. Replicas with no
    /// queued, resident or suspended work schedule no event and are
    /// never touched, so a mostly-idle wide cluster ticks in O(active
    /// replicas).
    pub fn tick(&mut self) -> Result<()> {
        if self.migrate && self.replicas.len() >= 2 {
            self.rebalance()?;
        }
        let now = self.clock.now();
        debug_assert!(self.tick_events.is_empty(), "tick schedule drains within the tick");
        for (id, b) in self.replicas.iter().enumerate() {
            if b.has_work() {
                self.tick_events.schedule_at(now, id as u32, 0, id);
            }
        }
        while let Some((_, id)) = self.tick_events.pop() {
            self.replicas[id].tick()?;
        }
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.replicas.iter().map(|b| b.pending()).sum()
    }

    pub fn active_count(&self) -> usize {
        self.replicas.iter().map(|b| b.active_count()).sum()
    }

    pub fn suspended_count(&self) -> usize {
        self.replicas.iter().map(|b| b.suspended_count()).sum()
    }

    pub fn has_work(&self) -> bool {
        self.replicas.iter().any(|b| b.has_work())
    }

    /// Drain: tick until every replica is empty (virtual clocks advance
    /// [`DEFAULT_TICK_DT`] per tick, like [`Batcher::run_to_completion`]).
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            self.tick()?;
            self.clock.advance(DEFAULT_TICK_DT);
        }
        Ok(())
    }

    /// Drain every replica's completed results, sorted by question id.
    pub fn all_results(&mut self) -> Vec<RequestResult> {
        let mut out: Vec<RequestResult> = Vec::new();
        for b in self.replicas.iter_mut() {
            out.append(&mut b.results);
        }
        out.sort_by_key(|r| r.question_id);
        out
    }

    /// Deterministic cluster snapshot: router counters, totals summed
    /// over replicas, and each replica's full [`ServeMetrics`] JSON
    /// embedded by id (what makes the `cluster(N=1) ≡ single` CI check
    /// a plain byte diff).
    ///
    /// [`ServeMetrics`]: super::metrics::ServeMetrics
    pub fn metrics(&self) -> ClusterMetrics {
        let elapsed_s = match self.started {
            Some(t0) => (self.clock.now() - t0).max(0.0),
            None => 0.0,
        };
        ClusterMetrics {
            replicas: self.replicas.len(),
            routed: self.routed.clone(),
            migrations: self.migrations,
            reroutes: self.reroutes,
            migrated_tokens: self.migrated_tokens,
            completed: self.replicas.iter().map(|b| b.metrics.completed).sum(),
            correct: self.replicas.iter().map(|b| b.metrics.correct).sum(),
            reasoning_tokens: self.replicas.iter().map(|b| b.metrics.reasoning_tokens).sum(),
            preemptions: self.replicas.iter().map(|b| b.metrics.preemptions).sum(),
            resumes: self.replicas.iter().map(|b| b.metrics.resumes).sum(),
            kv_spills: self.replicas.iter().map(|b| b.metrics.kv_spills).sum(),
            deadline_misses: self.replicas.iter().map(|b| b.metrics.deadline_misses).sum(),
            shed_exits: self.replicas.iter().map(|b| b.metrics.shed_exits).sum(),
            rejected: self.replicas.iter().map(|b| b.metrics.rejected).sum(),
            elapsed_s,
            per_replica: self.replicas.iter().map(|b| b.metrics.to_json()).collect(),
        }
    }
}

impl OpenLoopTarget for Cluster<'_> {
    fn clock(&self) -> &Clock {
        Cluster::clock(self)
    }

    fn submit(&mut self, question: Question) {
        Cluster::submit(self, question)
    }

    fn submit_tenant(&mut self, question: Question, tenant: u32) {
        Cluster::submit_tenant(self, question, tenant)
    }

    fn has_work(&self) -> bool {
        Cluster::has_work(self)
    }

    fn tick_once(&mut self) -> Result<()> {
        Cluster::tick(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::eat_policy_factory;
    use crate::datasets::Dataset;

    fn mk_cluster(rt: &Runtime, ccfg: ClusterConfig, seed: u64) -> Cluster<'_> {
        let mut cfg = ServeConfig::default();
        cfg.seed = seed;
        let factories = (0..ccfg.replicas).map(|_| eat_policy_factory(&cfg)).collect();
        Cluster::with_clock(rt, cfg, MonitorModel::SelfModel, ccfg, factories, Clock::virt())
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let rt = Runtime::reference();
        let ccfg = ClusterConfig {
            replicas: 3,
            slots_per_replica: 2,
            route: RoutePolicy::RoundRobin,
            migrate: false,
        };
        let mut c = mk_cluster(&rt, ccfg, 1);
        let ds = Dataset::synth_gpqa(&rt.vocab, 6, 1);
        for q in ds.questions.iter().take(6) {
            c.submit(q.clone());
        }
        assert_eq!(c.metrics().routed, vec![2, 2, 2]);
    }

    #[test]
    fn eat_aware_routing_avoids_the_loaded_replica() {
        let rt = Runtime::reference();
        let ccfg = ClusterConfig {
            replicas: 2,
            slots_per_replica: 2,
            route: RoutePolicy::EatAware,
            migrate: false,
        };
        let mut c = mk_cluster(&rt, ccfg, 2);
        let ds = Dataset::synth_gpqa(&rt.vocab, 4, 2);
        // both idle: ties go to replica 0; its backlog then pushes the
        // next arrival to replica 1, and so on
        for q in ds.questions.iter().take(4) {
            c.submit(q.clone());
        }
        assert_eq!(c.metrics().routed, vec![2, 2]);
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics().completed, 4);
        assert!(!c.has_work());
    }

    #[test]
    fn cluster_drains_and_aggregates() {
        let rt = Runtime::reference();
        let ccfg = ClusterConfig {
            replicas: 2,
            slots_per_replica: 2,
            route: RoutePolicy::RoundRobin,
            migrate: true,
        };
        let mut c = mk_cluster(&rt, ccfg, 3);
        let ds = Dataset::synth_gpqa(&rt.vocab, 6, 3);
        for q in ds.questions.iter().take(6) {
            c.submit(q.clone());
        }
        c.run_to_completion().unwrap();
        let m = c.metrics();
        assert_eq!(m.completed, 6);
        assert_eq!(c.all_results().len(), 6);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.active_count(), 0);
        assert_eq!(c.suspended_count(), 0);
        assert_eq!(m.per_replica.len(), 2);
        assert!(m.elapsed_s > 0.0);
    }
}
