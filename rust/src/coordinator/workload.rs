//! Open-loop Poisson workload driver (DESIGN.md §3.4): submits requests
//! as their arrival times pass, interleaved with scheduler ticks.
//!
//! Under a wall clock this paces a live load test (arrivals fire in real
//! time, the driver naps while idle). Under a virtual clock the driver
//! advances time itself — `tick_dt` simulated seconds per scheduling
//! tick, jumping straight to the next *event* when the target idles —
//! so the entire serve run (arrival pattern, admission order, preemption
//! decisions, latency percentiles) is a pure function of the seed.
//!
//! The driver is generic over [`OpenLoopTarget`], so it paces both the
//! white-box [`Batcher`] and the black-box
//! [`crate::blackbox::BlackboxBatcher`] (DESIGN.md §3.6). The black-box
//! target adds a second event source besides arrivals: simulated chunk
//! deliveries — `blocked_until` reports the earliest one whenever every
//! active stream is parked on a future arrival, and the driver jumps to
//! `min(next request arrival, next chunk delivery)` instead of burning
//! empty ticks.

use anyhow::Result;

use super::batcher::{Batcher, DEFAULT_TICK_DT};
use crate::blackbox::BlackboxBatcher;
use crate::datasets::Question;
use crate::util::clock::Clock;
use crate::util::rng::Rng;
use crate::util::wheel::EventWheel;

/// Streaming Poisson arrival process: yields the same cumulative-sum
/// sequence as [`poisson_arrivals`] one timestamp at a time, in O(1)
/// state — the soak driver paces a million arrivals through this
/// without ever materializing them.
pub struct PoissonStream {
    rng: Rng,
    rate_per_s: f64,
    t: f64,
}

impl PoissonStream {
    pub fn new(rate_per_s: f64, seed: u64) -> PoissonStream {
        PoissonStream {
            rng: Rng::new(seed ^ 0xA221),
            rate_per_s,
            t: 0.0,
        }
    }

    /// Next arrival time (seconds): the previous one plus an exponential
    /// inter-arrival gap.
    pub fn next_arrival(&mut self) -> f64 {
        self.t += self.rng.exponential(self.rate_per_s);
        self.t
    }
}

/// Seeded Poisson arrival times (seconds) for `n` requests at
/// `rate_per_s`: cumulative sums of exponential inter-arrival gaps.
pub fn poisson_arrivals(n: usize, rate_per_s: f64, seed: u64) -> Vec<f64> {
    let mut stream = PoissonStream::new(rate_per_s, seed);
    (0..n).map(|_| stream.next_arrival()).collect()
}

/// Anything the open-loop driver can pace: a clocked batcher that
/// accepts submissions and advances by ticks.
pub trait OpenLoopTarget {
    fn clock(&self) -> &Clock;
    fn submit(&mut self, question: Question);
    /// Anything left to do (queued or in flight).
    fn has_work(&self) -> bool;
    /// Earliest *future* event the target is parked on when a tick right
    /// now would advance nothing (e.g. every black-box stream awaiting a
    /// scheduled chunk arrival). `None` = tick away.
    fn blocked_until(&self) -> Option<f64> {
        None
    }
    fn tick_once(&mut self) -> Result<()>;
}

impl OpenLoopTarget for Batcher<'_> {
    fn clock(&self) -> &Clock {
        Batcher::clock(self)
    }

    fn submit(&mut self, question: Question) {
        Batcher::submit(self, question)
    }

    fn has_work(&self) -> bool {
        Batcher::has_work(self)
    }

    fn tick_once(&mut self) -> Result<()> {
        Batcher::tick(self).map(|_| ())
    }
}

impl OpenLoopTarget for BlackboxBatcher<'_> {
    fn clock(&self) -> &Clock {
        BlackboxBatcher::clock(self)
    }

    fn submit(&mut self, question: Question) {
        BlackboxBatcher::submit(self, question)
    }

    fn has_work(&self) -> bool {
        BlackboxBatcher::has_work(self)
    }

    fn blocked_until(&self) -> Option<f64> {
        BlackboxBatcher::blocked_until(self)
    }

    fn tick_once(&mut self) -> Result<()> {
        BlackboxBatcher::tick(self).map(|_| ())
    }
}

/// Drive `target` through an open-loop arrival process until everything
/// submitted has completed. Questions are taken round-robin from
/// `questions`; `arrivals` must be non-decreasing (as produced by
/// [`poisson_arrivals`]).
///
/// Arrivals live on the event wheel (DESIGN.md §3.10): each loop
/// iteration pops the due ones — `(time, seq)` order over a
/// non-decreasing input reproduces the old slice scan exactly — and the
/// wheel's peeked head doubles as the idle-jump target, so a long gap
/// between arrivals costs one jump, not a bucket crawl.
pub fn run_open_loop<T: OpenLoopTarget>(
    target: &mut T,
    questions: &[Question],
    arrivals: &[f64],
    tick_dt: f64,
) -> Result<()> {
    anyhow::ensure!(!questions.is_empty(), "workload needs at least one question");
    let clock = target.clock().clone();
    let mut wheel: EventWheel<usize> = EventWheel::new(DEFAULT_TICK_DT);
    for (i, &t) in arrivals.iter().enumerate() {
        wheel.schedule_at(t, 0, i as u64, i);
    }
    loop {
        let now = clock.now();
        while wheel.peek_time().is_some_and(|t| t <= now) {
            let (_, i) = wheel.pop().expect("peeked arrival exists");
            target.submit(questions[i % questions.len()].clone());
        }
        if !target.has_work() {
            let Some(next_t) = wheel.peek_time() else {
                break;
            };
            // idle: jump (virtual) or wait (wall) for the next arrival
            if clock.is_virtual() {
                clock.advance(next_t - now);
            } else {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            continue;
        }
        if let Some(until) = target.blocked_until() {
            // parked on a future event (chunk delivery): jump to the
            // earlier of it and the next request arrival
            let mut at = until;
            if let Some(next_t) = wheel.peek_time() {
                at = at.min(next_t);
            }
            if at > now {
                if clock.is_virtual() {
                    clock.advance(at - now);
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                continue;
            }
            // fp edge: the event is effectively "now" — fall through
        }
        target.tick_once()?;
        clock.advance(tick_dt);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_positive_and_increasing() {
        let a = poisson_arrivals(50, 8.0, 3);
        assert_eq!(a.len(), 50);
        assert!(a[0] > 0.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrival times must strictly increase");
        }
    }

    #[test]
    fn arrivals_are_seed_deterministic() {
        assert_eq!(poisson_arrivals(20, 4.0, 9), poisson_arrivals(20, 4.0, 9));
        assert_ne!(poisson_arrivals(20, 4.0, 9), poisson_arrivals(20, 4.0, 10));
    }

    #[test]
    fn mean_gap_tracks_the_rate() {
        let a = poisson_arrivals(4000, 10.0, 1);
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 0.1).abs() < 0.01, "mean gap {mean_gap}");
    }

    #[test]
    fn stream_reproduces_the_batch_arrivals_bit_for_bit() {
        let batch = poisson_arrivals(256, 6.0, 77);
        let mut stream = PoissonStream::new(6.0, 77);
        for (i, &t) in batch.iter().enumerate() {
            assert_eq!(stream.next_arrival().to_bits(), t.to_bits(), "arrival {i}");
        }
    }
}
