//! Open-loop Poisson workload driver (DESIGN.md §3.4): submits requests
//! as their arrival times pass, interleaved with scheduler ticks.
//!
//! Under a wall clock this paces a live load test (arrivals fire in real
//! time, the driver naps while idle). Under a virtual clock the driver
//! advances time itself — `tick_dt` simulated seconds per scheduling
//! tick, jumping straight to the next arrival when the batcher idles —
//! so the entire serve run (arrival pattern, admission order, preemption
//! decisions, latency percentiles) is a pure function of the seed.

use anyhow::Result;

use super::batcher::Batcher;
use crate::datasets::Question;
use crate::util::rng::Rng;

/// Seeded Poisson arrival times (seconds) for `n` requests at
/// `rate_per_s`: cumulative sums of exponential inter-arrival gaps.
pub fn poisson_arrivals(n: usize, rate_per_s: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xA221);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate_per_s);
            t
        })
        .collect()
}

/// Drive `batcher` through an open-loop arrival process until everything
/// submitted has completed. Questions are taken round-robin from
/// `questions`; `arrivals` must be non-decreasing (as produced by
/// [`poisson_arrivals`]).
pub fn run_open_loop(
    batcher: &mut Batcher,
    questions: &[Question],
    arrivals: &[f64],
    tick_dt: f64,
) -> Result<()> {
    anyhow::ensure!(!questions.is_empty(), "workload needs at least one question");
    let clock = batcher.clock().clone();
    let mut next = 0usize;
    loop {
        let now = clock.now();
        while next < arrivals.len() && arrivals[next] <= now {
            batcher.submit(questions[next % questions.len()].clone());
            next += 1;
        }
        if !batcher.has_work() {
            if next >= arrivals.len() {
                break;
            }
            // idle: jump (virtual) or wait (wall) for the next arrival
            if clock.is_virtual() {
                clock.advance(arrivals[next] - now);
            } else {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            continue;
        }
        batcher.tick()?;
        clock.advance(tick_dt);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_positive_and_increasing() {
        let a = poisson_arrivals(50, 8.0, 3);
        assert_eq!(a.len(), 50);
        assert!(a[0] > 0.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrival times must strictly increase");
        }
    }

    #[test]
    fn arrivals_are_seed_deterministic() {
        assert_eq!(poisson_arrivals(20, 4.0, 9), poisson_arrivals(20, 4.0, 9));
        assert_ne!(poisson_arrivals(20, 4.0, 9), poisson_arrivals(20, 4.0, 10));
    }

    #[test]
    fn mean_gap_tracks_the_rate() {
        let a = poisson_arrivals(4000, 10.0, 1);
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 0.1).abs() < 0.01, "mean gap {mean_gap}");
    }
}
