//! Open-loop workload driver and the arrival-process zoo (DESIGN.md
//! §3.4, §3.11): submits requests as their arrival times pass,
//! interleaved with scheduler ticks.
//!
//! Under a wall clock this paces a live load test (arrivals fire in real
//! time, the driver naps while idle). Under a virtual clock the driver
//! advances time itself — `tick_dt` simulated seconds per scheduling
//! tick, jumping straight to the next *event* when the target idles —
//! so the entire serve run (arrival pattern, admission order, preemption
//! decisions, latency percentiles) is a pure function of the seed.
//!
//! Arrival patterns come from [`ArrivalProcess`] implementations —
//! Poisson, bursty (MMPP on/off), diurnal (sinusoid-thinned), and trace
//! replay — every one an O(1)-state stream that yields timestamps one at
//! a time, so the soak paces a million arrivals without materializing
//! them. The seeded variants are pure functions of `(rate, seed)`;
//! [`PoissonStream`] through the trait is pinned bit-identical to the
//! pre-trait stream.
//!
//! The driver is generic over [`OpenLoopTarget`], so it paces both the
//! white-box [`Batcher`] and the black-box
//! [`crate::blackbox::BlackboxBatcher`] (DESIGN.md §3.6). The black-box
//! target adds a second event source besides arrivals: simulated chunk
//! deliveries — `blocked_until` reports the earliest one whenever every
//! active stream is parked on a future arrival, and the driver jumps to
//! `min(next request arrival, next chunk delivery)` instead of burning
//! empty ticks.

use anyhow::{Context, Result};

use super::batcher::{Batcher, DEFAULT_TICK_DT};
use crate::blackbox::BlackboxBatcher;
use crate::datasets::Question;
use crate::util::cli::ArrivalSpec;
use crate::util::clock::Clock;
use crate::util::rng::Rng;
use crate::util::wheel::EventWheel;

/// A streaming arrival process: each call yields the next arrival
/// timestamp (seconds), non-decreasing across calls. Implementations
/// keep O(1)+O(trace) state and — apart from trace replay, which is a
/// pure function of its file — are pure functions of `(rate, seed)`.
pub trait ArrivalProcess {
    fn next_arrival(&mut self) -> f64;
}

/// Streaming Poisson arrival process: yields the same cumulative-sum
/// sequence as [`poisson_arrivals`] one timestamp at a time, in O(1)
/// state — the soak driver paces a million arrivals through this
/// without ever materializing them.
pub struct PoissonStream {
    rng: Rng,
    rate_per_s: f64,
    t: f64,
}

impl PoissonStream {
    pub fn new(rate_per_s: f64, seed: u64) -> PoissonStream {
        PoissonStream {
            rng: Rng::new(seed ^ 0xA221),
            rate_per_s,
            t: 0.0,
        }
    }

    /// Next arrival time (seconds): the previous one plus an exponential
    /// inter-arrival gap.
    pub fn next_arrival(&mut self) -> f64 {
        self.t += self.rng.exponential(self.rate_per_s);
        self.t
    }
}

impl ArrivalProcess for PoissonStream {
    fn next_arrival(&mut self) -> f64 {
        PoissonStream::next_arrival(self)
    }
}

/// On-state rate multiplier of the bursty (MMPP) process.
const BURST_HIGH: f64 = 2.5;
/// Off-state rate multiplier of the bursty (MMPP) process.
const BURST_LOW: f64 = 0.5;
/// Mean dwell in the on (burst) state, seconds.
const BURST_ON_MEAN_S: f64 = 2.0;
/// Mean dwell in the off (quiet) state, seconds.
const BURST_OFF_MEAN_S: f64 = 6.0;

/// Bursty arrivals: a two-state Markov-modulated Poisson process. The
/// rate alternates between `BURST_HIGH`x (on) and `BURST_LOW`x (off)
/// the base rate, with exponentially distributed dwell times; the duty
/// cycle (2s on / 6s off) makes the long-run mean rate equal the base
/// rate, so `--arrivals burst` stresses queueing without changing
/// offered load. Exactness note: the exponential clock is memoryless,
/// so redrawing the inter-arrival gap at each state flip simulates the
/// MMPP exactly.
pub struct BurstStream {
    gaps: Rng,
    dwell: Rng,
    rate_per_s: f64,
    t: f64,
    on: bool,
    phase_end: f64,
}

impl BurstStream {
    pub fn new(rate_per_s: f64, seed: u64) -> BurstStream {
        let mut dwell = Rng::new(seed ^ 0xB5257);
        let phase_end = dwell.exponential(1.0 / BURST_OFF_MEAN_S);
        BurstStream {
            gaps: Rng::new(seed ^ 0xA221),
            dwell,
            rate_per_s,
            t: 0.0,
            on: false,
            phase_end,
        }
    }
}

impl ArrivalProcess for BurstStream {
    fn next_arrival(&mut self) -> f64 {
        loop {
            let rate = self.rate_per_s * if self.on { BURST_HIGH } else { BURST_LOW };
            let gap = self.gaps.exponential(rate);
            if self.t + gap <= self.phase_end {
                self.t += gap;
                return self.t;
            }
            // No arrival before the state flips: restart the memoryless
            // exponential clock at the boundary under the new rate.
            self.t = self.phase_end;
            self.on = !self.on;
            let mean = if self.on { BURST_ON_MEAN_S } else { BURST_OFF_MEAN_S };
            self.phase_end = self.t + self.dwell.exponential(1.0 / mean);
        }
    }
}

/// One synthetic "day", seconds — short enough that soak-length runs see
/// several peaks and troughs.
const DIURNAL_PERIOD_S: f64 = 120.0;

/// Diurnal arrivals: a sinusoid-modulated Poisson process via thinning.
/// Candidates are drawn at the 2x peak rate and accepted with
/// probability `(1 + sin(2πt/P))/2`, giving instantaneous rate
/// `rate · (1 + sin(2πt/P))` — mean rate equal to the base rate, peaks
/// at 2x, troughs near zero.
pub struct DiurnalStream {
    rng: Rng,
    rate_per_s: f64,
    t: f64,
}

impl DiurnalStream {
    pub fn new(rate_per_s: f64, seed: u64) -> DiurnalStream {
        DiurnalStream { rng: Rng::new(seed ^ 0xD1042), rate_per_s, t: 0.0 }
    }
}

impl ArrivalProcess for DiurnalStream {
    fn next_arrival(&mut self) -> f64 {
        loop {
            self.t += self.rng.exponential(2.0 * self.rate_per_s);
            let phase = self.t / DIURNAL_PERIOD_S * std::f64::consts::TAU;
            let accept = (1.0 + phase.sin()) / 2.0;
            if self.rng.f64() < accept {
                return self.t;
            }
        }
    }
}

/// Trace replay: arrivals at recorded timestamps, cycled with a growing
/// offset when the trace is shorter than the run. When `rate_per_s > 0`
/// the timestamps are rescaled so the trace's mean rate matches it
/// (burstiness *shape* preserved, offered load controllable); at
/// `rate_per_s <= 0` the trace replays verbatim.
pub struct TraceStream {
    times: Vec<f64>,
    idx: usize,
    offset: f64,
    span: f64,
}

impl TraceStream {
    pub fn new(mut times: Vec<f64>, rate_per_s: f64) -> Result<TraceStream> {
        anyhow::ensure!(!times.is_empty(), "arrival trace is empty");
        for w in times.windows(2) {
            anyhow::ensure!(
                w[0].is_finite() && w[1] >= w[0],
                "arrival trace must be finite and non-decreasing"
            );
        }
        anyhow::ensure!(
            times[0].is_finite() && times[0] >= 0.0,
            "arrival trace must start at a non-negative time"
        );
        let last = *times.last().expect("non-empty");
        if rate_per_s > 0.0 && last > 0.0 {
            let native = times.len() as f64 / last;
            let scale = native / rate_per_s;
            for t in &mut times {
                *t *= scale;
            }
        }
        let last = *times.last().expect("non-empty");
        // Wrap the cycle with one mean inter-arrival gap so the replayed
        // stream stays strictly ordered across the seam.
        let span = if last > 0.0 { last + last / times.len() as f64 } else { 1.0 };
        Ok(TraceStream { times, idx: 0, offset: 0.0, span })
    }

    /// Load a trace from a file of timestamps: either a JSON array of
    /// numbers or whitespace/comma-separated floats — both reduce to
    /// "every numeric token in the file, in order".
    pub fn from_file(path: &str, rate_per_s: f64) -> Result<TraceStream> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading arrival trace {path}"))?;
        let times: Vec<f64> = raw
            .split(|c: char| c.is_whitespace() || matches!(c, ',' | '[' | ']'))
            .filter(|tok| !tok.is_empty())
            .map(|tok| {
                tok.parse::<f64>()
                    .with_context(|| format!("bad timestamp {tok:?} in trace {path}"))
            })
            .collect::<Result<_>>()?;
        TraceStream::new(times, rate_per_s)
            .with_context(|| format!("invalid arrival trace {path}"))
    }
}

impl ArrivalProcess for TraceStream {
    fn next_arrival(&mut self) -> f64 {
        if self.idx == self.times.len() {
            self.idx = 0;
            self.offset += self.span;
        }
        let t = self.offset + self.times[self.idx];
        self.idx += 1;
        t
    }
}

/// Build the arrival process a parsed [`ArrivalSpec`] names, at the
/// given offered rate and seed. The single place the `--arrivals` flag
/// becomes a stream — serve (single/cluster/blackbox), soak, and the
/// benches all route through here.
pub fn build_arrivals(
    spec: &ArrivalSpec,
    rate_per_s: f64,
    seed: u64,
) -> Result<Box<dyn ArrivalProcess>> {
    Ok(match spec {
        ArrivalSpec::Poisson => Box::new(PoissonStream::new(rate_per_s, seed)),
        ArrivalSpec::Burst => Box::new(BurstStream::new(rate_per_s, seed)),
        ArrivalSpec::Diurnal => Box::new(DiurnalStream::new(rate_per_s, seed)),
        ArrivalSpec::Trace(path) => Box::new(TraceStream::from_file(path, rate_per_s)?),
    })
}

/// Materialize the first `n` arrivals of a spec'd process — the batch
/// shape the pre-wheel soak driver core and offline analyses want.
pub fn collect_arrivals(
    spec: &ArrivalSpec,
    n: usize,
    rate_per_s: f64,
    seed: u64,
) -> Result<Vec<f64>> {
    let mut process = build_arrivals(spec, rate_per_s, seed)?;
    Ok((0..n).map(|_| process.next_arrival()).collect())
}

/// Seeded Poisson arrival times (seconds) for `n` requests at
/// `rate_per_s`: cumulative sums of exponential inter-arrival gaps.
pub fn poisson_arrivals(n: usize, rate_per_s: f64, seed: u64) -> Vec<f64> {
    let mut stream = PoissonStream::new(rate_per_s, seed);
    (0..n).map(|_| stream.next_arrival()).collect()
}

/// Anything the open-loop driver can pace: a clocked batcher that
/// accepts submissions and advances by ticks.
pub trait OpenLoopTarget {
    fn clock(&self) -> &Clock;
    fn submit(&mut self, question: Question);
    /// Submit on behalf of a tenant (multi-tenant admission, DESIGN.md
    /// §3.11). Targets without tenancy ignore the id.
    fn submit_tenant(&mut self, question: Question, tenant: u32) {
        let _ = tenant;
        self.submit(question);
    }
    /// Anything left to do (queued or in flight).
    fn has_work(&self) -> bool;
    /// Earliest *future* event the target is parked on when a tick right
    /// now would advance nothing (e.g. every black-box stream awaiting a
    /// scheduled chunk arrival). `None` = tick away.
    fn blocked_until(&self) -> Option<f64> {
        None
    }
    fn tick_once(&mut self) -> Result<()>;
}

impl OpenLoopTarget for Batcher<'_> {
    fn clock(&self) -> &Clock {
        Batcher::clock(self)
    }

    fn submit(&mut self, question: Question) {
        Batcher::submit(self, question)
    }

    fn submit_tenant(&mut self, question: Question, tenant: u32) {
        Batcher::submit_tenant(self, question, tenant)
    }

    fn has_work(&self) -> bool {
        Batcher::has_work(self)
    }

    fn tick_once(&mut self) -> Result<()> {
        Batcher::tick(self).map(|_| ())
    }
}

impl OpenLoopTarget for BlackboxBatcher<'_> {
    fn clock(&self) -> &Clock {
        BlackboxBatcher::clock(self)
    }

    fn submit(&mut self, question: Question) {
        BlackboxBatcher::submit(self, question)
    }

    fn has_work(&self) -> bool {
        BlackboxBatcher::has_work(self)
    }

    fn blocked_until(&self) -> Option<f64> {
        BlackboxBatcher::blocked_until(self)
    }

    fn tick_once(&mut self) -> Result<()> {
        BlackboxBatcher::tick(self).map(|_| ())
    }
}

/// A slice viewed as an [`ArrivalProcess`] — lets the batch-shaped
/// [`run_open_loop`] share one driver core with the streaming entry
/// point.
struct SliceProcess<'a> {
    arrivals: &'a [f64],
    i: usize,
}

impl ArrivalProcess for SliceProcess<'_> {
    fn next_arrival(&mut self) -> f64 {
        let t = self.arrivals[self.i];
        self.i += 1;
        t
    }
}

/// Drive `target` through an open-loop arrival process until everything
/// submitted has completed. Questions are taken round-robin from
/// `questions`; `arrivals` must be non-decreasing (as produced by
/// [`poisson_arrivals`]).
pub fn run_open_loop<T: OpenLoopTarget>(
    target: &mut T,
    questions: &[Question],
    arrivals: &[f64],
    tick_dt: f64,
) -> Result<()> {
    let mut process = SliceProcess { arrivals, i: 0 };
    run_open_loop_stream(target, questions, &mut process, arrivals.len(), tick_dt, 1)
}

/// Drive `target` through a *streaming* [`ArrivalProcess`] for `n`
/// arrivals, assigning tenants round-robin (`seq % tenants`; pass 1 for
/// the single-tenant workloads).
///
/// Arrivals live on the event wheel (DESIGN.md §3.10), scheduled one at
/// a time: popping arrival `i` schedules arrival `i+1`, which is sound
/// because the process is non-decreasing — the next arrival can never
/// sort before the one just popped. Keys are `(time, lane 0, seq)`,
/// identical to the batch path, so the wheel's total order makes the
/// streamed and materialized drivers pop the same event sequence. The
/// wheel's peeked head doubles as the idle-jump target, so a long gap
/// between arrivals costs one jump, not a bucket crawl.
pub fn run_open_loop_stream<T: OpenLoopTarget>(
    target: &mut T,
    questions: &[Question],
    process: &mut dyn ArrivalProcess,
    n: usize,
    tick_dt: f64,
    tenants: u32,
) -> Result<()> {
    anyhow::ensure!(!questions.is_empty(), "workload needs at least one question");
    anyhow::ensure!(tenants > 0, "tenant count must be positive");
    let clock = target.clock().clone();
    let mut wheel: EventWheel<usize> = EventWheel::new(DEFAULT_TICK_DT);
    let mut scheduled = 0usize;
    let mut last_t = 0.0f64;
    let mut schedule_next =
        |wheel: &mut EventWheel<usize>, scheduled: &mut usize, last_t: &mut f64| -> Result<()> {
            if *scheduled < n {
                let t = process.next_arrival();
                anyhow::ensure!(
                    t.is_finite() && t >= *last_t,
                    "arrival process must yield finite non-decreasing times (got {t} after {last_t})"
                );
                *last_t = t;
                wheel.schedule_at(t, 0, *scheduled as u64, *scheduled);
                *scheduled += 1;
            }
            Ok(())
        };
    schedule_next(&mut wheel, &mut scheduled, &mut last_t)?;
    loop {
        let now = clock.now();
        while wheel.peek_time().is_some_and(|t| t <= now) {
            let (_, i) = wheel.pop().expect("peeked arrival exists");
            target.submit_tenant(
                questions[i % questions.len()].clone(),
                (i % tenants as usize) as u32,
            );
            schedule_next(&mut wheel, &mut scheduled, &mut last_t)?;
        }
        if !target.has_work() {
            let Some(next_t) = wheel.peek_time() else {
                break;
            };
            // idle: jump (virtual) or wait (wall) for the next arrival
            if clock.is_virtual() {
                clock.advance(next_t - now);
            } else {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            continue;
        }
        if let Some(until) = target.blocked_until() {
            // parked on a future event (chunk delivery): jump to the
            // earlier of it and the next request arrival
            let mut at = until;
            if let Some(next_t) = wheel.peek_time() {
                at = at.min(next_t);
            }
            if at > now {
                if clock.is_virtual() {
                    clock.advance(at - now);
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                continue;
            }
            // fp edge: the event is effectively "now" — fall through
        }
        target.tick_once()?;
        clock.advance(tick_dt);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_positive_and_increasing() {
        let a = poisson_arrivals(50, 8.0, 3);
        assert_eq!(a.len(), 50);
        assert!(a[0] > 0.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrival times must strictly increase");
        }
    }

    #[test]
    fn arrivals_are_seed_deterministic() {
        assert_eq!(poisson_arrivals(20, 4.0, 9), poisson_arrivals(20, 4.0, 9));
        assert_ne!(poisson_arrivals(20, 4.0, 9), poisson_arrivals(20, 4.0, 10));
    }

    #[test]
    fn mean_gap_tracks_the_rate() {
        let a = poisson_arrivals(4000, 10.0, 1);
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 0.1).abs() < 0.01, "mean gap {mean_gap}");
    }

    #[test]
    fn stream_reproduces_the_batch_arrivals_bit_for_bit() {
        let batch = poisson_arrivals(256, 6.0, 77);
        let mut stream = PoissonStream::new(6.0, 77);
        for (i, &t) in batch.iter().enumerate() {
            assert_eq!(stream.next_arrival().to_bits(), t.to_bits(), "arrival {i}");
        }
    }

    #[test]
    fn poisson_through_the_trait_is_the_legacy_stream() {
        // The ArrivalSpec::Poisson path must stay bit-identical to the
        // pre-trait PoissonStream — this is what keeps every default
        // serve/soak run byte-identical across the refactor.
        let batch = poisson_arrivals(512, 12.5, 41);
        let mut process = build_arrivals(&ArrivalSpec::Poisson, 12.5, 41).unwrap();
        for (i, &t) in batch.iter().enumerate() {
            assert_eq!(process.next_arrival().to_bits(), t.to_bits(), "arrival {i}");
        }
    }

    #[test]
    fn burst_and_diurnal_are_deterministic_and_non_decreasing() {
        for spec in [ArrivalSpec::Burst, ArrivalSpec::Diurnal] {
            let a = collect_arrivals(&spec, 2000, 40.0, 9).unwrap();
            let b = collect_arrivals(&spec, 2000, 40.0, 9).unwrap();
            assert_eq!(a, b, "{spec:?} is not a pure function of (rate, seed)");
            let c = collect_arrivals(&spec, 2000, 40.0, 10).unwrap();
            assert_ne!(a, c, "{spec:?} ignores its seed");
            assert!(a[0] > 0.0);
            for w in a.windows(2) {
                assert!(w[1] >= w[0], "{spec:?} went backwards: {} -> {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn burst_and_diurnal_mean_rates_track_the_base_rate() {
        // Both processes modulate *shape*, not offered load: long-run
        // mean rate stays within ~15% of the base rate.
        for spec in [ArrivalSpec::Burst, ArrivalSpec::Diurnal] {
            let a = collect_arrivals(&spec, 40_000, 50.0, 3).unwrap();
            let rate = a.len() as f64 / a.last().unwrap();
            assert!(
                (rate - 50.0).abs() < 7.5,
                "{spec:?} drifted the offered load: {rate}/s"
            );
        }
    }

    #[test]
    fn burst_is_actually_burstier_than_poisson() {
        // Squared coefficient of variation of inter-arrival gaps: 1 for
        // Poisson, >1 for the on/off MMPP.
        let cv2 = |a: &[f64]| {
            let gaps: Vec<f64> = a.windows(2).map(|w| w[1] - w[0]).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let burst = collect_arrivals(&ArrivalSpec::Burst, 20_000, 50.0, 5).unwrap();
        let pois = poisson_arrivals(20_000, 50.0, 5);
        assert!(
            cv2(&burst) > cv2(&pois) * 1.5,
            "burst CV² {} vs poisson {}",
            cv2(&burst),
            cv2(&pois)
        );
    }

    #[test]
    fn trace_replay_cycles_with_a_growing_offset() {
        let mut tr = TraceStream::new(vec![0.5, 1.0, 2.0], 0.0).unwrap();
        let got: Vec<f64> = (0..7).map(|_| tr.next_arrival()).collect();
        // span = 2.0 + 2.0/3
        let span = 2.0 + 2.0 / 3.0;
        let want = [0.5, 1.0, 2.0, span + 0.5, span + 1.0, span + 2.0, 2.0 * span + 0.5];
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12, "{got:?} vs {want:?}");
        }
        for w in got.windows(2) {
            assert!(w[1] > w[0], "trace replay must keep increasing across the seam");
        }
    }

    #[test]
    fn trace_rescales_to_the_requested_rate() {
        // Native rate 3 arrivals / 2s = 1.5/s; ask for 15/s -> 10x faster.
        let mut tr = TraceStream::new(vec![0.5, 1.0, 2.0], 15.0).unwrap();
        assert!((tr.next_arrival() - 0.05).abs() < 1e-12);
        assert!((tr.next_arrival() - 0.10).abs() < 1e-12);
    }

    #[test]
    fn trace_rejects_garbage() {
        assert!(TraceStream::new(vec![], 0.0).is_err());
        assert!(TraceStream::new(vec![1.0, 0.5], 0.0).is_err());
        assert!(TraceStream::new(vec![-1.0, 0.5], 0.0).is_err());
        assert!(TraceStream::new(vec![f64::NAN], 0.0).is_err());
    }
}
