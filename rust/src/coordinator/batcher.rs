//! Continuous batcher: vLLM-style slot scheduling over `ReasoningSession`s.
//!
//! Requests arrive with timestamps (the workload generator produces a
//! Poisson process); the batcher admits them into up to `slots` concurrent
//! sessions (KV capacity permitting — backpressure otherwise), advances all
//! active sessions round-robin one decode step per scheduling tick, and
//! retires finished ones. On 1 CPU core the decode steps of co-resident
//! requests interleave rather than parallelize; the scheduling, admission,
//! fairness and accounting logic is identical to the multi-device case.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::engine::{MonitorModel, ReasoningSession, RequestResult};
use super::kv::{KvSlotManager, SlotId};
use super::metrics::ServeMetrics;
use crate::config::ServeConfig;
use crate::datasets::Question;
use crate::exit::ExitPolicy;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// A request waiting for admission.
pub struct QueuedRequest {
    pub question: Question,
    pub arrived: Instant,
}

struct Active<'a> {
    session: ReasoningSession<'a>,
    slot: SlotId,
    arrived: Instant,
    admitted: Instant,
}

/// Policy factory: each admitted request gets a fresh policy instance.
pub type PolicyFactory = Box<dyn Fn() -> Box<dyn ExitPolicy>>;

pub struct Batcher<'a> {
    rt: &'a Runtime,
    cfg: ServeConfig,
    monitor: MonitorModel,
    make_policy: PolicyFactory,
    kv: KvSlotManager,
    queue: VecDeque<QueuedRequest>,
    active: Vec<Active<'a>>,
    rng: Rng,
    pub metrics: ServeMetrics,
    pub results: Vec<RequestResult>,
}

impl<'a> Batcher<'a> {
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        slots: usize,
        make_policy: PolicyFactory,
    ) -> Batcher<'a> {
        let slot_bytes = rt.cfg.main.cache_elems() * 4 * 2
            + if monitor == MonitorModel::Proxy {
                rt.cfg.proxy.cache_elems() * 4 * 2
            } else {
                0
            };
        let seed = cfg.seed;
        Batcher {
            rt,
            cfg,
            monitor,
            make_policy,
            kv: KvSlotManager::new(slots, slot_bytes),
            queue: VecDeque::new(),
            active: Vec::new(),
            rng: Rng::new(seed ^ 0xBA7C4E5),
            metrics: ServeMetrics::new(),
            results: Vec::new(),
        }
    }

    pub fn submit(&mut self, question: Question) {
        self.queue.push_back(QueuedRequest {
            question,
            arrived: Instant::now(),
        });
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    pub fn kv_peak(&self) -> usize {
        self.kv.peak()
    }

    /// Admit queued requests while KV slots are free (prefill phase).
    fn admit(&mut self) -> Result<()> {
        while !self.queue.is_empty() {
            let Some(slot) = self.kv.acquire() else {
                break; // backpressure: leave the rest queued
            };
            let req = self.queue.pop_front().unwrap();
            let policy = (self.make_policy)();
            let session = ReasoningSession::new(
                self.rt,
                self.cfg.clone(),
                self.monitor,
                req.question,
                policy,
                self.rng.fork(),
            )?;
            self.active.push(Active {
                session,
                slot,
                arrived: req.arrived,
                admitted: Instant::now(),
            });
        }
        Ok(())
    }

    /// One scheduling tick: admit, then advance every active session by a
    /// single decode step (continuous batching granularity), retiring the
    /// finished ones. Returns the number of sessions advanced.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        let mut advanced = 0;
        let mut finished_idx = Vec::new();
        for (i, a) in self.active.iter_mut().enumerate() {
            let done = a.session.step()?;
            advanced += 1;
            if done {
                finished_idx.push(i);
            }
        }
        // retire in reverse index order to keep indices valid
        for &i in finished_idx.iter().rev() {
            let a = self.active.swap_remove(i);
            self.kv.release(a.slot)?;
            let queue_ms =
                a.admitted.duration_since(a.arrived).as_secs_f64() * 1e3;
            let latency_ms =
                a.arrived.elapsed().as_secs_f64() * 1e3;
            let result = a.session.finish();
            self.metrics.record_completion(
                result.correct,
                result.reasoning_tokens,
                result.probes,
                result.rollout_tokens,
                latency_ms,
                queue_ms,
                result.exit_reason,
            );
            self.results.push(result);
        }
        Ok(advanced)
    }

    /// Drain: run ticks until queue and active set are empty.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.queue.is_empty() || !self.active.is_empty() {
            self.tick()?;
        }
        Ok(())
    }
}
