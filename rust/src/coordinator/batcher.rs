//! Continuous batcher: vLLM-style slot scheduling over split-phase
//! [`ReasoningSession`]s — the batcher, not the session, owns model
//! execution (DESIGN.md §3.3).
//!
//! Requests arrive with timestamps (the workload generator produces a
//! Poisson process); the batcher admits them into up to `slots`
//! concurrent sessions (KV capacity permitting — backpressure
//! otherwise). Each scheduling tick it polls every active session up to
//! its pending decode, servicing probes and rollouts *out-of-band* as
//! they surface, then commits **all pending decodes in one fused
//! `decode_batch` call** against the slot-major [`BatchCacheStore`]
//! (idle lanes padded; chunked only if active > batch width). When the
//! backend carries no batch entry point — or `force_sequential` is set —
//! the same decodes run one by one in admission order. The session
//! protocol cannot observe which path serviced it, so on the reference
//! backend (a pure function of token history) the two paths are
//! bit-identical for the same seed; on PJRT artifacts the fused kernel
//! agrees with the single-decode kernel to ~1e-3, so sampled tokens can
//! in principle diverge at nucleus boundaries.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::batch_cache::{BatchCacheStore, StoreCounters};
use super::engine::{
    run_probe, run_rollout, start_session, MonitorModel, ReasoningSession, RequestResult,
    StepWork,
};
use super::kv::{KvSlotManager, SlotId};
use super::metrics::ServeMetrics;
use crate::config::ServeConfig;
use crate::datasets::Question;
use crate::exit::ExitPolicy;
use crate::runtime::{Backend, Runtime};
use crate::util::rng::Rng;

/// A request waiting for admission.
pub struct QueuedRequest {
    pub question: Question,
    pub arrived: Instant,
}

struct Active {
    session: ReasoningSession,
    slot: SlotId,
    arrived: Instant,
    admitted: Instant,
}

/// Policy factory: each admitted request gets a fresh policy instance.
pub type PolicyFactory = Box<dyn Fn() -> Box<dyn ExitPolicy>>;

pub struct Batcher<'a> {
    rt: &'a Runtime,
    cfg: ServeConfig,
    monitor: MonitorModel,
    make_policy: PolicyFactory,
    kv: KvSlotManager,
    store: BatchCacheStore,
    queue: VecDeque<QueuedRequest>,
    active: Vec<Active>,
    rng: Rng,
    /// Disable the fused path even when the backend has one (A/B
    /// determinism checks, ablations).
    pub force_sequential: bool,
    pub metrics: ServeMetrics,
    pub results: Vec<RequestResult>,
}

impl<'a> Batcher<'a> {
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        slots: usize,
        make_policy: PolicyFactory,
    ) -> Batcher<'a> {
        let slot_bytes = rt.main.cache_elems() * 4 * 2
            + if monitor == MonitorModel::Proxy {
                rt.proxy.cache_elems() * 4 * 2
            } else {
                0
            };
        let seed = cfg.seed;
        Batcher {
            rt,
            cfg,
            monitor,
            make_policy,
            kv: KvSlotManager::new(slots, slot_bytes),
            store: BatchCacheStore::new(slots),
            queue: VecDeque::new(),
            active: Vec::new(),
            rng: Rng::new(seed ^ 0xBA7C4E5),
            force_sequential: false,
            metrics: ServeMetrics::new(),
            results: Vec::new(),
        }
    }

    pub fn submit(&mut self, question: Question) {
        self.queue.push_back(QueuedRequest {
            question,
            arrived: Instant::now(),
        });
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    pub fn kv_peak(&self) -> usize {
        self.kv.peak()
    }

    /// Batch-store upload/residency accounting.
    pub fn store_counters(&self) -> StoreCounters {
        self.store.counters
    }

    /// Admit queued requests while KV slots are free (prefill phase).
    fn admit(&mut self) -> Result<()> {
        while !self.queue.is_empty() {
            let Some(slot) = self.kv.acquire() else {
                break; // backpressure: leave the rest queued
            };
            let req = self.queue.pop_front().unwrap();
            let policy = (self.make_policy)();
            let (session, caches) = start_session(
                self.rt,
                self.cfg.clone(),
                self.monitor,
                req.question,
                policy,
                self.rng.fork(),
            )?;
            self.store.install(slot, caches.main, caches.proxy)?;
            self.active.push(Active {
                session,
                slot,
                arrived: req.arrived,
                admitted: Instant::now(),
            });
        }
        Ok(())
    }

    /// One scheduling tick: admit; poll every active session to its
    /// pending decode (probes/rollouts serviced out-of-band); commit all
    /// pending decodes — fused when possible, sequential otherwise;
    /// retire sessions that reported `Done`. Returns the number of
    /// sessions advanced.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        let rt = self.rt;
        let force_sequential = self.force_sequential;
        let store = &mut self.store;
        let active = &mut self.active;

        let mut advanced = 0usize;
        // (active index, token, mirror-to-proxy)
        let mut decodes: Vec<(usize, u32, bool)> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();

        // phase A: drive each session to its next decode or completion
        for (i, a) in active.iter_mut().enumerate() {
            loop {
                match a.session.poll() {
                    StepWork::Done => {
                        finished.push(i);
                        break;
                    }
                    StepWork::Decode { token, mirror } => {
                        decodes.push((i, token, mirror));
                        break;
                    }
                    StepWork::Probe { suffix, target } => {
                        run_probe(
                            rt,
                            &mut a.session,
                            store.main(a.slot)?,
                            store.proxy(a.slot),
                            &suffix,
                            target,
                        )?;
                    }
                    StepWork::Rollout { suffix, max_tokens } => {
                        run_rollout(rt, &mut a.session, store.main(a.slot)?, &suffix, max_tokens)?;
                    }
                }
            }
            advanced += 1;
        }

        // phase B: commit every pending decode
        let width = if force_sequential {
            None
        } else {
            rt.main.batch_width()
        };
        match width {
            Some(w) => {
                // one fused decode_batch per tick (chunked only when the
                // active set exceeds the batch width)
                for chunk in decodes.chunks(w) {
                    let picks: Vec<(SlotId, u32)> = chunk
                        .iter()
                        .map(|&(i, tok, _)| (active[i].slot, tok))
                        .collect();
                    let logits = store.fused_decode(rt.main.as_ref(), &picks)?;
                    for (&(i, token, mirror), lg) in chunk.iter().zip(logits) {
                        if mirror {
                            if let Some(pc) = store.proxy_mut(active[i].slot) {
                                rt.proxy.decode(pc, token)?;
                            }
                        }
                        active[i].session.complete_decode(lg)?;
                    }
                }
            }
            None => {
                // sequential fallback, admission order: same results,
                // one decode per session
                for &(i, token, mirror) in &decodes {
                    let slot = active[i].slot;
                    let lg = rt.main.decode(store.main_mut(slot)?, token)?;
                    store.mark_dirty(slot)?;
                    if mirror {
                        if let Some(pc) = store.proxy_mut(slot) {
                            rt.proxy.decode(pc, token)?;
                        }
                    }
                    active[i].session.complete_decode(lg)?;
                }
            }
        }

        // phase C: retire in reverse index order to keep indices valid
        for &i in finished.iter().rev() {
            let a = active.swap_remove(i);
            store.retire(a.slot)?;
            self.kv.release(a.slot)?;
            let queue_ms = a.admitted.duration_since(a.arrived).as_secs_f64() * 1e3;
            let latency_ms = a.arrived.elapsed().as_secs_f64() * 1e3;
            let result = a.session.finish();
            self.metrics.record_completion(
                result.correct,
                result.reasoning_tokens,
                result.probes,
                result.rollout_tokens,
                latency_ms,
                queue_ms,
                result.exit_reason,
            );
            self.results.push(result);
        }
        Ok(advanced)
    }

    /// Drain: run ticks until queue and active set are empty.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.queue.is_empty() || !self.active.is_empty() {
            self.tick()?;
        }
        Ok(())
    }
}
