//! Continuous batcher + EAT-aware preemptive scheduler: vLLM-style slot
//! scheduling over split-phase [`ReasoningSession`]s — the batcher, not
//! the session, owns model execution (DESIGN.md §3.3/§3.4).
//!
//! Requests arrive with timestamps read from an injected [`Clock`] (the
//! workload driver produces a Poisson process; under a virtual clock the
//! whole run is a pure function of the seed). The batcher admits them
//! into up to `slots` concurrent sessions (KV capacity permitting —
//! backpressure otherwise). Each scheduling tick it polls every active
//! session up to its pending decode, servicing probes and rollouts
//! *out-of-band* as they surface, then commits **all pending decodes in
//! one fused `decode_batch` call** against the slot-major
//! [`BatchCacheStore`] (idle lanes padded; chunked only if active >
//! batch width). When the backend carries no batch entry point — or
//! `force_sequential` is set — the same decodes run one by one in
//! admission order. The session protocol cannot observe which path
//! serviced it, so on the reference backend (a pure function of token
//! history) the two paths are bit-identical for the same seed; on PJRT
//! artifacts the fused kernel agrees with the single-decode kernel to
//! ~1e-3, so sampled tokens can in principle diverge at nucleus
//! boundaries.
//!
//! In `SchedMode::EatAware` the FIFO loop becomes a scheduler
//! (DESIGN.md §3.4): admission prefers earliest deadlines, long-stalled
//! sessions (low `ExitPolicy::stability`, past the aging bound) are
//! *preempted* — KV slot evicted, token history + monitor/policy state
//! retained in a [`SuspendedSession`] — and later resumed by re-prefill,
//! which is bit-identical on the reference backend. Per-request RNGs are
//! seeded from the submission sequence number, so a request's trajectory
//! is invariant to admission order and scheduling mode.

use std::collections::VecDeque;

use anyhow::Result;

use super::batch_cache::{BatchCacheStore, StoreCounters};
use super::engine::{
    resume_session, run_probe, run_rollout, start_session, MonitorModel, ReasoningSession,
    RequestResult, StepWork,
};
use super::kv::{KvSlotManager, SlotId};
use super::metrics::ServeMetrics;
use crate::config::{SchedMode, ServeConfig};
use crate::datasets::Question;
use crate::exit::{EatPolicy, ExitPolicy, ExitReason};
use crate::runtime::{Backend, Runtime};
use crate::util::clock::Clock;
use crate::util::rng::Rng;

/// A request waiting for admission.
pub struct QueuedRequest {
    pub question: Question,
    /// Clock seconds at submission.
    pub arrived: f64,
    /// SLO deadline: `arrived + sched.deadline_s`.
    pub deadline: f64,
    /// Submission sequence number: FIFO tiebreaker *and* the per-request
    /// RNG seed component, so a request's trajectory does not depend on
    /// admission order or scheduling mode.
    pub seq: u64,
}

struct Active {
    session: ReasoningSession,
    slot: SlotId,
    arrived: f64,
    /// First admission (queue-delay measurement; preserved across
    /// preemptions).
    admitted: f64,
    deadline: f64,
    seq: u64,
    /// Ticks since this session last entered its slot.
    resident_ticks: u64,
    preemptions: u32,
}

/// A preempted mid-flight session: the KV slot is evicted while the
/// token history and monitor/policy state live on here; resumption
/// rebuilds the caches by re-prefill ([`resume_session`]).
pub struct SuspendedSession {
    session: ReasoningSession,
    arrived: f64,
    admitted: f64,
    deadline: f64,
    seq: u64,
    preemptions: u32,
    suspended_at: f64,
}

/// Which waiter gets the next free slot.
enum AdmitPick {
    /// Index into the queue.
    Fresh(usize),
    /// Index into the suspended list.
    Resume(usize),
}

/// Policy factory: each admitted request gets a fresh policy instance.
pub type PolicyFactory = Box<dyn Fn() -> Box<dyn ExitPolicy>>;

/// The default serving policy factory: fresh [`EatPolicy`] instances
/// with the config's alpha/delta/budget (shared by the CLI, examples,
/// benches and tests).
pub fn eat_policy_factory(cfg: &ServeConfig) -> PolicyFactory {
    let (alpha, delta, budget) = (cfg.alpha, cfg.delta, cfg.max_think_tokens);
    Box::new(move || Box::new(EatPolicy::new(alpha, delta, budget)))
}

/// Simulated seconds charged per scheduling tick on a virtual clock
/// (one fused decode step at ~10 ms) — used by [`Batcher::run_to_completion`]
/// and as the workload driver's default.
pub const DEFAULT_TICK_DT: f64 = 0.01;

pub struct Batcher<'a> {
    rt: &'a Runtime,
    cfg: ServeConfig,
    monitor: MonitorModel,
    make_policy: PolicyFactory,
    kv: KvSlotManager,
    store: BatchCacheStore,
    clock: Clock,
    queue: VecDeque<QueuedRequest>,
    active: Vec<Active>,
    suspended: VecDeque<SuspendedSession>,
    next_seq: u64,
    /// Disable the fused path even when the backend has one (A/B
    /// determinism checks, ablations).
    pub force_sequential: bool,
    pub metrics: ServeMetrics,
    pub results: Vec<RequestResult>,
}

impl<'a> Batcher<'a> {
    /// Wall-clock batcher (live serving).
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        slots: usize,
        make_policy: PolicyFactory,
    ) -> Batcher<'a> {
        Batcher::with_clock(rt, cfg, monitor, slots, make_policy, Clock::wall())
    }

    /// Full constructor: inject the time source (a [`Clock::virt`] makes
    /// the entire serve run deterministic in the seed).
    pub fn with_clock(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        slots: usize,
        make_policy: PolicyFactory,
        clock: Clock,
    ) -> Batcher<'a> {
        let slot_bytes = rt.main.cache_elems() * 4 * 2
            + if monitor == MonitorModel::Proxy {
                rt.proxy.cache_elems() * 4 * 2
            } else {
                0
            };
        Batcher {
            rt,
            cfg,
            monitor,
            make_policy,
            kv: KvSlotManager::new(slots, slot_bytes),
            store: BatchCacheStore::new(slots),
            metrics: ServeMetrics::new(clock.clone()),
            clock,
            queue: VecDeque::new(),
            active: Vec::new(),
            suspended: VecDeque::new(),
            next_seq: 0,
            force_sequential: false,
            results: Vec::new(),
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn submit(&mut self, question: Question) {
        self.metrics.mark_start();
        let now = self.clock.now();
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(QueuedRequest {
            question,
            arrived: now,
            deadline: now + self.cfg.sched.deadline_s,
            seq,
        });
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn suspended_count(&self) -> usize {
        self.suspended.len()
    }

    /// Anything left to do: queued, resident, or suspended work.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty() || !self.suspended.is_empty()
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    pub fn kv_peak(&self) -> usize {
        self.kv.peak()
    }

    /// Batch-store upload/residency accounting.
    pub fn store_counters(&self) -> StoreCounters {
        self.store.counters
    }

    /// The per-request RNG: a pure function of the serve seed and the
    /// submission sequence number.
    fn request_rng(&self, seq: u64) -> Rng {
        Rng::new(self.cfg.seed ^ 0xBA7C4E5 ^ seq.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Pick the waiter for the next free slot.
    ///
    /// FIFO mode: suspended sessions first (oldest suspension), then the
    /// queue head. EAT-aware mode (DESIGN.md §3.4): (1) suspended
    /// sessions past the starvation guard (preempted `max_preemptions`
    /// times, or waiting longer than `resume_priority_after_s`), (2)
    /// fresh requests by earliest deadline, (3) remaining suspended
    /// sessions, oldest suspension first.
    fn pick_admission(&self) -> Option<AdmitPick> {
        if self.cfg.sched.mode == SchedMode::Fifo {
            if !self.suspended.is_empty() {
                return Some(AdmitPick::Resume(0));
            }
            return if self.queue.is_empty() {
                None
            } else {
                Some(AdmitPick::Fresh(0))
            };
        }
        let now = self.clock.now();
        let aged = self
            .suspended
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.preemptions >= self.cfg.sched.max_preemptions
                    || now - s.suspended_at >= self.cfg.sched.resume_priority_after_s
            })
            .min_by(|(_, a), (_, b)| {
                (a.deadline, a.seq).partial_cmp(&(b.deadline, b.seq)).unwrap()
            });
        if let Some((i, _)) = aged {
            return Some(AdmitPick::Resume(i));
        }
        let fresh = self.queue.iter().enumerate().min_by(|(_, a), (_, b)| {
            (a.deadline, a.seq).partial_cmp(&(b.deadline, b.seq)).unwrap()
        });
        if let Some((i, _)) = fresh {
            return Some(AdmitPick::Fresh(i));
        }
        self.suspended
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                (a.suspended_at, a.seq).partial_cmp(&(b.suspended_at, b.seq)).unwrap()
            })
            .map(|(i, _)| AdmitPick::Resume(i))
    }

    /// Admit waiters while KV slots are free: fresh requests prefill,
    /// suspended sessions resume by re-prefill.
    fn admit(&mut self) -> Result<()> {
        while self.kv.available() > 0 {
            let Some(pick) = self.pick_admission() else {
                break;
            };
            let Some(slot) = self.kv.acquire() else {
                break;
            };
            match pick {
                AdmitPick::Fresh(i) => {
                    let req = self.queue.remove(i).expect("picked index in range");
                    let policy = (self.make_policy)();
                    let rng = self.request_rng(req.seq);
                    let (session, caches) = start_session(
                        self.rt,
                        self.cfg.clone(),
                        self.monitor,
                        req.question,
                        policy,
                        rng,
                    )?;
                    self.store.install(slot, caches.main, caches.proxy)?;
                    self.active.push(Active {
                        session,
                        slot,
                        arrived: req.arrived,
                        admitted: self.clock.now(),
                        deadline: req.deadline,
                        seq: req.seq,
                        resident_ticks: 0,
                        preemptions: 0,
                    });
                }
                AdmitPick::Resume(i) => {
                    let mut s = self.suspended.remove(i).expect("picked index in range");
                    // Adaptive compute governor: a session still stalled
                    // after burning through the starvation guard has
                    // shown no EAT progress across multiple residencies —
                    // stop reasoning and elicit its answer now instead of
                    // burning the rest of the token budget (the paper's
                    // §6 stall extension, applied at the scheduler level).
                    if self.cfg.sched.mode == SchedMode::EatAware
                        && s.preemptions >= self.cfg.sched.max_preemptions
                        && s.session.stability().unwrap_or(1.0) <= self.cfg.sched.stall_stability
                    {
                        s.session.force_exit(ExitReason::Stalled);
                    }
                    let caches = resume_session(self.rt, &s.session)?;
                    self.metrics.record_resume(s.session.pos());
                    self.store.install(slot, caches.main, caches.proxy)?;
                    self.active.push(Active {
                        session: s.session,
                        slot,
                        arrived: s.arrived,
                        admitted: s.admitted,
                        deadline: s.deadline,
                        seq: s.seq,
                        resident_ticks: 0,
                        preemptions: s.preemptions,
                    });
                }
            }
            self.metrics.sample_slots(self.kv.in_use());
        }
        Ok(())
    }

    /// Preempt long-stalled sessions to free slots for fresh work
    /// (EAT-aware mode only): evict the KV slot, retain the session —
    /// token history plus monitor/policy state — in the suspended list.
    /// Stabilized sessions (stability above the stall cutoff) are never
    /// preempted: they are driven to completion.
    fn preempt(&mut self) -> Result<()> {
        if self.cfg.sched.mode != SchedMode::EatAware {
            return Ok(());
        }
        let aging = self.cfg.sched.preempt_after_ticks;
        let max_pre = self.cfg.sched.max_preemptions;
        let cutoff = self.cfg.sched.stall_stability;
        while !self.queue.is_empty() && self.kv.available() == 0 {
            let victim = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| {
                    a.session.can_suspend()
                        && !a.session.eliciting()
                        && a.preemptions < max_pre
                        && a.resident_ticks >= aging
                        && a.session.stability().unwrap_or(1.0) <= cutoff
                })
                .min_by(|(_, a), (_, b)| {
                    let sa = a.session.stability().unwrap_or(1.0);
                    let sb = b.session.stability().unwrap_or(1.0);
                    (sa, a.seq).partial_cmp(&(sb, b.seq)).unwrap()
                })
                .map(|(i, _)| i);
            let Some(i) = victim else {
                break;
            };
            let a = self.active.swap_remove(i);
            self.store.retire(a.slot)?;
            self.kv.release(a.slot)?;
            self.metrics.record_preemption();
            self.metrics.sample_slots(self.kv.in_use());
            self.suspended.push_back(SuspendedSession {
                session: a.session,
                arrived: a.arrived,
                admitted: a.admitted,
                deadline: a.deadline,
                seq: a.seq,
                preemptions: a.preemptions + 1,
                suspended_at: self.clock.now(),
            });
        }
        Ok(())
    }

    /// One scheduling tick: preempt (EAT-aware mode); admit/resume; poll
    /// every active session to its pending decode (probes/rollouts
    /// serviced out-of-band); commit all pending decodes — fused when
    /// possible, sequential otherwise; retire sessions that reported
    /// `Done`. Returns the number of sessions advanced.
    pub fn tick(&mut self) -> Result<usize> {
        self.preempt()?;
        self.admit()?;
        let rt = self.rt;
        let force_sequential = self.force_sequential;
        let store = &mut self.store;
        let active = &mut self.active;

        let mut advanced = 0usize;
        // (active index, token, mirror-to-proxy)
        let mut decodes: Vec<(usize, u32, bool)> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();

        // phase A: drive each session to its next decode or completion
        for (i, a) in active.iter_mut().enumerate() {
            a.resident_ticks += 1;
            loop {
                match a.session.poll() {
                    StepWork::Done => {
                        finished.push(i);
                        break;
                    }
                    StepWork::Decode { token, mirror } => {
                        decodes.push((i, token, mirror));
                        break;
                    }
                    StepWork::Probe { suffix, target } => {
                        run_probe(
                            rt,
                            &mut a.session,
                            store.main(a.slot)?,
                            store.proxy(a.slot),
                            &suffix,
                            target,
                        )?;
                    }
                    StepWork::Rollout { suffix, max_tokens } => {
                        run_rollout(rt, &mut a.session, store.main(a.slot)?, &suffix, max_tokens)?;
                    }
                }
            }
            advanced += 1;
        }

        // phase B: commit every pending decode
        let width = if force_sequential {
            None
        } else {
            rt.main.batch_width()
        };
        match width {
            Some(w) => {
                // one fused decode_batch per tick (chunked only when the
                // active set exceeds the batch width)
                for chunk in decodes.chunks(w) {
                    let picks: Vec<(SlotId, u32)> = chunk
                        .iter()
                        .map(|&(i, tok, _)| (active[i].slot, tok))
                        .collect();
                    let logits = store.fused_decode(rt.main.as_ref(), &picks)?;
                    for (&(i, token, mirror), lg) in chunk.iter().zip(logits) {
                        if mirror {
                            if let Some(pc) = store.proxy_mut(active[i].slot) {
                                rt.proxy.decode(pc, token)?;
                            }
                        }
                        active[i].session.complete_decode(lg)?;
                    }
                }
            }
            None => {
                // sequential fallback, admission order: same results,
                // one decode per session
                for &(i, token, mirror) in &decodes {
                    let slot = active[i].slot;
                    let lg = rt.main.decode(store.main_mut(slot)?, token)?;
                    store.mark_dirty(slot)?;
                    if mirror {
                        if let Some(pc) = store.proxy_mut(slot) {
                            rt.proxy.decode(pc, token)?;
                        }
                    }
                    active[i].session.complete_decode(lg)?;
                }
            }
        }

        // phase C: retire in reverse index order to keep indices valid
        let now = self.clock.now();
        for &i in finished.iter().rev() {
            let a = self.active.swap_remove(i);
            self.store.retire(a.slot)?;
            self.kv.release(a.slot)?;
            let queue_ms = (a.admitted - a.arrived) * 1e3;
            let latency_ms = (now - a.arrived) * 1e3;
            let mut result = a.session.finish();
            result.wall_ms = latency_ms;
            self.metrics.record_completion(
                result.correct,
                result.reasoning_tokens,
                result.probes,
                result.rollout_tokens,
                latency_ms,
                queue_ms,
                now > a.deadline,
                result.exit_reason,
            );
            self.metrics.sample_slots(self.kv.in_use());
            self.results.push(result);
        }
        Ok(advanced)
    }

    /// Drain: run ticks until queue, active set and suspended list are
    /// all empty. On a virtual clock each tick is charged
    /// [`DEFAULT_TICK_DT`] simulated seconds (a frozen clock would report
    /// zero latencies and infinite throughput, and time-based scheduling
    /// — suspension aging, deadline misses — could never trigger); on a
    /// wall clock the advance is a no-op.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            self.tick()?;
            self.clock.advance(DEFAULT_TICK_DT);
        }
        Ok(())
    }
}
