//! Continuous batcher + EAT-aware preemptive scheduler: vLLM-style
//! paged-KV scheduling over split-phase [`ReasoningSession`]s — the
//! batcher, not the session, owns model execution (DESIGN.md §3.3–§3.5).
//!
//! Requests arrive with timestamps read from an injected [`Clock`] (the
//! workload driver produces a Poisson process; under a virtual clock the
//! whole run is a pure function of the seed). The batcher admits them
//! into up to `slots` concurrent sessions — each admission claims a
//! batch lane *and* a worst-case page reservation in the
//! [`KvPageManager`]; with the default `--kv-pages` budget the page gate
//! degenerates to lane admission, which is what keeps paged and
//! monolithic serve runs byte-identical. Each scheduling tick it polls
//! every active session up to its pending decode, servicing probes and
//! rollouts *out-of-band* as they surface, then commits **all pending
//! decodes in one fused `decode_batch` call** against the slot-major
//! [`BatchCacheStore`] (idle lanes padded; chunked only if active >
//! batch width). When the backend carries no batch entry point — or
//! `force_sequential` is set — the same decodes run one by one in
//! admission order; the session protocol cannot observe which path
//! serviced it.
//!
//! In `SchedMode::EatAware` the FIFO loop becomes a scheduler
//! (DESIGN.md §3.4): admission pulls from binary heaps — fresh requests
//! keyed on `(deadline, seq)`, suspended sessions on `(suspended_at,
//! seq)` with an aged heap on `(deadline, seq)` — so a freed slot costs
//! O(log n), not an O(n) rescan. Long-stalled sessions (low
//! [`crate::exit::ExitPolicy::stability`], past the aging bound) are
//! *preempted*: the KV lane is released and, on a paged backend, the
//! session's pages are **unpinned and retained** against the host-side
//! budget — resumption *repins* them with zero re-prefill. When
//! retention would overflow that budget the pages are spilled (dropped)
//! and the session falls back to the PR 3 resume-by-re-prefill path,
//! which doubles as the equivalence oracle: on the reference backend
//! both resume paths are bit-identical. Per-request RNGs are seeded
//! from the submission sequence number, so a request's trajectory is
//! invariant to admission order, scheduling mode and store layout.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use anyhow::Result;

use super::batch_cache::{BatchCacheStore, StoreCounters};
use super::engine::{
    resume_session, run_probe, run_rollout, start_session, MonitorModel, ReasoningSession,
    RequestResult, SessionCaches, StepWork,
};
use super::kv::{pages_for, KvPageManager, SlotId};
use super::metrics::ServeMetrics;
use crate::config::{OverloadPolicy, SchedMode, ServeConfig};
use crate::datasets::Question;
use crate::exit::{
    AnswerConsistencyPolicy, ConfidencePolicy, CumulativeEntropyPolicy, EatPolicy, ExitPolicy,
    ExitReason, PathDeviationPolicy, SequenceEntropyPolicy, StallAwareEatPolicy,
    TokenBudgetPolicy, UniqueAnswersPolicy, WeightedEnsemble, DEFAULT_CUM_BUDGET_NATS,
};
use crate::runtime::{Backend, BackendCache, Runtime, RuntimeCounters};
use crate::util::clock::Clock;
use crate::util::rng::Rng;
use crate::util::slab::{GenKey, Slab};
use crate::util::wheel::EventWheel;

/// A request waiting for admission.
pub struct QueuedRequest {
    pub question: Question,
    /// Clock seconds at submission.
    pub arrived: f64,
    /// SLO deadline: `arrived + sched.deadline_s`.
    pub deadline: f64,
    /// Submission sequence number: FIFO tiebreaker *and* the per-request
    /// RNG seed component, so a request's trajectory does not depend on
    /// admission order or scheduling mode.
    pub seq: u64,
    /// Owning tenant (DESIGN.md §3.11): EAT-aware admission round-robins
    /// deficit credit across tenants so one hot tenant cannot starve the
    /// rest. 0 for every single-tenant workload.
    pub tenant: u32,
}

struct Active {
    session: ReasoningSession,
    slot: SlotId,
    arrived: f64,
    /// First admission (queue-delay measurement; preserved across
    /// preemptions).
    admitted: f64,
    deadline: f64,
    seq: u64,
    tenant: u32,
    /// Ticks since this session last entered its slot.
    resident_ticks: u64,
    preemptions: u32,
}

/// A preempted mid-flight session. The KV lane is released while the
/// token history and monitor/policy state live on here. On a paged
/// backend the caches themselves are retained too (unpinned pages,
/// accounted against the host-side budget) and resumption *repins*
/// them; `caches == None` (monolithic store, or spilled under host
/// pressure) falls back to resume-by-re-prefill ([`resume_session`]).
pub struct SuspendedSession {
    session: ReasoningSession,
    arrived: f64,
    admitted: f64,
    deadline: f64,
    seq: u64,
    tenant: u32,
    preemptions: u32,
    suspended_at: f64,
    caches: Option<SessionCaches>,
    /// Pages the retained caches hold against the host budget.
    held_pages: usize,
    /// Filed in the aged (deadline-ordered) class rather than the wait
    /// class. Entries in the wait heap whose arena slot says `aged` are
    /// stale and get skipped on pop.
    aged: bool,
}

/// Min-heap entry ordered by an `(f64, u64)` key — deadlines or
/// suspension times with the submission seq as the (unique) tiebreaker,
/// so heap order is total and deterministic.
struct Prioritized<V> {
    key: (f64, u64),
    val: V,
}

impl<V> PartialEq for Prioritized<V> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<V> Eq for Prioritized<V> {}

impl<V> PartialOrd for Prioritized<V> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<V> Ord for Prioritized<V> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.0.total_cmp(&other.key.0).then(self.key.1.cmp(&other.key.1))
    }
}

type MinHeap<V> = BinaryHeap<Reverse<Prioritized<V>>>;

fn heap_push<V>(heap: &mut MinHeap<V>, key: (f64, u64), val: V) {
    heap.push(Reverse(Prioritized { key, val }));
}

fn heap_pop<V>(heap: &mut MinHeap<V>) -> Option<V> {
    heap.pop().map(|Reverse(p)| p.val)
}

fn heap_peek_key<V>(heap: &MinHeap<V>) -> Option<(f64, u64)> {
    heap.peek().map(|Reverse(p)| p.key)
}

/// One tenant's fresh-request EDF heap plus its deficit-round-robin
/// accounting (DESIGN.md §3.11). Tenant queues live in a `Vec` sorted
/// by tenant id — binary search on submit, cursor sweep on pop — so
/// admission order is deterministic (lowest id breaks every tie) with
/// no hash-map iteration anywhere.
struct TenantQueue {
    tenant: u32,
    heap: MinHeap<QueuedRequest>,
    /// Deficit credit (whole admissions): refilled to `weight` when the
    /// round-robin cursor reaches a backlogged tenant with spent
    /// credit, decremented once per admission. A tenant that goes idle
    /// (or runs into its page cap) forfeits leftover credit, so it
    /// cannot hoard a burst allowance.
    deficit: u64,
    /// DRR quantum: admissions granted per cursor visit (default 1).
    weight: u64,
}

/// Shed order under page pressure (DESIGN.md §3.11): victims sorted by
/// *descending* `ExitPolicy::stability` — the sessions nearest a safe
/// exit surrender their lanes first, the mirror image of preemption's
/// min-stability pick — with ties broken by ascending submission seq.
/// Sessions below `min_stability`, without a stability estimate yet, or
/// already eliciting (including any shed on an earlier tick — shedding
/// is one-shot per session) are not candidates.
///
/// Pure over `(stability, seq, eliciting)` triples so the shed-ordering
/// unit tests and proptests can drive it directly.
pub fn pick_shed_victims(candidates: &[(Option<f64>, u64, bool)], min_stability: f64) -> Vec<usize> {
    let mut order: Vec<(f64, u64, usize)> = candidates
        .iter()
        .enumerate()
        .filter_map(|(i, &(stability, seq, eliciting))| {
            let s = stability?;
            (!eliciting && s >= min_stability).then_some((s, seq, i))
        })
        .collect();
    order.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    order.into_iter().map(|(_, _, i)| i).collect()
}

/// Which waiter gets the next free slot.
enum AdmitPick {
    Fresh(QueuedRequest),
    Resume(SuspendedSession),
}

/// A unit of work in flight between replicas (cluster migration,
/// DESIGN.md §3.7): either a queued request rerouted before first
/// admission, or a mid-flight suspended session whose retained KV pages
/// ride along — both replicas draw on one shared page pool, so the
/// handoff moves budget accounting, never page contents.
pub enum Migration {
    Fresh(QueuedRequest),
    Session(Box<SuspendedSession>),
}

impl Migration {
    /// Committed tokens the migrated state carries (0 for a queued
    /// request that never prefilled).
    pub fn tokens(&self) -> usize {
        match self {
            Migration::Fresh(_) => 0,
            Migration::Session(s) => s.session.pos(),
        }
    }

    /// True for a mid-flight session handoff (vs a queue reroute).
    pub fn is_session(&self) -> bool {
        matches!(self, Migration::Session(_))
    }
}

/// Policy factory: each admitted request gets a fresh policy instance.
pub type PolicyFactory = Box<dyn Fn() -> Box<dyn ExitPolicy>>;

/// The default serving policy factory: fresh [`EatPolicy`] instances
/// with the config's alpha/delta/budget (shared by the CLI, examples,
/// benches and tests).
pub fn eat_policy_factory(cfg: &ServeConfig) -> PolicyFactory {
    let (alpha, delta, budget) = (cfg.alpha, cfg.delta, cfg.max_think_tokens);
    Box::new(move || Box::new(EatPolicy::new(alpha, delta, budget)))
}

/// Factory for any exit-policy zoo family by name, parameterized from
/// the serve config (alpha/delta/budget). Every family runs online in
/// the [`Batcher`] through the same [`PolicyFactory`] seam: the engine
/// services whatever `needs()` the policy reports and the scheduler
/// consumes its `stability()` hint — no engine changes per policy.
pub fn zoo_policy_factory(name: &str, cfg: &ServeConfig) -> anyhow::Result<PolicyFactory> {
    let (alpha, delta, budget) = (cfg.alpha, cfg.delta, cfg.max_think_tokens);
    Ok(match name {
        "eat" => eat_policy_factory(cfg),
        "token" => Box::new(move || Box::new(TokenBudgetPolicy::new(budget))),
        "eat-stall" | "stall" => {
            Box::new(move || Box::new(StallAwareEatPolicy::new(alpha, delta, budget)))
        }
        "ua" => Box::new(move || Box::new(UniqueAnswersPolicy::new(16, 1, budget))),
        "confidence" => Box::new(move || Box::new(ConfidencePolicy::new(alpha, delta, budget))),
        "path-dev" => Box::new(move || Box::new(PathDeviationPolicy::new(alpha, delta, budget))),
        // delta doubles as the entropy level (nats) for the level rules
        "seq-entropy" => Box::new(move || Box::new(SequenceEntropyPolicy::new(delta, budget))),
        "cum-entropy" => Box::new(move || {
            Box::new(CumulativeEntropyPolicy::new(
                alpha,
                delta,
                DEFAULT_CUM_BUDGET_NATS,
                budget,
            ))
        }),
        "consistency" => {
            Box::new(move || Box::new(AnswerConsistencyPolicy::with_stride(8, 2, budget, 2)))
        }
        "ensemble" => Box::new(move || {
            Box::new(WeightedEnsemble::new(
                vec![
                    (2.0, Box::new(EatPolicy::new(alpha, delta, budget)) as Box<dyn ExitPolicy>),
                    (1.0, Box::new(StallAwareEatPolicy::new(alpha, delta, budget))),
                    (1.0, Box::new(ConfidencePolicy::new(alpha, delta, budget))),
                ],
                0.5,
            ))
        }),
        other => anyhow::bail!(
            "unknown policy `{other}` (expected eat, token, eat-stall, ua, confidence, \
             path-dev, seq-entropy, cum-entropy, consistency or ensemble)"
        ),
    })
}

/// Simulated seconds charged per scheduling tick on a virtual clock
/// (one fused decode step at ~10 ms) — used by [`Batcher::run_to_completion`]
/// and as the workload driver's default.
pub const DEFAULT_TICK_DT: f64 = 0.01;

/// Per-tick work lists, hoisted out of `Batcher::tick` so steady-state
/// ticks are allocation-free (DESIGN.md §3.8): the vectors are
/// preallocated to the slot count and only grow — a growth event bumps
/// `RuntimeCounters::sched_allocs` — if the active set ever outgrows
/// every previous tick.
#[derive(Default)]
struct TickScratch {
    /// (active index, token, mirror-to-proxy)
    decodes: Vec<(usize, u32, bool)>,
    finished: Vec<usize>,
    /// Fused-path lane picks for the current chunk.
    picks: Vec<(SlotId, u32)>,
}

impl TickScratch {
    fn with_slots(slots: usize) -> TickScratch {
        TickScratch {
            decodes: Vec::with_capacity(slots),
            finished: Vec::with_capacity(slots),
            picks: Vec::with_capacity(slots),
        }
    }

    fn capacity_sum(&self) -> usize {
        self.decodes.capacity() + self.finished.capacity() + self.picks.capacity()
    }
}

pub struct Batcher<'a> {
    rt: &'a Runtime,
    cfg: ServeConfig,
    monitor: MonitorModel,
    make_policy: PolicyFactory,
    kv: KvPageManager,
    store: BatchCacheStore,
    clock: Clock,
    /// FIFO-mode admission queue (arrival order).
    queue: VecDeque<QueuedRequest>,
    /// EAT-aware fresh requests: one EDF heap per tenant (sorted by
    /// tenant id), drained by weighted deficit-round-robin. Single-
    /// tenant workloads hold exactly one queue, which DRR drains in
    /// plain `(deadline, seq)` order — bit-identical to the pre-tenant
    /// batcher.
    fresh: Vec<TenantQueue>,
    /// DRR cursor into `fresh`.
    rr_cursor: usize,
    active: Vec<Active>,
    /// Suspended-session arena (DESIGN.md §3.10): payloads live here in
    /// one allocation; the admission heaps and the aging wheel hold
    /// generational keys into it, so a session admitted or migrated out
    /// leaves only stale keys behind — they miss on pop and are skipped.
    suspended: Slab<SuspendedSession>,
    /// Keys of suspended sessions past the starvation guard (or aged
    /// past the wait bound), earliest `(deadline, seq)` first — they
    /// outrank fresh admissions.
    suspended_aged: MinHeap<GenKey>,
    /// Keys of the remaining suspended sessions, earliest
    /// `(suspended_at, seq)` first.
    suspended_wait: MinHeap<GenKey>,
    /// Promotion timers: one event per parked session at
    /// `suspended_at + resume_priority_after_s`, so `promote_aged` pops
    /// due timers instead of re-peeking the wait heap each tick.
    aging: EventWheel<GenKey>,
    /// Caches are page tables (retain on suspend, repin on resume).
    paged: bool,
    /// Token-page geometry per model, for budget accounting in the same
    /// unit as `KvPageManager::reserve_pages` (a K+V pair counts once,
    /// like `cache_elems`).
    main_page_size: usize,
    proxy_page_size: usize,
    next_seq: u64,
    /// Reusable per-tick work lists (see [`TickScratch`]).
    scratch: TickScratch,
    /// Disable the fused path even when the backend has one (A/B
    /// determinism checks, ablations).
    pub force_sequential: bool,
    pub metrics: ServeMetrics,
    pub results: Vec<RequestResult>,
}

impl<'a> Batcher<'a> {
    /// Wall-clock batcher (live serving).
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        slots: usize,
        make_policy: PolicyFactory,
    ) -> Batcher<'a> {
        Batcher::with_clock(rt, cfg, monitor, slots, make_policy, Clock::wall())
    }

    /// Full constructor: inject the time source (a [`Clock::virt`] makes
    /// the entire serve run deterministic in the seed).
    pub fn with_clock(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        slots: usize,
        make_policy: PolicyFactory,
        clock: Clock,
    ) -> Batcher<'a> {
        let main_ps = rt.main.page_size().unwrap_or(rt.main.seq_len());
        let proxy_ps = rt.proxy.page_size().unwrap_or(rt.proxy.seq_len());
        // worst-case pages a resident session can pin: full sequence on
        // the main model, plus the proxy mirror when black-box monitored
        let reserve = pages_for(rt.main.seq_len(), main_ps)
            + if monitor == MonitorModel::Proxy {
                pages_for(rt.proxy.seq_len(), proxy_ps)
            } else {
                0
            };
        Batcher {
            paged: rt.main.page_size().is_some(),
            main_page_size: main_ps,
            proxy_page_size: proxy_ps,
            kv: KvPageManager::new(slots, main_ps, reserve, cfg.kv_pages),
            store: BatchCacheStore::new(slots),
            metrics: ServeMetrics::new(clock.clone()),
            rt,
            cfg,
            monitor,
            make_policy,
            clock,
            queue: VecDeque::new(),
            fresh: Vec::new(),
            rr_cursor: 0,
            active: Vec::new(),
            suspended: Slab::new(),
            suspended_aged: BinaryHeap::new(),
            suspended_wait: BinaryHeap::new(),
            aging: EventWheel::new(DEFAULT_TICK_DT),
            next_seq: 0,
            scratch: TickScratch::with_slots(slots),
            force_sequential: false,
            results: Vec::new(),
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn submit(&mut self, question: Question) {
        self.submit_seq(question, self.next_seq);
    }

    /// Submit on behalf of a tenant (DESIGN.md §3.11). Tenant 0 via
    /// [`Self::submit`] is the single-tenant legacy path.
    pub fn submit_tenant(&mut self, question: Question, tenant: u32) {
        self.submit_seq_tenant(question, self.next_seq, tenant);
    }

    /// Submit with an externally assigned sequence number (the cluster
    /// router hands out globally unique seqs so a request's RNG — and
    /// therefore its trajectory — is invariant to replica placement).
    /// `submit` delegates here with the local counter.
    pub fn submit_seq(&mut self, question: Question, seq: u64) {
        self.submit_seq_tenant(question, seq, 0);
    }

    /// The full submission entry point: externally assigned seq *and*
    /// tenant.
    pub fn submit_seq_tenant(&mut self, question: Question, seq: u64, tenant: u32) {
        self.metrics.mark_start();
        self.next_seq = self.next_seq.max(seq + 1);
        let now = self.clock.now();
        let req = QueuedRequest {
            question,
            arrived: now,
            deadline: now + self.cfg.sched.deadline_s,
            seq,
            tenant,
        };
        self.file_fresh(req);
    }

    /// File a fresh request into the mode's admission structure: the
    /// FIFO queue, or the owning tenant's EDF heap.
    fn file_fresh(&mut self, req: QueuedRequest) {
        match self.cfg.sched.mode {
            SchedMode::Fifo => self.queue.push_back(req),
            SchedMode::EatAware => {
                let idx = self.tenant_queue_idx(req.tenant);
                let key = (req.deadline, req.seq);
                heap_push(&mut self.fresh[idx].heap, key, req);
            }
        }
    }

    /// Index of `tenant`'s queue in the id-sorted `fresh` vec, creating
    /// it (weight 1) on first sight. O(log tenants) search; creation is
    /// once per tenant.
    fn tenant_queue_idx(&mut self, tenant: u32) -> usize {
        match self.fresh.binary_search_by_key(&tenant, |t| t.tenant) {
            Ok(i) => i,
            Err(i) => {
                self.fresh.insert(
                    i,
                    TenantQueue { tenant, heap: BinaryHeap::new(), deficit: 0, weight: 1 },
                );
                i
            }
        }
    }

    /// Set a tenant's DRR weight: admissions granted per round-robin
    /// visit while backlogged (default 1; clamped to at least 1).
    pub fn set_tenant_weight(&mut self, tenant: u32, weight: u64) {
        let idx = self.tenant_queue_idx(tenant);
        self.fresh[idx].weight = weight.max(1);
    }

    /// Cap a tenant's pinned KV pages (hierarchical budget, DESIGN.md
    /// §3.11): fresh admissions for a tenant at its cap are skipped by
    /// the DRR sweep until it releases pages.
    pub fn set_tenant_page_cap(&mut self, tenant: u32, pages: usize) {
        self.kv.set_tenant_cap(tenant, pages);
    }

    /// Fresh requests waiting across every tenant queue.
    fn fresh_backlog(&self) -> usize {
        self.fresh.iter().map(|t| t.heap.len()).sum()
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.fresh_backlog()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn suspended_count(&self) -> usize {
        self.suspended.len()
    }

    /// Anything left to do: queued, resident, or suspended work.
    pub fn has_work(&self) -> bool {
        self.pending() > 0 || !self.active.is_empty() || self.suspended_count() > 0
    }

    /// KV lanes currently free (admission capacity) — a router load
    /// signal.
    pub fn free_lanes(&self) -> usize {
        self.kv.available()
    }

    /// Waiters not yet resident: queued requests plus suspended
    /// sessions — the router's backlog signal.
    pub fn waiters(&self) -> usize {
        self.pending() + self.suspended_count()
    }

    /// Σ over resident sessions of `1 − stability`: the EAT
    /// distance-to-exit load signal (DESIGN.md §3.7). 0 when every
    /// resident session sits at its exit threshold, so a replica about
    /// to free its lanes looks cheap to the router. Sessions without a
    /// stability estimate yet count 0.5.
    pub fn drain_distance(&self) -> f64 {
        self.active
            .iter()
            .map(|a| 1.0 - a.session.stability().unwrap_or(0.5))
            .sum()
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    pub fn kv_peak(&self) -> usize {
        self.kv.peak()
    }

    /// Page-budget accounting (pinned reservations, suspended
    /// retention, peak) for reports.
    pub fn kv_pages(&self) -> &KvPageManager {
        &self.kv
    }

    /// Batch-store upload/residency accounting.
    pub fn store_counters(&self) -> StoreCounters {
        self.store.counters
    }

    /// The per-request RNG: a pure function of the serve seed and the
    /// submission sequence number.
    fn request_rng(&self, seq: u64) -> Rng {
        Rng::new(self.cfg.seed ^ 0xBA7C4E5 ^ seq.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Migrate suspended sessions whose wait crossed the aging bound
    /// into the aged heap (EAT-aware mode), driven by the promotion
    /// timers [`Self::park`] filed into the aging wheel. Amortized
    /// O(log n) once per session; timers for sessions that were admitted
    /// or migrated away in the meantime miss the arena and are dropped.
    fn promote_aged(&mut self) {
        if self.cfg.sched.mode != SchedMode::EatAware {
            return;
        }
        let now = self.clock.now();
        while let Some(ev) = self.aging.peek() {
            if ev.time > now {
                break;
            }
            let (_, key) = self.aging.pop().expect("peeked event exists");
            let Some(s) = self.suspended.get_mut(key) else {
                continue; // session left the arena before its timer fired
            };
            if s.aged {
                continue;
            }
            s.aged = true;
            let hk = (s.deadline, s.seq);
            heap_push(&mut self.suspended_aged, hk, key);
        }
    }

    /// Pop the oldest-suspension live waiter. Skips keys whose arena
    /// entry is gone (admitted/migrated) or was promoted to the aged
    /// class since filing.
    fn pop_wait(&mut self) -> Option<SuspendedSession> {
        while let Some(key) = heap_pop(&mut self.suspended_wait) {
            match self.suspended.get(key) {
                Some(s) if !s.aged => return self.suspended.remove(key),
                _ => {}
            }
        }
        None
    }

    /// Pop the earliest-deadline live aged session; stale keys miss the
    /// arena and are skipped.
    fn pop_aged(&mut self) -> Option<SuspendedSession> {
        while let Some(key) = heap_pop(&mut self.suspended_aged) {
            if let Some(s) = self.suspended.remove(key) {
                return Some(s);
            }
        }
        None
    }

    /// Weighted deficit-round-robin pop over the per-tenant fresh heaps
    /// (DESIGN.md §3.11): the cursor sweeps tenant queues in id order,
    /// refilling a backlogged tenant's deficit to its weight on
    /// arrival and spending one credit per admission, so long-run
    /// admission shares track the weights while each tenant's own
    /// requests still leave in EDF `(deadline, seq)` order. Idle or
    /// page-capped tenants forfeit their credit and are skipped. With
    /// one tenant queue this is exactly a plain EDF heap pop.
    fn pop_fresh(&mut self) -> Option<QueuedRequest> {
        let n = self.fresh.len();
        // two sweeps: the first may only refill deficits, the second
        // must then serve or prove every queue empty/capped
        let mut visited = 0usize;
        while visited < 2 * n {
            if self.rr_cursor >= n {
                self.rr_cursor = 0;
            }
            let idx = self.rr_cursor;
            let admissible = !self.fresh[idx].heap.is_empty()
                && self.kv.tenant_can_admit(self.fresh[idx].tenant);
            if !admissible {
                self.fresh[idx].deficit = 0;
                self.rr_cursor += 1;
                visited += 1;
                continue;
            }
            if self.fresh[idx].deficit == 0 {
                self.fresh[idx].deficit = self.fresh[idx].weight;
            }
            self.fresh[idx].deficit -= 1;
            if self.fresh[idx].deficit == 0 {
                // credit spent: the next pop starts at the next tenant
                self.rr_cursor = idx + 1;
            }
            return heap_pop(&mut self.fresh[idx].heap);
        }
        None
    }

    /// Pick the waiter for the next free slot.
    ///
    /// FIFO mode: suspended sessions first (oldest suspension), then the
    /// queue head. EAT-aware mode (DESIGN.md §3.4): (1) aged suspended
    /// sessions (preempted `max_preemptions` times, or waiting longer
    /// than `resume_priority_after_s`) by earliest deadline, (2) fresh
    /// requests by DRR over tenants, EDF within one (§3.11), (3)
    /// remaining suspended sessions, oldest suspension first.
    fn pick_admission(&mut self) -> Option<AdmitPick> {
        if self.cfg.sched.mode == SchedMode::Fifo {
            if let Some(s) = self.pop_wait() {
                return Some(AdmitPick::Resume(s));
            }
            return self.queue.pop_front().map(AdmitPick::Fresh);
        }
        if let Some(s) = self.pop_aged() {
            return Some(AdmitPick::Resume(s));
        }
        if let Some(r) = self.pop_fresh() {
            return Some(AdmitPick::Fresh(r));
        }
        self.pop_wait().map(AdmitPick::Resume)
    }

    /// Admit waiters while KV lanes + page budget allow: fresh requests
    /// prefill; suspended sessions repin their retained pages (paged)
    /// or rebuild by re-prefill (monolithic / spilled).
    fn admit(&mut self) -> Result<()> {
        self.promote_aged();
        while self.kv.available() > 0 {
            let Some(pick) = self.pick_admission() else {
                break;
            };
            let tenant = match &pick {
                AdmitPick::Fresh(req) => req.tenant,
                AdmitPick::Resume(s) => s.tenant,
            };
            let Some(slot) = self.kv.acquire_for(tenant) else {
                // The pick's tenant is at its page cap (resumes are not
                // pre-gated the way pop_fresh gates fresh picks): put
                // the pick back and stop admitting this tick.
                match pick {
                    AdmitPick::Fresh(req) => self.file_fresh(req),
                    AdmitPick::Resume(s) => self.park(s),
                }
                break;
            };
            match pick {
                AdmitPick::Fresh(req) => {
                    let policy = (self.make_policy)();
                    let rng = self.request_rng(req.seq);
                    let (session, caches) = start_session(
                        self.rt,
                        self.cfg.clone(),
                        self.monitor,
                        req.question,
                        policy,
                        rng,
                    )?;
                    self.store.install(slot, caches.main, caches.proxy)?;
                    self.active.push(Active {
                        session,
                        slot,
                        arrived: req.arrived,
                        admitted: self.clock.now(),
                        deadline: req.deadline,
                        seq: req.seq,
                        tenant: req.tenant,
                        resident_ticks: 0,
                        preemptions: 0,
                    });
                }
                AdmitPick::Resume(mut s) => {
                    // Adaptive compute governor: a session still stalled
                    // after burning through the starvation guard has
                    // shown no EAT progress across multiple residencies —
                    // stop reasoning and elicit its answer now instead of
                    // burning the rest of the token budget (the paper's
                    // §6 stall extension, applied at the scheduler level).
                    if self.cfg.sched.mode == SchedMode::EatAware
                        && s.preemptions >= self.cfg.sched.max_preemptions
                        && s.session.stability().unwrap_or(1.0) <= self.cfg.sched.stall_stability
                    {
                        s.session.force_exit(ExitReason::Stalled);
                    }
                    let caches = match s.caches.take() {
                        Some(caches) => {
                            // repin: the pages never left the pool — zero
                            // re-prefill work, the reservation just moves
                            // from the host budget back to a pinned lane
                            self.kv.release_suspended(s.held_pages);
                            anyhow::ensure!(
                                caches.main.pos() == s.session.pos(),
                                "repin position mismatch: cache {} vs session {}",
                                caches.main.pos(),
                                s.session.pos()
                            );
                            caches
                        }
                        None => resume_session(self.rt, &s.session)?,
                    };
                    self.metrics.record_resume(s.session.pos());
                    self.store.install(slot, caches.main, caches.proxy)?;
                    self.active.push(Active {
                        session: s.session,
                        slot,
                        arrived: s.arrived,
                        admitted: s.admitted,
                        deadline: s.deadline,
                        seq: s.seq,
                        tenant: s.tenant,
                        resident_ticks: 0,
                        preemptions: s.preemptions,
                    });
                }
            }
            self.metrics.sample_slots(self.kv.in_use());
        }
        Ok(())
    }

    /// Park a preempted session: on a paged backend retain its caches
    /// (unpinned pages) against the host budget, spilling to the
    /// re-prefill fallback when retention would overflow; then file it
    /// into the right suspended heap.
    fn suspend(&mut self, a: Active, main: BackendCache, proxy: Option<BackendCache>) {
        let now = self.clock.now();
        let (caches, held_pages) = if self.paged {
            // charged in the same token-page unit as the admission
            // reserve (one count per K+V pair, whatever the backend's
            // physical page multiplicity)
            let pages = pages_for(main.pos(), self.main_page_size)
                + proxy
                    .as_ref()
                    .map(|p| pages_for(p.pos(), self.proxy_page_size))
                    .unwrap_or(0);
            if self.kv.try_hold_suspended(pages) {
                (Some(SessionCaches { main, proxy }), pages)
            } else {
                // host budget full: drop the pages, resume re-prefills
                self.metrics.record_spill();
                (None, 0)
            }
        } else {
            (None, 0)
        };
        self.park(SuspendedSession {
            session: a.session,
            arrived: a.arrived,
            admitted: a.admitted,
            deadline: a.deadline,
            seq: a.seq,
            tenant: a.tenant,
            preemptions: a.preemptions + 1,
            suspended_at: now,
            caches,
            held_pages,
            aged: false,
        });
    }

    /// File a suspended session into the arena and the right admission
    /// class: aged (out of preemption credit) straight into the
    /// deadline-ordered heap, everything else into the wait heap with a
    /// promotion timer on the aging wheel.
    fn park(&mut self, mut s: SuspendedSession) {
        let eat = self.cfg.sched.mode == SchedMode::EatAware;
        s.aged = eat && s.preemptions >= self.cfg.sched.max_preemptions;
        let (aged, deadline, suspended_at, seq) = (s.aged, s.deadline, s.suspended_at, s.seq);
        let key = self.suspended.insert(s);
        if aged {
            heap_push(&mut self.suspended_aged, (deadline, seq), key);
        } else {
            heap_push(&mut self.suspended_wait, (suspended_at, seq), key);
            if eat {
                let fire = suspended_at + self.cfg.sched.resume_priority_after_s;
                self.aging.schedule_at(fire, 0, seq, key);
            }
        }
    }

    /// Preempt long-stalled sessions to free slots for fresh work
    /// (EAT-aware mode only): release the KV lane, retain the session —
    /// token history plus monitor/policy state, and on a paged backend
    /// the unpinned pages themselves. Stabilized sessions (stability
    /// above the stall cutoff) are never preempted: they are driven to
    /// completion.
    fn preempt(&mut self) -> Result<()> {
        if self.cfg.sched.mode != SchedMode::EatAware {
            return Ok(());
        }
        let aging = self.cfg.sched.preempt_after_ticks;
        let max_pre = self.cfg.sched.max_preemptions;
        let cutoff = self.cfg.sched.stall_stability;
        while self.fresh_backlog() > 0 && self.kv.available() == 0 {
            let victim = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| {
                    a.session.can_suspend()
                        && !a.session.eliciting()
                        && a.preemptions < max_pre
                        && a.resident_ticks >= aging
                        && a.session.stability().unwrap_or(1.0) <= cutoff
                })
                .min_by(|(_, a), (_, b)| {
                    let sa = a.session.stability().unwrap_or(1.0);
                    let sb = b.session.stability().unwrap_or(1.0);
                    (sa, a.seq).partial_cmp(&(sb, b.seq)).unwrap()
                })
                .map(|(i, _)| i);
            let Some(i) = victim else {
                break;
            };
            let a = self.active.swap_remove(i);
            let (main, proxy) = self.store.take(a.slot)?;
            self.kv.release(a.slot)?;
            self.metrics.record_preemption();
            self.metrics.sample_slots(self.kv.in_use());
            self.suspend(a, main, proxy);
        }
        Ok(())
    }

    /// Lift one unit of work off this replica for migration (cluster
    /// rebalancing, DESIGN.md §3.7). Preference order:
    ///
    /// 1. the waiter that would be admitted next ([`Self::pick_admission`],
    ///    so migration respects the same priority the local scheduler
    ///    would have) — a suspended session leaves with its retained
    ///    pages still charged to this manager's host budget until
    ///    [`Self::inject_migration`] transfers the charge;
    /// 2. with no waiters, a resident session is suspended out
    ///    mid-flight (lowest stability first, like preemption but
    ///    without the aging/count gates — migration is the router's
    ///    decision, not a starvation guard), its pages retained the
    ///    same way.
    ///
    /// Returns `Ok(None)` when nothing is movable.
    pub fn extract_migration(&mut self) -> Result<Option<Migration>> {
        self.promote_aged();
        if let Some(pick) = self.pick_admission() {
            self.metrics.record_migration_out();
            return Ok(Some(match pick {
                AdmitPick::Fresh(req) => Migration::Fresh(req),
                AdmitPick::Resume(s) => Migration::Session(Box::new(s)),
            }));
        }
        let victim = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, a)| a.session.can_suspend() && !a.session.eliciting())
            .min_by(|(_, a), (_, b)| {
                let sa = a.session.stability().unwrap_or(1.0);
                let sb = b.session.stability().unwrap_or(1.0);
                (sa, a.seq).partial_cmp(&(sb, b.seq)).unwrap()
            })
            .map(|(i, _)| i);
        let Some(i) = victim else {
            return Ok(None);
        };
        let a = self.active.swap_remove(i);
        let (main, proxy) = self.store.take(a.slot)?;
        self.kv.release(a.slot)?;
        self.metrics.sample_slots(self.kv.in_use());
        let now = self.clock.now();
        let (caches, held_pages) = if self.paged {
            let pages = pages_for(main.pos(), self.main_page_size)
                + proxy
                    .as_ref()
                    .map(|p| pages_for(p.pos(), self.proxy_page_size))
                    .unwrap_or(0);
            if self.kv.try_hold_suspended(pages) {
                (Some(SessionCaches { main, proxy }), pages)
            } else {
                self.metrics.record_spill();
                (None, 0)
            }
        } else {
            (None, 0)
        };
        self.metrics.record_migration_out();
        Ok(Some(Migration::Session(Box::new(SuspendedSession {
            session: a.session,
            arrived: a.arrived,
            admitted: a.admitted,
            deadline: a.deadline,
            seq: a.seq,
            tenant: a.tenant,
            preemptions: a.preemptions,
            suspended_at: now,
            caches,
            held_pages,
            aged: false,
        }))))
    }

    /// Receive a migrated unit of work from `src`. A rerouted request
    /// just joins the local waiters; a migrated session's retained-page
    /// charge moves from `src`'s host budget to ours
    /// ([`KvPageManager::transfer_suspended`] — the pages themselves
    /// never move, both managers draw on one shared pool), spilling to
    /// the re-prefill fallback when our budget cannot absorb it. The
    /// session keeps its seq (RNG), deadline and suspension time, so
    /// its trajectory is bit-identical to an unmigrated run.
    pub fn inject_migration(&mut self, src: &mut Batcher<'_>, m: Migration) {
        self.metrics.mark_start();
        match m {
            Migration::Fresh(req) => {
                self.next_seq = self.next_seq.max(req.seq + 1);
                self.metrics.record_migration_in(0);
                self.file_fresh(req);
            }
            Migration::Session(mut s) => {
                self.next_seq = self.next_seq.max(s.seq + 1);
                if s.held_pages > 0 && !src.kv.transfer_suspended(&mut self.kv, s.held_pages) {
                    // our host budget is full: drop the retained pages,
                    // resume falls back to re-prefill
                    s.caches = None;
                    s.held_pages = 0;
                    self.metrics.record_spill();
                }
                self.metrics.record_migration_in(s.session.pos());
                self.park(*s);
            }
        }
    }

    /// Reject queued arrivals whose SLO deadline has already passed
    /// (overload policies only, DESIGN.md §3.11): a request that can no
    /// longer be served in time is dropped *before* it wastes a prefill.
    /// The FIFO queue and every tenant EDF heap keep their earliest
    /// deadline at the front, so expiry drains from the top in O(log n)
    /// per rejection.
    fn reject_expired(&mut self) {
        if self.cfg.sched.overload == OverloadPolicy::None {
            return;
        }
        let now = self.clock.now();
        while self.queue.front().is_some_and(|r| r.deadline < now) {
            self.queue.pop_front();
            self.metrics.record_rejection();
        }
        for t in &mut self.fresh {
            while heap_peek_key(&t.heap).is_some_and(|(deadline, _)| deadline < now) {
                heap_pop(&mut t.heap);
                self.metrics.record_rejection();
            }
        }
    }

    /// EAT-guided load shedding (DESIGN.md §3.11): when fresh arrivals
    /// are starved of pages and the policy allows, force-exit the
    /// resident sessions *nearest* a safe exit — descending
    /// `ExitPolicy::stability` (see [`pick_shed_victims`]) — instead of
    /// spilling anything to re-prefill. A force-exited session flips
    /// into elicitation, completes within a few ticks and frees its
    /// lane; `eliciting()` excludes it from later sweeps, so a session
    /// is never shed twice, and lanes already draining count against
    /// the need so one starved arrival triggers at most one shed.
    fn shed_for_pressure(&mut self) {
        if self.cfg.sched.mode != SchedMode::EatAware
            || self.cfg.sched.overload != OverloadPolicy::EatShed
            || self.kv.available() > 0
        {
            return;
        }
        let starved = self.fresh_backlog();
        if starved == 0 {
            return;
        }
        let draining = self.active.iter().filter(|a| a.session.eliciting()).count();
        let mut want = starved.min(self.active.len()).saturating_sub(draining);
        if want == 0 {
            return;
        }
        let candidates: Vec<(Option<f64>, u64, bool)> = self
            .active
            .iter()
            .map(|a| (a.session.stability(), a.seq, a.session.eliciting()))
            .collect();
        for idx in pick_shed_victims(&candidates, self.cfg.sched.shed_min_stability) {
            if want == 0 {
                break;
            }
            // force_exit refuses mid-decode states; skip those victims
            if self.active[idx].session.force_exit(ExitReason::Shed) {
                self.metrics.record_shed();
                want -= 1;
            }
        }
    }

    /// One scheduling tick: reject expired arrivals and shed for page
    /// pressure (overload policies); preempt (EAT-aware mode);
    /// admit/resume; poll every active session to its pending decode
    /// (probes/rollouts serviced out-of-band); commit all pending
    /// decodes — fused when possible, sequential otherwise; retire
    /// sessions that reported `Done`. Returns the number of sessions
    /// advanced.
    pub fn tick(&mut self) -> Result<usize> {
        self.reject_expired();
        self.preempt()?;
        self.shed_for_pressure();
        self.admit()?;
        let rt = self.rt;
        let force_sequential = self.force_sequential;
        let store = &mut self.store;
        let active = &mut self.active;
        let scratch = &mut self.scratch;

        let mut advanced = 0usize;
        // reuse the hoisted work lists: steady-state ticks must not
        // allocate, and any capacity growth is counted below
        let cap_before = scratch.capacity_sum();
        scratch.decodes.clear();
        scratch.finished.clear();

        // phase A: drive each session to its next decode or completion
        for (i, a) in active.iter_mut().enumerate() {
            a.resident_ticks += 1;
            loop {
                match a.session.poll() {
                    StepWork::Done => {
                        scratch.finished.push(i);
                        break;
                    }
                    StepWork::Decode { token, mirror } => {
                        scratch.decodes.push((i, token, mirror));
                        break;
                    }
                    StepWork::Probe { suffix, target } => {
                        run_probe(
                            rt,
                            &mut a.session,
                            store.main(a.slot)?,
                            store.proxy(a.slot),
                            &suffix,
                            target,
                        )?;
                    }
                    StepWork::Rollout { suffix, max_tokens } => {
                        run_rollout(rt, &mut a.session, store.main(a.slot)?, &suffix, max_tokens)?;
                    }
                }
            }
            advanced += 1;
        }

        // phase B: commit every pending decode
        let width = if force_sequential {
            None
        } else {
            rt.main.batch_width()
        };
        match width {
            Some(w) => {
                // one fused decode_batch per tick (chunked only when the
                // active set exceeds the batch width)
                for chunk in scratch.decodes.chunks(w) {
                    scratch.picks.clear();
                    scratch
                        .picks
                        .extend(chunk.iter().map(|&(i, tok, _)| (active[i].slot, tok)));
                    let logits = store.fused_decode(rt.main.as_ref(), &scratch.picks)?;
                    for (&(i, token, mirror), lg) in chunk.iter().zip(logits) {
                        if mirror {
                            if let Some(pc) = store.proxy_mut(active[i].slot) {
                                rt.proxy.decode(pc, token)?;
                            }
                        }
                        active[i].session.complete_decode(lg)?;
                    }
                }
            }
            None => {
                // sequential fallback, admission order: same results,
                // one decode per session
                for &(i, token, mirror) in &scratch.decodes {
                    let slot = active[i].slot;
                    let lg = rt.main.decode(store.main_mut(slot)?, token)?;
                    store.mark_dirty(slot)?;
                    if mirror {
                        if let Some(pc) = store.proxy_mut(slot) {
                            rt.proxy.decode(pc, token)?;
                        }
                    }
                    active[i].session.complete_decode(lg)?;
                }
            }
        }

        // tick accounting: a capacity change means a work list reallocated
        let ctr = rt.main.counters();
        RuntimeCounters::bump(&ctr.sched_ticks);
        if self.scratch.capacity_sum() != cap_before {
            RuntimeCounters::bump(&ctr.sched_allocs);
        }

        // phase C: retire in reverse index order to keep indices valid
        let now = self.clock.now();
        for &i in self.scratch.finished.iter().rev() {
            let a = self.active.swap_remove(i);
            self.store.retire(a.slot)?;
            self.kv.release(a.slot)?;
            let queue_ms = (a.admitted - a.arrived) * 1e3;
            let latency_ms = (now - a.arrived) * 1e3;
            let mut result = a.session.finish();
            result.wall_ms = latency_ms;
            self.metrics.record_completion(
                result.correct,
                result.reasoning_tokens,
                result.probes,
                result.rollout_tokens,
                latency_ms,
                queue_ms,
                now > a.deadline,
                result.exit_reason,
            );
            self.metrics.sample_slots(self.kv.in_use());
            self.results.push(result);
        }
        Ok(advanced)
    }

    /// Approximate scheduler-side heap footprint (capacity-based):
    /// admission queues, the active set, the suspended arena with its
    /// key heaps, the aging wheel and the tick scratch. Session
    /// *contents* (token buffers, caches) are not walked — this is the
    /// arena-accounting number DESIGN.md §3.10 pairs with the soak's
    /// bytes/session report.
    pub fn approx_sched_bytes(&self) -> usize {
        use std::mem::size_of;
        self.queue.capacity() * size_of::<QueuedRequest>()
            + self
                .fresh
                .iter()
                .map(|t| {
                    size_of::<TenantQueue>()
                        + t.heap.capacity() * size_of::<Reverse<Prioritized<QueuedRequest>>>()
                })
                .sum::<usize>()
            + self.active.capacity() * size_of::<Active>()
            + self.suspended.approx_bytes()
            + self.suspended_aged.capacity() * size_of::<Reverse<Prioritized<GenKey>>>()
            + self.suspended_wait.capacity() * size_of::<Reverse<Prioritized<GenKey>>>()
            + self.aging.approx_bytes()
            + self.scratch.capacity_sum() * size_of::<usize>()
    }

    /// Drain: run ticks until queue, active set and suspended heaps are
    /// all empty. On a virtual clock each tick is charged
    /// [`DEFAULT_TICK_DT`] simulated seconds (a frozen clock would report
    /// zero latencies and infinite throughput, and time-based scheduling
    /// — suspension aging, deadline misses — could never trigger); on a
    /// wall clock the advance is a no-op.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            self.tick()?;
            self.clock.advance(DEFAULT_TICK_DT);
        }
        Ok(())
    }
}
