//! Memory-bounded million-session soak core (DESIGN.md §3.10): the
//! scale regression harness behind `repro soak` and `bench_soak`.
//!
//! The soak exercises the *scheduling* layer at a scale where model math
//! is irrelevant: each synthetic session carries a seed-derived service
//! demand (an EAT-like early-exit tick profile with a stall tail) and
//! the question is how much coordinator work — and how much memory — it
//! costs to push a million of them through a bounded slot pool
//! deterministically.
//!
//! Two interchangeable cores produce the same completion invariants
//! (sessions completed, total tokens, stall count):
//!
//!  * [`SoakMode::Events`] — the event wheel owns every future event
//!    (arrivals one at a time off a streaming Poisson source, one
//!    completion timer per residency), sessions live in a generational
//!    [`Slab`], and metrics are bounded ([`Summary`] reservoirs +
//!    streaming moments). Cost is O(events) = O(2 · sessions); idle gaps
//!    are one wheel jump; the high-water footprint is
//!    `peak_resident × slot_size`, which is the bytes/session the
//!    report prints.
//!  * [`SoakMode::Driver`] — the pre-wheel shape, kept as the honest
//!    baseline `bench_soak` measures against: arrivals materialized
//!    upfront, a `blocked_until`-style scan over every resident each
//!    driver iteration, a second per-tick scan advancing every resident
//!    by one tick, and unbounded per-sample metric vectors. Cost is
//!    O(resident × ticks) — mean service is tens of ticks, so the event
//!    core beats it by roughly that factor.
//!
//! Both cores are pure functions of [`SoakConfig`]: no wall-clock reads,
//! no hashing — a double run serializes byte-identical JSON, which the
//! CI `soak-smoke` job diffs, alongside an enforced memory ceiling
//! ([`SoakConfig::mem_budget_bytes`] fails the run on breach).

use std::collections::{BinaryHeap, VecDeque};

use anyhow::Result;

use super::batcher::DEFAULT_TICK_DT;
use super::metrics::summary_json;
use super::workload::{build_arrivals, collect_arrivals};
use crate::config::OverloadPolicy;
use crate::util::cli::ArrivalSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::slab::{GenKey, Slab};
use crate::util::stats::{StreamingMoments, Summary, DEFAULT_SUMMARY_CAP};
use crate::util::wheel::EventWheel;

/// Mean seed-derived service demand of [`session_demand`], in ticks:
/// 0.6·15.5 + 0.3·47.5 + 0.1·119.5 ≈ 35.5, times the 2% stall tail's
/// 3× penalty ≈ 37. Capacity below is derived from it — update both if
/// the demand profile changes.
pub const MEAN_DEMAND_TICKS: f64 = 37.0;

/// Sustainable completion rate of a `slots`-wide pool: slots over the
/// mean service time. The `--overload` factor multiplies this.
pub fn capacity_per_s(slots: usize) -> f64 {
    slots as f64 / (MEAN_DEMAND_TICKS * DEFAULT_TICK_DT)
}

/// Soak shape. Everything the run depends on — the report is a pure
/// function of this struct.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Sessions to arrive (the open-loop total).
    pub sessions: u64,
    /// Arrival rate, sessions per virtual second (ignored when
    /// `overload` pins the rate to a capacity multiple).
    pub rate_per_s: f64,
    /// Arrival process shape (DESIGN.md §3.11 zoo); Poisson is the
    /// pinned default.
    pub arrivals: ArrivalSpec,
    /// Offered load as a multiple of [`capacity_per_s`]; overrides
    /// `rate_per_s`. `Some(2.0)` = 2x saturation.
    pub overload: Option<f64>,
    /// Per-session SLO on total latency (arrival → completion), virtual
    /// seconds. Infinite = no SLO (the default): nothing is rejected
    /// and every completion counts toward goodput.
    pub slo_s: f64,
    /// Overload control: reject expired waiters, optionally shedding
    /// nearest-to-exit residents to admit fresh arrivals.
    pub shed: OverloadPolicy,
    /// Concurrent resident sessions (the slot pool).
    pub slots: usize,
    pub seed: u64,
    /// Reservoir bound for the latency/wait [`Summary`]s.
    pub summary_cap: usize,
    /// Hard ceiling on the accounted footprint; breaching it fails the
    /// run (the CI `soak-smoke` contract).
    pub mem_budget_bytes: Option<u64>,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            sessions: 100_000,
            // ~0.7 utilization at 256 slots and the mean ~37-tick demand
            // (capacity ≈ 690/s): heavily loaded but stable, so the
            // waiting queue — and with it the footprint — stays bounded
            // by residency, not by how many sessions ever arrive.
            rate_per_s: 500.0,
            arrivals: ArrivalSpec::Poisson,
            overload: None,
            slo_s: f64::INFINITY,
            shed: OverloadPolicy::None,
            slots: 256,
            seed: 0,
            summary_cap: DEFAULT_SUMMARY_CAP,
            mem_budget_bytes: None,
        }
    }
}

/// Which core runs the soak; see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SoakMode {
    Events,
    Driver,
}

/// A session's seed-derived service demand: reasoning ticks (≈ one
/// decode per tick) already folded with the stall penalty.
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    pub ticks: u32,
    pub stalled: bool,
}

/// Pure function of `(seed, seq)` — like the serving stack's per-request
/// RNGs, a session's demand is invariant to admission order and to
/// which soak core services it. The profile mirrors the paper's
/// early-exit shape: most sessions exit within a few ticks, a mid band
/// reasons longer, a thin tail runs deep, and a small fraction stalls
/// (3× the ticks — the scheduler-level cost of a stuck stream).
pub fn session_demand(seed: u64, seq: u64) -> Demand {
    let mut rng = Rng::new(seed ^ 0x50AC ^ seq.wrapping_mul(0x9E3779B97F4A7C15));
    let class = rng.f64();
    let base = if class < 0.60 {
        8 + rng.below(16)
    } else if class < 0.90 {
        24 + rng.below(48)
    } else {
        80 + rng.below(80)
    };
    let stalled = rng.chance(0.02);
    Demand {
        ticks: (if stalled { base * 3 } else { base }) as u32,
        stalled,
    }
}

/// Whether a session's answer is correct — pure in `(seed, seq)` like
/// [`session_demand`] (separate xor constant so the two draws are
/// independent). EAT-shedding only fires past [`SHED_MIN_PROGRESS`],
/// where the paper's premise is that the answer is already committed —
/// so a shed completion keeps this bit and accuracy is policy-invariant
/// by construction (what the CI equal-accuracy gate checks).
pub fn session_correct(seed: u64, seq: u64) -> bool {
    let mut rng = Rng::new(seed ^ 0xACC5 ^ seq.wrapping_mul(0x9E3779B97F4A7C15));
    rng.chance(0.85)
}

/// Progress floor for EAT-shedding: only residents that have served at
/// least this fraction of their demand may be force-exited (the soak
/// analog of `shed_min_stability` — near the exit point the remaining
/// ticks no longer change the answer).
pub const SHED_MIN_PROGRESS: f64 = 0.75;

/// A session parked behind the full slot pool.
#[derive(Debug, Clone, Copy)]
struct Waiting {
    seq: u64,
    arrived: f64,
}

/// A resident session in the event core: everything needed to account
/// its completion when the timer fires.
#[derive(Debug, Clone, Copy)]
struct Resident {
    seq: u64,
    arrived: f64,
    started: f64,
    finish: f64,
    demand: Demand,
}

/// EAT-shed victim ordering in the event core: min `(finish, seq)` =
/// nearest-to-exit first. `finish >= 0` always, so `f64::to_bits` is
/// order-preserving and gives the heap a total integer order without a
/// float wrapper. Entries for sessions that already finished go stale
/// and are skipped by a generation-key liveness probe.
type ShedEntry = std::cmp::Reverse<(u64, u64, GenKey)>;

/// The deterministic soak outcome. Invariant fields (`completed`,
/// `total_tokens`, `stalled`) are identical across both cores; latency
/// shapes differ only by the driver's tick quantization.
pub struct SoakReport {
    pub mode: &'static str,
    pub arrivals: u64,
    pub completed: u64,
    /// Completions whose [`session_correct`] bit is set (shed sessions
    /// keep theirs — see [`SHED_MIN_PROGRESS`]).
    pub correct: u64,
    /// Completions inside the SLO (= `completed` with no SLO set).
    pub within_slo: u64,
    /// Residents force-exited under saturation (they still complete).
    pub shed: u64,
    /// Waiters dropped because their SLO expired before admission.
    pub rejected: u64,
    pub stalled: u64,
    /// Σ reasoning ticks ≈ decode tokens served.
    pub total_tokens: u64,
    pub peak_resident: usize,
    pub peak_waiting: usize,
    /// High-water accounted footprint (arena + wheel + queues + metrics).
    pub peak_bytes: usize,
    pub elapsed_virtual_s: f64,
    pub latency_ms: Summary,
    pub wait_ms: Summary,
    /// Resident-count moments, sampled once per completion.
    pub occupancy: StreamingMoments,
}

impl SoakReport {
    /// Accounted bytes per concurrently-resident session — the arena
    /// sizing number (total footprint is bounded by residency, not by
    /// how many sessions ever pass through).
    pub fn bytes_per_session(&self) -> usize {
        self.peak_bytes / self.peak_resident.max(1)
    }

    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.completed.max(1) as f64
    }

    /// Useful throughput under saturation: within-SLO completions per
    /// virtual second.
    pub fn goodput_per_s(&self) -> f64 {
        self.within_slo as f64 / self.elapsed_virtual_s.max(1e-9)
    }

    /// Within-SLO completions over everything that asked (completions +
    /// rejections). 1.0 in the unsaturated default.
    pub fn slo_attainment(&self) -> f64 {
        self.within_slo as f64 / (self.completed + self.rejected).max(1) as f64
    }

    /// Deterministic JSON snapshot (sorted keys; byte-identical across
    /// same-config runs — the CI `soak-smoke` double-run diff).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accuracy", Json::num(self.accuracy())),
            ("arrivals", Json::num(self.arrivals as f64)),
            ("bytes_per_session", Json::num(self.bytes_per_session() as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("correct", Json::num(self.correct as f64)),
            ("elapsed_virtual_s", Json::num(self.elapsed_virtual_s)),
            ("goodput_per_s", Json::num(self.goodput_per_s())),
            ("latency_ms", summary_json(&self.latency_ms)),
            ("mode", Json::str(self.mode)),
            ("occupancy_mean", Json::num(self.occupancy.mean())),
            ("occupancy_peak", Json::num(self.peak_resident as f64)),
            ("peak_bytes", Json::num(self.peak_bytes as f64)),
            ("peak_waiting", Json::num(self.peak_waiting as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("stalled", Json::num(self.stalled as f64)),
            ("total_tokens", Json::num(self.total_tokens as f64)),
            ("wait_ms", summary_json(&self.wait_ms)),
        ])
    }

    /// One-block human report for the CLI.
    pub fn report(&self) -> String {
        let mut s = format!(
            "soak[{mode}] {completed} sessions ({stalled} stalled), {tok} tokens \
             over {secs:.1} virtual s\n\
             occupancy mean {occ:.1} peak {peak} (waiting peak {pw})\n\
             latency ms p50 {p50:.1} p95 {p95:.1} p99 {p99:.1} max {max:.1}\n\
             memory peak {kb} KiB ({bps} bytes/session)",
            mode = self.mode,
            completed = self.completed,
            stalled = self.stalled,
            tok = self.total_tokens,
            secs = self.elapsed_virtual_s,
            occ = self.occupancy.mean(),
            peak = self.peak_resident,
            pw = self.peak_waiting,
            p50 = self.latency_ms.p50(),
            p95 = self.latency_ms.p95(),
            p99 = self.latency_ms.p99(),
            max = self.latency_ms.max(),
            kb = self.peak_bytes / 1024,
            bps = self.bytes_per_session(),
        );
        if self.shed + self.rejected > 0 {
            s += &format!(
                "\noverload shed {shed} rejected {rej} | goodput {gp:.1}/s \
                 SLO attainment {slo:.3} accuracy {acc:.3}",
                shed = self.shed,
                rej = self.rejected,
                gp = self.goodput_per_s(),
                slo = self.slo_attainment(),
                acc = self.accuracy(),
            );
        }
        s
    }
}

/// Run the soak with the chosen core.
pub fn run_soak(cfg: &SoakConfig, mode: SoakMode) -> Result<SoakReport> {
    anyhow::ensure!(cfg.sessions > 0, "soak needs at least one session");
    anyhow::ensure!(cfg.slots > 0, "soak needs at least one slot");
    anyhow::ensure!(
        cfg.rate_per_s.is_finite() && cfg.rate_per_s > 0.0,
        "soak arrival rate must be positive"
    );
    if let Some(f) = cfg.overload {
        anyhow::ensure!(f.is_finite() && f > 0.0, "overload factor must be positive");
    }
    anyhow::ensure!(cfg.slo_s > 0.0, "SLO must be positive (infinite = none)");
    if mode == SoakMode::Driver {
        // the driver is frozen as the pre-wheel baseline; overload
        // control only exists in the event core
        anyhow::ensure!(
            cfg.shed == OverloadPolicy::None && cfg.slo_s.is_infinite(),
            "the driver baseline has no overload control; use the events core"
        );
    }
    match mode {
        SoakMode::Events => run_events(cfg),
        SoakMode::Driver => run_driver(cfg),
    }
}

/// The offered rate a config resolves to: the explicit rate, or the
/// overload factor times pool capacity.
fn offered_rate(cfg: &SoakConfig) -> f64 {
    match cfg.overload {
        Some(f) => f * capacity_per_s(cfg.slots),
        None => cfg.rate_per_s,
    }
}

/// Check the accounted footprint against the budget, tracking the peak.
fn account(peak: &mut usize, bytes: usize, budget: Option<u64>) -> Result<()> {
    if bytes > *peak {
        *peak = bytes;
    }
    if let Some(b) = budget {
        anyhow::ensure!(
            bytes as u64 <= b,
            "soak memory budget exceeded: {bytes} bytes accounted against a {b}-byte ceiling"
        );
    }
    Ok(())
}

/// Event lanes: completions fire before the arrival sharing their
/// instant, so a freed slot is visible to it.
const LANE_FINISH: u32 = 0;
const LANE_ARRIVAL: u32 = 1;

enum SoakEvent {
    Arrival,
    Finish(GenKey),
}

/// How often (in events) the footprint is re-accounted. Capacities only
/// move on container growth, so a coarse cadence loses nothing.
const MEM_PROBE_EVERY: u64 = 4096;

fn run_events(cfg: &SoakConfig) -> Result<SoakReport> {
    let mut wheel: EventWheel<SoakEvent> = EventWheel::new(DEFAULT_TICK_DT);
    let mut arrivals = build_arrivals(&cfg.arrivals, offered_rate(cfg), cfg.seed)?;
    let mut resident: Slab<Resident> = Slab::with_capacity(cfg.slots);
    let mut waiting: VecDeque<Waiting> = VecDeque::new();
    // only maintained under EatShed; empty (and free) otherwise
    let mut shed_heap: BinaryHeap<ShedEntry> = BinaryHeap::new();
    let shedding = cfg.shed == OverloadPolicy::EatShed;

    let mut latency_ms = Summary::bounded(cfg.summary_cap);
    let mut wait_ms = Summary::bounded(cfg.summary_cap);
    let mut occupancy = StreamingMoments::default();
    let (mut completed, mut stalled, mut total_tokens) = (0u64, 0u64, 0u64);
    let (mut correct, mut within_slo) = (0u64, 0u64);
    let (mut shed, mut rejected) = (0u64, 0u64);
    let (mut peak_resident, mut peak_waiting, mut peak_bytes) = (0usize, 0usize, 0usize);
    let mut last_t = 0.0f64;
    let mut events = 0u64;

    let mut admitted = 0u64;
    let mut start = |w: Waiting, now: f64, resident: &mut Slab<Resident>,
                     wheel: &mut EventWheel<SoakEvent>,
                     shed_heap: &mut BinaryHeap<ShedEntry>,
                     wait_ms: &mut Summary| {
        let demand = session_demand(cfg.seed, w.seq);
        wait_ms.record((now - w.arrived) * 1e3);
        let finish = now + demand.ticks as f64 * DEFAULT_TICK_DT;
        let key = resident.insert(Resident {
            seq: w.seq,
            arrived: w.arrived,
            started: now,
            finish,
            demand,
        });
        wheel.schedule_at(finish, LANE_FINISH, w.seq, SoakEvent::Finish(key));
        if shedding {
            shed_heap.push(std::cmp::Reverse((finish.to_bits(), w.seq, key)));
        }
        admitted += 1;
    };

    wheel.schedule_at(arrivals.next_arrival(), LANE_ARRIVAL, 0, SoakEvent::Arrival);
    let mut next_seq = 1u64;

    while let Some((k, ev)) = wheel.pop() {
        let now = k.time;
        match ev {
            SoakEvent::Arrival => {
                last_t = now;
                let w = Waiting { seq: k.seq, arrived: now };
                if resident.len() < cfg.slots {
                    start(w, now, &mut resident, &mut wheel, &mut shed_heap, &mut wait_ms);
                } else {
                    // saturated: under EatShed, force-exit the
                    // nearest-to-exit resident past the progress floor
                    // and admit the arrival into its slot
                    let mut victim: Option<Resident> = None;
                    while shedding {
                        let Some(&std::cmp::Reverse((bits, _, key))) = shed_heap.peek() else {
                            break;
                        };
                        let Some(r) = resident.get(key).copied() else {
                            shed_heap.pop(); // finished already: stale
                            continue;
                        };
                        debug_assert_eq!(r.finish.to_bits(), bits);
                        let total = (r.demand.ticks as f64 * DEFAULT_TICK_DT).max(1e-12);
                        if (now - r.started) / total < SHED_MIN_PROGRESS {
                            break; // nearest-to-exit is still mid-flight
                        }
                        shed_heap.pop();
                        resident.remove(key);
                        victim = Some(r);
                        break;
                    }
                    if let Some(r) = victim {
                        // the shed session completes now with whatever
                        // it served; its answer bit survives because we
                        // only shed past SHED_MIN_PROGRESS
                        shed += 1;
                        completed += 1;
                        total_tokens += ((now - r.started) / DEFAULT_TICK_DT) as u64;
                        if r.demand.stalled {
                            stalled += 1;
                        }
                        correct += session_correct(cfg.seed, r.seq) as u64;
                        let lat_s = now - r.arrived;
                        within_slo += (lat_s <= cfg.slo_s) as u64;
                        latency_ms.record(lat_s * 1e3);
                        occupancy.record(resident.len() as f64);
                        start(w, now, &mut resident, &mut wheel, &mut shed_heap, &mut wait_ms);
                    } else {
                        waiting.push_back(w);
                        peak_waiting = peak_waiting.max(waiting.len());
                    }
                }
                peak_resident = peak_resident.max(resident.len());
                if next_seq < cfg.sessions {
                    wheel.schedule_at(
                        arrivals.next_arrival(),
                        LANE_ARRIVAL,
                        next_seq,
                        SoakEvent::Arrival,
                    );
                    next_seq += 1;
                }
            }
            SoakEvent::Finish(key) => {
                // a shed session's original timer fires into nothing
                let Some(r) = resident.remove(key) else {
                    continue;
                };
                last_t = now;
                completed += 1;
                total_tokens += r.demand.ticks as u64;
                if r.demand.stalled {
                    stalled += 1;
                }
                correct += session_correct(cfg.seed, r.seq) as u64;
                let lat_s = now - r.arrived;
                within_slo += (lat_s <= cfg.slo_s) as u64;
                latency_ms.record(lat_s * 1e3);
                occupancy.record(resident.len() as f64);
                // admit the next waiter whose SLO hasn't already passed;
                // under overload control an expired waiter is rejected
                // (it could only complete late — spending a slot on it
                // costs goodput)
                while let Some(w) = waiting.pop_front() {
                    if cfg.shed != OverloadPolicy::None && now - w.arrived > cfg.slo_s {
                        rejected += 1;
                        continue;
                    }
                    start(w, now, &mut resident, &mut wheel, &mut shed_heap, &mut wait_ms);
                    peak_resident = peak_resident.max(resident.len());
                    break;
                }
            }
        }
        events += 1;
        if events % MEM_PROBE_EVERY == 0 {
            let bytes = resident.approx_bytes()
                + wheel.approx_bytes()
                + waiting.capacity() * std::mem::size_of::<Waiting>()
                + shed_heap.capacity() * std::mem::size_of::<ShedEntry>()
                + latency_ms.approx_bytes()
                + wait_ms.approx_bytes();
            account(&mut peak_bytes, bytes, cfg.mem_budget_bytes)?;
        }
    }
    // final probe so short runs still report a footprint
    let bytes = resident.approx_bytes()
        + wheel.approx_bytes()
        + waiting.capacity() * std::mem::size_of::<Waiting>()
        + shed_heap.capacity() * std::mem::size_of::<ShedEntry>()
        + latency_ms.approx_bytes()
        + wait_ms.approx_bytes();
    account(&mut peak_bytes, bytes, cfg.mem_budget_bytes)?;

    debug_assert!(resident.is_empty() && waiting.is_empty());
    Ok(SoakReport {
        mode: "events",
        arrivals: admitted,
        completed,
        correct,
        within_slo,
        shed,
        rejected,
        stalled,
        total_tokens,
        peak_resident,
        peak_waiting,
        peak_bytes,
        elapsed_virtual_s: last_t,
        latency_ms,
        wait_ms,
        occupancy,
    })
}

/// A resident session in the driver core: advanced one tick at a time.
struct DriverResident {
    seq: u64,
    arrived: f64,
    remaining: u32,
    demand: Demand,
}

/// The pre-wheel reference core: a faithful miniature of the old
/// `run_open_loop` + per-tick batcher shape. Every driver iteration
/// scans the whole resident set once for the `blocked_until` probe and
/// once to advance it a tick; arrivals are a fully materialized vector;
/// per-sample metrics grow unbounded and sort at the end. This is the
/// baseline `bench_soak` holds the event core's ≥5× against — do not
/// "optimize" it.
fn run_driver(cfg: &SoakConfig) -> Result<SoakReport> {
    let sessions = usize::try_from(cfg.sessions).expect("driver soak within usize");
    let arrivals = collect_arrivals(&cfg.arrivals, sessions, offered_rate(cfg), cfg.seed)?;
    let mut resident: Vec<DriverResident> = Vec::new();
    let mut waiting: VecDeque<Waiting> = VecDeque::new();

    // unbounded per-sample vectors: the old Summary/ServeMetrics shape
    let mut lat_samples: Vec<f64> = Vec::new();
    let mut wait_samples: Vec<f64> = Vec::new();
    let mut occupancy = StreamingMoments::default();
    let (mut completed, mut stalled, mut total_tokens) = (0u64, 0u64, 0u64);
    let mut correct = 0u64;
    let (mut peak_resident, mut peak_waiting, mut peak_bytes) = (0usize, 0usize, 0usize);

    let mut next = 0usize;
    let mut now = 0.0f64;
    let mut ticks = 0u64;
    while completed < cfg.sessions {
        while next < arrivals.len() && arrivals[next] <= now {
            waiting.push_back(Waiting { seq: next as u64, arrived: arrivals[next] });
            next += 1;
        }
        peak_waiting = peak_waiting.max(waiting.len());
        while resident.len() < cfg.slots {
            let Some(w) = waiting.pop_front() else {
                break;
            };
            let demand = session_demand(cfg.seed, w.seq);
            wait_samples.push((now - w.arrived) * 1e3);
            resident.push(DriverResident {
                seq: w.seq,
                arrived: w.arrived,
                remaining: demand.ticks,
                demand,
            });
        }
        peak_resident = peak_resident.max(resident.len());
        if resident.is_empty() {
            // idle: jump to the next arrival (the old driver did too —
            // the per-tick cost is the busy-path scan, not idle spin)
            if next < arrivals.len() {
                now = arrivals[next];
                continue;
            }
            break;
        }
        // blocked_until-style probe: scan every resident (always finds
        // serviceable work in the white-box model, but the scan is the
        // pre-wheel per-iteration cost being measured — black_box keeps
        // the optimizer from deleting it)
        let serviceable = std::hint::black_box(resident.iter().any(|r| r.remaining > 0));
        debug_assert!(serviceable);
        // tick: advance every resident one tick, retiring the done ones
        let mut i = 0;
        while i < resident.len() {
            resident[i].remaining -= 1;
            if resident[i].remaining == 0 {
                let r = resident.swap_remove(i);
                completed += 1;
                total_tokens += r.demand.ticks as u64;
                if r.demand.stalled {
                    stalled += 1;
                }
                correct += session_correct(cfg.seed, r.seq) as u64;
                lat_samples.push((now + DEFAULT_TICK_DT - r.arrived) * 1e3);
                occupancy.record(resident.len() as f64);
            } else {
                i += 1;
            }
        }
        now += DEFAULT_TICK_DT;
        ticks += 1;
        if ticks % MEM_PROBE_EVERY == 0 {
            let bytes = arrivals.capacity() * std::mem::size_of::<f64>()
                + resident.capacity() * std::mem::size_of::<DriverResident>()
                + waiting.capacity() * std::mem::size_of::<Waiting>()
                + (lat_samples.capacity() + wait_samples.capacity())
                    * std::mem::size_of::<f64>();
            account(&mut peak_bytes, bytes, cfg.mem_budget_bytes)?;
        }
    }
    let bytes = arrivals.capacity() * std::mem::size_of::<f64>()
        + resident.capacity() * std::mem::size_of::<DriverResident>()
        + waiting.capacity() * std::mem::size_of::<Waiting>()
        + (lat_samples.capacity() + wait_samples.capacity()) * std::mem::size_of::<f64>();
    account(&mut peak_bytes, bytes, cfg.mem_budget_bytes)?;

    // fold the unbounded samples into Summaries for a comparable report
    let mut latency_ms = Summary::bounded(cfg.summary_cap);
    let mut wait_ms = Summary::bounded(cfg.summary_cap);
    for &v in &lat_samples {
        latency_ms.record(v);
    }
    for &v in &wait_samples {
        wait_ms.record(v);
    }
    Ok(SoakReport {
        mode: "driver",
        arrivals: completed,
        completed,
        correct,
        // no SLO in the driver baseline: everything completed is good
        within_slo: completed,
        shed: 0,
        rejected: 0,
        stalled,
        total_tokens,
        peak_resident,
        peak_waiting,
        peak_bytes,
        elapsed_virtual_s: now,
        latency_ms,
        wait_ms,
        occupancy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SoakConfig {
        // ~0.7 utilization at 32 slots, like the default shape
        SoakConfig {
            sessions: 2000,
            rate_per_s: 60.0,
            slots: 32,
            seed: 7,
            ..SoakConfig::default()
        }
    }

    #[test]
    fn event_core_completes_every_session() {
        let r = run_soak(&small(), SoakMode::Events).unwrap();
        assert_eq!(r.completed, 2000);
        assert_eq!(r.arrivals, 2000);
        assert!(r.total_tokens > 0);
        assert!(r.peak_resident <= 32);
        assert!(r.elapsed_virtual_s > 0.0);
        assert!(r.peak_bytes > 0);
    }

    #[test]
    fn cores_agree_on_completion_invariants() {
        let cfg = small();
        let ev = run_soak(&cfg, SoakMode::Events).unwrap();
        let dr = run_soak(&cfg, SoakMode::Driver).unwrap();
        assert_eq!(ev.completed, dr.completed);
        assert_eq!(ev.total_tokens, dr.total_tokens);
        assert_eq!(ev.stalled, dr.stalled);
        assert_eq!(ev.correct, dr.correct, "correctness is a pure (seed, seq) draw");
    }

    #[test]
    fn double_runs_serialize_byte_identical_json() {
        let cfg = small();
        let a = run_soak(&cfg, SoakMode::Events).unwrap().to_json().to_string();
        let b = run_soak(&cfg, SoakMode::Events).unwrap().to_json().to_string();
        assert_eq!(a, b);
        let c = run_soak(&cfg, SoakMode::Driver).unwrap().to_json().to_string();
        let d = run_soak(&cfg, SoakMode::Driver).unwrap().to_json().to_string();
        assert_eq!(c, d);
    }

    #[test]
    fn event_core_memory_is_bounded_by_residency_not_arrivals() {
        // 10x the sessions must not grow the accounted footprint
        // (same slots, same reservoir caps; only the wheel's transient
        // occupancy varies)
        let small_run = run_soak(
            &SoakConfig { sessions: 5000, summary_cap: 512, ..small() },
            SoakMode::Events,
        )
        .unwrap();
        let big_run = run_soak(
            &SoakConfig { sessions: 50_000, summary_cap: 512, ..small() },
            SoakMode::Events,
        )
        .unwrap();
        assert!(
            big_run.peak_bytes < small_run.peak_bytes * 4,
            "10x sessions grew accounted bytes {} -> {}",
            small_run.peak_bytes,
            big_run.peak_bytes
        );
    }

    #[test]
    fn memory_budget_breach_fails_the_run() {
        let cfg = SoakConfig { mem_budget_bytes: Some(64), ..small() };
        assert!(run_soak(&cfg, SoakMode::Events).is_err());
    }

    fn overloaded(shed: OverloadPolicy) -> SoakConfig {
        SoakConfig {
            overload: Some(2.0),
            slo_s: 10.0,
            shed,
            ..small()
        }
    }

    #[test]
    fn eat_shed_beats_reject_only_at_equal_accuracy() {
        // the PR's headline claim at soak scale: under 2x overload,
        // shedding nearest-to-exit residents converts queue time into
        // completions without touching the answer bits
        let rej = run_soak(&overloaded(OverloadPolicy::RejectOnly), SoakMode::Events).unwrap();
        let eat = run_soak(&overloaded(OverloadPolicy::EatShed), SoakMode::Events).unwrap();
        assert!(eat.shed > 0, "2x overload must trigger shedding");
        assert_eq!(rej.shed, 0, "reject-only never force-exits");
        assert!(
            eat.goodput_per_s() > rej.goodput_per_s(),
            "EAT-shed goodput {} must beat reject-only {}",
            eat.goodput_per_s(),
            rej.goodput_per_s()
        );
        assert!(
            eat.slo_attainment() > rej.slo_attainment(),
            "EAT-shed SLO attainment {} must beat reject-only {}",
            eat.slo_attainment(),
            rej.slo_attainment()
        );
        // equal accuracy: sheds fire past SHED_MIN_PROGRESS, so the
        // per-session answer bits are untouched; only the completion
        // mix shifts, which moves the ratio a hair
        // (0.05 at this 2000-session scale; the 100k CI smoke
        // tightens it to 0.02 where the sampling noise vanishes)
        assert!(
            (eat.accuracy() - rej.accuracy()).abs() < 0.05,
            "accuracy must hold: eat {} vs reject {}",
            eat.accuracy(),
            rej.accuracy()
        );
    }

    #[test]
    fn overload_runs_are_deterministic_for_every_arrival_shape() {
        for arrivals in [ArrivalSpec::Poisson, ArrivalSpec::Burst, ArrivalSpec::Diurnal] {
            let cfg = SoakConfig {
                arrivals: arrivals.clone(),
                ..overloaded(OverloadPolicy::EatShed)
            };
            let a = run_soak(&cfg, SoakMode::Events).unwrap().to_json().to_string();
            let b = run_soak(&cfg, SoakMode::Events).unwrap().to_json().to_string();
            assert_eq!(a, b, "double run diverged under {arrivals:?}");
        }
    }

    #[test]
    fn driver_baseline_refuses_overload_control() {
        assert!(run_soak(&overloaded(OverloadPolicy::EatShed), SoakMode::Driver).is_err());
        assert!(run_soak(
            &SoakConfig { slo_s: 5.0, ..small() },
            SoakMode::Driver
        )
        .is_err());
        // but it does replay the arrival zoo (no overload knobs)
        let r = run_soak(
            &SoakConfig { arrivals: ArrivalSpec::Burst, ..small() },
            SoakMode::Driver,
        )
        .unwrap();
        assert_eq!(r.completed, 2000);
    }

    #[test]
    fn every_arrival_is_accounted_under_overload_control() {
        for shed in [OverloadPolicy::RejectOnly, OverloadPolicy::EatShed] {
            let r = run_soak(&overloaded(shed), SoakMode::Events).unwrap();
            assert_eq!(
                r.completed + r.rejected,
                2000,
                "every session completes or is rejected ({shed:?})"
            );
            // served tokens never exceed total demand (sheds truncate,
            // they don't invent work)
            let full_demand: u64 =
                (0..2000u64).map(|s| session_demand(7, s).ticks as u64).sum();
            assert!(r.total_tokens <= full_demand);
        }
    }

    #[test]
    fn demand_is_a_pure_function_of_seed_and_seq() {
        for seq in 0..100u64 {
            let a = session_demand(3, seq);
            let b = session_demand(3, seq);
            assert_eq!((a.ticks, a.stalled), (b.ticks, b.stalled));
        }
        let changed = (0..100u64)
            .filter(|&s| session_demand(3, s).ticks != session_demand(4, s).ticks)
            .count();
        assert!(changed > 50, "seed must reshuffle demands ({changed}/100 changed)");
    }
}
