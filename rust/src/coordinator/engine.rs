//! The serving engine: a *split-phase* per-request state machine.
//!
//! `ReasoningSession` holds **no model or runtime reference**. It is
//! advanced by a poll/complete protocol (DESIGN.md §3.2):
//!
//!  * [`ReasoningSession::poll`] returns the next [`StepWork`] the
//!    request needs — a decode to commit, a probe, a rollout, or `Done`;
//!  * the driver executes that work against a [`Backend`] (it owns the
//!    caches) and feeds the result back through `complete_decode` /
//!    `complete_probe` / `complete_rollout`.
//!
//! This inversion is what lets the continuous batcher gather the pending
//! decode of *every* active session into one fused `decode_batch` call
//! per scheduling tick, while single-request paths ([`serve_one`],
//! tracegen, quickstart) drive the same protocol sequentially through
//! [`service_work`]. The session's control flow — line loop, EAT
//! monitoring at line boundaries (Alg. 1), forced answer elicitation —
//! is identical either way, and with identical seeds the produced
//! [`RequestResult`]s are identical too.

use std::time::Instant;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::datasets::{check_answer, Question};
use crate::exit::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::runtime::{Backend, BackendCache, Runtime};
use crate::sampler::Sampler;
use crate::util::rng::Rng;
use crate::vocab::{Vocab, ANSWER_SAMPLE_CAP};

/// Greedy rollout length of the confidence baseline (Eq. 16).
pub const CONFIDENCE_ROLLOUT_LEN: usize = 5;

/// Which model computes EAT (Alg. 1's optional proxy phi).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MonitorModel {
    /// White-box: the reasoning model's own logits.
    SelfModel,
    /// Black-box: a separate small proxy keeps its own KV cache over the
    /// verbal reasoning stream and supplies the entropy.
    Proxy,
}

/// Which cache/model a probe targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeTarget {
    /// Always the main model (answer-distribution probes, #UA@K).
    Main,
    /// The monitoring model: the proxy when black-box, else the main.
    Monitor,
}

/// Work a session asks its driver to perform.
#[derive(Debug, Clone, PartialEq)]
pub enum StepWork {
    /// Commit `token` on the main model and reply with the new logits.
    /// When `mirror` is set (proxy-monitored reasoning tokens), the
    /// driver also commits the token into the proxy cache.
    Decode { token: u32, mirror: bool },
    /// Probe `suffix` against `target` (cache untouched); reply with
    /// (entropy, logits).
    Probe { suffix: Vec<u32>, target: ProbeTarget },
    /// Greedy confidence rollout (Eq. 16) on a *fork* of the main cache:
    /// decode `suffix`, then up to `max_tokens` greedy continuations;
    /// reply with (length-normalized likelihood, tokens charged).
    Rollout { suffix: Vec<u32>, max_tokens: usize },
    /// The request is finished; call [`ReasoningSession::finish`].
    Done,
}

/// Completed request summary.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub question_id: usize,
    pub exit_reason: ExitReason,
    /// Reasoning tokens committed (|R|).
    pub reasoning_tokens: usize,
    /// Reasoning lines observed.
    pub lines: usize,
    /// EAT probes issued (each costs ~suffix_len decode-equivalents).
    pub probes: usize,
    /// Rollout tokens charged by rollout-based signals (#UA@K, confidence).
    pub rollout_tokens: usize,
    /// The generated answer tail (after `</think>`).
    pub answer_tail: Vec<u32>,
    pub correct: bool,
    pub wall_ms: f64,
}

/// Internal protocol state. `Await*` states have work in flight; the
/// others decide the next work at `poll` time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Reasoning phase, logits in hand: next poll samples a token.
    Ready,
    /// Reasoning decode in flight.
    AwaitDecode { tok: u32 },
    /// EAT probe in flight (line boundary).
    AwaitEat,
    /// Answer-distribution probe in flight for #UA@K sampling.
    AwaitUa,
    /// Confidence rollout in flight.
    AwaitConf,
    /// Elicitation: about to emit the next forced/sampled tail token.
    Elicit { forced: usize, sampled: usize },
    /// Elicitation decode in flight.
    AwaitElicit { tok: u32, forced: usize, sampled: usize },
    Done,
}

/// Per-request split-phase state machine (no model access).
pub struct ReasoningSession {
    cfg: ServeConfig,
    monitor: MonitorModel,
    vocab: Vocab,
    seq_len: usize,
    pub question: Question,
    policy: Box<dyn ExitPolicy>,
    rng: Rng,
    sampler: Sampler,

    /// Logits of the next token (updated by every completed decode).
    cur_logits: Vec<f32>,
    /// Mirror of the main cache's write position.
    pos: usize,
    state: State,
    /// Line-boundary observation under construction.
    pending_obs: LineObs,
    line_needs: SignalNeeds,

    reasoning_tokens: Vec<u32>,
    line_count: usize,
    probes: usize,
    rollout_tokens: usize,
    exit_reason: Option<ExitReason>,
    answer_tail: Vec<u32>,
    started: Instant,
}

impl ReasoningSession {
    /// Build a session from a completed prefill. The driver prefilled
    /// `question.prompt + <think>` (see [`start_session`]) and hands the
    /// resulting logits + position in; the session never touches a model
    /// from here on.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        vocab: Vocab,
        seq_len: usize,
        cfg: ServeConfig,
        monitor: MonitorModel,
        question: Question,
        policy: Box<dyn ExitPolicy>,
        rng: Rng,
        prefill_logits: Vec<f32>,
        prompt_len: usize,
    ) -> ReasoningSession {
        let sampler = Sampler::new(cfg.temperature, cfg.top_p);
        ReasoningSession {
            cfg,
            monitor,
            vocab,
            seq_len,
            question,
            policy,
            rng,
            sampler,
            cur_logits: prefill_logits,
            pos: prompt_len,
            state: State::Ready,
            pending_obs: LineObs::default(),
            line_needs: SignalNeeds::default(),
            reasoning_tokens: Vec::new(),
            line_count: 0,
            probes: 0,
            rollout_tokens: 0,
            exit_reason: None,
            answer_tail: Vec::new(),
            started: Instant::now(),
        }
    }

    pub fn done(&self) -> bool {
        self.state == State::Done
    }

    pub fn reasoning_len(&self) -> usize {
        self.reasoning_tokens.len()
    }

    /// The main-cache write position this session mirrors.
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn monitor(&self) -> MonitorModel {
        self.monitor
    }

    /// Scheduler hint from the exit policy: closeness of the monitored
    /// signal to its exit threshold in (0, 1], or `None` for fixed
    /// policies (see `ExitPolicy::stability`).
    pub fn stability(&self) -> Option<f64> {
        self.policy.stability()
    }

    /// True when no decode is in flight and the session is not finished
    /// — i.e. between scheduling ticks. In these states the committed
    /// token history fully determines the KV caches, so the slot can be
    /// evicted and rebuilt later by re-prefill ([`resume_session`]).
    /// Probe/rollout states are suspendable too: `poll` is idempotent,
    /// so the pending work is simply re-requested against the rebuilt
    /// caches after resume.
    pub fn can_suspend(&self) -> bool {
        !matches!(self.state, State::AwaitDecode { .. } | State::AwaitElicit { .. } | State::Done)
    }

    /// True while the answer tail is being elicited — the session is
    /// past its reasoning phase and a handful of tokens from retiring
    /// (the scheduler never preempts these: a full re-prefill to decode
    /// a few tail tokens is pure waste).
    pub fn eliciting(&self) -> bool {
        matches!(self.state, State::Elicit { .. } | State::AwaitElicit { .. })
    }

    /// The committed main-model token history: prompt + `<think>` +
    /// reasoning tokens + decoded answer tail. Re-prefilling exactly
    /// this sequence rebuilds the evicted main cache — bit-identical on
    /// the reference backend, whose logits are a pure function of the
    /// history.
    pub fn history(&self) -> Vec<u32> {
        let mut h = Vec::with_capacity(self.pos);
        h.extend_from_slice(&self.question.prompt);
        h.push(self.vocab.think);
        h.extend_from_slice(&self.reasoning_tokens);
        h.extend_from_slice(&self.answer_tail);
        debug_assert_eq!(h.len(), self.pos, "token history out of sync with cache position");
        h
    }

    /// The token history mirrored into the proxy cache: proxy-monitored
    /// sessions mirror reasoning tokens only (the answer tail is decoded
    /// with `mirror: false`).
    pub fn mirrored_history(&self) -> Vec<u32> {
        let mut h =
            Vec::with_capacity(self.question.prompt.len() + 1 + self.reasoning_tokens.len());
        h.extend_from_slice(&self.question.prompt);
        h.push(self.vocab.think);
        h.extend_from_slice(&self.reasoning_tokens);
        h
    }

    /// Scheduler-driven exit (DESIGN.md §3.4 stall retirement): abandon
    /// the reasoning phase and elicit the answer now. Legal only between
    /// ticks (no decode in flight) and before elicitation started;
    /// returns false (and changes nothing) otherwise.
    pub fn force_exit(&mut self, reason: ExitReason) -> bool {
        match self.state {
            State::Ready | State::AwaitEat | State::AwaitUa | State::AwaitConf => {
                self.begin_elicit(reason);
                true
            }
            _ => false,
        }
    }

    /// The probe target of the EAT signal per the monitoring mode.
    fn monitor_target(&self) -> ProbeTarget {
        match self.monitor {
            MonitorModel::Proxy => ProbeTarget::Monitor,
            MonitorModel::SelfModel => ProbeTarget::Main,
        }
    }

    /// EAT probe suffix per config (Eq. 12 vs Eq. 13).
    fn probe_suffix(&self) -> Vec<u32> {
        if self.cfg.prefixed_probe {
            self.vocab.suffix_prefixed()
        } else {
            self.vocab.suffix_plain()
        }
    }

    /// After a line boundary (or a completed line signal), pick the next
    /// signal the policy still needs, or finalize the line.
    fn advance_line(&mut self) {
        let needs = self.line_needs;
        if needs.eat && self.pending_obs.eat.is_none() {
            self.state = State::AwaitEat;
            return;
        }
        let wants_ua = needs.rollouts_k > 0
            && self.line_count % needs.rollout_every == 0
            && self.pending_obs.unique_answers.is_none();
        if wants_ua {
            self.state = State::AwaitUa;
            return;
        }
        if needs.confidence && self.pending_obs.confidence.is_none() {
            self.state = State::AwaitConf;
            return;
        }
        // all signals gathered: evaluate the exit policy (Alg. 1 l. 6-9)
        match self.policy.observe(&self.pending_obs) {
            ExitDecision::Exit(reason) => self.begin_elicit(reason),
            ExitDecision::Continue => self.state = State::Ready,
        }
    }

    /// Begin answer elicitation with the given exit reason.
    fn begin_elicit(&mut self, reason: ExitReason) {
        self.exit_reason = Some(reason);
        self.state = State::Elicit {
            forced: 0,
            sampled: 0,
        };
    }

    /// What should the driver do next? Idempotent for in-flight states:
    /// re-polling without completing returns the same work.
    pub fn poll(&mut self) -> StepWork {
        loop {
            match self.state {
                State::Ready => {
                    // headroom check: leave space for the full answer tail
                    // (forced suffix + sampled value/EOS) — derived from
                    // the vocab, not a magic constant
                    let room = self.seq_len - self.pos;
                    if room <= self.vocab.answer_reserve() {
                        self.begin_elicit(ExitReason::TokenBudget);
                        continue;
                    }
                    let tok = self.sampler.sample(&self.cur_logits, &mut self.rng);
                    if tok == self.vocab.ethink {
                        // the model stopped thinking on its own
                        self.policy.observe(&LineObs {
                            tokens: self.reasoning_tokens.len(),
                            self_terminated: true,
                            ..Default::default()
                        });
                        self.begin_elicit(ExitReason::SelfTerminated);
                        continue;
                    }
                    self.state = State::AwaitDecode { tok };
                    return StepWork::Decode {
                        token: tok,
                        mirror: self.monitor == MonitorModel::Proxy,
                    };
                }
                State::AwaitDecode { tok } => {
                    return StepWork::Decode {
                        token: tok,
                        mirror: self.monitor == MonitorModel::Proxy,
                    };
                }
                State::AwaitEat => {
                    return StepWork::Probe {
                        suffix: self.probe_suffix(),
                        target: self.monitor_target(),
                    };
                }
                State::AwaitUa => {
                    // #UA@K always samples the main model's forced-answer
                    // distribution (Alg. 3)
                    return StepWork::Probe {
                        suffix: self.vocab.suffix_prefixed(),
                        target: ProbeTarget::Main,
                    };
                }
                State::AwaitConf => {
                    return StepWork::Rollout {
                        suffix: self.vocab.suffix_prefixed(),
                        max_tokens: CONFIDENCE_ROLLOUT_LEN,
                    };
                }
                State::Elicit { forced, sampled } => {
                    if self.pos >= self.seq_len {
                        self.state = State::Done;
                        continue;
                    }
                    let force = self.vocab.forced_answer_tail();
                    if forced < force.len() {
                        let tok = force[forced];
                        self.state = State::AwaitElicit {
                            tok,
                            forced,
                            sampled,
                        };
                        return StepWork::Decode {
                            token: tok,
                            mirror: false,
                        };
                    }
                    if sampled >= ANSWER_SAMPLE_CAP {
                        self.state = State::Done;
                        continue;
                    }
                    let tok = self.sampler.sample(&self.cur_logits, &mut self.rng);
                    self.answer_tail.push(tok);
                    if tok == self.vocab.eos {
                        self.state = State::Done;
                        continue;
                    }
                    self.state = State::AwaitElicit {
                        tok,
                        forced,
                        sampled: sampled + 1,
                    };
                    return StepWork::Decode {
                        token: tok,
                        mirror: false,
                    };
                }
                State::AwaitElicit { tok, .. } => {
                    return StepWork::Decode {
                        token: tok,
                        mirror: false,
                    };
                }
                State::Done => return StepWork::Done,
            }
        }
    }

    /// Feed back the logits of a completed [`StepWork::Decode`].
    pub fn complete_decode(&mut self, logits: Vec<f32>) -> Result<()> {
        match self.state {
            State::AwaitDecode { tok } => {
                self.cur_logits = logits;
                self.pos += 1;
                self.reasoning_tokens.push(tok);
                if tok == self.vocab.nl {
                    // line boundary: gather what the policy needs
                    self.line_count += 1;
                    self.line_needs = self.policy.needs();
                    self.pending_obs = LineObs {
                        tokens: self.reasoning_tokens.len(),
                        ..Default::default()
                    };
                    self.advance_line();
                } else if self.reasoning_tokens.len() >= self.cfg.max_think_tokens {
                    self.begin_elicit(ExitReason::TokenBudget);
                } else {
                    self.state = State::Ready;
                }
                Ok(())
            }
            State::AwaitElicit {
                tok,
                forced,
                sampled,
            } => {
                self.cur_logits = logits;
                self.pos += 1;
                let force_len = self.vocab.forced_answer_tail().len();
                if forced < force_len {
                    // forced tokens enter the tail once actually decoded
                    self.answer_tail.push(tok);
                    self.state = State::Elicit {
                        forced: forced + 1,
                        sampled,
                    };
                } else {
                    self.state = State::Elicit { forced, sampled };
                }
                Ok(())
            }
            _ => anyhow::bail!("complete_decode in state {:?}", self.state),
        }
    }

    /// Feed back a completed [`StepWork::Probe`].
    pub fn complete_probe(&mut self, eat: f32, logits: &[f32]) -> Result<()> {
        match self.state {
            State::AwaitEat => {
                self.probes += 1;
                self.pending_obs.eat = Some(eat as f64);
                self.advance_line();
                Ok(())
            }
            State::AwaitUa => {
                // #UA@K: the answer of the chain-sum task is a single
                // token after the forced suffix, so sampling the probe
                // logits K times is *distributionally identical* to K
                // full rollouts; we charge the full rollout token cost
                // (suffix + answer + EOS per rollout), as the paper does
                // in Fig. 6b.
                self.probes += 1;
                let k = self.line_needs.rollouts_k;
                let mut seen = std::collections::BTreeSet::new();
                for _ in 0..k {
                    seen.insert(self.sampler.sample(logits, &mut self.rng));
                }
                self.pending_obs.unique_answers = Some(seen.len());
                let per_rollout = self.vocab.suffix_prefixed().len() + 2; // value + EOS
                self.rollout_tokens += k * per_rollout;
                self.advance_line();
                Ok(())
            }
            _ => anyhow::bail!("complete_probe in state {:?}", self.state),
        }
    }

    /// Feed back a completed [`StepWork::Rollout`].
    pub fn complete_rollout(&mut self, confidence: f64, tokens_charged: usize) -> Result<()> {
        match self.state {
            State::AwaitConf => {
                self.pending_obs.confidence = Some(confidence);
                self.rollout_tokens += tokens_charged;
                self.advance_line();
                Ok(())
            }
            _ => anyhow::bail!("complete_rollout in state {:?}", self.state),
        }
    }

    /// Summarize a finished session.
    pub fn finish(self) -> RequestResult {
        debug_assert_eq!(self.state, State::Done);
        let correct = check_answer(&self.vocab, &self.question, &self.answer_tail);
        RequestResult {
            question_id: self.question.id,
            exit_reason: self.exit_reason.unwrap_or(ExitReason::TokenBudget),
            reasoning_tokens: self.reasoning_tokens.len(),
            lines: self.line_count,
            probes: self.probes,
            rollout_tokens: self.rollout_tokens,
            answer_tail: self.answer_tail,
            correct,
            wall_ms: self.started.elapsed().as_secs_f64() * 1e3,
        }
    }
}

/// The per-session caches a driver owns on the session's behalf.
pub struct SessionCaches {
    pub main: BackendCache,
    /// Present iff the session is proxy-monitored.
    pub proxy: Option<BackendCache>,
}

/// Prefill `prompt + <think>` on the main model (and the proxy when
/// black-box monitoring is requested) and build the session.
pub fn start_session(
    rt: &Runtime,
    cfg: ServeConfig,
    monitor: MonitorModel,
    question: Question,
    policy: Box<dyn ExitPolicy>,
    rng: Rng,
) -> Result<(ReasoningSession, SessionCaches)> {
    let mut prompt = question.prompt.clone();
    prompt.push(rt.vocab.think);
    let (logits, main) = rt.main.prefill(&prompt)?;
    let proxy = match monitor {
        MonitorModel::SelfModel => None,
        MonitorModel::Proxy => Some(rt.proxy.prefill(&prompt)?.1),
    };
    let session = ReasoningSession::new(
        rt.vocab,
        rt.main.seq_len(),
        cfg,
        monitor,
        question,
        policy,
        rng,
        logits,
        prompt.len(),
    );
    Ok((session, SessionCaches { main, proxy }))
}

/// Rebuild the KV caches of a suspended session by re-prefilling its
/// committed token history (DESIGN.md §3.4 preemption protocol). On the
/// reference backend the rebuilt caches are bit-identical to the evicted
/// ones — prefill and step-wise decode are the same pure function of the
/// token history — so a resumed session continues exactly as if it had
/// never been preempted (pinned by `tests/scheduler_sim.rs`). Under the
/// paged store (DESIGN.md §3.5) this is the *fallback* path: suspension
/// normally retains the session's pages and resume repins them with no
/// prefill at all; re-prefill runs only when host page pressure spilled
/// the retained pages, and doubles as the equivalence oracle for the
/// repin path.
pub fn resume_session(rt: &Runtime, session: &ReasoningSession) -> Result<SessionCaches> {
    anyhow::ensure!(session.can_suspend(), "cannot rebuild caches while a decode is in flight");
    let hist = session.history();
    let (_logits, main) = rt.main.prefill(&hist)?;
    anyhow::ensure!(
        main.pos() == session.pos(),
        "resume prefill position mismatch: cache {} vs session {}",
        main.pos(),
        session.pos()
    );
    let proxy = match session.monitor() {
        MonitorModel::SelfModel => None,
        MonitorModel::Proxy => Some(rt.proxy.prefill(&session.mirrored_history())?.1),
    };
    Ok(SessionCaches { main, proxy })
}

/// Service a probe against the right backend/cache pair and feed the
/// result back into the session.
pub fn run_probe(
    rt: &Runtime,
    session: &mut ReasoningSession,
    main: &BackendCache,
    proxy: Option<&BackendCache>,
    suffix: &[u32],
    target: ProbeTarget,
) -> Result<()> {
    let (backend, cache) = match (target, proxy) {
        (ProbeTarget::Monitor, Some(pc)) => (rt.proxy.as_ref(), pc),
        _ => (rt.main.as_ref(), main),
    };
    let (eat, logits) = backend.probe(cache, suffix)?;
    session.complete_probe(eat, &logits)
}

/// Confidence (Eq. 16): greedy rollout of up to `rollout_len` tokens
/// after the answer-inducing suffix on a *forked* cache; returns the
/// length-normalized likelihood and the tokens charged. On a paged
/// backend (DESIGN.md §3.5) the fork is O(pages) refcount bumps and the
/// rollout's divergence copies at most the shared tail page — the
/// monolithic full-sequence cache copy this used to cost is exactly
/// what the paged store eliminates (`RuntimeCounters::{cow_forks,
/// pages_copied, pages_shared}` audit it).
pub fn confidence_rollout(
    backend: &dyn Backend,
    cache: &BackendCache,
    suffix: &[u32],
    rollout_len: usize,
) -> Result<(f64, usize)> {
    let mut fork = backend.fork(cache)?;
    let mut logits = Vec::new();
    for &t in suffix {
        logits = backend.decode(&mut fork, t)?;
    }
    let mut logprob_sum = 0.0f64;
    let mut produced = 0usize;
    for _ in 0..rollout_len {
        if fork.pos() >= backend.seq_len() {
            break;
        }
        let tok = crate::sampler::argmax(&logits);
        logprob_sum += Sampler::logprob(&logits, tok);
        logits = backend.decode(&mut fork, tok)?;
        produced += 1;
    }
    let conf = (logprob_sum / produced.max(1) as f64).exp();
    Ok((conf, suffix.len() + produced))
}

/// Service a rollout request and feed the result back.
pub fn run_rollout(
    rt: &Runtime,
    session: &mut ReasoningSession,
    main: &BackendCache,
    suffix: &[u32],
    max_tokens: usize,
) -> Result<()> {
    let (conf, toks) = confidence_rollout(rt.main.as_ref(), main, suffix, max_tokens)?;
    session.complete_rollout(conf, toks)
}

/// Execute one unit of [`StepWork`] sequentially — the single-session
/// driver the batcher's fused path is equivalent to.
pub fn service_work(
    rt: &Runtime,
    session: &mut ReasoningSession,
    caches: &mut SessionCaches,
    work: StepWork,
) -> Result<()> {
    match work {
        StepWork::Decode { token, mirror } => {
            let logits = rt.main.decode(&mut caches.main, token)?;
            if mirror {
                if let Some(pc) = caches.proxy.as_mut() {
                    rt.proxy.decode(pc, token)?;
                }
            }
            session.complete_decode(logits)
        }
        StepWork::Probe { suffix, target } => run_probe(
            rt,
            session,
            &caches.main,
            caches.proxy.as_ref(),
            &suffix,
            target,
        ),
        StepWork::Rollout { suffix, max_tokens } => {
            run_rollout(rt, session, &caches.main, &suffix, max_tokens)
        }
        StepWork::Done => Ok(()),
    }
}

/// Convenience wrapper: serve one question end-to-end with a policy.
pub fn serve_one(
    rt: &Runtime,
    cfg: &ServeConfig,
    monitor: MonitorModel,
    question: &Question,
    policy: Box<dyn ExitPolicy>,
    seed: u64,
) -> Result<RequestResult> {
    let (mut session, mut caches) = start_session(
        rt,
        cfg.clone(),
        monitor,
        question.clone(),
        policy,
        Rng::new(seed),
    )?;
    loop {
        match session.poll() {
            StepWork::Done => break,
            work => service_work(rt, &mut session, &mut caches, work)?,
        }
    }
    Ok(session.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Dataset;
    use crate::exit::{EatPolicy, TokenBudgetPolicy};

    fn rt() -> Runtime {
        Runtime::reference()
    }

    fn easy_question(rt: &Runtime) -> Question {
        Dataset::synth_math500(&rt.vocab, 30, 3)
            .questions
            .into_iter()
            .find(|q| q.n_ops() <= 3)
            .expect("an easy question exists")
    }

    #[test]
    fn serve_one_answers_easy_questions_correctly() {
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let res = serve_one(
            &rt,
            &cfg,
            MonitorModel::SelfModel,
            &q,
            Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens)),
            7,
        )
        .unwrap();
        assert!(res.correct, "{res:?}");
        assert!(res.probes > 0, "EAT must probe at line boundaries");
        assert!(res.reasoning_tokens > 0);
        assert!(!res.answer_tail.is_empty());
    }

    #[test]
    fn proxy_monitoring_probes_the_proxy() {
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let res = serve_one(
            &rt,
            &cfg,
            MonitorModel::Proxy,
            &q,
            Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens)),
            7,
        )
        .unwrap();
        assert!(res.correct, "{res:?}");
        assert!(rt.proxy.counters().probes.get() >= res.probes as u64);
        // reasoning tokens were mirrored into the proxy cache
        assert!(rt.proxy.counters().decodes.get() >= res.reasoning_tokens as u64);
    }

    #[test]
    fn poll_is_idempotent_while_work_is_in_flight() {
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let (mut session, mut caches) = start_session(
            &rt,
            cfg,
            MonitorModel::SelfModel,
            q,
            Box::new(TokenBudgetPolicy::new(96)),
            Rng::new(1),
        )
        .unwrap();
        let w1 = session.poll();
        let w2 = session.poll();
        assert_eq!(w1, w2, "re-polling must not re-sample");
        service_work(&rt, &mut session, &mut caches, w1).unwrap();
    }

    #[test]
    fn completing_out_of_order_is_an_error_not_a_panic() {
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let (mut session, _caches) = start_session(
            &rt,
            cfg,
            MonitorModel::SelfModel,
            q,
            Box::new(TokenBudgetPolicy::new(96)),
            Rng::new(1),
        )
        .unwrap();
        let _ = session.poll(); // a Decode is now in flight
        assert!(session.complete_probe(0.1, &[0.0; 48]).is_err());
        assert!(session.complete_rollout(0.5, 8).is_err());
    }

    #[test]
    fn headroom_reserve_prevents_answer_truncation() {
        // a tiny budget forces the token-budget exit; the elicited tail
        // must still carry the full forced suffix and an answer value
        let rt = rt();
        let mut cfg = ServeConfig::default();
        cfg.max_think_tokens = 9;
        let q = easy_question(&rt);
        let res = serve_one(
            &rt,
            &cfg,
            MonitorModel::SelfModel,
            &q,
            Box::new(TokenBudgetPolicy::new(9)),
            5,
        )
        .unwrap();
        let v = rt.vocab;
        assert!(res.answer_tail.len() >= v.forced_answer_tail().len() + 1);
        assert_eq!(res.answer_tail[0], v.ethink);
        assert_eq!(res.answer_tail[1], v.final_);
        assert_eq!(res.answer_tail[2], v.ans);
        assert!(
            res.answer_tail[3..]
                .iter()
                .any(|&t| v.num_value(t).is_some()),
            "answer value truncated: {:?}",
            res.answer_tail
        );
    }

    #[test]
    fn suspend_resume_mid_flight_is_bit_identical() {
        // drive two same-seeded sessions; one has its caches evicted and
        // rebuilt by re-prefill at every 5th suspendable boundary — the
        // trajectories must match exactly (DESIGN.md §3.4)
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let run = |suspend: bool| {
            let (mut session, mut caches) = start_session(
                &rt,
                cfg.clone(),
                MonitorModel::SelfModel,
                q.clone(),
                Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens)),
                Rng::new(21),
            )
            .unwrap();
            let mut steps = 0usize;
            loop {
                match session.poll() {
                    StepWork::Done => break,
                    work => {
                        service_work(&rt, &mut session, &mut caches, work).unwrap();
                        steps += 1;
                        if suspend && steps % 5 == 0 && session.can_suspend() {
                            caches = resume_session(&rt, &session).unwrap();
                        }
                    }
                }
            }
            session.finish()
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.reasoning_tokens, b.reasoning_tokens);
        assert_eq!(a.answer_tail, b.answer_tail);
        assert_eq!(a.probes, b.probes);
        assert_eq!(a.exit_reason, b.exit_reason);
        assert_eq!(a.correct, b.correct);
    }

    #[test]
    fn force_exit_refused_while_a_decode_is_in_flight() {
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let (mut session, mut caches) = start_session(
            &rt,
            cfg,
            MonitorModel::SelfModel,
            q,
            Box::new(TokenBudgetPolicy::new(96)),
            Rng::new(2),
        )
        .unwrap();
        let w = session.poll();
        assert!(matches!(w, StepWork::Decode { .. }));
        assert!(!session.can_suspend(), "decode in flight");
        assert!(!session.force_exit(ExitReason::Stalled));
        service_work(&rt, &mut session, &mut caches, w).unwrap();
        assert!(session.can_suspend());
        assert!(session.force_exit(ExitReason::Stalled));
        loop {
            match session.poll() {
                StepWork::Done => break,
                work => service_work(&rt, &mut session, &mut caches, work).unwrap(),
            }
        }
        let res = session.finish();
        assert_eq!(res.exit_reason, ExitReason::Stalled);
        assert!(!res.answer_tail.is_empty(), "forced exit must still elicit an answer");
    }

    #[test]
    fn sequential_driver_is_deterministic_by_seed() {
        let rt = rt();
        let cfg = ServeConfig::default();
        let q = easy_question(&rt);
        let run = |seed| {
            serve_one(
                &rt,
                &cfg,
                MonitorModel::SelfModel,
                &q,
                Box::new(EatPolicy::new(cfg.alpha, cfg.delta, cfg.max_think_tokens)),
                seed,
            )
            .unwrap()
        };
        let (a, b) = (run(11), run(11));
        assert_eq!(a.reasoning_tokens, b.reasoning_tokens);
        assert_eq!(a.answer_tail, b.answer_tail);
        assert_eq!(a.probes, b.probes);
        assert_eq!(a.exit_reason, b.exit_reason);
    }
}
