//! The serving engine: drives a request through
//! prefill -> reasoning (line loop + EAT monitoring) -> answer elicitation.
//!
//! `ReasoningSession` is a per-request state machine advanced one decode
//! step at a time, so the continuous batcher can interleave many sessions
//! (vLLM-style) while the quickstart/eval paths drive a single session to
//! completion. All model access goes through the AOT artifacts — no Python
//! anywhere near this path.

use std::time::Instant;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::datasets::{check_answer, Question};
use crate::exit::{ExitDecision, ExitPolicy, ExitReason, LineObs, SignalNeeds};
use crate::runtime::{KvCache, ModelRuntime, Runtime};
use crate::sampler::Sampler;
use crate::util::rng::Rng;

/// Which model computes EAT (Alg. 1's optional proxy phi).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MonitorModel {
    /// White-box: the reasoning model's own logits.
    SelfModel,
    /// Black-box: a separate small proxy keeps its own KV cache over the
    /// verbal reasoning stream and supplies the entropy.
    Proxy,
}

/// Completed request summary.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub question_id: usize,
    pub exit_reason: ExitReason,
    /// Reasoning tokens committed (|R|).
    pub reasoning_tokens: usize,
    /// Reasoning lines observed.
    pub lines: usize,
    /// EAT probes issued (each costs ~suffix_len decode-equivalents).
    pub probes: usize,
    /// Rollout tokens charged by rollout-based signals (#UA@K, confidence).
    pub rollout_tokens: usize,
    /// The generated answer tail (after `</think>`).
    pub answer_tail: Vec<u32>,
    pub correct: bool,
    pub wall_ms: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Reasoning,
    Done,
}

/// Per-request state machine.
pub struct ReasoningSession<'a> {
    rt: &'a Runtime,
    cfg: ServeConfig,
    monitor: MonitorModel,
    pub question: Question,
    policy: Box<dyn ExitPolicy>,
    rng: Rng,
    sampler: Sampler,

    cache: KvCache,
    proxy_cache: Option<KvCache>,
    cur_logits: Vec<f32>,
    phase: Phase,

    reasoning_tokens: Vec<u32>,
    line_count: usize,
    probes: usize,
    rollout_tokens: usize,
    exit_reason: Option<ExitReason>,
    answer_tail: Vec<u32>,
    started: Instant,
}

impl<'a> ReasoningSession<'a> {
    /// Prefill the prompt (+`<think>`) on the main model, and on the proxy
    /// when black-box monitoring is requested.
    pub fn new(
        rt: &'a Runtime,
        cfg: ServeConfig,
        monitor: MonitorModel,
        question: Question,
        policy: Box<dyn ExitPolicy>,
        rng: Rng,
    ) -> Result<ReasoningSession<'a>> {
        let mut prompt = question.prompt.clone();
        prompt.push(rt.cfg.vocab.think);
        let (logits, cache) = rt.main.prefill(&rt.client, &prompt)?;
        let proxy_cache = match monitor {
            MonitorModel::SelfModel => None,
            MonitorModel::Proxy => Some(rt.proxy.prefill(&rt.client, &prompt)?.1),
        };
        let sampler = Sampler::new(cfg.temperature, cfg.top_p);
        Ok(ReasoningSession {
            rt,
            cfg,
            monitor,
            question,
            policy,
            rng,
            sampler,
            cache,
            proxy_cache,
            cur_logits: logits,
            phase: Phase::Reasoning,
            reasoning_tokens: Vec::new(),
            line_count: 0,
            probes: 0,
            rollout_tokens: 0,
            exit_reason: None,
            answer_tail: Vec::new(),
            started: Instant::now(),
        })
    }

    pub fn done(&self) -> bool {
        self.phase == Phase::Done
    }

    pub fn reasoning_len(&self) -> usize {
        self.reasoning_tokens.len()
    }

    /// The monitoring model + cache used for probes.
    fn probe_target(&self) -> (&ModelRuntime, &KvCache) {
        match (self.monitor, &self.proxy_cache) {
            (MonitorModel::Proxy, Some(pc)) => (&self.rt.proxy, pc),
            _ => (&self.rt.main, &self.cache),
        }
    }

    /// EAT probe suffix per config (Eq. 12 vs Eq. 13).
    fn probe_suffix(&self) -> Vec<u32> {
        if self.cfg.prefixed_probe {
            self.rt.cfg.vocab.suffix_prefixed()
        } else {
            self.rt.cfg.vocab.suffix_plain()
        }
    }

    /// Compute the signals the active policy needs at a line boundary.
    fn line_signals(&mut self, needs: SignalNeeds) -> Result<LineObs> {
        let mut obs = LineObs {
            tokens: self.reasoning_tokens.len(),
            ..Default::default()
        };
        if needs.eat {
            let suffix = self.probe_suffix();
            let (model, cache) = self.probe_target();
            let (eat, _logits) = model.probe(&self.rt.client, cache, &suffix)?;
            self.probes += 1;
            obs.eat = Some(eat as f64);
        }
        if needs.rollouts_k > 0 && self.line_count % needs.rollout_every == 0 {
            let (ua, toks) = self.sample_unique_answers(needs.rollouts_k)?;
            obs.unique_answers = Some(ua);
            self.rollout_tokens += toks;
        }
        if needs.confidence {
            let (conf, toks) = self.confidence_rollout()?;
            obs.confidence = Some(conf);
            self.rollout_tokens += toks;
        }
        Ok(obs)
    }

    /// #UA@K: sample K answer rollouts, count unique extracted answers.
    /// The answer of the chain-sum task is a single token after the forced
    /// `</think> Final answer: A` suffix, so sampling the probe logits K
    /// times is *distributionally identical* to K full rollouts; we charge
    /// the full rollout token cost (suffix + answer + EOS per rollout), as
    /// the paper does in Fig. 6b.
    fn sample_unique_answers(&mut self, k: usize) -> Result<(usize, usize)> {
        let suffix = self.rt.cfg.vocab.suffix_prefixed();
        let (_eat, logits) = self
            .rt
            .main
            .probe(&self.rt.client, &self.cache, &suffix)?;
        self.probes += 1;
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..k {
            seen.insert(self.sampler.sample(&logits, &mut self.rng));
        }
        let per_rollout_tokens = suffix.len() + 2; // answer value + EOS
        Ok((seen.len(), k * per_rollout_tokens))
    }

    /// Confidence (Eq. 16): greedy rollout of `rollout_len` tokens after
    /// the answer-inducing suffix on a *forked* cache; returns the
    /// length-normalized likelihood.
    fn confidence_rollout(&mut self) -> Result<(f64, usize)> {
        let suffix = self.rt.cfg.vocab.suffix_prefixed();
        let mut fork = self.rt.main.fork_cache(&self.rt.client, &self.cache)?;
        let mut logits = Vec::new();
        for &t in &suffix {
            logits = self.rt.main.decode(&self.rt.client, &mut fork, t)?;
        }
        let rollout_len = 5usize;
        let mut logprob_sum = 0.0f64;
        let mut produced = 0usize;
        for _ in 0..rollout_len {
            if fork.pos >= self.rt.cfg.main.seq_len {
                break;
            }
            let tok = crate::sampler::argmax(&logits);
            logprob_sum += Sampler::logprob(&logits, tok);
            logits = self.rt.main.decode(&self.rt.client, &mut fork, tok)?;
            produced += 1;
        }
        let conf = (logprob_sum / produced.max(1) as f64).exp();
        Ok((conf, suffix.len() + produced))
    }

    /// Advance by one decode step. Returns true when the request finished.
    pub fn step(&mut self) -> Result<bool> {
        if self.phase == Phase::Done {
            return Ok(true);
        }
        // room check: leave space for the answer tail (suffix + value + EOS)
        let room = self.rt.cfg.main.seq_len - self.cache.pos;
        if room <= 6 {
            self.exit_reason = Some(ExitReason::TokenBudget);
            return self.elicit_answer().map(|_| true);
        }

        let tok = self.sampler.sample(&self.cur_logits, &mut self.rng);
        let vocab = self.rt.cfg.vocab;

        if tok == vocab.ethink {
            // the model decided to stop thinking on its own
            self.policy.observe(&LineObs {
                tokens: self.reasoning_tokens.len(),
                self_terminated: true,
                ..Default::default()
            });
            self.exit_reason = Some(ExitReason::SelfTerminated);
            return self.elicit_answer().map(|_| true);
        }

        // commit the token to the main cache (and mirror into the proxy)
        self.cur_logits = self.rt.main.decode(&self.rt.client, &mut self.cache, tok)?;
        if let Some(pc) = self.proxy_cache.as_mut() {
            self.rt.proxy.decode(&self.rt.client, pc, tok)?;
        }
        self.reasoning_tokens.push(tok);

        if tok == vocab.nl {
            // line boundary: evaluate the exit policy (Alg. 1 lines 6-9)
            self.line_count += 1;
            let needs = self.policy.needs();
            let obs = self.line_signals(needs)?;
            if let ExitDecision::Exit(reason) = self.policy.observe(&obs) {
                self.exit_reason = Some(reason);
                return self.elicit_answer().map(|_| true);
            }
        } else if self.reasoning_tokens.len() >= self.cfg.max_think_tokens {
            self.exit_reason = Some(ExitReason::TokenBudget);
            return self.elicit_answer().map(|_| true);
        }
        Ok(false)
    }

    /// Force `</think> Final answer: A` and sample the answer
    /// (GenTillEoS, Alg. 1 line 11).
    fn elicit_answer(&mut self) -> Result<()> {
        let vocab = self.rt.cfg.vocab;
        let force = [vocab.ethink, vocab.final_, vocab.ans];
        let mut logits = self.cur_logits.clone();
        for &t in &force {
            if self.cache.pos >= self.rt.cfg.main.seq_len {
                break;
            }
            logits = self.rt.main.decode(&self.rt.client, &mut self.cache, t)?;
            self.answer_tail.push(t);
        }
        // sample until EOS or a short cap (answers are value + EOS)
        for _ in 0..4 {
            if self.cache.pos >= self.rt.cfg.main.seq_len {
                break;
            }
            let t = self.sampler.sample(&logits, &mut self.rng);
            self.answer_tail.push(t);
            if t == vocab.eos {
                break;
            }
            logits = self.rt.main.decode(&self.rt.client, &mut self.cache, t)?;
        }
        self.phase = Phase::Done;
        Ok(())
    }

    /// Run the session to completion (single-request paths).
    pub fn run(mut self) -> Result<RequestResult> {
        while !self.step()? {}
        Ok(self.finish())
    }

    /// Summarize a finished session.
    pub fn finish(self) -> RequestResult {
        debug_assert_eq!(self.phase, Phase::Done);
        let correct = check_answer(&self.rt.cfg.vocab, &self.question, &self.answer_tail);
        RequestResult {
            question_id: self.question.id,
            exit_reason: self.exit_reason.unwrap_or(ExitReason::TokenBudget),
            reasoning_tokens: self.reasoning_tokens.len(),
            lines: self.line_count,
            probes: self.probes,
            rollout_tokens: self.rollout_tokens,
            answer_tail: self.answer_tail,
            correct,
            wall_ms: self.started.elapsed().as_secs_f64() * 1e3,
        }
    }
}

/// Convenience wrapper: serve one question end-to-end with a policy.
pub fn serve_one(
    rt: &Runtime,
    cfg: &ServeConfig,
    monitor: MonitorModel,
    question: &Question,
    policy: Box<dyn ExitPolicy>,
    seed: u64,
) -> Result<RequestResult> {
    let session = ReasoningSession::new(
        rt,
        cfg.clone(),
        monitor,
        question.clone(),
        policy,
        Rng::new(seed),
    )?;
    session.run()
}
