//! Tokenizer / vocabulary for the chain-sum reasoning task.
//!
//! Loaded from `artifacts/vocab.json`, which python/compile/vocab.py writes
//! at AOT time — the single source of truth, so trained weights and the
//! Rust tokenizer can never drift apart.

use crate::util::json::Json;

/// Maximum sampled answer-tail tokens after the forced suffix (value +
/// EOS, with slack for summarization babble the model may emit first).
pub const ANSWER_SAMPLE_CAP: usize = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Vocab {
    pub pad: u32,
    pub bos: u32,
    pub eos: u32,
    pub think: u32,
    pub ethink: u32,
    pub nl: u32,
    pub final_: u32,
    pub ans: u32,
    pub q: u32,
    pub sep: u32,
    pub ver: u32,
    pub unk: u32,
    pub lbrack: u32,
    pub tool: u32,
    pub num0: u32,
    pub modulus: u32,
    pub size: u32,
}

impl Vocab {
    pub fn from_json(v: &Json) -> anyhow::Result<Vocab> {
        Ok(Vocab {
            pad: v.req_usize("pad")? as u32,
            bos: v.req_usize("bos")? as u32,
            eos: v.req_usize("eos")? as u32,
            think: v.req_usize("think")? as u32,
            ethink: v.req_usize("ethink")? as u32,
            nl: v.req_usize("nl")? as u32,
            final_: v.req_usize("final")? as u32,
            ans: v.req_usize("ans")? as u32,
            q: v.req_usize("q")? as u32,
            sep: v.req_usize("sep")? as u32,
            ver: v.req_usize("ver")? as u32,
            unk: v.req_usize("unk")? as u32,
            lbrack: v.req_usize("lbrack")? as u32,
            tool: v.req_usize("tool")? as u32,
            num0: v.req_usize("num0")? as u32,
            modulus: v.req_usize("mod")? as u32,
            size: v.req_usize("vocab")? as u32,
        })
    }

    /// The layout python/compile/vocab.py defines; used by unit tests and
    /// in-process workload generators that run without artifacts on disk.
    pub fn default_layout() -> Vocab {
        Vocab {
            pad: 0,
            bos: 1,
            eos: 2,
            think: 3,
            ethink: 4,
            nl: 5,
            final_: 6,
            ans: 7,
            q: 8,
            sep: 9,
            ver: 10,
            unk: 11,
            lbrack: 12,
            tool: 13,
            num0: 16,
            modulus: 32,
            size: 48,
        }
    }

    /// Token id of number `v` (mod `modulus`).
    #[inline]
    pub fn num(&self, v: u32) -> u32 {
        self.num0 + (v % self.modulus)
    }

    #[inline]
    pub fn is_num(&self, tok: u32) -> bool {
        tok >= self.num0 && tok < self.num0 + self.modulus
    }

    #[inline]
    pub fn num_value(&self, tok: u32) -> Option<u32> {
        if self.is_num(tok) {
            Some(tok - self.num0)
        } else {
            None
        }
    }

    /// Tokens the engine force-feeds to elicit an answer
    /// (GenTillEoS, Alg. 1 line 11): `</think> Final answer:` + the ANS
    /// marker. Returned by value (no allocation — this sits on the
    /// per-token serving hot path via [`Vocab::answer_reserve`]).
    pub fn forced_answer_tail(&self) -> [u32; 3] {
        [self.ethink, self.final_, self.ans]
    }

    /// Decode positions that must stay free for answer elicitation: the
    /// forced tail plus up to [`ANSWER_SAMPLE_CAP`] sampled answer tokens
    /// (value + EOS + slack). The engine refuses to commit another
    /// reasoning token once headroom drops to this, so a longer forced
    /// suffix can never silently truncate answers.
    pub fn answer_reserve(&self) -> usize {
        self.forced_answer_tail().len() + ANSWER_SAMPLE_CAP
    }

    /// The EAT probe suffixes of the paper (App. D):
    /// Eq. 12 (no prefix string): just `</think>`.
    pub fn suffix_plain(&self) -> Vec<u32> {
        vec![self.ethink]
    }

    /// Eq. 13 (with prefix string "The final answer:"): the probed token is
    /// the answer value itself.
    pub fn suffix_prefixed(&self) -> Vec<u32> {
        vec![self.ethink, self.final_, self.ans]
    }

    /// Eq. 14 (App. F): entropy after a newline, inside the reasoning.
    pub fn suffix_newline(&self) -> Vec<u32> {
        vec![self.nl]
    }

    /// Eq. 15 (App. I.2): tool-calling probe, appending `[` after
    /// `</think>` (here: `</think> FINAL [` then ANS value follows).
    pub fn suffix_tool(&self) -> Vec<u32> {
        vec![self.ethink, self.final_, self.lbrack, self.ans]
    }

    /// Render a token sequence for logs / examples.
    pub fn detok(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| self.tok_str(t))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn tok_str(&self, t: u32) -> String {
        if let Some(v) = self.num_value(t) {
            return v.to_string();
        }
        match t {
            x if x == self.pad => "<pad>".into(),
            x if x == self.bos => "<bos>".into(),
            x if x == self.eos => "<eos>".into(),
            x if x == self.think => "<think>".into(),
            x if x == self.ethink => "</think>".into(),
            x if x == self.nl => "⏎".into(),
            x if x == self.final_ => "Final:".into(),
            x if x == self.ans => "A".into(),
            x if x == self.q => "Q".into(),
            x if x == self.sep => ";".into(),
            x if x == self.ver => "V".into(),
            x if x == self.unk => "?".into(),
            x if x == self.lbrack => "[".into(),
            x if x == self.tool => "T".into(),
            x => format!("<{x}>"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn default_layout_roundtrips_through_json() {
        let v = Vocab::default_layout();
        let js = format!(
            r#"{{"pad":0,"bos":1,"eos":2,"think":3,"ethink":4,"nl":5,
                "final":6,"ans":7,"q":8,"sep":9,"ver":10,"unk":11,
                "lbrack":12,"tool":13,"num0":16,"mod":32,"vocab":48}}"#
        );
        let parsed = Vocab::from_json(&json::parse(&js).unwrap()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn num_mapping() {
        let v = Vocab::default_layout();
        assert_eq!(v.num(0), 16);
        assert_eq!(v.num(31), 47);
        assert_eq!(v.num(33), 17); // wraps mod 32
        assert_eq!(v.num_value(16), Some(0));
        assert_eq!(v.num_value(5), None);
        assert!(v.is_num(47));
        assert!(!v.is_num(48));
    }

    #[test]
    fn probe_suffixes() {
        let v = Vocab::default_layout();
        assert_eq!(v.suffix_plain(), vec![v.ethink]);
        assert_eq!(v.suffix_prefixed(), vec![v.ethink, v.final_, v.ans]);
        assert_eq!(v.suffix_newline(), vec![v.nl]);
        assert!(v.suffix_prefixed().len() <= 4); // must fit probe_len
    }

    #[test]
    fn answer_reserve_covers_forced_tail_and_sampling() {
        let v = Vocab::default_layout();
        assert_eq!(v.forced_answer_tail(), [v.ethink, v.final_, v.ans]);
        // the reserve must cover every decode the elicitation path can
        // issue: each forced token plus each sampled (non-EOS) token
        assert_eq!(
            v.answer_reserve(),
            v.forced_answer_tail().len() + ANSWER_SAMPLE_CAP
        );
        // a minimal full answer (forced tail + value + EOS) always fits
        assert!(v.answer_reserve() >= v.forced_answer_tail().len() + 2);
    }

    #[test]
    fn detok_readable() {
        let v = Vocab::default_layout();
        let s = v.detok(&[v.bos, v.q, v.num(3), v.num(7), v.sep]);
        assert_eq!(s, "<bos> Q 3 7 ;");
    }
}
