//! Answer extraction + correctness checking.
//!
//! The paper checks Math500/AIME answers with SymPy equivalence and GPQA
//! with an LLM judge; our synthetic task has a unique single-token answer
//! so equivalence is exact token identity (DESIGN.md §1). The extraction
//! logic still has to parse the model's generated answer tail, which (as
//! in the paper, §5.1) may include summarization tokens before the value.

use super::chainsum::Question;
use crate::vocab::Vocab;

/// Extract the answer value from a generated answer-tail token sequence:
/// the first number token after the ANS marker, or — fallback — the first
/// number token at all (the model "does not necessarily always start with
/// boxed{}", §5.1).
pub fn extract_answer(vocab: &Vocab, tail: &[u32]) -> Option<u32> {
    let mut after_ans = false;
    for &t in tail {
        if t == vocab.ans {
            after_ans = true;
            continue;
        }
        if after_ans {
            if let Some(v) = vocab.num_value(t) {
                return Some(v);
            }
            if t == vocab.eos {
                break;
            }
        }
    }
    // fallback: first number anywhere in the tail
    tail.iter().find_map(|&t| vocab.num_value(t))
}

/// Is the generated tail a correct answer to the question?
/// Unsolvable questions are never "correct" (the paper filters them or
/// reports them separately — Fig. 20 / App. I.4).
pub fn check_answer(vocab: &Vocab, q: &Question, tail: &[u32]) -> bool {
    match (q.answer, extract_answer(vocab, tail)) {
        (Some(want), Some(got)) => want == got,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::chainsum::{Dataset, Kind};

    fn v() -> Vocab {
        Vocab::default_layout()
    }

    fn q_with_answer(ans: u32) -> Question {
        Question {
            id: 0,
            kind: Kind::ChainSum,
            ops: vec![ans],
            corrupt_at: None,
            prompt: vec![],
            answer: Some(ans),
        }
    }

    #[test]
    fn extracts_after_ans_marker() {
        let vb = v();
        let tail = vec![vb.final_, vb.ans, vb.num(13), vb.eos];
        assert_eq!(extract_answer(&vb, &tail), Some(13));
    }

    #[test]
    fn extraction_skips_non_numbers() {
        let vb = v();
        // model babbles a VER marker after ANS before the value
        let tail = vec![vb.ans, vb.ver, vb.num(7), vb.eos];
        assert_eq!(extract_answer(&vb, &tail), Some(7));
    }

    #[test]
    fn fallback_first_number() {
        let vb = v();
        // malformed tail without ANS marker
        let tail = vec![vb.final_, vb.num(21), vb.eos];
        assert_eq!(extract_answer(&vb, &tail), Some(21));
    }

    #[test]
    fn no_number_is_none() {
        let vb = v();
        assert_eq!(extract_answer(&vb, &[vb.final_, vb.eos]), None);
    }

    #[test]
    fn check_correct_and_incorrect() {
        let vb = v();
        let q = q_with_answer(5);
        assert!(check_answer(&vb, &q, &[vb.ans, vb.num(5), vb.eos]));
        assert!(!check_answer(&vb, &q, &[vb.ans, vb.num(6), vb.eos]));
        assert!(!check_answer(&vb, &q, &[vb.eos]));
    }

    #[test]
    fn unsolvable_never_correct() {
        let vb = v();
        let ds = Dataset::synth_gpqa(&vb, 50, 0);
        let q = ds
            .questions
            .iter()
            .find(|q| q.kind == Kind::Corrupted)
            .unwrap();
        // even if the model emits some number, it cannot be "correct"
        assert!(!check_answer(&vb, q, &[vb.ans, vb.num(3), vb.eos]));
    }
}
