//! Benchmark datasets: synthetic analogues of the paper's MATH-500,
//! AIME-2025 and GPQA-Diamond (DESIGN.md §1 substitution table), plus the
//! tool-calling subset (App. I.2).

pub mod answer;
pub mod chainsum;

pub use answer::check_answer;
pub use chainsum::{Dataset, Question};
