//! Chain-sum question generators.
//!
//! Difficulty is the operand count n (the model must execute n sequential
//! additions). The benchmark analogues:
//!
//! | name            | paper benchmark | composition                        |
//! |-----------------|-----------------|------------------------------------|
//! | `synth-math500` | MATH-500        | 500 questions, n in 2..=6          |
//! | `synth-aime`    | AIME-2025       | 30 questions, n in 7..=10          |
//! | `synth-gpqa`    | GPQA-Diamond    | 100 questions, n in 3..=10, 25%    |
//! |                 |                 | corrupted (unsolvable) + 10% OOD   |
//! |                 |                 | length (n in 11..=12)              |
//! | `synth-tool`    | BFCL subset     | 100 copy-task questions (I.2)      |

use crate::util::rng::Rng;
use crate::vocab::Vocab;

/// Question category, determining evaluation handling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Standard chain-sum.
    ChainSum,
    /// An operand is masked with UNK: unsolvable, answer undeterminable.
    Corrupted,
    /// Chain longer than the training distribution (n > 10): the model
    /// degrades — the paper's "decreasing Pass@1" error class (Fig. 15).
    OutOfDistribution,
    /// Tool-calling copy task (answer = last operand; reasoning optional).
    ToolCall,
}

#[derive(Debug, Clone)]
pub struct Question {
    pub id: usize,
    pub kind: Kind,
    /// Operand values (the UNK position holds the original value for
    /// bookkeeping; it is masked in `prompt`).
    pub ops: Vec<u32>,
    pub corrupt_at: Option<usize>,
    /// Prompt token sequence: `BOS Q a_1 .. a_n SEP` (+THINK appended by
    /// the engine).
    pub prompt: Vec<u32>,
    /// Ground-truth answer value; None when unsolvable.
    pub answer: Option<u32>,
}

impl Question {
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn solvable(&self) -> bool {
        self.answer.is_some()
    }
}

#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub questions: Vec<Question>,
}

fn make_question(
    vocab: &Vocab,
    rng: &mut Rng,
    id: usize,
    n: usize,
    kind: Kind,
) -> Question {
    let ops: Vec<u32> = (0..n).map(|_| rng.below(vocab.modulus as u64) as u32).collect();
    let corrupt_at = if kind == Kind::Corrupted {
        Some(rng.below(n as u64) as usize)
    } else {
        None
    };
    let marker = if kind == Kind::ToolCall { vocab.tool } else { vocab.q };
    let mut prompt = vec![vocab.bos, marker];
    for (i, &a) in ops.iter().enumerate() {
        prompt.push(if corrupt_at == Some(i) {
            vocab.unk
        } else {
            vocab.num(a)
        });
    }
    prompt.push(vocab.sep);
    let answer = match kind {
        Kind::Corrupted => None,
        Kind::ToolCall => Some(ops[n - 1]),
        _ => Some(ops.iter().sum::<u32>() % vocab.modulus),
    };
    Question {
        id,
        kind,
        ops,
        corrupt_at,
        prompt,
        answer,
    }
}

impl Dataset {
    /// MATH-500 analogue: heavy-tailed difficulty (most questions easy, a
    /// long tail of hard ones), all solvable. The tail is what makes
    /// adaptive budgets pay off — a fixed budget must cover the rare hard
    /// questions and therefore wastes tokens on the easy majority, exactly
    /// the paper's §1 argument.
    pub fn synth_math500(vocab: &Vocab, size: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x4d415448);
        let questions = (0..size)
            .map(|id| {
                let roll = rng.f64();
                let n = if roll < 0.6 {
                    rng.range(2, 4) // easy majority
                } else if roll < 0.9 {
                    rng.range(5, 7) // medium
                } else {
                    rng.range(8, 10) // hard tail
                } as usize;
                make_question(vocab, &mut rng, id, n, Kind::ChainSum)
            })
            .collect();
        Dataset {
            name: "synth-math500".into(),
            questions,
        }
    }

    /// AIME-2025 analogue: hard, long chains, all solvable.
    pub fn synth_aime(vocab: &Vocab, size: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x41494d45);
        let questions = (0..size)
            .map(|id| {
                let n = rng.range(6, 10) as usize;
                make_question(vocab, &mut rng, id, n, Kind::ChainSum)
            })
            .collect();
        Dataset {
            name: "synth-aime".into(),
            questions,
        }
    }

    /// GPQA-Diamond analogue: mixed difficulty with unsolvable (corrupted)
    /// and out-of-distribution instances — the error-analysis benchmark.
    pub fn synth_gpqa(vocab: &Vocab, size: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x47505141);
        let questions = (0..size)
            .map(|id| {
                let roll = rng.f64();
                if roll < 0.25 {
                    let n = rng.range(3, 10) as usize;
                    make_question(vocab, &mut rng, id, n, Kind::Corrupted)
                } else if roll < 0.35 {
                    let n = rng.range(11, 12) as usize;
                    make_question(vocab, &mut rng, id, n, Kind::OutOfDistribution)
                } else {
                    let n = rng.range(3, 10) as usize;
                    make_question(vocab, &mut rng, id, n, Kind::ChainSum)
                }
            })
            .collect();
        Dataset {
            name: "synth-gpqa".into(),
            questions,
        }
    }

    /// Tool-calling subset analogue (App. I.2).
    pub fn synth_tool(vocab: &Vocab, size: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x544f4f4c);
        let questions = (0..size)
            .map(|id| {
                let n = rng.range(2, 6) as usize;
                make_question(vocab, &mut rng, id, n, Kind::ToolCall)
            })
            .collect();
        Dataset {
            name: "synth-tool".into(),
            questions,
        }
    }

    /// Dataset registry used by the CLI.
    pub fn by_name(name: &str, vocab: &Vocab, seed: u64) -> anyhow::Result<Dataset> {
        Ok(match name {
            "synth-math500" => Dataset::synth_math500(vocab, 500, seed),
            "synth-math500-small" => Dataset::synth_math500(vocab, 60, seed),
            "synth-aime" => Dataset::synth_aime(vocab, 30, seed),
            "synth-gpqa" => Dataset::synth_gpqa(vocab, 100, seed),
            "synth-gpqa-small" => Dataset::synth_gpqa(vocab, 40, seed),
            "synth-tool" => Dataset::synth_tool(vocab, 100, seed),
            other => anyhow::bail!(
                "unknown dataset `{other}` (synth-math500[-small], \
                 synth-aime, synth-gpqa[-small], synth-tool)"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v() -> Vocab {
        Vocab::default_layout()
    }

    #[test]
    fn math500_all_solvable_with_correct_answers() {
        let ds = Dataset::synth_math500(&v(), 200, 0);
        assert_eq!(ds.questions.len(), 200);
        for q in &ds.questions {
            assert!(q.solvable());
            assert!((2..=10).contains(&q.n_ops()));
            let want = q.ops.iter().sum::<u32>() % v().modulus;
            assert_eq!(q.answer, Some(want));
        }
        // heavy tail: easy majority, rare hard questions
        let easy = ds.questions.iter().filter(|q| q.n_ops() <= 4).count();
        let hard = ds.questions.iter().filter(|q| q.n_ops() >= 8).count();
        assert!(easy > 90, "easy={easy}");
        assert!(hard > 5 && hard < 50, "hard={hard}");
    }

    #[test]
    fn aime_is_harder() {
        let ds = Dataset::synth_aime(&v(), 30, 0);
        for q in &ds.questions {
            assert!((6..=10).contains(&q.n_ops()));
        }
    }

    #[test]
    fn gpqa_has_unsolvable_and_ood() {
        let ds = Dataset::synth_gpqa(&v(), 200, 0);
        let corrupted = ds
            .questions
            .iter()
            .filter(|q| q.kind == Kind::Corrupted)
            .count();
        let ood = ds
            .questions
            .iter()
            .filter(|q| q.kind == Kind::OutOfDistribution)
            .count();
        assert!(corrupted > 20, "corrupted={corrupted}");
        assert!(ood > 5, "ood={ood}");
        for q in &ds.questions {
            match q.kind {
                Kind::Corrupted => {
                    assert!(!q.solvable());
                    // prompt contains the UNK mask
                    assert!(q.prompt.contains(&v().unk));
                }
                Kind::OutOfDistribution => assert!(q.n_ops() >= 11),
                _ => assert!(q.solvable()),
            }
        }
    }

    #[test]
    fn prompt_structure() {
        let ds = Dataset::synth_math500(&v(), 5, 3);
        for q in &ds.questions {
            assert_eq!(q.prompt[0], v().bos);
            assert_eq!(q.prompt[1], v().q);
            assert_eq!(*q.prompt.last().unwrap(), v().sep);
            assert_eq!(q.prompt.len(), q.n_ops() + 3);
        }
    }

    #[test]
    fn tool_answer_is_last_operand() {
        let ds = Dataset::synth_tool(&v(), 20, 1);
        for q in &ds.questions {
            assert_eq!(q.prompt[1], v().tool);
            assert_eq!(q.answer, Some(*q.ops.last().unwrap()));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Dataset::synth_math500(&v(), 10, 42);
        let b = Dataset::synth_math500(&v(), 10, 42);
        for (qa, qb) in a.questions.iter().zip(&b.questions) {
            assert_eq!(qa.ops, qb.ops);
        }
        let c = Dataset::synth_math500(&v(), 10, 43);
        assert!(a.questions.iter().zip(&c.questions).any(|(x, y)| x.ops != y.ops));
    }

    #[test]
    fn registry() {
        assert!(Dataset::by_name("synth-aime", &v(), 0).is_ok());
        assert!(Dataset::by_name("nope", &v(), 0).is_err());
    }
}
