//! `repro` — the leader CLI of the EAT serving stack.
//!
//! Subcommands:
//!   info                         backend + model summary
//!   serve                        continuous-batch serving of a workload
//!   trace                        generate monitored reasoning traces
//!   figures                      reproduce the paper's figures
//!   blackbox                     black-box streaming demo (Fig. 5)
//!   soak                         million-session scheduling soak
//!
//! Every live command loads the AOT artifacts when present (feature
//! `pjrt` + `make artifacts`) and otherwise falls back to the
//! deterministic in-process reference backend, so the whole CLI works in
//! a clean checkout.

use anyhow::{Context, Result};

use eat_serve::blackbox::{
    BlackboxBatcher, BlackboxConfig, LatencyModel, ProxyCostModel, CHUNK_MONITOR_ALPHA,
    CHUNK_MONITOR_DELTA,
};
use eat_serve::config::{OverloadPolicy, SchedMode, ServeConfig};
use eat_serve::coordinator::{
    build_arrivals, run_open_loop_stream, run_soak, zoo_policy_factory, Batcher, Cluster,
    ClusterConfig, MetricsReport, MonitorModel, PolicyFactory, RoutePolicy, SoakConfig,
    SoakMode, DEFAULT_TICK_DT,
};
use eat_serve::datasets::Dataset;
use eat_serve::eval::figures::{self, FigureCtx};
use eat_serve::eval::{run_zoo, zoo_report_json, TraceGen, TraceSet, ZooConfig};
use eat_serve::exit::EatPolicy;
use eat_serve::runtime::{Backend, Runtime};
use eat_serve::util::cli::{
    render_flags, Args, ArrivalSpec, ServeArgs, ServeMode, SERVE_BLACKBOX_FLAGS,
    SERVE_CLUSTER_FLAGS, SERVE_ENGINE_FLAGS, SERVE_SHARED_FLAGS, SOAK_FLAGS,
};
use eat_serve::util::clock::Clock;
use eat_serve::util::stats::DEFAULT_SUMMARY_CAP;

fn usage() -> ! {
    // the serve flag sections are generated from the FlagSpec tables in
    // util/cli.rs, so this text cannot drift from the accepted flags
    eprintln!(
        "repro — EAT early-exit reasoning serving (paper reproduction)

USAGE: repro <command> [flags]

COMMANDS
  info                          backend inventory + smoke execution
  serve [single]                continuous-batch serving, one engine
  serve cluster                 N engine replicas behind the EAT-aware
                                router with KV-page session migration
  serve blackbox                black-box streams: remote main model
                                behind a text-only chunked API, local
                                proxy monitor issues the stop
                                (legacy spellings unchanged: bare
                                 `serve` = single, `serve --blackbox`
                                 = blackbox)
  trace     --dataset D [--out FILE] [--max-questions N] [--swap-models]
            [--no-confidence] [--seed K]
  sweep-zoo [--traces FILE | --dataset D --questions N] [--iso-frac F]
            [--out FILE]  race every exit-policy family (EAT, token,
            #UA@K, confidence, path-dev, seq-entropy, cum-entropy,
            consistency + combinators) over one trace set; prints the
            per-family Pareto table, writes sorted-key JSON with --out
  figures   --fig N|all  [--traces-dir DIR] [--out-dir DIR]
  blackbox  [--questions N] [--chunk C] [--delta X]
  soak      million-session scheduling soak on the event wheel + slab
            arena (DESIGN.md §3.10); virtual-time, deterministic,
            memory-bounded
  bench-diff BASE NEW [--tol X]  compare BENCH_*.json snapshots (two
            files, or two dirs matched by file name); exits non-zero
            when a bench's mean slows past 1+tol (default tol 1.0)

SERVE FLAGS (all modes)
{shared}
SERVE FLAGS (single, cluster)
{engine}
SERVE FLAGS (cluster)
{cluster}
SERVE FLAGS (blackbox)
{blackbox}
SOAK FLAGS
{soak}
FLAG DEFAULTS
  --artifacts artifacts   --traces-dir results/traces   --out-dir results
  --alpha 0.2  --delta 1e-3  --budget 96  (blackbox: --alpha 0.8
  --delta 5e-2)
  (--rate R > 0 drives open-loop arrivals shaped by --arrivals
   poisson|burst|diurnal|trace:PATH; with --virtual the
   run is simulated on a virtual clock and fully seed-deterministic.
   --kv-store mono keeps the monolithic full-sequence store — the
   equivalence oracle: same seed, byte-identical metrics JSON)
",
        shared = render_flags("  ", SERVE_SHARED_FLAGS),
        engine = render_flags("  ", SERVE_ENGINE_FLAGS),
        cluster = render_flags("  ", SERVE_CLUSTER_FLAGS),
        blackbox = render_flags("  ", SERVE_BLACKBOX_FLAGS),
        soak = render_flags("  ", SOAK_FLAGS),
    );
    std::process::exit(2);
}

fn serve_cfg(args: &Args) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.alpha = args.f64_or("alpha", cfg.alpha);
    cfg.delta = args.f64_or("delta", cfg.delta);
    cfg.max_think_tokens = args.usize_or("budget", cfg.max_think_tokens);
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.prefixed_probe = !args.has("no-prefix");
    cfg.kv_pages = args.usize_opt("kv-pages");
    cfg
}

/// KV store selection: `Some(page_size)` = paged (the default), `None`
/// = monolithic full-sequence caches (the equivalence oracle). Paged
/// tuning flags combined with the monolithic store are rejected rather
/// than silently ignored.
fn kv_page_size(args: &Args) -> Result<Option<usize>> {
    match args.str_or("kv-store", "paged") {
        "paged" => Ok(Some(args.usize_or(
            "page-size",
            eat_serve::coordinator::DEFAULT_PAGE_SIZE,
        ))),
        "mono" | "monolithic" => {
            anyhow::ensure!(
                !args.has("page-size"),
                "--page-size applies to the paged store (drop it, or use --kv-store paged)"
            );
            Ok(None)
        }
        other => anyhow::bail!("unknown --kv-store `{other}` (paged|mono)"),
    }
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    load_runtime_with(args, kv_page_size(args)?)
}

fn load_runtime_with(args: &Args, page_size: Option<usize>) -> Result<Runtime> {
    let dir = args.str_or("artifacts", eat_serve::DEFAULT_ARTIFACTS);
    Ok(Runtime::load_or_reference_with(dir, page_size))
}

/// Scheduler flags shared by `serve single` and `serve cluster`.
fn sched_from_args(args: &Args, cfg: &mut ServeConfig) -> Result<()> {
    cfg.sched.mode = match args.str_or("sched", "fifo") {
        "fifo" => SchedMode::Fifo,
        "eat" | "eat-aware" => SchedMode::EatAware,
        other => anyhow::bail!("unknown --sched `{other}` (fifo|eat)"),
    };
    cfg.sched.deadline_s = args.f64_or("deadline", cfg.sched.deadline_s);
    cfg.sched.overload = OverloadPolicy::from_flag(args.str_or("shed", "none"))?;
    Ok(())
}

/// Exit-policy factory shared by `serve single` and `serve cluster`
/// (the cluster mints one per replica): any zoo family runs online.
fn policy_from_args(args: &Args, cfg: &ServeConfig) -> Result<PolicyFactory> {
    zoo_policy_factory(args.str_or("policy", "eat"), cfg)
}

/// Paged store selection + tuning-flag validation shared by every
/// engine-serving mode: a mono "page" is a whole full-sequence cache,
/// so a page count is not comparable across stores — refuse the mix
/// rather than gate admission on silently different budgets.
fn engine_runtime(args: &Args) -> Result<Runtime> {
    let page_size = kv_page_size(args)?;
    if args.has("kv-pages") && page_size.is_none() {
        anyhow::bail!("--kv-pages applies to the paged store (drop it, or use --kv-store paged)");
    }
    load_runtime_with(args, page_size)
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    println!("backend         {}", rt.backend_kind());
    for b in [&rt.main, &rt.proxy] {
        println!("model {}", b.describe());
    }
    // smoke: answer one easy question
    let ds = Dataset::synth_math500(&rt.vocab, 1, 0);
    let q = &ds.questions[0];
    let res = eat_serve::coordinator::serve_one(
        &rt,
        &ServeConfig::default(),
        MonitorModel::SelfModel,
        q,
        Box::new(EatPolicy::new(0.2, 1e-3, 96)),
        0,
    )?;
    println!(
        "smoke           q0 ops={:?} answer={:?} -> correct={} ({} reasoning tokens, {:?})",
        q.ops, q.answer, res.correct, res.reasoning_tokens, res.exit_reason
    );
    let c = rt.main.counters();
    println!(
        "exec counters   prefills={} decodes={} probes={} batch_decodes={}",
        c.prefills.get(),
        c.decodes.get(),
        c.probes.get(),
        c.batch_decodes.get()
    );
    if args.has("hlo") {
        match &rt.artifacts {
            Some(art) => {
                println!("\nHLO cost analysis (L2 perf, DESIGN.md \u{a7}6):");
                for m in [&art.main, &art.proxy] {
                    for f in [&m.hlo_prefill, &m.hlo_decode, &m.hlo_probe] {
                        let rep = eat_serve::runtime::hlo_analysis::analyze_file(
                            &art.path(f),
                        )?;
                        print!("{}", rep.render(f));
                    }
                }
            }
            None => println!("\n(--hlo needs the AOT artifacts; reference backend active)"),
        }
    }
    Ok(())
}

/// Black-box serving (DESIGN.md §3.6): many proxy-monitored remote
/// streams batched through the coordinator. Deterministic under
/// `--virtual` — CI double-runs this and diffs the metrics JSON.
fn cmd_serve_blackbox(args: &Args, serve: &ServeArgs) -> Result<()> {
    let rt = engine_runtime(args)?;
    let mut cfg = serve_cfg(args);
    cfg.alpha = args.f64_or("alpha", CHUNK_MONITOR_ALPHA);
    cfg.delta = args.f64_or("delta", CHUNK_MONITOR_DELTA);
    let defaults = LatencyModel::default();
    let bb = BlackboxConfig {
        chunk_tokens: args.usize_or("chunk", 12),
        latency: LatencyModel {
            base_ms: args.f64_or("base-ms", defaults.base_ms),
            per_token_ms: args.f64_or("tok-ms", defaults.per_token_ms),
            jitter: args.f64_or("jitter", defaults.jitter),
        },
        proxy_cost: ProxyCostModel::default(),
    };
    let slots = serve.slots;
    let ds = Dataset::by_name(&serve.dataset, &rt.vocab, cfg.seed)?;
    let clock = if serve.virtual_clock {
        Clock::virt()
    } else {
        Clock::wall()
    };
    let seed = cfg.seed;
    let mut batcher = BlackboxBatcher::with_clock(&rt, cfg, bb, slots, clock);
    batcher.force_sequential = serve.sequential;
    if serve.rate > 0.0 {
        let mut process = build_arrivals(&serve.arrivals, serve.rate, seed)?;
        run_open_loop_stream(
            &mut batcher,
            &ds.questions,
            process.as_mut(),
            serve.requests,
            DEFAULT_TICK_DT,
            1,
        )?;
    } else {
        for q in ds.questions.iter().take(serve.requests) {
            batcher.submit(q.clone());
        }
        batcher.run_to_completion()?;
    }
    println!("{}", batcher.metrics.report());
    println!("kv slots        peak {} / {}", batcher.kv_peak(), slots);
    let (mc, pc) = (rt.main.counters(), rt.proxy.counters());
    let (ms, ps) = (batcher.main_store_counters(), batcher.proxy_store_counters());
    println!(
        "remote lanes    fused_calls {}  lanes {}  dirty uploads {}  single decodes {}",
        ms.fused_calls,
        mc.batch_lanes.get(),
        ms.dirty_lane_uploads,
        mc.decodes.get()
    );
    println!(
        "proxy lanes     fused_calls {}  lanes {}  dirty uploads {}  single decodes {}  probes {}",
        ps.fused_calls,
        pc.batch_lanes.get(),
        ps.dirty_lane_uploads,
        pc.decodes.get(),
        pc.probes.get()
    );
    if let Some(path) = &serve.metrics_json {
        std::fs::write(path, batcher.metrics.to_json().to_string())?;
        println!("metrics json    {path}");
    }
    Ok(())
}

/// `serve` dispatcher: the mode word (`single`/`cluster`/`blackbox`)
/// picks the engine; legacy spellings (`serve`, `serve --blackbox`)
/// resolve through [`ServeMode::from_args`] unchanged.
fn cmd_serve(args: &Args) -> Result<()> {
    let serve = ServeArgs::parse(args)?;
    match serve.mode {
        ServeMode::Single => cmd_serve_single(args, &serve),
        ServeMode::Cluster => cmd_serve_cluster(args, &serve),
        ServeMode::Blackbox => cmd_serve_blackbox(args, &serve),
    }
}

fn cmd_serve_single(args: &Args, serve: &ServeArgs) -> Result<()> {
    let rt = engine_runtime(args)?;
    let mut cfg = serve_cfg(args);
    sched_from_args(args, &mut cfg)?;
    let slots = serve.slots;
    let monitor = if args.has("proxy") {
        MonitorModel::Proxy
    } else {
        MonitorModel::SelfModel
    };
    let ds = Dataset::by_name(&serve.dataset, &rt.vocab, cfg.seed)?;
    let factory = policy_from_args(args, &cfg)?;
    let clock = if serve.virtual_clock {
        Clock::virt()
    } else {
        Clock::wall()
    };
    let seed = cfg.seed;
    let mut batcher = Batcher::with_clock(&rt, cfg, monitor, slots, factory, clock);
    batcher.force_sequential = serve.sequential;
    if serve.rate > 0.0 {
        // open-loop arrivals from the --arrivals process at `rate`
        // req/s (deterministic under --virtual: the whole run is a
        // pure function of the seed), fanned over --tenants round-robin
        let mut process = build_arrivals(&serve.arrivals, serve.rate, seed)?;
        run_open_loop_stream(
            &mut batcher,
            &ds.questions,
            process.as_mut(),
            serve.requests,
            DEFAULT_TICK_DT,
            serve.tenants,
        )?;
    } else {
        for q in ds.questions.iter().take(serve.requests) {
            batcher.submit(q.clone());
        }
        batcher.run_to_completion()?;
    }
    println!("{}", batcher.metrics.report());
    println!("kv slots        peak {} / {}", batcher.kv_peak(), slots);
    let kvp = batcher.kv_pages();
    println!(
        "kv pages        size {} tok  reserve {}/session  peak pinned {} / {}  suspended-held {}",
        kvp.page_size(),
        kvp.reserve_pages(),
        kvp.peak_pinned_pages(),
        kvp.device_capacity_pages(),
        kvp.host_held_pages()
    );
    let sc = batcher.store_counters();
    let mc = rt.main.counters();
    println!(
        "batch decode    fused_calls {}  lanes {} (resident {})  dirty uploads {} ({} pages)  single decodes {}",
        mc.batch_decodes.get(),
        mc.batch_lanes.get(),
        mc.batch_resident_lanes.get(),
        sc.dirty_lane_uploads,
        sc.dirty_page_uploads,
        mc.decodes.get()
    );
    println!(
        "paged kv        cow_forks {}  pages_shared {}  pages_copied {}  prefills {}",
        mc.cow_forks.get(),
        mc.pages_shared.get(),
        mc.pages_copied.get(),
        mc.prefills.get()
    );
    println!(
        "tick scratch    ticks {}  allocs {}  allocs/tick {:.4}",
        mc.sched_ticks.get(),
        mc.sched_allocs.get(),
        mc.sched_allocs.get() as f64 / mc.sched_ticks.get().max(1) as f64
    );
    if let Some(path) = &serve.metrics_json {
        std::fs::write(path, batcher.metrics.to_json().to_string())?;
        println!("metrics json    {path}");
    }
    Ok(())
}

/// `serve cluster` (DESIGN.md §3.7): N engine replicas over the one
/// runtime behind the EAT-aware router, with optional live session
/// migration as a KV-page handoff. Deterministic under `--virtual` —
/// CI double-runs N=3 and diffs the metrics JSON byte-for-byte, and
/// diffs `cluster --replicas 1` per-replica metrics against `single`.
fn cmd_serve_cluster(args: &Args, serve: &ServeArgs) -> Result<()> {
    let rt = engine_runtime(args)?;
    let mut cfg = serve_cfg(args);
    sched_from_args(args, &mut cfg)?;
    let route = match serve.route.as_str() {
        "eat" => RoutePolicy::EatAware,
        "rr" | "round-robin" => RoutePolicy::RoundRobin,
        other => anyhow::bail!("unknown --route `{other}` (eat|rr)"),
    };
    let monitor = if args.has("proxy") {
        MonitorModel::Proxy
    } else {
        MonitorModel::SelfModel
    };
    let ds = Dataset::by_name(&serve.dataset, &rt.vocab, cfg.seed)?;
    let cluster_cfg = ClusterConfig {
        replicas: serve.replicas,
        slots_per_replica: serve.slots,
        route,
        migrate: serve.migrate,
    };
    let factories = (0..serve.replicas)
        .map(|_| policy_from_args(args, &cfg))
        .collect::<Result<Vec<_>>>()?;
    let clock = if serve.virtual_clock {
        Clock::virt()
    } else {
        Clock::wall()
    };
    let seed = cfg.seed;
    let mut cluster = Cluster::with_clock(&rt, cfg, monitor, cluster_cfg, factories, clock);
    cluster.set_force_sequential(serve.sequential);
    if serve.rate > 0.0 {
        let mut process = build_arrivals(&serve.arrivals, serve.rate, seed)?;
        run_open_loop_stream(
            &mut cluster,
            &ds.questions,
            process.as_mut(),
            serve.requests,
            DEFAULT_TICK_DT,
            serve.tenants,
        )?;
    } else {
        for q in ds.questions.iter().take(serve.requests) {
            cluster.submit(q.clone());
        }
        cluster.run_to_completion()?;
    }
    let metrics = cluster.metrics();
    println!("{}", metrics.report());
    let mc = rt.main.counters();
    println!(
        "paged kv        cow_forks {}  pages_shared {}  pages_copied {}  prefills {}",
        mc.cow_forks.get(),
        mc.pages_shared.get(),
        mc.pages_copied.get(),
        mc.prefills.get()
    );
    if let Some(path) = &serve.metrics_json {
        std::fs::write(path, metrics.to_json().to_string())?;
        println!("metrics json    {path}");
    }
    if let Some(prefix) = &serve.replica_metrics_json {
        for id in 0..cluster.replica_count() {
            let path = format!("{prefix}.{id}.json");
            let json = cluster.replica(id).metrics.to_json().to_string();
            std::fs::write(&path, json)?;
            println!("replica json    {path}");
        }
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let cfg = serve_cfg(args);
    let dataset = args.str_or("dataset", "synth-math500");
    let swap = args.has("swap-models");
    let default_name = if swap {
        format!("{dataset}-proxyreason")
    } else {
        dataset.to_string()
    };
    let out = args
        .str_opt("out")
        .map(|s| s.to_string())
        .unwrap_or(format!("{}/{}.json", eat_serve::DEFAULT_TRACES, default_name));
    let ds = Dataset::by_name(dataset, &rt.vocab, cfg.seed)?;
    let maxq = args.usize_or("max-questions", ds.questions.len());

    let mut tracegen = TraceGen::new(&rt, cfg.clone());
    tracegen.swap_models = swap;
    tracegen.with_confidence = !args.has("no-confidence");
    let t0 = std::time::Instant::now();
    let mut traces = Vec::new();
    for (i, q) in ds.questions.iter().take(maxq).enumerate() {
        traces.push(tracegen.run(q, cfg.seed)?);
        if (i + 1) % 25 == 0 {
            println!(
                "  {}/{} traces ({:.1}s)",
                i + 1,
                maxq.min(ds.questions.len()),
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let set = TraceSet {
        dataset: default_name.clone(),
        traces,
    };
    set.save(std::path::Path::new(&out))?;
    println!(
        "wrote {} traces to {out} in {:.1}s",
        set.traces.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `sweep-zoo`: the exit-policy Pareto race (DESIGN.md §3.9). Loads a
/// recorded trace set with `--traces`, otherwise generates seeded
/// chainsum traces on the fly (deterministic on the reference backend —
/// CI double-runs this and diffs the JSON byte-for-byte).
fn cmd_sweep_zoo(args: &Args) -> Result<()> {
    let cfg = serve_cfg(args);
    let traces = match args.str_opt("traces") {
        Some(path) => TraceSet::load(std::path::Path::new(path))?,
        None => {
            let rt = load_runtime(args)?;
            let ds = Dataset::by_name(
                args.str_or("dataset", "synth-math500-small"),
                &rt.vocab,
                cfg.seed,
            )?;
            let n = args.usize_or("questions", 24).min(ds.questions.len());
            let mut tracegen = TraceGen::new(&rt, cfg.clone());
            let mut traces = Vec::new();
            for q in ds.questions.iter().take(n) {
                traces.push(tracegen.run(q, cfg.seed)?);
            }
            TraceSet {
                dataset: ds.name.clone(),
                traces,
            }
        }
    };
    anyhow::ensure!(!traces.traces.is_empty(), "no traces to sweep");

    let zc = ZooConfig {
        alpha: cfg.alpha,
        iso_frac: args.f64_or("iso-frac", 0.98),
        ..ZooConfig::default()
    };
    let report = run_zoo(&traces, &zc);

    println!(
        "zoo over {} traces ({})  iso-accuracy {:.3}  frontier eps {:.0} tokens",
        report.n_traces, report.dataset, report.iso_accuracy, report.eps_tokens
    );
    println!(
        "{:<22} {:>8} {:>8} {:>10} {:>10} {:>8} {:>9}  {}",
        "family", "auc", "auc+ovh", "iso-tok", "iso+ovh", "save%", "exit-line", "frontier"
    );
    let fmt_opt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.0}"));
    for f in &report.families {
        println!(
            "{:<22} {:>8.4} {:>8.4} {:>10} {:>10} {:>8} {:>9.1}  {}",
            f.family,
            f.auc_raw,
            f.auc_charged,
            fmt_opt(f.iso_tokens_raw),
            fmt_opt(f.iso_tokens_charged),
            f.saving_vs_token_pct
                .map_or("-".to_string(), |s| format!("{s:.1}")),
            f.mean_exit_line,
            if f.on_frontier { "*" } else { "" }
        );
    }
    if let Some(path) = args.str_opt("out") {
        std::fs::write(path, zoo_report_json(&report).to_string())?;
        println!("zoo json        {path}");
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let ctx = {
        let mut c = FigureCtx::new(
            args.str_or("traces-dir", eat_serve::DEFAULT_TRACES),
            args.str_or("out-dir", eat_serve::DEFAULT_RESULTS),
        );
        c.cfg = serve_cfg(args);
        c
    };
    let fig = args.str_or("fig", "all");
    let mut ran = 0;
    if fig == "all" {
        for f in figures::OFFLINE_FIGS {
            match figures::run_offline(&ctx, f) {
                Ok(_) => ran += 1,
                Err(e) => println!("[fig{f}] skipped: {e}"),
            }
        }
        let rt = load_runtime(args)?;
        for f in figures::LIVE_FIGS {
            match figures::run_live(&ctx, &rt, f) {
                Ok(_) => ran += 1,
                Err(e) => println!("[fig{f}] skipped: {e}"),
            }
        }
    } else if figures::run_offline(&ctx, fig)? {
        ran += 1;
    } else {
        let rt = load_runtime(args)?;
        if figures::run_live(&ctx, &rt, fig)? {
            ran += 1;
        } else {
            anyhow::bail!("unknown figure `{fig}`");
        }
    }
    println!("done: {ran} figure(s)");
    Ok(())
}

fn cmd_blackbox(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let ctx = {
        let mut c = FigureCtx::new(
            args.str_or("traces-dir", eat_serve::DEFAULT_TRACES),
            args.str_or("out-dir", eat_serve::DEFAULT_RESULTS),
        );
        c.cfg = serve_cfg(args);
        // chunk-granularity monitoring defaults (see serve --blackbox)
        c.cfg.alpha = args.f64_or("alpha", CHUNK_MONITOR_ALPHA);
        c.cfg.delta = args.f64_or("delta", CHUNK_MONITOR_DELTA);
        c
    };
    figures::fig5a(&ctx, &rt, args.usize_or("questions", 8))
}

/// The CI bench regression gate: diff two snapshot files, or every
/// `BENCH_*.json` the two directories share. Added/removed benches are
/// reported but never fail the gate (benches come and go); only a mean
/// slowdown past `1 + tol` does.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let (Some(base), Some(new)) = (args.positional(1), args.positional(2)) else {
        usage();
    };
    let tol = args.f64_or("tol", 1.0);
    let pairs: Vec<(String, std::path::PathBuf, std::path::PathBuf)> =
        if std::path::Path::new(base).is_dir() {
            let mut names: Vec<String> = std::fs::read_dir(base)?
                .filter_map(|e| e.ok()?.file_name().into_string().ok())
                .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .collect();
            names.sort();
            if names.is_empty() {
                println!("no baseline snapshots in {base} — nothing to gate");
                return Ok(());
            }
            names
                .into_iter()
                .map(|n| {
                    let b = std::path::Path::new(base).join(&n);
                    let w = std::path::Path::new(new).join(&n);
                    (n, b, w)
                })
                .collect()
        } else if !std::path::Path::new(base).exists() {
            // a fresh branch has no baseline yet: report, don't fail —
            // the gate only bites when there is something to compare
            println!("no baseline at {base} — nothing to gate");
            return Ok(());
        } else {
            vec![(base.to_string(), base.into(), new.into())]
        };

    let mut regressions = 0usize;
    let mut compared = 0usize;
    for (name, base_path, new_path) in pairs {
        if !new_path.exists() {
            println!("{name}: only in base (skipped)");
            continue;
        }
        let base_text = std::fs::read_to_string(&base_path)
            .with_context(|| format!("reading {}", base_path.display()))?;
        let new_text = std::fs::read_to_string(&new_path)
            .with_context(|| format!("reading {}", new_path.display()))?;
        let diff = eat_serve::util::bench::diff_snapshots(&base_text, &new_text, tol)
            .with_context(|| format!("diffing {name}"))?;
        for d in &diff.deltas {
            let flag = if d.regressed { "  <-- REGRESSED" } else { "" };
            println!(
                "{name} {:<44} {:>12.0} ns -> {:>12.0} ns  {:>+7.1}%{flag}",
                d.name,
                d.base_mean_ns,
                d.new_mean_ns,
                d.ratio * 100.0
            );
            compared += 1;
        }
        for n in &diff.only_base {
            println!("{name} {n}: removed (not failed)");
        }
        for n in &diff.only_new {
            println!("{name} {n}: added (not failed)");
        }
        regressions += diff.regressions();
    }
    println!("\n{compared} rows compared, {regressions} regression(s) at tol {tol}");
    anyhow::ensure!(
        regressions == 0,
        "{regressions} bench regression(s) past {:.0}% slower",
        tol * 100.0
    );
    Ok(())
}

/// `repro soak` — the memory-bounded million-session scheduling soak
/// (DESIGN.md §3.10). Virtual-time only, a pure function of the flags:
/// a double run writes byte-identical `--metrics-json` output, which is
/// exactly what the CI `soak-smoke` job diffs. `--driver` selects the
/// pre-wheel tick-scan reference core so the two can be raced and
/// cross-checked on completion invariants.
fn cmd_soak(args: &Args) -> Result<()> {
    let cfg = SoakConfig {
        sessions: args.u64_or("sessions", 100_000),
        rate_per_s: args.f64_or("rate", 500.0),
        arrivals: ArrivalSpec::from_args(args)?,
        overload: args.f64_opt("overload"),
        slo_s: args.f64_or("slo", f64::INFINITY),
        shed: OverloadPolicy::from_flag(args.str_or("shed", "none"))?,
        slots: args.usize_or("slots", 256),
        seed: args.u64_or("seed", 0),
        summary_cap: args.usize_or("summary-cap", DEFAULT_SUMMARY_CAP),
        mem_budget_bytes: args.usize_opt("mem-mb").map(|m| m as u64 * 1024 * 1024),
    };
    let mode = if args.has("driver") { SoakMode::Driver } else { SoakMode::Events };
    let t0 = std::time::Instant::now();
    let report = run_soak(&cfg, mode)?;
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    println!("{}", report.report());
    println!(
        "wall {:.2}s — {:.0} sessions/s",
        wall,
        report.completed as f64 / wall
    );
    if let Some(path) = args.str_opt("metrics-json") {
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("soak metrics -> {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional(0) {
        Some("info") => cmd_info(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("sweep-zoo") => cmd_sweep_zoo(&args),
        Some("figures") => cmd_figures(&args),
        Some("blackbox") => cmd_blackbox(&args),
        Some("soak") => cmd_soak(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        _ => usage(),
    }
}
