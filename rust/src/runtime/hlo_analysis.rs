//! HLO cost analysis: parse the AOT-lowered HLO text and estimate FLOPs /
//! memory traffic per executable — the L2 performance-profiling tool of
//! the §Perf pass (DESIGN.md §6: "no redundant recomputation, fused where
//! XLA can fuse").
//!
//! Two-pass structural parser (not a full HLO grammar): pass 1 records
//! every instruction's output shape into a symbol table; pass 2 resolves
//! dot operands by name to compute exact 2*M*N*K FLOPs and aggregates:
//!   * op histogram (dot / elementwise / reduce / dynamic-update-slice ...)
//!   * FLOP estimate (exact for dots, 1 flop/elem for elementwise)
//!   * output-bytes estimate (memory-traffic lower bound)
//! quoted by `repro info --hlo` and the EXPERIMENTS.md §Perf log.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Parsed output shape: dtype byte width and dimensions.
#[derive(Debug, Clone, Default)]
pub struct Shape {
    pub dims: Vec<u64>,
    pub elem_bytes: u64,
}

impl Shape {
    pub fn elems(&self) -> u64 {
        self.dims.iter().product::<u64>().max(1)
    }
}

/// Aggregate analysis of one HLO module.
#[derive(Debug, Clone, Default)]
pub struct HloReport {
    pub instr_count: usize,
    pub op_histogram: BTreeMap<String, usize>,
    pub flops: u64,
    pub output_bytes: u64,
    pub dot_flops: u64,
    pub dot_count: usize,
}

impl HloReport {
    /// Arithmetic intensity proxy: FLOPs per byte of instruction output.
    pub fn intensity(&self) -> f64 {
        self.flops as f64 / self.output_bytes.max(1) as f64
    }

    pub fn render(&self, name: &str) -> String {
        let mut s = format!(
            "{name}: {} instrs, {:.3} MFLOP ({:.0}% in {} dots), {:.2} MB outputs, intensity {:.2} flop/B\n",
            self.instr_count,
            self.flops as f64 / 1e6,
            100.0 * self.dot_flops as f64 / self.flops.max(1) as f64,
            self.dot_count,
            self.output_bytes as f64 / 1e6,
            self.intensity()
        );
        let mut ops: Vec<(&String, &usize)> = self.op_histogram.iter().collect();
        ops.sort_by_key(|(_, c)| std::cmp::Reverse(**c));
        s += "  top ops: ";
        for (op, c) in ops.iter().take(8) {
            s += &format!("{op}:{c} ");
        }
        s += "\n";
        s
    }
}

fn dtype_bytes(ty: &str) -> u64 {
    match ty {
        "f64" | "s64" | "u64" | "c64" => 8,
        "f32" | "s32" | "u32" => 4,
        "bf16" | "f16" | "s16" | "u16" => 2,
        "pred" | "s8" | "u8" => 1,
        _ => 0,
    }
}

/// Parse `f32[2,3]{1,0}` / `s32[]` / `pred[4]` into a Shape.
fn parse_shape(s: &str) -> Shape {
    let s = s.trim();
    let Some(br) = s.find('[') else {
        return Shape {
            dims: vec![],
            elem_bytes: dtype_bytes(s),
        };
    };
    let ty = &s[..br];
    let end = s[br..].find(']').map(|e| br + e).unwrap_or(s.len());
    let dims = s[br + 1..end]
        .split(',')
        .filter_map(|d| d.trim().parse::<u64>().ok())
        .collect();
    Shape {
        dims,
        elem_bytes: dtype_bytes(ty),
    }
}

/// Sum of elems/bytes over a (possibly tuple) shape string.
fn tuple_totals(shape_str: &str) -> (u64, u64) {
    let inner = shape_str.trim().trim_start_matches('(').trim_end_matches(')');
    let mut elems = 0u64;
    let mut bytes = 0u64;
    // split at "]," boundaries to keep dim lists intact
    let mut start = 0usize;
    let b = inner.as_bytes();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == b']' {
            // include the layout suffix `{...}` if present
            let mut j = i + 1;
            if j < b.len() && b[j] == b'{' {
                while j < b.len() && b[j] != b'}' {
                    j += 1;
                }
                j += 1;
            }
            let sh = parse_shape(inner[start..j.min(inner.len())].trim_matches(','));
            if sh.elem_bytes > 0 {
                elems += sh.elems();
                bytes += sh.elems() * sh.elem_bytes;
            }
            start = j;
            i = j;
        } else {
            i += 1;
        }
    }
    if start == 0 && !inner.is_empty() {
        // no ']' at all: scalar like `s32[]` handled above, or plain type
        let sh = parse_shape(inner);
        if sh.elem_bytes > 0 {
            elems += sh.elems();
            bytes += sh.elems() * sh.elem_bytes;
        }
    }
    (elems, bytes)
}

struct Line<'a> {
    name: &'a str,
    shape_str: &'a str,
    opcode: String,
    rest: &'a str,
    raw: &'a str,
}

fn split_line(line: &str) -> Option<Line<'_>> {
    let line = line.trim();
    let (lhs, rhs) = line.split_once(" = ")?;
    let name = lhs.trim_start_matches("ROOT ").trim().trim_start_matches('%');
    let rhs = rhs.trim();
    let (shape_str, rest) = if rhs.starts_with('(') {
        let mut depth = 0usize;
        let mut end = 0usize;
        for (i, c) in rhs.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        (&rhs[..end], rhs[end..].trim_start())
    } else {
        let sp = rhs.find(' ')?;
        (&rhs[..sp], rhs[sp..].trim_start())
    };
    let opcode: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
        .collect();
    if opcode.is_empty() {
        return None;
    }
    Some(Line {
        name,
        shape_str,
        opcode,
        rest,
        raw: line,
    })
}

/// Operand names of `opcode(a, b, ...)` — first paren group of `rest`.
fn operand_names(rest: &str) -> Vec<&str> {
    let Some(open) = rest.find('(') else {
        return vec![];
    };
    let Some(close) = rest[open..].find(')') else {
        return vec![];
    };
    rest[open + 1..open + close]
        .split(',')
        .map(|s| s.trim().trim_start_matches('%'))
        .filter(|s| !s.is_empty())
        .collect()
}

fn braces_list(line: &str, key: &str) -> Vec<usize> {
    let Some(p) = line.find(key) else {
        return vec![];
    };
    let s = &line[p + key.len()..];
    let Some(close) = s.find('}') else {
        return vec![];
    };
    s[..close]
        .split(',')
        .filter_map(|d| d.trim().parse().ok())
        .collect()
}

const ELEMENTWISE: &[&str] = &[
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "compare",
    "select", "and", "or", "not", "power", "abs", "sign", "floor", "ceil",
    "clamp", "exponential-minus-one", "log-plus-one", "atan2",
];

const DATA_MOVEMENT: &[&str] = &[
    "convert", "broadcast", "reshape", "transpose", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "gather", "scatter", "iota",
    "constant", "parameter", "tuple", "get-tuple-element", "copy", "bitcast",
    "pad", "reverse", "rng-bit-generator", "after-all", "custom-call",
];

/// Analyze HLO text.
pub fn analyze_text(text: &str) -> HloReport {
    // pass 1: symbol table of output shapes (entry + nested computations)
    let mut shapes: BTreeMap<&str, Shape> = BTreeMap::new();
    for raw in text.lines() {
        let t = raw.trim();
        if !t.contains(" = ") || t.starts_with("HloModule") {
            continue;
        }
        if let Some(l) = split_line(t) {
            if !l.shape_str.starts_with('(') {
                shapes.insert(l.name, parse_shape(l.shape_str));
            }
        }
    }

    // pass 2: only the ENTRY computation contributes to the totals (the
    // others are fusion/reduce bodies already accounted through callers)
    let mut report = HloReport::default();
    let mut in_entry = false;
    for raw in text.lines() {
        let t = raw.trim();
        if t.starts_with("ENTRY") {
            in_entry = true;
            continue;
        }
        if in_entry && t == "}" {
            in_entry = false;
        }
        if !in_entry || !t.contains(" = ") {
            continue;
        }
        let Some(l) = split_line(t) else { continue };
        let (out_elems, out_bytes) = tuple_totals(l.shape_str);
        *report.op_histogram.entry(l.opcode.clone()).or_insert(0) += 1;
        report.instr_count += 1;

        let flops = if l.opcode == "dot" {
            let ops = operand_names(l.rest);
            let k: u64 = {
                let cdims = braces_list(l.raw, "lhs_contracting_dims={");
                ops.first()
                    .and_then(|n| shapes.get(n))
                    .map(|sh| {
                        cdims
                            .iter()
                            .map(|&i| sh.dims.get(i).copied().unwrap_or(1))
                            .product::<u64>()
                            .max(1)
                    })
                    .unwrap_or(1)
            };
            let f = 2 * out_elems * k;
            report.dot_flops += f;
            report.dot_count += 1;
            f
        } else if ELEMENTWISE.contains(&l.opcode.as_str()) {
            out_elems
        } else if l.opcode == "reduce" || l.opcode == "reduce-window" {
            // cost ~ number of inputs reduced; approximate via operand size
            operand_names(l.rest)
                .first()
                .and_then(|n| shapes.get(n))
                .map(|sh| sh.elems())
                .unwrap_or(2 * out_elems)
        } else if DATA_MOVEMENT.contains(&l.opcode.as_str()) {
            0
        } else {
            out_elems // unknown compute op: 1 flop per output element
        };
        report.flops += flops;
        report.output_bytes += out_bytes;
    }
    report
}

/// Analyze one HLO text file.
pub fn analyze_file(path: &Path) -> Result<HloReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(analyze_text(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn

ENTRY %main (p0: f32[4,8], p1: f32[8,16]) -> f32[4,16] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,16]{1,0} parameter(1)
  %dot.1 = f32[4,16]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %exp.1 = f32[4,16]{1,0} exponential(%dot.1)
  ROOT %add.1 = f32[4,16]{1,0} add(%dot.1, %exp.1)
}
"#;

    #[test]
    fn parses_sample_module() {
        let r = analyze_text(SAMPLE);
        assert_eq!(r.op_histogram["dot"], 1);
        assert_eq!(r.op_histogram["add"], 1);
        assert_eq!(r.op_histogram["exponential"], 1);
        assert_eq!(r.op_histogram["parameter"], 2);
    }

    #[test]
    fn dot_flops_exact_via_symbol_table() {
        let r = analyze_text(SAMPLE);
        // dot: 2 * (4*16) * 8 = 1024; exp + add: 64 + 64
        assert_eq!(r.dot_flops, 1024);
        assert_eq!(r.flops, 1024 + 128);
    }

    #[test]
    fn output_bytes_counted() {
        let r = analyze_text(SAMPLE);
        // params 32+128 elems + dot/exp/add 64 each, all f32
        assert_eq!(r.output_bytes, (32 + 128 + 3 * 64) * 4);
    }

    #[test]
    fn shape_parser() {
        let s = parse_shape("f32[2,3]{1,0}");
        assert_eq!((s.elems(), s.elem_bytes), (6, 4));
        assert_eq!(parse_shape("s32[]").elems(), 1);
        assert_eq!(parse_shape("bf16[8]").elem_bytes, 2);
        assert_eq!(parse_shape("pred[4]").elem_bytes, 1);
    }

    #[test]
    fn tuple_shape_totals() {
        let (e, b) = tuple_totals("(f32[48]{0}, f32[2,2,128,32]{3,2,1,0}, s32[])");
        assert_eq!(e, 48 + 2 * 2 * 128 * 32 + 1);
        assert_eq!(b, (48 + 16384 + 1) * 4);
    }

    #[test]
    fn operand_name_extraction() {
        assert_eq!(operand_names("dot(%a, b.2), extra"), vec!["a", "b.2"]);
        assert_eq!(operand_names("constant(3)"), vec!["3"]);
    }

    #[test]
    fn real_artifacts_analyzable_if_present() {
        let p = Path::new("artifacts/decode_main.hlo.txt");
        if !p.exists() {
            return;
        }
        let r = analyze_file(p).unwrap();
        assert!(r.instr_count > 50, "decode HLO suspiciously small");
        // decode step ~ 2 * params * 1 token ~ 0.2 MFLOP for the 113k-param
        // main model
        assert!(
            r.flops > 100_000,
            "decode FLOPs too low: {} (dots {})",
            r.flops,
            r.dot_count
        );
        assert!(r.op_histogram.contains_key("dot"));
        // decode must update the cache functionally
        assert!(r.op_histogram.contains_key("dynamic-update-slice"));
    }
}
