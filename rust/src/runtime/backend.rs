//! The `Backend` trait: the execution interface the coordinator programs
//! against (DESIGN.md §2).
//!
//! Everything above the runtime layer — sessions, batcher, exit policies,
//! trace generation, the black-box simulator — speaks only this trait.
//! Two implementations exist:
//!
//!  * [`crate::runtime::model::ModelRuntime`] behind `PjrtBackend`
//!    (feature `pjrt`): executes the AOT-compiled HLO artifacts through
//!    the PJRT C API.
//!  * [`crate::runtime::reference::RefBackend`]: a deterministic
//!    in-process table-driven chain-sum reasoner, so the full serving
//!    stack runs (and is tested) without artifacts or a PJRT toolchain.
//!
//! The trait is deliberately session-free: callers own the caches and
//! pass them in, which is what lets the continuous batcher keep all
//! per-slot state in one [`crate::coordinator::BatchCacheStore`] and
//! drive a single fused `decode_batch` per scheduling tick.

use std::cell::Cell;

use anyhow::Result;

use super::reference::RefCache;

/// Execution counters for the perf report (`repro info`, §Perf) and the
/// batching tests (one fused call per tick is asserted through these).
#[derive(Debug, Default)]
pub struct RuntimeCounters {
    pub prefills: Cell<u64>,
    pub decodes: Cell<u64>,
    pub probes: Cell<u64>,
    /// Fused batched decode *calls* (one per engaged tick).
    pub batch_decodes: Cell<u64>,
    /// Total engaged lanes across all fused calls.
    pub batch_lanes: Cell<u64>,
    /// Engaged lanes whose K/V image was already resident in the
    /// backend's batched scratch from the previous fused call — the
    /// per-lane host *gather* was skipped. (On PJRT the batched image
    /// itself is still uploaded/downloaded once per call: the tuple
    /// output API offers no device-side buffer reuse; see DESIGN.md §6.)
    pub batch_resident_lanes: Cell<u64>,
    /// Copy-on-write cache forks (paged store only; a monolithic fork
    /// deep-copies and bumps nothing here).
    pub cow_forks: Cell<u64>,
    /// KV pages physically copied — CoW on the first divergent write of
    /// a shared page. Zero for probe-only steps (the acceptance bar the
    /// batching tests pin down: the EAT probe never copies cache state).
    pub pages_copied: Cell<u64>,
    /// Page references added by forks (refcount bumps instead of data
    /// copies).
    pub pages_shared: Cell<u64>,
    /// Scheduler ticks driven through `Batcher::tick` on this backend.
    pub sched_ticks: Cell<u64>,
    /// Heap allocations performed by the tick hot loop — scratch-vector
    /// capacity growth events. The batcher preallocates its per-tick work
    /// lists to the slot count, so this stays at ~0 after warmup
    /// (`allocs_per_tick` in BENCH_scheduler.json; asserted in
    /// scheduler_sim).
    pub sched_allocs: Cell<u64>,
}

impl RuntimeCounters {
    pub fn bump(cell: &Cell<u64>) {
        cell.set(cell.get() + 1);
    }

    pub fn add(cell: &Cell<u64>, n: u64) {
        cell.set(cell.get() + n);
    }
}

/// A per-sequence KV cache, owned by the caller (session driver or batch
/// store), interpreted by the backend that created it.
pub enum BackendCache {
    /// Token-history cache of the reference backend.
    Ref(RefCache),
    /// Device + host-mirror KV cache of the PJRT backend.
    #[cfg(feature = "pjrt")]
    Pjrt(super::model::KvCache),
}

impl BackendCache {
    /// Next write position (== number of committed tokens).
    pub fn pos(&self) -> usize {
        match self {
            BackendCache::Ref(c) => c.pos(),
            #[cfg(feature = "pjrt")]
            BackendCache::Pjrt(c) => c.pos,
        }
    }

    /// Bytes this cache accounts for against the KV budget.
    pub fn device_bytes(&self) -> usize {
        match self {
            BackendCache::Ref(c) => c.device_bytes(),
            #[cfg(feature = "pjrt")]
            BackendCache::Pjrt(c) => c.device_bytes(),
        }
    }
}

/// One engaged lane of a fused batched decode: the slot's cache and the
/// token to commit. Idle (padding) lanes are `None` in the lane slice.
pub struct BatchLane<'a> {
    pub cache: &'a mut BackendCache,
    pub token: u32,
}

/// The model-execution interface (prefill / decode / probe / fork /
/// fused batched decode). One instance per model (main, proxy).
pub trait Backend {
    /// Short model name for reports ("main", "proxy", "ref-main", ...).
    fn name(&self) -> &str;

    /// One-line human description for `repro info`.
    fn describe(&self) -> String;

    /// Maximum sequence length a cache can hold.
    fn seq_len(&self) -> usize;

    /// Maximum probe suffix length.
    fn probe_len(&self) -> usize;

    /// Vocabulary size (logits dimensionality).
    fn vocab_size(&self) -> usize;

    /// Fused batch width, when this backend carries a batched decode
    /// entry point (`None` → the batcher falls back to sequential
    /// decodes).
    fn batch_width(&self) -> Option<usize>;

    fn has_batch(&self) -> bool {
        self.batch_width().is_some()
    }

    /// Elements of one K (or V) cache tensor per sequence — the unit the
    /// KV page manager converts into a byte budget.
    fn cache_elems(&self) -> usize;

    /// Tokens per KV page when this backend stores caches in a paged,
    /// refcounted pool (`None` = monolithic full-sequence caches). The
    /// batch store and the scheduler use this for page-granular dirty
    /// tracking and page-budget admission (DESIGN.md §3.5).
    fn page_size(&self) -> Option<usize> {
        None
    }

    /// Live pages in this backend's shared KV pool (`None` when the
    /// store is monolithic) — the cross-replica leak audits assert this
    /// returns to zero once every cache is dropped.
    fn pool_pages_in_use(&self) -> Option<usize> {
        None
    }

    /// Lifetime `(allocs, frees)` of the pool's page allocator (`None`
    /// when monolithic): with no live caches the two must be equal —
    /// every page freed exactly once.
    fn pool_alloc_free(&self) -> Option<(u64, u64)> {
        None
    }

    /// Parameter count (for `repro info`).
    fn param_elems(&self) -> usize;

    /// Run a prompt; returns logits at the last position and a fresh
    /// cache positioned just past the prompt.
    fn prefill(&self, tokens: &[u32]) -> Result<(Vec<f32>, BackendCache)>;

    /// Commit one token, returning next-token logits.
    fn decode(&self, cache: &mut BackendCache, token: u32) -> Result<Vec<f32>>;

    /// EAT probe (paper §4.3): virtually append `suffix`, return the
    /// entropy of the following token plus its full logits. The cache is
    /// NOT modified.
    fn probe(&self, cache: &BackendCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)>;

    /// Fork a cache for hypothetical continuations (rollout baselines).
    fn fork(&self, cache: &BackendCache) -> Result<BackendCache>;

    /// Fused batched decode over exactly `batch_width()` lanes. Engaged
    /// lanes commit their token and receive logits (index-aligned with
    /// the input); `None` lanes are padding and stay untouched. Must be
    /// step-equivalent to `decode` per engaged lane. Errors when the
    /// backend has no batch entry point.
    fn decode_batch(&self, lanes: &mut [Option<BatchLane<'_>>]) -> Result<Vec<Option<Vec<f32>>>>;

    /// Execution counters (shared cell-based, bumped by every entry
    /// point).
    fn counters(&self) -> &RuntimeCounters;
}
