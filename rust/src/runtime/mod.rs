//! Runtime layer: load + execute the AOT artifacts via the PJRT C API
//! (`xla` crate, CPU client). See /opt/xla-example/load_hlo for the
//! reference wiring and DESIGN.md §2 for the entry-point signatures.

pub mod client;
pub mod hlo_analysis;
pub mod model;
pub mod weights;

pub use client::Client;
pub use model::{KvCache, ModelRuntime};

use std::path::Path;

use anyhow::Result;

use crate::config::ArtifactsConfig;

/// Both models loaded and ready: the full serving runtime.
pub struct Runtime {
    pub client: Client,
    pub cfg: ArtifactsConfig,
    pub main: ModelRuntime,
    pub proxy: ModelRuntime,
}

impl Runtime {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let cfg = ArtifactsConfig::load(artifacts_dir)?;
        let client = Client::cpu()?;
        let main = ModelRuntime::load(&client, &cfg.dir, &cfg.main)?;
        let proxy = ModelRuntime::load(&client, &cfg.dir, &cfg.proxy)?;
        Ok(Runtime {
            client,
            cfg,
            main,
            proxy,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelRuntime> {
        match name {
            "main" => Ok(&self.main),
            "proxy" => Ok(&self.proxy),
            other => anyhow::bail!("unknown model `{other}`"),
        }
    }
}
