//! Runtime layer: model execution behind the [`Backend`] trait.
//!
//! Two backends implement it (DESIGN.md §2):
//!  * `pjrt` feature — load + execute the AOT artifacts via the PJRT C
//!    API (`xla` crate, CPU client; see /opt/xla-example/load_hlo for the
//!    reference wiring);
//!  * always available — [`RefBackend`], a deterministic in-process
//!    reference model, so the engine/batcher/exit stack runs and tests
//!    without artifacts.
//!
//! [`Runtime`] is the loaded pair (main reasoner + proxy monitor) plus
//! the shared vocabulary — the only runtime type the coordinator sees.

pub mod backend;
pub mod hlo_analysis;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod model;
#[cfg(feature = "pjrt")]
pub mod weights;

pub use backend::{Backend, BackendCache, BatchLane, RuntimeCounters};
pub use reference::RefBackend;

#[cfg(feature = "pjrt")]
pub use client::Client;
#[cfg(feature = "pjrt")]
pub use model::{KvCache, ModelRuntime, PjrtBackend};

use std::path::Path;

use anyhow::Result;

use crate::config::ArtifactsConfig;
use crate::vocab::Vocab;

/// Both models loaded and ready: the full serving runtime.
pub struct Runtime {
    pub vocab: Vocab,
    /// The reasoning model.
    pub main: Box<dyn Backend>,
    /// The small proxy monitor (black-box setting).
    pub proxy: Box<dyn Backend>,
    /// Artifact metadata when PJRT-backed (`None` on the reference
    /// backend).
    pub artifacts: Option<ArtifactsConfig>,
}

impl Runtime {
    /// Load the AOT artifacts (requires the `pjrt` feature and a built
    /// `artifacts/` directory); errors otherwise so callers can skip or
    /// fall back.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Runtime::load_impl(artifacts_dir.as_ref())
    }

    #[cfg(feature = "pjrt")]
    fn load_impl(dir: &Path) -> Result<Runtime> {
        use std::rc::Rc;
        let cfg = ArtifactsConfig::load(dir)?;
        let client = Rc::new(Client::cpu()?);
        let main = PjrtBackend::load(client.clone(), &cfg.dir, &cfg.main)?;
        let proxy = PjrtBackend::load(client, &cfg.dir, &cfg.proxy)?;
        Ok(Runtime {
            vocab: cfg.vocab,
            main: Box::new(main),
            proxy: Box::new(proxy),
            artifacts: Some(cfg),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn load_impl(dir: &Path) -> Result<Runtime> {
        anyhow::bail!(
            "cannot load artifacts from {}: built without the `pjrt` feature \
             (use Runtime::reference(), or rebuild with `--features pjrt`)",
            dir.display()
        )
    }

    /// The deterministic in-process reference runtime: no artifacts, no
    /// PJRT, bit-reproducible from seeds alone.
    pub fn reference() -> Runtime {
        let vocab = Vocab::default_layout();
        Runtime {
            vocab,
            main: Box::new(RefBackend::main(vocab)),
            proxy: Box::new(RefBackend::proxy(vocab)),
            artifacts: None,
        }
    }

    /// Artifacts when present, otherwise the reference runtime (with a
    /// note) — the zero-setup path for the CLI and examples.
    pub fn load_or_reference(artifacts_dir: impl AsRef<Path>) -> Runtime {
        match Runtime::load(&artifacts_dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!(
                    "note: PJRT artifacts unavailable ({e:#}); using the \
                     deterministic reference backend"
                );
                Runtime::reference()
            }
        }
    }

    /// "pjrt" or "reference", for reports.
    pub fn backend_kind(&self) -> &'static str {
        if self.artifacts.is_some() {
            "pjrt"
        } else {
            "reference"
        }
    }
}
