//! Runtime layer: model execution behind the [`Backend`] trait.
//!
//! Two backends implement it (DESIGN.md §2):
//!  * `pjrt` feature — load + execute the AOT artifacts via the PJRT C
//!    API (`xla` crate, CPU client; see /opt/xla-example/load_hlo for the
//!    reference wiring);
//!  * always available — [`RefBackend`], a deterministic in-process
//!    reference model, so the engine/batcher/exit stack runs and tests
//!    without artifacts.
//!
//! [`Runtime`] is the loaded pair (main reasoner + proxy monitor) plus
//! the shared vocabulary — the only runtime type the coordinator sees.

pub mod backend;
pub mod hlo_analysis;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod model;
#[cfg(feature = "pjrt")]
pub mod weights;

pub use backend::{Backend, BackendCache, BatchLane, RuntimeCounters};
pub use reference::RefBackend;

#[cfg(feature = "pjrt")]
pub use client::Client;
#[cfg(feature = "pjrt")]
pub use model::{KvCache, ModelRuntime, PjrtBackend};

use std::path::Path;

use anyhow::Result;

use crate::config::ArtifactsConfig;
use crate::vocab::Vocab;

/// Both models loaded and ready: the full serving runtime.
pub struct Runtime {
    pub vocab: Vocab,
    /// The reasoning model.
    pub main: Box<dyn Backend>,
    /// The small proxy monitor (black-box setting).
    pub proxy: Box<dyn Backend>,
    /// Artifact metadata when PJRT-backed (`None` on the reference
    /// backend).
    pub artifacts: Option<ArtifactsConfig>,
}

impl Runtime {
    /// Load the AOT artifacts (requires the `pjrt` feature and a built
    /// `artifacts/` directory); errors otherwise so callers can skip or
    /// fall back.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Runtime::load_with(artifacts_dir, None)
    }

    /// Load the AOT artifacts with an optional paged KV store
    /// (`page_size` tokens per page; `None` = monolithic mirrors).
    pub fn load_with(artifacts_dir: impl AsRef<Path>, page_size: Option<usize>) -> Result<Runtime> {
        Runtime::load_impl(artifacts_dir.as_ref(), page_size)
    }

    #[cfg(feature = "pjrt")]
    fn load_impl(dir: &Path, page_size: Option<usize>) -> Result<Runtime> {
        use std::rc::Rc;
        let cfg = ArtifactsConfig::load(dir)?;
        let client = Rc::new(Client::cpu()?);
        let main = PjrtBackend::load_with(client.clone(), &cfg.dir, &cfg.main, page_size)?;
        let proxy = PjrtBackend::load_with(client, &cfg.dir, &cfg.proxy, page_size)?;
        Ok(Runtime {
            vocab: cfg.vocab,
            main: Box::new(main),
            proxy: Box::new(proxy),
            artifacts: Some(cfg),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn load_impl(dir: &Path, _page_size: Option<usize>) -> Result<Runtime> {
        anyhow::bail!(
            "cannot load artifacts from {}: built without the `pjrt` feature \
             (use Runtime::reference(), or rebuild with `--features pjrt`)",
            dir.display()
        )
    }

    /// The deterministic in-process reference runtime: no artifacts, no
    /// PJRT, bit-reproducible from seeds alone. Caches live in a paged
    /// copy-on-write store at the default page size (DESIGN.md §3.5).
    pub fn reference() -> Runtime {
        Runtime::reference_paged(crate::coordinator::kv::DEFAULT_PAGE_SIZE)
    }

    /// Paged reference runtime at an explicit page size.
    pub fn reference_paged(page_size: usize) -> Runtime {
        let vocab = Vocab::default_layout();
        Runtime {
            vocab,
            main: Box::new(RefBackend::with_pages(
                "ref-main",
                vocab,
                128,
                Some(8),
                Some(page_size),
            )),
            proxy: Box::new(RefBackend::with_pages(
                "ref-proxy",
                vocab,
                128,
                None,
                Some(page_size),
            )),
            artifacts: None,
        }
    }

    /// Monolithic full-sequence reference runtime: the pre-paging cache
    /// representation, kept as the equivalence oracle — same-seed serve
    /// runs must emit byte-identical metrics against either store.
    pub fn reference_monolithic() -> Runtime {
        let vocab = Vocab::default_layout();
        Runtime {
            vocab,
            main: Box::new(RefBackend::monolithic("ref-main", vocab, 128, Some(8))),
            proxy: Box::new(RefBackend::monolithic("ref-proxy", vocab, 128, None)),
            artifacts: None,
        }
    }

    /// Artifacts when present, otherwise the reference runtime (with a
    /// note) — the zero-setup path for the CLI and examples. Paged at
    /// the default page size; see [`Runtime::load_or_reference_with`].
    pub fn load_or_reference(artifacts_dir: impl AsRef<Path>) -> Runtime {
        Runtime::load_or_reference_with(
            artifacts_dir,
            Some(crate::coordinator::kv::DEFAULT_PAGE_SIZE),
        )
    }

    /// [`Runtime::load_or_reference`] with an explicit KV store choice:
    /// `Some(page_size)` = paged, `None` = monolithic — applied to the
    /// artifacts when they load and to the reference fallback alike
    /// (the CLI's `--kv-store`/`--page-size` flags route here).
    pub fn load_or_reference_with(
        artifacts_dir: impl AsRef<Path>,
        page_size: Option<usize>,
    ) -> Runtime {
        match Runtime::load_with(&artifacts_dir, page_size) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!(
                    "note: PJRT artifacts unavailable ({e:#}); using the \
                     deterministic reference backend"
                );
                match page_size {
                    Some(p) => Runtime::reference_paged(p),
                    None => Runtime::reference_monolithic(),
                }
            }
        }
    }

    /// "pjrt" or "reference", for reports.
    pub fn backend_kind(&self) -> &'static str {
        if self.artifacts.is_some() {
            "pjrt"
        } else {
            "reference"
        }
    }
}
