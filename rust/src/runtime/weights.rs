//! Trained-weights loader: manifest_{model}.json + weights_{model}.bin ->
//! one device buffer per parameter, in the canonical order that the AOT
//! HLO entry points expect (python/compile/model.py::param_specs).

use std::path::Path;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::client::Client;
use crate::util::json;

/// One entry of the manifest.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Parse `manifest_{model}.json`.
pub fn load_manifest(path: &Path) -> Result<Vec<ParamSpec>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let v = json::parse(&text)?;
    let arr = v
        .as_arr()
        .context("manifest must be a JSON array")?;
    let mut specs = Vec::with_capacity(arr.len());
    for item in arr {
        let shape = item
            .req("shape")?
            .as_arr()
            .context("shape must be array")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        specs.push(ParamSpec {
            name: item.req_str("name")?.to_string(),
            shape,
            offset: item.req_usize("offset")?,
            size: item.req_usize("size")?,
        });
    }
    Ok(specs)
}

/// Read the raw little-endian f32 blob.
pub fn load_blob(path: &Path, expected_elems: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(
        bytes.len() == expected_elems * 4,
        "weights blob {} has {} bytes, expected {}",
        path.display(),
        bytes.len(),
        expected_elems * 4
    );
    let mut out = vec![0f32; expected_elems];
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok(out)
}

/// Loaded weights: device buffers in manifest order.
pub struct Weights {
    pub specs: Vec<ParamSpec>,
    pub buffers: Vec<PjRtBuffer>,
    pub total_elems: usize,
}

impl Weights {
    pub fn load(client: &Client, manifest: &Path, blob: &Path) -> Result<Weights> {
        let specs = load_manifest(manifest)?;
        let total: usize = specs.iter().map(|s| s.size).sum();
        // manifest sanity: offsets must tile the blob exactly
        let mut expect = 0usize;
        for s in &specs {
            anyhow::ensure!(
                s.offset == expect,
                "manifest not contiguous at `{}` (offset {} != {})",
                s.name,
                s.offset,
                expect
            );
            let shape_elems: usize = s.shape.iter().product();
            anyhow::ensure!(
                shape_elems == s.size,
                "shape/size mismatch for `{}`",
                s.name
            );
            expect += s.size;
        }
        let blob = load_blob(blob, total)?;
        let mut buffers = Vec::with_capacity(specs.len());
        for s in &specs {
            let data = &blob[s.offset..s.offset + s.size];
            buffers.push(client.buf_f32(data, &s.shape)?);
        }
        Ok(Weights {
            specs,
            buffers,
            total_elems: total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(name);
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        p
    }

    #[test]
    fn manifest_parses() {
        let p = write_tmp(
            "eat_manifest_test.json",
            br#"[{"name":"a","shape":[2,3],"offset":0,"size":6},
                 {"name":"b","shape":[4],"offset":6,"size":4}]"#,
        );
        let specs = load_manifest(&p).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].shape, vec![2, 3]);
        assert_eq!(specs[1].offset, 6);
    }

    #[test]
    fn blob_roundtrip() {
        let vals: Vec<f32> = (0..10).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let p = write_tmp("eat_blob_test.bin", &bytes);
        let back = load_blob(&p, 10).unwrap();
        assert_eq!(back, vals);
        assert!(load_blob(&p, 11).is_err());
    }
}
