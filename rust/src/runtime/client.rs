//! PJRT client wrapper + literal/buffer helpers.
//!
//! Wraps the `xla` crate's CPU PJRT client with the small set of typed
//! helpers the serving stack needs: f32/i32 host->device uploads, HLO-text
//! loading (the interchange format — see DESIGN.md §2) and executable
//! compilation.

use std::path::Path;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Shared PJRT CPU client.
pub struct Client {
    inner: PjRtClient,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        let inner = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { inner })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    /// Load HLO *text* (not a serialized proto: xla_extension 0.5.1 rejects
    /// jax>=0.5 64-bit instruction ids; the text parser reassigns ids) and
    /// compile it for this client.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }

    // -- host -> device uploads -------------------------------------------

    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.inner.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.inner.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn buf_scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.buf_i32(&[v], &[])
    }

    pub fn buf_from_literal(&self, lit: &Literal) -> Result<PjRtBuffer> {
        Ok(self.inner.buffer_from_host_literal(None, lit)?)
    }
}

/// A compiled HLO executable plus its artifact name (for error messages
/// and profiling reports).
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute on device buffers; returns the decomposed output tuple as
    /// host literals. All our AOT graphs return a top-level tuple (the
    /// stablehlo->HLO converter is invoked with return_tuple=True), and
    /// PJRT hands it back as a single tuple buffer.
    pub fn run(&self, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let outs = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }

    /// Execute and keep the raw tuple buffer on device (used when the
    /// caller only needs a slice of the outputs and wants to defer/skip
    /// the host copy).
    pub fn run_raw(&self, args: &[&PjRtBuffer]) -> Result<PjRtBuffer> {
        let mut outs = self.exe.execute_b(args)?;
        Ok(outs.remove(0).remove(0))
    }
}

/// Extract an f32 vector from a literal.
pub fn lit_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn lit_f32_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
