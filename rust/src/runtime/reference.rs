//! `RefBackend` — a deterministic, in-process reference implementation of
//! the [`Backend`] trait (DESIGN.md §2.3).
//!
//! It emulates a *trained chain-sum reasoner* with a closed-form next-token
//! distribution instead of a neural net: given the committed token history
//! it scripts the reasoning ("verify partial-sum" lines, an overthinking
//! tail, self-termination) and shapes the forced-answer distribution so
//! that the paper's EAT dynamics hold —
//!
//!  * entropy after `</think>` starts at ~ln(32) and collapses as the
//!    partial sums accumulate, plateauing near zero once the chain is
//!    complete (solvable questions);
//!  * corrupted (unsolvable) questions keep a noisy high-entropy answer
//!    distribution forever (App. I.4: EAT never stabilizes);
//!  * out-of-distribution chains (n > 10) only sharpen to a small margin
//!    (the "degrading Pass@1" error class, Fig. 15);
//!  * tool-call questions know the answer from the prompt (reasoning
//!    optional, App. I.2).
//!
//! Because the distribution is a pure function of the token history,
//! fused batched decode is bit-identical to sequential decode, and the
//! paged copy-on-write cache (DESIGN.md §3.5) is bit-identical to the
//! monolithic full-sequence cache — the invariants the batcher's
//! determinism tests pin down — and every session is reproducible from
//! its seed alone.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::Result;

use super::backend::{Backend, BackendCache, BatchLane, RuntimeCounters};
use crate::coordinator::kv::{PagePool, PageTable, DEFAULT_PAGE_SIZE};
use crate::vocab::Vocab;

/// Paged token storage: a [`PageTable`] into the backend's shared
/// [`PagePool`]. The retain-on-Clone / release-on-Drop ownership
/// discipline lives on the generic table; this wrapper only adds the
/// token-length bookkeeping. A fork and its parent diverge by copying
/// exactly the page being written (the table's `write` CoW).
#[derive(Debug, Clone)]
pub struct PagedTokens {
    table: PageTable<u32>,
    len: usize,
    page_size: usize,
}

impl PagedTokens {
    fn from_slice(
        pool: &Rc<RefCell<PagePool<u32>>>,
        page_size: usize,
        tokens: &[u32],
    ) -> Result<PagedTokens> {
        let mut table = PageTable::new(pool.clone());
        for (i, chunk) in tokens.chunks(page_size).enumerate() {
            table.push_zeroed()?;
            table.write(i, |page| page[..chunk.len()].copy_from_slice(chunk))?;
        }
        Ok(PagedTokens {
            table,
            len: tokens.len(),
            page_size,
        })
    }

    /// Append one token: CoW the tail page if shared, or open a fresh
    /// page at a page boundary. Returns true when a page was physically
    /// copied.
    fn push(&mut self, token: u32) -> Result<bool> {
        let off = self.len % self.page_size;
        if off == 0 {
            self.table.push_zeroed()?;
        }
        let idx = self.table.page_count() - 1;
        let ((), copied) = self.table.write(idx, |page| page[off] = token)?;
        self.len += 1;
        Ok(copied)
    }

    /// Copy the committed tokens into `out` (cleared first).
    fn gather_into(&self, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.len);
        let pool = self.table.pool().borrow();
        for (i, pg) in self.table.pages().iter().enumerate() {
            let take = self.page_size.min(self.len - i * self.page_size);
            out.extend_from_slice(&pool.page(*pg)[..take]);
        }
    }

    fn page_count(&self) -> usize {
        self.table.page_count()
    }
}

/// Token storage of a reference cache: the monolithic full-sequence
/// vector (the PR 3 oracle) or a paged table (DESIGN.md §3.5). Logits
/// are a pure function of the token history either way, so the two
/// representations are bit-identical in behavior.
#[derive(Debug, Clone)]
enum TokenStore {
    Mono(Vec<u32>),
    Paged(PagedTokens),
}

/// Token-history cache of the reference backend.
#[derive(Debug, Clone)]
pub struct RefCache {
    store: TokenStore,
}

impl RefCache {
    pub fn pos(&self) -> usize {
        match &self.store {
            TokenStore::Mono(t) => t.len(),
            TokenStore::Paged(p) => p.len,
        }
    }

    pub fn device_bytes(&self) -> usize {
        self.pos() * 4
    }
}

/// Margin of scripted (deterministic) continuation tokens: large enough
/// that nucleus sampling at the paper's temperature/top-p always picks
/// the scripted token.
const SCRIPT_MARGIN: f32 = 12.0;
/// Peak answer margin once a solvable chain is fully resolved (entropy
/// effectively zero).
const SHARP_MARGIN: f32 = 9.0;
/// Degraded peak margin for out-of-distribution chains (n > 10).
const OOD_MARGIN: f32 = 2.0;
/// Logit floor for non-number tokens in the answer slot.
const NON_ANSWER_LOGIT: f32 = -6.0;

/// Deterministic in-process reference model.
pub struct RefBackend {
    name: String,
    vocab: Vocab,
    seq_len: usize,
    probe_len: usize,
    batch: Option<usize>,
    /// Per-model salt so main and proxy are distinct-but-correlated
    /// monitors (the black-box setting).
    salt: u64,
    /// Shared page pool (`Some` = paged caches; `None` = monolithic).
    pool: Option<Rc<RefCell<PagePool<u32>>>>,
    page_size: usize,
    /// Reusable token gather buffer: probes and decodes read the page
    /// table through here without allocating or touching the pool.
    scratch: RefCell<Vec<u32>>,
    /// Reusable f64 exp buffer for the fused entropy kernel, so a probe
    /// performs no per-call allocation beyond its logits.
    entropy_scratch: RefCell<Vec<f64>>,
    counters: RuntimeCounters,
}

/// What the reference model read off the prompt.
struct Parsed {
    /// Operand values; `None` where masked with UNK (corrupted).
    ops: Vec<Option<u32>>,
    tool: bool,
    /// Index just past `<think>`, when present.
    think_end: Option<usize>,
}

fn mix(h: u64, x: u64) -> u64 {
    // boost::hash_combine-style mixer over SplitMix64
    let mut z = h ^ x.wrapping_add(0x9E3779B97F4A7C15)
        .wrapping_add(h << 6)
        .wrapping_add(h >> 2);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 31)
}

/// Uniform f32 in [0, 1) from a hash.
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32) / (1u64 << 24) as f32
}

/// Logits peaked at `idx` with the given margin over a zero baseline.
fn peaked(n: usize, idx: usize, margin: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    out[idx] = margin;
    out
}

/// Shannon entropy (nats, temperature 1) of softmax(logits), computed in
/// f64 with the exact accumulation order of the Pallas entropy kernel:
/// max reduction, then one fused exp+sum sweep (exps staged into
/// `scratch`), then the `-p·ln(p)` reduction over the staged exps.
///
/// The fusion folds the old separate `exps.iter().sum()` pass into the
/// exp sweep — a sequential left fold either way, so the result is
/// bit-identical to the unfused three-pass form (pinned by
/// `fused_entropy_bit_matches_unfused`). Deeper fusion (online max
/// renormalization à la one-pass softmax) would change the f64 op order
/// and break that equality, so it is deliberately NOT done. `scratch` is
/// reused across calls, making the probe hot path allocation-free at
/// steady state.
fn entropy_into(logits: &[f32], scratch: &mut Vec<f64>) -> f32 {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max) as f64;
    scratch.clear();
    scratch.reserve(logits.len());
    let mut zsum = 0.0f64;
    for &z in logits {
        let e = (z as f64 - mx).exp();
        scratch.push(e);
        zsum += e;
    }
    let mut h = 0.0f64;
    for &e in scratch.iter() {
        let p = e / zsum;
        // guard, not branchless: p == 0 (exp underflow) would contribute
        // 0 · ln 0 = NaN
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h as f32
}

impl RefBackend {
    /// Paged reference model at the default page size — the mainline
    /// cache representation since DESIGN.md §3.5.
    pub fn new(name: &str, vocab: Vocab, seq_len: usize, batch: Option<usize>) -> RefBackend {
        RefBackend::with_pages(name, vocab, seq_len, batch, Some(DEFAULT_PAGE_SIZE))
    }

    /// Monolithic full-sequence caches: the pre-paging representation,
    /// kept as the equivalence oracle (same-seed serve runs must emit
    /// byte-identical metrics against either store).
    pub fn monolithic(
        name: &str,
        vocab: Vocab,
        seq_len: usize,
        batch: Option<usize>,
    ) -> RefBackend {
        RefBackend::with_pages(name, vocab, seq_len, batch, None)
    }

    /// Full constructor: `page_size` `Some(p)` = paged pool at `p`
    /// tokens per page, `None` = monolithic.
    pub fn with_pages(
        name: &str,
        vocab: Vocab,
        seq_len: usize,
        batch: Option<usize>,
        page_size: Option<usize>,
    ) -> RefBackend {
        let salt = name.bytes().fold(0xEA7u64, |h, b| mix(h, b as u64));
        let ps = page_size.unwrap_or(seq_len).max(1);
        RefBackend {
            name: name.to_string(),
            vocab,
            seq_len,
            probe_len: 4,
            batch,
            salt,
            pool: page_size.map(|_| Rc::new(RefCell::new(PagePool::new_growable(ps)))),
            page_size: ps,
            scratch: RefCell::new(Vec::new()),
            entropy_scratch: RefCell::new(Vec::new()),
            counters: RuntimeCounters::default(),
        }
    }

    /// The default "main" reference model: artifact-shaped dimensions
    /// (seq 128) with an 8-wide fused batch lane.
    pub fn main(vocab: Vocab) -> RefBackend {
        RefBackend::new("ref-main", vocab, 128, Some(8))
    }

    /// The default "proxy" monitor: no fused batch entry point (probes
    /// and mirrored decodes are serviced out-of-band anyway).
    pub fn proxy(vocab: Vocab) -> RefBackend {
        RefBackend::new("ref-proxy", vocab, 128, None)
    }

    /// Commit one token into a cache (CoW-aware on the paged store).
    fn push_token(&self, cache: &mut RefCache, token: u32) -> Result<()> {
        match &mut cache.store {
            TokenStore::Mono(t) => t.push(token),
            TokenStore::Paged(p) => {
                if p.push(token)? {
                    RuntimeCounters::bump(&self.counters.pages_copied);
                }
            }
        }
        Ok(())
    }

    /// Next-token logits for `cache`'s history plus an optional virtual
    /// `suffix` — the probe path reads the page table through the
    /// scratch buffer and never copies or allocates pool pages.
    fn logits_for(&self, cache: &RefCache, suffix: &[u32]) -> Vec<f32> {
        let mut scratch = self.scratch.borrow_mut();
        match &cache.store {
            TokenStore::Mono(t) if suffix.is_empty() => return self.next_logits(t),
            TokenStore::Mono(t) => {
                scratch.clear();
                scratch.extend_from_slice(t);
            }
            TokenStore::Paged(p) => p.gather_into(&mut scratch),
        }
        scratch.extend_from_slice(suffix);
        self.next_logits(&scratch)
    }

    fn parse(&self, tokens: &[u32]) -> Parsed {
        let v = self.vocab;
        let mut ops = Vec::new();
        let mut tool = false;
        let mut think_end = None;
        for (i, &t) in tokens.iter().enumerate() {
            if t == v.think {
                think_end = Some(i + 1);
                break;
            }
            if t == v.tool {
                tool = true;
            }
            if let Some(x) = v.num_value(t) {
                ops.push(Some(x));
            } else if t == v.unk {
                ops.push(None);
            }
        }
        Parsed {
            ops,
            tool,
            think_end,
        }
    }

    fn question_hash(&self, p: &Parsed) -> u64 {
        let mut h = mix(self.salt, p.tool as u64 + 1);
        for op in &p.ops {
            h = mix(h, op.map(|x| x as u64 + 2).unwrap_or(1));
        }
        h
    }

    /// Overthinking verification lines appended after the chain resolves
    /// (per-question, 2..=5) — the tail an adaptive exit can cut.
    fn extra_lines(&self, p: &Parsed) -> usize {
        2 + (self.question_hash(p) % 4) as usize
    }

    /// The value concluded by reasoning line `line` (1-based): the
    /// partial sum of the first min(line, n) operands (chain-sum), or the
    /// min(line, n)-th operand (tool copy task). `None` when an UNK mask
    /// makes it unknowable.
    fn line_value(&self, p: &Parsed, line: usize) -> Option<u32> {
        let n = p.ops.len();
        if n == 0 || line == 0 {
            return None;
        }
        let upto = line.min(n);
        if p.tool {
            p.ops[upto - 1]
        } else {
            let mut s = 0u32;
            for op in &p.ops[..upto] {
                s = (s + (*op)?) % self.vocab.modulus;
            }
            Some(s)
        }
    }

    /// The forced-answer distribution ("what comes after ANS") given how
    /// many reasoning lines were committed — the signal the EAT probe
    /// measures.
    fn answer_logits(&self, p: &Parsed, lines_done: usize) -> Vec<f32> {
        let v = self.vocab;
        let nv = v.size as usize;
        let m = v.modulus;
        let n = p.ops.len().max(1);
        let known = if p.tool { n } else { lines_done.min(n) };

        let mut out = vec![NON_ANSWER_LOGIT; nv];
        for val in 0..m {
            out[v.num(val) as usize] = 0.0;
        }
        match self.line_value(p, n) {
            None => {
                // unknowable: noisy, never-stabilizing high entropy
                let h = mix(self.question_hash(p), lines_done as u64 + 0xA);
                let center = (h % m as u64) as u32;
                let margin = 0.3 + 1.5 * unit(mix(h, 0x17));
                out[v.num(center) as usize] = margin;
            }
            Some(ans) => {
                let max_margin = if n > 10 { OOD_MARGIN } else { SHARP_MARGIN };
                let progress = known as f32 / n as f32;
                // small salt-dependent wiggle keeps the proxy monitor
                // distinct-but-close to the self-monitor
                let wiggle =
                    1.0 + 0.05 * (unit(mix(self.salt, known as u64 + 0x31)) - 0.5);
                let margin = max_margin * progress * progress * wiggle;
                let center = if known >= n {
                    ans
                } else {
                    // belief drifts line-to-line until the chain resolves
                    let drift =
                        (mix(self.question_hash(p), known as u64 + 0xB) % m as u64) as u32;
                    (self.line_value(p, known).unwrap_or(0) + drift) % m
                };
                out[v.num(center) as usize] = margin.max(0.0);
            }
        }
        out
    }

    /// Next-token distribution inside the reasoning stream: scripted
    /// `VER value ⏎` lines, then self-termination once the chain is
    /// resolved and re-verified.
    fn reasoning_logits(&self, p: &Parsed, tail: &[u32]) -> Vec<f32> {
        let v = self.vocab;
        let nv = v.size as usize;
        let n = p.ops.len().max(1);
        let lines_done = tail.iter().filter(|&&t| t == v.nl).count();
        let in_line = tail
            .iter()
            .rposition(|&t| t == v.nl)
            .map(|i| tail.len() - i - 1)
            .unwrap_or(tail.len());
        let planned = n + self.extra_lines(p);
        if in_line == 0 && lines_done >= planned && self.line_value(p, n).is_some() {
            // fully resolved and re-verified: stop thinking on our own
            return peaked(nv, v.ethink as usize, SCRIPT_MARGIN);
        }
        match in_line {
            0 => peaked(nv, v.ver as usize, SCRIPT_MARGIN),
            1 => match self.line_value(p, lines_done + 1) {
                Some(val) => peaked(nv, v.num(val) as usize, SCRIPT_MARGIN),
                None => peaked(nv, v.unk as usize, SCRIPT_MARGIN),
            },
            _ => peaked(nv, v.nl as usize, SCRIPT_MARGIN),
        }
    }

    /// The full next-token function: pure in the token history.
    fn next_logits(&self, tokens: &[u32]) -> Vec<f32> {
        let v = self.vocab;
        let nv = v.size as usize;
        let p = self.parse(tokens);
        let Some(te) = p.think_end else {
            // prompt still streaming: the model expects <think> next
            return peaked(nv, v.think as usize, SCRIPT_MARGIN);
        };
        let tail = &tokens[te..];
        if let Some(e) = tail.iter().position(|&t| t == v.ethink) {
            // answer elicitation (forced or probed): react to the last
            // token; reasoning progress is frozen at the `</think>` point
            let lines_done = tail[..e].iter().filter(|&&t| t == v.nl).count();
            let last = *tokens.last().expect("tail is non-empty here");
            return if last == v.ethink {
                peaked(nv, v.final_ as usize, SCRIPT_MARGIN)
            } else if last == v.final_ || last == v.lbrack {
                peaked(nv, v.ans as usize, SCRIPT_MARGIN)
            } else if last == v.ans {
                self.answer_logits(&p, lines_done)
            } else {
                // answer value / EOS / anything else: absorb on EOS
                peaked(nv, v.eos as usize, SCRIPT_MARGIN)
            };
        }
        self.reasoning_logits(&p, tail)
    }
}

fn ref_cache(cache: &BackendCache) -> Result<&RefCache> {
    match cache {
        BackendCache::Ref(c) => Ok(c),
        #[cfg(feature = "pjrt")]
        _ => anyhow::bail!("reference backend received a non-reference cache"),
    }
}

fn ref_cache_mut(cache: &mut BackendCache) -> Result<&mut RefCache> {
    match cache {
        BackendCache::Ref(c) => Ok(c),
        #[cfg(feature = "pjrt")]
        _ => anyhow::bail!("reference backend received a non-reference cache"),
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn describe(&self) -> String {
        format!(
            "{:<9} reference (table-driven chain-sum reasoner) seq={} probe={} batch={:?}",
            self.name, self.seq_len, self.probe_len, self.batch
        )
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn probe_len(&self) -> usize {
        self.probe_len
    }

    fn vocab_size(&self) -> usize {
        self.vocab.size as usize
    }

    fn batch_width(&self) -> Option<usize> {
        self.batch
    }

    fn page_size(&self) -> Option<usize> {
        self.pool.as_ref().map(|_| self.page_size)
    }

    fn pool_pages_in_use(&self) -> Option<usize> {
        self.pool.as_ref().map(|p| p.borrow().pages_in_use())
    }

    fn pool_alloc_free(&self) -> Option<(u64, u64)> {
        self.pool.as_ref().map(|p| {
            let c = p.borrow().counters();
            (c.allocs, c.frees)
        })
    }

    fn cache_elems(&self) -> usize {
        // nominal, for KV byte accounting only
        self.seq_len * 16
    }

    fn param_elems(&self) -> usize {
        0
    }

    fn prefill(&self, tokens: &[u32]) -> Result<(Vec<f32>, BackendCache)> {
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() <= self.seq_len,
            "prompt length {} out of range 1..={}",
            tokens.len(),
            self.seq_len
        );
        let store = match &self.pool {
            Some(pool) => {
                TokenStore::Paged(PagedTokens::from_slice(pool, self.page_size, tokens)?)
            }
            None => TokenStore::Mono(tokens.to_vec()),
        };
        let cache = RefCache { store };
        let logits = self.logits_for(&cache, &[]);
        RuntimeCounters::bump(&self.counters.prefills);
        Ok((logits, BackendCache::Ref(cache)))
    }

    fn decode(&self, cache: &mut BackendCache, token: u32) -> Result<Vec<f32>> {
        let c = ref_cache_mut(cache)?;
        anyhow::ensure!(
            c.pos() < self.seq_len,
            "KV cache full (pos {} of {})",
            c.pos(),
            self.seq_len
        );
        self.push_token(c, token)?;
        RuntimeCounters::bump(&self.counters.decodes);
        Ok(self.logits_for(c, &[]))
    }

    fn probe(&self, cache: &BackendCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)> {
        let c = ref_cache(cache)?;
        anyhow::ensure!(
            !suffix.is_empty() && suffix.len() <= self.probe_len,
            "probe suffix length {} out of range 1..={}",
            suffix.len(),
            self.probe_len
        );
        anyhow::ensure!(
            c.pos() + suffix.len() <= self.seq_len,
            "probe would overflow the sequence"
        );
        // virtual append through the scratch buffer: no page alloc, no
        // page copy, no cache mutation — the paper's "free" probe
        let logits = self.logits_for(c, suffix);
        RuntimeCounters::bump(&self.counters.probes);
        let h = entropy_into(&logits, &mut self.entropy_scratch.borrow_mut());
        Ok((h, logits))
    }

    fn fork(&self, cache: &BackendCache) -> Result<BackendCache> {
        let c = ref_cache(cache)?;
        if let TokenStore::Paged(p) = &c.store {
            // O(pages) refcount bumps; divergence copies one page at a
            // time via CoW
            RuntimeCounters::bump(&self.counters.cow_forks);
            RuntimeCounters::add(&self.counters.pages_shared, p.page_count() as u64);
        }
        Ok(BackendCache::Ref(c.clone()))
    }

    fn decode_batch(&self, lanes: &mut [Option<BatchLane<'_>>]) -> Result<Vec<Option<Vec<f32>>>> {
        let width = self
            .batch
            .ok_or_else(|| anyhow::anyhow!("backend `{}` has no fused batch lane", self.name))?;
        anyhow::ensure!(
            lanes.len() == width,
            "decode_batch got {} lanes, batch width is {width}",
            lanes.len()
        );
        let mut out = Vec::with_capacity(lanes.len());
        let mut engaged = 0u64;
        for lane in lanes.iter_mut() {
            match lane {
                Some(l) => {
                    let c = ref_cache_mut(l.cache)?;
                    anyhow::ensure!(
                        c.pos() < self.seq_len,
                        "KV cache full (pos {} of {})",
                        c.pos(),
                        self.seq_len
                    );
                    self.push_token(c, l.token)?;
                    out.push(Some(self.logits_for(c, &[])));
                    engaged += 1;
                }
                None => out.push(None),
            }
        }
        anyhow::ensure!(engaged > 0, "decode_batch needs at least one engaged lane");
        RuntimeCounters::bump(&self.counters.batch_decodes);
        RuntimeCounters::add(&self.counters.batch_lanes, engaged);
        Ok(out)
    }

    fn counters(&self) -> &RuntimeCounters {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> RefBackend {
        RefBackend::main(Vocab::default_layout())
    }

    fn prompt(ops: &[u32]) -> Vec<u32> {
        let v = Vocab::default_layout();
        let mut p = vec![v.bos, v.q];
        p.extend(ops.iter().map(|&a| v.num(a)));
        p.push(v.sep);
        p.push(v.think);
        p
    }

    #[test]
    fn scripted_reasoning_self_terminates_with_correct_answer() {
        let v = Vocab::default_layout();
        let b = backend();
        let ops = [3u32, 7, 9];
        let want = ops.iter().sum::<u32>() % v.modulus;
        let (mut logits, mut cache) = b.prefill(&prompt(&ops)).unwrap();
        let mut saw_ethink = false;
        for _ in 0..100 {
            let tok = crate::sampler::argmax(&logits);
            if tok == v.ethink {
                saw_ethink = true;
                break;
            }
            logits = b.decode(&mut cache, tok).unwrap();
        }
        assert!(saw_ethink, "reference reasoner must self-terminate");
        // force the tail and greedily read the answer
        logits = b.decode(&mut cache, v.ethink).unwrap();
        assert_eq!(crate::sampler::argmax(&logits), v.final_);
        logits = b.decode(&mut cache, v.final_).unwrap();
        assert_eq!(crate::sampler::argmax(&logits), v.ans);
        logits = b.decode(&mut cache, v.ans).unwrap();
        assert_eq!(crate::sampler::argmax(&logits), v.num(want));
    }

    #[test]
    fn eat_collapses_as_the_chain_resolves() {
        let v = Vocab::default_layout();
        let b = backend();
        let (mut logits, mut cache) = b.prefill(&prompt(&[5, 2, 8, 1])).unwrap();
        let suffix = v.suffix_prefixed();
        let mut eats = Vec::new();
        for _ in 0..60 {
            let tok = crate::sampler::argmax(&logits);
            if tok == v.ethink {
                break;
            }
            logits = b.decode(&mut cache, tok).unwrap();
            if tok == v.nl {
                eats.push(b.probe(&cache, &suffix).unwrap().0);
            }
        }
        assert!(eats.len() >= 5, "expected several line probes, got {eats:?}");
        let first = eats[0];
        let last = *eats.last().unwrap();
        assert!(first > 2.5, "initial EAT should be near ln(32), got {first}");
        assert!(last < 0.1, "post-resolution EAT should collapse, got {last}");
        // probes never advanced the cache
        assert_eq!(b.counters().probes.get(), eats.len() as u64);
    }

    #[test]
    fn probe_does_not_mutate_cache() {
        let v = Vocab::default_layout();
        let b = backend();
        let (_l, cache) = b.prefill(&prompt(&[4, 4])).unwrap();
        let before = cache.pos();
        for _ in 0..3 {
            b.probe(&cache, &v.suffix_prefixed()).unwrap();
        }
        assert_eq!(cache.pos(), before);
    }

    #[test]
    fn corrupted_questions_never_stabilize() {
        let v = Vocab::default_layout();
        let b = backend();
        let p = vec![v.bos, v.q, v.num(3), v.unk, v.num(5), v.sep, v.think];
        let (mut logits, mut cache) = b.prefill(&p).unwrap();
        let mut eats = Vec::new();
        for _ in 0..80 {
            let tok = crate::sampler::argmax(&logits);
            assert_ne!(tok, v.ethink, "corrupted chain must not self-terminate");
            logits = b.decode(&mut cache, tok).unwrap();
            if tok == v.nl {
                eats.push(b.probe(&cache, &v.suffix_prefixed()).unwrap().0 as f64);
            }
            if eats.len() >= 12 {
                break;
            }
        }
        // stays high and keeps moving (never flat-lines near zero)
        assert!(eats.iter().all(|&e| e > 2.0), "{eats:?}");
        let spread = eats.iter().cloned().fold(f64::MIN, f64::max)
            - eats.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.01, "corrupted EAT must stay noisy: {eats:?}");
    }

    #[test]
    fn paged_and_monolithic_stores_are_bit_identical() {
        let v = Vocab::default_layout();
        let paged = RefBackend::with_pages("ref-main", v, 128, Some(8), Some(4));
        let mono = RefBackend::monolithic("ref-main", v, 128, Some(8));
        let p = prompt(&[5, 2, 8, 1]);
        let (mut lp, mut cp) = paged.prefill(&p).unwrap();
        let (mut lm, mut cm) = mono.prefill(&p).unwrap();
        assert_eq!(lp, lm, "prefill logits diverged");
        let suffix = v.suffix_prefixed();
        for _ in 0..60 {
            let tok = crate::sampler::argmax(&lm);
            if tok == v.ethink {
                break;
            }
            lp = paged.decode(&mut cp, tok).unwrap();
            lm = mono.decode(&mut cm, tok).unwrap();
            assert_eq!(lp, lm, "decode logits diverged");
            let (ep, glp) = paged.probe(&cp, &suffix).unwrap();
            let (em, glm) = mono.probe(&cm, &suffix).unwrap();
            assert_eq!(ep, em);
            assert_eq!(glp, glm, "probe logits diverged");
        }
        assert_eq!(cp.pos(), cm.pos());
        // decodes and probes never copied or shared a single page
        assert_eq!(paged.counters().pages_copied.get(), 0);
        assert_eq!(paged.counters().pages_shared.get(), 0);
        assert_eq!(paged.counters().cow_forks.get(), 0);
    }

    #[test]
    fn cow_fork_copies_exactly_the_divergent_tail_page() {
        let v = Vocab::default_layout();
        let b = RefBackend::with_pages("ref-main", v, 128, None, Some(4));
        // 6 prompt tokens at page size 4: one full page + a 2-token tail
        let (_l, cache) = b.prefill(&prompt(&[3, 7])).unwrap();
        assert_eq!(cache.pos(), 6);
        let mut fork = b.fork(&cache).unwrap();
        assert_eq!(b.counters().cow_forks.get(), 1);
        assert_eq!(b.counters().pages_shared.get(), 2);
        assert_eq!(b.counters().pages_copied.get(), 0, "fork itself copies nothing");
        // first divergent write CoWs the shared tail page — exactly one
        b.decode(&mut fork, v.ver).unwrap();
        assert_eq!(b.counters().pages_copied.get(), 1);
        // the parent still writes its own (now exclusive) tail in place
        let mut parent = cache;
        b.decode(&mut parent, v.nl).unwrap();
        assert_eq!(b.counters().pages_copied.get(), 1, "parent write must not CoW");
        // histories diverged: logits disagree from here on
        assert_ne!(
            b.probe(&parent, &v.suffix_prefixed()).unwrap().1,
            b.probe(&fork, &v.suffix_prefixed()).unwrap().1
        );
    }

    #[test]
    fn dropping_caches_frees_every_page_exactly_once() {
        let v = Vocab::default_layout();
        let b = RefBackend::with_pages("ref-main", v, 128, None, Some(4));
        {
            let (_l, cache) = b.prefill(&prompt(&[1, 2, 3])).unwrap();
            let forks: Vec<BackendCache> = (0..5).map(|_| b.fork(&cache).unwrap()).collect();
            assert!(b.pool_pages_in_use().unwrap() > 0);
            drop(forks);
            drop(cache);
        }
        assert_eq!(b.pool_pages_in_use(), Some(0), "pages leaked");
    }

    #[test]
    fn fused_decode_is_bit_identical_to_sequential() {
        let b = backend();
        let width = b.batch_width().unwrap();
        let mk = |i: u32| prompt(&[i % 7 + 1, (i + 3) % 7 + 1]);
        // sequential
        let mut seq_logits = Vec::new();
        let mut seq_caches = Vec::new();
        for i in 0..3u32 {
            let (_l, mut c) = b.prefill(&mk(i)).unwrap();
            seq_logits.push(b.decode(&mut c, b.vocab.ver).unwrap());
            seq_caches.push(c);
        }
        // fused (3 engaged lanes + padding)
        let mut fused_caches: Vec<BackendCache> =
            (0..3u32).map(|i| b.prefill(&mk(i)).unwrap().1).collect();
        let mut lanes: Vec<Option<BatchLane>> = fused_caches
            .iter_mut()
            .map(|c| {
                Some(BatchLane {
                    cache: c,
                    token: b.vocab.ver,
                })
            })
            .collect();
        lanes.resize_with(width, || None);
        let out = b.decode_batch(&mut lanes).unwrap();
        drop(lanes);
        for i in 0..3 {
            assert_eq!(out[i].as_ref().unwrap(), &seq_logits[i]);
            assert_eq!(fused_caches[i].pos(), seq_caches[i].pos());
        }
        assert_eq!(b.counters().batch_decodes.get(), 1);
        assert_eq!(b.counters().batch_lanes.get(), 3);
    }

    #[test]
    fn fused_entropy_bit_matches_unfused() {
        // the pre-fusion three-pass formulation (allocated per call)
        fn unfused(logits: &[f32]) -> f32 {
            let mx = logits.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let exps: Vec<f64> =
                logits.iter().map(|&z| (z as f64 - mx).exp()).collect();
            let zsum: f64 = exps.iter().sum();
            let mut h = 0.0f64;
            for &e in &exps {
                let p = e / zsum;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            h as f32
        }
        let mut scratch = Vec::new();
        for case in 0..200u64 {
            let n = 1 + (mix(case, 17) % 96) as usize;
            let logits: Vec<f32> = (0..n)
                .map(|i| (unit(mix(case, i as u64)) - 0.5) * 40.0)
                .collect();
            assert_eq!(
                entropy_into(&logits, &mut scratch).to_bits(),
                unfused(&logits).to_bits(),
                "case {case}"
            );
        }
        // extreme spread drives exp to underflow: the p > 0 guard
        let logits = vec![0.0f32, -800.0, 30.0, -1000.0];
        assert_eq!(
            entropy_into(&logits, &mut scratch).to_bits(),
            unfused(&logits).to_bits()
        );
    }

    #[test]
    fn entropy_scratch_capacity_is_reused() {
        let mut scratch = Vec::new();
        let logits = vec![0.5f32; 64];
        entropy_into(&logits, &mut scratch);
        let cap = scratch.capacity();
        assert!(cap >= 64);
        for _ in 0..10 {
            entropy_into(&logits, &mut scratch);
        }
        assert_eq!(scratch.capacity(), cap, "entropy scratch reallocated");
    }
}
