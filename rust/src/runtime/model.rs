//! Typed model runtime: prefill / decode / probe / decode_batch over the
//! AOT artifacts.
//!
//! Buffer discipline (see DESIGN.md §6): weights are uploaded to device
//! once at load time and stay resident. KV caches are passed as device
//! buffers; because PJRT hands multi-output results back as a *single
//! tuple buffer* (no untupling in the `xla` crate), each decode step
//! downloads the output tuple and re-uploads the caches — the host mirror
//! this produces is kept on the `KvCache` and doubles as the cheap
//! cache-fork mechanism that rollout-based baselines (#UA@K, Alg. 3) need.

use std::cell::Cell;
use std::path::Path;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::client::{lit_f32_scalar, lit_f32_vec, Client, Executable};
use super::weights::Weights;
use crate::config::ModelConfig;

/// Per-sequence KV cache: device buffers + host mirror + write position.
pub struct KvCache {
    kc: PjRtBuffer,
    vc: PjRtBuffer,
    kc_host: Vec<f32>,
    vc_host: Vec<f32>,
    /// Next write position (== number of committed tokens).
    pub pos: usize,
}

impl KvCache {
    /// Bytes held on device by this cache (K + V), for the KV manager.
    pub fn device_bytes(&self) -> usize {
        (self.kc_host.len() + self.vc_host.len()) * 4
    }
}

/// Execution counters for the perf report (`repro info`, §Perf).
#[derive(Debug, Default)]
pub struct RuntimeCounters {
    pub prefills: Cell<u64>,
    pub decodes: Cell<u64>,
    pub probes: Cell<u64>,
    pub batch_decodes: Cell<u64>,
}

/// One loaded model: compiled executables + resident weights.
pub struct ModelRuntime {
    pub cfg: ModelConfig,
    weights: Weights,
    exe_prefill: Executable,
    exe_decode: Executable,
    exe_probe: Executable,
    exe_decode_batch: Option<Executable>,
    pub counters: RuntimeCounters,
}

impl ModelRuntime {
    pub fn load(client: &Client, dir: &Path, cfg: &ModelConfig) -> Result<ModelRuntime> {
        let weights = Weights::load(
            client,
            &dir.join(&cfg.manifest),
            &dir.join(&cfg.weights),
        )
        .with_context(|| format!("loading weights for model `{}`", cfg.name))?;
        anyhow::ensure!(
            weights.specs.len() == cfg.n_params,
            "manifest has {} params, config says {}",
            weights.specs.len(),
            cfg.n_params
        );
        let exe_prefill = client.compile_hlo_text(&dir.join(&cfg.hlo_prefill))?;
        let exe_decode = client.compile_hlo_text(&dir.join(&cfg.hlo_decode))?;
        let exe_probe = client.compile_hlo_text(&dir.join(&cfg.hlo_probe))?;
        let exe_decode_batch = cfg
            .hlo_decode_batch
            .as_ref()
            .map(|f| client.compile_hlo_text(&dir.join(f)))
            .transpose()?;
        Ok(ModelRuntime {
            cfg: cfg.clone(),
            weights,
            exe_prefill,
            exe_decode,
            exe_probe,
            exe_decode_batch,
            counters: RuntimeCounters::default(),
        })
    }

    fn args_with<'a>(&'a self, extra: &[&'a PjRtBuffer]) -> Vec<&'a PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = self.weights.buffers.iter().collect();
        args.extend_from_slice(extra);
        args
    }

    fn cache_dims(&self) -> [usize; 4] {
        [
            self.cfg.n_layer,
            self.cfg.n_head,
            self.cfg.seq_len,
            self.cfg.d_head,
        ]
    }

    /// Run the prompt through the model; returns logits at position n-1 and
    /// a fresh KV cache positioned at n.
    pub fn prefill(&self, client: &Client, tokens: &[u32]) -> Result<(Vec<f32>, KvCache)> {
        let s = self.cfg.seq_len;
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() <= s,
            "prompt length {} out of range 1..={s}",
            tokens.len()
        );
        let mut padded = vec![0i32; s];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let toks_buf = client.buf_i32(&padded, &[s])?;
        let n_buf = client.buf_scalar_i32(tokens.len() as i32)?;
        let outs = self
            .exe_prefill
            .run(&self.args_with(&[&toks_buf, &n_buf]))?;
        anyhow::ensure!(outs.len() == 3, "prefill must return 3 outputs");
        self.counters.prefills.set(self.counters.prefills.get() + 1);

        let logits = lit_f32_vec(&outs[0])?;
        let kc_host = lit_f32_vec(&outs[1])?;
        let vc_host = lit_f32_vec(&outs[2])?;
        let dims = self.cache_dims();
        let kc = client.buf_f32(&kc_host, &dims)?;
        let vc = client.buf_f32(&vc_host, &dims)?;
        Ok((
            logits,
            KvCache {
                kc,
                vc,
                kc_host,
                vc_host,
                pos: tokens.len(),
            },
        ))
    }

    /// One committed decode step: writes K/V at `cache.pos`, returns the
    /// next-token logits, advances the cache.
    pub fn decode(&self, client: &Client, cache: &mut KvCache, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            cache.pos < self.cfg.seq_len,
            "KV cache full (pos {} of {})",
            cache.pos,
            self.cfg.seq_len
        );
        let pos_buf = client.buf_scalar_i32(cache.pos as i32)?;
        let tok_buf = client.buf_scalar_i32(token as i32)?;
        let outs = self
            .exe_decode
            .run(&self.args_with(&[&cache.kc, &cache.vc, &pos_buf, &tok_buf]))?;
        anyhow::ensure!(outs.len() == 3, "decode must return 3 outputs");
        self.counters.decodes.set(self.counters.decodes.get() + 1);

        let logits = lit_f32_vec(&outs[0])?;
        cache.kc_host = lit_f32_vec(&outs[1])?;
        cache.vc_host = lit_f32_vec(&outs[2])?;
        let dims = self.cache_dims();
        cache.kc = client.buf_f32(&cache.kc_host, &dims)?;
        cache.vc = client.buf_f32(&cache.vc_host, &dims)?;
        cache.pos += 1;
        Ok(logits)
    }

    /// The EAT probe (Alg. 1 line 6): virtually append `suffix` after the
    /// current position and return (entropy of the following token, its
    /// full logits). The cache is NOT modified — this is the paper's
    /// "one extra token" overhead trick (§4.3).
    pub fn probe(&self, client: &Client, cache: &KvCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)> {
        let pk = self.cfg.probe_len;
        anyhow::ensure!(
            !suffix.is_empty() && suffix.len() <= pk,
            "probe suffix length {} out of range 1..={pk}",
            suffix.len()
        );
        anyhow::ensure!(
            cache.pos + suffix.len() <= self.cfg.seq_len,
            "probe would overflow the sequence"
        );
        let mut padded = vec![0i32; pk];
        for (i, &t) in suffix.iter().enumerate() {
            padded[i] = t as i32;
        }
        let suf_buf = client.buf_i32(&padded, &[pk])?;
        let slen_buf = client.buf_scalar_i32(suffix.len() as i32)?;
        let pos_buf = client.buf_scalar_i32(cache.pos as i32)?;
        let outs = self.exe_probe.run(&self.args_with(&[
            &cache.kc, &cache.vc, &pos_buf, &suf_buf, &slen_buf,
        ]))?;
        anyhow::ensure!(outs.len() == 2, "probe must return 2 outputs");
        self.counters.probes.set(self.counters.probes.get() + 1);
        Ok((lit_f32_scalar(&outs[0])?, lit_f32_vec(&outs[1])?))
    }

    /// Fork a cache (device buffers re-created from the host mirror) —
    /// used by rollout-based baselines that must decode hypothetical
    /// continuations without disturbing the request's real cache.
    pub fn fork_cache(&self, client: &Client, cache: &KvCache) -> Result<KvCache> {
        let dims = self.cache_dims();
        Ok(KvCache {
            kc: client.buf_f32(&cache.kc_host, &dims)?,
            vc: client.buf_f32(&cache.vc_host, &dims)?,
            kc_host: cache.kc_host.clone(),
            vc_host: cache.vc_host.clone(),
            pos: cache.pos,
        })
    }

    /// Build a cache for another model by re-prefilling the same tokens —
    /// the black-box proxy path (proxy recomputes its own cache over the
    /// received reasoning text).
    pub fn has_batch(&self) -> bool {
        self.exe_decode_batch.is_some()
    }

    /// Fused batched decode over B slots (continuous batching ablation).
    /// `caches` must have exactly cfg.batch entries; inactive slots can
    /// pass any token (their outputs are ignored by the caller).
    pub fn decode_batch(
        &self,
        client: &Client,
        caches: &mut [KvCache],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.cfg.batch;
        let exe = self
            .exe_decode_batch
            .as_ref()
            .context("model has no decode_batch artifact")?;
        anyhow::ensure!(caches.len() == b && tokens.len() == b);
        let dims = self.cache_dims();
        let elems: usize = dims.iter().product();
        let bdims = [b, dims[0], dims[1], dims[2], dims[3]];

        let mut kc_all = vec![0f32; b * elems];
        let mut vc_all = vec![0f32; b * elems];
        for (i, c) in caches.iter().enumerate() {
            kc_all[i * elems..(i + 1) * elems].copy_from_slice(&c.kc_host);
            vc_all[i * elems..(i + 1) * elems].copy_from_slice(&c.vc_host);
        }
        let kc_buf = client.buf_f32(&kc_all, &bdims)?;
        let vc_buf = client.buf_f32(&vc_all, &bdims)?;
        let pos: Vec<i32> = caches.iter().map(|c| c.pos as i32).collect();
        let pos_buf = client.buf_i32(&pos, &[b])?;
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let toks_buf = client.buf_i32(&toks, &[b])?;

        let outs = exe.run(&self.args_with(&[&kc_buf, &vc_buf, &pos_buf, &toks_buf]))?;
        anyhow::ensure!(outs.len() == 3, "decode_batch must return 3 outputs");
        self.counters
            .batch_decodes
            .set(self.counters.batch_decodes.get() + 1);

        let logits_all = lit_f32_vec(&outs[0])?;
        let kc_new = lit_f32_vec(&outs[1])?;
        let vc_new = lit_f32_vec(&outs[2])?;
        let v = self.cfg.vocab;
        let mut per_slot = Vec::with_capacity(b);
        for (i, c) in caches.iter_mut().enumerate() {
            per_slot.push(logits_all[i * v..(i + 1) * v].to_vec());
            c.kc_host.copy_from_slice(&kc_new[i * elems..(i + 1) * elems]);
            c.vc_host.copy_from_slice(&vc_new[i * elems..(i + 1) * elems]);
            c.kc = client.buf_f32(&c.kc_host, &dims)?;
            c.vc = client.buf_f32(&c.vc_host, &dims)?;
            c.pos += 1;
        }
        Ok(per_slot)
    }

    /// Parameter count (for `repro info`).
    pub fn total_param_elems(&self) -> usize {
        self.weights.total_elems
    }
}
