//! Typed PJRT model runtime: prefill / decode / probe / fused batched
//! decode over the AOT artifacts, plus the [`PjrtBackend`] adapter that
//! exposes it through the [`Backend`] trait.
//!
//! Buffer discipline (DESIGN.md §6): weights are uploaded to device once
//! at load time and stay resident. Because PJRT hands multi-output
//! results back as a *single tuple buffer* (no untupling in the `xla`
//! crate), every step downloads the output tuple; the host mirror this
//! produces is kept on the [`KvCache`] — either as dense arrays
//! (monolithic mode) or as refcounted page tables into a shared pool
//! (paged mode, DESIGN.md §3.5), where `fork` is O(pages) refcount
//! bumps and a committed write scatters exactly one position with
//! copy-on-write. Two things keep the batched hot path off the
//! per-slot copy treadmill:
//!
//!  * per-slot *device* buffers are lazy — they are only materialized
//!    when a single-sequence entry point (decode / probe) actually needs
//!    them, so slots that live entirely in the fused batch never pay a
//!    per-slot upload;
//!  * the fused `decode_batch` keeps one slot-major scratch image of the
//!    whole batch; lanes whose (cache id, generation) still match the
//!    previous fused call skip the host-side gather entirely, and the
//!    downloaded output *becomes* the next call's resident image.

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::backend::{Backend, BackendCache, BatchLane, RuntimeCounters};
use super::client::{lit_f32_scalar, lit_f32_vec, Client, Executable};
use super::weights::Weights;
use crate::config::ModelConfig;
use crate::coordinator::kv::{PageId, PagePool, PageTable};

/// Paged host mirror: K and V [`PageTable`]s into the model's shared f32
/// pool (DESIGN.md §3.5). One page holds `page_size` sequence positions
/// laid out `[L, H, P, Dh]`, so a page slice of the dense `[L, H, S,
/// Dh]` cache is a per-(layer, head) run of contiguous rows. The
/// retain-on-Clone / release-on-Drop refcount discipline lives on the
/// generic table; writes go through its CoW `write`/`make_unique`.
#[derive(Clone)]
struct PagedKv {
    kp: PageTable<f32>,
    vp: PageTable<f32>,
}

/// Host-side cache representation: monolithic dense mirrors (the PR 3
/// oracle) or refcounted page tables.
enum KvStore {
    Mono { kc: Vec<f32>, vc: Vec<f32> },
    Paged(PagedKv),
}

/// Per-sequence KV cache: host mirror (dense or paged) + lazily
/// materialized device buffers + write position.
pub struct KvCache {
    store: KvStore,
    /// Next write position (== number of committed tokens).
    pub pos: usize,
    /// Unique cache identity (survives moves; used by the fused-batch
    /// residency check).
    id: u64,
    /// Bumped on every host-mirror mutation.
    gen: u64,
    dev: RefCell<DevBuffers>,
}

#[derive(Default)]
struct DevBuffers {
    kc: Option<PjRtBuffer>,
    vc: Option<PjRtBuffer>,
    /// Generation the device copies reflect.
    gen: u64,
}

impl KvCache {
    /// Bytes held by this cache's K + V image, for the KV manager.
    pub fn device_bytes(&self) -> usize {
        match &self.store {
            KvStore::Mono { kc, vc } => (kc.len() + vc.len()) * 4,
            KvStore::Paged(p) => {
                let per_page = p.kp.pool().borrow().page_elems();
                (p.kp.page_count() + p.vp.page_count()) * per_page * 4
            }
        }
    }
}

/// Reusable slot-major image of the batched K/V for `decode_batch`.
#[derive(Default)]
struct BatchScratch {
    kc_all: Vec<f32>,
    vc_all: Vec<f32>,
    /// (cache id, generation) the lane image currently holds.
    lane_tag: Vec<Option<(u64, u64)>>,
}

/// Paged-store configuration of one model: the shared f32 page pool
/// plus the page geometry.
struct PagedCfg {
    pool: Rc<RefCell<PagePool<f32>>>,
    /// Sequence positions per page.
    page_size: usize,
}

/// One loaded model: compiled executables + resident weights.
pub struct ModelRuntime {
    pub cfg: ModelConfig,
    weights: Weights,
    exe_prefill: Executable,
    exe_decode: Executable,
    exe_probe: Executable,
    exe_decode_batch: Option<Executable>,
    /// `Some` = caches are page tables into a shared pool (CoW forks);
    /// `None` = monolithic dense host mirrors.
    paged: Option<PagedCfg>,
    pub counters: RuntimeCounters,
    next_cache_id: Cell<u64>,
    batch_scratch: RefCell<BatchScratch>,
    /// Reusable dense K/V gather target for single-sequence uploads of
    /// paged caches (keeps the per-decode hot path allocation-free,
    /// like the monolithic mirror it replaces).
    dense_scratch: RefCell<(Vec<f32>, Vec<f32>)>,
}

impl ModelRuntime {
    /// Load with monolithic caches (the PR 3 oracle representation).
    pub fn load(client: &Client, dir: &Path, cfg: &ModelConfig) -> Result<ModelRuntime> {
        ModelRuntime::load_with(client, dir, cfg, None)
    }

    /// Load with an optional paged KV store (`page_size` tokens per
    /// page).
    pub fn load_with(
        client: &Client,
        dir: &Path,
        cfg: &ModelConfig,
        page_size: Option<usize>,
    ) -> Result<ModelRuntime> {
        let weights = Weights::load(
            client,
            &dir.join(&cfg.manifest),
            &dir.join(&cfg.weights),
        )
        .with_context(|| format!("loading weights for model `{}`", cfg.name))?;
        anyhow::ensure!(
            weights.specs.len() == cfg.n_params,
            "manifest has {} params, config says {}",
            weights.specs.len(),
            cfg.n_params
        );
        let exe_prefill = client.compile_hlo_text(&dir.join(&cfg.hlo_prefill))?;
        let exe_decode = client.compile_hlo_text(&dir.join(&cfg.hlo_decode))?;
        let exe_probe = client.compile_hlo_text(&dir.join(&cfg.hlo_probe))?;
        let exe_decode_batch = cfg
            .hlo_decode_batch
            .as_ref()
            .map(|f| client.compile_hlo_text(&dir.join(f)))
            .transpose()?;
        let paged = page_size.map(|p| {
            let p = p.clamp(1, cfg.seq_len);
            PagedCfg {
                pool: Rc::new(RefCell::new(PagePool::new_growable(
                    cfg.n_layer * cfg.n_head * p * cfg.d_head,
                ))),
                page_size: p,
            }
        });
        Ok(ModelRuntime {
            cfg: cfg.clone(),
            weights,
            exe_prefill,
            exe_decode,
            exe_probe,
            exe_decode_batch,
            paged,
            counters: RuntimeCounters::default(),
            next_cache_id: Cell::new(0),
            batch_scratch: RefCell::new(BatchScratch::default()),
            dense_scratch: RefCell::new((Vec::new(), Vec::new())),
        })
    }

    /// Tokens per KV page (None = monolithic caches).
    pub fn page_size(&self) -> Option<usize> {
        self.paged.as_ref().map(|p| p.page_size)
    }

    fn args_with<'a>(&'a self, extra: &[&'a PjRtBuffer]) -> Vec<&'a PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = self.weights.buffers.iter().collect();
        args.extend_from_slice(extra);
        args
    }

    fn cache_dims(&self) -> [usize; 4] {
        [
            self.cfg.n_layer,
            self.cfg.n_head,
            self.cfg.seq_len,
            self.cfg.d_head,
        ]
    }

    fn fresh_cache(&self, store: KvStore, pos: usize) -> KvCache {
        let id = self.next_cache_id.get();
        self.next_cache_id.set(id + 1);
        KvCache {
            store,
            pos,
            id,
            gen: 0,
            dev: RefCell::new(DevBuffers::default()),
        }
    }

    /// Build one side's page table from a downloaded dense `[L, H, S,
    /// Dh]` image, covering `pos` committed positions.
    fn side_table_from_dense(
        &self,
        pool: &Rc<RefCell<PagePool<f32>>>,
        page_size: usize,
        dense: &[f32],
        pos: usize,
    ) -> Result<PageTable<f32>> {
        let (lh, s, dh) = (self.cfg.n_layer * self.cfg.n_head, self.cfg.seq_len, self.cfg.d_head);
        let n_pages = crate::coordinator::kv::pages_for(pos, page_size);
        let mut table = PageTable::new(pool.clone());
        for pi in 0..n_pages {
            table.push_zeroed()?;
            let base = pi * page_size;
            let take = page_size.min(pos - base);
            table.write(pi, |page| {
                for b in 0..lh {
                    let src = (b * s + base) * dh;
                    let dst = b * page_size * dh;
                    page[dst..dst + take * dh].copy_from_slice(&dense[src..src + take * dh]);
                }
            })?;
        }
        Ok(table)
    }

    /// Gather one side's dense `[L, H, S, Dh]` image from its page
    /// table into `out` (zero-filled beyond the committed positions —
    /// the kernels mask everything at or past `pos` anyway).
    fn gather_side(
        &self,
        pool: &PagePool<f32>,
        page_size: usize,
        pages: &[PageId],
        out: &mut [f32],
    ) {
        let (lh, s, dh) = (self.cfg.n_layer * self.cfg.n_head, self.cfg.seq_len, self.cfg.d_head);
        out.fill(0.0);
        for (pi, pg) in pages.iter().enumerate() {
            let data = pool.page(*pg);
            let base = pi * page_size;
            let take = page_size.min(s - base);
            for b in 0..lh {
                let dst = (b * s + base) * dh;
                let src = b * page_size * dh;
                out[dst..dst + take * dh].copy_from_slice(&data[src..src + take * dh]);
            }
        }
    }

    /// Run `f` over the dense K/V image of the cache — borrowed
    /// directly for monolithic mirrors, gathered from the page tables
    /// into the reusable `dense_scratch` otherwise (no per-call
    /// allocation on the hot path).
    fn with_dense<R>(
        &self,
        cache: &KvCache,
        f: impl FnOnce(&[f32], &[f32]) -> Result<R>,
    ) -> Result<R> {
        match &cache.store {
            KvStore::Mono { kc, vc } => f(kc, vc),
            KvStore::Paged(p) => {
                let paged = self.paged.as_ref().context("paged cache on a mono runtime")?;
                let pool = p.kp.pool().borrow();
                let elems: usize = self.cache_dims().iter().product();
                let mut scratch = self.dense_scratch.borrow_mut();
                let (kc, vc) = &mut *scratch;
                kc.resize(elems, 0.0);
                vc.resize(elems, 0.0);
                self.gather_side(&pool, paged.page_size, p.kp.pages(), kc);
                self.gather_side(&pool, paged.page_size, p.vp.pages(), vc);
                drop(pool);
                f(kc, vc)
            }
        }
    }

    /// Scatter the single position `s` of a downloaded dense image into
    /// one side's page table: alloc the covering page on first touch,
    /// CoW it if shared, write the per-(layer, head) rows. Returns true
    /// when a page was physically copied.
    fn scatter_position(
        &self,
        table: &mut PageTable<f32>,
        page_size: usize,
        dense: &[f32],
        s: usize,
    ) -> Result<bool> {
        let (lh, seq, dh) = (self.cfg.n_layer * self.cfg.n_head, self.cfg.seq_len, self.cfg.d_head);
        let (pi, r) = (s / page_size, s % page_size);
        table.grow_to(pi + 1)?;
        let ((), copied) = table.write(pi, |page| {
            for b in 0..lh {
                let src = (b * seq + s) * dh;
                let dst = (b * page_size + r) * dh;
                page[dst..dst + dh].copy_from_slice(&dense[src..src + dh]);
            }
        })?;
        Ok(copied)
    }

    /// Install the downloaded dense K/V of a step that wrote position
    /// `s` into the host mirror: full replacement for monolithic
    /// mirrors, a one-position CoW scatter for paged ones.
    fn commit_written(
        &self,
        cache: &mut KvCache,
        kc: Vec<f32>,
        vc: Vec<f32>,
        s: usize,
    ) -> Result<()> {
        match &mut cache.store {
            KvStore::Mono { kc: mkc, vc: mvc } => {
                *mkc = kc;
                *mvc = vc;
            }
            KvStore::Paged(p) => {
                let paged = self.paged.as_ref().context("paged cache on a mono runtime")?;
                let ck = self.scatter_position(&mut p.kp, paged.page_size, &kc, s)?;
                let cv = self.scatter_position(&mut p.vp, paged.page_size, &vc, s)?;
                RuntimeCounters::add(&self.counters.pages_copied, ck as u64 + cv as u64);
            }
        }
        cache.pos += 1;
        cache.gen += 1;
        Ok(())
    }

    /// Materialize (or refresh) the per-slot device buffers from the host
    /// mirror. Lazy so that fused-batch-only slots never pay this upload.
    fn ensure_device(&self, client: &Client, cache: &KvCache) -> Result<()> {
        {
            let dev = cache.dev.borrow();
            if dev.kc.is_some() && dev.gen == cache.gen {
                return Ok(());
            }
        }
        let dims = self.cache_dims();
        let (kc_buf, vc_buf) = self.with_dense(cache, |kc, vc| {
            Ok((client.buf_f32(kc, &dims)?, client.buf_f32(vc, &dims)?))
        })?;
        let mut dev = cache.dev.borrow_mut();
        dev.kc = Some(kc_buf);
        dev.vc = Some(vc_buf);
        dev.gen = cache.gen;
        Ok(())
    }

    /// Run the prompt through the model; returns logits at position n-1
    /// and a fresh KV cache positioned at n.
    pub fn prefill(&self, client: &Client, tokens: &[u32]) -> Result<(Vec<f32>, KvCache)> {
        let s = self.cfg.seq_len;
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() <= s,
            "prompt length {} out of range 1..={s}",
            tokens.len()
        );
        let mut padded = vec![0i32; s];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let toks_buf = client.buf_i32(&padded, &[s])?;
        let n_buf = client.buf_scalar_i32(tokens.len() as i32)?;
        let outs = self
            .exe_prefill
            .run(&self.args_with(&[&toks_buf, &n_buf]))?;
        anyhow::ensure!(outs.len() == 3, "prefill must return 3 outputs");
        RuntimeCounters::bump(&self.counters.prefills);

        let logits = lit_f32_vec(&outs[0])?;
        let kc = lit_f32_vec(&outs[1])?;
        let vc = lit_f32_vec(&outs[2])?;
        let store = match &self.paged {
            Some(paged) => {
                let n = tokens.len();
                let kp = self.side_table_from_dense(&paged.pool, paged.page_size, &kc, n)?;
                let vp = self.side_table_from_dense(&paged.pool, paged.page_size, &vc, n)?;
                KvStore::Paged(PagedKv { kp, vp })
            }
            None => KvStore::Mono { kc, vc },
        };
        Ok((logits, self.fresh_cache(store, tokens.len())))
    }

    /// One committed decode step: writes K/V at `cache.pos`, returns the
    /// next-token logits, advances the cache. The device copy goes stale
    /// and is refreshed lazily on the next single-sequence use.
    pub fn decode(&self, client: &Client, cache: &mut KvCache, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            cache.pos < self.cfg.seq_len,
            "KV cache full (pos {} of {})",
            cache.pos,
            self.cfg.seq_len
        );
        self.ensure_device(client, cache)?;
        let pos_buf = client.buf_scalar_i32(cache.pos as i32)?;
        let tok_buf = client.buf_scalar_i32(token as i32)?;
        let outs = {
            let dev = cache.dev.borrow();
            let (kc, vc) = (dev.kc.as_ref().unwrap(), dev.vc.as_ref().unwrap());
            self.exe_decode
                .run(&self.args_with(&[kc, vc, &pos_buf, &tok_buf]))?
        };
        anyhow::ensure!(outs.len() == 3, "decode must return 3 outputs");
        RuntimeCounters::bump(&self.counters.decodes);

        let logits = lit_f32_vec(&outs[0])?;
        let kc = lit_f32_vec(&outs[1])?;
        let vc = lit_f32_vec(&outs[2])?;
        // the kernel wrote K/V at the old `pos` only; the paged mirror
        // scatters exactly that position (CoW on a shared tail page)
        let written = cache.pos;
        self.commit_written(cache, kc, vc, written)?;
        Ok(logits)
    }

    /// The EAT probe (Alg. 1 line 6): virtually append `suffix` after the
    /// current position and return (entropy of the following token, its
    /// full logits). The cache is NOT modified — this is the paper's
    /// "one extra token" overhead trick (§4.3).
    pub fn probe(&self, client: &Client, cache: &KvCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)> {
        let pk = self.cfg.probe_len;
        anyhow::ensure!(
            !suffix.is_empty() && suffix.len() <= pk,
            "probe suffix length {} out of range 1..={pk}",
            suffix.len()
        );
        anyhow::ensure!(
            cache.pos + suffix.len() <= self.cfg.seq_len,
            "probe would overflow the sequence"
        );
        self.ensure_device(client, cache)?;
        let mut padded = vec![0i32; pk];
        for (i, &t) in suffix.iter().enumerate() {
            padded[i] = t as i32;
        }
        let suf_buf = client.buf_i32(&padded, &[pk])?;
        let slen_buf = client.buf_scalar_i32(suffix.len() as i32)?;
        let pos_buf = client.buf_scalar_i32(cache.pos as i32)?;
        let outs = {
            let dev = cache.dev.borrow();
            let (kc, vc) = (dev.kc.as_ref().unwrap(), dev.vc.as_ref().unwrap());
            self.exe_probe
                .run(&self.args_with(&[kc, vc, &pos_buf, &suf_buf, &slen_buf]))?
        };
        anyhow::ensure!(outs.len() == 2, "probe must return 2 outputs");
        RuntimeCounters::bump(&self.counters.probes);
        Ok((lit_f32_scalar(&outs[0])?, lit_f32_vec(&outs[1])?))
    }

    /// Fork a cache — used by rollout-based baselines that must decode
    /// hypothetical continuations without disturbing the request's real
    /// cache. On the paged store this is O(pages) refcount bumps
    /// (copy-on-write divergence); monolithic mirrors pay the full
    /// deep copy.
    pub fn fork_cache(&self, _client: &Client, cache: &KvCache) -> Result<KvCache> {
        let store = match &cache.store {
            KvStore::Mono { kc, vc } => KvStore::Mono {
                kc: kc.clone(),
                vc: vc.clone(),
            },
            KvStore::Paged(p) => {
                RuntimeCounters::bump(&self.counters.cow_forks);
                RuntimeCounters::add(
                    &self.counters.pages_shared,
                    (p.kp.page_count() + p.vp.page_count()) as u64,
                );
                KvStore::Paged(p.clone())
            }
        };
        Ok(self.fresh_cache(store, cache.pos))
    }

    pub fn has_batch(&self) -> bool {
        self.exe_decode_batch.is_some()
    }

    /// Fused batched decode over exactly `cfg.batch` lanes. Engaged lanes
    /// (`Some`) commit their token; `None` lanes are padding whose
    /// outputs are discarded and whose scratch image is invalidated.
    pub fn decode_batch(
        &self,
        client: &Client,
        lanes: &mut [Option<(&mut KvCache, u32)>],
    ) -> Result<Vec<Option<Vec<f32>>>> {
        let b = self.cfg.batch;
        let exe = self
            .exe_decode_batch
            .as_ref()
            .context("model has no decode_batch artifact")?;
        anyhow::ensure!(
            lanes.len() == b,
            "decode_batch got {} lanes, batch width is {b}",
            lanes.len()
        );
        let dims = self.cache_dims();
        let elems: usize = dims.iter().product();
        let bdims = [b, dims[0], dims[1], dims[2], dims[3]];

        let mut scratch_ref = self.batch_scratch.borrow_mut();
        let scratch: &mut BatchScratch = &mut scratch_ref;
        if scratch.kc_all.len() != b * elems {
            scratch.kc_all = vec![0.0; b * elems];
            scratch.vc_all = vec![0.0; b * elems];
            scratch.lane_tag = vec![None; b];
        }

        let mut pos = vec![0i32; b];
        let mut toks = vec![0i32; b];
        let mut engaged = 0u64;
        let mut resident = 0u64;
        for (i, lane) in lanes.iter().enumerate() {
            let Some((cache, token)) = lane else {
                continue;
            };
            anyhow::ensure!(
                cache.pos < self.cfg.seq_len,
                "KV cache full (pos {} of {})",
                cache.pos,
                self.cfg.seq_len
            );
            pos[i] = cache.pos as i32;
            toks[i] = *token as i32;
            engaged += 1;
            if scratch.lane_tag[i] == Some((cache.id, cache.gen)) {
                resident += 1; // lane image current from the previous call
            } else {
                let kc_out = &mut scratch.kc_all[i * elems..(i + 1) * elems];
                let vc_out = &mut scratch.vc_all[i * elems..(i + 1) * elems];
                match &cache.store {
                    KvStore::Mono { kc, vc } => {
                        kc_out.copy_from_slice(kc);
                        vc_out.copy_from_slice(vc);
                    }
                    KvStore::Paged(p) => {
                        let paged = self.paged.as_ref().context("paged cache on a mono runtime")?;
                        let pool = p.kp.pool().borrow();
                        self.gather_side(&pool, paged.page_size, p.kp.pages(), kc_out);
                        self.gather_side(&pool, paged.page_size, p.vp.pages(), vc_out);
                    }
                }
            }
        }
        anyhow::ensure!(engaged > 0, "decode_batch needs at least one engaged lane");

        let kc_buf = client.buf_f32(&scratch.kc_all, &bdims)?;
        let vc_buf = client.buf_f32(&scratch.vc_all, &bdims)?;
        let pos_buf = client.buf_i32(&pos, &[b])?;
        let toks_buf = client.buf_i32(&toks, &[b])?;
        let outs = exe.run(&self.args_with(&[&kc_buf, &vc_buf, &pos_buf, &toks_buf]))?;
        anyhow::ensure!(outs.len() == 3, "decode_batch must return 3 outputs");
        RuntimeCounters::bump(&self.counters.batch_decodes);
        RuntimeCounters::add(&self.counters.batch_lanes, engaged);
        RuntimeCounters::add(&self.counters.batch_resident_lanes, resident);

        let logits_all = lit_f32_vec(&outs[0])?;
        // the downloaded batch becomes the next call's resident image —
        // steady-state ticks never gather from host mirrors again
        scratch.kc_all = lit_f32_vec(&outs[1])?;
        scratch.vc_all = lit_f32_vec(&outs[2])?;
        anyhow::ensure!(
            scratch.kc_all.len() == b * elems && scratch.vc_all.len() == b * elems,
            "decode_batch returned a mis-shaped cache"
        );

        let v = self.cfg.vocab;
        let mut out = Vec::with_capacity(b);
        for (i, lane) in lanes.iter_mut().enumerate() {
            match lane {
                Some((cache, _)) => {
                    let written = cache.pos;
                    let kc_new = &scratch.kc_all[i * elems..(i + 1) * elems];
                    let vc_new = &scratch.vc_all[i * elems..(i + 1) * elems];
                    match &mut cache.store {
                        KvStore::Mono { kc, vc } => {
                            kc.copy_from_slice(kc_new);
                            vc.copy_from_slice(vc_new);
                        }
                        KvStore::Paged(p) => {
                            // the fused kernel wrote each engaged lane's
                            // K/V at its own `pos` only — scatter exactly
                            // that position (CoW on a shared tail page)
                            let paged =
                                self.paged.as_ref().context("paged cache on a mono runtime")?;
                            let ck = self.scatter_position(
                                &mut p.kp,
                                paged.page_size,
                                kc_new,
                                written,
                            )?;
                            let cv = self.scatter_position(
                                &mut p.vp,
                                paged.page_size,
                                vc_new,
                                written,
                            )?;
                            RuntimeCounters::add(
                                &self.counters.pages_copied,
                                ck as u64 + cv as u64,
                            );
                        }
                    }
                    cache.pos += 1;
                    cache.gen += 1;
                    scratch.lane_tag[i] = Some((cache.id, cache.gen));
                    out.push(Some(logits_all[i * v..(i + 1) * v].to_vec()));
                }
                None => {
                    // the fused kernel scribbled at pos 0 of idle lanes;
                    // their scratch image is no longer trustworthy
                    scratch.lane_tag[i] = None;
                    out.push(None);
                }
            }
        }
        Ok(out)
    }

    /// Parameter count (for `repro info`).
    pub fn total_param_elems(&self) -> usize {
        self.weights.total_elems
    }
}

/// [`Backend`] adapter over a PJRT [`ModelRuntime`]. Main and proxy
/// share the client.
pub struct PjrtBackend {
    client: Rc<Client>,
    pub model: ModelRuntime,
}

impl PjrtBackend {
    pub fn load(client: Rc<Client>, dir: &Path, cfg: &ModelConfig) -> Result<PjrtBackend> {
        PjrtBackend::load_with(client, dir, cfg, None)
    }

    /// Load with an optional paged KV store (`page_size` tokens per
    /// page; `None` = monolithic dense mirrors).
    pub fn load_with(
        client: Rc<Client>,
        dir: &Path,
        cfg: &ModelConfig,
        page_size: Option<usize>,
    ) -> Result<PjrtBackend> {
        let model = ModelRuntime::load_with(&client, dir, cfg, page_size)?;
        Ok(PjrtBackend { client, model })
    }

    pub fn client(&self) -> &Client {
        &self.client
    }
}

fn pjrt_cache(cache: &BackendCache) -> Result<&KvCache> {
    match cache {
        BackendCache::Pjrt(c) => Ok(c),
        _ => anyhow::bail!("pjrt backend received a non-pjrt cache"),
    }
}

fn pjrt_cache_mut(cache: &mut BackendCache) -> Result<&mut KvCache> {
    match cache {
        BackendCache::Pjrt(c) => Ok(c),
        _ => anyhow::bail!("pjrt backend received a non-pjrt cache"),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.model.cfg.name
    }

    fn describe(&self) -> String {
        let c = &self.model.cfg;
        format!(
            "{:<9} pjrt d={} L={} H={} ff={} seq={} params={}",
            c.name,
            c.d_model,
            c.n_layer,
            c.n_head,
            c.d_ff,
            c.seq_len,
            self.model.total_param_elems()
        )
    }

    fn seq_len(&self) -> usize {
        self.model.cfg.seq_len
    }

    fn probe_len(&self) -> usize {
        self.model.cfg.probe_len
    }

    fn vocab_size(&self) -> usize {
        self.model.cfg.vocab
    }

    fn batch_width(&self) -> Option<usize> {
        self.model.has_batch().then_some(self.model.cfg.batch)
    }

    fn page_size(&self) -> Option<usize> {
        self.model.page_size()
    }

    fn cache_elems(&self) -> usize {
        self.model.cfg.cache_elems()
    }

    fn param_elems(&self) -> usize {
        self.model.total_param_elems()
    }

    fn prefill(&self, tokens: &[u32]) -> Result<(Vec<f32>, BackendCache)> {
        let (logits, cache) = self.model.prefill(&self.client, tokens)?;
        Ok((logits, BackendCache::Pjrt(cache)))
    }

    fn decode(&self, cache: &mut BackendCache, token: u32) -> Result<Vec<f32>> {
        self.model
            .decode(&self.client, pjrt_cache_mut(cache)?, token)
    }

    fn probe(&self, cache: &BackendCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)> {
        self.model.probe(&self.client, pjrt_cache(cache)?, suffix)
    }

    fn fork(&self, cache: &BackendCache) -> Result<BackendCache> {
        Ok(BackendCache::Pjrt(
            self.model.fork_cache(&self.client, pjrt_cache(cache)?)?,
        ))
    }

    fn decode_batch(&self, lanes: &mut [Option<BatchLane<'_>>]) -> Result<Vec<Option<Vec<f32>>>> {
        let mut raw: Vec<Option<(&mut KvCache, u32)>> = Vec::with_capacity(lanes.len());
        for lane in lanes.iter_mut() {
            match lane {
                Some(BatchLane { cache, token }) => match &mut **cache {
                    BackendCache::Pjrt(c) => raw.push(Some((c, *token))),
                    _ => anyhow::bail!("pjrt backend received a non-pjrt cache"),
                },
                None => raw.push(None),
            }
        }
        self.model.decode_batch(&self.client, &mut raw)
    }

    fn counters(&self) -> &RuntimeCounters {
        &self.model.counters
    }
}
