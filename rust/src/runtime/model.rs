//! Typed PJRT model runtime: prefill / decode / probe / fused batched
//! decode over the AOT artifacts, plus the [`PjrtBackend`] adapter that
//! exposes it through the [`Backend`] trait.
//!
//! Buffer discipline (DESIGN.md §6): weights are uploaded to device once
//! at load time and stay resident. Because PJRT hands multi-output
//! results back as a *single tuple buffer* (no untupling in the `xla`
//! crate), every step downloads the output tuple; the host mirror this
//! produces is kept on the [`KvCache`] and doubles as the cheap
//! cache-fork mechanism that rollout-based baselines (#UA@K, Alg. 3)
//! need. Two things keep the batched hot path off the per-slot copy
//! treadmill:
//!
//!  * per-slot *device* buffers are lazy — they are only materialized
//!    when a single-sequence entry point (decode / probe) actually needs
//!    them, so slots that live entirely in the fused batch never pay a
//!    per-slot upload;
//!  * the fused `decode_batch` keeps one slot-major scratch image of the
//!    whole batch; lanes whose (cache id, generation) still match the
//!    previous fused call skip the host-side gather entirely, and the
//!    downloaded output *becomes* the next call's resident image.

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::backend::{Backend, BackendCache, BatchLane, RuntimeCounters};
use super::client::{lit_f32_scalar, lit_f32_vec, Client, Executable};
use super::weights::Weights;
use crate::config::ModelConfig;

/// Per-sequence KV cache: host mirror + lazily materialized device
/// buffers + write position.
pub struct KvCache {
    kc_host: Vec<f32>,
    vc_host: Vec<f32>,
    /// Next write position (== number of committed tokens).
    pub pos: usize,
    /// Unique cache identity (survives moves; used by the fused-batch
    /// residency check).
    id: u64,
    /// Bumped on every host-mirror mutation.
    gen: u64,
    dev: RefCell<DevBuffers>,
}

#[derive(Default)]
struct DevBuffers {
    kc: Option<PjRtBuffer>,
    vc: Option<PjRtBuffer>,
    /// Generation the device copies reflect.
    gen: u64,
}

impl KvCache {
    /// Bytes held by this cache's K + V image, for the KV manager.
    pub fn device_bytes(&self) -> usize {
        (self.kc_host.len() + self.vc_host.len()) * 4
    }
}

/// Reusable slot-major image of the batched K/V for `decode_batch`.
#[derive(Default)]
struct BatchScratch {
    kc_all: Vec<f32>,
    vc_all: Vec<f32>,
    /// (cache id, generation) the lane image currently holds.
    lane_tag: Vec<Option<(u64, u64)>>,
}

/// One loaded model: compiled executables + resident weights.
pub struct ModelRuntime {
    pub cfg: ModelConfig,
    weights: Weights,
    exe_prefill: Executable,
    exe_decode: Executable,
    exe_probe: Executable,
    exe_decode_batch: Option<Executable>,
    pub counters: RuntimeCounters,
    next_cache_id: Cell<u64>,
    batch_scratch: RefCell<BatchScratch>,
}

impl ModelRuntime {
    pub fn load(client: &Client, dir: &Path, cfg: &ModelConfig) -> Result<ModelRuntime> {
        let weights = Weights::load(
            client,
            &dir.join(&cfg.manifest),
            &dir.join(&cfg.weights),
        )
        .with_context(|| format!("loading weights for model `{}`", cfg.name))?;
        anyhow::ensure!(
            weights.specs.len() == cfg.n_params,
            "manifest has {} params, config says {}",
            weights.specs.len(),
            cfg.n_params
        );
        let exe_prefill = client.compile_hlo_text(&dir.join(&cfg.hlo_prefill))?;
        let exe_decode = client.compile_hlo_text(&dir.join(&cfg.hlo_decode))?;
        let exe_probe = client.compile_hlo_text(&dir.join(&cfg.hlo_probe))?;
        let exe_decode_batch = cfg
            .hlo_decode_batch
            .as_ref()
            .map(|f| client.compile_hlo_text(&dir.join(f)))
            .transpose()?;
        Ok(ModelRuntime {
            cfg: cfg.clone(),
            weights,
            exe_prefill,
            exe_decode,
            exe_probe,
            exe_decode_batch,
            counters: RuntimeCounters::default(),
            next_cache_id: Cell::new(0),
            batch_scratch: RefCell::new(BatchScratch::default()),
        })
    }

    fn args_with<'a>(&'a self, extra: &[&'a PjRtBuffer]) -> Vec<&'a PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = self.weights.buffers.iter().collect();
        args.extend_from_slice(extra);
        args
    }

    fn cache_dims(&self) -> [usize; 4] {
        [
            self.cfg.n_layer,
            self.cfg.n_head,
            self.cfg.seq_len,
            self.cfg.d_head,
        ]
    }

    fn new_cache(&self, kc_host: Vec<f32>, vc_host: Vec<f32>, pos: usize) -> KvCache {
        let id = self.next_cache_id.get();
        self.next_cache_id.set(id + 1);
        KvCache {
            kc_host,
            vc_host,
            pos,
            id,
            gen: 0,
            dev: RefCell::new(DevBuffers::default()),
        }
    }

    /// Materialize (or refresh) the per-slot device buffers from the host
    /// mirror. Lazy so that fused-batch-only slots never pay this upload.
    fn ensure_device(&self, client: &Client, cache: &KvCache) -> Result<()> {
        let mut dev = cache.dev.borrow_mut();
        if dev.kc.is_none() || dev.gen != cache.gen {
            let dims = self.cache_dims();
            dev.kc = Some(client.buf_f32(&cache.kc_host, &dims)?);
            dev.vc = Some(client.buf_f32(&cache.vc_host, &dims)?);
            dev.gen = cache.gen;
        }
        Ok(())
    }

    /// Run the prompt through the model; returns logits at position n-1
    /// and a fresh KV cache positioned at n.
    pub fn prefill(&self, client: &Client, tokens: &[u32]) -> Result<(Vec<f32>, KvCache)> {
        let s = self.cfg.seq_len;
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() <= s,
            "prompt length {} out of range 1..={s}",
            tokens.len()
        );
        let mut padded = vec![0i32; s];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let toks_buf = client.buf_i32(&padded, &[s])?;
        let n_buf = client.buf_scalar_i32(tokens.len() as i32)?;
        let outs = self
            .exe_prefill
            .run(&self.args_with(&[&toks_buf, &n_buf]))?;
        anyhow::ensure!(outs.len() == 3, "prefill must return 3 outputs");
        RuntimeCounters::bump(&self.counters.prefills);

        let logits = lit_f32_vec(&outs[0])?;
        let kc_host = lit_f32_vec(&outs[1])?;
        let vc_host = lit_f32_vec(&outs[2])?;
        Ok((logits, self.new_cache(kc_host, vc_host, tokens.len())))
    }

    /// One committed decode step: writes K/V at `cache.pos`, returns the
    /// next-token logits, advances the cache. The device copy goes stale
    /// and is refreshed lazily on the next single-sequence use.
    pub fn decode(&self, client: &Client, cache: &mut KvCache, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            cache.pos < self.cfg.seq_len,
            "KV cache full (pos {} of {})",
            cache.pos,
            self.cfg.seq_len
        );
        self.ensure_device(client, cache)?;
        let pos_buf = client.buf_scalar_i32(cache.pos as i32)?;
        let tok_buf = client.buf_scalar_i32(token as i32)?;
        let outs = {
            let dev = cache.dev.borrow();
            let (kc, vc) = (dev.kc.as_ref().unwrap(), dev.vc.as_ref().unwrap());
            self.exe_decode
                .run(&self.args_with(&[kc, vc, &pos_buf, &tok_buf]))?
        };
        anyhow::ensure!(outs.len() == 3, "decode must return 3 outputs");
        RuntimeCounters::bump(&self.counters.decodes);

        let logits = lit_f32_vec(&outs[0])?;
        cache.kc_host = lit_f32_vec(&outs[1])?;
        cache.vc_host = lit_f32_vec(&outs[2])?;
        cache.pos += 1;
        cache.gen += 1;
        Ok(logits)
    }

    /// The EAT probe (Alg. 1 line 6): virtually append `suffix` after the
    /// current position and return (entropy of the following token, its
    /// full logits). The cache is NOT modified — this is the paper's
    /// "one extra token" overhead trick (§4.3).
    pub fn probe(&self, client: &Client, cache: &KvCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)> {
        let pk = self.cfg.probe_len;
        anyhow::ensure!(
            !suffix.is_empty() && suffix.len() <= pk,
            "probe suffix length {} out of range 1..={pk}",
            suffix.len()
        );
        anyhow::ensure!(
            cache.pos + suffix.len() <= self.cfg.seq_len,
            "probe would overflow the sequence"
        );
        self.ensure_device(client, cache)?;
        let mut padded = vec![0i32; pk];
        for (i, &t) in suffix.iter().enumerate() {
            padded[i] = t as i32;
        }
        let suf_buf = client.buf_i32(&padded, &[pk])?;
        let slen_buf = client.buf_scalar_i32(suffix.len() as i32)?;
        let pos_buf = client.buf_scalar_i32(cache.pos as i32)?;
        let outs = {
            let dev = cache.dev.borrow();
            let (kc, vc) = (dev.kc.as_ref().unwrap(), dev.vc.as_ref().unwrap());
            self.exe_probe
                .run(&self.args_with(&[kc, vc, &pos_buf, &suf_buf, &slen_buf]))?
        };
        anyhow::ensure!(outs.len() == 2, "probe must return 2 outputs");
        RuntimeCounters::bump(&self.counters.probes);
        Ok((lit_f32_scalar(&outs[0])?, lit_f32_vec(&outs[1])?))
    }

    /// Fork a cache (host mirror cloned; device buffers materialize
    /// lazily) — used by rollout-based baselines that must decode
    /// hypothetical continuations without disturbing the request's real
    /// cache.
    pub fn fork_cache(&self, _client: &Client, cache: &KvCache) -> Result<KvCache> {
        Ok(self.new_cache(cache.kc_host.clone(), cache.vc_host.clone(), cache.pos))
    }

    pub fn has_batch(&self) -> bool {
        self.exe_decode_batch.is_some()
    }

    /// Fused batched decode over exactly `cfg.batch` lanes. Engaged lanes
    /// (`Some`) commit their token; `None` lanes are padding whose
    /// outputs are discarded and whose scratch image is invalidated.
    pub fn decode_batch(
        &self,
        client: &Client,
        lanes: &mut [Option<(&mut KvCache, u32)>],
    ) -> Result<Vec<Option<Vec<f32>>>> {
        let b = self.cfg.batch;
        let exe = self
            .exe_decode_batch
            .as_ref()
            .context("model has no decode_batch artifact")?;
        anyhow::ensure!(
            lanes.len() == b,
            "decode_batch got {} lanes, batch width is {b}",
            lanes.len()
        );
        let dims = self.cache_dims();
        let elems: usize = dims.iter().product();
        let bdims = [b, dims[0], dims[1], dims[2], dims[3]];

        let mut scratch = self.batch_scratch.borrow_mut();
        if scratch.kc_all.len() != b * elems {
            scratch.kc_all = vec![0.0; b * elems];
            scratch.vc_all = vec![0.0; b * elems];
            scratch.lane_tag = vec![None; b];
        }

        let mut pos = vec![0i32; b];
        let mut toks = vec![0i32; b];
        let mut engaged = 0u64;
        let mut resident = 0u64;
        for (i, lane) in lanes.iter().enumerate() {
            let Some((cache, token)) = lane else {
                continue;
            };
            anyhow::ensure!(
                cache.pos < self.cfg.seq_len,
                "KV cache full (pos {} of {})",
                cache.pos,
                self.cfg.seq_len
            );
            pos[i] = cache.pos as i32;
            toks[i] = *token as i32;
            engaged += 1;
            if scratch.lane_tag[i] == Some((cache.id, cache.gen)) {
                resident += 1; // lane image current from the previous call
            } else {
                scratch.kc_all[i * elems..(i + 1) * elems].copy_from_slice(&cache.kc_host);
                scratch.vc_all[i * elems..(i + 1) * elems].copy_from_slice(&cache.vc_host);
            }
        }
        anyhow::ensure!(engaged > 0, "decode_batch needs at least one engaged lane");

        let kc_buf = client.buf_f32(&scratch.kc_all, &bdims)?;
        let vc_buf = client.buf_f32(&scratch.vc_all, &bdims)?;
        let pos_buf = client.buf_i32(&pos, &[b])?;
        let toks_buf = client.buf_i32(&toks, &[b])?;
        let outs = exe.run(&self.args_with(&[&kc_buf, &vc_buf, &pos_buf, &toks_buf]))?;
        anyhow::ensure!(outs.len() == 3, "decode_batch must return 3 outputs");
        RuntimeCounters::bump(&self.counters.batch_decodes);
        RuntimeCounters::add(&self.counters.batch_lanes, engaged);
        RuntimeCounters::add(&self.counters.batch_resident_lanes, resident);

        let logits_all = lit_f32_vec(&outs[0])?;
        // the downloaded batch becomes the next call's resident image —
        // steady-state ticks never gather from host mirrors again
        scratch.kc_all = lit_f32_vec(&outs[1])?;
        scratch.vc_all = lit_f32_vec(&outs[2])?;
        anyhow::ensure!(
            scratch.kc_all.len() == b * elems && scratch.vc_all.len() == b * elems,
            "decode_batch returned a mis-shaped cache"
        );

        let v = self.cfg.vocab;
        let mut out = Vec::with_capacity(b);
        for (i, lane) in lanes.iter_mut().enumerate() {
            match lane {
                Some((cache, _)) => {
                    cache
                        .kc_host
                        .copy_from_slice(&scratch.kc_all[i * elems..(i + 1) * elems]);
                    cache
                        .vc_host
                        .copy_from_slice(&scratch.vc_all[i * elems..(i + 1) * elems]);
                    cache.pos += 1;
                    cache.gen += 1;
                    scratch.lane_tag[i] = Some((cache.id, cache.gen));
                    out.push(Some(logits_all[i * v..(i + 1) * v].to_vec()));
                }
                None => {
                    // the fused kernel scribbled at pos 0 of idle lanes;
                    // their scratch image is no longer trustworthy
                    scratch.lane_tag[i] = None;
                    out.push(None);
                }
            }
        }
        Ok(out)
    }

    /// Parameter count (for `repro info`).
    pub fn total_param_elems(&self) -> usize {
        self.weights.total_elems
    }
}

/// [`Backend`] adapter over a PJRT [`ModelRuntime`]. Main and proxy
/// share the client.
pub struct PjrtBackend {
    client: Rc<Client>,
    pub model: ModelRuntime,
}

impl PjrtBackend {
    pub fn load(client: Rc<Client>, dir: &Path, cfg: &ModelConfig) -> Result<PjrtBackend> {
        let model = ModelRuntime::load(&client, dir, cfg)?;
        Ok(PjrtBackend { client, model })
    }

    pub fn client(&self) -> &Client {
        &self.client
    }
}

fn pjrt_cache(cache: &BackendCache) -> Result<&KvCache> {
    match cache {
        BackendCache::Pjrt(c) => Ok(c),
        _ => anyhow::bail!("pjrt backend received a non-pjrt cache"),
    }
}

fn pjrt_cache_mut(cache: &mut BackendCache) -> Result<&mut KvCache> {
    match cache {
        BackendCache::Pjrt(c) => Ok(c),
        _ => anyhow::bail!("pjrt backend received a non-pjrt cache"),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.model.cfg.name
    }

    fn describe(&self) -> String {
        let c = &self.model.cfg;
        format!(
            "{:<9} pjrt d={} L={} H={} ff={} seq={} params={}",
            c.name,
            c.d_model,
            c.n_layer,
            c.n_head,
            c.d_ff,
            c.seq_len,
            self.model.total_param_elems()
        )
    }

    fn seq_len(&self) -> usize {
        self.model.cfg.seq_len
    }

    fn probe_len(&self) -> usize {
        self.model.cfg.probe_len
    }

    fn vocab_size(&self) -> usize {
        self.model.cfg.vocab
    }

    fn batch_width(&self) -> Option<usize> {
        self.model.has_batch().then_some(self.model.cfg.batch)
    }

    fn cache_elems(&self) -> usize {
        self.model.cfg.cache_elems()
    }

    fn param_elems(&self) -> usize {
        self.model.total_param_elems()
    }

    fn prefill(&self, tokens: &[u32]) -> Result<(Vec<f32>, BackendCache)> {
        let (logits, cache) = self.model.prefill(&self.client, tokens)?;
        Ok((logits, BackendCache::Pjrt(cache)))
    }

    fn decode(&self, cache: &mut BackendCache, token: u32) -> Result<Vec<f32>> {
        self.model
            .decode(&self.client, pjrt_cache_mut(cache)?, token)
    }

    fn probe(&self, cache: &BackendCache, suffix: &[u32]) -> Result<(f32, Vec<f32>)> {
        self.model.probe(&self.client, pjrt_cache(cache)?, suffix)
    }

    fn fork(&self, cache: &BackendCache) -> Result<BackendCache> {
        Ok(BackendCache::Pjrt(
            self.model.fork_cache(&self.client, pjrt_cache(cache)?)?,
        ))
    }

    fn decode_batch(&self, lanes: &mut [Option<BatchLane<'_>>]) -> Result<Vec<Option<Vec<f32>>>> {
        let mut raw: Vec<Option<(&mut KvCache, u32)>> = Vec::with_capacity(lanes.len());
        for lane in lanes.iter_mut() {
            match lane {
                Some(BatchLane { cache, token }) => match &mut **cache {
                    BackendCache::Pjrt(c) => raw.push(Some((c, *token))),
                    _ => anyhow::bail!("pjrt backend received a non-pjrt cache"),
                },
                None => raw.push(None),
            }
        }
        self.model.decode_batch(&self.client, &mut raw)
    }

    fn counters(&self) -> &RuntimeCounters {
        &self.model.counters
    }
}
