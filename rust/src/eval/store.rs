//! Trace persistence: a dataset's traces in one JSON file so sweeps and
//! figures replay offline without touching the models (App. H: "saving it
//! once to disk, and replaying it offline ... at arbitrary thresholds").

use std::path::Path;

use anyhow::{Context, Result};

use crate::monitor::Trace;
use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct TraceSet {
    pub dataset: String,
    pub traces: Vec<Trace>,
}

impl TraceSet {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let js = Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            (
                "traces",
                Json::arr(self.traces.iter().map(|t| t.to_json())),
            ),
        ]);
        std::fs::write(path, js.to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TraceSet> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {} (run `repro trace` first)", path.display()))?;
        // Lazy scan (DESIGN.md §3.8): decode straight from the text
        // without building a `Json` tree. `save` still writes through the
        // tree, which doubles as the differential oracle in tests.
        let sc = json::JsonScanner::new(&text);
        let traces_sc = sc
            .path(&["traces"])
            .context("missing JSON key `traces`")?;
        anyhow::ensure!(traces_sc.is_array(), "traces must be an array");
        let traces = traces_sc
            .array_items()
            .map(|t| Trace::from_scanner(&t))
            .collect::<Result<Vec<_>>>()?;
        Ok(TraceSet {
            dataset: sc.req_str("dataset")?.into_owned(),
            traces,
        })
    }

    /// Solvable-subset filter used for the GPQA figures (App. I.4: "only
    /// kept problems for which the models eventually reached Pass@1 >
    /// 0.8").
    pub fn filter_solvable(&self, min_final_pass1: f64) -> TraceSet {
        TraceSet {
            dataset: format!("{}-solvable", self.dataset),
            traces: self
                .traces
                .iter()
                .filter(|t| {
                    t.points
                        .last()
                        .map(|p| p.pass1_avgk > min_final_pass1)
                        .unwrap_or(false)
                })
                .cloned()
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::LinePoint;

    fn mk_trace(id: usize, final_pass1: f64) -> Trace {
        Trace {
            question_id: id,
            n_ops: 3,
            answer: Some(1),
            prompt_tokens: 6,
            self_terminated: false,
            reasoning_tokens: vec![5, 5],
            points: vec![LinePoint {
                line: 1,
                tokens: 3,
                eat: 1.0,
                eat_proxy: None,
                eat_plain: None,
                eat_newline: None,
                vhat: 0.5,
                p_correct: final_pass1,
                pass1_avgk: final_pass1,
                unique_answers: 2,
                confidence: None,
            }],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let ts = TraceSet {
            dataset: "unit".into(),
            traces: vec![mk_trace(0, 0.9), mk_trace(1, 0.2)],
        };
        let path = std::env::temp_dir().join("eat_traceset_test.json");
        ts.save(&path).unwrap();
        let back = TraceSet::load(&path).unwrap();
        assert_eq!(back.dataset, "unit");
        assert_eq!(back.traces.len(), 2);
        assert_eq!(back.traces[1].question_id, 1);
    }

    #[test]
    fn load_rejects_non_array_traces() {
        let path = std::env::temp_dir().join("eat_traceset_badshape.json");
        std::fs::write(&path, "{\"dataset\":\"x\",\"traces\":3}").unwrap();
        let err = TraceSet::load(&path).unwrap_err();
        assert!(err.to_string().contains("array"), "{err}");
    }

    #[test]
    fn solvable_filter() {
        let ts = TraceSet {
            dataset: "unit".into(),
            traces: vec![mk_trace(0, 0.9), mk_trace(1, 0.2)],
        };
        let f = ts.filter_solvable(0.8);
        assert_eq!(f.traces.len(), 1);
        assert_eq!(f.traces[0].question_id, 0);
    }
}
