//! Trace generation — the paper's *simulated early exiting* protocol
//! (App. H): generate ONE long reasoning chain per question (no exits),
//! record every per-line signal, and replay offline at arbitrary
//! thresholds "without re-querying the model".
//!
//! Per reasoning line we record: EAT with the prefix string (Eq. 13), EAT
//! without it (Eq. 12, App. D ablation), entropy-after-newline (Eq. 14,
//! App. F), proxy-model EAT (black-box setting), the analytic + sampled
//! Pass@1(Avg@K) (Eq. 9), #UA@K, and the confidence score (Eq. 16).
//!
//! Runs against any [`Backend`] — AOT artifacts or the deterministic
//! reference model.

use anyhow::Result;

use crate::config::ServeConfig;
use crate::coordinator::engine::{confidence_rollout, CONFIDENCE_ROLLOUT_LEN};
use crate::datasets::Question;
use crate::monitor::{EmaVar, LinePoint, Trace};
use crate::runtime::{Backend, BackendCache, Runtime};
use crate::sampler::Sampler;
use crate::util::rng::Rng;

/// Rollout count K of Pass@1(Avg@K) / #UA@K (paper: 128).
pub const AVG_K: usize = 128;

pub struct TraceGen<'a> {
    pub rt: &'a Runtime,
    pub cfg: ServeConfig,
    /// Record the monitor model's EAT alongside (costs a parallel decode).
    pub with_proxy: bool,
    /// Record the confidence score (costs a forked rollout per line).
    pub with_confidence: bool,
    /// Swap roles (Fig. 11): the *proxy* model reasons, the *main* model
    /// monitors. In the emitted trace, `eat` is the reasoner's own entropy
    /// and `eat_proxy` is the cross-model monitor's.
    pub swap_models: bool,
}

impl<'a> TraceGen<'a> {
    pub fn new(rt: &'a Runtime, cfg: ServeConfig) -> TraceGen<'a> {
        TraceGen {
            rt,
            cfg,
            with_proxy: true,
            with_confidence: true,
            swap_models: false,
        }
    }

    /// (reasoner, monitor) model pair per `swap_models`.
    fn models(&self) -> (&'a dyn Backend, &'a dyn Backend) {
        if self.swap_models {
            (self.rt.proxy.as_ref(), self.rt.main.as_ref())
        } else {
            (self.rt.main.as_ref(), self.rt.proxy.as_ref())
        }
    }

    /// Generate the monitored trace for one question.
    pub fn run(&self, q: &Question, seed: u64) -> Result<Trace> {
        let (reasoner, monitor) = self.models();
        let vocab = self.rt.vocab;
        let mut rng = Rng::new(seed ^ (q.id as u64).wrapping_mul(0x9E3779B9));
        let sampler = Sampler::new(self.cfg.temperature, self.cfg.top_p);

        let mut prompt = q.prompt.clone();
        prompt.push(vocab.think);
        let (mut logits, mut cache) = reasoner.prefill(&prompt)?;
        let mut proxy_cache = if self.with_proxy {
            Some(monitor.prefill(&prompt)?.1)
        } else {
            None
        };

        let mut ema = EmaVar::new(self.cfg.alpha);
        let mut reasoning = Vec::new();
        let mut points = Vec::new();
        let mut line = 0usize;
        let mut self_terminated = false;

        // headroom for the longest per-line signal: the confidence
        // rollout decodes suffix + CONFIDENCE_ROLLOUT_LEN greedy tokens
        let reserve = vocab.suffix_prefixed().len() + CONFIDENCE_ROLLOUT_LEN;
        loop {
            if reasoning.len() >= self.cfg.max_think_tokens
                || cache.pos() + reserve >= reasoner.seq_len()
            {
                break;
            }
            let tok = sampler.sample(&logits, &mut rng);
            if tok == vocab.ethink {
                self_terminated = true;
                break;
            }
            logits = reasoner.decode(&mut cache, tok)?;
            if let Some(pc) = proxy_cache.as_mut() {
                monitor.decode(pc, tok)?;
            }
            reasoning.push(tok);

            if tok == vocab.nl {
                line += 1;
                let p = self.line_point(
                    q,
                    line,
                    reasoning.len(),
                    &cache,
                    proxy_cache.as_ref(),
                    &mut ema,
                    &sampler,
                    &mut rng,
                )?;
                points.push(p);
            }
        }

        Ok(Trace {
            question_id: q.id,
            n_ops: q.n_ops(),
            answer: q.answer,
            prompt_tokens: prompt.len(),
            self_terminated,
            reasoning_tokens: reasoning,
            points,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn line_point(
        &self,
        q: &Question,
        line: usize,
        tokens: usize,
        cache: &BackendCache,
        proxy_cache: Option<&BackendCache>,
        ema: &mut EmaVar,
        sampler: &Sampler,
        rng: &mut Rng,
    ) -> Result<LinePoint> {
        let (reasoner, monitor) = self.models();
        let vocab = self.rt.vocab;

        // EAT with prefix string (Eq. 13) — the headline signal; its probe
        // logits also give the forced-answer distribution for Pass@1.
        // Tool-calling questions use the Eq. 15 variant: the probe appends
        // the tool-call opener `[` the way the paper appends it after
        // </think> (the trained answer format differs for tool calls).
        let answer_suffix = if q.kind == crate::datasets::chainsum::Kind::ToolCall {
            vocab.suffix_tool()
        } else {
            vocab.suffix_prefixed()
        };
        let (eat, ans_logits) = reasoner.probe(cache, &answer_suffix)?;
        // EAT without prefix (Eq. 12)
        let (eat_plain, _) = reasoner.probe(cache, &vocab.suffix_plain())?;
        // entropy after newline (Eq. 14)
        let (eat_nl, _) = reasoner.probe(cache, &vocab.suffix_newline())?;
        // cross-model EAT (black-box monitor)
        let eat_proxy = match proxy_cache {
            Some(pc) => Some(monitor.probe(pc, &vocab.suffix_prefixed())?.0 as f64),
            None => None,
        };

        let vhat = ema.update(eat as f64);

        // Pass@1(Avg@K), Eq. 9: the answer is the single token after the
        // forced suffix, so the rollout distribution IS the probed logits
        // under the serve-time sampler.
        let probs = sampler.probs(&ans_logits);
        let p_correct = q
            .answer
            .map(|a| probs[vocab.num(a) as usize] as f64)
            .unwrap_or(0.0);
        let mut hits = 0usize;
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..AVG_K {
            let t = sampler.sample(&ans_logits, rng);
            seen.insert(t);
            if let (Some(a), Some(v)) = (q.answer, vocab.num_value(t)) {
                hits += (v == a) as usize;
            }
        }

        let confidence = if self.with_confidence {
            let (conf, _toks) = confidence_rollout(
                reasoner,
                cache,
                &vocab.suffix_prefixed(),
                CONFIDENCE_ROLLOUT_LEN,
            )?;
            Some(conf)
        } else {
            None
        };

        Ok(LinePoint {
            line,
            tokens,
            eat: eat as f64,
            eat_proxy,
            eat_plain: Some(eat_plain as f64),
            eat_newline: Some(eat_nl as f64),
            vhat,
            p_correct,
            pass1_avgk: hits as f64 / AVG_K as f64,
            unique_answers: seen.len(),
            confidence,
        })
    }
}
