//! Trace generation — the paper's *simulated early exiting* protocol
//! (App. H): generate ONE long reasoning chain per question (no exits),
//! record every per-line signal, and replay offline at arbitrary
//! thresholds "without re-querying the model".
//!
//! Per reasoning line we record: EAT with the prefix string (Eq. 13), EAT
//! without it (Eq. 12, App. D ablation), entropy-after-newline (Eq. 14,
//! App. F), proxy-model EAT (black-box setting), the analytic + sampled
//! Pass@1(Avg@K) (Eq. 9), #UA@K, and the confidence score (Eq. 16).

use anyhow::Result;

use crate::config::ServeConfig;
use crate::datasets::Question;
use crate::monitor::{EmaVar, LinePoint, Trace};
use crate::runtime::{KvCache, Runtime};
use crate::sampler::Sampler;
use crate::util::rng::Rng;

/// Rollout count K of Pass@1(Avg@K) / #UA@K (paper: 128).
pub const AVG_K: usize = 128;

pub struct TraceGen<'a> {
    pub rt: &'a Runtime,
    pub cfg: ServeConfig,
    /// Record the monitor model's EAT alongside (costs a parallel decode).
    pub with_proxy: bool,
    /// Record the confidence score (costs a forked 8-step rollout/line).
    pub with_confidence: bool,
    /// Swap roles (Fig. 11): the *proxy* model reasons, the *main* model
    /// monitors. In the emitted trace, `eat` is the reasoner's own entropy
    /// and `eat_proxy` is the cross-model monitor's.
    pub swap_models: bool,
}

impl<'a> TraceGen<'a> {
    pub fn new(rt: &'a Runtime, cfg: ServeConfig) -> TraceGen<'a> {
        TraceGen {
            rt,
            cfg,
            with_proxy: true,
            with_confidence: true,
            swap_models: false,
        }
    }

    /// (reasoner, monitor) model pair per `swap_models`.
    fn models(&self) -> (&'a crate::runtime::ModelRuntime, &'a crate::runtime::ModelRuntime) {
        if self.swap_models {
            (&self.rt.proxy, &self.rt.main)
        } else {
            (&self.rt.main, &self.rt.proxy)
        }
    }

    /// Generate the monitored trace for one question.
    pub fn run(&self, q: &Question, seed: u64) -> Result<Trace> {
        let rt = self.rt;
        let (reasoner, monitor) = self.models();
        let vocab = rt.cfg.vocab;
        let mut rng = Rng::new(seed ^ (q.id as u64).wrapping_mul(0x9E3779B9));
        let sampler = Sampler::new(self.cfg.temperature, self.cfg.top_p);

        let mut prompt = q.prompt.clone();
        prompt.push(vocab.think);
        let (mut logits, mut cache) = reasoner.prefill(&rt.client, &prompt)?;
        let mut proxy_cache = if self.with_proxy {
            Some(monitor.prefill(&rt.client, &prompt)?.1)
        } else {
            None
        };

        let mut ema = EmaVar::new(self.cfg.alpha);
        let mut reasoning = Vec::new();
        let mut points = Vec::new();
        let mut line = 0usize;
        let mut self_terminated = false;

        loop {
            if reasoning.len() >= self.cfg.max_think_tokens
                || cache.pos + 8 >= reasoner.cfg.seq_len
            {
                break;
            }
            let tok = sampler.sample(&logits, &mut rng);
            if tok == vocab.ethink {
                self_terminated = true;
                break;
            }
            logits = reasoner.decode(&rt.client, &mut cache, tok)?;
            if let Some(pc) = proxy_cache.as_mut() {
                monitor.decode(&rt.client, pc, tok)?;
            }
            reasoning.push(tok);

            if tok == vocab.nl {
                line += 1;
                let p = self.line_point(
                    q,
                    line,
                    reasoning.len(),
                    &cache,
                    proxy_cache.as_ref(),
                    &mut ema,
                    &sampler,
                    &mut rng,
                )?;
                points.push(p);
            }
        }

        Ok(Trace {
            question_id: q.id,
            n_ops: q.n_ops(),
            answer: q.answer,
            prompt_tokens: prompt.len(),
            self_terminated,
            reasoning_tokens: reasoning,
            points,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn line_point(
        &self,
        q: &Question,
        line: usize,
        tokens: usize,
        cache: &KvCache,
        proxy_cache: Option<&KvCache>,
        ema: &mut EmaVar,
        sampler: &Sampler,
        rng: &mut Rng,
    ) -> Result<LinePoint> {
        let rt = self.rt;
        let (reasoner, monitor) = self.models();
        let vocab = rt.cfg.vocab;

        // EAT with prefix string (Eq. 13) — the headline signal; its probe
        // logits also give the forced-answer distribution for Pass@1.
        // Tool-calling questions use the Eq. 15 variant: the probe appends
        // the tool-call opener `[` the way the paper appends it after
        // </think> (the trained answer format differs for tool calls).
        let answer_suffix = if q.kind == crate::datasets::chainsum::Kind::ToolCall {
            vocab.suffix_tool()
        } else {
            vocab.suffix_prefixed()
        };
        let (eat, ans_logits) = reasoner.probe(&rt.client, cache, &answer_suffix)?;
        // EAT without prefix (Eq. 12)
        let (eat_plain, _) =
            reasoner.probe(&rt.client, cache, &vocab.suffix_plain())?;
        // entropy after newline (Eq. 14)
        let (eat_nl, _) =
            reasoner.probe(&rt.client, cache, &vocab.suffix_newline())?;
        // cross-model EAT (black-box monitor)
        let eat_proxy = match proxy_cache {
            Some(pc) => Some(
                monitor
                    .probe(&rt.client, pc, &vocab.suffix_prefixed())?
                    .0 as f64,
            ),
            None => None,
        };

        let vhat = ema.update(eat as f64);

        // Pass@1(Avg@K), Eq. 9: the answer is the single token after the
        // forced suffix, so the rollout distribution IS the probed logits
        // under the serve-time sampler.
        let probs = sampler.probs(&ans_logits);
        let p_correct = q
            .answer
            .map(|a| probs[vocab.num(a) as usize] as f64)
            .unwrap_or(0.0);
        let mut hits = 0usize;
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..AVG_K {
            let t = sampler.sample(&ans_logits, rng);
            seen.insert(t);
            if let (Some(a), Some(v)) = (q.answer, vocab.num_value(t)) {
                hits += (v == a) as usize;
            }
        }

        let confidence = if self.with_confidence {
            Some(self.confidence(cache)?)
        } else {
            None
        };

        Ok(LinePoint {
            line,
            tokens,
            eat: eat as f64,
            eat_proxy,
            eat_plain: Some(eat_plain as f64),
            eat_newline: Some(eat_nl as f64),
            vhat,
            p_correct,
            pass1_avgk: hits as f64 / AVG_K as f64,
            unique_answers: seen.len(),
            confidence,
        })
    }

    /// Confidence (Eq. 16): greedy 5-token rollout on a forked cache.
    fn confidence(&self, cache: &KvCache) -> Result<f64> {
        let rt = self.rt;
        let (reasoner, _) = self.models();
        let suffix = rt.cfg.vocab.suffix_prefixed();
        let mut fork = reasoner.fork_cache(&rt.client, cache)?;
        let mut logits = Vec::new();
        for &t in &suffix {
            logits = reasoner.decode(&rt.client, &mut fork, t)?;
        }
        let mut lp = 0.0f64;
        let mut n = 0usize;
        for _ in 0..5 {
            if fork.pos >= reasoner.cfg.seq_len {
                break;
            }
            let tok = crate::sampler::argmax(&logits);
            lp += Sampler::logprob(&logits, tok);
            logits = reasoner.decode(&rt.client, &mut fork, tok)?;
            n += 1;
        }
        Ok((lp / n.max(1) as f64).exp())
    }
}
