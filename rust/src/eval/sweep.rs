//! Threshold sweeps: build the Agg. Pass@1 vs total-token-usage curves of
//! §5.2/5.3 for every policy family, and the AUC efficiency metric.

use crate::exit::{ConfidencePolicy, EatPolicy, TokenBudgetPolicy, UniqueAnswersPolicy};
use crate::util::stats::auc_normalized;

use super::replay::{replay, Signal};
use super::store::TraceSet;

/// One point of an efficiency curve (a threshold setting evaluated over a
/// whole dataset).
#[derive(Debug, Clone)]
pub struct CurvePoint {
    /// The threshold that produced this point (delta, T, or Delta).
    pub threshold: f64,
    /// Total tokens over the dataset (reasoning + charged overhead).
    pub total_tokens: f64,
    /// Agg. Pass@1 (Eq. 11).
    pub agg_pass1: f64,
    /// Mean exit line (for diagnostics).
    pub mean_exit_line: f64,
}

#[derive(Debug, Clone)]
pub struct Curve {
    pub label: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    /// AUC of accuracy over normalized token usage (§5.2).
    pub fn auc(&self) -> f64 {
        auc_normalized(
            &self
                .points
                .iter()
                .map(|p| (p.total_tokens, p.agg_pass1))
                .collect::<Vec<_>>(),
        )
    }

    /// Tokens needed to reach (within the sweep) at least `acc` accuracy;
    /// None if never reached. Used for the headline "X% token saving at
    /// iso-accuracy" numbers.
    pub fn tokens_at_accuracy(&self, acc: f64) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.agg_pass1 >= acc)
            .map(|p| p.total_tokens)
            .fold(None, |m: Option<f64>, t| {
                Some(m.map_or(t, |m| m.min(t)))
            })
    }
}

fn aggregate(
    traces: &TraceSet,
    mut mk: impl FnMut() -> Box<dyn crate::exit::ExitPolicy>,
    signal: Signal,
    charge_overhead: bool,
    threshold: f64,
) -> CurvePoint {
    let mut tokens = 0.0;
    let mut acc = 0.0;
    let mut lines = 0.0;
    for t in &traces.traces {
        let mut policy = mk();
        let out = replay(t, policy.as_mut(), signal, charge_overhead);
        tokens += (out.reasoning_tokens + out.overhead_tokens) as f64;
        acc += out.accuracy;
        lines += out.exit_line.unwrap_or(t.points.len()) as f64;
    }
    let n = traces.traces.len().max(1) as f64;
    CurvePoint {
        threshold,
        total_tokens: tokens,
        agg_pass1: acc / n,
        mean_exit_line: lines / n,
    }
}

/// EAT sweep over variance thresholds delta (paper: 2^-{0..39}).
pub fn sweep_eat(
    traces: &TraceSet,
    signal: Signal,
    alpha: f64,
    deltas: &[f64],
    max_tokens: usize,
    charge_overhead: bool,
    label: &str,
) -> Curve {
    let points = deltas
        .iter()
        .map(|&d| {
            aggregate(
                traces,
                || Box::new(EatPolicy::new(alpha, d, max_tokens)),
                signal,
                charge_overhead,
                d,
            )
        })
        .collect();
    Curve {
        label: label.to_string(),
        points,
    }
}

/// Token-budget sweep over T (paper: 250 * {1..40}).
pub fn sweep_token(traces: &TraceSet, ts: &[usize], label: &str) -> Curve {
    let points = ts
        .iter()
        .map(|&t| {
            aggregate(
                traces,
                || Box::new(TokenBudgetPolicy::new(t)),
                Signal::MainPrefixed,
                false,
                t as f64,
            )
        })
        .collect();
    Curve {
        label: label.to_string(),
        points,
    }
}

/// #UA@K sweep over Delta for one K (paper: Delta in {1,2,3}, K in
/// {8,16,32}).
pub fn sweep_ua(
    traces: &TraceSet,
    k: usize,
    thresholds: &[usize],
    max_tokens: usize,
    charge_overhead: bool,
    every: usize,
    label: &str,
) -> Curve {
    let points = thresholds
        .iter()
        .map(|&d| {
            aggregate(
                traces,
                || Box::new(UniqueAnswersPolicy::with_stride(k, d, max_tokens, every)),
                Signal::MainPrefixed,
                charge_overhead,
                d as f64,
            )
        })
        .collect();
    Curve {
        label: label.to_string(),
        points,
    }
}

/// Confidence sweep over delta (Fig. 4).
pub fn sweep_confidence(
    traces: &TraceSet,
    alpha: f64,
    deltas: &[f64],
    max_tokens: usize,
    charge_overhead: bool,
    label: &str,
) -> Curve {
    let points = deltas
        .iter()
        .map(|&d| {
            aggregate(
                traces,
                || Box::new(ConfidencePolicy::new(alpha, d, max_tokens)),
                Signal::MainPrefixed,
                charge_overhead,
                d,
            )
        })
        .collect();
    Curve {
        label: label.to_string(),
        points,
    }
}

/// Default delta sweep: 2^0 .. 2^-23 (the paper sweeps to 2^-39; our EAT
/// floors are higher because the vocab is small).
pub fn default_deltas() -> Vec<f64> {
    (0..24).map(|i| 2f64.powi(-i)).collect()
}

/// Default token budgets: 6 * {1..16} reasoning tokens (scaled from the
/// paper's 250 * {1..40} against 10K budgets).
pub fn default_token_budgets(max: usize) -> Vec<usize> {
    let step = (max / 16).max(1);
    (1..=16).map(|i| i * step).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::{LinePoint, Trace};

    fn mk_traces() -> TraceSet {
        // 3 questions of widely-spread difficulty, stabilizing at lines
        // 2, 10 and 40 of a 60-line trace (adaptivity is what EAT exploits)
        let traces = [2usize, 10, 40]
            .iter()
            .enumerate()
            .map(|(id, &st)| Trace {
                question_id: id,
                n_ops: st,
                answer: Some(1),
                prompt_tokens: 6,
                self_terminated: false,
                reasoning_tokens: vec![0; 180],
                points: (1..=60)
                    .map(|i| LinePoint {
                        line: i,
                        tokens: i * 3,
                        eat: if i >= st { 0.02 } else { 2.0 + (i % 2) as f64 },
                        eat_proxy: Some(if i >= st { 0.05 } else { 2.2 + (i % 2) as f64 }),
                        eat_plain: None,
                        eat_newline: None,
                        vhat: f64::INFINITY,
                        p_correct: if i >= st { 0.98 } else { 0.1 },
                        pass1_avgk: if i >= st { 1.0 } else { 0.1 },
                        unique_answers: if i >= st { 1 } else { 10 },
                        confidence: Some(if i >= st { 0.9 } else { 0.2 }),
                    })
                    .collect(),
            })
            .collect();
        TraceSet {
            dataset: "unit".into(),
            traces,
        }
    }

    #[test]
    fn eat_beats_token_budget_auc() {
        // The core paper claim in miniature: with per-question adaptive
        // exits, EAT reaches high accuracy with fewer total tokens than
        // any fixed budget.
        let ts = mk_traces();
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &default_deltas(),
            10_000,
            false,
            "eat",
        );
        let tok = sweep_token(
            &ts,
            &(1..=15).map(|i| i * 12).collect::<Vec<_>>(),
            "token",
        );
        assert!(eat.auc() > tok.auc(), "eat={} tok={}", eat.auc(), tok.auc());
    }

    #[test]
    fn iso_accuracy_saving() {
        let ts = mk_traces();
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &default_deltas(),
            10_000,
            false,
            "eat",
        );
        let tok = sweep_token(&ts, &(1..=60).map(|i| i * 3).collect::<Vec<_>>(), "token");
        let e = eat.tokens_at_accuracy(0.95).unwrap();
        let t = tok.tokens_at_accuracy(0.95).unwrap();
        assert!(e < t, "eat tokens {e} >= budget tokens {t}");
    }

    #[test]
    fn ua_charged_overhead_dominates() {
        // Fig. 6b in miniature: with overhead charged, #UA@32 uses far
        // more tokens than EAT at the same accuracy.
        let ts = mk_traces();
        let ua = sweep_ua(&ts, 32, &[1], 10_000, true, 1, "ua32");
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &[1e-4],
            10_000,
            true,
            "eat",
        );
        assert!(ua.points[0].total_tokens > 3.0 * eat.points[0].total_tokens);
    }

    #[test]
    fn curve_helpers() {
        let c = Curve {
            label: "x".into(),
            points: vec![
                CurvePoint {
                    threshold: 1.0,
                    total_tokens: 10.0,
                    agg_pass1: 0.5,
                    mean_exit_line: 2.0,
                },
                CurvePoint {
                    threshold: 0.5,
                    total_tokens: 20.0,
                    agg_pass1: 0.9,
                    mean_exit_line: 4.0,
                },
            ],
        };
        assert_eq!(c.tokens_at_accuracy(0.8), Some(20.0));
        assert_eq!(c.tokens_at_accuracy(0.99), None);
        assert!(c.auc() > 0.0);
    }
}
