//! Threshold sweeps: build the Agg. Pass@1 vs total-token-usage curves of
//! §5.2/5.3 for every policy family, and the AUC efficiency metric.
//! [`sweep_policy`] is the one generic kernel — every named sweep (and
//! the whole zoo harness in [`super::zoo`]) is a policy factory handed to
//! it, so a new stopping rule costs one closure, not a new sweep loop.

use crate::exit::{ConfidencePolicy, EatPolicy, ExitPolicy, TokenBudgetPolicy, UniqueAnswersPolicy};
use crate::util::stats::auc_normalized_counting;

use super::replay::{replay, Signal};
use super::store::TraceSet;

/// One point of an efficiency curve (a threshold setting evaluated over a
/// whole dataset).
#[derive(Debug, Clone)]
pub struct CurvePoint {
    /// The threshold that produced this point (delta, T, or Delta).
    pub threshold: f64,
    /// Total tokens over the dataset (reasoning + charged overhead).
    pub total_tokens: f64,
    /// Agg. Pass@1 (Eq. 11).
    pub agg_pass1: f64,
    /// Mean exit line (for diagnostics).
    pub mean_exit_line: f64,
}

#[derive(Debug, Clone)]
pub struct Curve {
    pub label: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    /// AUC of accuracy over normalized token usage (§5.2).
    pub fn auc(&self) -> f64 {
        self.auc_with_skipped().0
    }

    /// AUC plus the number of non-finite points the NaN contract dropped
    /// (see [`crate::util::stats::auc_normalized_counting`]): a poisoned
    /// replay contributes a skip count to the report, not a panic.
    pub fn auc_with_skipped(&self) -> (f64, usize) {
        auc_normalized_counting(
            &self
                .points
                .iter()
                .map(|p| (p.total_tokens, p.agg_pass1))
                .collect::<Vec<_>>(),
        )
    }

    /// Tokens needed to reach (within the sweep) at least `acc` accuracy;
    /// None if never reached. Used for the headline "X% token saving at
    /// iso-accuracy" numbers.
    pub fn tokens_at_accuracy(&self, acc: f64) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.agg_pass1 >= acc)
            .map(|p| p.total_tokens)
            .fold(None, |m: Option<f64>, t| {
                Some(m.map_or(t, |m| m.min(t)))
            })
    }
}

fn aggregate(
    traces: &TraceSet,
    mut mk: impl FnMut() -> Box<dyn crate::exit::ExitPolicy>,
    signal: Signal,
    charge_overhead: bool,
    threshold: f64,
) -> CurvePoint {
    let mut tokens = 0.0;
    let mut acc = 0.0;
    let mut lines = 0.0;
    for t in &traces.traces {
        let mut policy = mk();
        let out = replay(t, policy.as_mut(), signal, charge_overhead);
        tokens += (out.reasoning_tokens + out.overhead_tokens) as f64;
        acc += out.accuracy;
        lines += out.exit_line.unwrap_or(t.points.len()) as f64;
    }
    let n = traces.traces.len().max(1) as f64;
    CurvePoint {
        threshold,
        total_tokens: tokens,
        agg_pass1: acc / n,
        mean_exit_line: lines / n,
    }
}

/// The generic sweep kernel: one curve point per threshold, each built by
/// replaying every trace against a policy minted by `mk(threshold)`.
/// Every named sweep below delegates here, and the zoo harness
/// ([`super::zoo::run_zoo`]) races whole families through it — the
/// threshold is whatever dial the family sweeps (delta, T, Delta,
/// level, patience...), always carried as f64 in `CurvePoint::threshold`.
pub fn sweep_policy<F>(
    traces: &TraceSet,
    thresholds: &[f64],
    signal: Signal,
    charge_overhead: bool,
    label: &str,
    mut mk: F,
) -> Curve
where
    F: FnMut(f64) -> Box<dyn ExitPolicy>,
{
    let points = thresholds
        .iter()
        .map(|&t| aggregate(traces, || mk(t), signal, charge_overhead, t))
        .collect();
    Curve {
        label: label.to_string(),
        points,
    }
}

/// EAT sweep over variance thresholds delta (paper: 2^-{0..39}).
pub fn sweep_eat(
    traces: &TraceSet,
    signal: Signal,
    alpha: f64,
    deltas: &[f64],
    max_tokens: usize,
    charge_overhead: bool,
    label: &str,
) -> Curve {
    sweep_policy(traces, deltas, signal, charge_overhead, label, |d| {
        Box::new(EatPolicy::new(alpha, d, max_tokens))
    })
}

/// Token-budget sweep over T (paper: 250 * {1..40}).
pub fn sweep_token(traces: &TraceSet, ts: &[usize], label: &str) -> Curve {
    let budgets: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
    sweep_policy(traces, &budgets, Signal::MainPrefixed, false, label, |t| {
        Box::new(TokenBudgetPolicy::new(t as usize))
    })
}

/// #UA@K sweep over Delta for one K (paper: Delta in {1,2,3}, K in
/// {8,16,32}).
pub fn sweep_ua(
    traces: &TraceSet,
    k: usize,
    thresholds: &[usize],
    max_tokens: usize,
    charge_overhead: bool,
    every: usize,
    label: &str,
) -> Curve {
    let deltas: Vec<f64> = thresholds.iter().map(|&d| d as f64).collect();
    sweep_policy(
        traces,
        &deltas,
        Signal::MainPrefixed,
        charge_overhead,
        label,
        |d| Box::new(UniqueAnswersPolicy::with_stride(k, d as usize, max_tokens, every)),
    )
}

/// Confidence sweep over delta (Fig. 4).
pub fn sweep_confidence(
    traces: &TraceSet,
    alpha: f64,
    deltas: &[f64],
    max_tokens: usize,
    charge_overhead: bool,
    label: &str,
) -> Curve {
    sweep_policy(
        traces,
        deltas,
        Signal::MainPrefixed,
        charge_overhead,
        label,
        |d| Box::new(ConfidencePolicy::new(alpha, d, max_tokens)),
    )
}

/// Default delta sweep: the 24 thresholds 2^-i for i in 0..=23, i.e.
/// 2^0 down to 2^-23 halving each step (the paper sweeps to 2^-39; our
/// EAT floors are higher because the vocab is small).
pub fn default_deltas() -> Vec<f64> {
    (0..24).map(|i| 2f64.powi(-i)).collect()
}

/// Default token budgets: 16 evenly spaced budgets `step * {1..16}` with
/// `step = (max/16).max(1)` — e.g. 6 * {1..16} for the default 96-token
/// cap (scaled from the paper's 250 * {1..40} against 10K budgets). A
/// `max` below 16 clamps the step to 1, so the grid is always 16
/// strictly positive budgets.
pub fn default_token_budgets(max: usize) -> Vec<usize> {
    let step = (max / 16).max(1);
    (1..=16).map(|i| i * step).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::{LinePoint, Trace};

    fn mk_traces() -> TraceSet {
        // 3 questions of widely-spread difficulty, stabilizing at lines
        // 2, 10 and 40 of a 60-line trace (adaptivity is what EAT exploits)
        let traces = [2usize, 10, 40]
            .iter()
            .enumerate()
            .map(|(id, &st)| Trace {
                question_id: id,
                n_ops: st,
                answer: Some(1),
                prompt_tokens: 6,
                self_terminated: false,
                reasoning_tokens: vec![0; 180],
                points: (1..=60)
                    .map(|i| LinePoint {
                        line: i,
                        tokens: i * 3,
                        eat: if i >= st { 0.02 } else { 2.0 + (i % 2) as f64 },
                        eat_proxy: Some(if i >= st { 0.05 } else { 2.2 + (i % 2) as f64 }),
                        eat_plain: None,
                        eat_newline: None,
                        vhat: f64::INFINITY,
                        p_correct: if i >= st { 0.98 } else { 0.1 },
                        pass1_avgk: if i >= st { 1.0 } else { 0.1 },
                        unique_answers: if i >= st { 1 } else { 10 },
                        confidence: Some(if i >= st { 0.9 } else { 0.2 }),
                    })
                    .collect(),
            })
            .collect();
        TraceSet {
            dataset: "unit".into(),
            traces,
        }
    }

    #[test]
    fn eat_beats_token_budget_auc() {
        // The core paper claim in miniature: with per-question adaptive
        // exits, EAT reaches high accuracy with fewer total tokens than
        // any fixed budget.
        let ts = mk_traces();
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &default_deltas(),
            10_000,
            false,
            "eat",
        );
        let tok = sweep_token(
            &ts,
            &(1..=15).map(|i| i * 12).collect::<Vec<_>>(),
            "token",
        );
        assert!(eat.auc() > tok.auc(), "eat={} tok={}", eat.auc(), tok.auc());
    }

    #[test]
    fn iso_accuracy_saving() {
        let ts = mk_traces();
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &default_deltas(),
            10_000,
            false,
            "eat",
        );
        let tok = sweep_token(&ts, &(1..=60).map(|i| i * 3).collect::<Vec<_>>(), "token");
        let e = eat.tokens_at_accuracy(0.95).unwrap();
        let t = tok.tokens_at_accuracy(0.95).unwrap();
        assert!(e < t, "eat tokens {e} >= budget tokens {t}");
    }

    #[test]
    fn ua_charged_overhead_dominates() {
        // Fig. 6b in miniature: with overhead charged, #UA@32 uses far
        // more tokens than EAT at the same accuracy.
        let ts = mk_traces();
        let ua = sweep_ua(&ts, 32, &[1], 10_000, true, 1, "ua32");
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &[1e-4],
            10_000,
            true,
            "eat",
        );
        assert!(ua.points[0].total_tokens > 3.0 * eat.points[0].total_tokens);
    }

    #[test]
    fn default_grids_match_their_docs() {
        // pins the documented shapes (the doc comments drifted once)
        let d = default_deltas();
        assert_eq!(d.len(), 24);
        assert_eq!(d[0], 1.0);
        assert_eq!(d[23], 2f64.powi(-23));
        assert!(d.windows(2).all(|w| w[1] == w[0] / 2.0), "halving grid");
        let b = default_token_budgets(96);
        assert_eq!(b, (1..=16).map(|i| i * 6).collect::<Vec<_>>());
        // a max below 16 still yields 16 strictly positive budgets
        assert_eq!(default_token_budgets(5), (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn generic_sweep_matches_specialized_sweeps() {
        let ts = mk_traces();
        let spec = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &default_deltas(),
            10_000,
            true,
            "eat",
        );
        let via_factory = sweep_policy(
            &ts,
            &default_deltas(),
            Signal::MainPrefixed,
            true,
            "eat",
            |d| Box::new(EatPolicy::new(0.2, d, 10_000)),
        );
        assert_eq!(spec.points.len(), via_factory.points.len());
        for (a, b) in spec.points.iter().zip(&via_factory.points) {
            assert_eq!(a.total_tokens.to_bits(), b.total_tokens.to_bits());
            assert_eq!(a.agg_pass1.to_bits(), b.agg_pass1.to_bits());
            assert_eq!(a.mean_exit_line.to_bits(), b.mean_exit_line.to_bits());
        }
    }

    #[test]
    fn nan_eat_sample_still_produces_a_finished_report() {
        // the ISSUE regression: a NaN EAT sample anywhere in a trace must
        // yield a complete sweep (the poisoned trace runs to its end —
        // NaN means "no adaptive exit", not "panic")
        let mut ts = mk_traces();
        ts.traces[1].points[3].eat = f64::NAN;
        let eat = sweep_eat(
            &ts,
            Signal::MainPrefixed,
            0.2,
            &default_deltas(),
            10_000,
            true,
            "eat",
        );
        assert_eq!(eat.points.len(), default_deltas().len());
        assert!(eat.points.iter().all(|p| p.total_tokens.is_finite()));
        let (auc, skipped) = eat.auc_with_skipped();
        assert!(auc.is_finite() && auc > 0.0);
        assert_eq!(skipped, 0, "aggregates stay finite, nothing to skip");
        assert!(eat.tokens_at_accuracy(0.5).is_some());
    }

    #[test]
    fn curve_helpers() {
        let c = Curve {
            label: "x".into(),
            points: vec![
                CurvePoint {
                    threshold: 1.0,
                    total_tokens: 10.0,
                    agg_pass1: 0.5,
                    mean_exit_line: 2.0,
                },
                CurvePoint {
                    threshold: 0.5,
                    total_tokens: 20.0,
                    agg_pass1: 0.9,
                    mean_exit_line: 4.0,
                },
            ],
        };
        assert_eq!(c.tokens_at_accuracy(0.8), Some(20.0));
        assert_eq!(c.tokens_at_accuracy(0.99), None);
        assert!(c.auc() > 0.0);
    }
}
