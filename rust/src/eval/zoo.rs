//! The exit-policy zoo frontier harness (DESIGN.md §3.9): race every
//! stopping rule in [`crate::exit`] — the paper's EAT and its baselines
//! plus the related-work policies and combinators — over one `TraceSet`,
//! through the single generic sweep kernel [`super::sweep::sweep_policy`].
//!
//! Each family is swept twice (probe overhead charged and raw) and scored
//! on the same axes: AUC of accuracy over normalized token usage,
//! iso-accuracy token cost vs the fixed-budget family, and the mean exit
//! line at the headline operating point. The charged curves are then
//! pooled into one epsilon-dominance Pareto frontier, where epsilon is
//! one reasoning line per question in total-token units — the
//! measurement granularity of a line-boundary stopping rule, so policies
//! that exit within a line of each other *share* the frontier instead of
//! shadowing each other over rounding noise.
//!
//! Everything is deterministic given the trace set: the report JSON uses
//! sorted keys ([`crate::util::json::Json::Obj`] is a `BTreeMap`) and two
//! runs over the same traces are byte-identical — CI diffs them.

use crate::exit::{
    AllOf, AnswerConsistencyPolicy, ConfidencePolicy, CumulativeEntropyPolicy, EatPolicy,
    ExitPolicy, PathDeviationPolicy, SequenceEntropyPolicy, StallAwareEatPolicy,
    TokenBudgetPolicy, UniqueAnswersPolicy, WeightedEnsemble, DEFAULT_CUM_BUDGET_NATS,
};
use crate::util::json::Json;

use super::replay::Signal;
use super::store::TraceSet;
use super::sweep::{default_deltas, default_token_budgets, sweep_policy, Curve, CurvePoint};

/// Knobs shared by every family in the race (per-family thresholds are
/// the swept dial, not config).
#[derive(Debug, Clone)]
pub struct ZooConfig {
    /// EMA timescale for every EMA-based policy.
    pub alpha: f64,
    /// Universal token-budget backstop handed to every adaptive policy.
    pub max_tokens: usize,
    /// Iso-accuracy target as a fraction of the token family's best raw
    /// accuracy (the paper's "98% of majority accuracy" convention).
    pub iso_frac: f64,
    /// K for the #UA@K family.
    pub ua_k: usize,
    /// Total-entropy budget for the cumulative-entropy family (nats).
    pub cum_budget_nats: f64,
    /// Quorum for the weighted-ensemble family.
    pub ensemble_quorum: f64,
}

impl Default for ZooConfig {
    fn default() -> Self {
        ZooConfig {
            alpha: 0.2,
            max_tokens: 10_000,
            iso_frac: 0.98,
            ua_k: 16,
            cum_budget_nats: DEFAULT_CUM_BUDGET_NATS,
            ensemble_quorum: 0.5,
        }
    }
}

/// One row of the Pareto table.
#[derive(Debug, Clone)]
pub struct FamilyResult {
    pub family: String,
    /// AUC without probe overhead (generation tokens only).
    pub auc_raw: f64,
    /// AUC with the cost model's probe/rollout overhead charged.
    pub auc_charged: f64,
    /// Non-finite curve points the NaN contract dropped from each AUC.
    pub skipped_raw: usize,
    pub skipped_charged: usize,
    /// Cheapest total tokens reaching the iso-accuracy target (None if
    /// the family never reaches it within its sweep).
    pub iso_tokens_raw: Option<f64>,
    pub iso_tokens_charged: Option<f64>,
    /// Raw-token saving vs the fixed-budget family at iso-accuracy, in
    /// percent (None when either side never reaches the target).
    pub saving_vs_token_pct: Option<f64>,
    /// Mean exit line at the headline operating point: the cheapest
    /// iso-reaching raw point, else the family's most accurate point.
    pub mean_exit_line: f64,
    /// Whether the family owns at least one non-dominated point of the
    /// pooled overhead-charged frontier.
    pub on_frontier: bool,
    pub raw: Curve,
    pub charged: Curve,
}

#[derive(Debug, Clone)]
pub struct ZooReport {
    pub dataset: String,
    pub n_traces: usize,
    /// The resolved iso-accuracy target (iso_frac x token-family best).
    pub iso_accuracy: f64,
    /// The frontier's token tolerance: one reasoning line per question.
    pub eps_tokens: f64,
    pub families: Vec<FamilyResult>,
}

type PolicyMk = Box<dyn Fn(f64) -> Box<dyn ExitPolicy>>;

/// Non-dominated mask over `(total_tokens, accuracy)` points under
/// epsilon-dominance: `q` dominates `p` iff `q` is weakly better on both
/// axes *and* strictly better on at least one by more than the tolerance
/// (`eps_tokens` on the token axis). Points within one line's worth of
/// tokens at equal accuracy therefore share the frontier. Non-finite
/// points are never on the frontier and never dominate.
pub fn pareto_non_dominated(points: &[(f64, f64)], eps_tokens: f64) -> Vec<bool> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(pt, pa))| {
            if !pt.is_finite() || !pa.is_finite() {
                return false;
            }
            !points.iter().enumerate().any(|(j, &(qt, qa))| {
                j != i
                    && qt.is_finite()
                    && qa.is_finite()
                    && qt <= pt
                    && qa >= pa
                    && (qt <= pt - eps_tokens || qa >= pa + 1e-9)
            })
        })
        .collect()
}

fn headline_exit_line(raw: &Curve, iso: f64) -> f64 {
    let at_iso = raw
        .points
        .iter()
        .filter(|p| p.agg_pass1 >= iso)
        .min_by(|a, b| a.total_tokens.total_cmp(&b.total_tokens));
    match at_iso {
        Some(p) => p.mean_exit_line,
        None => raw
            .points
            .iter()
            .max_by(|a, b| a.agg_pass1.total_cmp(&b.agg_pass1))
            .map(|p| p.mean_exit_line)
            .unwrap_or(0.0),
    }
}

/// Race every policy family over `traces` and score the Pareto table.
pub fn run_zoo(traces: &TraceSet, zc: &ZooConfig) -> ZooReport {
    let (alpha, max_tokens) = (zc.alpha, zc.max_tokens);
    let (ua_k, cum_budget, quorum) = (zc.ua_k, zc.cum_budget_nats, zc.ensemble_quorum);

    let tmax = traces
        .traces
        .iter()
        .filter_map(|t| t.points.last())
        .map(|p| p.tokens)
        .max()
        .unwrap_or(96);
    let deltas = default_deltas();
    let budgets: Vec<f64> = default_token_budgets(tmax)
        .into_iter()
        .map(|b| b as f64)
        .collect();
    // entropy levels: a geometric ladder from "any line passes" down to
    // "essentially deterministic", the level-rule analog of the delta grid
    let levels: Vec<f64> = (0..16).map(|i| 3.5 * 0.75f64.powi(i)).collect();
    let ua_thresholds = vec![1.0, 2.0, 3.0];
    let patiences = vec![1.0, 2.0, 3.0, 4.0];

    let families: Vec<(&'static str, Vec<f64>, PolicyMk)> = vec![
        (
            "eat",
            deltas.clone(),
            Box::new(move |d| Box::new(EatPolicy::new(alpha, d, max_tokens))),
        ),
        (
            "eat-stall",
            deltas.clone(),
            Box::new(move |d| Box::new(StallAwareEatPolicy::new(alpha, d, max_tokens))),
        ),
        (
            "token",
            budgets,
            Box::new(|t| Box::new(TokenBudgetPolicy::new(t as usize))),
        ),
        (
            "ua",
            ua_thresholds,
            Box::new(move |d| {
                Box::new(UniqueAnswersPolicy::with_stride(
                    ua_k, d as usize, max_tokens, 1,
                ))
            }),
        ),
        (
            "confidence",
            deltas.clone(),
            Box::new(move |d| Box::new(ConfidencePolicy::new(alpha, d, max_tokens))),
        ),
        (
            "path-dev",
            deltas.clone(),
            Box::new(move |d| Box::new(PathDeviationPolicy::new(alpha, d, max_tokens))),
        ),
        (
            "seq-entropy",
            levels.clone(),
            Box::new(move |l| Box::new(SequenceEntropyPolicy::new(l, max_tokens))),
        ),
        (
            "cum-entropy",
            levels,
            Box::new(move |l| {
                Box::new(CumulativeEntropyPolicy::new(alpha, l, cum_budget, max_tokens))
            }),
        ),
        (
            "consistency",
            patiences,
            Box::new(move |p| {
                Box::new(AnswerConsistencyPolicy::with_stride(
                    8, p as usize, max_tokens, 2,
                ))
            }),
        ),
        (
            "all(eat&conf)",
            deltas.clone(),
            Box::new(move |d| {
                Box::new(AllOf::new(vec![
                    Box::new(EatPolicy::new(alpha, d, max_tokens)),
                    Box::new(ConfidencePolicy::new(alpha, d, max_tokens)),
                ]))
            }),
        ),
        (
            "vote(eat+stall+conf)",
            deltas,
            Box::new(move |d| {
                Box::new(WeightedEnsemble::new(
                    vec![
                        (2.0, Box::new(EatPolicy::new(alpha, d, max_tokens))),
                        (1.0, Box::new(StallAwareEatPolicy::new(alpha, d, max_tokens))),
                        (1.0, Box::new(ConfidencePolicy::new(alpha, d, max_tokens))),
                    ],
                    quorum,
                ))
            }),
        ),
    ];

    let curves: Vec<(String, Curve, Curve)> = families
        .into_iter()
        .map(|(name, grid, mk)| {
            let raw = sweep_policy(traces, &grid, Signal::MainPrefixed, false, name, |d| mk(d));
            let charged = sweep_policy(traces, &grid, Signal::MainPrefixed, true, name, |d| mk(d));
            (name.to_string(), raw, charged)
        })
        .collect();

    // iso target anchored on the fixed-budget family: the universal
    // baseline every adaptive rule is trying to beat
    let token_raw = &curves
        .iter()
        .find(|(n, _, _)| n == "token")
        .expect("zoo always includes the token family")
        .1;
    let token_best = token_raw
        .points
        .iter()
        .map(|p| p.agg_pass1)
        .fold(0.0f64, |m, a| if a.is_finite() { m.max(a) } else { m });
    let iso = zc.iso_frac * token_best;
    let token_iso_raw = token_raw.tokens_at_accuracy(iso);

    // one reasoning line per question, in total-token units: the
    // granularity below which two exit rules are indistinguishable
    let total_last: f64 = traces
        .traces
        .iter()
        .filter_map(|t| t.points.last())
        .map(|p| p.tokens as f64)
        .sum();
    let total_lines: f64 = traces.traces.iter().map(|t| t.points.len() as f64).sum();
    let n_traces = traces.traces.len();
    let eps_tokens = if total_lines > 0.0 {
        (total_last / total_lines) * n_traces as f64
    } else {
        0.0
    };

    // pooled frontier over the charged curves
    let pool: Vec<(f64, f64)> = curves
        .iter()
        .flat_map(|(_, _, charged)| charged.points.iter().map(|p| (p.total_tokens, p.agg_pass1)))
        .collect();
    let mask = pareto_non_dominated(&pool, eps_tokens);
    let mut offset = 0usize;
    let families = curves
        .into_iter()
        .map(|(family, raw, charged)| {
            let n_pts = charged.points.len();
            let on_frontier = mask[offset..offset + n_pts].iter().any(|&m| m);
            offset += n_pts;
            let (auc_raw, skipped_raw) = raw.auc_with_skipped();
            let (auc_charged, skipped_charged) = charged.auc_with_skipped();
            let iso_tokens_raw = raw.tokens_at_accuracy(iso);
            let iso_tokens_charged = charged.tokens_at_accuracy(iso);
            let saving_vs_token_pct = match (iso_tokens_raw, token_iso_raw) {
                (Some(f), Some(t)) if t > 0.0 => Some(100.0 * (1.0 - f / t)),
                _ => None,
            };
            FamilyResult {
                mean_exit_line: headline_exit_line(&raw, iso),
                family,
                auc_raw,
                auc_charged,
                skipped_raw,
                skipped_charged,
                iso_tokens_raw,
                iso_tokens_charged,
                saving_vs_token_pct,
                on_frontier,
                raw,
                charged,
            }
        })
        .collect();

    ZooReport {
        dataset: traces.dataset.clone(),
        n_traces,
        iso_accuracy: iso,
        eps_tokens,
        families,
    }
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

fn opt_num(x: Option<f64>) -> Json {
    x.map_or(Json::Null, num_or_null)
}

fn curve_json(c: &Curve) -> Json {
    Json::arr(c.points.iter().map(|p: &CurvePoint| {
        Json::obj(vec![
            ("threshold", num_or_null(p.threshold)),
            ("total_tokens", num_or_null(p.total_tokens)),
            ("agg_pass1", num_or_null(p.agg_pass1)),
            ("mean_exit_line", num_or_null(p.mean_exit_line)),
        ])
    }))
}

/// Serialize the Pareto table with sorted keys — byte-identical across
/// runs over the same traces (CI double-runs `repro sweep-zoo` and diffs).
pub fn zoo_report_json(r: &ZooReport) -> Json {
    Json::obj(vec![
        ("dataset", Json::str(r.dataset.clone())),
        ("n_traces", Json::num(r.n_traces as f64)),
        ("iso_accuracy", num_or_null(r.iso_accuracy)),
        ("eps_tokens", num_or_null(r.eps_tokens)),
        (
            "families",
            Json::arr(r.families.iter().map(|f| {
                Json::obj(vec![
                    ("family", Json::str(f.family.clone())),
                    ("auc_raw", num_or_null(f.auc_raw)),
                    ("auc_charged", num_or_null(f.auc_charged)),
                    ("skipped_raw", Json::num(f.skipped_raw as f64)),
                    ("skipped_charged", Json::num(f.skipped_charged as f64)),
                    ("iso_tokens_raw", opt_num(f.iso_tokens_raw)),
                    ("iso_tokens_charged", opt_num(f.iso_tokens_charged)),
                    ("saving_vs_token_pct", opt_num(f.saving_vs_token_pct)),
                    ("mean_exit_line", num_or_null(f.mean_exit_line)),
                    ("on_frontier", Json::Bool(f.on_frontier)),
                    ("curve_raw", curve_json(&f.raw)),
                    ("curve_charged", curve_json(&f.charged)),
                ])
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::{LinePoint, Trace};

    /// Traces with *heterogeneous entropy scales* — the geometry the
    /// paper's variance rule is built for. Each tuple is
    /// `(stabilize_at, pre_mid, post_level)`: before stabilization EAT
    /// oscillates `pre_mid ± 0.5`; after it, EAT sits flat at
    /// `post_level` — but `post_level` differs *per question* (one
    /// question settles near 0, another plateaus at 1.2 nats), so no
    /// single absolute level threshold serves all questions, while a
    /// scale-free variance rule exits each as soon as its own signal
    /// flattens. Lines are 24 tokens, so the 3-token probe overhead is
    /// ~12% (the paper's regime), not 100%.
    fn step_traces(shapes: &[(usize, f64, f64)]) -> TraceSet {
        let traces = shapes
            .iter()
            .enumerate()
            .map(|(id, &(st, pre_mid, post_level))| Trace {
                question_id: id,
                n_ops: st,
                answer: Some(1),
                prompt_tokens: 6,
                self_terminated: false,
                reasoning_tokens: vec![0; 60 * 24],
                points: (1..=60)
                    .map(|i| {
                        let osc = (i % 2) as f64; // 0/1 alternation
                        let stable = i >= st;
                        LinePoint {
                            line: i,
                            tokens: i * 24,
                            eat: if stable {
                                post_level
                            } else {
                                pre_mid - 0.5 + osc
                            },
                            eat_proxy: Some(if stable {
                                post_level + 0.25
                            } else {
                                pre_mid - 0.25 + osc
                            }),
                            eat_plain: None,
                            eat_newline: None,
                            vhat: f64::INFINITY,
                            p_correct: if stable { 0.98 } else { 0.1 },
                            pass1_avgk: if stable { 1.0 } else { 0.1 },
                            // answer consistency converges a few lines
                            // after the entropy flattens, one answer at
                            // a time — no oracle snap-to-1 at `st`
                            unique_answers: if stable {
                                (8usize).saturating_sub(i - st).max(1)
                            } else {
                                8
                            },
                            // settled confidence still jitters a little:
                            // its variance floor is ~4e-4, not zero
                            confidence: Some(if stable {
                                0.88 + 0.04 * osc
                            } else {
                                0.2 + 0.2 * osc
                            }),
                        }
                    })
                    .collect(),
            })
            .collect();
        TraceSet {
            dataset: "zoo-unit".into(),
            traces,
        }
    }

    /// Easy / medium / hard questions stabilizing at lines 2, 10 and 40,
    /// settling onto *different* entropy plateaus (0.02, 1.2, 0.5 nats).
    fn zoo_traces() -> TraceSet {
        step_traces(&[(2, 2.5, 0.02), (10, 3.5, 1.2), (40, 2.0, 0.5)])
    }

    #[test]
    fn zoo_covers_required_families_with_eat_on_frontier() {
        let ts = zoo_traces();
        let report = run_zoo(&ts, &ZooConfig::default());
        let names: Vec<&str> = report.families.iter().map(|f| f.family.as_str()).collect();
        let req = ["eat", "token", "ua", "confidence", "path-dev", "seq-entropy", "cum-entropy"];
        for required in req {
            assert!(names.contains(&required), "missing family {required}");
        }
        assert!(
            names.iter().any(|n| n.contains('(')),
            "at least one combinator family must race: {names:?}"
        );
        assert!(names.len() >= 7);
        let eat = report.families.iter().find(|f| f.family == "eat").unwrap();
        assert!(eat.on_frontier, "EAT must own a point of the charged frontier");
        assert!(eat.auc_raw > 0.0 && eat.auc_charged > 0.0);
        // the adaptive rule beats the fixed budget at iso-accuracy
        let saving = eat.saving_vs_token_pct.expect("eat reaches iso-accuracy");
        assert!(saving > 0.0, "saving={saving}");
        assert!(report.iso_accuracy > 0.5);
        assert!(report.eps_tokens > 0.0);
    }

    #[test]
    fn zoo_json_is_deterministic_and_sorted() {
        let ts = step_traces(&[(3, 2.5, 0.02), (20, 3.0, 0.8)]);
        let a = zoo_report_json(&run_zoo(&ts, &ZooConfig::default())).to_string();
        let b = zoo_report_json(&run_zoo(&ts, &ZooConfig::default())).to_string();
        assert_eq!(a, b, "same traces must serialize byte-identically");
        // BTreeMap keys: "auc_charged" precedes "auc_raw" in each family
        assert!(a.find("auc_charged").unwrap() < a.find("auc_raw").unwrap());
    }

    #[test]
    fn nan_poisoned_trace_still_yields_a_full_report() {
        let mut ts = zoo_traces();
        ts.traces[2].points[5].eat = f64::NAN;
        ts.traces[2].points[5].confidence = Some(f64::NAN);
        let report = run_zoo(&ts, &ZooConfig::default());
        assert_eq!(report.families.len(), 11);
        for f in &report.families {
            assert!(
                f.auc_raw.is_finite() && f.auc_charged.is_finite(),
                "family {} produced a non-finite AUC",
                f.family
            );
        }
        // serialization also survives
        let s = zoo_report_json(&report).to_string();
        assert!(s.contains("\"families\""));
    }

    #[test]
    fn frontier_epsilon_dominance_semantics() {
        // a and b are within one line of tokens at equal accuracy: both
        // survive; c is strictly worse on both axes: dominated; d is the
        // cheapest accurate point: survives; NaN never makes the frontier
        let pts = [(10.0, 0.9), (10.5, 0.9), (20.0, 0.5), (5.0, 0.95), (f64::NAN, 1.0)];
        let mask = pareto_non_dominated(&pts, 1.0);
        assert_eq!(mask, vec![true, true, false, true, false]);
        // with a zero tolerance the strictly-cheaper twin wins alone
        let tight = pareto_non_dominated(&[(10.0, 0.9), (10.5, 0.9)], 0.0);
        assert_eq!(tight, vec![true, false]);
    }
}
