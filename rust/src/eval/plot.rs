//! Terminal ASCII plots for trajectories and efficiency curves, so the
//! figure drivers give immediate visual feedback without a plotting stack
//! (results/*.csv carry the precise data).

/// Render one or more named series as an ASCII line chart.
/// Each series is a list of (x, y); x need not be aligned across series.
pub fn ascii_chart(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.1.iter().cloned()).collect();
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        if x.is_finite() {
            x0 = x0.min(x);
            x1 = x1.max(x);
        }
        if y.is_finite() {
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
    }
    if !x0.is_finite() || !y0.is_finite() {
        return format!("{title}: (no finite data)\n");
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }

    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        // draw with linear interpolation between consecutive points for
        // continuous-looking lines
        let mut sorted = pts.clone();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in sorted.windows(2) {
            let steps = width * 2;
            for s in 0..=steps {
                let t = s as f64 / steps as f64;
                let x = w[0].0 + t * (w[1].0 - w[0].0);
                let y = w[0].1 + t * (w[1].1 - w[0].1);
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
                let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
                let cy = height - 1 - cy.min(height - 1);
                grid[cy][cx.min(width - 1)] = mark;
            }
        }
        if sorted.len() == 1 {
            let (x, y) = sorted[0];
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy.min(height - 1)][cx.min(width - 1)] = mark;
        }
    }

    let mut out = format!("  {title}\n");
    for (i, row) in grid.iter().enumerate() {
        let yv = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
        out += &format!("  {yv:>9.3} |{}|\n", row.iter().collect::<String>());
    }
    out += &format!(
        "  {:>9} +{}+\n  {:>9}  {:<w$.3}{:>r$.3}\n",
        "",
        "-".repeat(width),
        "",
        x0,
        x1,
        w = width / 2,
        r = width - width / 2
    );
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", marks[i % marks.len()], name))
        .collect();
    out += &format!("  legend: {}\n", legend.join("   "));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic_chart() {
        let s = ascii_chart(
            "test",
            &[("a", vec![(0.0, 0.0), (1.0, 1.0)]), ("b", vec![(0.0, 1.0), (1.0, 0.0)])],
            40,
            10,
        );
        assert!(s.contains("test"));
        assert!(s.contains('*'));
        assert!(s.contains('o'));
        assert!(s.contains("legend"));
        assert_eq!(s.lines().count(), 14);
    }

    #[test]
    fn handles_empty_and_degenerate() {
        assert!(ascii_chart("e", &[("x", vec![])], 20, 5).contains("no data"));
        let s = ascii_chart("c", &[("x", vec![(1.0, 2.0)])], 20, 5);
        assert!(s.contains('*'));
    }

    #[test]
    fn ignores_nonfinite() {
        let s = ascii_chart(
            "nf",
            &[("x", vec![(0.0, 1.0), (1.0, f64::INFINITY), (2.0, 2.0)])],
            20,
            5,
        );
        assert!(s.contains('*'));
    }
}
